"""AOT plumbing: artifact registry + manifest structure, and one real
lowering round-trip (the smallest kernel) to catch HLO-text regressions."""

import json

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M


def test_build_artifacts_registry():
    arts = aot.build_artifacts()
    names = [a[0] for a in arts]
    for expected in (
        "mnist_round",
        "cifar_round",
        "cifar_round_e1",
        "unet_round",
        "mnist_eval",
        "cifar_eval",
        "unet_eval",
        "mnist_grad",
        "quant_cos_2",
        "dequant_cos_8",
    ):
        assert expected in names, expected
    assert len(names) == len(set(names))


def test_round_artifact_shapes():
    arts = {a[0]: a for a in aot.build_artifacts()}
    _, _, inputs = arts["mnist_round"]
    shapes = {n: tuple(s.shape) for n, s in inputs}
    assert shapes["params"] == (1_663_370,)
    assert shapes["x"] == (600, 784)
    assert shapes["y"] == (600,)
    assert shapes["perms"] == (60, 10)  # E=1, N=600, B=10
    assert shapes["lr"] == ()
    _, _, inputs = arts["cifar_round"]
    shapes = {n: tuple(s.shape) for n, s in inputs}
    assert shapes["perms"] == (50, 50)  # E=5, N=500, B=50
    _, _, inputs = arts["cifar_round_e1"]
    shapes = {n: tuple(s.shape) for n, s in inputs}
    assert shapes["perms"] == (10, 50)  # E=1


def test_model_manifest_layer_layout():
    man = aot.model_manifest()
    for name in ("mnist", "cifar", "unet"):
        m = man[name]
        off = 0
        for layer in m["layers"]:
            assert layer["offset"] == off
            assert layer["size"] == int(np.prod(layer["shape"]))
            assert layer["init"] in ("he", "glorot", "zero")
            off += layer["size"]
        assert off == m["param_count"]
    assert man["mnist"]["param_count"] == 1_663_370
    assert man["cifar"]["param_count"] == 122_570


def test_hlo_text_lowering_roundtrip():
    """Lower the 2-bit dequant kernel to HLO text and sanity-check it."""
    arts = {a[0]: a for a in aot.build_artifacts()}
    name, fn, inputs = arts["dequant_cos_2"]
    lowered = jax.jit(fn).lower(*[s for _, s in inputs])
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "cosine" in text or "ROOT" in text
    # The manifest dtype tags round-trip.
    assert aot.dtype_tag(jnp.float32) == "f32"
    assert aot.dtype_tag(jnp.int32) == "i32"


def test_manifest_is_json_serializable():
    man = {
        "models": aot.model_manifest(),
        "round_cfg": aot.ROUND_CFG,
    }
    text = json.dumps(man)
    assert json.loads(text)["models"]["mnist"]["param_count"] == 1_663_370
