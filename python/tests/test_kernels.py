"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

The kernels are fixed-shape (CHUNK = 65536); hypothesis sweeps the VALUE
distributions (scale, heavy tails, constants, zeros) and all bit widths.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cosine_quant as K
from compile.kernels import ref

CHUNK = K.CHUNK


def gradient_like(seed: int, scale: float, spike_frac: float) -> np.ndarray:
    rng = np.random.default_rng(seed)
    g = rng.normal(0.0, 0.01, CHUNK).astype(np.float32)
    spikes = rng.random(CHUNK) < spike_frac
    g[spikes] += rng.normal(0.0, 1.0, spikes.sum()).astype(np.float32)
    return g * scale


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
def test_kernel_matches_ref_biased(bits):
    g = jnp.asarray(gradient_like(0, 1.0, 0.02))
    norm = ref.compute_norm(g)
    bound = ref.compute_bound_auto(g, norm)
    u = jnp.full((CHUNK,), 0.5, jnp.float32)
    codes_k = K.quantize_chunk(g, norm, bound, u, bits=bits)
    codes_r = ref.quantize(g, norm, bound, u, bits)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))
    deq_k = K.dequantize_chunk(codes_k, norm, bound, bits=bits)
    deq_r = ref.dequantize(codes_r, norm, bound, bits)
    np.testing.assert_allclose(
        np.asarray(deq_k), np.asarray(deq_r), rtol=1e-4, atol=1e-6
    )


@pytest.mark.parametrize("bits", [2, 8])
def test_kernel_matches_ref_stochastic(bits):
    g = jnp.asarray(gradient_like(1, 0.1, 0.05))
    norm = ref.compute_norm(g)
    bound = ref.compute_bound_auto(g, norm)
    u = jnp.asarray(np.random.default_rng(7).random(CHUNK, dtype=np.float32))
    codes_k = K.quantize_chunk(g, norm, bound, u, bits=bits)
    codes_r = ref.quantize(g, norm, bound, u, bits)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    scale=st.sampled_from([1e-4, 1e-2, 1.0, 100.0]),
    spike=st.sampled_from([0.0, 0.01, 0.2]),
    bits=st.sampled_from([1, 2, 4, 8]),
)
def test_kernel_vs_ref_hypothesis(seed, scale, spike, bits):
    g = jnp.asarray(gradient_like(seed, scale, spike))
    norm = ref.compute_norm(g)
    bound = ref.compute_bound_auto(g, norm)
    u = jnp.asarray(np.random.default_rng(seed + 1).random(CHUNK, dtype=np.float32))
    codes_k = np.asarray(K.quantize_chunk(g, norm, bound, u, bits=bits))
    codes_r = np.asarray(ref.quantize(g, norm, bound, u, bits))
    np.testing.assert_array_equal(codes_k, codes_r)
    assert codes_k.min() >= 0 and codes_k.max() <= 2**bits - 1
    # Dequantized angle error <= one interval everywhere (stochastic).
    deq = np.asarray(K.dequantize_chunk(jnp.asarray(codes_k), norm, bound, bits=bits))
    theta = np.arccos(np.clip(np.asarray(g) / max(float(norm), 1e-30), -1, 1))
    theta = np.clip(theta, float(bound), math.pi - float(bound))
    theta_back = np.arccos(np.clip(deq / max(float(norm), 1e-30), -1, 1))
    q = (math.pi - 2 * float(bound)) / (2**bits - 1)
    assert np.max(np.abs(theta - theta_back)) <= q + 1e-4


def test_zero_gradient_roundtrips_to_zero():
    g = jnp.zeros((CHUNK,), jnp.float32)
    norm = ref.compute_norm(g)
    bound = jnp.float32(0.0)
    u = jnp.full((CHUNK,), 0.5, jnp.float32)
    codes = K.quantize_chunk(g, norm, bound, u, bits=4)
    assert int(jnp.max(codes)) == 0
    deq = K.dequantize_chunk(codes, norm, bound, bits=4)
    np.testing.assert_array_equal(np.asarray(deq), np.zeros(CHUNK, np.float32))


def test_one_bit_degenerates_to_sign_norm():
    g = jnp.asarray(gradient_like(3, 1.0, 0.02))
    norm = ref.compute_norm(g)
    bound = ref.compute_bound_auto(g, norm)
    u = jnp.full((CHUNK,), 0.5, jnp.float32)
    codes = np.asarray(K.quantize_chunk(g, norm, bound, u, bits=1))
    assert set(np.unique(codes)) <= {0, 1}
    deq = np.asarray(K.dequantize_chunk(jnp.asarray(codes), norm, bound, bits=1))
    mags = np.abs(deq)
    np.testing.assert_allclose(mags, mags[0], rtol=1e-5)
    signs_match = np.sign(deq) == np.sign(np.asarray(g))
    nonzero = np.abs(np.asarray(g)) > 1e-7
    assert signs_match[nonzero].mean() > 0.999


def test_larger_gradients_reconstruct_relatively_better():
    """The paper's section 3.1 property, end to end through the kernel."""
    g = jnp.asarray(gradient_like(9, 1.0, 0.05))
    norm = ref.compute_norm(g)
    bound = ref.compute_bound_auto(g, norm)
    u = jnp.full((CHUNK,), 0.5, jnp.float32)
    codes = K.quantize_chunk(g, norm, bound, u, bits=4)
    deq = np.asarray(K.dequantize_chunk(codes, norm, bound, bits=4))
    gn = np.asarray(g)
    err = np.abs(gn - deq)
    big = np.abs(gn) > np.quantile(np.abs(gn), 0.99)
    small = np.abs(gn) < np.quantile(np.abs(gn), 0.5)
    # Mean absolute error of the top 1% is smaller than of the small half,
    # despite their values being ~100x larger.
    assert err[big].mean() < err[small].mean() * 1.5


def test_bound_auto_matches_definition():
    g = jnp.asarray(gradient_like(5, 1.0, 0.02))
    norm = ref.compute_norm(g)
    b = float(ref.compute_bound_auto(g, norm))
    theta = np.arccos(np.clip(np.asarray(g) / float(norm), -1, 1))
    expected = min(theta.min(), math.pi - theta.max())
    assert abs(b - expected) < 1e-6
    assert 0.0 <= b <= math.pi / 2
