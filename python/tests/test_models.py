"""L2 correctness: parameter counts, forward shapes, local-round semantics."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def init_flat(spec, seed=0) -> jnp.ndarray:
    """He/Glorot init matching the manifest spec (numpy, test-only)."""
    rng = np.random.default_rng(seed)
    entries, total = M.spec_sizes(spec)
    flat = np.zeros(total, np.float32)
    for name, shape, off, size, init in entries:
        if init == "he":
            std = math.sqrt(2.0 / M.fan_in(shape))
            flat[off : off + size] = rng.normal(0, std, size)
        elif init == "glorot":
            fan_out = shape[-1] if len(shape) > 1 else size
            limit = math.sqrt(6.0 / (M.fan_in(shape) + fan_out))
            flat[off : off + size] = rng.uniform(-limit, limit, size)
    return jnp.asarray(flat)


def test_param_counts_match_paper():
    assert M.param_count(M.MNIST_SPEC) == 1_663_370
    assert M.param_count(M.CIFAR_SPEC) == 122_570
    # UNet: our compact substitute — just assert it is nontrivial and fixed.
    assert M.param_count(M.UNET_SPEC) == 89_197


def test_spec_offsets_are_contiguous():
    for spec in (M.MNIST_SPEC, M.CIFAR_SPEC, M.UNET_SPEC):
        entries, total = M.spec_sizes(spec)
        expect = 0
        for _, shape, off, size, _ in entries:
            assert off == expect
            assert size == int(np.prod(shape))
            expect += size
        assert expect == total


@pytest.mark.parametrize(
    "name,batch,x_shape,out_shape",
    [
        ("mnist", 4, (4, 784), (4, 10)),
        ("cifar", 3, (3, 3072), (3, 10)),
        ("unet", 2, (2, 16, 16, 16, 4), (2, 16, 16, 16, 5)),
    ],
)
def test_forward_shapes(name, batch, x_shape, out_shape):
    info = M.MODELS[name]
    flat = init_flat(info["spec"])
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, x_shape), jnp.float32)
    logits = info["apply"](flat, x)
    assert logits.shape == out_shape
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_unflatten_roundtrip():
    spec = M.CIFAR_SPEC
    flat = init_flat(spec, 3)
    parts = M.unflatten(flat, spec)
    rebuilt = jnp.concatenate([parts[p.name].reshape(-1) for p in spec])
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(flat))


def test_softmax_xent_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.0, 0.0, 0.0]])
    y = jnp.asarray([0, 2])
    got = float(M.softmax_xent(logits, y))
    def xe(row, c):
        z = np.log(np.sum(np.exp(row)))
        return z - row[c]
    want = (xe(np.array([2.0, 0, -1]), 0) + xe(np.zeros(3), 2)) / 2
    assert abs(got - want) < 1e-6


def test_local_round_scan_equals_python_loop():
    """The scan-based round must agree with an explicit step loop."""
    info = M.MODELS["cifar"]
    flat = init_flat(info["spec"], 5)
    n, b, steps = 8, 4, 4  # 2 epochs of 2 batches
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(0, 1, (n, 3072)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, n), jnp.int32)
    perms = jnp.asarray(
        np.stack([rng.permutation(n).reshape(2, b) for _ in range(2)]).reshape(
            steps, b
        ),
        jnp.int32,
    )
    lr = jnp.float32(0.05)

    round_fn = M.make_local_round(info["apply"], info["spec"], "momentum")
    delta, loss = jax.jit(round_fn)(flat, x, y, perms, lr)

    # Python reference loop.
    def loss_fn(p, xb, yb):
        return M.softmax_xent(info["apply"](p, xb), yb)

    p = flat
    state = M.opt_init("momentum", flat.shape[0])
    losses = []
    for s in range(steps):
        idx = perms[s]
        l, g = jax.value_and_grad(loss_fn)(p, x[idx], y[idx])
        p, state = M.opt_update("momentum", p, g, state, lr)
        losses.append(float(l))
    np.testing.assert_allclose(
        np.asarray(delta), np.asarray(flat - p), rtol=2e-4, atol=2e-6
    )
    assert abs(float(loss) - np.mean(losses)) < 1e-4


def test_local_round_reduces_loss_on_learnable_task():
    """A separable toy task: loss after the round is lower."""
    info = M.MODELS["mnist"]
    flat = init_flat(info["spec"], 11)
    n, b = 40, 10
    rng = np.random.default_rng(13)
    y = rng.integers(0, 10, n)
    # Class-coded inputs: pixel block per class lights up.
    x = rng.normal(0, 0.1, (n, 784)).astype(np.float32)
    for i, c in enumerate(y):
        x[i, c * 50 : c * 50 + 50] += 2.0
    x, y = jnp.asarray(x), jnp.asarray(y, jnp.int32)
    steps = 3 * (n // b)
    perms = jnp.asarray(
        np.stack([rng.permutation(n) for _ in range(3)]).reshape(steps, b), jnp.int32
    )
    round_fn = jax.jit(M.make_local_round(info["apply"], info["spec"], "sgd", 1e-4))
    delta, loss0 = round_fn(flat, x, y, perms, jnp.float32(0.1))
    new = flat - delta  # M* = M_in - delta
    _, loss1 = round_fn(new, x, y, perms, jnp.float32(0.1))
    assert float(loss1) < float(loss0), (float(loss0), float(loss1))


def test_grad_step_matches_finite_differences():
    info = M.MODELS["cifar"]
    flat = init_flat(info["spec"], 17)
    rng = np.random.default_rng(19)
    x = jnp.asarray(rng.normal(0, 1, (4, 3072)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, 4), jnp.int32)
    grad_fn = jax.jit(M.make_grad_step(info["apply"]))
    g, loss = grad_fn(flat, x, y)
    assert g.shape == flat.shape

    def loss_at(p):
        return float(M.softmax_xent(info["apply"](p, x), y))

    eps = 1e-3
    for idx in [0, 1000, int(flat.shape[0]) - 1]:
        e = np.zeros(flat.shape[0], np.float32)
        e[idx] = eps
        fd = (loss_at(flat + jnp.asarray(e)) - loss_at(flat - jnp.asarray(e))) / (
            2 * eps
        )
        assert abs(fd - float(g[idx])) < 5e-3, (idx, fd, float(g[idx]))


def test_adam_and_momentum_update_shapes():
    n = 100
    p = jnp.zeros((n,), jnp.float32)
    g = jnp.ones((n,), jnp.float32)
    for kind in ("sgd", "momentum", "adam"):
        state = M.opt_init(kind, n)
        p2, state2 = M.opt_update(kind, p, g, state, jnp.float32(0.1))
        assert p2.shape == (n,)
        assert float(p2[0]) < 0.0  # moved against the gradient
        # Second step keeps working with the carried state.
        p3, _ = M.opt_update(kind, p2, g, state2, jnp.float32(0.1))
        assert float(p3[0]) < float(p2[0])


def test_segmentation_eval_dice_components():
    info = M.MODELS["unet"]
    flat = init_flat(info["spec"], 23)
    rng = np.random.default_rng(29)
    x = jnp.asarray(rng.normal(0, 1, (2, 16, 16, 16, 4)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 5, (2, 16, 16, 16)), jnp.int32)
    inter, psum, tsum, loss = jax.jit(M.segmentation_eval)(flat, x, y)
    assert inter.shape == (5,) and psum.shape == (5,) and tsum.shape == (5,)
    total = 2 * 16 ** 3
    assert abs(float(jnp.sum(psum)) - total) < 1e-3
    assert abs(float(jnp.sum(tsum)) - total) < 1e-3
    assert float(jnp.sum(inter)) <= total + 1e-3
    assert np.isfinite(float(loss))
