"""Layer 1: the CosSGD quantization hot-spot as Pallas kernels.

The encode path (`arccos` + affine + stochastic rounding) and decode path
(`cos` + scale) are elementwise transcendental pipelines — a VPU workload
on TPU, not an MXU one. The kernels therefore:

* reshape the flat CHUNK-element gradient to ``(CHUNK/128, 128)`` —
  lane-dim 128, sublane-aligned rows;
* tile with ``BlockSpec((BLOCK_ROWS, 128))`` over a 1-D grid, streaming
  HBM->VMEM one block per step (VMEM footprint per step:
  one f32 in-block + one f32 u-block + one i32 out-block
  = 3 * 8 * 128 * 4 B = 12 KiB, far under the ~16 MiB VMEM budget —
  leaving room for the compiler to double-buffer);
* read ``norm`` / ``bound`` as (1, 1) blocks replicated to every grid step
  so the whole quantize is a single fused pass over the gradient.

``interpret=True`` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; real-TPU performance is *estimated* in EXPERIMENTS.md from
the VMEM/bandwidth structure above (see DESIGN.md section 7).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PI = math.pi

# Fixed chunk the Rust runtime pads/splits layer gradients into.
CHUNK = 65536
LANES = 128
ROWS = CHUNK // LANES  # 512
BLOCK_ROWS = 8  # (8, 128) f32 blocks — the TPU-native tile
GRID = ROWS // BLOCK_ROWS  # 64 steps


def _quant_kernel(bits: int, g_ref, norm_ref, bound_ref, u_ref, o_ref):
    """One (BLOCK_ROWS, 128) tile of the encode pass."""
    norm = norm_ref[0, 0]
    bound = bound_ref[0, 0]
    max_code = float(2**bits - 1)
    rng = PI - 2.0 * bound
    inv = jnp.where(rng > 1e-6, 1.0 / rng, 0.0)

    g = g_ref[...]
    u = u_ref[...]
    ct = jnp.clip(g / jnp.maximum(norm, 1e-30), -1.0, 1.0)
    theta = jnp.clip(jnp.arccos(ct), bound, PI - bound)
    v = (theta - bound) * inv * max_code
    f = jnp.floor(v)
    code = f + (u < (v - f)).astype(jnp.float32)
    code = jnp.clip(code, 0.0, max_code)
    code = jnp.where(norm > 0.0, code, 0.0)
    o_ref[...] = code.astype(jnp.int32)


def _dequant_kernel(bits: int, c_ref, norm_ref, bound_ref, o_ref):
    """One (BLOCK_ROWS, 128) tile of the decode pass."""
    norm = norm_ref[0, 0]
    bound = bound_ref[0, 0]
    max_code = float(2**bits - 1)
    step = (PI - 2.0 * bound) / max_code
    theta = bound + c_ref[...].astype(jnp.float32) * step
    o_ref[...] = jnp.where(norm > 0.0, jnp.cos(theta) * norm, 0.0)


def _scalar_spec():
    # (1,1) scalar operand broadcast to every grid step.
    return pl.BlockSpec((1, 1), lambda i: (0, 0))


def _tile_spec():
    return pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))


def quantize_chunk(g, norm, bound, u, *, bits: int):
    """Quantize a CHUNK-element gradient slice.

    g: f32[CHUNK]; norm, bound: f32[] scalars; u: f32[CHUNK] uniform draws
    (u = 0.5 for the biased regime). Returns int32[CHUNK] codes.
    """
    g2 = g.reshape(ROWS, LANES)
    u2 = u.reshape(ROWS, LANES)
    n2 = norm.reshape(1, 1)
    b2 = bound.reshape(1, 1)
    out = pl.pallas_call(
        partial(_quant_kernel, bits),
        grid=(GRID,),
        in_specs=[_tile_spec(), _scalar_spec(), _scalar_spec(), _tile_spec()],
        out_specs=_tile_spec(),
        out_shape=jax.ShapeDtypeStruct((ROWS, LANES), jnp.int32),
        interpret=True,
    )(g2, n2, b2, u2)
    return out.reshape(CHUNK)


def dequantize_chunk(codes, norm, bound, *, bits: int):
    """Invert a CHUNK of codes back to gradient values (f32[CHUNK])."""
    c2 = codes.reshape(ROWS, LANES)
    n2 = norm.reshape(1, 1)
    b2 = bound.reshape(1, 1)
    out = pl.pallas_call(
        partial(_dequant_kernel, bits),
        grid=(GRID,),
        in_specs=[_tile_spec(), _scalar_spec(), _scalar_spec()],
        out_specs=_tile_spec(),
        out_shape=jax.ShapeDtypeStruct((ROWS, LANES), jnp.float32),
        interpret=True,
    )(c2, n2, b2)
    return out.reshape(CHUNK)


def quantize_fn(bits: int):
    """jit-able (g, norm, bound, u) -> codes, for AOT lowering."""
    return partial(quantize_chunk, bits=bits)


def dequantize_fn(bits: int):
    """jit-able (codes, norm, bound) -> g', for AOT lowering."""
    return partial(dequantize_chunk, bits=bits)
