"""Pure-jnp reference (oracle) for the cosine quantization kernels.

This is the ground truth the Pallas kernels (``cosine_quant.py``) and the
independent Rust implementation (``rust/src/compress/cosine.rs``) are both
checked against. It mirrors the paper's section 3 exactly, with the
``2^s - 1`` scaling documented in DESIGN.md.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

PI = math.pi


def compute_norm(g: jnp.ndarray) -> jnp.ndarray:
    """l2 norm, f32."""
    return jnp.sqrt(jnp.sum(g.astype(jnp.float32) ** 2))


def compute_bound_auto(g: jnp.ndarray, norm: jnp.ndarray) -> jnp.ndarray:
    """b_theta = min(min T, pi - max T) over the angle vector."""
    theta = jnp.arccos(jnp.clip(g / norm, -1.0, 1.0))
    return jnp.clip(jnp.minimum(jnp.min(theta), PI - jnp.max(theta)), 0.0, PI / 2)


def quantize(g, norm, bound, u, bits: int):
    """Quantize with stochastic rounding driven by u in [0,1).

    * ``u = 0.5`` everywhere reproduces (near-)biased round-to-nearest:
      floor(v) + (0.5 < frac) differs from round(v) only at frac == 0.5.
    * ``u ~ U[0,1)`` gives the unbiased regime of Eq. (3).

    Returns int32 codes in [0, 2^bits - 1].
    """
    max_code = float(2**bits - 1)
    rng = PI - 2.0 * bound
    inv = jnp.where(rng > 1e-6, 1.0 / rng, 0.0)
    theta = jnp.arccos(jnp.clip(g / jnp.maximum(norm, 1e-30), -1.0, 1.0))
    theta = jnp.clip(theta, bound, PI - bound)
    v = (theta - bound) * inv * max_code
    f = jnp.floor(v)
    frac = v - f
    code = f + (u < frac).astype(jnp.float32)
    code = jnp.clip(code, 0.0, max_code)
    code = jnp.where(norm > 0.0, code, 0.0)
    return code.astype(jnp.int32)


def dequantize(codes, norm, bound, bits: int):
    """Invert: g' = cos(b + c * (pi - 2b)/(2^s - 1)) * norm."""
    max_code = float(2**bits - 1)
    step = (PI - 2.0 * bound) / max_code
    theta = bound + codes.astype(jnp.float32) * step
    return jnp.where(norm > 0.0, jnp.cos(theta) * norm, 0.0)
