"""Layer 2: the paper's three workloads as JAX models over a FLAT f32
parameter vector.

Everything the Rust coordinator executes is defined here and AOT-lowered by
``aot.py``:

* ``mnist`` — the McMahan et al. [25] CNN (two 5x5 convs + fc512 + fc10),
  exactly 1,663,370 parameters.
* ``cifar`` — a three-conv + two-fc CNN with exactly 122,570 parameters
  (the paper's count for its CIFAR-10 model [42]).
* ``unet`` — a compact 3D-UNet for volumetric segmentation (the BraTS
  substitute; see DESIGN.md section 5).

The flat-parameter convention is what makes the federated pipeline clean:
the local update ``g = M_in - M*`` is a single f32 vector, which is exactly
the object CosSGD quantizes. Local training (E epochs x batches with
SGD / SGD-momentum / Adam) is a single ``lax.scan``, so one HLO artifact
per (model, E, B) covers a whole local round.
"""

from __future__ import annotations

import math
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Parameter specs: (name, shape, init_kind). Offsets are cumulative.
# init_kind: "he" (normal, std=sqrt(2/fan_in)), "glorot" (uniform limit
# sqrt(6/(fan_in+fan_out))), "zero".
# ---------------------------------------------------------------------------


class ParamSpec(NamedTuple):
    name: str
    shape: tuple
    init: str


def _conv_spec(name: str, kh_kw_in_out: tuple) -> list:
    return [
        ParamSpec(f"{name}_w", kh_kw_in_out, "he"),
        ParamSpec(f"{name}_b", (kh_kw_in_out[-1],), "zero"),
    ]


def _fc_spec(name: str, n_in: int, n_out: int, init: str = "he") -> list:
    return [
        ParamSpec(f"{name}_w", (n_in, n_out), init),
        ParamSpec(f"{name}_b", (n_out,), "zero"),
    ]


MNIST_SPEC: list = (
    _conv_spec("conv1", (5, 5, 1, 32))
    + _conv_spec("conv2", (5, 5, 32, 64))
    + _fc_spec("fc1", 7 * 7 * 64, 512)
    + _fc_spec("fc2", 512, 10, init="glorot")
)

CIFAR_SPEC: list = (
    _conv_spec("conv1", (3, 3, 3, 32))
    + _conv_spec("conv2", (3, 3, 32, 64))
    + _conv_spec("conv3", (3, 3, 64, 64))
    + _fc_spec("fc1", 4 * 4 * 64, 64)
    + _fc_spec("fc2", 64, 10, init="glorot")
)


def _conv3d_spec(name: str, cin: int, cout: int, k: int = 3) -> list:
    return [
        ParamSpec(f"{name}_w", (k, k, k, cin, cout), "he"),
        ParamSpec(f"{name}_b", (cout,), "zero"),
    ]


# Compact 3D-UNet: enc(4->8->8), down, enc(8->16->16), down, bottleneck
# (16->32->32), up+skip (48->16->16), up+skip (24->8->8), head (8->5).
UNET_SPEC: list = (
    _conv3d_spec("e1a", 4, 8)
    + _conv3d_spec("e1b", 8, 8)
    + _conv3d_spec("e2a", 8, 16)
    + _conv3d_spec("e2b", 16, 16)
    + _conv3d_spec("ba", 16, 32)
    + _conv3d_spec("bb", 32, 32)
    + _conv3d_spec("d2a", 32 + 16, 16)
    + _conv3d_spec("d2b", 16, 16)
    + _conv3d_spec("d1a", 16 + 8, 8)
    + _conv3d_spec("d1b", 8, 8)
    + _conv3d_spec("head", 8, 5, k=1)
)


def spec_sizes(spec: Sequence[ParamSpec]):
    """[(name, shape, offset, size, init)] with cumulative offsets."""
    out, off = [], 0
    for p in spec:
        size = int(math.prod(p.shape))
        out.append((p.name, p.shape, off, size, p.init))
        off += size
    return out, off


def param_count(spec: Sequence[ParamSpec]) -> int:
    return spec_sizes(spec)[1]


def unflatten(flat: jnp.ndarray, spec: Sequence[ParamSpec]) -> dict:
    """Split the flat vector into named tensors (static slices)."""
    entries, total = spec_sizes(spec)
    assert flat.shape == (total,), f"params {flat.shape} != ({total},)"
    return {
        name: flat[off : off + size].reshape(shape)
        for name, shape, off, size, _ in entries
    }


def fan_in(shape: tuple) -> int:
    """Fan-in of a weight tensor: all dims but the last (conv & fc)."""
    return int(math.prod(shape[:-1])) if len(shape) > 1 else int(shape[0])


# ---------------------------------------------------------------------------
# Forward passes.
# ---------------------------------------------------------------------------


def _conv2d(x, w, b):
    y = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _maxpool2d(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def mnist_apply(flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, 784] -> logits [B, 10]."""
    p = unflatten(flat, MNIST_SPEC)
    h = x.reshape(-1, 28, 28, 1)
    h = jax.nn.relu(_conv2d(h, p["conv1_w"], p["conv1_b"]))
    h = _maxpool2d(h)
    h = jax.nn.relu(_conv2d(h, p["conv2_w"], p["conv2_b"]))
    h = _maxpool2d(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["fc1_w"] + p["fc1_b"])
    return h @ p["fc2_w"] + p["fc2_b"]


def cifar_apply(flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, 3072] -> logits [B, 10]."""
    p = unflatten(flat, CIFAR_SPEC)
    h = x.reshape(-1, 32, 32, 3)
    for name in ("conv1", "conv2", "conv3"):
        h = jax.nn.relu(_conv2d(h, p[f"{name}_w"], p[f"{name}_b"]))
        h = _maxpool2d(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p["fc1_w"] + p["fc1_b"])
    return h @ p["fc2_w"] + p["fc2_b"]


def _conv3d(x, w, b):
    y = lax.conv_general_dilated(
        x, w, window_strides=(1, 1, 1), padding="SAME",
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC"),
    )
    return y + b


def _maxpool3d(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 2, 1), (1, 2, 2, 2, 1), "VALID"
    )


def _upsample3d(x):
    """Nearest-neighbour x2 in D, H, W."""
    for axis in (1, 2, 3):
        x = jnp.repeat(x, 2, axis=axis)
    return x


def unet_apply(flat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, D, H, W, 4] -> logits [B, D, H, W, 5]."""
    p = unflatten(flat, UNET_SPEC)

    def block(h, a, b):
        h = jax.nn.relu(_conv3d(h, p[f"{a}_w"], p[f"{a}_b"]))
        return jax.nn.relu(_conv3d(h, p[f"{b}_w"], p[f"{b}_b"]))

    e1 = block(x, "e1a", "e1b")
    e2 = block(_maxpool3d(e1), "e2a", "e2b")
    bott = block(_maxpool3d(e2), "ba", "bb")
    d2 = block(jnp.concatenate([_upsample3d(bott), e2], axis=-1), "d2a", "d2b")
    d1 = block(jnp.concatenate([_upsample3d(d2), e1], axis=-1), "d1a", "d1b")
    return _conv3d(d1, p["head_w"], p["head_b"])


# ---------------------------------------------------------------------------
# Losses and metrics.
# ---------------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean cross-entropy; labels are int class ids over the last axis."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def classification_eval(apply_fn, flat, x, y):
    """-> (num_correct: f32 scalar, mean_loss: f32 scalar)."""
    logits = apply_fn(flat, x)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return correct, softmax_xent(logits, y)


def segmentation_eval(flat, x, y):
    """-> (intersections[5], pred_sums[5], true_sums[5], mean_loss).

    Dice components summed over the batch; the Rust side computes
    2*I / (P + T) per class and averages (the BraTS dice protocol).
    """
    logits = unet_apply(flat, x)
    loss = softmax_xent(logits, y)
    pred = jnp.argmax(logits, axis=-1)
    classes = jnp.arange(5)

    def per_class(c):
        pm = (pred == c).astype(jnp.float32)
        tm = (y == c).astype(jnp.float32)
        return jnp.sum(pm * tm), jnp.sum(pm), jnp.sum(tm)

    inter, psum, tsum = jax.vmap(per_class)(classes)
    return inter, psum, tsum, loss


# ---------------------------------------------------------------------------
# Local optimizers (fresh state each round: FedAvg workers re-init from the
# incoming model — Algorithm 1 "Worker" lines 1-7).
# ---------------------------------------------------------------------------


def opt_init(kind: str, n: int):
    if kind == "sgd":
        return ()
    if kind == "momentum":
        return (jnp.zeros((n,), jnp.float32),)
    if kind == "adam":
        return (
            jnp.zeros((n,), jnp.float32),
            jnp.zeros((n,), jnp.float32),
            jnp.zeros((), jnp.float32),
        )
    raise ValueError(f"unknown optimizer {kind}")


def opt_update(kind: str, params, grad, state, lr):
    if kind == "sgd":
        return params - lr * grad, state
    if kind == "momentum":
        (v,) = state
        v = 0.9 * v + grad
        return params - lr * v, (v,)
    if kind == "adam":
        m, v, t = state
        t = t + 1.0
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = b1 * m + (1 - b1) * grad
        v = b2 * v + (1 - b2) * grad * grad
        mhat = m / (1 - b1**t)
        vhat = v / (1 - b2**t)
        return params - lr * mhat / (jnp.sqrt(vhat) + eps), (m, v, t)
    raise ValueError(f"unknown optimizer {kind}")


# ---------------------------------------------------------------------------
# Whole-local-round functions (one HLO artifact each).
# ---------------------------------------------------------------------------


def make_local_round(
    apply_fn: Callable,
    spec: Sequence[ParamSpec],
    opt: str,
    weight_decay: float = 0.0,
) -> Callable:
    """Build ``(params, x, y, perms, lr) -> (delta, mean_loss)``.

    * ``x``: the client's full local dataset ``[N, ...]``.
    * ``perms``: ``[steps, B]`` int32 batch-index matrix (the Rust side
      shuffles per epoch — see fl::client).
    * ``delta = M_in - M*`` — the update CosSGD quantizes (Alg. 1 line 8).
    """
    n_params = param_count(spec)

    def loss_fn(params, xb, yb):
        return softmax_xent(apply_fn(params, xb), yb)

    def fn(params, x, y, perms, lr):
        def step(carry, idx):
            p, s = carry
            xb = jnp.take(x, idx, axis=0)
            yb = jnp.take(y, idx, axis=0)
            loss, g = jax.value_and_grad(loss_fn)(p, xb, yb)
            if weight_decay > 0.0:
                g = g + weight_decay * p
            p, s = opt_update(opt, p, g, s, lr)
            return (p, s), loss

        (p_out, _), losses = lax.scan(
            step, (params, opt_init(opt, n_params)), perms
        )
        return params - p_out, jnp.mean(losses)

    return fn


def make_grad_step(apply_fn):
    """``(params, x, y) -> (grad, loss)`` — the Fig. 4 toy-study primitive
    (the Rust side masks/noises the gradient and applies the step)."""

    def loss_fn(params, xb, yb):
        return softmax_xent(apply_fn(params, xb), yb)

    def fn(params, x, y):
        loss, g = jax.value_and_grad(loss_fn)(params, x, y)
        return g, loss

    return fn


MODELS = {
    "mnist": dict(
        spec=MNIST_SPEC, apply=mnist_apply, opt="sgd", weight_decay=1e-4,
        input_shape=(784,), classes=10,
    ),
    "cifar": dict(
        spec=CIFAR_SPEC, apply=cifar_apply, opt="momentum", weight_decay=0.0,
        input_shape=(3072,), classes=10,
    ),
    "unet": dict(
        spec=UNET_SPEC, apply=unet_apply, opt="adam", weight_decay=0.0,
        input_shape=(16, 16, 16, 4), classes=5,
    ),
}
