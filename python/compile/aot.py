"""AOT lowering: every computation the Rust coordinator executes is lowered
here, once, to HLO **text** plus a ``manifest.json`` describing shapes,
dtypes and model metadata.

HLO text — NOT serialized HloModuleProto — is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot [--out-dir ../artifacts] [--only name,...]

Artifacts (defaults; all shapes recorded in the manifest):

    mnist_round   (params[1663370], x[600,784], y[600], perms[60,10], lr) -> (delta, loss)
    mnist_eval    (params, x[1000,784], y[1000]) -> (correct, loss)
    mnist_grad    (params, x[64,784], y[64]) -> (grad, loss)
    cifar_round   (params[122570], x[500,3072], y[500], perms[50,50], lr) -> (delta, loss)
    cifar_round_e1   same with E=1 (Table 1's (B=50,E=1,C=0.5) config)
    cifar_eval    (params, x[1000,3072], y[1000]) -> (correct, loss)
    unet_round    (params, x[12,16,16,16,4], y[12,16,16,16], perms[12,3], lr) -> (delta, loss)
    unet_eval     (params, x[10,...], y[10,...]) -> (inter[5], psum[5], tsum[5], loss)
    quant_cos_{1,2,4,8}    (g[65536], norm, bound, u[65536]) -> codes
    dequant_cos_{1,2,4,8}  (codes[65536], norm, bound) -> g'
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import cosine_quant as K

F32 = jnp.float32
I32 = jnp.int32


def sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def dtype_tag(dt) -> str:
    return {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[jnp.dtype(dt)]


# Round configurations (paper section 5.1). Rust reads these from the
# manifest; change here, re-run `make artifacts`.
ROUND_CFG = {
    "mnist": dict(n_data=600, batch=10, epochs=1, eval_n=1000),
    "cifar": dict(n_data=500, batch=50, epochs=5, eval_n=1000),
    "cifar_e1": dict(n_data=500, batch=50, epochs=1, eval_n=1000),
    "unet": dict(n_data=12, batch=3, epochs=3, eval_n=10),
}
GRAD_BATCH = 64
KERNEL_BITS = (1, 2, 4, 8)


def model_inputs(name: str, cfg: dict):
    info = M.MODELS[name]
    p = M.param_count(info["spec"])
    n, b, e = cfg["n_data"], cfg["batch"], cfg["epochs"]
    steps = e * (n // b)
    x_shape = (n, *info["input_shape"])
    if name == "unet":
        y_shape = (n, 16, 16, 16)
    else:
        y_shape = (n,)
    return p, steps, x_shape, y_shape, b


def build_artifacts():
    """[(artifact_name, fn, [(input_name, ShapeDtypeStruct)...])]."""
    arts = []

    for model_name, cfg_key in (
        ("mnist", "mnist"),
        ("cifar", "cifar"),
        ("cifar", "cifar_e1"),
        ("unet", "unet"),
    ):
        info = M.MODELS[model_name]
        cfg = ROUND_CFG[cfg_key]
        p, steps, x_shape, y_shape, b = model_inputs(model_name, cfg)
        fn = M.make_local_round(
            info["apply"], info["spec"], info["opt"], info["weight_decay"]
        )
        art_name = f"{cfg_key}_round" if cfg_key != "cifar_e1" else "cifar_round_e1"
        arts.append(
            (
                art_name,
                fn,
                [
                    ("params", sds((p,))),
                    ("x", sds(x_shape)),
                    ("y", sds(y_shape, I32)),
                    ("perms", sds((steps, b), I32)),
                    ("lr", sds(())),
                ],
            )
        )

    # Eval artifacts.
    for model_name in ("mnist", "cifar"):
        info = M.MODELS[model_name]
        cfg = ROUND_CFG[model_name]
        p = M.param_count(info["spec"])
        n = cfg["eval_n"]

        def eval_fn(params, x, y, _apply=info["apply"]):
            return M.classification_eval(_apply, params, x, y)

        arts.append(
            (
                f"{model_name}_eval",
                eval_fn,
                [
                    ("params", sds((p,))),
                    ("x", sds((n, *info["input_shape"]))),
                    ("y", sds((n,), I32)),
                ],
            )
        )
    # UNet eval returns dice components.
    info = M.MODELS["unet"]
    p = M.param_count(info["spec"])
    n = ROUND_CFG["unet"]["eval_n"]
    arts.append(
        (
            "unet_eval",
            M.segmentation_eval,
            [
                ("params", sds((p,))),
                ("x", sds((n, 16, 16, 16, 4))),
                ("y", sds((n, 16, 16, 16), I32)),
            ],
        )
    )

    # Per-step gradient (Fig. 4 toy study).
    info = M.MODELS["mnist"]
    p = M.param_count(info["spec"])
    arts.append(
        (
            "mnist_grad",
            M.make_grad_step(info["apply"]),
            [
                ("params", sds((p,))),
                ("x", sds((GRAD_BATCH, 784))),
                ("y", sds((GRAD_BATCH,), I32)),
            ],
        )
    )

    # Pallas quantization kernels.
    for bits in KERNEL_BITS:
        arts.append(
            (
                f"quant_cos_{bits}",
                K.quantize_fn(bits),
                [
                    ("g", sds((K.CHUNK,))),
                    ("norm", sds(())),
                    ("bound", sds(())),
                    ("u", sds((K.CHUNK,))),
                ],
            )
        )
        arts.append(
            (
                f"dequant_cos_{bits}",
                K.dequantize_fn(bits),
                [
                    ("codes", sds((K.CHUNK,), I32)),
                    ("norm", sds(())),
                    ("bound", sds(())),
                ],
            )
        )
    return arts


def model_manifest() -> dict:
    out = {}
    for name, info in M.MODELS.items():
        entries, total = M.spec_sizes(info["spec"])
        out[name] = {
            "param_count": total,
            "classes": info["classes"],
            "optimizer": info["opt"],
            "weight_decay": info["weight_decay"],
            "input_shape": list(info["input_shape"]),
            "layers": [
                {
                    "name": n,
                    "shape": list(shape),
                    "offset": off,
                    "size": size,
                    "init": init,
                    "fan_in": M.fan_in(shape),
                }
                for n, shape, off, size, init in entries
            ],
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--only", default="", help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(filter(None, args.only.split(",")))
    arts = build_artifacts()
    manifest = {
        "version": 1,
        "chunk": K.CHUNK,
        "kernel_bits": list(KERNEL_BITS),
        "grad_batch": GRAD_BATCH,
        "round_cfg": ROUND_CFG,
        "models": model_manifest(),
        "artifacts": {},
    }

    for name, fn, inputs in arts:
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"name": n, "dtype": dtype_tag(s.dtype), "shape": list(s.shape)}
                for n, s in inputs
            ],
        }
        if only and name not in only:
            if not os.path.exists(path):
                print(f"[aot] WARNING: skipping {name} but {path} is missing")
            continue
        t0 = time.time()
        lowered = jax.jit(fn).lower(*[s for _, s in inputs])
        # Record output shapes from the lowering itself.
        out_avals = jax.tree_util.tree_leaves(lowered.out_info)
        manifest["artifacts"][name]["outputs"] = [
            {"dtype": dtype_tag(o.dtype), "shape": list(o.shape)} for o in out_avals
        ]
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(
            f"[aot] {name}: {len(text)} chars in {time.time() - t0:.1f}s -> {path}",
            flush=True,
        )

    man_path = os.path.join(args.out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {man_path}")


if __name__ == "__main__":
    sys.exit(main())
