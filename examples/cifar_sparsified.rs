//! CIFAR + sparsification (the Figure 10 / Table 1 scenario): CosSGD
//! 2-bit with a 5% random mask — the paper's >1000x compression point —
//! against float32, reporting byte-exact cost ratios.
//!
//!     cargo run --release --example cifar_sparsified [-- --rounds 10]

use cossgd::compress::Pipeline;
use cossgd::fl::{self, FlConfig};
use cossgd::runtime::Engine;
use cossgd::util::cli::Args;
use cossgd::util::timer::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rounds = args.opt_usize("rounds", 8);
    let engine = Engine::load_default()?;
    let params = engine.manifest.model("cifar")?.param_count;

    println!("CIFAR-like federation (B=50, E=5, C=0.1), {rounds} rounds\n");
    let mut results = Vec::new();
    for (label, pipeline) in [
        ("float32 full", Pipeline::float32()),
        ("cosine-2 @5% mask", Pipeline::cosine(2).with_sparsify(0.05)),
        ("cosine-8 @10% mask", Pipeline::cosine(8).with_sparsify(0.10)),
    ] {
        let mut cfg = FlConfig::cifar().with_rounds(rounds).with_uplink(pipeline);
        cfg.eval_every = (rounds / 4).max(1);
        let r = fl::run(&cfg, &engine)?;
        println!(
            "{label:<20} best acc {:.4}  uplink {:>10}  mean/client {:>10}  ratio {:>9}",
            r.history.best_metric().unwrap_or(f64::NAN),
            fmt_bytes(r.network.uplink_bytes),
            fmt_bytes(r.network.mean_uplink() as u64),
            fl::network::fmt_ratio(r.network.uplink_compression_vs_float32(params)),
        );
        results.push(r);
    }
    println!(
        "\nThe 2-bit + 5% + DEFLATE point is the paper's 400-1200x regime; accuracy\n\
         should track float32 within a few points at equal rounds (Fig. 10, Table 1)."
    );
    Ok(())
}
