//! Compression explorer — no artifacts needed. Encodes a synthetic
//! heavy-tailed gradient with every pipeline in the library and prints
//! bytes, ratios, reconstruction error and entropy, demonstrating the
//! public compression API end to end.
//!
//!     cargo run --release --example compression_explorer [-- --n 500000]

use cossgd::compress::cosine::{BoundMode, Rounding};
use cossgd::compress::{decode, entropy, Direction, Pipeline, PipelineState};
use cossgd::util::cli::Args;
use cossgd::util::rng::Pcg64;
use cossgd::util::stats::l2_norm;
use cossgd::util::timer::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.opt_usize("n", 500_000);
    let mut rng = Pcg64::seeded(args.opt_u64("seed", 1));
    let g = cossgd::util::propcheck::gradient_like(&mut rng, n);
    let gnorm = l2_norm(&g);
    println!("synthetic gradient: n={n}, ‖g‖₂={gnorm:.3}\n");

    let pipelines: Vec<Pipeline> = vec![
        Pipeline::float32(),
        Pipeline::cosine(8),
        Pipeline::cosine(4),
        Pipeline::cosine(2),
        Pipeline::cosine(1),
        Pipeline::cosine_with(2, Rounding::Unbiased, BoundMode::Auto),
        Pipeline::linear(2, Rounding::Biased),
        Pipeline::linear(2, Rounding::Unbiased),
        Pipeline::linear_rotated(2, Rounding::Unbiased),
        Pipeline::cosine(8).with_rotation(), // rotation composes with any quantizer
        Pipeline::sign(),
        Pipeline::sign_norm(),
        Pipeline::ef_sign(),
        Pipeline::cosine(2).with_sparsify(0.5),
        Pipeline::cosine(2).with_sparsify(0.05),
    ];

    println!(
        "{:<32} {:>10} {:>9} {:>11} {:>10}",
        "pipeline", "wire", "ratio", "cos-sim", "rel-l2-err"
    );
    for pipe in pipelines {
        let mut st = PipelineState::new();
        let enc = pipe.encode(&g, Direction::Uplink, &mut st, &mut rng);
        let dec = decode(&enc)?;
        let dot: f64 = g.iter().zip(&dec).map(|(&a, &b)| (a * b) as f64).sum();
        let sim = dot / (gnorm * l2_norm(&dec)).max(1e-12);
        let err = (g
            .iter()
            .zip(&dec)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>())
        .sqrt()
            / gnorm;
        println!(
            "{:<32} {:>10} {:>8.1}x {:>11.4} {:>10.4}",
            pipe.name(),
            fmt_bytes(enc.wire_bytes() as u64),
            (n * 4) as f64 / enc.wire_bytes() as f64,
            sim,
            err
        );
    }

    // The Fig. 5 effect on this gradient.
    let q8 = cossgd::compress::cosine::CosineQuantizer::paper_default(8)
        .quantize(&g, &mut rng);
    let packed = cossgd::compress::bitpack::pack(&q8.codes, 8);
    let floats = entropy::f32_bytes(&g);
    println!("\nmulti-scale entropy (bits/byte):");
    for ((s, eq), (_, ef)) in entropy::multiscale_entropy(&packed)
        .iter()
        .zip(&entropy::multiscale_entropy(&floats))
    {
        println!("  scale {s}: 8-bit codes {eq:.3}  vs  float32 {ef:.3}");
    }
    Ok(())
}
