//! MNIST federated comparison (the Figure 6 scenario at example scale):
//! float32 vs CosSGD 2-bit vs biased linear 2-bit on the Non-IID split —
//! the regime where linear quantization collapses and cosine does not.
//!
//!     cargo run --release --example mnist_federated [-- --rounds 12]

use cossgd::compress::cosine::{BoundMode, Rounding};
use cossgd::compress::Pipeline;
use cossgd::fl::{self, FlConfig};
use cossgd::runtime::Engine;
use cossgd::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rounds = args.opt_usize("rounds", 10);
    let engine = Engine::load_default()?;

    let pipelines = [
        ("float32", Pipeline::float32()),
        ("cosine-2 (ours)", Pipeline::cosine(2)),
        ("linear-2 (biased)", Pipeline::linear(2, Rounding::Biased)),
        (
            "cosine-1 (=signSGD+Norm)",
            Pipeline::cosine_with(1, Rounding::Biased, BoundMode::ClipTopPercent(1.0)),
        ),
    ];

    println!("Non-IID MNIST-like federation: 100 clients, ≤2 classes each, C=0.1");
    let mut rows = Vec::new();
    for (label, pipeline) in pipelines {
        let mut cfg = FlConfig::mnist(true)
            .with_rounds(rounds)
            .with_uplink(pipeline);
        cfg.eval_every = (rounds / 5).max(1);
        let result = fl::run(&cfg, &engine)?;
        let params = engine.manifest.model("mnist")?.param_count;
        rows.push((
            label,
            result.history.best_metric().unwrap_or(f64::NAN),
            fl::network::fmt_ratio(result.network.uplink_compression_vs_float32(params)),
        ));
        println!("  {label}: done");
    }

    println!("\n{:<26} {:>10} {:>14}", "codec", "best acc", "compression");
    for (label, acc, ratio) in rows {
        println!("{label:<26} {acc:>10.4} {ratio:>14}");
    }
    println!("\nExpected shape (paper Fig. 6): cosine ≈ float32; biased linear-2 lags/collapses.");
    Ok(())
}
