//! Quickstart: a 5-round *round-trip* federated run on the MNIST-like
//! task — CosSGD 2-bit on the uplink (the paper's default: biased, top-1%
//! clipping, DEFLATE) and an 8-bit quantized model-delta broadcast on the
//! downlink.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Prints the convergence curve and the measured compression ratios in
//! both directions.

use cossgd::compress::Pipeline;
use cossgd::fl::{self, FlConfig};
use cossgd::runtime::Engine;
use cossgd::util::timer::fmt_bytes;

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifacts (built once by `make artifacts`; Python is
    //    never needed again after that).
    let engine = Engine::load_default()?;

    // 2. Describe the experiment: MNIST-like task, IID split, 20 clients,
    //    C = 0.1, E = 1, B = 10 — CosSGD 2-bit uplink compression and an
    //    8-bit cosine downlink (the paper's double-direction scheme).
    let mut cfg = FlConfig::mnist(false)
        .with_rounds(5)
        .with_uplink(Pipeline::cosine(2))
        .with_downlink(Pipeline::cosine(8));
    cfg.n_clients = 20;
    cfg.eval_every = 1;
    cfg.verbose = true;

    // 3. Run the federation.
    let result = fl::run(&cfg, &engine)?;

    // 4. Report.
    println!("\n── quickstart summary ──");
    for r in &result.history.records {
        println!(
            "round {:>2}: train loss {:.4}  accuracy {}",
            r.round,
            r.train_loss,
            r.eval_metric
                .map(|m| format!("{m:.4}"))
                .unwrap_or_else(|| "-".into())
        );
    }
    let params = engine.manifest.model("mnist")?.param_count;
    println!(
        "uplink total {} ({} smaller than float32 updates)",
        fmt_bytes(result.network.uplink_bytes),
        fl::network::fmt_ratio(result.network.uplink_compression_vs_float32(params)),
    );
    println!(
        "downlink total {} ({} smaller than float32 broadcasts)",
        fmt_bytes(result.network.downlink_bytes),
        fl::network::fmt_ratio(result.network.downlink_compression_vs_float32(params)),
    );
    Ok(())
}
