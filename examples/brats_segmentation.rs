//! Federated brain-tumor-style segmentation (the Figure 9 scenario):
//! 10 "hospitals", C=1, E=3, B=3, Adam with warm restarts, dice-scored —
//! with CosSGD 8-bit vs float32 updates, plus a full round-trip run
//! (cosine-4 uplink + cosine-8 downlink model deltas).
//!
//!     cargo run --release --example brats_segmentation [-- --rounds 12]

use cossgd::compress::Pipeline;
use cossgd::fl::{self, FlConfig};
use cossgd::runtime::Engine;
use cossgd::util::cli::Args;
use cossgd::util::timer::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rounds = args.opt_usize("rounds", 12);
    let engine = Engine::load_default()?;
    let params = engine.manifest.model("unet")?.param_count;

    println!("BraTS-substitute federation: 10 hospitals, C=1, Adam, warm restarts\n");
    let cases: Vec<(&str, FlConfig)> = vec![
        ("float32", FlConfig::unet().with_uplink(Pipeline::float32())),
        ("cosine-8", FlConfig::unet().with_uplink(Pipeline::cosine(8))),
        (
            "cosine-2 @25%",
            FlConfig::unet().with_uplink(Pipeline::cosine(2).with_sparsify(0.25)),
        ),
        (
            "round-trip 4↑/8↓",
            FlConfig::unet()
                .with_uplink(Pipeline::cosine(4))
                .with_downlink(Pipeline::cosine(8)),
        ),
    ];
    for (label, base) in cases {
        let mut cfg = base.with_rounds(rounds);
        cfg.eval_every = (rounds / 6).max(1);
        cfg.verbose = false;
        let r = fl::run(&cfg, &engine)?;
        print!("{label:<16} dice curve:");
        for rec in &r.history.records {
            if let Some(d) = rec.eval_metric {
                print!(" {d:.3}");
            }
        }
        println!(
            "  | uplink {} ({}) downlink {} ({})",
            fmt_bytes(r.network.uplink_bytes),
            fl::network::fmt_ratio(r.network.uplink_compression_vs_float32(params)),
            fmt_bytes(r.network.downlink_bytes),
            fl::network::fmt_ratio(r.network.downlink_compression_vs_float32(params)),
        );
    }
    println!("\nExpected shape (paper Fig. 9): quantized runs track float32 dice at a\nfraction of the transferred volume — in both directions for the round-trip run.");
    Ok(())
}
