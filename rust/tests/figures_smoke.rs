//! Tiny-scale smoke runs of every figure driver — the harnesses double as
//! end-to-end tests. Engine-backed figures self-skip without artifacts.

use cossgd::figures::{self, FigOpts};
use cossgd::runtime::Engine;

fn opts(rounds: usize) -> FigOpts {
    FigOpts {
        rounds: Some(rounds),
        full: false,
        seed: 7,
        verbose: false,
        out_dir: std::env::temp_dir().join("cossgd_fig_smoke"),
    }
}

fn artifacts_available() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

#[test]
fn fig3_analytic_runs_without_artifacts() {
    let mut engine: Option<Engine> = None;
    figures::run_figure("fig3", &mut engine, &opts(1)).unwrap();
    assert!(engine.is_none(), "fig3 must not need the engine");
}

#[test]
fn unknown_figure_is_an_error() {
    let mut engine: Option<Engine> = None;
    assert!(figures::run_figure("fig99", &mut engine, &opts(1)).is_err());
}

// The engine-backed figures at minimum viable scale. Grouped into one test
// per workload family to bound total runtime.

#[test]
fn fig5_entropy_smoke() {
    if !artifacts_available() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let mut engine: Option<Engine> = None;
    figures::run_figure("fig5", &mut engine, &opts(1)).unwrap();
}

#[test]
fn fig9_unet_smoke() {
    if !artifacts_available() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let mut engine: Option<Engine> = None;
    figures::run_figure("fig9", &mut engine, &opts(1)).unwrap();
    // Results file exists and parses.
    let text = std::fs::read_to_string(
        std::env::temp_dir().join("cossgd_fig_smoke/fig9.json"),
    )
    .unwrap();
    assert!(cossgd::util::json::Json::parse(&text).is_ok());
}
