//! Buffered-async acceptance tests — no artifacts needed. The headline
//! property: on a straggler-heavy 3G fleet, FedBuff-style buffered
//! aggregation ([`RoundMode::BufferedAsync`]) reaches the target metric
//! in fewer simulated seconds than synchronous FedAvg **at equal uplink
//! bytes** — same per-frame wire cost, same number of aggregated
//! updates, strictly less virtual time — because slow uplinks stop
//! gating every round.
//!
//! These tests drive the REAL stack minus training: real encoded frames
//! (fixed-size cosine-4, no DEFLATE, so byte accounting is exact),
//! through the real [`SimTransport`] and the real [`Server::ingest`]
//! state machine, via the shared [`dryrun`] drivers that
//! `repro sim --quick` also smokes in CI.

use cossgd::compress::Pipeline;
use cossgd::fl::metrics::{History, RoundRecord};
use cossgd::fl::transport::dryrun;
use cossgd::sim::{DeviceTier, RoundPolicy, SimConfig, Timeline};

/// A straggler-heavy 3G fleet: most devices are ordinary 3G, a fat tail
/// crawls at a quarter of the uplink and an eighth of the compute.
/// Availability/dropout are off so byte accounting is exact: every
/// trained update crosses the wire.
fn straggler_fleet() -> SimConfig {
    SimConfig {
        tiers: vec![
            DeviceTier::new("3g·fast", 0.6, 2.0, 0.75, 4000.0),
            DeviceTier::new("3g·slow", 0.2, 2.0, 0.75, 500.0),
            DeviceTier::new("3g·crawl", 0.2, 2.0, 0.25, 250.0),
        ],
        policy: RoundPolicy::Synchronous,
        availability: 1.0,
        dropout: 0.0,
        jitter: 0.2,
    }
}

/// Synthetic convergence curve: the metric depends only on how many
/// aggregated model updates have been applied (both modes aggregate the
/// same number of same-size updates per application, so curves are
/// comparable at equal uplink bytes).
fn history_over(tl: &Timeline, target_rounds: usize) -> History {
    let mut h = History::new("dry");
    for (i, r) in tl.records.iter().enumerate() {
        h.push(RoundRecord {
            round: r.round,
            train_loss: 1.0 / (i + 1) as f64,
            eval_metric: Some(0.9 * (i + 1) as f64 / target_rounds as f64),
            eval_loss: None,
            uplink_bytes: 0,
            downlink_bytes: 0,
            clients: r.reporters,
            stale_updates: r.stragglers_dropped,
        });
    }
    h
}

const N: usize = 100_000; // 100k-param model: transfers dominate on 3G
const CLIENTS: usize = 40;
const K: usize = 10; // reporters per aggregation, both modes
const ROUNDS: usize = 12;
const SEED: u64 = 9;

/// The acceptance criterion (ISSUE 4): buffered async beats synchronous
/// on a straggler-heavy 3G fleet at equal uplink bytes.
#[test]
fn buffered_async_beats_sync_on_straggler_heavy_3g_fleet_at_equal_uplink_bytes() {
    // No DEFLATE ⇒ every cosine-4 frame has the identical wire size, so
    // "equal uplink bytes" is exact arithmetic, not approximation.
    let pipe = Pipeline::cosine(4).without_deflate();
    let fleet = straggler_fleet();

    let sync = dryrun::run_sync(&pipe, &fleet, N, CLIENTS, K, ROUNDS, SEED).expect("sync run");
    // Same fleet (same seed ⇒ identical devices), same target number of
    // aggregations, each consuming the same K same-size updates. A
    // generous staleness bound keeps slow devices contributing
    // (discounted) instead of being discarded.
    let asyn = dryrun::run_async(&pipe, &fleet, N, CLIENTS, K, 2 * K, ROUNDS, 8, SEED)
        .expect("async run");

    assert_eq!(sync.timeline.records.len(), ROUNDS);
    assert_eq!(asyn.aggregations, ROUNDS);

    // Equal uplink bytes: sync delivered exactly ROUNDS·K frames; async
    // consumed ROUNDS·K accepted frames plus any discarded ones — with a
    // generous staleness bound the discard tail must stay marginal.
    let frame_bytes = sync.ledger.uplink_bytes / (ROUNDS as u64 * K as u64);
    assert_eq!(
        sync.ledger.uplink_bytes,
        frame_bytes * ROUNDS as u64 * K as u64,
        "cosine-4 without DEFLATE must have a fixed frame size"
    );
    assert_eq!(
        asyn.ledger.uplink_bytes,
        (ROUNDS * K + asyn.dropped) as u64 * frame_bytes
    );
    assert!(
        asyn.ledger.uplink_bytes as f64 <= sync.ledger.uplink_bytes as f64 * 1.1,
        "async spent {} uplink bytes vs sync {} — not an equal-bytes comparison",
        asyn.ledger.uplink_bytes,
        sync.ledger.uplink_bytes
    );

    // The headline: the same aggregation count in well under the sync
    // time — the crawl tier no longer gates every round.
    assert!(
        asyn.timeline.total_secs() < 0.7 * sync.timeline.total_secs(),
        "async {:.1}s not well below sync {:.1}s",
        asyn.timeline.total_secs(),
        sync.timeline.total_secs()
    );

    // And in time-to-target-metric terms (metric = f(aggregations), so
    // the curves are identical per update consumed).
    let h_sync = history_over(&sync.timeline, ROUNDS);
    let h_async = history_over(&asyn.timeline, ROUNDS);
    let t_sync = sync
        .timeline
        .time_to_metric(&h_sync, 0.89)
        .expect("sync reaches target");
    let t_async = asyn
        .timeline
        .time_to_metric(&h_async, 0.89)
        .expect("async reaches target");
    assert!(
        t_async < t_sync,
        "async to-target {t_async:.1}s not below sync {t_sync:.1}s"
    );
}

/// Same seed ⇒ tick- and byte-identical buffered-async runs: the event
/// loop (admission lottery, flight queue, window closes) is fully
/// deterministic.
#[test]
fn buffered_async_is_deterministic() {
    let pipe = Pipeline::cosine(4);
    let mut fleet = straggler_fleet();
    fleet.availability = 0.9;
    fleet.dropout = 0.03;
    let run = || dryrun::run_async(&pipe, &fleet, 20_000, 30, 8, 16, 6, 3, 17).expect("run");
    let (a, b) = (run(), run());
    assert_eq!(a.timeline, b.timeline);
    assert_eq!(a.ledger.uplink_bytes, b.ledger.uplink_bytes);
    assert_eq!(a.ledger.downlink_bytes, b.ledger.downlink_bytes);
    assert_eq!(a.dropped, b.dropped);
    // A different seed reshuffles the fleet, the lotteries and the clock.
    let c = dryrun::run_async(&pipe, &fleet, 20_000, 30, 8, 16, 6, 3, 18).expect("run");
    assert_ne!(a.timeline, c.timeline);
}

/// With a zero staleness bound on a heterogeneous fleet, slow uploads
/// land after the window that dispatched them and are discarded as
/// stale — the drops are visible in the ledger (they were metered: they
/// crossed the wire) and in the timeline's straggler counter, yet every
/// window still fills.
#[test]
fn zero_staleness_bound_drops_slow_updates_but_windows_still_fill() {
    let pipe = Pipeline::cosine(4).without_deflate();
    let fleet = straggler_fleet();
    let strict = dryrun::run_async(&pipe, &fleet, N, CLIENTS, K, 2 * K, 8, 0, SEED).expect("run");
    assert_eq!(strict.aggregations, 8, "windows must fill despite drops");
    assert!(
        strict.dropped > 0,
        "a zero staleness bound on a straggler fleet must drop something"
    );
    let tl_drops: usize = strict
        .timeline
        .records
        .iter()
        .map(|r| r.stragglers_dropped)
        .sum();
    assert_eq!(tl_drops, strict.dropped, "timeline must account for every drop");
    // Dropped updates were still metered — delivery is what costs bytes.
    let frame_bytes = strict.ledger.uplink_bytes / (8 * K + strict.dropped) as u64;
    assert_eq!(
        strict.ledger.uplink_bytes,
        (8 * K + strict.dropped) as u64 * frame_bytes
    );
    // Relaxing the bound keeps more updates (fewer drops).
    let relaxed = dryrun::run_async(&pipe, &fleet, N, CLIENTS, K, 2 * K, 8, 8, SEED).expect("run");
    assert!(relaxed.dropped < strict.dropped);
}

/// The async timeline is well-formed: contiguous monotone windows, each
/// reporting exactly the buffer size.
#[test]
fn async_timeline_windows_are_contiguous_and_sized() {
    let pipe = Pipeline::cosine(4);
    let out = dryrun::run_async(&pipe, &straggler_fleet(), 20_000, 30, 6, 12, 5, 4, 3)
        .expect("run");
    assert_eq!(out.timeline.records.len(), 5);
    for (i, r) in out.timeline.records.iter().enumerate() {
        assert_eq!(r.round, i + 1);
        assert_eq!(r.reporters, 6, "every window aggregates buffer_k updates");
        assert!(r.end >= r.start);
        if i > 0 {
            assert_eq!(r.start, out.timeline.records[i - 1].end, "window gap at {i}");
        }
    }
    assert!(out.timeline.mean_round_secs() > 0.0);
}
