//! Buffered-async acceptance tests — no artifacts needed. The headline
//! property: on a straggler-heavy 3G fleet, FedBuff-style buffered
//! aggregation ([`RoundMode::BufferedAsync`]) reaches the target metric
//! in fewer simulated seconds than synchronous FedAvg **at equal uplink
//! bytes** — same per-frame wire cost, same number of aggregated
//! updates, strictly less virtual time — because slow uplinks stop
//! gating every round.
//!
//! These tests drive the REAL stack minus training: real encoded frames
//! (fixed-size cosine-4, no DEFLATE, so byte accounting is exact),
//! through the real [`SimTransport`] and the real [`Server::ingest`]
//! state machine, via the shared [`dryrun`] drivers that
//! `repro sim --quick` also smokes in CI.

use cossgd::compress::Pipeline;
use cossgd::fl::metrics::{History, RoundRecord};
use cossgd::fl::transport::dryrun;
use cossgd::sim::{DeviceTier, RoundPolicy, SimConfig, Timeline};

/// A straggler-heavy 3G fleet: most devices are ordinary 3G, a fat tail
/// crawls at a quarter of the uplink and an eighth of the compute.
/// Availability/dropout are off so byte accounting is exact: every
/// trained update crosses the wire.
fn straggler_fleet() -> SimConfig {
    SimConfig {
        tiers: vec![
            DeviceTier::new("3g·fast", 0.6, 2.0, 0.75, 4000.0),
            DeviceTier::new("3g·slow", 0.2, 2.0, 0.75, 500.0),
            DeviceTier::new("3g·crawl", 0.2, 2.0, 0.25, 250.0),
        ],
        policy: RoundPolicy::Synchronous,
        availability: 1.0,
        dropout: 0.0,
        jitter: 0.2,
    }
}

/// Synthetic convergence curve: the metric depends only on how many
/// aggregated model updates have been applied (both modes aggregate the
/// same number of same-size updates per application, so curves are
/// comparable at equal uplink bytes).
fn history_over(tl: &Timeline, target_rounds: usize) -> History {
    let mut h = History::new("dry");
    for (i, r) in tl.records.iter().enumerate() {
        h.push(RoundRecord {
            round: r.round,
            train_loss: 1.0 / (i + 1) as f64,
            eval_metric: Some(0.9 * (i + 1) as f64 / target_rounds as f64),
            eval_loss: None,
            uplink_bytes: 0,
            downlink_bytes: 0,
            clients: r.reporters,
            stale_updates: r.stragglers_dropped,
            dup_updates: 0,
            malformed_updates: 0,
            bits: Vec::new(),
            deflate_level: None,
        });
    }
    h
}

const N: usize = 100_000; // 100k-param model: transfers dominate on 3G
const CLIENTS: usize = 40;
const K: usize = 10; // reporters per aggregation, both modes
const ROUNDS: usize = 12;
const SEED: u64 = 9;

/// The acceptance criterion (ISSUE 4): buffered async beats synchronous
/// on a straggler-heavy 3G fleet at equal uplink bytes.
#[test]
fn buffered_async_beats_sync_on_straggler_heavy_3g_fleet_at_equal_uplink_bytes() {
    // No DEFLATE ⇒ every cosine-4 frame has the identical wire size, so
    // "equal uplink bytes" is exact arithmetic, not approximation.
    let pipe = Pipeline::cosine(4).without_deflate();
    let fleet = straggler_fleet();

    let sync = dryrun::run_sync(&pipe, &fleet, N, CLIENTS, K, ROUNDS, SEED).expect("sync run");
    // Same fleet (same seed ⇒ identical devices), same target number of
    // aggregations, each consuming the same K same-size updates. A
    // generous staleness bound keeps slow devices contributing
    // (discounted) instead of being discarded.
    let asyn = dryrun::run_async(&pipe, &fleet, N, CLIENTS, K, 2 * K, ROUNDS, 8, SEED)
        .expect("async run");

    assert_eq!(sync.timeline.records.len(), ROUNDS);
    assert_eq!(asyn.aggregations, ROUNDS);

    // Equal uplink bytes: sync delivered exactly ROUNDS·K frames; async
    // consumed ROUNDS·K accepted frames plus any discarded ones — with a
    // generous staleness bound the discard tail must stay marginal.
    let frame_bytes = sync.ledger.uplink_bytes / (ROUNDS as u64 * K as u64);
    assert_eq!(
        sync.ledger.uplink_bytes,
        frame_bytes * ROUNDS as u64 * K as u64,
        "cosine-4 without DEFLATE must have a fixed frame size"
    );
    assert_eq!(
        asyn.ledger.uplink_bytes,
        (ROUNDS * K + asyn.dropped) as u64 * frame_bytes
    );
    assert!(
        asyn.ledger.uplink_bytes as f64 <= sync.ledger.uplink_bytes as f64 * 1.1,
        "async spent {} uplink bytes vs sync {} — not an equal-bytes comparison",
        asyn.ledger.uplink_bytes,
        sync.ledger.uplink_bytes
    );

    // The headline: the same aggregation count in well under the sync
    // time — the crawl tier no longer gates every round.
    assert!(
        asyn.timeline.total_secs() < 0.7 * sync.timeline.total_secs(),
        "async {:.1}s not well below sync {:.1}s",
        asyn.timeline.total_secs(),
        sync.timeline.total_secs()
    );

    // And in time-to-target-metric terms (metric = f(aggregations), so
    // the curves are identical per update consumed).
    let h_sync = history_over(&sync.timeline, ROUNDS);
    let h_async = history_over(&asyn.timeline, ROUNDS);
    let t_sync = sync
        .timeline
        .time_to_metric(&h_sync, 0.89)
        .expect("sync reaches target");
    let t_async = asyn
        .timeline
        .time_to_metric(&h_async, 0.89)
        .expect("async reaches target");
    assert!(
        t_async < t_sync,
        "async to-target {t_async:.1}s not below sync {t_sync:.1}s"
    );
}

/// Same seed ⇒ tick- and byte-identical buffered-async runs: the event
/// loop (admission lottery, flight queue, window closes) is fully
/// deterministic.
#[test]
fn buffered_async_is_deterministic() {
    let pipe = Pipeline::cosine(4);
    let mut fleet = straggler_fleet();
    fleet.availability = 0.9;
    fleet.dropout = 0.03;
    let run = || dryrun::run_async(&pipe, &fleet, 20_000, 30, 8, 16, 6, 3, 17).expect("run");
    let (a, b) = (run(), run());
    assert_eq!(a.timeline, b.timeline);
    assert_eq!(a.ledger.uplink_bytes, b.ledger.uplink_bytes);
    assert_eq!(a.ledger.downlink_bytes, b.ledger.downlink_bytes);
    assert_eq!(a.dropped, b.dropped);
    // A different seed reshuffles the fleet, the lotteries and the clock.
    let c = dryrun::run_async(&pipe, &fleet, 20_000, 30, 8, 16, 6, 3, 18).expect("run");
    assert_ne!(a.timeline, c.timeline);
}

/// With a zero staleness bound on a heterogeneous fleet, slow uploads
/// land after the window that dispatched them and are discarded as
/// stale — the drops are visible in the ledger (they were metered: they
/// crossed the wire) and in the timeline's straggler counter, yet every
/// window still fills.
#[test]
fn zero_staleness_bound_drops_slow_updates_but_windows_still_fill() {
    let pipe = Pipeline::cosine(4).without_deflate();
    let fleet = straggler_fleet();
    let strict = dryrun::run_async(&pipe, &fleet, N, CLIENTS, K, 2 * K, 8, 0, SEED).expect("run");
    assert_eq!(strict.aggregations, 8, "windows must fill despite drops");
    assert!(
        strict.dropped > 0,
        "a zero staleness bound on a straggler fleet must drop something"
    );
    let tl_drops: usize = strict
        .timeline
        .records
        .iter()
        .map(|r| r.stragglers_dropped)
        .sum();
    assert_eq!(tl_drops, strict.dropped, "timeline must account for every drop");
    // Dropped updates were still metered — delivery is what costs bytes.
    let frame_bytes = strict.ledger.uplink_bytes / (8 * K + strict.dropped) as u64;
    assert_eq!(
        strict.ledger.uplink_bytes,
        (8 * K + strict.dropped) as u64 * frame_bytes
    );
    // Relaxing the bound keeps more updates (fewer drops).
    let relaxed = dryrun::run_async(&pipe, &fleet, N, CLIENTS, K, 2 * K, 8, 8, SEED).expect("run");
    assert!(relaxed.dropped < strict.dropped);
}

/// The async timeline is well-formed: contiguous monotone windows, each
/// reporting exactly the buffer size.
#[test]
fn async_timeline_windows_are_contiguous_and_sized() {
    let pipe = Pipeline::cosine(4);
    let out = dryrun::run_async(&pipe, &straggler_fleet(), 20_000, 30, 6, 12, 5, 4, 3)
        .expect("run");
    assert_eq!(out.timeline.records.len(), 5);
    for (i, r) in out.timeline.records.iter().enumerate() {
        assert_eq!(r.round, i + 1);
        assert_eq!(r.reporters, 6, "every window aggregates buffer_k updates");
        assert!(r.end >= r.start);
        if i > 0 {
            assert_eq!(r.start, out.timeline.records[i - 1].end, "window gap at {i}");
        }
    }
    assert!(out.timeline.mean_round_secs() > 0.0);
}

// ---------------------------------------------------------------------------
// ISSUE 5 satellites: staleness-path audit + per-flight seed derivation.
// ---------------------------------------------------------------------------

/// The staleness audit's first claim, pinned at the integration level:
/// the open aggregate renormalizes by the *discounted* weight sum
/// Σ N_i/(1+s_i) — NOT the raw Σ N_i. With mixed staleness the two
/// normalizations differ measurably; the server must produce the former.
#[test]
fn buffered_async_renormalizes_by_discounted_weight_sum() {
    use cossgd::compress::{wire, Direction, PipelineState};
    use cossgd::fl::server::Server;
    use cossgd::fl::{Frame, Ingest, RoundMode};
    use cossgd::util::rng::Pcg64;

    let weights = [120u32, 80, 50];
    let updates: [Vec<f32>; 3] = [vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]];
    let mut server = Server::new(vec![0.0, 0.0], 1.0)
        .with_clients(weights.to_vec())
        .with_round_mode(RoundMode::BufferedAsync {
            buffer_k: 3,
            max_staleness: 4,
        });
    // Advance to round 2 so staleness 0/1/2 all exist.
    server.finish_round();
    server.finish_round();
    let pipe = cossgd::compress::Pipeline::float32();
    let staleness = [0usize, 1, 2];
    for (c, (g, &s)) in updates.iter().zip(&staleness).enumerate() {
        let enc = pipe.encode(
            g,
            Direction::Uplink,
            &mut PipelineState::new(),
            &mut Pcg64::seeded(c as u64),
        );
        let frame = Frame {
            round: 2 - s,
            client_id: c,
            payload: wire::serialize(&enc),
        };
        assert_eq!(server.ingest(&frame), Ingest::Accepted { staleness: s });
    }
    assert!(server.ready_to_apply());
    server.finish_round();

    // Discounted weights: 120/1, 80/2, 50/3.
    let dw = [120.0f64, 40.0, 50.0 / 3.0];
    let dsum: f64 = dw.iter().sum();
    let expect_discounted: Vec<f64> = (0..2)
        .map(|i| {
            let num = dw[0] * updates[0][i] as f64
                + dw[1] * updates[1][i] as f64
                + dw[2] * updates[2][i] as f64;
            -num / dsum
        })
        .collect();
    // The WRONG normalization (raw N_i sum) the audit guards against.
    let raw_sum: f64 = weights.iter().map(|&w| w as f64).sum();
    for i in 0..2 {
        let got = server.params[i] as f64;
        assert!(
            (got - expect_discounted[i]).abs() < 1e-6,
            "param {i}: {got} != discounted-normalized {}",
            expect_discounted[i]
        );
        let wrong = expect_discounted[i] * dsum / raw_sum;
        assert!(
            (got - wrong).abs() > 1e-3,
            "param {i}: matches the raw-Σ N_i normalization — discount lost"
        );
    }
}

/// The staleness audit's second claim: per-flight RNG seed derivation
/// cannot collide two flights onto one stream. The old derivations
/// (`seed.wrapping_add(round)` / `seed ^ (round << 1)`) were injective
/// in the ROUND — so a client re-dispatched within one round (arrive,
/// free the slot, re-admit before the window closes) replayed the exact
/// same stream. `flight_seed` is injective in the flight counter.
#[test]
fn per_flight_seed_derivation_never_collides() {
    use cossgd::fl::transport::dryrun::flight_seed;
    use cossgd::util::rng::Pcg64;
    use std::collections::HashSet;

    for run_seed in [0u64, 9, 42, u64::MAX] {
        let mut seen = HashSet::new();
        for flight in 0..10_000u64 {
            assert!(
                seen.insert(flight_seed(run_seed, flight)),
                "seed collision at run_seed={run_seed} flight={flight}"
            );
        }
    }
    // Two flights of the SAME client in the SAME round draw different
    // streams (this is the collision the old round-keyed salt produced).
    let client = 7u64;
    let a: Vec<u64> = {
        let mut r = Pcg64::new(flight_seed(9, 0), client);
        (0..8).map(|_| r.next_u64()).collect()
    };
    let b: Vec<u64> = {
        let mut r = Pcg64::new(flight_seed(9, 1), client);
        (0..8).map(|_| r.next_u64()).collect()
    };
    assert_ne!(a, b, "consecutive flights replayed one RNG stream");
}

// ---------------------------------------------------------------------------
// ISSUE 5 tentpole acceptance: adaptive bit allocation on the 3G
// straggler fleet.
// ---------------------------------------------------------------------------

use cossgd::compress::allocator::{uniform_cost, BitSchedule, LayerMap};
use cossgd::compress::cosine::{BoundMode, Rounding};

const BIT_N: usize = 40_000;
const BIT_CLIENTS: usize = 30;
const BIT_K: usize = 8;
const BIT_ROUNDS: usize = 36;
const BIT_LAYERS: usize = 8;
/// Per-layer gradient scale decay: layer 0 holds ~94% of the energy —
/// the regime where uniform widths waste most of their bits.
const BIT_DECAY: f32 = 0.25;

fn bit_harness(schedule: BitSchedule) -> dryrun::DryBits {
    dryrun::DryBits {
        schedule,
        map: LayerMap::even(BIT_N, BIT_LAYERS),
        decay: BIT_DECAY,
    }
}

/// Convergence proxy: each aggregation contributes progress
/// `1/(1 + relative quantization MSE)` — a round of exact updates is
/// worth 1, a round of noise-dominated updates nearly 0 — and the run
/// "reaches the target" when cumulative progress crosses `target`.
/// Returns the simulated seconds to that crossing (None if never).
fn time_to_progress(out: &dryrun::DryOutcome, target: f64) -> Option<f64> {
    let mut cum = 0.0f64;
    for (rec, mse) in out.timeline.records.iter().zip(&out.round_mse) {
        cum += 1.0 / (1.0 + mse);
        if cum >= target {
            return Some(cossgd::sim::secs(rec.end));
        }
    }
    None
}

/// The ISSUE 5 acceptance property: on the straggler-heavy 3G fleet,
/// `adaptive` (auto budget = the uniform 4-bit byte cost) reaches the
/// target in fewer simulated seconds than EVERY constant width 2..=8 —
/// including the widths that spend up to twice its bytes per round —
/// while never exceeding its own per-round uplink-byte budget.
#[test]
fn adaptive_beats_every_constant_width_on_3g_straggler_fleet() {
    // Auto bound + no DEFLATE: the error envelope is analytic and every
    // frame's wire size is exact arithmetic.
    let pipe = Pipeline::cosine_with(4, Rounding::Biased, BoundMode::Auto).without_deflate();
    let fleet = straggler_fleet();
    let target = 10.0f64;

    let adaptive = dryrun::run_sync_bits(
        &pipe,
        Some(&bit_harness(BitSchedule::Adaptive { budget: 0 })),
        &fleet,
        BIT_N,
        BIT_CLIENTS,
        BIT_K,
        BIT_ROUNDS,
        SEED,
    )
    .expect("adaptive run");
    let t_adaptive =
        time_to_progress(&adaptive, target).expect("adaptive must reach the target");

    // Budget discipline: per accepted update, the payload never exceeds
    // the auto budget (the uniform 4-bit cost over the layer map).
    let budget = uniform_cost(&LayerMap::even(BIT_N, BIT_LAYERS), 4) as u64;
    let per_round_cap = budget * BIT_K as u64;
    assert!(
        adaptive.ledger.uplink_bytes <= per_round_cap * BIT_ROUNDS as u64,
        "adaptive overspent its uplink budget: {} > {}",
        adaptive.ledger.uplink_bytes,
        per_round_cap * BIT_ROUNDS as u64
    );

    // The controller actually allocates per layer: after warm-up the plan
    // is non-uniform, concentrated on the energy-heavy first layer.
    let warm = &adaptive.round_bits[BIT_ROUNDS - 1];
    assert_eq!(warm.len(), BIT_LAYERS);
    assert!(
        warm[0] > warm[BIT_LAYERS - 1],
        "no per-layer concentration: {warm:?}"
    );

    for w in 2u8..=8 {
        let constant = dryrun::run_sync_bits(
            &pipe,
            Some(&bit_harness(BitSchedule::Const(w))),
            &fleet,
            BIT_N,
            BIT_CLIENTS,
            BIT_K,
            BIT_ROUNDS,
            SEED,
        )
        .unwrap_or_else(|e| panic!("const:{w} run: {e:#}"));
        match time_to_progress(&constant, target) {
            None => {} // never reached the target inside the horizon: loses
            Some(t_const) => assert!(
                t_adaptive < t_const,
                "adaptive {t_adaptive:.1}s !< const:{w} {t_const:.1}s"
            ),
        }
        // Sanity: at least the widest constants must reach the target,
        // otherwise the comparison above is vacuous.
        if w >= 7 {
            assert!(
                time_to_progress(&constant, target).is_some(),
                "const:{w} should reach the target inside {BIT_ROUNDS} rounds"
            );
        }
    }
}

/// `anneal:<hi>..<lo>` walks the width down monotonically across the
/// frame stream — one (uniform) width per round, decoded purely off the
/// per-frame headers.
#[test]
fn anneal_schedule_walks_widths_down_the_stream() {
    let pipe = Pipeline::cosine(4).without_deflate();
    let out = dryrun::run_sync_bits(
        &pipe,
        Some(&bit_harness(BitSchedule::Anneal { hi: 8, lo: 2 })),
        &straggler_fleet(),
        BIT_N,
        BIT_CLIENTS,
        BIT_K,
        10,
        SEED,
    )
    .expect("anneal run");
    assert_eq!(out.round_bits.len(), 10);
    assert_eq!(out.round_bits[0], vec![8]);
    assert_eq!(out.round_bits[9], vec![2]);
    for w in out.round_bits.windows(2) {
        assert!(w[0][0] >= w[1][0], "anneal went up: {:?}", out.round_bits);
    }
    // Fidelity degrades as the width anneals down (mixed widths across
    // the stream decode correctly round after round).
    assert!(
        out.round_mse[9] > out.round_mse[0],
        "2-bit rounds should be noisier than 8-bit rounds"
    );
}
