//! Wire-format contract tests over the public API: CSG2 round-trips for
//! every quantizer × direction × stage combination, and malformed-frame
//! rejection (bad magic, unknown identities, truncated payloads,
//! oversized `payload_len`).

use cossgd::compress::cosine::{BoundMode, Rounding};
use cossgd::compress::{decode, wire, Direction, EncodedTensor, Pipeline, PipelineState};
use cossgd::util::propcheck::gradient_like;
use cossgd::util::rng::Pcg64;

/// Every scheme in the library, covering all wire kind ids.
fn all_pipelines() -> Vec<Pipeline> {
    vec![
        Pipeline::float32(),
        Pipeline::cosine(8),
        Pipeline::cosine_with(2, Rounding::Unbiased, BoundMode::Auto),
        Pipeline::linear(4, Rounding::Biased),
        Pipeline::linear_rotated(2, Rounding::Unbiased),
        Pipeline::sign(),
        Pipeline::sign_norm(),
        Pipeline::ef_sign(),
    ]
}

#[test]
fn roundtrip_all_schemes_both_directions() {
    let mut rng = Pcg64::seeded(41);
    for size in [1usize, 7, 260, 4096] {
        let g = gradient_like(&mut rng, size);
        for pipe in all_pipelines() {
            for keep in [1.0, 0.3] {
                let pipe = pipe.clone().with_sparsify(keep);
                for dir in [Direction::Uplink, Direction::Downlink] {
                    let mut st = PipelineState::new();
                    let enc = pipe.encode(&g, dir, &mut st, &mut rng);
                    let frame = wire::serialize(&enc);
                    assert_eq!(frame.len(), enc.wire_bytes(), "{}", pipe.name());
                    let back = wire::deserialize(&frame).unwrap();
                    assert_eq!(back, enc, "{} {dir:?} n={size}", pipe.name());
                    assert_eq!(back.direction, dir);
                    // Decode from the deserialized frame matches decoding
                    // the original — and has the dense length.
                    let d1 = decode(&back).unwrap();
                    let d2 = decode(&enc).unwrap();
                    assert_eq!(d1, d2, "{}", pipe.name());
                    assert_eq!(d1.len(), size, "{}", pipe.name());
                }
            }
        }
    }
}

#[test]
fn frames_are_self_describing() {
    // Decoding consults only the frame: a receiver with no knowledge of
    // the sender's Pipeline reconstructs the same values.
    let mut rng = Pcg64::seeded(42);
    let g = gradient_like(&mut rng, 1000);
    let pipe = Pipeline::cosine(4).with_sparsify(0.5).with_rotation();
    let enc = pipe.encode(&g, Direction::Downlink, &mut PipelineState::new(), &mut rng);
    let frame = wire::serialize(&enc);
    // No pipeline in sight on the decode side:
    let dec = decode(&wire::deserialize(&frame).unwrap()).unwrap();
    assert_eq!(dec.len(), g.len());
    assert!(dec.iter().any(|&x| x != 0.0));
}

fn sample_frame() -> Vec<u8> {
    let mut rng = Pcg64::seeded(43);
    let g = gradient_like(&mut rng, 64);
    let enc = Pipeline::cosine(2).encode(
        &g,
        Direction::Uplink,
        &mut PipelineState::new(),
        &mut rng,
    );
    wire::serialize(&enc)
}

#[test]
fn rejects_bad_magic() {
    let mut frame = sample_frame();
    frame[0..4].copy_from_slice(b"XXXX");
    assert!(wire::deserialize(&frame).is_err());
    // CSG1 gets a dedicated legacy error.
    let mut frame = sample_frame();
    frame[0..4].copy_from_slice(b"CSG1");
    let err = wire::deserialize(&frame).unwrap_err().to_string();
    assert!(err.contains("CSG1"), "error should name the legacy format: {err}");
}

#[test]
fn rejects_unknown_quantizer_and_bad_bits() {
    let mut frame = sample_frame();
    frame[4] = 99; // unknown kind id
    assert!(wire::deserialize(&frame).is_err());
    let mut frame = sample_frame();
    frame[4] = 3; // retired CSG1 linear-rotated id
    assert!(wire::deserialize(&frame).is_err());
    let mut frame = sample_frame();
    frame[5] = 0; // zero-width codes
    assert!(wire::deserialize(&frame).is_err());
    let mut frame = sample_frame();
    frame[5] = 31; // cosine with absurd width
    assert!(wire::deserialize(&frame).is_err());
}

#[test]
fn rejects_bad_flags_and_direction() {
    let mut frame = sample_frame();
    frame[6] |= 0b100; // reserved flag bit
    assert!(wire::deserialize(&frame).is_err());
    let mut frame = sample_frame();
    frame[7] = 2; // no such direction
    assert!(wire::deserialize(&frame).is_err());
}

#[test]
fn rejects_truncated_and_oversized_payloads() {
    let frame = sample_frame();
    // Truncated header.
    assert!(wire::deserialize(&frame[..wire::HEADER_BYTES - 1]).is_err());
    // Truncated payload.
    assert!(wire::deserialize(&frame[..frame.len() - 1]).is_err());
    // Trailing garbage.
    let mut padded = frame.clone();
    padded.push(0);
    assert!(wire::deserialize(&padded).is_err());
    // payload_len larger than the actual payload.
    let mut oversized = frame.clone();
    oversized[40..44].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(wire::deserialize(&oversized).is_err());
    // payload_len smaller than the actual payload.
    let mut undersized = frame;
    undersized[40..44].copy_from_slice(&0u32.to_le_bytes());
    assert!(wire::deserialize(&undersized).is_err());
}

#[test]
fn rejects_inconsistent_kept_count() {
    let mut frame = sample_frame();
    // kept > n.
    let n = u32::from_le_bytes(frame[8..12].try_into().unwrap());
    frame[12..16].copy_from_slice(&(n + 1).to_le_bytes());
    assert!(wire::deserialize(&frame).is_err());
}

#[test]
fn corrupt_deflate_payload_fails_decode_not_panic() {
    let mut rng = Pcg64::seeded(44);
    let g = gradient_like(&mut rng, 50_000);
    let enc = Pipeline::cosine(8).encode(
        &g,
        Direction::Uplink,
        &mut PipelineState::new(),
        &mut rng,
    );
    assert!(enc.deflated, "expected a deflated payload for this test");
    let mut bad = EncodedTensor {
        payload: enc.payload.clone(),
        ..enc
    };
    // Corrupt the middle of the DEFLATE stream.
    let mid = bad.payload.len() / 2;
    bad.payload[mid] ^= 0xFF;
    bad.payload[mid + 1] ^= 0xFF;
    // Corruption must surface as Err (inflate failure / short payload) or
    // — if the garbage still inflates to enough bytes — as a dense vector
    // of the declared length. Never a panic, never a wrong-length Ok.
    if let Ok(v) = decode(&bad) {
        assert_eq!(v.len(), 50_000, "decode returned a wrong-length vector");
    }
}

/// ISSUE 5 satellite: a CSG2 frame sequence whose bit width changes on
/// EVERY frame (cycling 1..=8) must round-trip purely off the
/// self-describing headers — the receiver never consults the sender's
/// configuration — and `Server::ingest` must fold it bit-identically to
/// per-frame decode-then-add. The sequence is ingested inside ONE
/// buffered-async round, so the width changes *within* an open
/// aggregation window, exactly as an adaptive plan change lands on
/// in-flight frames.
#[test]
fn mixed_width_frame_stream_roundtrips_and_ingests_bit_identically() {
    use cossgd::fl::server::Server;
    use cossgd::fl::{Frame, Ingest, RoundMode};
    use cossgd::util::propcheck::forall;

    forall(
        12,
        71,
        |rng, size| {
            let n = size.len(rng) * 50 + 64;
            gradient_like(rng, n)
        },
        |g| {
            let n = g.len();
            let n_frames = 16usize; // two full 1..=8 width cycles
            let weights: Vec<u32> = (0..n_frames as u32).map(|i| 10 + i * 7).collect();

            // One encoded frame per client, width cycling 1..=8.
            let mut encs: Vec<EncodedTensor> = Vec::new();
            for i in 0..n_frames {
                let bits = (i % 8) as u8 + 1;
                let pipe = Pipeline::cosine(4).with_bits(bits);
                let mut rng = Pcg64::seeded(1000 + i as u64);
                let enc = pipe.encode(g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
                assert_eq!(enc.bits, bits, "header must carry the per-frame width");
                // Round-trip through the wire: header-driven decode only.
                let back = wire::deserialize(&wire::serialize(&enc)).unwrap();
                if back != enc || decode(&back).unwrap() != decode(&enc).unwrap() {
                    return false;
                }
                encs.push(enc);
            }

            // Ingest the whole mixed-width sequence inside ONE
            // buffered-async window (every frame tags round 0; the
            // buffer only fills at the last frame).
            let mut server = Server::new(vec![0.0f32; n], 1.0)
                .with_clients(weights.clone())
                .with_round_mode(RoundMode::BufferedAsync {
                    buffer_k: n_frames,
                    max_staleness: 2,
                });
            for (i, enc) in encs.iter().enumerate() {
                let frame = Frame {
                    round: 0,
                    client_id: i,
                    payload: wire::serialize(enc),
                };
                assert_eq!(server.ingest(&frame), Ingest::Accepted { staleness: 0 });
            }
            assert!(server.ready_to_apply());
            assert_eq!(server.finish_round(), n_frames);

            // Reference: per-frame decode-then-add with the same weights.
            let mut acc = vec![0.0f64; n];
            let mut wsum = 0.0f64;
            for (enc, &w) in encs.iter().zip(&weights) {
                let dec = decode(enc).unwrap();
                for (a, &d) in acc.iter_mut().zip(&dec) {
                    *a += d as f64 * w as f64;
                }
                wsum += w as f64;
            }
            // Mirror finish_round's arithmetic exactly (scale then mul).
            let scale = 1.0f64 / wsum;
            let expect: Vec<f32> = acc.iter().map(|&a| -((a * scale) as f32)).collect();
            server.params == expect
        },
    );
}
