//! Shard-count invariance of the parallel ingest plane (system level).
//!
//! The contract under test: a server fed the SAME arrival stream through
//! an [`IngestPlane`] at **any** shard count and **any** flush
//! granularity produces
//!
//! * a bit-identical final model (`params.to_bits()` equal element-wise),
//! * an identical verdict stream (accept/duplicate/stale/malformed, in
//!   arrival order) and identical per-round verdict counters,
//! * identical controller observation streams (`round_observations`),
//!
//! across frame orders, duplicate/stale/malformed interleavings, and
//! quantizer widths 1..=8 (single-frame and segmented mixed-width
//! streams) in a buffered-async window. The plane may only change WHEN
//! the folds run — never what they sum to.

use cossgd::compress::{wire, Direction, LayerMap, Pipeline, PipelineState, SegmentObs};
use cossgd::fl::transport::dryrun::{self, DryBits};
use cossgd::fl::{Frame, Ingest, IngestPlane, RoundMode, Server};
use cossgd::obs::{Metrics, Tracer};
use cossgd::sim::SimConfig;
use cossgd::util::propcheck::gradient_like;
use cossgd::util::rng::Pcg64;

const N: usize = 640;
const LAYERS: usize = 8;
const CLIENTS: usize = 8;
const BUFFER_K: usize = 4;
const MAX_STALENESS: usize = 2;

/// One arrival in the scripted stream.
enum Kind {
    /// Whole-tensor single segment at the given width.
    Single(u8),
    /// Per-layer segmented stream, widths cycling 1..=8 from `salt`.
    Segmented,
    /// Garbage bytes the server must refuse without unwinding.
    Malformed,
}

fn payload(map: &LayerMap, kind: &Kind, salt: u64) -> Vec<u8> {
    let mut rng = Pcg64::new(salt, 0x1A6E);
    match kind {
        Kind::Single(bits) => {
            let g = gradient_like(&mut rng, N);
            let pipe = Pipeline::cosine(*bits);
            wire::serialize(&pipe.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng))
        }
        Kind::Segmented => {
            let g = gradient_like(&mut rng, N);
            let segs: Vec<_> = (0..map.len())
                .map(|l| {
                    let bits = 1 + ((salt as usize + l) % 8) as u8;
                    Pipeline::cosine(bits).encode(
                        &g[map.segment(l)],
                        Direction::Uplink,
                        &mut PipelineState::new(),
                        &mut rng,
                    )
                })
                .collect();
            wire::serialize_stream(&segs)
        }
        Kind::Malformed => vec![0xFF; 24],
    }
}

/// The scripted arrival stream: accepted single + segmented frames at
/// every width, a same-window duplicate, a future-tagged stale frame and
/// a malformed frame interleaved, across enough accepts to close several
/// buffered-async windows.
fn arrivals() -> Vec<(usize, usize, Kind)> {
    vec![
        (0, 0, Kind::Single(1)),
        (1, 0, Kind::Segmented),
        (0, 0, Kind::Single(3)), // duplicate: same client, same window
        (2, 99, Kind::Single(2)), // stale: future model tag
        (3, 0, Kind::Malformed),
        (2, 0, Kind::Segmented),
        (4, 0, Kind::Single(5)), // 4th accept -> window 1 closes
        (5, 0, Kind::Single(2)), // staleness 1, discounted
        (6, 1, Kind::Segmented),
        (7, 0, Kind::Single(8)),
        (3, 1, Kind::Single(4)), // 4th accept -> window 2 closes
        (1, 1, Kind::Segmented), // staleness 1
        (5, 2, Kind::Single(7)),
        (0, 2, Kind::Single(6)),
        (6, 2, Kind::Segmented), // 4th accept -> window 3 closes
    ]
}

struct Outcome {
    param_bits: Vec<u32>,
    verdicts: Vec<&'static str>,
    round_verdicts: Vec<(usize, usize, usize)>,
    observations: Vec<Vec<SegmentObs>>,
}

fn label(v: &Ingest) -> &'static str {
    match v {
        Ingest::Accepted { .. } => "accepted",
        Ingest::Duplicate => "duplicate",
        Ingest::StaleRound => "stale",
        Ingest::Malformed => "malformed",
    }
}

/// Drive the scripted stream through a server + plane at the given shard
/// count and queue capacity (capacity 1 = flush per arrival, the
/// streamed extreme; large = flush only at window close).
fn run_scenario(map: &LayerMap, order: &[usize], shards: usize, capacity: usize) -> Outcome {
    let script = arrivals();
    let mut server = Server::new(vec![0.1; N], 1.0)
        .with_clients(vec![100; CLIENTS])
        .with_round_mode(RoundMode::BufferedAsync {
            buffer_k: BUFFER_K,
            max_staleness: MAX_STALENESS,
        });
    let mut plane = IngestPlane::new(shards, map).with_capacity(capacity);
    let mut out = Outcome {
        param_bits: Vec::new(),
        verdicts: Vec::new(),
        round_verdicts: Vec::new(),
        observations: Vec::new(),
    };
    for &i in order {
        let (client_id, round, kind) = &script[i];
        let frame = Frame {
            round: *round,
            client_id: *client_id,
            payload: payload(map, kind, i as u64),
        };
        let (verdict, prepared) = server.ingest_prepare(&frame);
        out.verdicts.push(label(&verdict));
        if let Some(p) = prepared {
            if plane.full() {
                plane.flush_into(&mut server).expect("mid-window flush");
            }
            plane.submit(p);
        }
        if server.ready_to_apply() {
            plane.flush_into(&mut server).expect("window-close flush");
            out.observations.push(server.round_observations());
            out.round_verdicts.push(server.round_verdicts());
            server.finish_round();
        }
    }
    plane.flush_into(&mut server).expect("tail flush");
    out.param_bits = server.params.iter().map(|p| p.to_bits()).collect();
    out
}

/// The same scripted stream through plain serial [`Server::ingest`] — no
/// plane at all, every accepted frame folded at the arrival site.
fn run_serial(map: &LayerMap, order: &[usize]) -> Outcome {
    let script = arrivals();
    let mut server = Server::new(vec![0.1; N], 1.0)
        .with_clients(vec![100; CLIENTS])
        .with_round_mode(RoundMode::BufferedAsync {
            buffer_k: BUFFER_K,
            max_staleness: MAX_STALENESS,
        });
    let mut out = Outcome {
        param_bits: Vec::new(),
        verdicts: Vec::new(),
        round_verdicts: Vec::new(),
        observations: Vec::new(),
    };
    for &i in order {
        let (client_id, round, kind) = &script[i];
        let frame = Frame {
            round: *round,
            client_id: *client_id,
            payload: payload(map, kind, i as u64),
        };
        out.verdicts.push(label(&server.ingest(&frame)));
        if server.ready_to_apply() {
            out.observations.push(server.round_observations());
            out.round_verdicts.push(server.round_verdicts());
            server.finish_round();
        }
    }
    out.param_bits = server.params.iter().map(|p| p.to_bits()).collect();
    out
}

/// A few deterministic stream orders: scripted order, reversed, and two
/// seeded shuffles — duplicates/stales land in different windows per
/// order, and EVERY order must be shard-count invariant.
fn orders(len: usize) -> Vec<Vec<usize>> {
    let identity: Vec<usize> = (0..len).collect();
    let reversed: Vec<usize> = (0..len).rev().collect();
    let mut shuffles = vec![identity, reversed];
    for seed in [7u64, 1234] {
        let mut rng = Pcg64::new(seed, 0x0D0E);
        let mut v: Vec<usize> = (0..len).collect();
        for i in (1..v.len()).rev() {
            v.swap(i, rng.below_usize(i + 1));
        }
        shuffles.push(v);
    }
    shuffles
}

#[test]
fn sharded_ingest_is_bit_identical_across_shard_counts_and_granularities() {
    let map = LayerMap::even(N, LAYERS);
    // The scripted order must exercise every adversarial verdict (other
    // orders may shift which window a frame lands in, so only invariance
    // is asserted for them).
    let scripted = run_scenario(&map, &(0..arrivals().len()).collect::<Vec<_>>(), 1, 64);
    for needle in ["accepted", "duplicate", "stale", "malformed"] {
        assert!(
            scripted.verdicts.iter().any(|&v| v == needle),
            "script lost its `{needle}` interleaving: {:?}",
            scripted.verdicts
        );
    }
    for order in orders(arrivals().len()) {
        let reference = run_scenario(&map, &order, 1, 64);
        assert!(!reference.round_verdicts.is_empty(), "no window closed in {order:?}");
        for shards in [4usize, 16] {
            for capacity in [1usize, 3, 64] {
                let got = run_scenario(&map, &order, shards, capacity);
                assert_eq!(
                    got.param_bits, reference.param_bits,
                    "params diverged: shards={shards} capacity={capacity} order={order:?}"
                );
                assert_eq!(got.verdicts, reference.verdicts, "verdict stream diverged");
                assert_eq!(
                    got.round_verdicts, reference.round_verdicts,
                    "per-round verdict counters diverged"
                );
                assert_eq!(
                    got.observations, reference.observations,
                    "controller observation streams diverged"
                );
            }
        }
    }
}

/// Regression guard for the fused-wire-accumulate dispatch: plain serial
/// [`Server::ingest`] (fold at the arrival site, no plane) and the
/// prepare → queue → flush plane path must agree on EVERYTHING — verdict
/// stream, per-round counters, observation streams, and final params to
/// the bit — for every stream order. The two paths share one fold kernel
/// by construction; this pins that equivalence against future drift.
#[test]
fn serial_ingest_matches_plane_flush_verdict_for_verdict() {
    let map = LayerMap::even(N, LAYERS);
    for order in orders(arrivals().len()) {
        let serial = run_serial(&map, &order);
        for (shards, capacity) in [(1usize, 64usize), (4, 1), (16, 3)] {
            let planed = run_scenario(&map, &order, shards, capacity);
            assert_eq!(
                planed.verdicts, serial.verdicts,
                "verdicts diverged from serial ingest: shards={shards} capacity={capacity} order={order:?}"
            );
            assert_eq!(
                planed.round_verdicts, serial.round_verdicts,
                "round counters diverged from serial ingest: shards={shards} capacity={capacity}"
            );
            assert_eq!(
                planed.observations, serial.observations,
                "observations diverged from serial ingest: shards={shards} capacity={capacity}"
            );
            assert_eq!(
                planed.param_bits, serial.param_bits,
                "params diverged from serial ingest: shards={shards} capacity={capacity} order={order:?}"
            );
        }
    }
}

/// Single-layer (whole-tensor) maps shard by even element split — the
/// legacy frame shape must be invariant too.
#[test]
fn whole_tensor_maps_shard_evenly_and_stay_invariant() {
    let map = LayerMap::whole(N);
    let order: Vec<usize> = (0..arrivals().len()).collect();
    let reference = run_scenario(&map, &order, 1, 64);
    for shards in [4usize, 16] {
        let got = run_scenario(&map, &order, shards, 2);
        assert_eq!(got.param_bits, reference.param_bits, "shards={shards}");
        assert_eq!(got.round_verdicts, reference.round_verdicts);
    }
}

/// End-to-end through the shared dry protocol drivers (the exact path
/// `repro sim --quick --ingest-shards N` smokes in CI): byte-identical
/// ledgers and identical controller decisions at 1 vs 4 vs 16 shards, in
/// both round modes.
#[test]
fn dry_protocol_runs_are_invariant_under_ingest_sharding() {
    let pipe = Pipeline::cosine(4);
    let sim = SimConfig::heterogeneous();
    let bits = DryBits {
        schedule: cossgd::compress::BitSchedule::Adaptive { budget: 0 },
        map: LayerMap::even(2_000, 4),
        decay: 0.5,
    };
    let run_pair = |shards: usize| {
        let sync = dryrun::run_sync_bits_traced(
            &pipe,
            Some(&bits),
            &sim,
            2_000,
            12,
            4,
            3,
            42,
            shards,
            &mut Tracer::disabled(),
            &mut Metrics::new(),
        )
        .expect("sync dry run");
        let asyn = dryrun::run_async_bits_traced(
            &pipe,
            Some(&bits),
            &sim,
            2_000,
            12,
            4,
            8,
            3,
            2,
            42,
            shards,
            &mut Tracer::disabled(),
            &mut Metrics::new(),
        )
        .expect("async dry run");
        (
            sync.ledger.uplink_bytes,
            sync.round_mse,
            sync.round_bits,
            asyn.ledger.uplink_bytes,
            asyn.round_mse,
            asyn.round_bits,
            asyn.dropped,
        )
    };
    let reference = run_pair(1);
    for shards in [4usize, 16] {
        assert_eq!(run_pair(shards), reference, "dry run diverged at {shards} shards");
    }
}
