//! The kernel fast path's bit-exactness contract: for every bit width in
//! 1..=16, the transcendental-free biased cosine quantizer (threshold
//! search) must produce codes **bit-identical** to the reference `acos`
//! path — including at adversarial inputs: ±0.0, subnormals, values
//! landing exactly on bin edges (±1 ULP), all-equal vectors, saturated
//! tails and degenerate shapes.

use cossgd::compress::cosine::{BoundMode, CosineQuantizer, Rounding};
use cossgd::compress::kernel::{
    accumulate_cosine, accumulate_linear, build_thresholds, reference_code, scale_for,
    search_code, KernelScratch,
};
use cossgd::compress::linear::LinearQuantizer;
use cossgd::compress::pipeline::{accumulate_with, decode_with};
use cossgd::compress::{Direction, EncodeScratch, Pipeline, PipelineState, Quantizer};
use cossgd::util::propcheck::{forall, gradient_like};
use cossgd::util::rng::Pcg64;

/// Neighbor in the IEEE-754 total order (same monotone-key construction
/// as the kernel's threshold bisection; handles the ±0 boundary).
fn ulp_step(x: f32, up: bool) -> f32 {
    let b = x.to_bits();
    let k = if b & 0x8000_0000 != 0 { !b } else { b | 0x8000_0000 };
    let k2 = if up { k + 1 } else { k - 1 };
    f32::from_bits(if k2 & 0x8000_0000 != 0 { k2 & 0x7fff_ffff } else { !k2 })
}

/// The scalar contract at the bin edges themselves: for every threshold
/// `t_k`, the search and the reference agree at `t_k` and both ULP
/// neighbors. This is exactly where a naive `cos(edge)` table (without
/// the exact bisection) goes wrong.
#[test]
fn scalar_search_matches_reference_at_every_bin_edge() {
    let mut thresholds = Vec::new();
    for bits in 1..=16u8 {
        // Wide tables get strided probing and fewer bounds to keep the
        // test fast; narrow ones are swept exhaustively.
        let stride = if bits <= 10 { 1 } else { 251 };
        let bounds: &[f32] = if bits <= 10 {
            &[0.0, 0.1, 0.7, 1.5]
        } else {
            &[0.0, 0.7]
        };
        for &bound in bounds {
            let scale = scale_for(bits, bound);
            assert!(scale > 0.0);
            build_thresholds(bits, bound, &mut thresholds);
            for (k, &t) in thresholds.iter().enumerate().step_by(stride) {
                if !t.is_finite() {
                    continue;
                }
                for x in [t, ulp_step(t, false), ulp_step(t, true)] {
                    let x = x.clamp(-1.0, 1.0);
                    assert_eq!(
                        search_code(x, &thresholds),
                        reference_code(x, bound, scale),
                        "bits={bits} bound={bound} k={k} x={x:?} ({:#010x})",
                        x.to_bits()
                    );
                }
            }
            // A uniform sweep away from the edges, for good measure.
            for i in 0..500 {
                let x = -1.0 + i as f32 * (2.0 / 499.0);
                assert_eq!(
                    search_code(x.clamp(-1.0, 1.0), &thresholds),
                    reference_code(x, bound, scale),
                    "bits={bits} bound={bound} sweep x={x}"
                );
            }
        }
    }
}

/// Hand-built adversarial vectors: signed zeros, subnormals, dominated
/// tails, all-equal values, single elements.
fn adversarial_vectors(rng: &mut Pcg64) -> Vec<Vec<f32>> {
    let mut base = gradient_like(rng, 512);
    base[0] = 0.0;
    base[1] = -0.0;
    base[2] = 1e-41; // subnormal
    base[3] = -1e-41;
    base[4] = f32::MIN_POSITIVE;
    base[5] = -f32::MIN_POSITIVE;
    base[6] = 40.0; // dominating coordinate (saturates the clip bound)
    base[7] = -40.0;
    vec![
        base,
        vec![0.25f32; 100],  // all-equal: degenerate angle spread
        vec![-1e-30f32; 17], // all-equal tiny
        vec![3.0f32],        // single element
        vec![0.0f32, -0.0, 5.0], // zeros beside a spike
        vec![0.0f32; 64],        // zero vector (norm-0 early path)
        gradient_like(rng, 10_000), // bulk realistic
    ]
}

#[test]
fn kernel_codes_bit_identical_to_reference_all_bit_widths() {
    let mut rng = Pcg64::seeded(2024);
    let vectors = adversarial_vectors(&mut rng);
    for bits in 1..=16u8 {
        // Wide tables are expensive to rebuild per (bound, vector) pair in
        // debug builds; one bound mode still exercises the whole path.
        let bounds: &[BoundMode] = if bits <= 10 {
            &[
                BoundMode::Auto,
                BoundMode::ClipTopPercent(1.0),
                BoundMode::FixedAngle(0.3),
            ]
        } else {
            &[BoundMode::ClipTopPercent(1.0)]
        };
        for &bound in bounds {
            let q = CosineQuantizer::new(bits, Rounding::Biased, bound);
            for (vi, g) in vectors.iter().enumerate() {
                let fast = q.quantize(g, &mut Pcg64::seeded(1));
                let refr = q.quantize_reference(g, &mut Pcg64::seeded(1));
                assert_eq!(
                    fast.codes, refr.codes,
                    "bits={bits} bound={bound:?} vector #{vi} (n={})",
                    g.len()
                );
                assert_eq!(fast.norm.to_bits(), refr.norm.to_bits());
                assert_eq!(fast.bound.to_bits(), refr.bound.to_bits());
                // And the LUT decode inverts to the same values as the
                // reference formula (it IS the formula, tabulated).
                assert_eq!(fast.dequantize(), refr.dequantize());
            }
        }
    }
}

/// Vector-level probing of the bin-edge neighborhood: elements built from
/// the threshold table (±1 ULP) so normalized ratios cluster tightly
/// around the code boundaries. (Exact-edge coverage is the scalar test
/// above — after normalization by the full vector's norm these land
/// *near*, which is the regime real gradients hit.)
#[test]
fn vector_with_planted_bin_edges_matches_reference() {
    for bits in [2u8, 4, 8] {
        let bound = 0.25f32;
        let mut thresholds = Vec::new();
        build_thresholds(bits, bound, &mut thresholds);
        let norm_target = 8.0f32;
        let mut g: Vec<f32> = thresholds
            .iter()
            .filter(|t| t.is_finite())
            .flat_map(|&t| {
                let v = t * norm_target;
                [v, ulp_step(v, true), ulp_step(v, false)]
            })
            .collect();
        g.push(1.0); // keep the vector non-degenerate
        let q = CosineQuantizer::new(bits, Rounding::Biased, BoundMode::FixedAngle(bound));
        let fast = q.quantize(&g, &mut Pcg64::seeded(3));
        let refr = q.quantize_reference(&g, &mut Pcg64::seeded(3));
        assert_eq!(fast.codes, refr.codes, "bits={bits}");
    }
}

/// Large tensor at a wide code width: clears the table-build break-even,
/// so the *table* path (not the small-n reference fallback) is what gets
/// compared against the reference.
#[test]
fn wide_table_path_forced_matches_reference() {
    let mut rng = Pcg64::seeded(5);
    let g = gradient_like(&mut rng, 40_000);
    let q = CosineQuantizer::new(12, Rounding::Biased, BoundMode::ClipTopPercent(1.0));
    let fast = q.quantize(&g, &mut Pcg64::seeded(1));
    let refr = q.quantize_reference(&g, &mut Pcg64::seeded(1));
    assert_eq!(fast.codes, refr.codes);
}

/// One scratch across changing bounds: the threshold cache must key the
/// table out, never serve a stale one.
#[test]
fn stale_threshold_cache_is_keyed_out() {
    let mut scratch = KernelScratch::new();
    let mut codes = Vec::new();
    let mut rng = Pcg64::seeded(6);
    let g = gradient_like(&mut rng, 5_000);
    for bound in [0.2f32, 0.9, 0.2] {
        let q = CosineQuantizer::new(4, Rounding::Biased, BoundMode::FixedAngle(bound));
        q.quantize_into(&g, &mut Pcg64::seeded(1), &mut scratch, &mut codes);
        let refr = q.quantize_reference(&g, &mut Pcg64::seeded(1));
        assert_eq!(codes, refr.codes, "bound={bound}");
    }
}

/// The fused dequantize+accumulate contract: for every bit width in
/// 1..=8, folding codes straight into an f64 accumulator must be
/// **bit-identical** to the decode-then-add reference path — across
/// weights, repeated accumulation (multiple clients into one
/// accumulator), small-tensor fallback and LUT regimes.
#[test]
fn fused_accumulate_bit_identical_to_decode_then_add() {
    let mut rng = Pcg64::seeded(404);
    for bits in 1..=8u8 {
        // Both the LUT path (n ≥ 2^bits) and the direct fallback (n < 2^bits).
        for n in [10_000usize, (1usize << bits).saturating_sub(1).max(1)] {
            let clients: Vec<Vec<f32>> = (0..4).map(|_| gradient_like(&mut rng, n)).collect();
            let weights = [3.0f64, 10.0, 0.5, 117.0];

            // --- cosine ---
            let q = CosineQuantizer::new(bits, Rounding::Biased, BoundMode::ClipTopPercent(1.0));
            let mut scratch = KernelScratch::new();
            let mut reference = vec![0.0f64; n];
            let mut fused = vec![0.0f64; n];
            for (g, &w) in clients.iter().zip(&weights) {
                let quant = q.quantize(g, &mut Pcg64::seeded(1));
                // Reference: materialize the decode, then fold.
                for (a, &d) in reference.iter_mut().zip(&quant.dequantize()) {
                    *a += d as f64 * w;
                }
                accumulate_cosine(
                    &quant.codes,
                    quant.norm,
                    quant.bound,
                    bits,
                    &mut scratch,
                    w,
                    &mut fused,
                );
            }
            for (i, (a, b)) in reference.iter().zip(&fused).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "cosine bits={bits} n={n} elem {i}: {a} vs {b}"
                );
            }

            // --- linear ---
            let lq = LinearQuantizer::biased(bits);
            let mut reference = vec![0.0f64; n];
            let mut fused = vec![0.0f64; n];
            for (g, &w) in clients.iter().zip(&weights) {
                let quant = Quantizer::quantize(&lq, g, &mut Pcg64::seeded(1));
                for (a, &d) in reference
                    .iter_mut()
                    .zip(&lq.dequantize(&quant.codes, quant.norm, quant.bound))
                {
                    *a += d as f64 * w;
                }
                accumulate_linear(&quant.codes, quant.bound, bits, &mut scratch, w, &mut fused);
            }
            for (a, b) in reference.iter().zip(&fused) {
                assert_eq!(a.to_bits(), b.to_bits(), "linear bits={bits} n={n}");
            }
        }
    }
}

/// Degenerate regimes fold exactly like the reference: a zero-norm
/// cosine tensor and a zero-bound linear tensor decode to exact zeros,
/// and the fused fold performs the same adds.
#[test]
fn fused_accumulate_degenerate_scales() {
    let mut scratch = KernelScratch::new();
    let codes = vec![1u16, 0, 3, 2];
    let mut acc = vec![1.5f64, -2.5, 0.0, -0.0];
    let before = acc.clone();
    accumulate_cosine(&codes, 0.0, 0.3, 2, &mut scratch, 7.0, &mut acc);
    let expect: Vec<f64> = before.iter().map(|a| a + 0.0f64 * 7.0).collect();
    assert_eq!(
        acc.iter().map(|a| a.to_bits()).collect::<Vec<_>>(),
        expect.iter().map(|a| a.to_bits()).collect::<Vec<_>>()
    );
    accumulate_linear(&codes, 0.0, 2, &mut scratch, 3.0, &mut acc);
    assert_eq!(
        acc.iter().map(|a| a.to_bits()).collect::<Vec<_>>(),
        expect.iter().map(|a| a.to_bits()).collect::<Vec<_>>()
    );
}

/// The pipeline-level fused dispatcher ([`accumulate_with`]) matches
/// decode-then-add for every frame shape: dense (fused fast path),
/// deflated, float32 passthrough, and the rotated/sparsified fallbacks.
#[test]
fn accumulate_with_matches_decode_for_every_frame_shape() {
    let mut rng = Pcg64::seeded(505);
    let g = gradient_like(&mut rng, 4096);
    let pipes = [
        Pipeline::cosine(4),                          // dense + deflate
        Pipeline::cosine(4).without_deflate(),        // dense, raw packed
        Pipeline::float32(),                          // passthrough bytes
        Pipeline::linear(2, Rounding::Biased),        // linear LUT
        Pipeline::sign_norm(),                        // sign family
        Pipeline::cosine(8).with_rotation(),          // fallback: rotated
        Pipeline::cosine(4).with_sparsify(0.25),      // fallback: masked
        Pipeline::ef_sign(),                          // sign + deflate
    ];
    for pipe in pipes {
        let enc = pipe.encode(
            &g,
            Direction::Uplink,
            &mut PipelineState::new(),
            &mut Pcg64::seeded(6),
        );
        let mut scratch = EncodeScratch::new();
        let w = 42.5f64;
        let decoded = decode_with(&enc, &mut scratch).unwrap();
        let mut reference = vec![0.125f64; g.len()];
        for (a, &d) in reference.iter_mut().zip(&decoded) {
            *a += d as f64 * w;
        }
        let mut fused = vec![0.125f64; g.len()];
        accumulate_with(&enc, w, &mut fused, &mut scratch).unwrap();
        for (i, (a, b)) in reference.iter().zip(&fused).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{} elem {i}", pipe.name());
        }
        // Length mismatch is an error, and must not touch the accumulator.
        let mut wrong = vec![0.0f64; g.len() + 1];
        assert!(accumulate_with(&enc, w, &mut wrong, &mut scratch).is_err());
        assert!(wrong.iter().all(|&a| a == 0.0));
    }
}

#[test]
fn property_random_vectors_and_widths() {
    forall(
        60,
        91,
        |rng, size| {
            let n = size.len(rng) * 16 + 1;
            let bits = 1 + rng.below(16) as u8;
            let clip = rng.bernoulli(0.5);
            (gradient_like(rng, n), bits, clip)
        },
        |(g, bits, clip)| {
            let bound = if *clip {
                BoundMode::ClipTopPercent(1.0)
            } else {
                BoundMode::Auto
            };
            let q = CosineQuantizer::new(*bits, Rounding::Biased, bound);
            let fast = q.quantize(g, &mut Pcg64::seeded(7));
            let refr = q.quantize_reference(g, &mut Pcg64::seeded(7));
            fast.codes == refr.codes
                && fast.norm.to_bits() == refr.norm.to_bits()
                && fast.bound.to_bits() == refr.bound.to_bits()
        },
    );
}

/// The sharded ingest plane's worker kernel: folding a dense frame as
/// several contiguous sub-ranges (any cut points, including ragged ones
/// that straddle packed-byte boundaries) must be bit-identical to one
/// full-frame `accumulate_with` — for every quantizer family the range
/// path serves, every width 1..=8, float32 passthrough and the
/// length-dependent signSGD+Norm magnitude.
#[test]
fn accumulate_range_splits_bit_identical_to_full_fold() {
    use cossgd::compress::accumulate_range_with;
    let mut rng = Pcg64::seeded(808);
    let n = 1_003; // deliberately not a multiple of any code-per-byte count
    let g = gradient_like(&mut rng, n);
    let mut pipes: Vec<Pipeline> = (1..=8u8)
        .map(|b| Pipeline::cosine(b).without_deflate())
        .collect();
    pipes.push(Pipeline::float32());
    pipes.push(Pipeline::sign_norm().without_deflate());
    pipes.push(Pipeline::linear(3, Rounding::Biased).without_deflate());
    for pipe in pipes {
        let enc = pipe.encode(
            &g,
            Direction::Uplink,
            &mut PipelineState::new(),
            &mut Pcg64::seeded(9),
        );
        let mut scratch = EncodeScratch::new();
        let w = -3.75f64;
        let mut full = vec![0.5f64; n];
        accumulate_with(&enc, w, &mut full, &mut scratch).unwrap();
        for cuts in [
            vec![0usize, n],
            vec![0, 1, 2, n - 1, n],
            vec![0, 17, 333, 600, n],
            vec![0, 251, 502, 753, n],
        ] {
            let mut split = vec![0.5f64; n];
            for pair in cuts.windows(2) {
                let (lo, hi) = (pair[0], pair[1]);
                accumulate_range_with(&enc, lo, w, &mut split[lo..hi], &mut scratch).unwrap();
            }
            for (i, (a, b)) in full.iter().zip(&split).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{} elem {i} cuts {cuts:?}",
                    pipe.name()
                );
            }
        }
        // Out-of-range sub-slices are an error, not a wrap-around.
        let mut acc = vec![0.0f64; 8];
        assert!(accumulate_range_with(&enc, n - 4, w, &mut acc, &mut scratch).is_err());
    }
}

/// Fused segmented ingest (the server's prepare→fold split): a
/// mixed-width multi-segment CSG2 stream ingested by the server must
/// land bit-identically to the decode-then-add reference — per segment,
/// decode the frame and add `decoded[j] * weight` at the segment offset
/// in f64, then apply the round exactly as `finish_round` does. Covers
/// deflated segments (inflated once at prepare) and the rotated /
/// sparsified stage-decode fallback.
#[test]
fn segmented_server_ingest_bit_identical_to_decode_then_add() {
    use cossgd::compress::wire;
    use cossgd::fl::{Frame, Server};
    let mut rng = Pcg64::seeded(909);
    let n = 1_200;
    let g = gradient_like(&mut rng, n);
    let bounds = [0usize, 150, 400, 700, 1_000, n];
    let seg_pipes = [
        Pipeline::cosine(1).without_deflate(),
        Pipeline::cosine(5), // deflated: inflated once on the coordinator
        Pipeline::sign_norm().without_deflate(),
        Pipeline::cosine(8).with_rotation(), // staged fallback
        Pipeline::cosine(4).with_sparsify(0.25), // staged fallback
    ];
    let segs: Vec<_> = bounds
        .windows(2)
        .zip(&seg_pipes)
        .map(|(pair, pipe)| {
            pipe.encode(
                &g[pair[0]..pair[1]],
                Direction::Uplink,
                &mut PipelineState::new(),
                &mut Pcg64::seeded(11),
            )
        })
        .collect();
    let payload = wire::serialize_stream(&segs);

    let init = vec![0.25f32; n];
    let weight = 100u32;
    let eta = 1.5f32;
    let mut server = Server::new(init.clone(), eta).with_clients(vec![weight; 4]);
    let verdict = server.ingest(&Frame {
        round: 0,
        client_id: 2,
        payload,
    });
    assert!(matches!(verdict, cossgd::fl::Ingest::Accepted { .. }));
    server.finish_round();

    // Reference: decode-then-add in f64, then the FedAvg apply formula.
    let mut acc = vec![0.0f64; n];
    for (pair, seg) in bounds.windows(2).zip(&segs) {
        let decoded = cossgd::compress::decode(seg).unwrap();
        for (a, &d) in acc[pair[0]..pair[1]].iter_mut().zip(&decoded) {
            *a += d as f64 * weight as f64;
        }
    }
    let scale = eta as f64 / weight as f64;
    for (i, (&p, (&m, &a))) in server.params.iter().zip(init.iter().zip(&acc)).enumerate() {
        let expect = m - (a * scale) as f32;
        assert_eq!(p.to_bits(), expect.to_bits(), "param {i}");
    }
}
