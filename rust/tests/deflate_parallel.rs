//! Parallel DEFLATE plane: the tests that make `--deflate-threads` safe
//! to flip in production.
//!
//! 1. **Byte identity**: `deflate_into` emits the *same bytes* at every
//!    thread count (chunk boundaries depend only on input length, one
//!    chunk = one block, bit-level stitching), so compressed artifacts
//!    are reproducible regardless of the machine that produced them.
//! 2. **Pipeline identity**: `Pipeline::encode_with` is bit-identical
//!    across thread counts for every stage combination (deflate on/off ×
//!    rotation × sparsification), and `encode_wire_with` streams exactly
//!    the bytes `wire::serialize(&encode_with(..))` would produce.
//! 3. **Decoder robustness**: truncations, corrupt block headers,
//!    mid-stream bit flips, and random garbage return clean
//!    [`InflateError`]s — never panics, never wrong-but-Ok silently
//!    accepted as the original payload.

use cossgd::compress::deflate::{deflate, deflate_into, inflate, CompressionLevel};
use cossgd::compress::{wire, Direction, EncodeScratch, Pipeline, PipelineState};
use cossgd::util::propcheck::{bytes, compressible_bytes, gradient_like};
use cossgd::util::rng::Pcg64;

/// 128 KiB — keep in sync with `compress::deflate::matcher::CHUNK_SIZE`.
/// The corruption tests poke bytes around these seams.
const CHUNK: usize = 128 * 1024;

const LEVELS: [CompressionLevel; 3] = [
    CompressionLevel::Fast,
    CompressionLevel::Default,
    CompressionLevel::Best,
];

#[test]
fn parallel_deflate_is_byte_identical_at_every_thread_count() {
    let mut rng = Pcg64::seeded(0xD3F1);
    // Multi-chunk compressible, multi-chunk incompressible (stored
    // blocks), sub-chunk, and empty inputs.
    let inputs: Vec<Vec<u8>> = vec![
        compressible_bytes(&mut rng, 3 * CHUNK + 4321),
        bytes(&mut rng, 2 * CHUNK + 999),
        compressible_bytes(&mut rng, 1000),
        Vec::new(),
    ];
    for data in &inputs {
        for level in LEVELS {
            let serial = deflate(data, level);
            assert_eq!(inflate(&serial).expect("serial roundtrip"), *data);
            for threads in [1usize, 2, 4, 8] {
                let mut out = Vec::new();
                let stats = deflate_into(data, level, threads, &mut out);
                assert_eq!(
                    out, serial,
                    "{} bytes at {level:?} ×{threads}: parallel != serial",
                    data.len()
                );
                assert_eq!(stats.bytes_in as usize, data.len());
                assert_eq!(stats.bytes_out as usize, out.len());
                assert_eq!(stats.chunks as usize, data.len().div_ceil(CHUNK).max(1));
                // Requested threads are clamped to the chunk count.
                assert!(stats.threads >= 1 && stats.threads <= threads.max(1));
                assert_eq!(stats.per_thread.len(), stats.threads);
            }
        }
    }
}

#[test]
fn deflate_into_appends_behind_existing_bytes() {
    // Streaming into a wire buffer means the stream starts mid-Vec; the
    // prefix must survive untouched and the suffix must still inflate.
    let mut rng = Pcg64::seeded(7);
    let data = compressible_bytes(&mut rng, CHUNK + 17);
    let mut out = b"HEADER".to_vec();
    let stats = deflate_into(&data, CompressionLevel::Default, 4, &mut out);
    assert_eq!(&out[..6], b"HEADER");
    assert_eq!(stats.bytes_out as usize, out.len() - 6);
    assert_eq!(inflate(&out[6..]).expect("suffix inflates"), data);
}

/// The stage combinations the protocol actually ships: plain cosine,
/// rotated, sparsified, and the deflate-off control.
fn pipelines(threads: usize, level: CompressionLevel) -> Vec<(&'static str, Pipeline)> {
    let tune = |p: Pipeline| p.with_deflate_level(level).with_deflate_threads(threads);
    vec![
        ("cosine4", tune(Pipeline::cosine(4))),
        ("cosine8+rot", tune(Pipeline::cosine(8).with_rotation())),
        ("cosine4+sparse", tune(Pipeline::cosine(4).with_sparsify(0.25))),
        ("cosine4-nodeflate", tune(Pipeline::cosine(4)).without_deflate()),
    ]
}

#[test]
fn pipeline_encode_is_bit_identical_across_threads() {
    let mut grng = Pcg64::seeded(42);
    // Big enough that the packed payload spans multiple DEFLATE chunks
    // for the 8-bit config (n bytes) — the seams must not leak into the
    // observable frame.
    let n = 3 * CHUNK / 2;
    let g = gradient_like(&mut grng, n);
    for level in [CompressionLevel::Fast, CompressionLevel::Default] {
        let baseline = pipelines(1, level)
            .into_iter()
            .map(|(name, p)| {
                let mut rng = Pcg64::seeded(9);
                let enc = p.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
                (name, enc)
            })
            .collect::<Vec<_>>();
        for threads in [4usize, 8] {
            for ((name, want), (_, p)) in baseline.iter().zip(pipelines(threads, level)) {
                let mut rng = Pcg64::seeded(9);
                let enc =
                    p.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
                assert_eq!(
                    &enc, want,
                    "{name} at {level:?} ×{threads} diverges from serial"
                );
            }
        }
    }
}

#[test]
fn encode_wire_with_streams_exactly_the_serialized_frame() {
    let mut grng = Pcg64::seeded(3);
    let g = gradient_like(&mut grng, CHUNK + 5000);
    for (name, p) in pipelines(4, CompressionLevel::Default) {
        let mut rng = Pcg64::seeded(11);
        let enc = p.encode(&g, Direction::Downlink, &mut PipelineState::new(), &mut rng);
        let want = wire::serialize(&enc);

        let mut rng = Pcg64::seeded(11);
        let mut scratch = EncodeScratch::new();
        let mut frame = Vec::new();
        let meta = p.encode_wire_with(
            &g,
            Direction::Downlink,
            &mut PipelineState::new(),
            &mut rng,
            &mut scratch,
            &mut frame,
        );
        assert_eq!(frame, want, "{name}: streamed frame != serialize(encode)");
        assert!(meta.payload.is_empty(), "{name}: streamed meta keeps payload");
        assert_eq!(meta.deflated, enc.deflated, "{name}: deflated flag");
        // The frame parses back to the same tensor the two-step path made.
        let parsed = wire::deserialize(&frame).expect("parse streamed frame");
        assert_eq!(parsed, enc, "{name}: parsed frame != encoded tensor");
        // Stats surface iff the deflate stage ran.
        assert_eq!(scratch.deflate_stats().is_some(), name != "cosine4-nodeflate");
    }
}

#[test]
fn truncated_streams_error_cleanly() {
    let mut rng = Pcg64::seeded(21);
    let data = compressible_bytes(&mut rng, 2 * CHUNK + 100);
    let full = deflate(&data, CompressionLevel::Default);
    assert_eq!(inflate(&full).expect("full stream"), data);
    let mut rejected = 0usize;
    let mut cut = 0usize;
    while cut < full.len() {
        // A proper prefix must never be silently accepted as the payload.
        match inflate(&full[..cut]) {
            Err(_) => rejected += 1,
            Ok(d) => assert_ne!(d, data, "truncation at {cut} decoded the full payload"),
        }
        cut += 97;
    }
    assert!(rejected > 0, "no truncation was ever rejected");
}

#[test]
fn corrupt_block_headers_and_bit_flips_never_panic() {
    let mut rng = Pcg64::seeded(33);
    let data = compressible_bytes(&mut rng, 2 * CHUNK + 777);
    let full = deflate(&data, CompressionLevel::Default);

    // BTYPE=11 is reserved: forcing it in the first block header must be
    // a clean error.
    let mut bad = full.clone();
    bad[0] |= 0b110;
    assert!(inflate(&bad).is_err(), "reserved BTYPE accepted");

    // Flip one byte at a stride across the stream — including around the
    // chunk seams — and demand a clean error or a decode that differs
    // (a flip confined to final-byte padding may legitimately round-trip,
    // so the last byte is exempt).
    let mut errors = 0usize;
    let mut pos = 0usize;
    while pos + 1 < full.len() {
        let mut bent = full.clone();
        bent[pos] ^= 0x5A;
        match inflate(&bent) {
            Err(_) => errors += 1,
            Ok(d) => assert_ne!(d, data, "flip at {pos} was invisible"),
        }
        pos += 211;
    }
    assert!(errors > 0, "no corruption was ever rejected");

    // Stored blocks (incompressible input) take the other decode path:
    // same contract.
    let raw = bytes(&mut rng, CHUNK / 2);
    let stored = deflate(&raw, CompressionLevel::Default);
    assert_eq!(inflate(&stored).expect("stored roundtrip"), raw);
    for cut in [0, 1, 4, stored.len() / 2, stored.len() - 1] {
        match inflate(&stored[..cut]) {
            Err(_) => {}
            Ok(d) => assert_ne!(d, raw, "stored truncation at {cut} round-tripped"),
        }
    }

    // Random garbage: never a panic, (almost) never an accept — and an
    // accept of garbage can at most produce garbage, which we ignore.
    for seed in 0..64u64 {
        let mut frng = Pcg64::seeded(0xFACE + seed);
        let junk = bytes(&mut frng, 257);
        let _ = inflate(&junk);
    }
}
