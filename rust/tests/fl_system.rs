//! System-level tests of the FL stack that do NOT need artifacts: server
//! aggregation semantics over the full wire path, pipeline composition
//! under federation-shaped traffic, determinism of the whole selection +
//! encode pipeline, and round-trip (downlink delta) cost accounting.

use cossgd::compress::cosine::{BoundMode, Rounding};
use cossgd::compress::{wire, Direction, Pipeline, PipelineState};
use cossgd::fl::server::Server;
use cossgd::fl::{Downlink, Frame, Ingest, Loopback, ModelReplica, NetworkLedger, Transport};
use cossgd::util::propcheck::gradient_like;
use cossgd::util::rng::Pcg64;
use cossgd::util::stats::l2_norm;

fn encode_up(pipe: &Pipeline, g: &[f32], rng: &mut Pcg64) -> cossgd::compress::EncodedTensor {
    pipe.encode(g, Direction::Uplink, &mut PipelineState::new(), rng)
}

/// FedAvg over compressed updates approximates FedAvg over exact updates.
#[test]
fn compressed_aggregation_approximates_exact() {
    let n = 4096;
    let mut rng = Pcg64::seeded(1);
    let deltas: Vec<Vec<f32>> = (0..8).map(|_| gradient_like(&mut rng, n)).collect();
    let weights: Vec<u32> = (0..8).map(|i| 100 + i * 50).collect();

    // Exact weighted mean.
    let wsum: f64 = weights.iter().map(|&w| w as f64).sum();
    let mut exact = vec![0.0f64; n];
    for (d, &w) in deltas.iter().zip(&weights) {
        for (e, &x) in exact.iter_mut().zip(d) {
            *e += x as f64 * w as f64 / wsum;
        }
    }

    // Auto bound (no tail saturation) so the error envelope is the
    // analytic q/2-per-element one; paper-default clipping deliberately
    // sacrifices the top tail (tested separately in pipeline tests).
    let cosine_auto = |bits| Pipeline::cosine_with(bits, Rounding::Biased, BoundMode::Auto);
    // L2 tolerance scales with the interval width q: per-element error is
    // ≤ q/2·‖g‖, so the aggregate rel err is ~sqrt(n/3)·q/2/√clients —
    // large at 4 bits; the direction (cosine similarity, what SGD needs)
    // is asserted separately below.
    for (pipe, tol) in [
        (Pipeline::float32(), 1e-6),
        (cosine_auto(8), 0.35),
        (cosine_auto(4), 1.6),
    ] {
        let mut server = Server::new(vec![0.0f32; n], 1.0);
        for (d, &w) in deltas.iter().zip(&weights) {
            let enc = encode_up(&pipe, d, &mut rng);
            server.receive_update(&wire::serialize(&enc), w).unwrap();
        }
        server.finish_round();
        // params = -eta * mean  =>  compare -params to exact mean.
        let got: Vec<f64> = server.params.iter().map(|&p| -p as f64).collect();
        let err: f64 = got
            .iter()
            .zip(&exact)
            .map(|(a, b)| (a - b).powi(2))
            .sum::<f64>()
            .sqrt();
        let scale = exact.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(
            err / scale < tol,
            "{}: rel err {} > {tol}",
            pipe.name(),
            err / scale
        );
        // Direction of the aggregated update is preserved.
        let dot: f64 = got.iter().zip(&exact).map(|(a, b)| a * b).sum();
        let got_norm = got.iter().map(|x| x * x).sum::<f64>().sqrt();
        let sim = dot / (got_norm * scale).max(1e-12);
        assert!(sim > 0.6, "{}: aggregate cos-sim {sim}", pipe.name());
    }
}

/// Sparsified updates from many clients cover the full parameter space.
#[test]
fn sparsified_federation_covers_parameters() {
    let n = 2000;
    let mut rng = Pcg64::seeded(2);
    let pipe = Pipeline::cosine(4).with_sparsify(0.25);
    let mut server = Server::new(vec![0.0f32; n], 1.0);
    for _ in 0..20 {
        let d = gradient_like(&mut rng, n);
        let enc = encode_up(&pipe, &d, &mut rng);
        server.receive_update(&wire::serialize(&enc), 1).unwrap();
    }
    server.finish_round();
    let touched = server.params.iter().filter(|&&p| p != 0.0).count();
    // P(untouched) = 0.75^20 ≈ 0.3%; expect nearly all parameters updated.
    assert!(touched > n * 95 / 100, "only {touched}/{n} touched");
}

/// The whole encode path is deterministic given the same seed.
#[test]
fn encode_pipeline_deterministic() {
    let g = {
        let mut rng = Pcg64::seeded(3);
        gradient_like(&mut rng, 10_000)
    };
    for pipe in [
        Pipeline::cosine_with(2, Rounding::Unbiased, BoundMode::ClipTopPercent(1.0)),
        Pipeline::linear_rotated(4, Rounding::Unbiased),
        Pipeline::ef_sign(),
    ] {
        let pipe = pipe.with_sparsify(0.5);
        let enc1 = pipe.encode(
            &g,
            Direction::Uplink,
            &mut PipelineState::new(),
            &mut Pcg64::new(7, 9),
        );
        let enc2 = pipe.encode(
            &g,
            Direction::Uplink,
            &mut PipelineState::new(),
            &mut Pcg64::new(7, 9),
        );
        assert_eq!(enc1, enc2, "{}", pipe.name());
        let enc3 = pipe.encode(
            &g,
            Direction::Uplink,
            &mut PipelineState::new(),
            &mut Pcg64::new(8, 9),
        );
        assert_ne!(
            wire::serialize(&enc1),
            wire::serialize(&enc3),
            "different seeds must differ for {}",
            pipe.name()
        );
    }
}

/// Byte accounting: ledger totals equal the sum of serialized updates, and
/// 2-bit + 5% mask + deflate lands in the paper's 400-1200x band.
#[test]
fn cost_accounting_matches_paper_band() {
    let n = 122_570; // the CIFAR model
    let mut rng = Pcg64::seeded(4);
    let pipe = Pipeline::cosine(2).with_sparsify(0.05);
    let mut ledger = NetworkLedger::new();
    let mut manual_total = 0usize;
    for _ in 0..10 {
        let d = gradient_like(&mut rng, n);
        let enc = encode_up(&pipe, &d, &mut rng);
        let bytes = wire::serialize(&enc);
        manual_total += bytes.len();
        ledger.record_uplink(bytes.len());
    }
    assert_eq!(ledger.uplink_bytes as usize, manual_total);
    let ratio = ledger.uplink_compression_vs_float32(n).unwrap();
    assert!(
        (300.0..2000.0).contains(&ratio),
        "2-bit@5% ratio {ratio} outside the paper's band"
    );
}

/// EF-signSGD residual persists across federation rounds per client.
#[test]
fn ef_state_persists_across_rounds() {
    let n = 256;
    let pipe = Pipeline::ef_sign();
    let mut state = PipelineState::new();
    let mut rng = Pcg64::seeded(5);
    // Non-constant gradient: sign compression leaves a nonzero residual.
    let g: Vec<f32> = (0..n).map(|i| 0.1 + 0.9 * ((i % 7) as f32 / 7.0)).collect();
    let e1 = pipe.encode(&g, Direction::Uplink, &mut state, &mut rng);
    // After the first round the residual is nonzero; a second identical
    // gradient encodes differently than from a fresh client.
    let e2_continuing = pipe.encode(&g, Direction::Uplink, &mut state, &mut rng);
    let e2_fresh = pipe.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
    assert_eq!(e1.payload, e2_fresh.payload);
    // With a constant positive gradient, sign codes agree but the scale
    // (bound field) reflects accumulated residual.
    assert!((e2_continuing.bound - e2_fresh.bound).abs() > 1e-6);
}

/// Concurrent per-client encodes (each with its own RNG lane, EF state
/// and scratch — the runner's fan-out shape) are bit-identical to the
/// serial loop, independent of how clients land on threads.
#[test]
fn threaded_client_encodes_bit_identical_to_serial() {
    let n_clients = 13;
    let n = 5000;
    let pipe = Pipeline::cosine(4).with_error_feedback();
    let gradients: Vec<Vec<f32>> = (0..n_clients)
        .map(|c| gradient_like(&mut Pcg64::new(99, c as u64), n))
        .collect();
    let encode_client = |c: usize| {
        // Two rounds so the EF residual carries across encodes.
        let mut rng = Pcg64::new(7, c as u64);
        let mut st = PipelineState::new();
        let mut scratch = cossgd::compress::EncodeScratch::new();
        let g = &gradients[c];
        let e1 = pipe.encode_with(g, Direction::Uplink, &mut st, &mut rng, &mut scratch);
        let e2 = pipe.encode_with(g, Direction::Uplink, &mut st, &mut rng, &mut scratch);
        (wire::serialize(&e1), wire::serialize(&e2))
    };

    let serial: Vec<_> = (0..n_clients).map(encode_client).collect();
    for threads in [2usize, 4, 7] {
        let mut parallel: Vec<Option<(Vec<u8>, Vec<u8>)>> = vec![None; n_clients];
        let chunks: Vec<Vec<usize>> = (0..threads)
            .map(|t| (0..n_clients).filter(|c| c % threads == t).collect())
            .collect();
        let ec = &encode_client;
        std::thread::scope(|s| {
            let handles: Vec<_> = chunks
                .iter()
                .map(|chunk| {
                    s.spawn(move || chunk.iter().map(|&c| (c, ec(c))).collect::<Vec<_>>())
                })
                .collect();
            for h in handles {
                for (c, frames) in h.join().unwrap() {
                    parallel[c] = Some(frames);
                }
            }
        });
        for (c, (got, want)) in parallel.into_iter().zip(&serial).enumerate() {
            assert_eq!(got.as_ref(), Some(want), "client {c} at {threads} threads");
        }
    }
}

/// The frame-driven path end to end at the protocol level: loopback
/// transport + ingest state machine aggregates bit-identically to the
/// trusted direct receive path, and the transport's ledger matches the
/// frames it carried.
#[test]
fn frame_driven_rounds_match_direct_aggregation_bit_exactly() {
    let n = 3000;
    let n_clients = 8;
    let rounds = 3;
    let pipe = Pipeline::cosine(4);
    let weights: Vec<u32> = (0..n_clients as u32).map(|c| 50 + c * 10).collect();
    let mut rng = Pcg64::seeded(31);

    let mut framed = Server::new(vec![0.0; n], 1.0).with_clients(weights.clone());
    let mut direct = Server::new(vec![0.0; n], 1.0);
    let mut transport = Loopback::new();
    for t in 0..rounds {
        let candidates: Vec<usize> = (0..n_clients).collect();
        let plan = transport.plan_round(&candidates);
        transport.broadcast(n * 4, plan.active.len());
        let frames: Vec<Frame> = plan
            .active
            .iter()
            .map(|&c| {
                let g = gradient_like(&mut rng, n);
                Frame {
                    round: framed.round(),
                    client_id: c,
                    payload: wire::serialize(&encode_up(&pipe, &g, &mut Pcg64::new(t as u64, c as u64))),
                }
            })
            .collect();
        for f in &transport.exchange(t + 1, n_clients, n * 4, frames, 100) {
            assert_eq!(framed.ingest(f), Ingest::Accepted { staleness: 0 });
            direct.receive_update(&f.payload, weights[f.client_id]).unwrap();
        }
        assert_eq!(framed.finish_round(), n_clients);
        direct.finish_round();
        // Bit-identical every round, not just at the end.
        assert_eq!(framed.params, direct.params, "round {t}");
    }
    // The ledger metered exactly the frames that crossed the loopback.
    let ledger = transport.ledger();
    assert_eq!(ledger.uplink_messages, (rounds * n_clients) as u64);
    assert_eq!(ledger.downlink_messages, (rounds * n_clients) as u64);
    assert!(ledger.uplink_bytes > 0);
}

/// Norm is preserved through wire f32 round-trips (header floats).
#[test]
fn wire_floats_exact() {
    let mut rng = Pcg64::seeded(6);
    let g = gradient_like(&mut rng, 333);
    let pipe = Pipeline::cosine(8);
    let enc = encode_up(&pipe, &g, &mut rng);
    let rt = wire::deserialize(&wire::serialize(&enc)).unwrap();
    assert_eq!(rt.norm.to_bits(), enc.norm.to_bits());
    assert_eq!(rt.bound.to_bits(), enc.bound.to_bits());
    let norm_check = l2_norm(&g) as f32;
    assert_eq!(enc.norm.to_bits(), norm_check.to_bits());
}

/// Legacy downlink mode meters exactly the CSG1-era float32 broadcast:
/// 4·n bytes per selected client, no framing.
#[test]
fn legacy_downlink_byte_accounting() {
    let n = 1234;
    let mut server = Server::new(vec![0.1; n], 1.0);
    let mut ledger = NetworkLedger::new();
    for _ in 0..3 {
        let b = server.broadcast().unwrap();
        assert!(b.wire.is_none());
        for _ in 0..5 {
            ledger.record_downlink(b.bytes);
        }
        server.finish_round();
    }
    assert_eq!(ledger.downlink_bytes, (3 * 5 * n * 4) as u64);
    let ratio = ledger.downlink_compression_vs_float32(n).unwrap();
    assert!((ratio - 1.0).abs() < 1e-12, "legacy ratio {ratio} != 1.0");
}

/// The acceptance scenario, artifact-free: cosine-4 uplink + cosine-8
/// downlink drive a multi-round federation through the real wire path;
/// downlink bytes land strictly below the float32 broadcast baseline and
/// the fleet replica tracks the server.
#[test]
fn round_trip_federation_compresses_both_directions() {
    let n = 20_000;
    let rounds = 4;
    let clients = 5;
    let uplink = Pipeline::cosine(4);
    let mut rng = Pcg64::seeded(7);
    let init = gradient_like(&mut rng, n);
    let mut server = Server::new(init.clone(), 1.0)
        .with_downlink(Downlink::Delta(Pipeline::cosine(8)), 7);
    let mut fleet = ModelReplica::new(init);
    let mut ledger = NetworkLedger::new();

    for _ in 0..rounds {
        let b = server.broadcast().unwrap();
        fleet.apply_wire(b.wire.as_ref().unwrap()).unwrap();
        assert_eq!(
            fleet.params.as_slice(),
            server.replica(),
            "fleet and server replica diverged"
        );
        for c in 0..clients {
            ledger.record_downlink(b.bytes);
            // Synthetic local training: a gradient-like step from the
            // broadcast model (what a real client would compute).
            let g = gradient_like(&mut Pcg64::new(rng.next_u64(), c as u64), n);
            let enc = uplink.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
            let bytes = wire::serialize(&enc);
            ledger.record_uplink(bytes.len());
            server.receive_update(&bytes, 10).unwrap();
        }
        server.finish_round();
    }

    // Downlink strictly below the float32 broadcast baseline.
    let float32_baseline = (ledger.downlink_messages as usize * n * 4) as u64;
    assert!(
        ledger.downlink_bytes < float32_baseline,
        "downlink {} !< float32 baseline {float32_baseline}",
        ledger.downlink_bytes
    );
    let down_ratio = ledger.downlink_compression_vs_float32(n).unwrap();
    assert!(down_ratio > 1.0, "downlink ratio {down_ratio}");
    let up_ratio = ledger.uplink_compression_vs_float32(n).unwrap();
    assert!(up_ratio > 4.0, "uplink ratio {up_ratio}");

    // The fleet model tracks the server: syncing the last aggregated
    // update shrinks the gap, and what remains is only the (bounded)
    // quantization error of the final delta.
    let gap = |a: &[f32], b: &[f32]| -> f64 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    };
    let err_before = gap(&server.params, &fleet.params);
    let b = server.broadcast().unwrap();
    fleet.apply_wire(b.wire.as_ref().unwrap()).unwrap();
    let err_after = gap(&server.params, &fleet.params);
    assert!(
        err_after < err_before,
        "sync did not shrink the gap: {err_after} !< {err_before}"
    );
    assert!(
        err_after / l2_norm(&server.params).max(1e-9) < 0.6,
        "replica error {err_after} out of the quantization envelope"
    );
}
