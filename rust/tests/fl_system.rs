//! System-level tests of the FL stack that do NOT need artifacts: server
//! aggregation semantics over the full wire path, codec composition under
//! federation-shaped traffic, and determinism of the whole selection +
//! encode pipeline.

use cossgd::compress::codec::ClientCodecState;
use cossgd::compress::{wire, Codec, CodecKind};
use cossgd::fl::server::Server;
use cossgd::fl::NetworkLedger;
use cossgd::util::propcheck::gradient_like;
use cossgd::util::rng::Pcg64;
use cossgd::util::stats::l2_norm;

/// FedAvg over compressed updates approximates FedAvg over exact updates.
#[test]
fn compressed_aggregation_approximates_exact() {
    let n = 4096;
    let mut rng = Pcg64::seeded(1);
    let deltas: Vec<Vec<f32>> = (0..8).map(|_| gradient_like(&mut rng, n)).collect();
    let weights: Vec<u32> = (0..8).map(|i| 100 + i * 50).collect();

    // Exact weighted mean.
    let wsum: f64 = weights.iter().map(|&w| w as f64).sum();
    let mut exact = vec![0.0f64; n];
    for (d, &w) in deltas.iter().zip(&weights) {
        for (e, &x) in exact.iter_mut().zip(d) {
            *e += x as f64 * w as f64 / wsum;
        }
    }

    // Auto bound (no tail saturation) so the error envelope is the
    // analytic q/2-per-element one; paper-default clipping deliberately
    // sacrifices the top tail (tested separately in codec tests).
    let cosine_auto = |bits| {
        Codec::new(CodecKind::Cosine {
            bits,
            rounding: cossgd::compress::cosine::Rounding::Biased,
            bound: cossgd::compress::cosine::BoundMode::Auto,
        })
    };
    // L2 tolerance scales with the interval width q: per-element error is
    // ≤ q/2·‖g‖, so the aggregate rel err is ~sqrt(n/3)·q/2/√clients —
    // large at 4 bits; the direction (cosine similarity, what SGD needs)
    // is asserted separately below.
    for (codec, tol) in [
        (Codec::float32(), 1e-6),
        (cosine_auto(8), 0.35),
        (cosine_auto(4), 1.6),
    ] {
        let mut server = Server::new(vec![0.0f32; n], 1.0, codec);
        for (d, &w) in deltas.iter().zip(&weights) {
            let enc = codec.encode(d, &mut ClientCodecState::new(), &mut rng);
            server.receive_update(&wire::serialize(&enc), w).unwrap();
        }
        server.finish_round();
        // params = -eta * mean  =>  compare -params to exact mean.
        let got: Vec<f64> = server.params.iter().map(|&p| -p as f64).collect();
        let err: f64 = got
            .iter()
            .zip(&exact)
            .map(|(a, b)| (a - b).powi(2))
            .sum::<f64>()
            .sqrt();
        let scale = exact.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(
            err / scale < tol,
            "{}: rel err {} > {tol}",
            codec.name(),
            err / scale
        );
        // Direction of the aggregated update is preserved.
        let dot: f64 = got.iter().zip(&exact).map(|(a, b)| a * b).sum();
        let got_norm = got.iter().map(|x| x * x).sum::<f64>().sqrt();
        let sim = dot / (got_norm * scale).max(1e-12);
        assert!(sim > 0.6, "{}: aggregate cos-sim {sim}", codec.name());
    }
}

/// Sparsified updates from many clients cover the full parameter space.
#[test]
fn sparsified_federation_covers_parameters() {
    let n = 2000;
    let mut rng = Pcg64::seeded(2);
    let codec = Codec::cosine(4).with_sparsify(0.25);
    let mut server = Server::new(vec![0.0f32; n], 1.0, codec);
    for _ in 0..20 {
        let d = gradient_like(&mut rng, n);
        let enc = codec.encode(&d, &mut ClientCodecState::new(), &mut rng);
        server.receive_update(&wire::serialize(&enc), 1).unwrap();
    }
    server.finish_round();
    let touched = server.params.iter().filter(|&&p| p != 0.0).count();
    // P(untouched) = 0.75^20 ≈ 0.3%; expect nearly all parameters updated.
    assert!(touched > n * 95 / 100, "only {touched}/{n} touched");
}

/// The whole encode path is deterministic given the same seed.
#[test]
fn encode_pipeline_deterministic() {
    let g = {
        let mut rng = Pcg64::seeded(3);
        gradient_like(&mut rng, 10_000)
    };
    for kind in [
        CodecKind::Cosine {
            bits: 2,
            rounding: cossgd::compress::cosine::Rounding::Unbiased,
            bound: cossgd::compress::cosine::BoundMode::ClipTopPercent(1.0),
        },
        CodecKind::LinearRotated {
            bits: 4,
            rounding: cossgd::compress::cosine::Rounding::Unbiased,
        },
        CodecKind::EfSignSgd,
    ] {
        let codec = Codec::new(kind).with_sparsify(0.5);
        let enc1 = codec.encode(&g, &mut ClientCodecState::new(), &mut Pcg64::new(7, 9));
        let enc2 = codec.encode(&g, &mut ClientCodecState::new(), &mut Pcg64::new(7, 9));
        assert_eq!(enc1, enc2, "{:?}", kind);
        let enc3 = codec.encode(&g, &mut ClientCodecState::new(), &mut Pcg64::new(8, 9));
        assert_ne!(
            wire::serialize(&enc1),
            wire::serialize(&enc3),
            "different seeds must differ for {kind:?}"
        );
    }
}

/// Byte accounting: ledger totals equal the sum of serialized updates, and
/// 2-bit + 5% mask + deflate lands in the paper's 400-1200x band.
#[test]
fn cost_accounting_matches_paper_band() {
    let n = 122_570; // the CIFAR model
    let mut rng = Pcg64::seeded(4);
    let codec = Codec::cosine(2).with_sparsify(0.05);
    let mut ledger = NetworkLedger::new();
    let mut manual_total = 0usize;
    for _ in 0..10 {
        let d = gradient_like(&mut rng, n);
        let enc = codec.encode(&d, &mut ClientCodecState::new(), &mut rng);
        let bytes = wire::serialize(&enc);
        manual_total += bytes.len();
        ledger.record_uplink(bytes.len());
    }
    assert_eq!(ledger.uplink_bytes as usize, manual_total);
    let ratio = ledger.uplink_compression_vs_float32(n);
    assert!(
        (300.0..2000.0).contains(&ratio),
        "2-bit@5% ratio {ratio} outside the paper's band"
    );
}

/// EF-signSGD residual persists across federation rounds per client.
#[test]
fn ef_state_persists_across_rounds() {
    let n = 256;
    let codec = Codec::new(CodecKind::EfSignSgd);
    let mut state = ClientCodecState::new();
    let mut rng = Pcg64::seeded(5);
    // Non-constant gradient: sign compression leaves a nonzero residual.
    let g: Vec<f32> = (0..n).map(|i| 0.1 + 0.9 * ((i % 7) as f32 / 7.0)).collect();
    let e1 = codec.encode(&g, &mut state, &mut rng);
    // After the first round the residual is nonzero; a second identical
    // gradient encodes differently than from a fresh client.
    let e2_continuing = codec.encode(&g, &mut state, &mut rng);
    let e2_fresh = codec.encode(&g, &mut ClientCodecState::new(), &mut rng);
    assert_eq!(e1.payload, e2_fresh.payload);
    // With a constant positive gradient, sign codes agree but the scale
    // (bound field) reflects accumulated residual.
    assert!((e2_continuing.bound - e2_fresh.bound).abs() > 1e-6);
}

/// Norm is preserved through wire f32 round-trips (header floats).
#[test]
fn wire_floats_exact() {
    let mut rng = Pcg64::seeded(6);
    let g = gradient_like(&mut rng, 333);
    let codec = Codec::cosine(8);
    let enc = codec.encode(&g, &mut ClientCodecState::new(), &mut rng);
    let rt = wire::deserialize(&wire::serialize(&enc)).unwrap();
    assert_eq!(rt.norm.to_bits(), enc.norm.to_bits());
    assert_eq!(rt.bound.to_bits(), enc.bound.to_bits());
    let norm_check = l2_norm(&g) as f32;
    assert_eq!(enc.norm.to_bits(), norm_check.to_bits());
}
