//! Perf guard for the kernel fast path, `#[ignore]`d by default — timing
//! assertions are meaningless in debug builds and flaky on loaded CI
//! boxes. Run deliberately with:
//!
//! ```text
//! cargo test --release --test bench_guard -- --ignored
//! ```
//!
//! It runs the shared compress perf suite (quick sampling), records the
//! trajectory to `BENCH_compress.json`, and asserts the acceptance
//! criterion: the transcendental-free 4-bit biased cosine quantize+pack
//! is at least 5× fewer ns/elem than the reference `acos` path at n≈1M.

use cossgd::compress::perf;
use cossgd::util::bench::{write_trajectory, Bencher};

#[test]
#[ignore = "perf guard: run with --release -- --ignored"]
fn kernel_quantize_pack_is_5x_faster_than_reference() {
    let mut b = Bencher::quick();
    perf::run_suite(&mut b, 1 << 20, 1);
    let path = std::path::Path::new("BENCH_compress.json");
    write_trajectory(path, perf::SUITE, b.results()).expect("record trajectory");
    let speedup = perf::headline_speedup(b.results()).expect("headline cases ran");
    println!("4-bit biased quantize+pack: kernel {speedup:.1}x faster than reference");
    assert!(
        speedup >= 5.0,
        "kernel quantize+pack speedup {speedup:.2}x < 5x \
         (see {path:?} for the full trajectory)"
    );
}
