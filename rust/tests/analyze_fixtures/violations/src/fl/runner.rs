//! Fixture: wall-clock read in the round loop, plus scoped-thread spawn
//! closures that alias shared `&mut` state — thread_aliasing must fire
//! on the non-`move` closure AND on both unblessed `&mut` captures.
pub fn round_loop() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos()
}

pub fn fan_out(shared: &mut [f64], flags: &mut [u32]) {
    std::thread::scope(|s| {
        s.spawn(|| {
            bump(&mut flags);
        });
        s.spawn(move || {
            scale(&mut shared);
        });
    });
}

fn bump(flags: &mut [u32]) {
    if let Some(f) = flags.first_mut() {
        *f += 1;
    }
}

fn scale(shared: &mut [f64]) {
    for v in shared.iter_mut() {
        *v *= 2.0;
    }
}
