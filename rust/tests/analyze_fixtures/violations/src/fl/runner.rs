//! Fixture: wall-clock read in the round loop.
pub fn round_loop() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos()
}
