//! Fixture: per-frame allocations and unordered state in the worker fold
//! loop — both the hotpath and determinism scopes must fire here.

use std::collections::HashMap;

pub fn fold_frames(frames: &[Vec<f32>], acc: &mut [f64]) {
    let mut seen: HashMap<usize, usize> = HashMap::new();
    for (i, frame) in frames.iter().enumerate() {
        seen.insert(i, frame.len());
        let staged = frame.clone();
        let copy = staged.to_vec();
        for (a, v) in acc.iter_mut().zip(copy.iter()) {
            *a += f64::from(*v);
        }
    }
}

/// The loop looks allocation-free, but `stage_frame` (in
/// `compress/decode.rs`) `.to_vec()`s per frame — only the call-graph
/// walk of hotloop_alloc can see through it.
pub fn fold_indirect(frames: &[Vec<f32>], acc: &mut [f64]) {
    for frame in frames {
        stage_frame(frame, acc);
    }
}
