//! Fixture: panic-safety and determinism violations in an ingest path,
//! plus a call into `compress/decode.rs` whose sins only the
//! interprocedural panic_propagation walk can reach.
use std::collections::HashMap;

pub fn ingest(payload: &[u8]) -> u32 {
    let mut seen: HashMap<u32, u32> = HashMap::new();
    let head = payload[0];
    let tail = payload.get(1..).unwrap();
    let text = std::str::from_utf8(tail).expect("utf8");
    if text.is_empty() {
        panic!("empty frame");
    }
    let word = decode_codes(tail);
    seen.insert(head as u32, word as u32);
    head as u32
}
