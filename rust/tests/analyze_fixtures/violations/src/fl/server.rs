//! Fixture: panic-safety and determinism violations in an ingest path.
use std::collections::HashMap;

pub fn ingest(payload: &[u8]) -> u32 {
    let mut seen: HashMap<u32, u32> = HashMap::new();
    let head = payload[0];
    let tail = payload.get(1..).unwrap();
    let text = std::str::from_utf8(tail).expect("utf8");
    if text.is_empty() {
        panic!("empty frame");
    }
    seen.insert(head as u32, 1);
    head as u32
}
