//! Fixture: transcendentals and allocations back in the hot kernel,
//! with no waiver annotations.
pub fn quantize(xs: &[f32], out: &mut Vec<u16>) {
    for &x in xs {
        out.push(x.acos() as u16);
    }
}

pub fn dequantize(codes: &[u16], step: f32) -> Vec<f32> {
    let copy = codes.to_vec();
    let scaled: Vec<f32> = copy.iter().map(|&c| (c as f32 * step).cos()).collect();
    scaled.clone()
}
