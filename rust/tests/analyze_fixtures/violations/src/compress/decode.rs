//! Fixture: decode-path helpers in a file NO lexical rule scopes. The
//! `.unwrap()` in `word_load` and the `.to_vec()` in `stage_frame` are
//! only reachable through the call graph — from `fl/server.rs::ingest`
//! (panic_propagation) and from the fold loop in `fl/ingest.rs`
//! (hotloop_alloc) respectively. Both interprocedural rules must fire
//! with a rendered chain; neither per-file rule may.

pub fn decode_codes(bytes: &[u8]) -> u64 {
    word_load(bytes)
}

fn word_load(bytes: &[u8]) -> u64 {
    u64::from_le_bytes(bytes[..8].try_into().unwrap())
}

pub fn stage_frame(frame: &[f32], acc: &mut [f64]) {
    let staged = frame.to_vec();
    for (a, v) in acc.iter_mut().zip(staged.iter()) {
        *a += f64::from(*v);
    }
}
