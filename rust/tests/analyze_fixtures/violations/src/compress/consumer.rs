//! Fixture: a consumer that re-declares wire constants instead of
//! importing them.
pub const HEADER_BYTES: usize = 48;

pub fn wire_cost(n: usize) -> usize {
    44 + n
}

pub fn magic() -> &'static [u8] {
    b"CSG2"
}
