//! Fixture: a block writer that stamps frames with the wall clock and
//! clones its token stream per block — determinism and hotpath must fire.

use std::time::SystemTime;

pub fn emit_block(tokens: &[(u8, u32)], out: &mut Vec<u8>) -> u64 {
    let owned = tokens.to_vec();
    for (lit, dist) in owned.clone() {
        out.push(lit);
        out.extend_from_slice(&dist.to_le_bytes());
    }
    SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}
