//! Fixture: a chunked match-finder that keeps its hash chains in an
//! unordered map, reads the wall clock for per-chunk timing, and
//! allocates per chunk — the determinism and hotpath scopes must both
//! fire on the parallel-DEFLATE plane.

use std::collections::HashMap;
use std::time::Instant;

pub fn tokenize_chunk(data: &[u8], out: &mut Vec<(u8, u32)>) -> u128 {
    let t0 = Instant::now();
    let mut chains: HashMap<u32, usize> = HashMap::new();
    for (i, w) in data.windows(3).enumerate() {
        let key = u32::from(w[0]) << 16 | u32::from(w[1]) << 8 | u32::from(w[2]);
        chains.insert(key, i);
    }
    let staged = data.to_vec();
    let scratch = vec![0u32; staged.len()];
    for (b, s) in staged.iter().zip(scratch.iter()) {
        out.push((*b, *s));
    }
    t0.elapsed().as_nanos()
}
