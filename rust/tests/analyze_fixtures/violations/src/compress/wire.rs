//! Fixture: the wire header spec drifted — the doc table no longer sums
//! to HEADER_BYTES. Layout:
//!
//! ```text
//! offset size field
//! 0      4    magic
//! 4      4    n
//! 8      ..   payload
//! ```
pub const HEADER_BYTES: usize = 44;

pub fn frame_len(payload: usize) -> usize {
    HEADER_BYTES + payload
}
