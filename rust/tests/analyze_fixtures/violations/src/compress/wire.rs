//! Fixture: the wire header spec drifted — the doc table no longer sums
//! to HEADER_BYTES. Layout:
//!
//! ```text
//! offset size field
//! 0      4    magic
//! 4      4    n
//! 8      ..   payload
//! ```
pub const HEADER_BYTES: usize = 44;

// FLAG_ROTATED is neither OR-ed into KNOWN_FLAGS nor consumed on the
// decode path — the flag-exhaustiveness check must fire twice.
pub const FLAG_DEFLATED: u8 = 1 << 0;
pub const FLAG_ROTATED: u8 = 1 << 1;
pub const KNOWN_FLAGS: u8 = FLAG_DEFLATED;

pub fn is_deflated(flags: u8) -> bool {
    flags & FLAG_DEFLATED != 0
}

pub fn frame_len(payload: usize) -> usize {
    HEADER_BYTES + payload
}
