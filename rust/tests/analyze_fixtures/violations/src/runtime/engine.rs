//! Fixture: undocumented unsafe.
pub struct Engine {
    ptr: *mut u8,
}

unsafe impl Send for Engine {}

pub fn poke(e: &Engine) -> u8 {
    unsafe {
        e.ptr.read()
    }
}
