//! Fixture: a tracer that reads raw clocks — determinism hits on all
//! three wall-time tokens.
pub fn stamp() -> u64 {
    let t = std::time::Instant::now();
    let wall = std::time::SystemTime::now();
    let sw = crate::util::timer::Stopwatch::start();
    let _ = (wall, sw);
    t.elapsed().as_micros() as u64
}
