//! Fixture: nondeterministic time and RNG in the simulator.
pub fn now_ms() -> u64 {
    let wall = std::time::SystemTime::now();
    let _ = wall;
    let noise: u64 = rand::thread_rng().gen();
    noise
}
