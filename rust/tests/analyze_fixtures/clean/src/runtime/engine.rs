//! Fixture: documented unsafe — comment-above and same-line styles.
pub struct Engine {
    ptr: *mut u8,
}

// SAFETY: the pointer is owned by Engine and never aliased; dropping the
// engine frees it exactly once.
unsafe impl Send for Engine {}

pub fn poke(e: &Engine) -> u8 {
    // SAFETY: constructors guarantee ptr is non-null and valid for reads.
    unsafe { e.ptr.read() }
}
