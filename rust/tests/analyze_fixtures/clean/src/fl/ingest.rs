//! Fixture: a scoped-thread shard fold that passes every scope —
//! `std::thread::scope` is deterministic (disjoint shards, per-worker
//! arrival-order folds), the worker loop borrows every slice, and each
//! spawn closure `move`-captures with `&mut` state blessed by a
//! recognized disjointness idiom (`split_at_mut` halves, a body-local
//! scratch), so determinism, hotpath, hotloop_alloc, and thread_aliasing
//! must all stay quiet.

pub fn fold_sharded(frames: &[(f64, Vec<f32>)], acc: &mut [f64], cut: usize) {
    let (lo, hi) = acc.split_at_mut(cut);
    std::thread::scope(|s| {
        s.spawn(move || fold_range(frames, &mut lo[..], 0));
        s.spawn(move || {
            let mut local = [0.0f64; 8];
            fold_range(frames, &mut local[..], cut);
            merge(hi, &local);
        });
    });
}

fn fold_range(frames: &[(f64, Vec<f32>)], acc: &mut [f64], start: usize) {
    for (w, frame) in frames {
        for (a, v) in acc.iter_mut().zip(frame[start..].iter()) {
            *a += f64::from(*v) * *w;
        }
    }
}

fn merge(acc: &mut [f64], local: &[f64]) {
    for (a, v) in acc.iter_mut().zip(local.iter()) {
        *a += *v;
    }
}
