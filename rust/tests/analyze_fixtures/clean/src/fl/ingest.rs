//! Fixture: a scoped-thread shard fold that passes both scopes —
//! `std::thread::scope` is deterministic (disjoint shards, per-worker
//! arrival-order folds) and the worker loop borrows every slice, so
//! neither the determinism nor the hotpath rule may fire.

pub fn fold_sharded(frames: &[(f64, Vec<f32>)], acc: &mut [f64], cut: usize) {
    let (lo, hi) = acc.split_at_mut(cut);
    std::thread::scope(|s| {
        s.spawn(|| fold_range(frames, lo, 0));
        s.spawn(|| fold_range(frames, hi, cut));
    });
}

fn fold_range(frames: &[(f64, Vec<f32>)], acc: &mut [f64], start: usize) {
    for (w, frame) in frames {
        for (a, v) in acc.iter_mut().zip(frame[start..].iter()) {
            *a += f64::from(*v) * *w;
        }
    }
}
