//! Fixture: a compliant ingest path — fallible access only, panics
//! confined to test code and an allowlisted debug helper.
use std::collections::BTreeMap;

pub fn ingest(payload: &[u8]) -> Option<u32> {
    let mut seen: BTreeMap<u32, u32> = BTreeMap::new();
    let head = parse_head(payload)?;
    let tail = payload.get(1..)?;
    seen.insert(head as u32, tail.len() as u32);
    Some(head as u32)
}

/// Reachable from the `ingest` boundary entry; fallible access only, so
/// the interprocedural panic_propagation walk stays quiet.
fn parse_head(payload: &[u8]) -> Option<u8> {
    payload.first().copied()
}

/// Allowlisted in analyze.toml (`fl/server.rs::debug_probe`).
pub fn debug_probe(payload: &[u8]) -> u32 {
    payload.first().copied().unwrap() as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_ingest() {
        // Panicking combinators are fine inside #[cfg(test)].
        assert_eq!(ingest(&[7, 1]).unwrap(), 7);
        let head = [7u8, 1][0];
        assert_eq!(head, 7);
    }
}
