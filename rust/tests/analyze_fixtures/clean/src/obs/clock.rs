//! Fixture: a compliant tracing clock — the wall read is confined to the
//! allowlisted `wall` constructor; everything else is virtual time.
pub enum TimeSource {
    Manual { now: u64 },
}

pub fn manual() -> TimeSource {
    TimeSource::Manual { now: 0 }
}

/// Allowlisted in analyze.toml (`obs/clock.rs::wall`).
pub fn wall() -> u64 {
    std::time::Instant::now().elapsed().as_micros() as u64
}
