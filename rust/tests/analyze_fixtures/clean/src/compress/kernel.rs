//! Fixture: transcendentals behind explicit waivers — fn-level above the
//! reference path, line-level at the LUT seed.

// analyze: allow(hotpath): reference ground-truth path
pub fn reference_code(x: f32) -> u16 {
    x.acos() as u16
}

pub fn dequantize(codes: &[u16], step: f32, lut: &mut Vec<f32>, out: &mut Vec<f32>) {
    if lut.is_empty() {
        // analyze: allow(hotpath): LUT seed, amortized over the tensor
        lut.extend((0..16).map(|c| (c as f32 * step).cos()));
    }
    out.clear();
    out.extend(codes.iter().map(|&c| lut.get(c as usize).copied().unwrap_or(0.0)));
}
