//! Fixture: a consistent wire spec. Layout:
//!
//! ```text
//! offset size field
//! 0      4    magic
//! 4      4    n
//! 8      4    payload_len
//! 12     ..   payload
//! ```
pub const MAGIC: [u8; 4] = *b"CSG2";
pub const HEADER_BYTES: usize = 12;

// Every flag bit is in KNOWN_FLAGS and consumed on decode — the
// flag-exhaustiveness check must stay quiet.
pub const FLAG_DEFLATED: u8 = 1 << 0;
pub const KNOWN_FLAGS: u8 = FLAG_DEFLATED;

pub fn is_deflated(flags: u8) -> bool {
    flags & FLAG_DEFLATED != 0
}

pub fn frame_len(payload: usize) -> usize {
    HEADER_BYTES + payload
}
