//! Fixture: a consistent wire spec. Layout:
//!
//! ```text
//! offset size field
//! 0      4    magic
//! 4      4    n
//! 8      4    payload_len
//! 12     ..   payload
//! ```
pub const MAGIC: [u8; 4] = *b"CSG2";
pub const HEADER_BYTES: usize = 12;

pub fn frame_len(payload: usize) -> usize {
    HEADER_BYTES + payload
}
