//! Fixture: a match-finder that passes both scopes — fixed-size array
//! chains (deterministic iteration), caller-owned scratch reused across
//! chunks, and a waived one-time construction. Test-span allocations are
//! excluded. Nothing here may fire.

pub struct Scratch {
    head: Vec<i32>,
}

impl Scratch {
    // analyze: allow(hotpath): one-time scratch construction, reused across every chunk
    pub fn new() -> Self {
        Scratch { head: vec![-1; 1 << 15] }
    }
}

pub fn tokenize_chunk(data: &[u8], scratch: &mut Scratch, out: &mut Vec<u8>) {
    scratch.head.fill(-1);
    for w in data.windows(3) {
        let key = (usize::from(w[0]) << 7) ^ usize::from(w[1]) ^ usize::from(w[2]);
        scratch.head[key & ((1 << 15) - 1)] = i32::from(w[0]);
        out.push(w[0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        // Allocations in test spans are fine.
        let data = vec![1u8; 64].to_vec();
        let mut out = Vec::new();
        tokenize_chunk(&data, &mut Scratch::new(), &mut out);
        assert!(!out.is_empty());
    }
}
