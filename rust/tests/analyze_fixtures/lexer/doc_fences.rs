//! Lexer fixture: doc comments with fenced code blocks carrying fake
//! `fn` / `unsafe` / `.unwrap()` tokens. Everything inside a comment is
//! comment — the structure pass must see exactly two real fns and zero
//! unsafe sites.

/// Decode one frame. Example:
///
/// ```
/// fn fake_in_doc() { let x = v.unwrap(); }
/// unsafe { core::hint::unreachable_unchecked() }
/// ```
pub fn real(x: u32) -> u32 {
    x + 1
}

/** Block doc with a fence:
```
fn also_fake() { panic!("doc only"); }
```
*/
pub fn real_two() -> u32 {
    2
}
