// Byte strings, raw byte strings, and byte chars.
pub fn bytes() -> u8 {
    let magic = b"CSG9";
    let raw = br#"also "CSG9" raw"#;
    let nl = b'\n';
    let x = b'x';
    let _ = (magic, raw, nl);
    x
}

pub fn not_byte_string(grab: &[u8]) -> usize {
    // `b` as the tail of an identifier must not start a byte string.
    grab.len()
}
