//! Lexer fixture: multi-line fn signatures — the fn span must anchor at
//! the `fn` keyword line, find the opening brace lines later, and the
//! call graph must still resolve calls to the fn.

pub fn long_signature(
    first: &[f32],
    second: &mut Vec<f32>,
    third: usize,
) -> Option<f32> {
    second.clear();
    first.get(third).copied()
}

pub fn caller() -> Option<f32> {
    long_signature(&[1.0], &mut Vec::new(), 0)
}
