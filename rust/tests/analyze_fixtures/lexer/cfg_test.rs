// Production code above; everything inside #[cfg(test)] / #[test] spans
// is invisible to the rules.
pub fn production(xs: &[u32]) -> u32 {
    xs.iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn sums() {
        let mut m: HashMap<u32, u32> = HashMap::new();
        m.insert(1, 2);
        assert_eq!(production(&[1, 2]), 3);
        assert_eq!(m.get(&1).copied().unwrap(), 2);
    }
}

pub fn also_production(xs: &[u32]) -> u32 {
    xs.len() as u32
}

#[test]
fn free_test_fn() {
    let v = vec![1u32];
    assert_eq!(v.first().copied().unwrap(), 1);
}
