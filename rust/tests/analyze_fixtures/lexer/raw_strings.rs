// Raw strings: hash fences, embedded quotes, multi-line bodies. None of
// the banned tokens inside them are code.
pub fn raw() -> (&'static str, &'static str, &'static str) {
    let a = r"HashMap::new() .unwrap()";
    let b = r#"quote " then HashMap"#;
    let c = r##"fence "# inside, still HashMap"##;
    let multi = r#"line one HashMap
line two .unwrap()"#;
    let _ = multi;
    (a, b, c)
}

pub fn not_raw(radius: f32) -> f32 {
    // `r` as the tail of an identifier must not start a raw string.
    let scale_factor = radius * 2.0;
    scale_factor
}
