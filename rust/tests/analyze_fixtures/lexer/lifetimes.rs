// Lifetimes must survive as code; char literals must be scrubbed.
pub struct Holder<'a> {
    inner: &'a str,
}

impl<'a> Holder<'a> {
    pub fn classify(&self, c: char) -> bool {
        let newline = '\n';
        let quote = '\'';
        let alpha = 'a';
        let wide = 'π';
        c == newline || c == quote || c == alpha || c == wide
    }

    pub fn get(&self) -> &'a str {
        self.inner
    }
}

pub fn labeled() -> u32 {
    let mut n = 0;
    'outer: loop {
        n += 1;
        if n > 3 {
            break 'outer;
        }
    }
    n
}
