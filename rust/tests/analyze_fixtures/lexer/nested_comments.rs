/* outer /* nested HashMap */ still a comment .unwrap() */
pub fn after() -> u32 {
    /* multi
       line /* deeper SystemTime */
       tail */
    42
}
