//! Lexer fixture: nested generics closing with `>>` (and a real shift
//! expression) must not derail impl-owner capture or fn spans.

pub struct Wrap<T>(pub Vec<Vec<T>>);

pub fn nested(m: Vec<Vec<u32>>) -> Option<Vec<Vec<u32>>> {
    let shifted = 1u32 >> 2;
    let _ = shifted;
    Some(m)
}

impl<T> Wrap<T> {
    pub fn get_all(&self) -> &Vec<Vec<T>> {
        &self.0
    }

    pub fn depth(map: Vec<Vec<Vec<u8>>>) -> usize {
        map.len()
    }
}
