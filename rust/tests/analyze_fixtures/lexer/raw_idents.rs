//! Lexer fixture: raw identifiers. `r#fn` / `r#unsafe` / `r#match` are
//! names, not keywords — they must not open fn spans, unsafe sites, or
//! confuse the structure pass, and a fn *named* via a raw identifier
//! keeps its `r#`-prefixed name.

pub fn caller() -> u32 {
    let r#match = 3u32;
    let r#loop = r#match + 1;
    r#fn(r#loop)
}

fn r#fn(x: u32) -> u32 {
    x + r#unsafe()
}

fn r#unsafe() -> u32 {
    7
}
