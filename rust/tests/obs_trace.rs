//! The observability plane's system-level contracts:
//!
//! * **byte-identical traces** — a sim-clocked (`TimeSource::manual`)
//!   traced run is a pure function of the seed: two runs with equal seeds
//!   render byte-for-byte equal JSONL documents, different seeds diverge;
//! * every line of a rendered trace parses as JSON and the document ends
//!   with exactly one metrics snapshot;
//! * the bounded ring drops oldest-first without reallocating;
//! * the `repro trace` explorer renders all its panels from a real
//!   protocol-run trace.

use cossgd::compress::allocator::{BitSchedule, LayerMap};
use cossgd::compress::Pipeline;
use cossgd::fl::transport::dryrun::{self, DryBits};
use cossgd::obs::{self, Metrics, TimeSource, Tracer};
use cossgd::sim::SimConfig;
use cossgd::util::json::Json;

const N: usize = 2_000;
const CLIENTS: usize = 12;

fn bits() -> DryBits {
    DryBits {
        schedule: BitSchedule::Adaptive { budget: 0 },
        map: LayerMap::even(N, 4),
        decay: 0.5,
    }
}

/// One traced sync + async protocol run, rendered to a JSONL document.
/// Runs with a 2-shard ingest plane so the trace covers the sharded fold
/// path (`ingest_flush` points, per-shard gauges) — bit-identical
/// protocol outcomes either way, and still a pure function of the seed.
fn trace_doc(seed: u64) -> String {
    let pipe = Pipeline::cosine(4);
    let sim = SimConfig::heterogeneous();
    let b = bits();
    let mut tracer = Tracer::new(TimeSource::manual(), 4096);
    let mut metrics = Metrics::new();
    dryrun::run_sync_bits_traced(
        &pipe,
        Some(&b),
        &sim,
        N,
        CLIENTS,
        4,
        3,
        seed,
        2,
        &mut tracer,
        &mut metrics,
    )
    .expect("sync dry run");
    dryrun::run_async_bits_traced(
        &pipe,
        Some(&b),
        &sim,
        N,
        CLIENTS,
        4,
        8,
        3,
        2,
        seed,
        2,
        &mut tracer,
        &mut metrics,
    )
    .expect("async dry run");
    obs::render_trace(&tracer, &metrics)
}

#[test]
fn same_seed_traces_are_byte_identical() {
    let a = trace_doc(42);
    let b = trace_doc(42);
    assert_eq!(a, b, "sim-clocked traces must be a pure function of the seed");
    let c = trace_doc(43);
    assert_ne!(a, c, "different seeds must diverge somewhere in the trace");
    assert!(a.lines().count() > 10, "the run actually traced something");
}

#[test]
fn every_line_parses_and_the_doc_ends_with_one_metrics_snapshot() {
    let doc = trace_doc(42);
    let mut metrics_lines = 0usize;
    let mut event_lines = 0usize;
    for (i, line) in doc.lines().enumerate() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("line {}: {e}", i + 1));
        if j.get("metrics").is_some() {
            metrics_lines += 1;
            assert_eq!(
                i + 1,
                doc.lines().count(),
                "the metrics snapshot must be the final line"
            );
        } else {
            event_lines += 1;
            let kind = j.get("ev").and_then(Json::as_str).expect("ev key");
            assert!(matches!(kind, "open" | "close" | "point"), "kind {kind}");
            assert!(j.get("at").and_then(Json::as_u64).is_some(), "timestamp");
            assert!(j.get("name").and_then(Json::as_str).is_some(), "name");
        }
    }
    assert_eq!(metrics_lines, 1);
    assert!(event_lines > 0);
}

#[test]
fn the_trace_covers_the_round_story() {
    let doc = trace_doc(42);
    let names: Vec<String> = doc
        .lines()
        .filter_map(|l| {
            Json::parse(l)
                .ok()?
                .get("name")
                .and_then(Json::as_str)
                .map(str::to_string)
        })
        .collect();
    for needle in [
        "round", "broadcast", "train", "upload", // timeline-replay spans
        "downlink", "dispatch", "ingest", "observe", "bit_plan", // live points
        "ingest_flush", // sharded-plane fold telemetry
    ] {
        assert!(
            names.iter().any(|n| n == needle),
            "no `{needle}` event in the trace; saw {names:?}"
        );
    }
    // The metrics snapshot carries the verdict counters and the ledger.
    let last = doc.lines().last().expect("metrics line");
    let m = Json::parse(last).expect("metrics json");
    for counter in [
        "ingest_accepted",
        "ingest_flushes",
        "ingest_frames_folded",
        "uplink_bytes",
        "downlink_bytes",
        "rounds",
    ] {
        assert!(
            m.path(&["metrics", "counters", counter])
                .and_then(Json::as_u64)
                .is_some_and(|v| v > 0),
            "counter {counter} missing or zero in {last}"
        );
    }
}

#[test]
fn ring_overflow_drops_oldest_without_reallocation() {
    let cap = 64usize;
    let mut t = Tracer::new(TimeSource::frozen(7), cap);
    for i in 0..(cap * 3) {
        t.point("tick", vec![("i", Json::from(i))]);
    }
    assert_eq!(t.len(), cap);
    assert_eq!(t.allocated_capacity(), cap, "the ring must never reallocate");
    assert_eq!(t.dropped(), (cap * 2) as u64);
    // Oldest-first ordering survived the wrap: the survivors are the tail.
    let first = t.events().next().expect("events");
    assert_eq!(
        first.fields[0].1,
        Json::from(cap * 2),
        "oldest surviving event is the first undropped one"
    );
}

#[test]
fn explorer_renders_all_panels_from_a_real_run() {
    let doc = trace_doc(42);
    let report = cossgd::obs::explore::report(&doc).expect("explorer parses its own output");
    for needle in [
        "trace:",
        "critical path:",
        "flame",
        "ingest verdicts:",
        "allocator decisions:",
        "counters:",
    ] {
        assert!(report.contains(needle), "missing `{needle}` in:\n{report}");
    }
}

#[test]
fn disabled_tracer_records_nothing() {
    let mut t = Tracer::disabled();
    let s = t.open("round");
    t.point("ingest", vec![("client", Json::from(1usize))]);
    t.close(s);
    assert!(t.is_empty());
    assert_eq!(t.dropped(), 0);
    assert_eq!(t.allocated_capacity(), 0, "disabled tracer allocates no ring");
}
