//! Integration tests for `repro analyze`: the real tree is clean and the
//! report is deterministic; every rule family fires on the seeded
//! `violations` fixture and stays quiet on the `clean` fixture; the lexer
//! edge cases hold; and — the invariant panic-safety exists to protect —
//! `Server::ingest` survives a barrage of malformed frames without
//! panicking or corrupting state.

use std::path::{Path, PathBuf};

use cossgd::analyze::{self, lexer};
use cossgd::compress::{Direction, Pipeline, PipelineState};
use cossgd::fl::server::{Ingest, Server};
use cossgd::fl::transport::Frame;
use cossgd::util::propcheck::gradient_like;
use cossgd::util::rng::Pcg64;

fn crate_dir() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn fixture(name: &str) -> PathBuf {
    crate_dir().join("tests/analyze_fixtures").join(name)
}

fn lex_fixture(name: &str) -> lexer::SourceFile {
    let path = fixture("lexer").join(name);
    let text = std::fs::read_to_string(&path).expect("lexer fixture readable");
    lexer::lex_str(name, &text)
}

// ---------------------------------------------------------------------------
// Self-check: the real tree passes its own analyzer, deterministically.
// ---------------------------------------------------------------------------

#[test]
fn real_tree_is_clean() {
    let report = analyze::run(&crate_dir().join("src"), &crate_dir().join("analyze.toml"), &[])
        .expect("analyzer runs on the real tree");
    assert!(
        report.clean(),
        "the real tree must pass its own analyzer:\n{}",
        report.text()
    );
    assert!(report.files_scanned > 30, "walk found the whole tree");
    assert_eq!(report.rules_run.len(), 8);

    // The committed CI coverage baseline must stay honest: every rule it
    // pins actually runs, and its files-scanned floor is not above what
    // the walk finds (the CI diff step enforces the same two facts with
    // jq against the live report).
    let base = std::fs::read_to_string(crate_dir().join("analyze-baseline.json"))
        .expect("analyze-baseline.json is committed next to Cargo.toml");
    let base = cossgd::util::json::Json::parse(&base).expect("baseline parses");
    let pinned = base.get("rules").and_then(|r| r.as_arr()).expect("baseline rules");
    for rule in pinned {
        let name = rule.as_str().expect("rule name");
        assert!(
            report.rules_run.iter().any(|r| r == name),
            "baseline pins rule `{name}` which no longer runs"
        );
    }
    assert_eq!(pinned.len(), report.rules_run.len(), "baseline rule list is stale");
    let floor = base
        .get("files_scanned")
        .and_then(|v| v.as_usize())
        .expect("baseline files_scanned");
    assert!(
        report.files_scanned >= floor,
        "tree shrank below the committed baseline floor ({} < {floor})",
        report.files_scanned
    );
}

#[test]
fn report_is_byte_identical_across_runs() {
    let run = || {
        analyze::run(
            &fixture("violations/src"),
            &fixture("violations/analyze.toml"),
            &[],
        )
        .expect("analyzer runs on the violations fixture")
    };
    let (a, b) = (run(), run());
    assert_eq!(a.text(), b.text());
    assert_eq!(a.json(), b.json());
    // And on the real tree as well.
    let real = || {
        analyze::run(&crate_dir().join("src"), &crate_dir().join("analyze.toml"), &[])
            .expect("analyzer runs")
            .json()
    };
    assert_eq!(real(), real());
}

// ---------------------------------------------------------------------------
// Every rule family fires on the seeded violations; the clean tree with
// waivers / allowlists / test spans stays quiet.
// ---------------------------------------------------------------------------

#[test]
fn every_rule_family_fires_on_the_violations_fixture() {
    let report = analyze::run(
        &fixture("violations/src"),
        &fixture("violations/analyze.toml"),
        &[],
    )
    .expect("analyzer runs");
    assert!(!report.clean());

    let has = |rule: &str, file: &str, needle: &str| {
        report
            .diagnostics
            .iter()
            .any(|d| d.rule == rule && d.path == file && d.message.contains(needle))
    };
    // determinism
    assert!(has("determinism", "fl/server.rs", "HashMap"), "{}", report.text());
    assert!(has("determinism", "fl/runner.rs", "Instant"));
    assert!(has("determinism", "sim/clock.rs", "SystemTime"));
    assert!(has("determinism", "sim/clock.rs", "thread_rng"));
    // ...and the observability plane is scoped: raw clock reads in a
    // tracer would break the byte-identical-trace contract.
    assert!(has("determinism", "obs/trace.rs", "Instant"));
    assert!(has("determinism", "obs/trace.rs", "SystemTime"));
    assert!(has("determinism", "obs/trace.rs", "Stopwatch"));
    // ...and the sharded ingest plane: unordered per-shard state would
    // break the bit-identical merge contract.
    assert!(has("determinism", "fl/ingest.rs", "HashMap"));
    // ...and the parallel DEFLATE plane: wall-clock reads or unordered
    // chains in the match-finder/block-writer would break the
    // byte-identical-at-any-thread-count contract.
    assert!(has("determinism", "compress/deflate/matcher.rs", "HashMap"));
    assert!(has("determinism", "compress/deflate/matcher.rs", "Instant"));
    assert!(has("determinism", "compress/deflate/block.rs", "SystemTime"));
    // panic_safety
    assert!(has("panic_safety", "fl/server.rs", ".unwrap()"));
    assert!(has("panic_safety", "fl/server.rs", ".expect("));
    assert!(has("panic_safety", "fl/server.rs", "panic!"));
    assert!(has("panic_safety", "fl/server.rs", "indexing"));
    // hotpath
    assert!(has("hotpath", "compress/kernel.rs", ".acos("));
    assert!(has("hotpath", "compress/kernel.rs", ".cos("));
    assert!(has("hotpath", "compress/kernel.rs", ".to_vec()"));
    assert!(has("hotpath", "compress/kernel.rs", ".clone()"));
    // ...and the ingest worker fold loop: no per-frame allocations.
    assert!(has("hotpath", "fl/ingest.rs", ".clone()"));
    assert!(has("hotpath", "fl/ingest.rs", ".to_vec()"));
    // ...and the DEFLATE per-chunk loops: workers reuse caller scratch.
    assert!(has("hotpath", "compress/deflate/matcher.rs", ".to_vec()"));
    assert!(has("hotpath", "compress/deflate/matcher.rs", "vec!["));
    assert!(has("hotpath", "compress/deflate/block.rs", ".clone()"));
    assert!(has("hotpath", "compress/deflate/block.rs", ".to_vec()"));
    // unsafe_audit
    assert!(has("unsafe_audit", "runtime/engine.rs", "unsafe impl"));
    assert!(has("unsafe_audit", "runtime/engine.rs", "unsafe block"));
    // wire
    assert!(has("wire", "compress/wire.rs", "doc table ends at offset 8"));
    assert!(has("wire", "compress/consumer.rs", "duplicate HEADER_BYTES"));
    assert!(has("wire", "compress/consumer.rs", "bare `44`"));
    assert!(has("wire", "compress/consumer.rs", "magic bytes"));
    // ...flag exhaustiveness: FLAG_ROTATED is neither in the mask nor read.
    assert!(has("wire", "compress/wire.rs", "`FLAG_ROTATED` is not OR-ed into KNOWN_FLAGS"));
    assert!(has("wire", "compress/wire.rs", "`FLAG_ROTATED` is never consumed"));
    // panic_propagation: the `.unwrap()` sits in compress/decode.rs — a
    // file no lexical rule scopes — and is reached only through the
    // ingest -> decode_codes -> word_load call chain.
    assert!(has("panic_propagation", "compress/decode.rs", ".unwrap()"));
    assert!(has("panic_propagation", "fl/server.rs", "panic!"));
    assert!(has("panic_propagation", "fl/server.rs", "bare indexing"));
    let chained = report
        .diagnostics
        .iter()
        .find(|d| d.rule == "panic_propagation" && d.path == "compress/decode.rs")
        .expect("interprocedural finding present");
    assert_eq!(
        chained.chain,
        vec![
            "fl/server.rs::ingest".to_string(),
            "compress/decode.rs::decode_codes".to_string(),
            "compress/decode.rs::word_load".to_string(),
        ]
    );
    assert!(
        report.text().contains(
            "    via fl/server.rs::ingest -> compress/decode.rs::decode_codes -> compress/decode.rs::word_load"
        ),
        "{}",
        report.text()
    );
    // ...and the JSON report carries the same chain, machine-readably.
    let json = cossgd::util::json::Json::parse(&report.json()).expect("report JSON parses");
    let chains: Vec<Vec<&str>> = json
        .get("violations")
        .and_then(|v| v.as_arr())
        .expect("violations array")
        .iter()
        .filter(|v| v.get("rule").and_then(|r| r.as_str()) == Some("panic_propagation"))
        .filter_map(|v| v.get("chain").and_then(|c| c.as_arr()))
        .map(|c| c.iter().filter_map(|e| e.as_str()).collect())
        .collect();
    assert!(
        chains.iter().any(|c| c.len() == 3 && c[0] == "fl/server.rs::ingest"),
        "JSON report must render a full offending call chain"
    );
    // thread_aliasing: non-move spawn closure + two unblessed &mut captures.
    assert!(has("thread_aliasing", "fl/runner.rs", "must `move`-capture"));
    assert!(has("thread_aliasing", "fl/runner.rs", "`&mut flags`"));
    assert!(has("thread_aliasing", "fl/runner.rs", "`&mut shared`"));
    // hotloop_alloc: direct per-iteration allocations in the fold loop...
    assert!(has("hotloop_alloc", "fl/ingest.rs", "`.clone()` inside a hot loop"));
    assert!(has("hotloop_alloc", "fl/ingest.rs", "`.to_vec()` inside a hot loop"));
    // ...and the transitive one hidden behind a cross-file call.
    assert!(has("hotloop_alloc", "fl/ingest.rs", "compress/decode.rs::stage_frame"));
    let transitive = report
        .diagnostics
        .iter()
        .find(|d| d.rule == "hotloop_alloc" && d.message.contains("stage_frame"))
        .expect("transitive allocation finding present");
    assert_eq!(
        transitive.chain,
        vec![
            "fl/ingest.rs::fold_indirect".to_string(),
            "compress/decode.rs::stage_frame".to_string(),
        ]
    );

    // Exit-code contract: the CLI turns a dirty report into exit 1; the
    // report itself is the source of truth.
    assert!(report.diagnostics.len() >= 43);
}

#[test]
fn clean_fixture_is_quiet() {
    let report = analyze::run(&fixture("clean/src"), &fixture("clean/analyze.toml"), &[])
        .expect("analyzer runs");
    assert!(
        report.clean(),
        "waivers/allowlists/test spans must suppress everything:\n{}",
        report.text()
    );
}

#[test]
fn path_filters_restrict_the_scan() {
    let report = analyze::run(
        &fixture("violations/src"),
        &fixture("violations/analyze.toml"),
        &["sim/".to_string()],
    )
    .expect("analyzer runs");
    assert_eq!(report.files_scanned, 1);
    assert!(report.diagnostics.iter().all(|d| d.path.starts_with("sim/")));
    assert!(!report.clean());
}

// ---------------------------------------------------------------------------
// Lexer edge cases (one fixture per case).
// ---------------------------------------------------------------------------

#[test]
fn lexer_raw_strings() {
    let f = lex_fixture("raw_strings.rs");
    for line in &f.lines {
        assert!(!line.contains("HashMap"), "raw-string body leaked: {line}");
        assert!(!line.contains(".unwrap()"), "raw-string body leaked: {line}");
    }
    // The contents are captured as literals (with fences stripped).
    assert!(f.literals.iter().any(|(_, v)| v.contains("quote \" then HashMap")));
    assert!(f.literals.iter().any(|(_, v)| v.contains("fence \"# inside")));
    assert!(f.literals.iter().any(|(_, v)| v.contains("line one HashMap\nline two")));
    // `r` at the end of an identifier does not open a raw string.
    assert!(f.lines.iter().any(|l| l.contains("let scale_factor = radius * 2.0;")));
    assert_eq!(f.fns.len(), 2);
}

#[test]
fn lexer_nested_block_comments() {
    let f = lex_fixture("nested_comments.rs");
    for line in &f.lines {
        assert!(!line.contains("HashMap"));
        assert!(!line.contains("SystemTime"));
        assert!(!line.contains(".unwrap()"));
    }
    assert!(f.comments[0].contains("nested HashMap"));
    assert_eq!(f.fns.len(), 1);
    assert_eq!(f.fns[0].name, "after");
    assert!(f.lines.iter().any(|l| l.trim() == "42"));
}

#[test]
fn lexer_byte_literals() {
    let f = lex_fixture("byte_literals.rs");
    for line in &f.lines {
        assert!(!line.contains("CSG9"), "byte-string body leaked: {line}");
    }
    assert!(f.literals.iter().any(|(_, v)| v == "CSG9"));
    assert!(f.literals.iter().any(|(_, v)| v.contains("also \"CSG9\" raw")));
    // `b` at the end of an identifier does not open a byte string, and
    // byte chars scrub cleanly.
    assert!(f.lines.iter().any(|l| l.contains("grab.len()")));
    assert!(f.lines.iter().any(|l| l.contains("let nl =")));
}

#[test]
fn lexer_lifetimes_vs_char_literals() {
    let f = lex_fixture("lifetimes.rs");
    // Lifetimes and loop labels survive as code.
    assert!(f.lines.iter().any(|l| l.contains("Holder<'a>")));
    assert!(f.lines.iter().any(|l| l.contains("&'a str")));
    assert!(f.lines.iter().any(|l| l.contains("'outer: loop")));
    assert!(f.lines.iter().any(|l| l.contains("break 'outer;")));
    // Char literals (plain, escaped quote, wide) are scrubbed.
    for needle in ["'\\n'", "'\\''", "'a'", "'π'"] {
        assert!(
            !f.lines.iter().any(|l| l.contains(needle)),
            "char literal {needle} leaked into code"
        );
    }
    assert_eq!(f.fns.len(), 3);
}

#[test]
fn lexer_cfg_test_span_exclusion() {
    let f = lex_fixture("cfg_test.rs");
    // Every HashMap / unwrap mention sits inside a test span.
    for (ln, line) in f.lines.iter().enumerate() {
        if line.contains("HashMap") || line.contains(".unwrap()") {
            assert!(f.in_test(ln), "line {} not excluded: {line}", ln + 1);
        }
    }
    // Production functions are outside every test span.
    for name in ["production", "also_production"] {
        let fspan = f.fns.iter().find(|s| s.name == name).expect("fn span");
        assert!(!f.in_test(fspan.open), "{name} wrongly inside a test span");
    }
    // The free #[test] fn is excluded too.
    let free = f.fns.iter().find(|s| s.name == "free_test_fn").expect("fn span");
    assert!(f.in_test(free.open));
}

#[test]
fn lexer_raw_identifiers() {
    let f = lex_fixture("raw_idents.rs");
    let names: Vec<&str> = f.fns.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(names, vec!["caller", "r#fn", "r#unsafe"]);
    assert!(f.unsafes.is_empty(), "r#unsafe is a name, not a keyword");
    // `r#fn` / `r#loop` as *expressions* must not open fn spans or loops.
    let syms = analyze::symbols::SymbolTable::build(&[f]);
    assert!(syms.loops.is_empty(), "r#loop must not open a loop span");
}

#[test]
fn lexer_doc_fences() {
    let f = lex_fixture("doc_fences.rs");
    let names: Vec<&str> = f.fns.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(names, vec!["real", "real_two"], "fenced fns are comment text");
    assert!(f.unsafes.is_empty(), "unsafe inside a doc fence is comment text");
    for line in &f.lines {
        assert!(!line.contains(".unwrap()"), "doc fence leaked into code: {line}");
        assert!(!line.contains("panic!"), "doc fence leaked into code: {line}");
    }
    assert!(f.comments.iter().any(|c| c.contains("fake_in_doc")));
}

#[test]
fn lexer_nested_generics() {
    let f = lex_fixture("generics.rs");
    let syms = analyze::symbols::SymbolTable::build(&[f]);
    let names: Vec<(&str, Option<&str>)> = syms
        .fns
        .iter()
        .map(|s| (s.name.as_str(), s.owner.as_deref()))
        .collect();
    assert_eq!(
        names,
        vec![
            ("nested", None),
            ("get_all", Some("Wrap")),
            ("depth", Some("Wrap")),
        ],
        "`Vec<Vec<T>>` closers and `1u32 >> 2` must not derail owner capture"
    );
}

#[test]
fn lexer_multiline_signatures() {
    let f = lex_fixture("multiline_sig.rs");
    let long = f.fns.iter().find(|s| s.name == "long_signature").expect("fn span");
    assert!(long.open > long.decl, "opening brace sits lines below `fn`");
    assert!(long.end > long.open);
    let syms = analyze::symbols::SymbolTable::build(&[f]);
    let call = syms
        .calls
        .iter()
        .find(|c| c.name == "long_signature")
        .expect("call site recorded");
    let targets = syms.resolve(call);
    assert_eq!(targets.len(), 1);
    assert_eq!(syms.label(targets[0]), "multiline_sig.rs::long_signature");
}

// ---------------------------------------------------------------------------
// Malformed-frame fuzz: hostile payloads through the real ingest path.
// ---------------------------------------------------------------------------

/// A well-formed single-frame uplink payload for an `n`-param model.
fn good_payload(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Pcg64::seeded(seed);
    let g = gradient_like(&mut rng, n);
    let pipe = Pipeline::cosine(4);
    let enc = pipe.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
    cossgd::compress::wire::serialize(&enc)
}

fn fresh_server(params: &[f32]) -> Server {
    Server::new(params.to_vec(), 0.5).with_clients(vec![10, 20, 30])
}

#[test]
fn ingest_survives_malformed_frames() {
    let n = 512usize;
    let params: Vec<f32> = (0..n).map(|i| (i as f32) * 0.01 - 2.0).collect();
    let good = good_payload(n, 7);

    // Sanity: the untouched payload is accepted.
    let mut s = fresh_server(&params);
    assert_eq!(
        s.ingest(&Frame { round: 0, client_id: 1, payload: good.clone() }),
        Ingest::Accepted { staleness: 0 }
    );

    let mut rng = Pcg64::seeded(0xBAD_F00D);
    let mut accepted = 0usize;
    let mut refused = 0usize;
    let mut case = |payload: Vec<u8>, client_id: usize, round: usize| {
        let mut s = fresh_server(&params);
        let before = params.clone();
        match s.ingest(&Frame { round, client_id, payload }) {
            Ingest::Accepted { .. } => accepted += 1,
            _ => {
                refused += 1;
                // Refusal must leave the server untouched: nothing
                // buffered, and closing the round moves no weight.
                assert_eq!(s.buffered(), 0);
                assert_eq!(s.finish_round(), 0);
                assert_eq!(s.params, before, "refused frame mutated the model");
            }
        }
    };

    // Deterministic structured corruptions.
    for cut in [0, 1, 10, 43, 44, 45, good.len() - 1] {
        case(good[..cut].to_vec(), 0, 0); // truncations
    }
    case([good.clone(), vec![0xA5; 17]].concat(), 0, 0); // trailing garbage
    for off in 0..48usize.min(good.len()) {
        let mut p = good.clone();
        p[off] ^= 0x40; // single-bit header corruption, every header byte
        case(p, 0, 0);
    }
    let mut p = good.clone();
    p[40..44].copy_from_slice(&u32::MAX.to_le_bytes()); // oversized payload_len
    case(p, 0, 0);
    let mut p = good.clone();
    p[40..44].copy_from_slice(&0u32.to_le_bytes()); // undersized payload_len
    case(p, 0, 0);
    case(good.clone(), 99, 0); // unregistered client
    case(good.clone(), 2, 5); // future round tag
    case(Vec::new(), 0, 0); // empty payload
    case(vec![0; 44], 0, 0); // all-zero header
    // A truncated two-segment stream: first frame valid, tail cut off.
    case([good.clone(), good[..30].to_vec()].concat(), 0, 0);

    // Random mutations: flips, splices, random lengths.
    for _ in 0..300 {
        let mut p = good.clone();
        match rng.below(4) {
            0 => {
                let at = rng.below_usize(p.len());
                p[at] ^= 1u8 << rng.below(8);
            }
            1 => {
                let cut = rng.below_usize(p.len());
                p.truncate(cut);
            }
            2 => {
                let at = rng.below_usize(p.len());
                let extra = rng.below_usize(64);
                let tail = p.split_off(at);
                p.extend((0..extra).map(|_| rng.next_u64() as u8));
                p.extend(tail);
            }
            _ => {
                let len = rng.below_usize(128);
                p = (0..len).map(|_| rng.next_u64() as u8).collect();
            }
        }
        case(p, rng.below_usize(3), rng.below_usize(2));
    }
    // Flips landing in the packed-code body (or in seed/norm header
    // fields) still decode — those are legitimately Accepted. Everything
    // structurally broken must be refused, which dominates.
    assert!(
        refused > 200,
        "mutations mostly refused ({refused} refused, {accepted} accepted)"
    );
}
