//! Integration tests over the real AOT artifacts through PJRT.
//!
//! These require `make artifacts` to have run; they self-skip (with a
//! loud message) if the artifacts directory is missing so `cargo test`
//! stays usable on a fresh checkout.

use cossgd::compress::cosine::{BoundMode, CosineQuantizer, Rounding};
use cossgd::compress::Pipeline;
use cossgd::data::partition::eval_set;
use cossgd::data::synth::{SynthMnist, SynthTask};
use cossgd::fl::{self, FlConfig, RoundMode};
use cossgd::runtime::manifest::init_params;
use cossgd::runtime::Engine;
use cossgd::sim::SimConfig;
use cossgd::util::rng::Pcg64;

fn engine_or_skip() -> Option<Engine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(dir).expect("engine load"))
}

#[test]
fn eval_at_init_is_chance_level() {
    let Some(engine) = engine_or_skip() else { return };
    let model = engine.manifest.model("mnist").unwrap().clone();
    let params = init_params(&model, 1);
    let task = SynthMnist::new(42);
    let n = engine.manifest.round("mnist").unwrap().eval_n;
    let (x, y) = eval_set(&task, n);
    let (acc, loss) = engine
        .classification_eval("mnist_eval", &params, x, y, n)
        .unwrap();
    assert!((0.0..=0.35).contains(&acc), "init acc {acc} not near chance");
    assert!(loss.is_finite() && loss > 1.0, "init loss {loss}");
}

#[test]
fn local_round_produces_learning_update() {
    let Some(engine) = engine_or_skip() else { return };
    let model = engine.manifest.model("mnist").unwrap().clone();
    let cfg = engine.manifest.round("mnist").unwrap();
    let params = init_params(&model, 2);
    let task = SynthMnist::new(42);

    // One client's data: balanced classes.
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..cfg.n_data {
        let (xi, yi) = task.gen(i % 10, (i / 10) as u64);
        x.extend_from_slice(&xi);
        y.push(yi[0]);
    }
    let mut rng = Pcg64::seeded(3);
    let mut perms = Vec::new();
    for _ in 0..cfg.epochs {
        let p = rng.permutation(cfg.n_data);
        perms.extend(p.iter().map(|&i| i as i32));
    }
    let (delta, loss) = engine
        .local_round("mnist_round", &params, x.clone(), y.clone(), perms, 0.1)
        .unwrap();
    assert_eq!(delta.len(), model.param_count);
    assert!(loss.is_finite() && loss > 0.0);
    let nonzero = delta.iter().filter(|&&d| d != 0.0).count();
    assert!(nonzero > delta.len() / 2, "delta mostly zero: {nonzero}");

    // Applying the update improves the local loss (M* = M_in - delta).
    let after: Vec<f32> = params.iter().zip(&delta).map(|(p, d)| p - d).collect();
    let n = engine.manifest.round("mnist").unwrap().eval_n;
    let (ex, ey) = eval_set(&task, n);
    let (_, loss_before) = engine
        .classification_eval("mnist_eval", &params, ex.clone(), ey.clone(), n)
        .unwrap();
    let (_, loss_after) = engine
        .classification_eval("mnist_eval", &after, ex, ey, n)
        .unwrap();
    assert!(
        loss_after < loss_before,
        "eval loss should drop: {loss_before} -> {loss_after}"
    );
}

#[test]
fn pallas_kernel_matches_rust_codec() {
    let Some(engine) = engine_or_skip() else { return };
    let mut rng = Pcg64::seeded(7);
    let n = engine.manifest.chunk + 1234; // force pad+chunk path
    let g = cossgd::util::propcheck::gradient_like(&mut rng, n);
    let norm = cossgd::util::stats::l2_norm(&g) as f32;
    for bits in [2u8, 8] {
        // Shared bound so both paths quantize identically.
        let q = CosineQuantizer::new(bits, Rounding::Biased, BoundMode::Auto);
        let rust_q = q.quantize(&g, &mut rng);
        let u = vec![0.5f32; g.len()];
        let kernel_codes = engine
            .kernel_quantize(bits, &g, norm, rust_q.bound, &u)
            .unwrap();
        // u=0.5 gives floor(v)+(0.5<frac): differs from round-to-nearest
        // only when frac == 0.5 exactly. Allow <=1 code difference.
        let mut diffs = 0usize;
        for (a, b) in rust_q.codes.iter().zip(&kernel_codes) {
            let d = (*a as i32 - *b as i32).abs();
            assert!(d <= 1, "code diff {d} at bits={bits}");
            diffs += (d != 0) as usize;
        }
        assert!(
            diffs < g.len() / 100,
            "bits={bits}: too many boundary diffs {diffs}"
        );
        // Dequant round-trips through the kernel too.
        let deq_k = engine
            .kernel_dequantize(bits, &kernel_codes, norm, rust_q.bound)
            .unwrap();
        let deq_r =
            cossgd::compress::cosine::dequantize_codes(&kernel_codes, norm, rust_q.bound, bits);
        for (a, b) in deq_k.iter().zip(&deq_r) {
            assert!((a - b).abs() <= 1e-4 * norm.max(1.0), "{a} vs {b}");
        }
    }
}

#[test]
fn tiny_federated_run_end_to_end() {
    let Some(engine) = engine_or_skip() else { return };
    // 3 rounds of MNIST IID with 2-bit cosine quantization.
    let cfg = FlConfig::mnist(false)
        .with_rounds(3)
        .with_uplink(Pipeline::cosine(2));
    let mut cfg = cfg;
    cfg.eval_every = 1;
    cfg.n_clients = 20; // smaller federation for test speed
    let result = fl::run(&cfg, &engine).expect("run");
    assert_eq!(result.history.records.len(), 3);
    assert!(result.history.final_metric().is_some());
    // 2 clients/round * 3 rounds updates were metered.
    assert_eq!(result.network.uplink_messages, 6);
    assert!(result.network.uplink_bytes > 0);
    // 2-bit + deflate: orders of magnitude below float32.
    let ratio = result
        .network
        .uplink_compression_vs_float32(engine.manifest.model("mnist").unwrap().param_count)
        .expect("uplink traffic was recorded");
    assert!(ratio > 10.0, "compression ratio {ratio}");
    // Training signal exists: train loss finite and generally decreasing.
    let first = result.history.records.first().unwrap().train_loss;
    let last = result.history.records.last().unwrap().train_loss;
    assert!(first.is_finite() && last.is_finite());
}

#[test]
fn unet_round_and_dice_eval() {
    let Some(engine) = engine_or_skip() else { return };
    let mut cfg = FlConfig::unet()
        .with_rounds(1)
        .with_uplink(Pipeline::cosine(8));
    cfg.eval_every = 1;
    let result = fl::run(&cfg, &engine).expect("unet run");
    let dice = result.history.final_metric().unwrap();
    assert!((0.0..=1.0).contains(&dice), "dice {dice}");
}

#[test]
fn round_trip_federated_run_end_to_end() {
    let Some(engine) = engine_or_skip() else { return };
    // The acceptance scenario: cosine-4 uplink + cosine-8 downlink.
    let mut cfg = FlConfig::mnist(false)
        .with_rounds(3)
        .with_uplink(Pipeline::cosine(4))
        .with_downlink(Pipeline::cosine(8));
    cfg.eval_every = 1;
    cfg.n_clients = 20;
    let result = fl::run(&cfg, &engine).expect("round-trip run");
    assert_eq!(result.history.records.len(), 3);
    assert!(result.history.final_metric().is_some());
    let params = engine.manifest.model("mnist").unwrap().param_count;
    // Downlink bytes strictly below the float32 broadcast baseline.
    let baseline = result.network.downlink_messages * (params as u64) * 4;
    assert!(
        result.network.downlink_bytes < baseline,
        "downlink {} !< float32 baseline {baseline}",
        result.network.downlink_bytes
    );
    let down = result
        .network
        .downlink_compression_vs_float32(params)
        .expect("downlink traffic was recorded");
    assert!(down > 1.0, "downlink ratio {down}");
}

#[test]
fn simulated_federation_end_to_end() {
    let Some(engine) = engine_or_skip() else { return };
    // Round-trip compression on a heterogeneous virtual fleet: the full
    // runner → FleetSim integration, with REAL per-round frame sizes.
    let mut cfg = FlConfig::mnist(false)
        .with_rounds(3)
        .with_uplink(Pipeline::cosine(4))
        .with_downlink(Pipeline::cosine(8))
        .with_sim(SimConfig::heterogeneous());
    cfg.eval_every = 1;
    cfg.n_clients = 20;
    let r1 = fl::run(&cfg, &engine).expect("sim run");
    let tl1 = r1.timeline.as_ref().expect("sim runs carry a timeline");
    assert_eq!(tl1.records.len(), 3);
    assert!(tl1.total_ticks() > 0, "virtual time never advanced");
    // The new history fields flow through: cumulative downlink recorded.
    let last = r1.history.records.last().unwrap();
    assert!(last.downlink_bytes > 0);
    assert_eq!(last.downlink_bytes, r1.network.downlink_bytes);
    // End-to-end determinism: the same config replays tick-identically
    // through real training, encoding and the event queue.
    let r2 = fl::run(&cfg, &engine).expect("sim rerun");
    assert_eq!(r2.timeline.as_ref(), Some(tl1));
    assert_eq!(r2.network.uplink_bytes, r1.network.uplink_bytes);
}

#[test]
fn buffered_async_federated_run_end_to_end() {
    let Some(engine) = engine_or_skip() else { return };
    // Full runner through the buffered-async event loop with REAL
    // training: 3 aggregation windows of 3 updates each on a sim-clocked
    // heterogeneous fleet, cosine-4 uplink + cosine-8 delta downlink.
    let mut cfg = FlConfig::mnist(false)
        .with_rounds(3)
        .with_uplink(Pipeline::cosine(4))
        .with_downlink(Pipeline::cosine(8))
        .with_sim(SimConfig::heterogeneous())
        .with_round_mode(RoundMode::BufferedAsync {
            buffer_k: 3,
            max_staleness: 2,
        });
    cfg.eval_every = 1;
    cfg.n_clients = 12;
    cfg.participation = 0.5;
    let r1 = fl::run(&cfg, &engine).expect("async run");
    assert_eq!(r1.history.records.len(), 3, "one record per window");
    for rec in &r1.history.records {
        assert_eq!(rec.clients, 3, "every window aggregates buffer_k updates");
        assert!(rec.train_loss.is_finite());
    }
    assert!(r1.history.final_metric().is_some());
    let tl = r1.timeline.as_ref().expect("sim runs carry a timeline");
    assert_eq!(tl.records.len(), 3);
    assert!(tl.total_ticks() > 0, "virtual time never advanced");
    assert!(r1.network.uplink_bytes > 0);
    // Deterministic end to end: same config, tick- and byte-identical.
    let r2 = fl::run(&cfg, &engine).expect("async rerun");
    assert_eq!(r2.timeline.as_ref(), Some(tl));
    assert_eq!(r2.network.uplink_bytes, r1.network.uplink_bytes);
    assert_eq!(r2.final_params, r1.final_params);
}

#[test]
fn parallel_client_rounds_bit_identical_to_serial() {
    let Some(engine) = engine_or_skip() else { return };
    // The tentpole determinism contract: the per-round client
    // train+encode fan-out must be bit-identical to the serial loop at
    // ANY thread count — same final params, same byte meters, same
    // history — because every client owns its RNG lane, EF residual and
    // scratch, and updates re-enter aggregation in selection order.
    let base = {
        let mut cfg = FlConfig::mnist(false)
            .with_rounds(2)
            .with_uplink(Pipeline::cosine(4).with_error_feedback())
            .with_downlink(Pipeline::cosine(8));
        cfg.eval_every = 1;
        cfg.n_clients = 12;
        cfg.participation = 0.5; // several clients per round
        cfg
    };
    let serial = fl::run(&base.clone().with_threads(1), &engine).expect("serial run");
    for threads in [2usize, 5, 0] {
        let par = fl::run(&base.clone().with_threads(threads), &engine)
            .unwrap_or_else(|e| panic!("threads={threads}: {e:#}"));
        assert_eq!(
            par.final_params, serial.final_params,
            "threads={threads}: final params diverged"
        );
        assert_eq!(par.network.uplink_bytes, serial.network.uplink_bytes);
        assert_eq!(par.network.downlink_bytes, serial.network.downlink_bytes);
        assert_eq!(
            par.history.records.len(),
            serial.history.records.len()
        );
        for (a, b) in par.history.records.iter().zip(&serial.history.records) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        }
    }
}

#[test]
fn kernel_quantizer_path_runs_in_federation() {
    let Some(engine) = engine_or_skip() else { return };
    let mut cfg = FlConfig::mnist(false)
        .with_rounds(1)
        .with_uplink(Pipeline::cosine_with(
            4,
            Rounding::Biased,
            BoundMode::ClipTopPercent(1.0),
        ));
    cfg.n_clients = 10;
    cfg.use_kernel_quantizer = true;
    cfg.eval_every = 1;
    let result = fl::run(&cfg, &engine).expect("kernel-path run");
    assert!(result.history.final_metric().is_some());
    assert!(result.network.uplink_bytes > 0);
}

#[test]
fn const_bit_schedule_is_bit_identical_to_fixed_width() {
    let Some(engine) = engine_or_skip() else { return };
    // The bit-identity contract of the adaptive controller (ISSUE 5):
    // `--bits const:<b>` routed through the controller must reproduce the
    // legacy fixed-width run byte for byte — same final params, same
    // per-round losses, same ledger totals — because a uniform plan
    // collapses to the identical pipeline and the identical RNG draws.
    let base = {
        let mut cfg = FlConfig::mnist(false)
            .with_rounds(2)
            .with_uplink(Pipeline::cosine(4))
            .with_downlink(Pipeline::cosine(8));
        cfg.eval_every = 1;
        cfg.n_clients = 12;
        cfg.participation = 0.5;
        cfg
    };
    let fixed = fl::run(&base, &engine).expect("fixed-width run");
    let scheduled = fl::run(
        &base
            .clone()
            .with_bit_schedule(cossgd::compress::BitSchedule::Const(4)),
        &engine,
    )
    .expect("const-schedule run");
    assert_eq!(
        scheduled.final_params, fixed.final_params,
        "const:4 diverged from the fixed-width path"
    );
    assert_eq!(scheduled.network.uplink_bytes, fixed.network.uplink_bytes);
    assert_eq!(scheduled.network.downlink_bytes, fixed.network.downlink_bytes);
    assert_eq!(scheduled.history.records.len(), fixed.history.records.len());
    for (a, b) in scheduled.history.records.iter().zip(&fixed.history.records) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.bits, vec![4], "const schedule must record its width");
    }
    // Sanity on the legacy side: no schedule → no recorded widths.
    assert!(fixed.history.records.iter().all(|r| r.bits.is_empty()));
}

#[test]
fn adaptive_and_anneal_schedules_run_end_to_end() {
    let Some(engine) = engine_or_skip() else { return };
    let base = {
        let mut cfg = FlConfig::mnist(false)
            .with_rounds(3)
            .with_uplink(Pipeline::cosine(4));
        cfg.eval_every = 3;
        cfg.n_clients = 10;
        cfg
    };
    // Anneal: width walks 8 → 2 across the run, one entry per round.
    let annealed = fl::run(
        &base
            .clone()
            .with_bit_schedule(cossgd::compress::BitSchedule::Anneal { hi: 8, lo: 2 }),
        &engine,
    )
    .expect("anneal run");
    let widths: Vec<u8> = annealed.history.records.iter().map(|r| r.bits[0]).collect();
    assert_eq!(widths, vec![8, 5, 2]);
    // Adaptive: per-layer mixed widths travel as real segment streams and
    // the run converges to a finite metric.
    let adaptive = fl::run(
        &base
            .clone()
            .with_bit_schedule(cossgd::compress::BitSchedule::Adaptive { budget: 0 }),
        &engine,
    )
    .expect("adaptive run");
    let rec = &adaptive.history.records[0];
    assert!(!rec.bits.is_empty(), "adaptive must record per-layer widths");
    assert!(adaptive.history.final_metric().is_some());
    assert!(adaptive.network.uplink_bytes > 0);
}
