//! System tests of the discrete-event federated systems simulator — no
//! artifacts needed. Covers the two acceptance properties:
//!
//! 1. **Determinism**: same seed + config ⇒ tick-identical timeline.
//! 2. **Time-to-accuracy**: on a bandwidth-bound (3G) fleet, cosine 4-bit
//!    round-trip compression reaches the target metric in fewer simulated
//!    seconds than float32 in both directions — *even when the quantized
//!    run needs 30% more rounds* — using REAL encoded frame sizes from
//!    the actual pipelines.

use cossgd::compress::{wire, Direction, Pipeline, PipelineState};
use cossgd::fl::metrics::{History, RoundRecord};
use cossgd::sim::{
    secs, ClientLoad, FleetSim, RoundPolicy, SimConfig, Timeline,
};
use cossgd::util::propcheck::gradient_like;
use cossgd::util::rng::Pcg64;

/// Real wire size of one frame through `pipe`.
fn frame_bytes(pipe: &Pipeline, g: &[f32], dir: Direction) -> usize {
    let mut rng = Pcg64::seeded(1);
    let enc = pipe.encode(g, dir, &mut PipelineState::new(), &mut rng);
    wire::serialize(&enc).len()
}

/// Drive `rounds` simulated FedAvg rounds with fixed per-round transfer
/// sizes over a 10-client selection of a 100-device fleet.
fn simulate(
    cfg: &SimConfig,
    seed: u64,
    rounds: usize,
    broadcast_bytes: usize,
    upload_bytes: usize,
) -> Timeline {
    let mut sim = FleetSim::new(cfg, 100, seed);
    let k = 10;
    let candidates: Vec<usize> = (0..sim.selection_count(k)).collect();
    for round in 1..=rounds {
        let plan = sim.begin_round(&candidates);
        let loads: Vec<ClientLoad> = plan
            .active
            .iter()
            .map(|&device| ClientLoad {
                device,
                upload_bytes,
                examples: 300,
            })
            .collect();
        sim.complete_round(round, &plan, k, broadcast_bytes, &loads);
    }
    sim.into_timeline()
}

#[test]
fn simulator_is_tick_identical_for_same_seed() {
    let cfg = SimConfig::heterogeneous()
        .with_policy(RoundPolicy::OverSelect { over_sample: 1.5 });
    let a = simulate(&cfg, 42, 12, 200_000, 17_000);
    let b = simulate(&cfg, 42, 12, 200_000, 17_000);
    // Byte- and tick-identical: every field of every record.
    assert_eq!(a, b);
    assert_eq!(a.records.len(), 12);
    // A different seed reshuffles the fleet and the lotteries.
    let c = simulate(&cfg, 43, 12, 200_000, 17_000);
    assert_ne!(a, c);
}

#[test]
fn virtual_clock_is_monotone_and_contiguous() {
    let tl = simulate(&SimConfig::heterogeneous(), 7, 8, 100_000, 10_000);
    for (i, r) in tl.records.iter().enumerate() {
        assert_eq!(r.round, i + 1);
        assert!(r.end >= r.start, "round {} ends before it starts", r.round);
        if i > 0 {
            assert_eq!(r.start, tl.records[i - 1].end, "clock gap at {i}");
        }
        // The bookkeeping partitions the selection.
        assert_eq!(
            r.reporters + r.stragglers_dropped + r.offline + r.dropouts,
            r.selected,
            "round {} does not account for every selected client",
            r.round
        );
    }
    assert_eq!(tl.total_ticks(), tl.records.last().unwrap().end);
}

#[test]
fn overselection_caps_waiting_on_stragglers() {
    // Identical fleet, candidates and traffic; everyone online — the ONLY
    // difference is the round policy. Closing at the 10th of 15 reporters
    // can never be slower than waiting for all 15, and on a heterogeneous
    // fleet (15 distinct device speeds) it is strictly faster.
    let mut base = SimConfig::heterogeneous();
    base.availability = 1.0;
    base.dropout = 0.0;
    let run = |policy: RoundPolicy| -> Timeline {
        let mut sim = FleetSim::new(&base.clone().with_policy(policy), 100, 11);
        let candidates: Vec<usize> = (0..15).collect();
        for round in 1..=10 {
            let plan = sim.begin_round(&candidates);
            let loads: Vec<ClientLoad> = plan
                .active
                .iter()
                .map(|&device| ClientLoad {
                    device,
                    upload_bytes: 50_000,
                    examples: 300,
                })
                .collect();
            sim.complete_round(round, &plan, 10, 400_000, &loads);
        }
        sim.into_timeline()
    };
    let sync = run(RoundPolicy::Synchronous);
    let over = run(RoundPolicy::OverSelect { over_sample: 1.5 });
    assert_eq!(sync.stragglers_dropped(), 0, "sync policy drops nobody");
    assert_eq!(over.stragglers_dropped(), 5 * 10, "5 stragglers per round");
    for (s, o) in sync.records.iter().zip(&over.records) {
        assert!(
            o.duration() <= s.duration(),
            "round {}: overselect {} !<= sync {}",
            s.round,
            o.duration(),
            s.duration()
        );
    }
    assert!(
        over.total_secs() < sync.total_secs(),
        "overselect {:.1}s !< sync {:.1}s",
        over.total_secs(),
        sync.total_secs()
    );
}

/// The headline acceptance test: a bandwidth-bound fleet reaches the
/// target metric in fewer simulated seconds with cosine 4-bit round-trip
/// compression than with float32 in both directions.
#[test]
fn bandwidth_bound_fleet_reaches_target_sooner_with_round_trip_quantization() {
    let n = 100_000; // a 100k-param model
    let mut rng = Pcg64::seeded(5);
    let g = gradient_like(&mut rng, n);

    // REAL frame sizes from the actual pipelines.
    let up_f32 = frame_bytes(&Pipeline::float32(), &g, Direction::Uplink);
    let down_f32 = n * 4; // raw float32 model broadcast (no framing)
    let cosine4 = Pipeline::cosine(4);
    let up_q = frame_bytes(&cosine4, &g, Direction::Uplink);
    let down_q = frame_bytes(&cosine4, &g, Direction::Downlink);
    assert!(
        up_q * 6 < up_f32,
        "cosine-4 frame {up_q} not ≪ float32 {up_f32}"
    );

    // Same 3G fleet (same seed ⇒ identical devices and lotteries).
    let cfg = SimConfig::cellular();
    // The paper's trade-off: quantized runs may need more rounds to the
    // same accuracy. Give cosine-4 30% more rounds — it still wins big.
    let rounds_f32 = 20;
    let rounds_q = 26;
    let tl_f32 = simulate(&cfg, 9, rounds_f32, down_f32, up_f32);
    let tl_q = simulate(&cfg, 9, rounds_q, down_q, up_q);

    // Synthetic convergence curves hitting the target on the last round.
    let history = |label: &str, rounds: usize, tl: &Timeline| -> History {
        let mut h = History::new(label);
        for (i, r) in tl.records.iter().enumerate() {
            h.push(RoundRecord {
                round: r.round,
                train_loss: 1.0 / (i + 1) as f64,
                eval_metric: Some(0.9 * (i + 1) as f64 / rounds as f64),
                eval_loss: None,
                uplink_bytes: 0,
                downlink_bytes: 0,
                clients: r.reporters,
                stale_updates: 0,
                dup_updates: 0,
                malformed_updates: 0,
                bits: Vec::new(),
                deflate_level: None,
            });
        }
        h
    };
    let h_f32 = history("float32", rounds_f32, &tl_f32);
    let h_q = history("cosine-4", rounds_q, &tl_q);

    let t_f32 = tl_f32.time_to_metric(&h_f32, 0.89).expect("f32 reaches target");
    let t_q = tl_q.time_to_metric(&h_q, 0.89).expect("cosine reaches target");
    assert!(
        t_q < t_f32 / 2.0,
        "cosine-4 round-trip {t_q:.1}s not well below float32 {t_f32:.1}s"
    );
    // Sanity: the totals agree with the per-round clock.
    assert!((tl_f32.total_secs() - secs(tl_f32.total_ticks())).abs() < 1e-9);
    assert!(t_f32 <= tl_f32.total_secs() + 1e-9);
}

#[test]
fn dropouts_thin_rounds_but_never_stall_them() {
    let mut cfg = SimConfig::heterogeneous();
    cfg.availability = 0.6;
    cfg.dropout = 0.2;
    let tl = simulate(&cfg, 3, 30, 100_000, 10_000);
    assert!(tl.offline() > 0, "nobody was ever offline");
    assert!(tl.dropouts() > 0, "nobody ever dropped mid-round");
    // Every round still closes in finite time with whoever survived.
    for r in &tl.records {
        assert!(r.end >= r.start);
        assert_eq!(r.reporters + r.stragglers_dropped, r.selected - r.offline - r.dropouts);
    }
}
