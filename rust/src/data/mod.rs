//! Synthetic datasets + federated partitioning.
//!
//! The paper evaluates on MNIST, CIFAR-10 and BraTS; none are available in
//! this environment, so each is substituted by a procedurally-generated
//! task with the same shape, class structure and partitioning behaviour
//! (DESIGN.md §5). Generation is fully deterministic from `(seed, class,
//! instance)`, so the 100-client × 600-example federations never need to
//! be materialized — each selected client generates its shard on demand.

pub mod partition;
pub mod synth;

pub use partition::{eval_set, iid_partition, non_iid_partition, ClientShard};
pub use synth::{SynthCifar, SynthMnist, SynthTask, SynthVolume};
