//! Procedural dataset generators (the MNIST / CIFAR-10 / BraTS substitutes).
//!
//! Requirements the generators must satisfy for the paper's phenomenology
//! to transfer (DESIGN.md §5):
//!
//! 1. deterministic in `(seed, class, instance)` — shards regenerate
//!    identically on any process;
//! 2. clearly learnable but not linearly trivial (convergence curves need
//!    headroom for quantization schemes to differ);
//! 3. class structure compatible with the paper's Non-IID shard split.

use crate::util::rng::Pcg64;

/// A synthetic classification/segmentation task.
///
/// `Sync` because the runner's parallel client rounds share `&Task`
/// across worker threads; generators are pure in `(seed, class,
/// instance)`, so concurrent `gen` calls are naturally safe.
pub trait SynthTask: Sync {
    /// Flat input length per example.
    fn input_len(&self) -> usize;
    /// Label length per example (1 for classification, voxels for seg).
    fn label_len(&self) -> usize;
    fn classes(&self) -> usize;
    /// Generate one example of `class` (for segmentation, `class` selects
    /// the scene family). Returns `(input, labels)`.
    fn gen(&self, class: usize, instance: u64) -> (Vec<f32>, Vec<i32>);
}

// ---------------------------------------------------------------------------
// MNIST-like: 28x28 grayscale stroke digits.
// ---------------------------------------------------------------------------

/// 10-class stroke-pattern images, 28x28x1. Each class has a fixed
/// prototype polyline skeleton (class-seeded); instances apply affine
/// jitter, per-vertex noise, stroke-width variation and pixel noise.
#[derive(Debug, Clone, Copy)]
pub struct SynthMnist {
    pub seed: u64,
}

const MN: usize = 28;

impl SynthMnist {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    /// Class prototype: 4 connected stroke segments in [4, 24]^2.
    fn prototype(&self, class: usize) -> Vec<(f32, f32)> {
        let mut rng = Pcg64::new(self.seed ^ 0xA11CE, class as u64);
        let n_pts = 5;
        (0..n_pts)
            .map(|_| {
                (
                    rng.range_f64(5.0, 23.0) as f32,
                    rng.range_f64(5.0, 23.0) as f32,
                )
            })
            .collect()
    }
}

fn dist_to_segment(px: f32, py: f32, a: (f32, f32), b: (f32, f32)) -> f32 {
    let (vx, vy) = (b.0 - a.0, b.1 - a.1);
    let (wx, wy) = (px - a.0, py - a.1);
    let len2 = vx * vx + vy * vy;
    let t = if len2 > 1e-9 {
        ((wx * vx + wy * vy) / len2).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let (dx, dy) = (px - (a.0 + t * vx), py - (a.1 + t * vy));
    (dx * dx + dy * dy).sqrt()
}

impl SynthTask for SynthMnist {
    fn input_len(&self) -> usize {
        MN * MN
    }
    fn label_len(&self) -> usize {
        1
    }
    fn classes(&self) -> usize {
        10
    }

    fn gen(&self, class: usize, instance: u64) -> (Vec<f32>, Vec<i32>) {
        let proto = self.prototype(class);
        let mut rng = Pcg64::new(
            self.seed ^ 0xD161,
            (class as u64) << 32 | (instance & 0xFFFF_FFFF),
        );
        // Instance transform: small rotation + translation + vertex jitter.
        let angle = rng.range_f64(-0.25, 0.25) as f32;
        let (ca, sa) = (angle.cos(), angle.sin());
        let (tx, ty) = (
            rng.range_f64(-2.0, 2.0) as f32,
            rng.range_f64(-2.0, 2.0) as f32,
        );
        let pts: Vec<(f32, f32)> = proto
            .iter()
            .map(|&(x, y)| {
                let (cx, cy) = (x - 14.0, y - 14.0);
                let (rx, ry) = (ca * cx - sa * cy, sa * cx + ca * cy);
                (
                    rx + 14.0 + tx + rng.normal_f32(0.0, 0.7),
                    ry + 14.0 + ty + rng.normal_f32(0.0, 0.7),
                )
            })
            .collect();
        let sigma = rng.range_f64(0.8, 1.3) as f32;
        let mut img = vec![0.0f32; MN * MN];
        for (i, pix) in img.iter_mut().enumerate() {
            let (px, py) = ((i % MN) as f32, (i / MN) as f32);
            let mut d = f32::MAX;
            for w in pts.windows(2) {
                d = d.min(dist_to_segment(px, py, w[0], w[1]));
            }
            let v = (-d * d / (2.0 * sigma * sigma)).exp();
            *pix = v + rng.normal_f32(0.0, 0.08);
        }
        (img, vec![class as i32])
    }
}

// ---------------------------------------------------------------------------
// CIFAR-like: 32x32x3 textured color images.
// ---------------------------------------------------------------------------

/// 10-class color-texture images, flattened HWC (32*32*3 = 3072). Class
/// prototypes are mixtures of oriented sinusoidal gratings with a color
/// tint; instances jitter phase/frequency and add noise.
#[derive(Debug, Clone, Copy)]
pub struct SynthCifar {
    pub seed: u64,
}

const CN: usize = 32;

struct Grating {
    fx: f32,
    fy: f32,
    phase: f32,
    rgb: [f32; 3],
}

impl SynthCifar {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    fn prototype(&self, class: usize) -> Vec<Grating> {
        let mut rng = Pcg64::new(self.seed ^ 0xC1FA, class as u64);
        (0..3)
            .map(|_| {
                let freq = rng.range_f64(0.2, 1.1) as f32;
                let theta = rng.range_f64(0.0, std::f64::consts::PI) as f32;
                Grating {
                    fx: freq * theta.cos(),
                    fy: freq * theta.sin(),
                    phase: rng.range_f64(0.0, 6.28) as f32,
                    rgb: [
                        rng.range_f64(-1.0, 1.0) as f32,
                        rng.range_f64(-1.0, 1.0) as f32,
                        rng.range_f64(-1.0, 1.0) as f32,
                    ],
                }
            })
            .collect()
    }
}

impl SynthTask for SynthCifar {
    fn input_len(&self) -> usize {
        CN * CN * 3
    }
    fn label_len(&self) -> usize {
        1
    }
    fn classes(&self) -> usize {
        10
    }

    fn gen(&self, class: usize, instance: u64) -> (Vec<f32>, Vec<i32>) {
        let protos = self.prototype(class);
        let mut rng = Pcg64::new(
            self.seed ^ 0xF00D,
            (class as u64) << 32 | (instance & 0xFFFF_FFFF),
        );
        let dp: Vec<f32> = protos.iter().map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let mut img = vec![0.0f32; CN * CN * 3];
        for yy in 0..CN {
            for xx in 0..CN {
                let base = (yy * CN + xx) * 3;
                for (g, d) in protos.iter().zip(&dp) {
                    let v = (g.fx * xx as f32 + g.fy * yy as f32 + g.phase + d).sin();
                    for c in 0..3 {
                        img[base + c] += 0.5 * v * g.rgb[c];
                    }
                }
                for c in 0..3 {
                    img[base + c] += rng.normal_f32(0.0, 0.25);
                }
            }
        }
        (img, vec![class as i32])
    }
}

// ---------------------------------------------------------------------------
// BraTS-like: 16^3 4-channel volumes with 5-label segmentation masks.
// ---------------------------------------------------------------------------

/// Volumetric "tumor" scenes: background tissue + 1–2 nested ellipsoids.
/// Labels: 0 background, 1 outer shell ("edema"), 2–4 core types. The four
/// channels are modalities with label-correlated intensity profiles.
///
/// `class` selects the scene family (core label = 2 + class % 3), so the
/// same class/instance indexing as the classification tasks drives
/// partitioning.
#[derive(Debug, Clone, Copy)]
pub struct SynthVolume {
    pub seed: u64,
}

const VD: usize = 16;

impl SynthVolume {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }
}

impl SynthTask for SynthVolume {
    fn input_len(&self) -> usize {
        VD * VD * VD * 4
    }
    fn label_len(&self) -> usize {
        VD * VD * VD
    }
    fn classes(&self) -> usize {
        3 // scene families
    }

    fn gen(&self, class: usize, instance: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Pcg64::new(
            self.seed ^ 0xB7A7,
            (class as u64) << 32 | (instance & 0xFFFF_FFFF),
        );
        let core_label = 2 + (class % 3) as i32;
        let cx = rng.range_f64(5.0, 11.0) as f32;
        let cy = rng.range_f64(5.0, 11.0) as f32;
        let cz = rng.range_f64(5.0, 11.0) as f32;
        let r_core = rng.range_f64(1.8, 3.2) as f32;
        let r_shell = r_core + rng.range_f64(1.2, 2.4) as f32;
        // Per-modality intensity of (background, shell, core).
        let profile: Vec<[f32; 3]> = (0..4)
            .map(|m| {
                [
                    0.1 + 0.05 * m as f32,
                    0.5 + rng.normal_f32(0.0, 0.05),
                    0.8 + 0.1 * (core_label as f32 - 2.0) + rng.normal_f32(0.0, 0.05),
                ]
            })
            .collect();
        let mut x = vec![0.0f32; self.input_len()];
        let mut y = vec![0i32; self.label_len()];
        for zz in 0..VD {
            for yy in 0..VD {
                for xx in 0..VD {
                    let d = ((xx as f32 - cx).powi(2)
                        + (yy as f32 - cy).powi(2)
                        + (zz as f32 - cz).powi(2))
                    .sqrt();
                    let vox = (zz * VD + yy) * VD + xx;
                    let region = if d < r_core {
                        y[vox] = core_label;
                        2
                    } else if d < r_shell {
                        y[vox] = 1;
                        1
                    } else {
                        0
                    };
                    for m in 0..4 {
                        x[vox * 4 + m] =
                            profile[m][region] + rng.normal_f32(0.0, 0.08);
                    }
                }
            }
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let t = SynthMnist::new(7);
        assert_eq!(t.gen(3, 42), t.gen(3, 42));
        assert_ne!(t.gen(3, 42).0, t.gen(3, 43).0);
        assert_ne!(t.gen(3, 42).0, t.gen(4, 42).0);
        let c = SynthCifar::new(7);
        assert_eq!(c.gen(1, 5), c.gen(1, 5));
        let v = SynthVolume::new(7);
        assert_eq!(v.gen(0, 1), v.gen(0, 1));
    }

    #[test]
    fn shapes_and_ranges() {
        let t = SynthMnist::new(1);
        let (x, y) = t.gen(0, 0);
        assert_eq!(x.len(), 784);
        assert_eq!(y, vec![0]);
        assert!(x.iter().all(|v| v.is_finite()));
        let c = SynthCifar::new(1);
        let (x, _) = c.gen(9, 0);
        assert_eq!(x.len(), 3072);
        let v = SynthVolume::new(1);
        let (x, y) = v.gen(2, 0);
        assert_eq!(x.len(), 16 * 16 * 16 * 4);
        assert_eq!(y.len(), 16 * 16 * 16);
        assert!(y.iter().all(|&l| (0..5).contains(&l)));
    }

    /// Nearest-centroid accuracy must be far above chance — the task is
    /// learnable — but below perfect — it is not trivial.
    fn centroid_accuracy<T: SynthTask>(task: &T, per_class: usize) -> f64 {
        let k = task.classes();
        let dim = task.input_len();
        let mut centroids = vec![vec![0.0f64; dim]; k];
        for (c, cent) in centroids.iter_mut().enumerate() {
            for i in 0..per_class {
                let (x, _) = task.gen(c, i as u64);
                for (a, b) in cent.iter_mut().zip(&x) {
                    *a += *b as f64 / per_class as f64;
                }
            }
        }
        let mut correct = 0usize;
        let trials = k * 20;
        for c in 0..k {
            for i in 0..20 {
                let (x, _) = task.gen(c, (per_class + i) as u64);
                let best = centroids
                    .iter()
                    .enumerate()
                    .min_by(|(_, a), (_, b)| {
                        let da: f64 =
                            a.iter().zip(&x).map(|(p, q)| (p - *q as f64).powi(2)).sum();
                        let db: f64 =
                            b.iter().zip(&x).map(|(p, q)| (p - *q as f64).powi(2)).sum();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap()
                    .0;
                correct += (best == c) as usize;
            }
        }
        correct as f64 / trials as f64
    }

    #[test]
    fn mnist_like_is_learnable_not_trivial() {
        let acc = centroid_accuracy(&SynthMnist::new(3), 30);
        assert!(acc > 0.5, "acc {acc} too low — not learnable");
    }

    #[test]
    fn cifar_like_is_learnable() {
        let acc = centroid_accuracy(&SynthCifar::new(3), 30);
        assert!(acc > 0.4, "acc {acc} too low");
    }

    #[test]
    fn volume_labels_cover_multiple_classes() {
        let v = SynthVolume::new(5);
        let mut seen = std::collections::HashSet::new();
        for class in 0..3 {
            for i in 0..4 {
                let (_, y) = v.gen(class, i);
                seen.extend(y);
            }
        }
        assert!(seen.contains(&0) && seen.contains(&1));
        assert!(seen.len() >= 4, "labels seen: {seen:?}");
    }
}
