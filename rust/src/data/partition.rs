//! Federated data partitioning: IID and the paper's Non-IID shard split
//! (McMahan et al. [25]: sort by label, divide into 2·m shards, give each
//! client two shards so it sees at most two classes).
//!
//! A client's shard is a list of `(class, instance)` pairs; with the
//! deterministic generators in [`super::synth`], that list *is* the data —
//! nothing is materialized until a client is selected for a round.

use crate::util::rng::Pcg64;

use super::synth::SynthTask;

/// One client's local dataset description.
#[derive(Debug, Clone)]
pub struct ClientShard {
    pub client_id: usize,
    /// (class, instance) pairs; instances are globally unique per class.
    pub examples: Vec<(usize, u64)>,
}

impl ClientShard {
    pub fn len(&self) -> usize {
        self.examples.len()
    }
    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }

    /// Materialize the shard through a generator: returns flattened
    /// `(x, y)` with x of `n*input_len` and y of `n*label_len`.
    pub fn materialize<T: SynthTask>(&self, task: &T) -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::with_capacity(self.len() * task.input_len());
        let mut y = Vec::with_capacity(self.len() * task.label_len());
        for &(class, instance) in &self.examples {
            let (xi, yi) = task.gen(class, instance);
            x.extend_from_slice(&xi);
            y.extend_from_slice(&yi);
        }
        (x, y)
    }
}

/// IID: every client draws classes uniformly (instances unique).
pub fn iid_partition(
    seed: u64,
    n_clients: usize,
    per_client: usize,
    classes: usize,
) -> Vec<ClientShard> {
    let mut rng = Pcg64::new(seed, 0x11D);
    let mut next_instance = vec![0u64; classes];
    (0..n_clients)
        .map(|client_id| {
            let examples = (0..per_client)
                .map(|_| {
                    let c = rng.below_usize(classes);
                    let inst = next_instance[c];
                    next_instance[c] += 1;
                    (c, inst)
                })
                .collect();
            ClientShard {
                client_id,
                examples,
            }
        })
        .collect()
}

/// Non-IID shard split [25]: the virtual pool (balanced classes, sorted by
/// label) is cut into `2·n_clients` contiguous shards; each client gets two
/// random shards, hence sees at most two classes.
pub fn non_iid_partition(
    seed: u64,
    n_clients: usize,
    per_client: usize,
    classes: usize,
) -> Vec<ClientShard> {
    let total = n_clients * per_client;
    let per_class = total / classes;
    // Virtual label-sorted pool.
    let pool: Vec<(usize, u64)> = (0..classes)
        .flat_map(|c| (0..per_class as u64).map(move |i| (c, i)))
        .collect();
    let n_shards = 2 * n_clients;
    let shard_size = pool.len() / n_shards;
    let mut shard_ids: Vec<usize> = (0..n_shards).collect();
    let mut rng = Pcg64::new(seed, 0x2071D);
    rng.shuffle(&mut shard_ids);
    (0..n_clients)
        .map(|client_id| {
            let mut examples = Vec::with_capacity(2 * shard_size);
            for k in 0..2 {
                let s = shard_ids[client_id * 2 + k];
                let start = s * shard_size;
                examples.extend_from_slice(&pool[start..start + shard_size]);
            }
            ClientShard {
                client_id,
                examples,
            }
        })
        .collect()
}

/// Balanced held-out evaluation set (instances offset far beyond any
/// training instance so train/test never overlap).
pub fn eval_set<T: SynthTask>(task: &T, n: usize) -> (Vec<f32>, Vec<i32>) {
    const EVAL_OFFSET: u64 = 1 << 40;
    let classes = task.classes();
    let mut x = Vec::with_capacity(n * task.input_len());
    let mut y = Vec::with_capacity(n * task.label_len());
    for i in 0..n {
        let c = i % classes;
        let (xi, yi) = task.gen(c, EVAL_OFFSET + (i / classes) as u64);
        x.extend_from_slice(&xi);
        y.extend_from_slice(&yi);
    }
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthMnist;
    use std::collections::HashSet;

    #[test]
    fn iid_covers_many_classes_per_client() {
        let parts = iid_partition(1, 20, 100, 10);
        assert_eq!(parts.len(), 20);
        for p in &parts {
            assert_eq!(p.len(), 100);
            let classes: HashSet<usize> = p.examples.iter().map(|e| e.0).collect();
            assert!(classes.len() >= 6, "client {} saw {classes:?}", p.client_id);
        }
    }

    #[test]
    fn iid_instances_unique() {
        let parts = iid_partition(2, 10, 50, 10);
        let mut seen = HashSet::new();
        for p in &parts {
            for &e in &p.examples {
                assert!(seen.insert(e), "duplicate example {e:?}");
            }
        }
    }

    #[test]
    fn non_iid_at_most_two_classes() {
        let parts = non_iid_partition(3, 100, 600, 10);
        assert_eq!(parts.len(), 100);
        let mut class_counts = vec![0usize; 10];
        for p in &parts {
            assert_eq!(p.len(), 600);
            let classes: HashSet<usize> = p.examples.iter().map(|e| e.0).collect();
            assert!(
                classes.len() <= 2,
                "client {} saw {} classes",
                p.client_id,
                classes.len()
            );
            for c in classes {
                class_counts[c] += 1;
            }
        }
        // All classes represented across the federation.
        assert!(class_counts.iter().all(|&c| c > 0));
    }

    #[test]
    fn non_iid_shards_disjoint() {
        let parts = non_iid_partition(4, 10, 60, 10);
        let mut seen = HashSet::new();
        for p in &parts {
            for &e in &p.examples {
                assert!(seen.insert(e), "duplicate {e:?}");
            }
        }
        assert_eq!(seen.len(), 600);
    }

    #[test]
    fn partitions_deterministic_in_seed() {
        let a = non_iid_partition(5, 10, 20, 10);
        let b = non_iid_partition(5, 10, 20, 10);
        assert_eq!(a[3].examples, b[3].examples);
        let c = non_iid_partition(6, 10, 20, 10);
        assert_ne!(a[3].examples, c[3].examples);
    }

    #[test]
    fn materialize_and_eval_shapes() {
        let task = SynthMnist::new(1);
        let parts = iid_partition(1, 2, 5, 10);
        let (x, y) = parts[0].materialize(&task);
        assert_eq!(x.len(), 5 * 784);
        assert_eq!(y.len(), 5);
        let (ex, ey) = eval_set(&task, 30);
        assert_eq!(ex.len(), 30 * 784);
        assert_eq!(ey.len(), 30);
        // Balanced.
        let count0 = ey.iter().filter(|&&c| c == 0).count();
        assert_eq!(count0, 3);
    }
}
