//! Virtual time and the deterministic event queue — the simulator's
//! engine room.
//!
//! Time is integer microseconds ([`Ticks`]), never floating-point, so a
//! whole simulated federation is *tick-identical* across runs and
//! platforms: equal seeds produce equal timelines down to the last bit.
//! Ties in the event queue are broken by insertion order (a monotone
//! sequence number), which keeps pop order total and reproducible even
//! when two transfers finish on the same tick.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// Simulated time in microseconds.
pub type Ticks = u64;

/// Ticks per simulated second.
pub const TICKS_PER_SEC: u64 = 1_000_000;

/// Simulated seconds for a tick count.
pub fn secs(t: Ticks) -> f64 {
    t as f64 / TICKS_PER_SEC as f64
}

/// Ticks to move `bytes` over a `bits_per_sec` link (ceiling division:
/// any nonzero transfer costs at least one tick, so causality never
/// collapses to zero-time).
pub fn transfer_ticks(bytes: u64, bits_per_sec: u64) -> Ticks {
    assert!(bits_per_sec > 0, "transfer over a 0 bps link");
    if bytes == 0 {
        return 0;
    }
    let num = bytes as u128 * 8 * TICKS_PER_SEC as u128;
    num.div_ceil(bits_per_sec as u128) as Ticks
}

/// Ticks to process `examples` at `examples_per_sec` device throughput.
pub fn compute_ticks(examples: u64, examples_per_sec: f64) -> Ticks {
    assert!(
        examples_per_sec > 0.0,
        "compute on a 0 examples/s device"
    );
    if examples == 0 {
        return 0;
    }
    let t = examples as f64 / examples_per_sec * TICKS_PER_SEC as f64;
    t.ceil() as Ticks // saturating f64→u64 cast
}

/// One scheduled entry. Ordering is `(time, seq)` only — the payload
/// never participates, so `E` needs no `Ord`.
struct Scheduled<E> {
    at: Ticks,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        self.at.cmp(&other.at).then(self.seq.cmp(&other.seq))
    }
}

/// Min-heap of events keyed by virtual time, FIFO within a tick.
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Scheduled<E>>>,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Schedule `event` at absolute time `at`.
    pub fn push(&mut self, at: Ticks, event: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Scheduled { at, seq, event }));
    }

    /// Pop the earliest event (FIFO among same-tick events).
    pub fn pop(&mut self) -> Option<(Ticks, E)> {
        self.heap.pop().map(|Reverse(s)| (s.at, s.event))
    }

    /// Time of the next event, if any.
    pub fn peek_time(&self) -> Option<Ticks> {
        self.heap.peek().map(|Reverse(s)| s.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drop all pending events (round closed; stragglers aborted).
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        assert_eq!(q.peek_time(), Some(10));
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn same_tick_is_fifo() {
        let mut q = EventQueue::new();
        q.push(5, 1);
        q.push(5, 2);
        q.push(5, 3);
        assert_eq!(q.pop(), Some((5, 1)));
        assert_eq!(q.pop(), Some((5, 2)));
        assert_eq!(q.pop(), Some((5, 3)));
    }

    #[test]
    fn clear_aborts_pending() {
        let mut q = EventQueue::new();
        q.push(1, ());
        q.push(2, ());
        assert_eq!(q.len(), 2);
        q.clear();
        assert!(q.is_empty());
    }

    #[test]
    fn transfer_ticks_is_exact_ceiling() {
        // 1 MiB over 8 Mbps = 2^20 * 8 bits / 8e6 bps = 1.048576 s.
        assert_eq!(transfer_ticks(1 << 20, 8_000_000), 1_048_576);
        // Any nonzero payload costs at least one tick.
        assert_eq!(transfer_ticks(1, u64::MAX / 16), 1);
        assert_eq!(transfer_ticks(0, 1), 0);
    }

    #[test]
    fn compute_ticks_scales_with_throughput() {
        assert_eq!(compute_ticks(1000, 1000.0), TICKS_PER_SEC);
        assert_eq!(compute_ticks(500, 1000.0), TICKS_PER_SEC / 2);
        assert_eq!(compute_ticks(0, 1.0), 0);
    }

    #[test]
    fn seconds_roundtrip() {
        assert!((secs(1_500_000) - 1.5).abs() < 1e-12);
    }
}
