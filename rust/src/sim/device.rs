//! Heterogeneous device fleet: bandwidth/compute tiers and the profiles
//! sampled from them.
//!
//! A [`DeviceTier`] is a *population* (e.g. "wifi·fast": 50 Mbps down,
//! 20 Mbps up, 4000 examples/s) with a sampling weight; a
//! [`DeviceProfile`] is one concrete device drawn from a tier, with
//! per-device multiplicative jitter so no two devices are exactly alike
//! unless jitter is zero. Sampling is a pure function of `(tiers, n,
//! jitter, rng)` — the same seed always yields the same fleet.

use crate::util::rng::Pcg64;

/// A device population with a sampling weight.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceTier {
    pub name: &'static str,
    /// Relative sampling weight (normalized over the tier list).
    pub weight: f64,
    /// Downlink (server → device) bandwidth in Mbit/s.
    pub down_mbps: f64,
    /// Uplink (device → server) bandwidth in Mbit/s.
    pub up_mbps: f64,
    /// Local-training throughput in examples/s.
    pub examples_per_sec: f64,
}

impl DeviceTier {
    pub fn new(
        name: &'static str,
        weight: f64,
        down_mbps: f64,
        up_mbps: f64,
        examples_per_sec: f64,
    ) -> DeviceTier {
        DeviceTier {
            name,
            weight,
            down_mbps,
            up_mbps,
            examples_per_sec,
        }
    }
}

/// One concrete device.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub id: usize,
    /// Name of the tier this device was drawn from.
    pub tier: &'static str,
    /// Downlink bandwidth in bits/s (≥ 1).
    pub down_bps: u64,
    /// Uplink bandwidth in bits/s (≥ 1).
    pub up_bps: u64,
    /// Local-training throughput in examples/s (> 0).
    pub examples_per_sec: f64,
}

/// Sample `n` device profiles from weighted `tiers`, each rate jittered
/// independently by a uniform factor in `[1−jitter, 1+jitter]`.
pub fn sample_fleet(
    tiers: &[DeviceTier],
    n: usize,
    jitter: f64,
    rng: &mut Pcg64,
) -> Vec<DeviceProfile> {
    assert!(!tiers.is_empty(), "sample_fleet: empty tier list");
    assert!((0.0..1.0).contains(&jitter), "jitter must be in [0, 1)");
    let total_w: f64 = tiers.iter().map(|t| t.weight).sum();
    assert!(total_w > 0.0, "sample_fleet: zero total tier weight");
    let mut fleet = Vec::with_capacity(n);
    for id in 0..n {
        // Weighted tier pick, then three independent jitter factors —
        // always four draws per device, so the stream stays aligned.
        let mut r = rng.f64() * total_w;
        let mut tier = &tiers[tiers.len() - 1];
        for t in tiers {
            if r < t.weight {
                tier = t;
                break;
            }
            r -= t.weight;
        }
        let jd = 1.0 + jitter * (2.0 * rng.f64() - 1.0);
        let ju = 1.0 + jitter * (2.0 * rng.f64() - 1.0);
        let jc = 1.0 + jitter * (2.0 * rng.f64() - 1.0);
        fleet.push(DeviceProfile {
            id,
            tier: tier.name,
            down_bps: ((tier.down_mbps * 1e6 * jd) as u64).max(1),
            up_bps: ((tier.up_mbps * 1e6 * ju) as u64).max(1),
            examples_per_sec: (tier.examples_per_sec * jc).max(1e-6),
        });
    }
    fleet
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_tiers() -> Vec<DeviceTier> {
        vec![
            DeviceTier::new("wifi", 3.0, 50.0, 20.0, 4000.0),
            DeviceTier::new("3g", 1.0, 2.0, 0.75, 500.0),
        ]
    }

    #[test]
    fn sampling_is_deterministic() {
        let a = sample_fleet(&two_tiers(), 100, 0.2, &mut Pcg64::new(7, 1));
        let b = sample_fleet(&two_tiers(), 100, 0.2, &mut Pcg64::new(7, 1));
        assert_eq!(a, b);
        let c = sample_fleet(&two_tiers(), 100, 0.2, &mut Pcg64::new(8, 1));
        assert_ne!(a, c);
    }

    #[test]
    fn weights_shape_the_mix() {
        let fleet = sample_fleet(&two_tiers(), 2000, 0.0, &mut Pcg64::new(1, 2));
        let wifi = fleet.iter().filter(|d| d.tier == "wifi").count();
        // Expect ~75% wifi; allow a generous band.
        assert!((1300..1700).contains(&wifi), "wifi count {wifi}");
    }

    #[test]
    fn zero_jitter_matches_tier_rates_exactly() {
        let tiers = vec![DeviceTier::new("only", 1.0, 10.0, 5.0, 100.0)];
        let fleet = sample_fleet(&tiers, 5, 0.0, &mut Pcg64::new(3, 3));
        for d in &fleet {
            assert_eq!(d.down_bps, 10_000_000);
            assert_eq!(d.up_bps, 5_000_000);
            assert!((d.examples_per_sec - 100.0).abs() < 1e-9);
        }
    }

    #[test]
    fn jitter_stays_in_band() {
        let tiers = vec![DeviceTier::new("only", 1.0, 10.0, 10.0, 100.0)];
        let fleet = sample_fleet(&tiers, 500, 0.25, &mut Pcg64::new(4, 4));
        for d in &fleet {
            assert!((7_500_000..=12_500_000).contains(&d.down_bps), "{}", d.down_bps);
            assert!((75.0..=125.0).contains(&d.examples_per_sec));
        }
    }
}
