//! Round-completion policies: what the server waits for before closing a
//! round.
//!
//! * [`RoundPolicy::Synchronous`] — pure FedAvg: the round ends when the
//!   *slowest* surviving participant reports. Simple, but one 3G straggler
//!   gates the whole fleet.
//! * [`RoundPolicy::OverSelect`] — deadline-style over-selection (the
//!   standard production mitigation): select `⌈K·over_sample⌉` clients,
//!   close the round as soon as the first `K` uploads land, and abort the
//!   stragglers mid-flight (their uploads are neither aggregated nor
//!   metered).

/// When does a round end?
#[derive(Debug, Clone, PartialEq)]
pub enum RoundPolicy {
    /// Wait for every surviving participant.
    Synchronous,
    /// Select `⌈K·over_sample⌉`, keep the first `K` reporters.
    OverSelect { over_sample: f64 },
}

impl RoundPolicy {
    /// How many clients to select so that `k` reporters are expected,
    /// clamped to the fleet size `n`.
    pub fn selection_count(&self, k: usize, n: usize) -> usize {
        match self {
            RoundPolicy::Synchronous => k.min(n),
            RoundPolicy::OverSelect { over_sample } => {
                ((k as f64 * over_sample).ceil() as usize).max(k).min(n)
            }
        }
    }

    /// How many reporters close the round, given `active` surviving
    /// participants.
    pub fn quota(&self, k: usize, active: usize) -> usize {
        match self {
            RoundPolicy::Synchronous => active,
            RoundPolicy::OverSelect { .. } => k.min(active),
        }
    }

    pub fn name(&self) -> String {
        match self {
            RoundPolicy::Synchronous => "sync".into(),
            RoundPolicy::OverSelect { over_sample } => {
                format!("overselect x{over_sample:.2}")
            }
        }
    }

    /// Parse the CLI grammar (`--policy sync|overselect`, with the
    /// over-sampling factor supplied separately by `--over`).
    pub fn parse(name: &str, over_sample: f64) -> anyhow::Result<RoundPolicy> {
        match name {
            "sync" | "synchronous" => Ok(RoundPolicy::Synchronous),
            "overselect" | "deadline" => Ok(RoundPolicy::OverSelect { over_sample }),
            other => anyhow::bail!("unknown policy '{other}' (sync, overselect)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synchronous_selects_exactly_k_and_waits_for_all() {
        let p = RoundPolicy::Synchronous;
        assert_eq!(p.selection_count(10, 100), 10);
        assert_eq!(p.selection_count(10, 5), 5);
        assert_eq!(p.quota(10, 7), 7); // dropouts thinned the round
    }

    #[test]
    fn overselect_rounds_up_and_caps_at_fleet() {
        let p = RoundPolicy::OverSelect { over_sample: 1.3 };
        assert_eq!(p.selection_count(10, 100), 13);
        assert_eq!(p.selection_count(3, 100), 4); // ceil(3.9)
        assert_eq!(p.selection_count(10, 11), 11);
        assert_eq!(p.quota(10, 13), 10);
        assert_eq!(p.quota(10, 6), 6); // never wait for more than survive
    }

    #[test]
    fn overselect_below_one_never_underselects() {
        let p = RoundPolicy::OverSelect { over_sample: 0.5 };
        assert_eq!(p.selection_count(10, 100), 10);
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(RoundPolicy::Synchronous.name(), "sync");
        assert_eq!(
            RoundPolicy::OverSelect { over_sample: 1.3 }.name(),
            "overselect x1.30"
        );
    }

    #[test]
    fn parse_roundtrips_the_cli_grammar() {
        assert_eq!(RoundPolicy::parse("sync", 1.3).unwrap(), RoundPolicy::Synchronous);
        assert_eq!(
            RoundPolicy::parse("overselect", 1.5).unwrap(),
            RoundPolicy::OverSelect { over_sample: 1.5 }
        );
        assert_eq!(
            RoundPolicy::parse("deadline", 2.0).unwrap(),
            RoundPolicy::OverSelect { over_sample: 2.0 }
        );
        assert!(RoundPolicy::parse("bogus", 1.0).is_err());
    }
}
