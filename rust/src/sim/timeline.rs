//! The simulator's output stream: one [`TimelineRecord`] per round
//! (simulated seconds per phase, straggler/dropout counts) and the
//! [`Timeline`] aggregate with the headline number — **time to target
//! metric** — that turns compression ratios into wall-clock speedups.
//!
//! In buffered-async runs a "round" is one aggregation window (the span
//! between two model applications) and `stragglers_dropped` counts the
//! delivered updates the server discarded as stale — the async analogue
//! of an aborted straggler upload.

use crate::fl::metrics::History;
use crate::util::json::Json;

use super::clock::{secs, Ticks};

/// One simulated round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TimelineRecord {
    /// 1-based round index (matches [`crate::fl::RoundRecord::round`]).
    pub round: usize,
    /// Virtual time when the round opened.
    pub start: Ticks,
    /// Virtual time when the round closed (the quota-th upload landed).
    pub end: Ticks,
    /// Phase breakdown of the *critical-path* reporter — the device whose
    /// upload closed the round.
    pub broadcast_ticks: Ticks,
    pub compute_ticks: Ticks,
    pub upload_ticks: Ticks,
    /// Clients selected this round (after policy over-selection).
    pub selected: usize,
    /// Selected but unreachable when the round opened.
    pub offline: usize,
    /// Started the round but failed mid-round; never reported.
    pub dropouts: usize,
    /// Uploads that were aggregated.
    pub reporters: usize,
    /// Survivors whose uploads were aborted when the quota filled.
    pub stragglers_dropped: usize,
}

impl TimelineRecord {
    /// Round duration in ticks.
    pub fn duration(&self) -> Ticks {
        self.end - self.start
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("round", self.round)
            .set("start_secs", secs(self.start))
            .set("end_secs", secs(self.end))
            .set("broadcast_secs", secs(self.broadcast_ticks))
            .set("compute_secs", secs(self.compute_ticks))
            .set("upload_secs", secs(self.upload_ticks))
            .set("selected", self.selected)
            .set("offline", self.offline)
            .set("dropouts", self.dropouts)
            .set("reporters", self.reporters)
            .set("stragglers_dropped", self.stragglers_dropped)
    }
}

/// The full simulated run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Timeline {
    pub records: Vec<TimelineRecord>,
}

impl Timeline {
    pub fn push(&mut self, r: TimelineRecord) {
        self.records.push(r);
    }

    /// Total simulated time (virtual clock at the end of the last round).
    pub fn total_ticks(&self) -> Ticks {
        self.records.last().map_or(0, |r| r.end)
    }

    pub fn total_secs(&self) -> f64 {
        secs(self.total_ticks())
    }

    /// Mean round (or async aggregation-window) duration in seconds —
    /// the cadence columns (`sync/rnd`, `async/rnd`) of the
    /// `repro sim --quick` protocol table.
    pub fn mean_round_secs(&self) -> f64 {
        if self.records.is_empty() {
            0.0
        } else {
            self.total_secs() / self.records.len() as f64
        }
    }

    /// Total stragglers aborted across the run.
    pub fn stragglers_dropped(&self) -> usize {
        self.records.iter().map(|r| r.stragglers_dropped).sum()
    }

    /// Total mid-round dropouts across the run.
    pub fn dropouts(&self) -> usize {
        self.records.iter().map(|r| r.dropouts).sum()
    }

    /// Total devices that were selected but offline across the run.
    pub fn offline(&self) -> usize {
        self.records.iter().map(|r| r.offline).sum()
    }

    /// Simulated seconds until the run first reaches `target` on the eval
    /// metric: the virtual-clock time at the end of the first round whose
    /// [`History`] record evaluates at `≥ target`. `None` if the target is
    /// never reached (or never evaluated).
    pub fn time_to_metric(&self, history: &History, target: f64) -> Option<f64> {
        let round = history
            .records
            .iter()
            .find(|r| r.eval_metric.is_some_and(|m| m >= target))?
            .round;
        let rec = self.records.iter().find(|t| t.round == round)?;
        Some(secs(rec.end))
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("total_secs", self.total_secs())
            .set("stragglers_dropped", self.stragglers_dropped())
            .set("dropouts", self.dropouts())
            .set("offline", self.offline())
            .set(
                "records",
                Json::Arr(self.records.iter().map(TimelineRecord::to_json).collect()),
            )
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "{} rounds in {} simulated ({} stragglers dropped, {} dropouts, {} offline)",
            self.records.len(),
            fmt_sim_secs(self.total_secs()),
            self.stragglers_dropped(),
            self.dropouts(),
            self.offline(),
        )
    }
}

/// Human form of a simulated duration: `"42.1s"`, `"12.3m"`, `"2.1h"`.
pub fn fmt_sim_secs(s: f64) -> String {
    if s >= 3600.0 {
        format!("{:.1}h", s / 3600.0)
    } else if s >= 60.0 {
        format!("{:.1}m", s / 60.0)
    } else {
        format!("{s:.1}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::metrics::RoundRecord;

    fn tl_rec(round: usize, start: Ticks, end: Ticks) -> TimelineRecord {
        TimelineRecord {
            round,
            start,
            end,
            broadcast_ticks: 0,
            compute_ticks: 0,
            upload_ticks: 0,
            selected: 10,
            offline: 0,
            dropouts: 0,
            reporters: 10,
            stragglers_dropped: 0,
        }
    }

    fn hist_rec(round: usize, metric: Option<f64>) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 0.5,
            eval_metric: metric,
            eval_loss: None,
            uplink_bytes: 100,
            downlink_bytes: 400,
            clients: 10,
            stale_updates: 0,
            dup_updates: 0,
            malformed_updates: 0,
            bits: Vec::new(),
            deflate_level: None,
        }
    }

    #[test]
    fn time_to_metric_finds_first_crossing() {
        let mut tl = Timeline::default();
        tl.push(tl_rec(1, 0, 10_000_000));
        tl.push(tl_rec(2, 10_000_000, 20_000_000));
        tl.push(tl_rec(3, 20_000_000, 30_000_000));
        let mut h = History::new("s");
        h.push(hist_rec(1, None));
        h.push(hist_rec(2, Some(0.5)));
        h.push(hist_rec(3, Some(0.9)));
        assert_eq!(tl.time_to_metric(&h, 0.4), Some(20.0));
        assert_eq!(tl.time_to_metric(&h, 0.8), Some(30.0));
        assert_eq!(tl.time_to_metric(&h, 0.99), None);
        assert!((tl.total_secs() - 30.0).abs() < 1e-12);
        assert!((tl.mean_round_secs() - 10.0).abs() < 1e-12);
        assert_eq!(Timeline::default().mean_round_secs(), 0.0);
    }

    #[test]
    fn totals_aggregate_over_rounds() {
        let mut tl = Timeline::default();
        let mut a = tl_rec(1, 0, 5);
        a.stragglers_dropped = 2;
        a.dropouts = 1;
        let mut b = tl_rec(2, 5, 9);
        b.stragglers_dropped = 1;
        b.offline = 3;
        tl.push(a);
        tl.push(b);
        assert_eq!(tl.stragglers_dropped(), 3);
        assert_eq!(tl.dropouts(), 1);
        assert_eq!(tl.offline(), 3);
        assert_eq!(tl.total_ticks(), 9);
    }

    #[test]
    fn json_shape() {
        let mut tl = Timeline::default();
        tl.push(tl_rec(1, 0, 2_000_000));
        let j = tl.to_json();
        assert_eq!(j.get("total_secs").unwrap().as_f64(), Some(2.0));
        let recs = j.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs[0].get("round").unwrap().as_usize(), Some(1));
        assert_eq!(recs[0].get("end_secs").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_sim_secs(42.13), "42.1s");
        assert_eq!(fmt_sim_secs(125.0), "2.1m");
        assert_eq!(fmt_sim_secs(7560.0), "2.1h");
    }
}
