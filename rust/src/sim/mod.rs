//! Discrete-event federated *systems* simulator: a virtual clock over a
//! heterogeneous device fleet.
//!
//! The byte ledger ([`crate::fl::NetworkLedger`]) tells you a 40× ratio;
//! this module tells you what that ratio is *worth*: it replays each
//! FedAvg round as timed events over devices with real bandwidths and
//! compute throughputs, so float32 and cosine-k-bit runs compare in
//! **simulated seconds to target accuracy**, not just bytes.
//!
//! ```text
//!            one round, per participating device
//!   ────────────────────────────────────────────────────────▶ virtual time
//!   │ broadcast          │ local training       │ upload        │
//!   │ frame bytes        │ examples             │ frame bytes   │
//!   │ ───────────        │ ────────────────     │ ───────────   │
//!   │ device ↓ bandwidth │ device throughput    │ device ↑ bw   │
//!   └────────────────────┴──────────────────────┴───────────────┘
//!                                                 ▲
//!        RoundPolicy closes the round here ───────┘
//!        (slowest reporter, or the K-th when over-selecting)
//! ```
//!
//! Everything is deterministic: integer-tick time ([`clock`]), seeded
//! fleet sampling ([`device`]), seeded availability/dropout lanes, and a
//! FIFO-tie-broken event queue — same seed + config ⇒ tick-identical
//! [`Timeline`].
//!
//! | file | contents |
//! |------|----------|
//! | [`clock`] | `Ticks`, transfer/compute time math, deterministic `EventQueue` |
//! | [`device`] | `DeviceTier` populations → sampled `DeviceProfile` fleet |
//! | [`policy`] | `RoundPolicy`: synchronous vs. deadline over-selection |
//! | [`timeline`] | `TimelineRecord` stream, time-to-target-metric |
//! | this file | `SimConfig` presets + the [`FleetSim`] round engine |

pub mod clock;
pub mod device;
pub mod policy;
pub mod timeline;

pub use clock::{compute_ticks, secs, transfer_ticks, EventQueue, Ticks};
pub use device::{sample_fleet, DeviceProfile, DeviceTier};
pub use policy::RoundPolicy;
pub use timeline::{fmt_sim_secs, Timeline, TimelineRecord};

use crate::util::rng::Pcg64;

/// Fleet + policy description: everything the simulator needs besides the
/// per-round transfer sizes the runner threads through.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Device populations to sample the fleet from.
    pub tiers: Vec<DeviceTier>,
    /// Round-completion policy.
    pub policy: RoundPolicy,
    /// P(selected device is reachable when the round opens).
    pub availability: f64,
    /// P(participating device fails mid-round and never reports).
    pub dropout: f64,
    /// ± fractional jitter applied to every sampled device rate.
    pub jitter: f64,
}

impl SimConfig {
    /// Homogeneous always-on wifi fleet — isolates protocol timing from
    /// heterogeneity (every device identical, nobody offline).
    pub fn uniform() -> SimConfig {
        SimConfig {
            tiers: vec![DeviceTier::new("wifi·fast", 1.0, 50.0, 20.0, 4000.0)],
            policy: RoundPolicy::Synchronous,
            availability: 1.0,
            dropout: 0.0,
            jitter: 0.0,
        }
    }

    /// The deployment regime that motivates low-bit quantization:
    /// wifi/4g/3g × fast/slow compute, 90% availability, 3% mid-round
    /// dropout, ±20% per-device jitter.
    pub fn heterogeneous() -> SimConfig {
        SimConfig {
            tiers: vec![
                DeviceTier::new("wifi·fast", 0.25, 50.0, 20.0, 4000.0),
                DeviceTier::new("wifi·slow", 0.15, 50.0, 20.0, 500.0),
                DeviceTier::new("4g·fast", 0.20, 20.0, 8.0, 4000.0),
                DeviceTier::new("4g·slow", 0.20, 20.0, 8.0, 500.0),
                DeviceTier::new("3g·fast", 0.10, 2.0, 0.75, 4000.0),
                DeviceTier::new("3g·slow", 0.10, 2.0, 0.75, 500.0),
            ],
            policy: RoundPolicy::Synchronous,
            availability: 0.9,
            dropout: 0.03,
            jitter: 0.2,
        }
    }

    /// Bandwidth-bound 3G-only fleet: transfer time dominates, so
    /// compression ratios translate almost 1:1 into round-time speedups.
    pub fn cellular() -> SimConfig {
        SimConfig {
            tiers: vec![
                DeviceTier::new("3g·fast", 0.5, 2.0, 0.75, 4000.0),
                DeviceTier::new("3g·slow", 0.5, 2.0, 0.75, 500.0),
            ],
            policy: RoundPolicy::Synchronous,
            availability: 0.95,
            dropout: 0.02,
            jitter: 0.2,
        }
    }

    pub fn with_policy(mut self, policy: RoundPolicy) -> SimConfig {
        self.policy = policy;
        self
    }

    /// Compact label for tables / results files.
    pub fn name(&self) -> String {
        format!(
            "{} tiers · {} · avail {:.2} · drop {:.2}",
            self.tiers.len(),
            self.policy.name(),
            self.availability,
            self.dropout
        )
    }
}

/// The per-dispatch availability/dropout lottery verdict (buffered-async
/// rounds, where clients are admitted one at a time instead of per-round).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Online and will survive to upload: worth training.
    Admitted,
    /// Unreachable right now; never starts.
    Offline,
    /// Would start but fail mid-flight and never report: not worth
    /// training (mirrors [`FleetSim::begin_round`]'s pre-thinning).
    Dropout,
}

/// What the availability/dropout lottery decided for one round.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    /// Devices that will actually train this round, in selection order.
    pub active: Vec<usize>,
    /// How many were selected in total.
    pub selected: usize,
    /// Selected but unreachable when the round opened.
    pub offline: usize,
    /// Will start training but fail mid-round (never report).
    pub dropouts: usize,
}

impl RoundPlan {
    /// A plan with everyone participating (the no-simulator path).
    pub fn full(active: Vec<usize>) -> RoundPlan {
        RoundPlan {
            selected: active.len(),
            active,
            offline: 0,
            dropouts: 0,
        }
    }
}

/// One participant's measured round inputs: who it is and what it moves.
#[derive(Debug, Clone)]
pub struct ClientLoad {
    /// Device index into the fleet.
    pub device: usize,
    /// Real serialized uplink frame size for this client's update.
    pub upload_bytes: usize,
    /// Examples processed locally this round.
    pub examples: u64,
}

/// What the event replay decided.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    /// Devices whose uploads were aggregated, in arrival order.
    pub kept: Vec<usize>,
    /// Round duration in ticks.
    pub duration: Ticks,
    /// Survivors aborted when the quota filled.
    pub stragglers_dropped: usize,
}

/// The fleet-level simulator: devices, virtual clock, and the per-round
/// discrete-event replay.
pub struct FleetSim {
    pub devices: Vec<DeviceProfile>,
    policy: RoundPolicy,
    availability: f64,
    dropout: f64,
    /// Availability/dropout lane — separate from fleet sampling so adding
    /// rounds never reshuffles the fleet.
    rng: Pcg64,
    clock: Ticks,
    timeline: Timeline,
    /// In-flight asynchronous uploads: `(token, phase breakdown)` keyed by
    /// arrival tick (buffered-async rounds; empty in round-batch use).
    flights: EventQueue<(u64, (Ticks, Ticks, Ticks))>,
    /// Virtual time when the open async aggregation window began.
    window_start: Ticks,
    /// Phase breakdown of the most recent async arrival (the critical
    /// path of the window it closes).
    last_phases: (Ticks, Ticks, Ticks),
}

/// Per-participant lifecycle events (index into the round's load list).
enum Ev {
    BroadcastDone(usize),
    TrainDone(usize),
    UploadDone(usize),
}

impl FleetSim {
    /// Sample an `n_devices` fleet and zero the clock. Two seed lanes:
    /// `0xF1EE7` for fleet sampling, `0xD1CE` for per-round lotteries.
    pub fn new(cfg: &SimConfig, n_devices: usize, seed: u64) -> FleetSim {
        let devices = sample_fleet(
            &cfg.tiers,
            n_devices,
            cfg.jitter,
            &mut Pcg64::new(seed, 0xF1EE7),
        );
        FleetSim {
            devices,
            policy: cfg.policy.clone(),
            availability: cfg.availability,
            dropout: cfg.dropout,
            rng: Pcg64::new(seed, 0xD1CE),
            clock: 0,
            timeline: Timeline::default(),
            flights: EventQueue::new(),
            window_start: 0,
            last_phases: (0, 0, 0),
        }
    }

    /// Current virtual time.
    pub fn clock(&self) -> Ticks {
        self.clock
    }

    /// Policy-adjusted selection size targeting `k` reporters.
    pub fn selection_count(&self, k: usize) -> usize {
        self.policy.selection_count(k, self.devices.len())
    }

    /// Open a round: roll availability and mid-round dropout for each
    /// candidate (two Bernoulli draws per candidate, in candidate order,
    /// so the lottery stream is reproducible). Only `active` devices are
    /// worth training — offline devices never start, dropouts would never
    /// report.
    pub fn begin_round(&mut self, candidates: &[usize]) -> RoundPlan {
        let mut plan = RoundPlan {
            active: Vec::with_capacity(candidates.len()),
            selected: candidates.len(),
            offline: 0,
            dropouts: 0,
        };
        for &c in candidates {
            debug_assert!(c < self.devices.len(), "device {c} outside fleet");
            let online = self.rng.bernoulli(self.availability);
            let fails = self.rng.bernoulli(self.dropout);
            if !online {
                plan.offline += 1;
            } else if fails {
                plan.dropouts += 1;
            } else {
                plan.active.push(c);
            }
        }
        plan
    }

    /// Replay one round's events: per participant, broadcast transfer →
    /// local training → upload transfer, each timed by that device's
    /// profile. The policy's quota closes the round; pending uploads are
    /// aborted as stragglers. Advances the virtual clock and appends a
    /// [`TimelineRecord`].
    pub fn complete_round(
        &mut self,
        round: usize,
        plan: &RoundPlan,
        k_target: usize,
        broadcast_bytes: usize,
        loads: &[ClientLoad],
    ) -> RoundOutcome {
        let start = self.clock;
        let quota = self.policy.quota(k_target, loads.len());
        let mut q = EventQueue::new();
        let mut phases: Vec<(Ticks, Ticks, Ticks)> = Vec::with_capacity(loads.len());
        for (i, load) in loads.iter().enumerate() {
            let d = &self.devices[load.device];
            let b = transfer_ticks(broadcast_bytes as u64, d.down_bps);
            let c = compute_ticks(load.examples, d.examples_per_sec);
            let u = transfer_ticks(load.upload_bytes as u64, d.up_bps);
            phases.push((b, c, u));
            q.push(start + b, Ev::BroadcastDone(i));
        }

        let mut kept: Vec<usize> = Vec::with_capacity(quota);
        let mut end = start;
        let mut critical: Option<usize> = None;
        while let Some((t, ev)) = q.pop() {
            match ev {
                Ev::BroadcastDone(i) => q.push(t + phases[i].1, Ev::TrainDone(i)),
                Ev::TrainDone(i) => q.push(t + phases[i].2, Ev::UploadDone(i)),
                Ev::UploadDone(i) => {
                    kept.push(i);
                    end = t;
                    critical = Some(i);
                    if kept.len() >= quota {
                        // Quota filled: the round closes NOW; everything
                        // still in flight is a straggler, aborted.
                        q.clear();
                    }
                }
            }
        }

        let stragglers_dropped = loads.len() - kept.len();
        let (bt, ct, ut) = critical.map_or((0, 0, 0), |i| phases[i]);
        self.clock = end;
        self.timeline.push(TimelineRecord {
            round,
            start,
            end,
            broadcast_ticks: bt,
            compute_ticks: ct,
            upload_ticks: ut,
            selected: plan.selected,
            offline: plan.offline,
            dropouts: plan.dropouts,
            reporters: kept.len(),
            stragglers_dropped,
        });
        RoundOutcome {
            kept: kept.into_iter().map(|i| loads[i].device).collect(),
            duration: end - start,
            stragglers_dropped,
        }
    }

    /// Per-dispatch lottery for buffered-async rounds: the same two
    /// Bernoulli draws per candidate — in call order — as
    /// [`FleetSim::begin_round`], so the lottery stream stays reproducible
    /// across modes.
    pub fn admit(&mut self, device: usize) -> Admission {
        debug_assert!(device < self.devices.len(), "device {device} outside fleet");
        let online = self.rng.bernoulli(self.availability);
        let fails = self.rng.bernoulli(self.dropout);
        if !online {
            Admission::Offline
        } else if fails {
            Admission::Dropout
        } else {
            Admission::Admitted
        }
    }

    /// Launch one asynchronous flight *now*: broadcast transfer → local
    /// training → upload transfer on `device`, timed from the current
    /// virtual instant. The arrival is queued under `token` (the caller's
    /// handle for the in-flight payload); returns the arrival tick.
    pub fn launch(
        &mut self,
        token: u64,
        device: usize,
        broadcast_bytes: usize,
        upload_bytes: usize,
        examples: u64,
    ) -> Ticks {
        let d = &self.devices[device];
        let b = transfer_ticks(broadcast_bytes as u64, d.down_bps);
        let c = compute_ticks(examples, d.examples_per_sec);
        let u = transfer_ticks(upload_bytes as u64, d.up_bps);
        let at = self.clock + b + c + u;
        self.flights.push(at, (token, (b, c, u)));
        at
    }

    /// Pop the earliest in-flight arrival, advancing the virtual clock to
    /// it. `None` when nothing is in flight. The clock is monotone: every
    /// launch lands at or after the instant it started.
    pub fn arrive(&mut self) -> Option<(Ticks, u64)> {
        let (t, (token, phases)) = self.flights.pop()?;
        self.clock = t;
        self.last_phases = phases;
        Some((t, token))
    }

    /// Close one buffered-async aggregation window: appends a
    /// [`TimelineRecord`] spanning the window, with the *triggering*
    /// arrival's phase breakdown as the critical path. In async runs
    /// `stragglers_dropped` counts updates discarded as stale — the async
    /// analogue of an aborted straggler upload.
    pub fn close_async_round(
        &mut self,
        round: usize,
        selected: usize,
        offline: usize,
        dropouts: usize,
        reporters: usize,
        stale_dropped: usize,
    ) {
        let (bt, ct, ut) = self.last_phases;
        self.timeline.push(TimelineRecord {
            round,
            start: self.window_start,
            end: self.clock,
            broadcast_ticks: bt,
            compute_ticks: ct,
            upload_ticks: ut,
            selected,
            offline,
            dropouts,
            reporters,
            stragglers_dropped: stale_dropped,
        });
        self.window_start = self.clock;
    }

    /// The timeline so far.
    pub fn timeline(&self) -> &Timeline {
        &self.timeline
    }

    /// Consume the simulator, yielding its timeline.
    pub fn into_timeline(self) -> Timeline {
        self.timeline
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(devices: &[usize], upload_bytes: usize, examples: u64) -> Vec<ClientLoad> {
        devices
            .iter()
            .map(|&device| ClientLoad {
                device,
                upload_bytes,
                examples,
            })
            .collect()
    }

    #[test]
    fn uniform_round_matches_closed_form() {
        // jitter 0 → every device is exactly the tier: 50 Mbps down,
        // 20 Mbps up, 4000 ex/s.
        let mut sim = FleetSim::new(&SimConfig::uniform(), 10, 1);
        let plan = sim.begin_round(&[0, 1, 2]);
        assert_eq!(plan.active, vec![0, 1, 2]);
        let ls = loads(&plan.active, 100_000, 2000);
        let out = sim.complete_round(1, &plan, 3, 400_000, &ls);
        let expect = transfer_ticks(400_000, 50_000_000)
            + compute_ticks(2000, 4000.0)
            + transfer_ticks(100_000, 20_000_000);
        assert_eq!(out.duration, expect);
        assert_eq!(out.kept, vec![0, 1, 2]); // identical devices: FIFO ties
        assert_eq!(out.stragglers_dropped, 0);
        let rec = &sim.timeline().records[0];
        assert_eq!(rec.duration(), expect);
        assert_eq!(
            rec.broadcast_ticks + rec.compute_ticks + rec.upload_ticks,
            expect
        );
    }

    #[test]
    fn clock_accumulates_across_rounds() {
        let mut sim = FleetSim::new(&SimConfig::uniform(), 4, 2);
        for round in 1..=3 {
            let plan = sim.begin_round(&[0, 1]);
            let ls = loads(&plan.active, 10_000, 100);
            sim.complete_round(round, &plan, 2, 10_000, &ls);
        }
        let tl = sim.timeline();
        assert_eq!(tl.records.len(), 3);
        assert_eq!(tl.records[1].start, tl.records[0].end);
        assert_eq!(tl.total_ticks(), tl.records[2].end);
        assert_eq!(tl.total_ticks(), 3 * tl.records[0].duration());
    }

    #[test]
    fn overselect_keeps_first_k_and_aborts_stragglers() {
        let cfg = SimConfig::uniform().with_policy(RoundPolicy::OverSelect {
            over_sample: 2.0,
        });
        let mut sim = FleetSim::new(&cfg, 20, 3);
        assert_eq!(sim.selection_count(5), 10);
        let candidates: Vec<usize> = (0..10).collect();
        let plan = sim.begin_round(&candidates);
        assert_eq!(plan.active.len(), 10); // uniform: everyone online
        // Heavier uploads finish later on identical devices.
        let ls: Vec<ClientLoad> = plan
            .active
            .iter()
            .map(|&device| ClientLoad {
                device,
                upload_bytes: (device + 1) * 10_000,
                examples: 100,
            })
            .collect();
        let out = sim.complete_round(1, &plan, 5, 1_000, &ls);
        assert_eq!(out.kept, vec![0, 1, 2, 3, 4]);
        assert_eq!(out.stragglers_dropped, 5);
        let rec = &sim.timeline().records[0];
        assert_eq!(rec.reporters, 5);
        assert_eq!(rec.stragglers_dropped, 5);
        // The critical path is the 5th reporter, not the slowest device.
        assert_eq!(rec.upload_ticks, transfer_ticks(5 * 10_000, 20_000_000));
    }

    #[test]
    fn synchronous_waits_for_the_slowest() {
        let mut sim = FleetSim::new(&SimConfig::uniform(), 4, 4);
        let plan = sim.begin_round(&[0, 1]);
        let ls = vec![
            ClientLoad { device: 0, upload_bytes: 1_000, examples: 100 },
            ClientLoad { device: 1, upload_bytes: 1_000_000, examples: 100 },
        ];
        let out = sim.complete_round(1, &plan, 2, 1_000, &ls);
        assert_eq!(out.kept, vec![0, 1]);
        let slow = transfer_ticks(1_000, 50_000_000)
            + compute_ticks(100, 4000.0)
            + transfer_ticks(1_000_000, 20_000_000);
        assert_eq!(out.duration, slow);
    }

    #[test]
    fn lottery_partitions_the_selection() {
        let mut cfg = SimConfig::uniform();
        cfg.availability = 0.5;
        cfg.dropout = 0.2;
        let mut sim = FleetSim::new(&cfg, 500, 5);
        let candidates: Vec<usize> = (0..500).collect();
        let plan = sim.begin_round(&candidates);
        assert_eq!(
            plan.active.len() + plan.offline + plan.dropouts,
            plan.selected
        );
        assert!(plan.offline > 150, "offline {}", plan.offline);
        assert!(plan.dropouts > 20, "dropouts {}", plan.dropouts);
        assert!(!plan.active.is_empty());
    }

    #[test]
    fn empty_round_is_instant() {
        let mut sim = FleetSim::new(&SimConfig::uniform(), 2, 6);
        let plan = RoundPlan::full(vec![]);
        let out = sim.complete_round(1, &plan, 1, 1_000, &[]);
        assert_eq!(out.duration, 0);
        assert!(out.kept.is_empty());
        assert_eq!(sim.clock(), 0);
    }

    #[test]
    fn preset_names() {
        assert!(SimConfig::heterogeneous().name().contains("6 tiers"));
        assert!(SimConfig::uniform().name().contains("sync"));
        assert!(SimConfig::cellular()
            .with_policy(RoundPolicy::OverSelect { over_sample: 1.5 })
            .name()
            .contains("overselect"));
    }
}
