//! `repro` — the CosSGD reproduction launcher.
//!
//! ```text
//! repro figure <id>|all [--rounds N] [--scale full] [--seed S] [--quiet]
//! repro train --task mnist|mnist-iid|cifar|unet --codec <name> [--bits B]
//!             [--keep F] [--rounds N] [--kernel] [--seed S]
//! repro compress-stats [--n N]      # codec table, no artifacts needed
//! repro check                       # load + compile all artifacts
//! repro list                        # figure ids and codec names
//! ```

use anyhow::{bail, Result};

use cossgd::compress::cosine::{BoundMode, Rounding};
use cossgd::compress::{Codec, CodecKind};
use cossgd::figures::{self, FigOpts};
use cossgd::fl::{self, FlConfig, Task};
use cossgd::runtime::Engine;
use cossgd::util::cli::Args;
use cossgd::util::rng::Pcg64;
use cossgd::util::timer::{fmt_bytes, Stopwatch};

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("figure") => cmd_figure(args),
        Some("train") => cmd_train(args),
        Some("compress-stats") => cmd_compress_stats(args),
        Some("check") => cmd_check(),
        Some("list") | None => cmd_list(),
        Some(other) => bail!("unknown subcommand '{other}' (try `repro list`)"),
    }
}

fn cmd_list() -> Result<()> {
    println!("subcommands: figure, train, compress-stats, check, list");
    println!("figures: {}", figures::ALL.join(", "));
    println!("tasks:   mnist (non-iid), mnist-iid, cifar, unet");
    println!(
        "codecs:  float32, cosine, linear, linear-rotated, signsgd, signsgd-norm, ef-signsgd"
    );
    println!("options: --bits 1..8, --keep 0.05..1.0, --unbiased, --clip P, --no-deflate");
    Ok(())
}

fn cmd_check() -> Result<()> {
    let sw = Stopwatch::start();
    let engine = Engine::load_default()?;
    let names: Vec<String> = engine.manifest.artifacts.keys().cloned().collect();
    println!(
        "manifest: {} artifacts, {} models",
        names.len(),
        engine.manifest.models.len()
    );
    for n in &names {
        engine.warmup(&[n.as_str()])?;
        println!("  compiled {n}");
    }
    println!("all artifacts compiled in {:.1}s", sw.elapsed_secs());
    Ok(())
}

/// Build a codec from CLI flags.
fn codec_from_args(args: &Args) -> Result<Codec> {
    let bits = args.opt_usize("bits", 2) as u8;
    let rounding = if args.flag("unbiased") {
        Rounding::Unbiased
    } else {
        Rounding::Biased
    };
    let bound = match args.opt("clip") {
        Some(p) => {
            let p: f64 = p.parse()?;
            if p == 0.0 {
                BoundMode::Auto
            } else {
                BoundMode::ClipTopPercent(p)
            }
        }
        None => BoundMode::ClipTopPercent(1.0),
    };
    let kind = match args.opt_or("codec", "cosine") {
        "float32" | "f32" => CodecKind::Float32,
        "cosine" | "cos" => CodecKind::Cosine {
            bits,
            rounding,
            bound,
        },
        "linear" => CodecKind::Linear { bits, rounding },
        "linear-rotated" | "linear-ur" => CodecKind::LinearRotated { bits, rounding },
        "signsgd" => CodecKind::SignSgd,
        "signsgd-norm" => CodecKind::SignSgdNorm,
        "ef-signsgd" => CodecKind::EfSignSgd,
        other => bail!("unknown codec '{other}'"),
    };
    let mut codec = Codec::new(kind).with_sparsify(args.opt_f64("keep", 1.0));
    if args.flag("no-deflate") || kind == CodecKind::Float32 {
        codec = codec.without_deflate();
    }
    Ok(codec)
}

fn cmd_train(args: &Args) -> Result<()> {
    let task = Task::parse(args.opt_or("task", "mnist-iid"))?;
    let codec = codec_from_args(args)?;
    let mut cfg = match task {
        Task::MnistIid => FlConfig::mnist(false),
        Task::MnistNonIid => FlConfig::mnist(true),
        Task::Cifar => FlConfig::cifar(),
        Task::Unet => FlConfig::unet(),
    };
    let default_rounds = cfg.rounds.min(20);
    cfg = cfg
        .with_rounds(args.opt_usize("rounds", default_rounds))
        .with_codec(codec)
        .with_seed(args.opt_u64("seed", 42));
    cfg.eval_every = args.opt_usize("eval-every", 5);
    cfg.use_kernel_quantizer = args.flag("kernel");
    cfg.verbose = !args.flag("quiet");
    if let Some(c) = args.opt("clients") {
        cfg.n_clients = c.parse()?;
    }
    if let Some(c) = args.opt("participation") {
        cfg.participation = c.parse()?;
    }

    println!("config: {}", cfg.describe().dump());
    let engine = Engine::load_default()?;
    let result = fl::run(&cfg, &engine)?;
    let model = engine.manifest.model(cfg.task.model_key())?;
    println!("\nfinished in {:.1}s", result.wall_secs);
    println!("network: {}", result.network.summary());
    println!(
        "uplink compression vs float32: {:.1}x",
        result
            .network
            .uplink_compression_vs_float32(model.param_count)
    );
    if let Some(m) = result.history.best_metric() {
        println!("best metric: {m:.4}");
    }
    let out = std::path::Path::new("artifacts/results").join("train_last.json");
    fl::metrics::save_results(&out, "train", &[result.history])?;
    println!("history written to {out:?}");
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let opts = FigOpts::from_args(args);
    let mut engine: Option<Engine> = None;
    let sw = Stopwatch::start();
    if id == "all" {
        for fid in figures::ALL {
            println!("\n######## {fid} ########");
            figures::run_figure(fid, &mut engine, &opts)?;
        }
    } else {
        figures::run_figure(id, &mut engine, &opts)?;
    }
    println!("\ntotal {:.1}s", sw.elapsed_secs());
    Ok(())
}

fn cmd_compress_stats(args: &Args) -> Result<()> {
    let n = args.opt_usize("n", 1_000_000);
    let mut rng = Pcg64::seeded(args.opt_u64("seed", 42));
    let g = cossgd::util::propcheck::gradient_like(&mut rng, n);
    println!("== codec wire costs on a synthetic {n}-element gradient ==");
    println!(
        "{:<24} {:>12} {:>10} {:>10}",
        "codec", "bytes", "ratio", "deflated"
    );
    let f32_bytes = (n * 4) as f64;
    let mut table: Vec<Codec> = vec![Codec::float32()];
    for bits in [8u8, 4, 2, 1] {
        table.push(Codec::cosine(bits));
    }
    table.push(Codec::cosine(2).with_sparsify(0.05));
    table.push(Codec::new(CodecKind::LinearRotated {
        bits: 2,
        rounding: Rounding::Unbiased,
    }));
    table.push(Codec::new(CodecKind::SignSgdNorm));
    for codec in table {
        let mut st = cossgd::compress::ClientCodecState::new();
        let enc = codec.encode(&g, &mut st, &mut rng);
        let bytes = enc.wire_bytes();
        println!(
            "{:<24} {:>12} {:>9.1}x {:>10}",
            codec.name(),
            fmt_bytes(bytes as u64),
            f32_bytes / bytes as f64,
            enc.deflated
        );
    }
    Ok(())
}
