//! `repro` — the CosSGD reproduction launcher.
//!
//! ```text
//! repro figure <id>|all [--rounds N] [--scale full] [--seed S] [--quiet]
//! repro train --task mnist|mnist-iid|cifar|unet --codec <name>
//!             [--bits B|const:<b>|anneal:<hi>..<lo>|adaptive[:<bytes>]]
//!             [--keep F] [--rounds N] [--kernel] [--seed S] [--threads N]
//!             [--round-mode sync|async:K[:S]] [--trace FILE]
//!             [--ingest-shards N]  # sharded server ingest (0 = auto)
//!             [--deflate-level fast|default|best]
//!             [--deflate-threads N]  # parallel DEFLATE (0 = auto,
//!                                    # bytes identical at any value)
//!             [--downlink <name>] [--downlink-bits B] [--downlink-keep F]
//! repro sim   --task <t> [--rounds N] [--fleet heterogeneous|uniform|3g]
//!             [--policy sync|overselect] [--over F] [--availability P]
//!             [--dropout P] [--target M] [--round-mode async:K[:S]]
//!             [--ingest-shards N]  # sharded server ingest (0 = auto)
//!             [--deflate-level L] [--deflate-threads N]
//!             [--bits <schedule>]  # adds const vs anneal vs adaptive rows
//!             [--trace FILE]       # structured JSONL round telemetry
//!             [--quick]   # sync vs buffered-async time-to-accuracy table
//!                         # (--quick without artifacts: protocol dry-run)
//! repro trace FILE                  # explore a --trace JSONL: phase
//!                                   # breakdowns, ingest verdicts,
//!                                   # bit-plan decision log, metrics
//! repro compress-stats [--n N]      # pipeline table, no artifacts needed
//! repro bench [--quick] [--n N] [--out FILE]
//!                                   # compress perf trajectory
//!                                   # (ns/elem per stage × bit width;
//!                                   #  every run APPENDS a point)
//! repro check                       # load + compile all artifacts
//! repro analyze [--json] [--out FILE] [--root DIR] [--manifest FILE] [paths…]
//!                                   # project-invariant static analysis
//!                                   # (exit 1 on any violation)
//! repro list                        # figure ids and codec names
//! ```

use anyhow::{bail, Result};

use cossgd::compress::allocator::{BitSchedule, LayerMap};
use cossgd::compress::cosine::{BoundMode, Rounding};
use cossgd::compress::deflate::CompressionLevel;
use cossgd::compress::{Direction, Pipeline, PipelineState};
use cossgd::figures::{self, FigOpts};
use cossgd::fl::{self, FlConfig, RoundMode, Task};
use cossgd::obs::{self, Metrics, PhaseBreakdown, TimeSource, Tracer};
use cossgd::runtime::Engine;
use cossgd::sim::{fmt_sim_secs, RoundPolicy, SimConfig, Timeline};
use cossgd::util::cli::Args;
use cossgd::util::rng::Pcg64;
use cossgd::util::timer::{fmt_bytes, Stopwatch};

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("figure") => cmd_figure(args),
        Some("train") => cmd_train(args),
        Some("sim") => cmd_sim(args),
        Some("trace") => cmd_trace(args),
        Some("compress-stats") => cmd_compress_stats(args),
        Some("bench") => cmd_bench(args),
        Some("check") => cmd_check(),
        Some("analyze") => cmd_analyze(args),
        Some("list") | None => cmd_list(),
        Some(other) => bail!("unknown subcommand '{other}' (try `repro list`)"),
    }
}

fn cmd_list() -> Result<()> {
    println!("subcommands: figure, train, sim, trace, compress-stats, bench, check, analyze, list");
    println!("figures: {}", figures::ALL.join(", "));
    println!("tasks:   mnist (non-iid), mnist-iid, cifar, unet");
    println!(
        "codecs:  float32, cosine, linear, linear-rotated, signsgd, signsgd-norm, ef-signsgd"
    );
    println!(
        "options: --bits 1..8 | const:<b> | anneal:<hi>..<lo> | adaptive[:<bytes>], \
         --keep 0.05..1.0, --unbiased, --clip P, --no-deflate"
    );
    println!(
        "round-trip: --downlink <codec> [--downlink-bits B] [--downlink-keep F] \
         [--downlink-unbiased] [--downlink-clip P] [--downlink-no-deflate]"
    );
    println!(
        "sim: --fleet heterogeneous|uniform|3g, --policy sync|overselect [--over F], \
         --availability P, --dropout P, --target M, --quick"
    );
    println!("rounds: --round-mode sync|async:K[:S]  (K = buffer size, S = max staleness)");
    println!("observability: --trace FILE writes JSONL round telemetry; `repro trace FILE` explores it");
    println!(
        "perf: --threads N (0 = all cores), --ingest-shards N (sharded server ingest, 0 = auto, \
         bit-identical at any value), bench [--quick] [--n N] [--out FILE]"
    );
    println!(
        "deflate: --deflate-level fast|default|best, --deflate-threads N \
         (parallel DEFLATE, 0 = auto; output bytes identical at any thread count)"
    );
    Ok(())
}

/// Parse the DEFLATE knobs shared by `train` and `sim`:
/// `--deflate-level fast|default|best` (effort) and `--deflate-threads N`
/// (0 = auto; scheduling only — compressed bytes are identical at every
/// value).
fn deflate_from_args(args: &Args) -> Result<(CompressionLevel, usize)> {
    let level = match args.opt("deflate-level") {
        Some(s) => CompressionLevel::parse(s).ok_or_else(|| {
            anyhow::anyhow!("unknown --deflate-level '{s}' (fast, default, best)")
        })?,
        None => CompressionLevel::Default,
    };
    Ok((level, args.opt_usize("deflate-threads", 1)))
}

/// Parse `--round-mode` (default synchronous).
fn round_mode_from_args(args: &Args) -> Result<RoundMode> {
    match args.opt("round-mode") {
        Some(s) => RoundMode::parse(s),
        None => Ok(RoundMode::Synchronous),
    }
}

/// The compress perf trajectory: ns/elem for every hot stage at every bit
/// width plus end-to-end round time, ALWAYS appended to
/// `BENCH_compress.json` (or `--out FILE`) so the checked-in trajectory
/// never goes stale — a `repro bench` run that leaves the file empty was
/// a run nobody can compare against. `--json` is accepted for
/// back-compat; the append no longer hides behind it.
fn cmd_bench(args: &Args) -> Result<()> {
    let n = args.opt_usize("n", 1 << 20);
    let seed = args.opt_u64("seed", 42);
    let mut b = if args.flag("quick") {
        cossgd::util::bench::Bencher::quick()
    } else {
        cossgd::util::bench::Bencher::new()
    };
    cossgd::compress::perf::run_suite(&mut b, n, seed);
    if let Some(speedup) = cossgd::compress::perf::headline_speedup(b.results()) {
        println!("headline: 4-bit biased quantize+pack kernel speedup {speedup:.1}x vs reference");
    }
    let out = std::path::PathBuf::from(args.opt_or("out", "BENCH_compress.json"));
    cossgd::util::bench::write_trajectory(&out, cossgd::compress::perf::SUITE, b.results())?;
    println!("run appended to {out:?}");
    Ok(())
}

/// `repro analyze` — run the project-invariant static analyzer over
/// `rust/src` (or `--root DIR`) against `rust/analyze.toml` (or
/// `--manifest FILE`). Extra positionals restrict the scan to relative
/// path prefixes. `--json` switches the stdout report to JSON; `--out`
/// additionally writes the JSON report to a file (written even when dirty,
/// so CI can upload it before the gate fails). Exit code 1 on violations.
fn cmd_analyze(args: &Args) -> Result<()> {
    let manifest_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = args
        .opt("root")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| manifest_dir.join("src"));
    let manifest = args
        .opt("manifest")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| manifest_dir.join("analyze.toml"));
    let filters: Vec<String> = args.positional.iter().skip(1).cloned().collect();
    let report = cossgd::analyze::run(&root, &manifest, &filters)?;
    if let Some(out) = args.opt("out") {
        std::fs::write(out, report.json())?;
    }
    if args.flag("json") {
        println!("{}", report.json());
    } else {
        print!("{}", report.text());
    }
    if !report.clean() {
        bail!("analyze: {} violation(s)", report.diagnostics.len());
    }
    Ok(())
}

fn cmd_check() -> Result<()> {
    let sw = Stopwatch::start();
    let engine = Engine::load_default()?;
    let names: Vec<String> = engine.manifest.artifacts.keys().cloned().collect();
    println!(
        "manifest: {} artifacts, {} models",
        names.len(),
        engine.manifest.models.len()
    );
    for n in &names {
        engine.warmup(&[n.as_str()])?;
        println!("  compiled {n}");
    }
    println!("all artifacts compiled in {:.1}s", sw.elapsed_secs());
    Ok(())
}

/// Build a pipeline from a codec name + generic options.
fn pipeline_from_opts(
    name: &str,
    bits: u8,
    rounding: Rounding,
    bound: BoundMode,
    keep: f64,
    no_deflate: bool,
) -> Result<Pipeline> {
    let mut pipe = match name {
        "float32" | "f32" => Pipeline::float32(),
        "cosine" | "cos" => Pipeline::cosine_with(bits, rounding, bound),
        "linear" => Pipeline::linear(bits, rounding),
        "linear-rotated" | "linear-ur" => Pipeline::linear_rotated(bits, rounding),
        "signsgd" => Pipeline::sign(),
        "signsgd-norm" => Pipeline::sign_norm(),
        "ef-signsgd" => Pipeline::ef_sign(),
        other => bail!("unknown codec '{other}'"),
    };
    pipe = pipe.with_sparsify(keep);
    if no_deflate {
        pipe = pipe.without_deflate();
    }
    Ok(pipe)
}

/// Parse a `--<flag>` clip percentage into a bound mode (0 = auto).
fn bound_from_args(args: &Args, flag: &str) -> Result<BoundMode> {
    Ok(match args.opt(flag) {
        Some(p) => {
            let p: f64 = p.parse()?;
            if p == 0.0 {
                BoundMode::Auto
            } else {
                BoundMode::ClipTopPercent(p)
            }
        }
        None => BoundMode::ClipTopPercent(1.0),
    })
}

fn rounding_from_flag(unbiased: bool) -> Rounding {
    if unbiased {
        Rounding::Unbiased
    } else {
        Rounding::Biased
    }
}

/// Parse `--bits`: a bare integer is the legacy fixed width; anything
/// else is a [`BitSchedule`] (`const:<b>`, `anneal:<hi>..<lo>`,
/// `adaptive[:<budget>]`) routed through the adaptive bit controller.
fn bits_from_args(args: &Args) -> Result<(u8, Option<BitSchedule>)> {
    match args.opt("bits") {
        None => Ok((2, None)),
        Some(s) => match s.parse::<u8>() {
            // Legacy: width baked into the pipeline. Same validation as
            // `const:<b>` — a clean error, not a quantizer assert.
            Ok(b) if (1..=16).contains(&b) => Ok((b, None)),
            Ok(b) => bail!("--bits width {b} outside 1..=16"),
            Err(_) => {
                let sched = BitSchedule::parse(s)?;
                // The pipeline's base width is the schedule's anchor; the
                // controller overrides it per round / per layer.
                let base = match sched {
                    BitSchedule::Const(b) => b,
                    BitSchedule::Anneal { hi, .. } => hi,
                    BitSchedule::Adaptive { .. } => 4,
                };
                Ok((base, Some(sched)))
            }
        },
    }
}

/// Build the uplink pipeline (+ optional bit schedule) from CLI flags.
fn uplink_from_args(args: &Args) -> Result<(Pipeline, Option<BitSchedule>)> {
    let (bits, schedule) = bits_from_args(args)?;
    let pipe = pipeline_from_opts(
        args.opt_or("codec", "cosine"),
        bits,
        rounding_from_flag(args.flag("unbiased")),
        bound_from_args(args, "clip")?,
        args.opt_f64("keep", 1.0),
        args.flag("no-deflate"),
    )?;
    Ok((pipe, schedule))
}

/// Build the optional downlink policy (`--downlink <codec>`), with its own
/// `--downlink-*` variant of every uplink knob so the two directions are
/// configured independently. `--downlink float32` names the legacy
/// raw-model broadcast explicitly (4·n bytes, no framing) — NOT a float32
/// delta pipeline, which would cost strictly more (44-byte header on top
/// of the same payload).
fn downlink_from_args(args: &Args) -> Result<Option<fl::Downlink>> {
    let Some(name) = args.opt("downlink") else {
        return Ok(None);
    };
    if name == "float32" || name == "f32" || name == "model" {
        return Ok(Some(fl::Downlink::Float32Model));
    }
    pipeline_from_opts(
        name,
        args.opt_usize("downlink-bits", 8) as u8,
        rounding_from_flag(args.flag("downlink-unbiased")),
        bound_from_args(args, "downlink-clip")?,
        args.opt_f64("downlink-keep", 1.0),
        args.flag("downlink-no-deflate"),
    )
    .map(|p| Some(fl::Downlink::Delta(p)))
}

fn cmd_train(args: &Args) -> Result<()> {
    let task = Task::parse(args.opt_or("task", "mnist-iid"))?;
    let (uplink, bit_schedule) = uplink_from_args(args)?;
    let mut cfg = match task {
        Task::MnistIid => FlConfig::mnist(false),
        Task::MnistNonIid => FlConfig::mnist(true),
        Task::Cifar => FlConfig::cifar(),
        Task::Unet => FlConfig::unet(),
    };
    let default_rounds = cfg.rounds.min(20);
    cfg = cfg
        .with_rounds(args.opt_usize("rounds", default_rounds))
        .with_uplink(uplink)
        .with_seed(args.opt_u64("seed", 42));
    cfg.bit_schedule = bit_schedule;
    if let Some(down) = downlink_from_args(args)? {
        cfg.downlink = down;
    }
    cfg.eval_every = args.opt_usize("eval-every", 5);
    cfg.use_kernel_quantizer = args.flag("kernel");
    cfg.client_threads = args.opt_usize("threads", 1);
    (cfg.deflate_level, cfg.deflate_threads) = deflate_from_args(args)?;
    cfg.ingest_shards = args.opt_usize("ingest-shards", 1);
    cfg.round_mode = round_mode_from_args(args)?;
    cfg.verbose = !args.flag("quiet");
    if let Some(p) = args.opt("trace") {
        cfg = cfg.with_trace(p);
    }
    if let Some(c) = args.opt("clients") {
        cfg.n_clients = c.parse()?;
    }
    if let Some(c) = args.opt("participation") {
        cfg.participation = c.parse()?;
    }

    println!("config: {}", cfg.describe().dump());
    let engine = Engine::load_default()?;
    let result = fl::run(&cfg, &engine)?;
    let model = engine.manifest.model(cfg.task.model_key())?;
    println!("\nfinished in {:.1}s", result.wall_secs);
    println!("network: {}", result.network.summary());
    println!(
        "uplink compression vs float32:   {}",
        fl::network::fmt_ratio(result.network.uplink_compression_vs_float32(model.param_count))
    );
    println!(
        "downlink compression vs float32: {}",
        fl::network::fmt_ratio(
            result.network.downlink_compression_vs_float32(model.param_count)
        )
    );
    if let Some(m) = result.history.best_metric() {
        println!("best metric: {m:.4}");
    }
    let out = std::path::Path::new("artifacts/results").join("train_last.json");
    fl::metrics::save_results(&out, "train", &[result.history])?;
    println!("history written to {out:?}");
    if let Some(p) = args.opt("trace") {
        println!("trace written to {p}; inspect with `repro trace {p}`");
    }
    Ok(())
}

/// Build the fleet/policy description from `--fleet`, `--policy` and the
/// lottery knobs.
fn sim_from_args(args: &Args) -> Result<SimConfig> {
    let mut sim = match args.opt_or("fleet", "heterogeneous") {
        "heterogeneous" | "het" => SimConfig::heterogeneous(),
        "uniform" | "wifi" => SimConfig::uniform(),
        "3g" | "cellular" => SimConfig::cellular(),
        other => bail!("unknown fleet '{other}' (heterogeneous, uniform, 3g)"),
    };
    sim.policy = RoundPolicy::parse(args.opt_or("policy", "sync"), args.opt_f64("over", 1.3))?;
    if let Some(a) = args.opt("availability") {
        sim.availability = a.parse()?;
        if !(0.0..=1.0).contains(&sim.availability) {
            bail!("--availability is a probability in [0, 1], got {a}");
        }
    }
    if let Some(d) = args.opt("dropout") {
        sim.dropout = d.parse()?;
        if !(0.0..=1.0).contains(&sim.dropout) {
            bail!("--dropout is a probability in [0, 1], got {d}");
        }
    }
    Ok(sim)
}

/// The buffered-async mode to compare against synchronous rounds: what
/// `--round-mode` says, or an `async:K` default where `K` matches the
/// synchronous cohort (equal updates per aggregation ⇒ comparable bytes).
fn async_mode_for(args: &Args, per_round: usize) -> Result<RoundMode> {
    match round_mode_from_args(args)? {
        m @ RoundMode::BufferedAsync { .. } => Ok(m),
        RoundMode::Synchronous => Ok(RoundMode::BufferedAsync {
            buffer_k: per_round,
            max_staleness: 2,
        }),
    }
}

/// Time-to-accuracy comparison: the same federated task across
/// uplink/downlink pipelines, every run replayed on the same virtual
/// fleet in BOTH round modes, so compression ratios — and buffered-async
/// aggregation — become simulated-seconds speedups side by side.
fn cmd_sim(args: &Args) -> Result<()> {
    // Same location Engine::load_default resolves.
    let artifacts_built = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists();
    if args.flag("quick") && !artifacts_built {
        // CI smoke path: no training artifacts — drive the REAL
        // transport + server state machine with synthetic updates.
        return cmd_sim_dry(args);
    }
    let task = Task::parse(args.opt_or("task", "mnist-iid"))?;
    let mut base = match task {
        Task::MnistIid => FlConfig::mnist(false),
        Task::MnistNonIid => FlConfig::mnist(true),
        Task::Cifar => FlConfig::cifar(),
        Task::Unet => FlConfig::unet(),
    };
    if let Some(c) = args.opt("clients") {
        base.n_clients = c.parse()?;
    }
    if let Some(p) = args.opt("participation") {
        base.participation = p.parse()?;
    }
    let default_rounds = if args.flag("quick") { 6 } else { base.rounds.min(20) };
    let rounds = args.opt_usize("rounds", default_rounds);
    let seed = args.opt_u64("seed", 42);
    let sim = sim_from_args(args)?;
    let target: Option<f64> = args.opt("target").map(str::parse).transpose()?;
    let async_mode = async_mode_for(args, base.clients_per_round())?;
    let engine = Engine::load_default()?;

    // With a `--bits` schedule the table compares bit *schedules* (const
    // vs anneal vs adaptive — the user's parameters seed the matching
    // row); without one it compares the fixed pipelines as before.
    type SchemeRow = (String, Pipeline, Option<Pipeline>, Option<BitSchedule>);
    let schemes: Vec<SchemeRow> = match bits_from_args(args)? {
        (_, Some(user)) => {
            let (c, a, ad) = match user {
                BitSchedule::Const(b) => (
                    BitSchedule::Const(b),
                    BitSchedule::Anneal { hi: 8, lo: 2 },
                    BitSchedule::Adaptive { budget: 0 },
                ),
                BitSchedule::Anneal { hi, lo } => (
                    BitSchedule::Const(4),
                    BitSchedule::Anneal { hi, lo },
                    BitSchedule::Adaptive { budget: 0 },
                ),
                BitSchedule::Adaptive { budget } => (
                    BitSchedule::Const(4),
                    BitSchedule::Anneal { hi: 8, lo: 2 },
                    BitSchedule::Adaptive { budget },
                ),
            };
            [c, a, ad]
                .into_iter()
                .map(|s| {
                    (
                        format!("cosine {} ↑ / Δ cosine-4 ↓", s.name()),
                        Pipeline::cosine(4),
                        Some(Pipeline::cosine(4)),
                        Some(s),
                    )
                })
                .collect()
        }
        _ => vec![
            (
                "float32 ↑ / float32 ↓".to_string(),
                Pipeline::float32(),
                None,
                None,
            ),
            (
                "cosine-8 ↑ / Δ cosine-8 ↓".to_string(),
                Pipeline::cosine(8),
                Some(Pipeline::cosine(8)),
                None,
            ),
            (
                "cosine-4 ↑ / Δ cosine-4 ↓".to_string(),
                Pipeline::cosine(4),
                Some(Pipeline::cosine(4)),
                None,
            ),
            (
                "cosine-2@5% ↑ / Δ cosine-4 ↓".to_string(),
                Pipeline::cosine(2).with_sparsify(0.05),
                Some(Pipeline::cosine(4)),
                None,
            ),
        ],
    };

    println!(
        "fleet: {} over {} clients · {} rounds · task {task:?} · seed {seed} · async = {}",
        sim.name(),
        base.n_clients,
        rounds,
        async_mode.name()
    );
    println!(
        "{:<30} {:>7} {:>10} {:>10} {:>10} {:>10} {:>11} {:>6}",
        "scheme", "best", "sync time", "sync t2t", "async time", "async t2t", "uplink", "stale"
    );
    let trace_path = args.opt("trace");
    for (i, (name, up, down, schedule)) in schemes.into_iter().enumerate() {
        let name = name.as_str();
        let mut cfg = base
            .clone()
            .with_rounds(rounds)
            .with_uplink(up)
            .with_seed(seed)
            .with_sim(sim.clone());
        if let Some(d) = down {
            cfg = cfg.with_downlink(d);
        }
        cfg.bit_schedule = schedule;
        cfg.eval_every = args.opt_usize("eval-every", 5);
        cfg.client_threads = args.opt_usize("threads", 1);
        (cfg.deflate_level, cfg.deflate_threads) = deflate_from_args(args)?;
        cfg.ingest_shards = args.opt_usize("ingest-shards", 1);
        cfg.verbose = args.flag("verbose");
        // `--trace` captures the first scheme's synchronous run (one run
        // per file; the dry-run path traces every row into one file).
        if i == 0 {
            if let Some(p) = trace_path {
                cfg = cfg.with_trace(p);
            }
        }
        let sync_run = fl::run_labeled(&cfg, &engine, name)?;
        let mut async_cfg = cfg.clone().with_round_mode(async_mode);
        async_cfg.trace = None;
        let async_run = fl::run_labeled(&async_cfg, &engine, name)?;
        let tl_sync = sync_run.timeline.as_ref().expect("sim runs carry a timeline");
        let tl_async = async_run.timeline.as_ref().expect("sim runs carry a timeline");
        let best = sync_run
            .history
            .best_metric()
            .map_or("-".to_string(), |m| format!("{m:.4}"));
        let t2t = |run: &fl::RunResult, tl: &Timeline| {
            target
                .and_then(|tg| tl.time_to_metric(&run.history, tg))
                .map_or("-".to_string(), fmt_sim_secs)
        };
        let stale: usize = async_run.history.records.iter().map(|r| r.stale_updates).sum();
        println!(
            "{:<30} {:>7} {:>10} {:>10} {:>10} {:>10} {:>11} {:>6}",
            name,
            best,
            fmt_sim_secs(tl_sync.total_secs()),
            t2t(&sync_run, tl_sync),
            fmt_sim_secs(tl_async.total_secs()),
            t2t(&async_run, tl_async),
            fmt_bytes(sync_run.network.uplink_bytes),
            stale
        );
    }
    if target.is_none() {
        println!("(pass --target M for time-to-target-metric, e.g. --target 0.8)");
    }
    if let Some(p) = trace_path {
        println!("trace written to {p} (first scheme, sync mode); inspect with `repro trace {p}`");
    }
    Ok(())
}

/// Artifact-free `repro sim --quick`: the protocol smoke CI runs. Real
/// encoded frames, real transport, real server state machine — both round
/// modes side by side; only the training is synthetic
/// ([`cossgd::fl::transport::dryrun`]).
fn cmd_sim_dry(args: &Args) -> Result<()> {
    use cossgd::fl::transport::dryrun;
    let n = args.opt_usize("n", 20_000);
    let n_clients = args.opt_usize("clients", 40);
    let k = 10usize.min(n_clients);
    let rounds = args.opt_usize("rounds", 6);
    let seed = args.opt_u64("seed", 42);
    let sim = sim_from_args(args)?;
    let RoundMode::BufferedAsync {
        buffer_k,
        max_staleness,
    } = async_mode_for(args, k)?
    else {
        unreachable!("async_mode_for always returns BufferedAsync")
    };
    let concurrency = (2 * buffer_k).min(n_clients);
    let (deflate_level, deflate_threads) = deflate_from_args(args)?;
    let ingest_shards = match args.opt_usize("ingest-shards", 1) {
        0 => cossgd::fl::ingest::auto_shards(),
        s => s,
    };
    println!(
        "protocol dry-run (artifacts not built): {n}-param synthetic updates, real frames \
         through transport + ingest state machine ({ingest_shards}-shard ingest plane)"
    );
    println!(
        "fleet: {} over {n_clients} clients · {rounds} rounds · async:{buffer_k} ≤{max_staleness} stale",
        sim.name()
    );
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>10} {:>11} {:>11} {:>6}",
        "uplink codec",
        "sync time",
        "sync/rnd",
        "async time",
        "async/rnd",
        "sync ↑B",
        "async ↑B",
        "stale"
    );
    // A `--bits` schedule adds a controller-in-the-loop row: the full
    // adaptive/anneal control loop over real mixed-width CSG2 segment
    // streams (this is what CI smokes on every push).
    let bit_row: Option<dryrun::DryBits> = match bits_from_args(args)? {
        (_, Some(schedule)) => Some(dryrun::DryBits {
            schedule,
            map: LayerMap::even(n, 6),
            decay: 0.5,
        }),
        _ => None,
    };
    // Every row's pipeline carries the DEFLATE knobs (a no-op for
    // float32, which skips the stage) — so `--deflate-threads 4` smokes
    // the parallel encoder through the whole protocol path.
    let tuned = |p: Pipeline| {
        p.with_deflate_level(deflate_level)
            .with_deflate_threads(deflate_threads)
    };
    let mut rows: Vec<(String, Pipeline, Option<dryrun::DryBits>)> = vec![
        ("float32".into(), tuned(Pipeline::float32()), None),
        ("cosine-4".into(), tuned(Pipeline::cosine(4)), None),
    ];
    if let Some(b) = bit_row {
        rows.push((
            format!("cosine {}", b.schedule.name()),
            tuned(Pipeline::cosine(4)),
            Some(b),
        ));
    }
    // `--trace` captures every row (sync + async) into one JSONL file,
    // separated by `section` points — the explorer reports per section.
    let trace_path = args.opt("trace");
    let mut tracer = match trace_path {
        Some(_) => Tracer::new(TimeSource::manual(), obs::DEFAULT_RING_CAPACITY),
        None => Tracer::disabled(),
    };
    let mut metrics = Metrics::new();
    for (name, pipe, bits) in rows {
        tracer.point("section", vec![("label", format!("{name} sync").into())]);
        let sync = dryrun::run_sync_bits_traced(
            &pipe,
            bits.as_ref(),
            &sim,
            n,
            n_clients,
            k,
            rounds,
            seed,
            ingest_shards,
            &mut tracer,
            &mut metrics,
        )?;
        tracer.point("section", vec![("label", format!("{name} async").into())]);
        let asyn = dryrun::run_async_bits_traced(
            &pipe,
            bits.as_ref(),
            &sim,
            n,
            n_clients,
            buffer_k,
            concurrency,
            rounds,
            max_staleness,
            seed,
            ingest_shards,
            &mut tracer,
            &mut metrics,
        )?;
        anyhow::ensure!(
            sync.timeline.records.len() == rounds && asyn.aggregations == rounds,
            "{name}: protocol run incomplete"
        );
        println!(
            "{:<22} {:>10} {:>10} {:>10} {:>10} {:>11} {:>11} {:>6}",
            name,
            fmt_sim_secs(sync.timeline.total_secs()),
            fmt_sim_secs(sync.timeline.mean_round_secs()),
            fmt_sim_secs(asyn.timeline.total_secs()),
            fmt_sim_secs(asyn.timeline.mean_round_secs()),
            fmt_bytes(sync.ledger.uplink_bytes),
            fmt_bytes(asyn.ledger.uplink_bytes),
            asyn.dropped
        );
        // The same phase model `repro trace` reports from — one code path.
        println!(
            "  └ {}",
            PhaseBreakdown::from_timeline(&sync.timeline).critical_path_line()
        );
        if !sync.round_bits.is_empty() {
            let widths: Vec<String> = sync
                .round_bits
                .iter()
                .map(|b| {
                    b.iter()
                        .map(|w| w.to_string())
                        .collect::<Vec<_>>()
                        .join("")
                })
                .collect();
            println!("  └ widths/round (sync): {}", widths.join(" "));
        }
    }
    println!("protocol dry-run OK (both round modes)");
    if let Some(path) = trace_path {
        std::fs::write(path, obs::render_trace(&tracer, &metrics))?;
        println!(
            "trace written to {path} ({} events); inspect with `repro trace {path}`",
            tracer.len()
        );
    }
    Ok(())
}

/// `repro trace FILE` — render a `--trace` JSONL file: per-section phase
/// tables with the critical-path share, the flame table, ingest verdict
/// totals, the bit controller's decision log, and the metrics snapshot.
fn cmd_trace(args: &Args) -> Result<()> {
    let Some(path) = args.positional.get(1) else {
        bail!("usage: repro trace FILE  (a JSONL file written by --trace)");
    };
    let report = cossgd::obs::explore::explore_file(std::path::Path::new(path))?;
    println!("{}", report.trim_end());
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let opts = FigOpts::from_args(args);
    let mut engine: Option<Engine> = None;
    let sw = Stopwatch::start();
    if id == "all" {
        for fid in figures::ALL {
            println!("\n######## {fid} ########");
            figures::run_figure(fid, &mut engine, &opts)?;
        }
    } else {
        figures::run_figure(id, &mut engine, &opts)?;
    }
    println!("\ntotal {:.1}s", sw.elapsed_secs());
    Ok(())
}

fn cmd_compress_stats(args: &Args) -> Result<()> {
    let n = args.opt_usize("n", 1_000_000);
    let mut rng = Pcg64::seeded(args.opt_u64("seed", 42));
    let g = cossgd::util::propcheck::gradient_like(&mut rng, n);
    println!("== pipeline wire costs on a synthetic {n}-element gradient ==");
    println!(
        "{:<32} {:>12} {:>10} {:>10}",
        "pipeline", "bytes", "ratio", "deflated"
    );
    let f32_bytes = (n * 4) as f64;
    let mut table: Vec<Pipeline> = vec![Pipeline::float32()];
    for bits in [8u8, 4, 2, 1] {
        table.push(Pipeline::cosine(bits));
    }
    table.push(Pipeline::cosine(2).with_sparsify(0.05));
    table.push(Pipeline::linear_rotated(2, Rounding::Unbiased));
    table.push(Pipeline::sign_norm());
    for pipe in table {
        let mut st = PipelineState::new();
        let enc = pipe.encode(&g, Direction::Uplink, &mut st, &mut rng);
        let bytes = enc.wire_bytes();
        println!(
            "{:<32} {:>12} {:>9.1}x {:>10}",
            pipe.name(),
            fmt_bytes(bytes as u64),
            f32_bytes / bytes as f64,
            enc.deflated
        );
    }
    Ok(())
}
