//! # CosSGD — communication-efficient federated learning with a
//! cosine-based nonlinear gradient quantization.
//!
//! Reproduction of *"CosSGD: Nonlinear Quantization for
//! Communication-efficient Federated Learning"* (He, Zenk, Fritz, 2020) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the federated-learning coordinator: FedAvg
//!   server, simulated client fleet, the full gradient-compression stack
//!   (cosine quantization plus every baseline the paper compares against),
//!   a byte-exact simulated network, metrics, config and CLI.
//! * **Layer 2** — JAX models (`python/compile/model.py`), AOT-lowered to
//!   HLO text executed through the PJRT CPU client (`runtime`).
//! * **Layer 1** — Pallas quantization kernels
//!   (`python/compile/kernels/`), lowered into the same artifacts.
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! compute once; everything else is this crate.
//!
//! ## Crate map
//!
//! | module | contents |
//! |--------|----------|
//! | [`analyze`] | zero-dependency static analyzer for project invariants (determinism, panic-safety, hot-path purity, unsafe-audit, wire constants) behind `repro analyze` |
//! | [`compress`] | the `Quantizer` trait + schemes (cosine, linear, sign-family, float32), the direction-agnostic `Pipeline` (EF → sparsify → rotate → quantize → pack → DEFLATE), entropy stats, the `CSG2` wire format |
//! | [`fl`] | FedAvg server/clients, model replica (round-trip downlink), round runner, schedules, simulated network, centralized toy harness |
//! | [`obs`] | observability plane: `TimeSource` clocks, span tracing over a bounded ring, typed metrics registry, JSONL/Prometheus sinks, the `repro trace` explorer |
//! | [`sim`] | discrete-event systems simulator: virtual clock + event queue, heterogeneous device tiers, synchronous / over-selection round policies, per-round timelines and time-to-accuracy |
//! | [`data`] | synthetic MNIST/CIFAR/volume datasets + IID/Non-IID partitioning |
//! | [`runtime`] | PJRT engine: manifest-driven loading and execution of AOT artifacts |
//! | [`figures`] | one driver per paper figure/table (fig3..fig10, tab1, tab2) |
//! | [`util`] | offline substrates: PCG64 RNG, JSON, CLI, stats, timing, micro-bench, property-check |

pub mod analyze;
pub mod compress;
pub mod data;
pub mod figures;
pub mod fl;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;
