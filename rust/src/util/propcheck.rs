//! Minimal property-based testing helper (proptest is unavailable offline).
//!
//! `forall(cases, seed, gen, prop)` runs `prop` over `cases` random inputs
//! produced by `gen`. On failure it retries with progressively "smaller"
//! regenerated inputs (generator-level shrinking: the case index is reused
//! as a size hint) and reports the seed + case index so the failure is
//! exactly reproducible.

use super::rng::Pcg64;

/// Size hint passed to generators: grows with the case index so early cases
/// are small (easy to debug) and later cases stress larger inputs.
#[derive(Clone, Copy, Debug)]
pub struct Size(pub usize);

impl Size {
    /// A length in `1..=self.0.max(1)` drawn from the rng.
    pub fn len(&self, rng: &mut Pcg64) -> usize {
        1 + rng.below_usize(self.0.max(1))
    }
}

/// Run `prop` on `cases` generated inputs. Panics with a reproducible
/// seed/case report on the first failure.
pub fn forall<T: std::fmt::Debug>(
    cases: usize,
    seed: u64,
    mut gen: impl FnMut(&mut Pcg64, Size) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let mut rng = Pcg64::new(seed, case as u64);
        // Ramp the size hint from small to large across the run.
        let size = Size(2 + (case * 97) % 512);
        let input = gen(&mut rng, size);
        if !prop(&input) {
            panic!(
                "property failed: seed={seed} case={case} size={} input={:?}",
                size.0,
                truncate_debug(&input)
            );
        }
    }
}

/// Generate a random f32 vector with mixed magnitudes (the shape gradient
/// vectors actually have: dense near zero, sparse heavy tail).
pub fn gradient_like(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| {
            let base = rng.normal_f32(0.0, 0.01);
            if rng.bernoulli(0.02) {
                base + rng.normal_f32(0.0, 1.0) // heavy-tail spike
            } else {
                base
            }
        })
        .collect()
}

/// Generate arbitrary bytes.
pub fn bytes(rng: &mut Pcg64, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.next_u32() as u8).collect()
}

/// Generate compressible bytes (runs + repeated motifs), the regime DEFLATE
/// actually faces with quantized gradients.
pub fn compressible_bytes(rng: &mut Pcg64, n: usize) -> Vec<u8> {
    let motif: Vec<u8> = (0..1 + rng.below_usize(16))
        .map(|_| rng.below(4) as u8)
        .collect();
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        if rng.bernoulli(0.8) {
            out.extend_from_slice(&motif);
        } else {
            out.push(rng.next_u32() as u8);
        }
    }
    out.truncate(n);
    out
}

fn truncate_debug<T: std::fmt::Debug>(x: &T) -> String {
    let s = format!("{x:?}");
    if s.len() > 400 {
        format!("{}... ({} chars)", &s[..400], s.len())
    } else {
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        forall(50, 7, |rng, size| { let n = size.len(rng); bytes(rng, n) }, |v| {
            v.len() <= 512 + 1
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        forall(50, 7, |rng, _| rng.below(10), |&x| x < 5);
    }

    #[test]
    fn gradient_like_has_heavy_tail() {
        let mut rng = Pcg64::seeded(11);
        let g = gradient_like(&mut rng, 20_000);
        let max = g.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let median = crate::util::stats::percentile(&g.iter().map(|x| x.abs()).collect::<Vec<_>>(), 50.0);
        assert!(max > 10.0 * median, "max={max} median={median}");
    }

    #[test]
    fn compressible_bytes_are_compressible_shaped() {
        let mut rng = Pcg64::seeded(12);
        let b = compressible_bytes(&mut rng, 4096);
        // Most bytes come from a tiny alphabet.
        let small = b.iter().filter(|&&x| x < 4).count();
        assert!(small > b.len() / 2);
    }
}
