//! Minimal JSON parser / emitter (serde is unavailable offline).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Used for the artifact manifest, experiment
//! configs, and result dumps. Object key order is preserved on emit.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap gives deterministic emit order; insertion order is not
    /// semantically meaningful for our uses (manifest / configs / results).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }
    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn from_f32_slice(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    pub fn from_usize_slice(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Insert a key (panics if `self` is not an object) — builder style.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- accessors ------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a", "b")` — nested lookup helper.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // ---- parse / emit ---------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact single-line emit.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed emit with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no Inf/NaN; emit null (documented lossy case).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(x: Vec<Json>) -> Json {
        Json::Arr(x)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let c = if (0xd800..0xdc00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            self.pos -= 1; // compensate the += 1 below
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap(),
            &Json::Bool(false)
        );
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip_dump_parse() {
        let j = Json::obj()
            .set("name", "cossgd")
            .set("bits", 2.0)
            .set("vals", Json::from_f64_slice(&[0.5, -1.0, 3.25]))
            .set("nested", Json::obj().set("ok", true).set("n", Json::Null));
        let text = j.dump();
        assert_eq!(Json::parse(&text).unwrap(), j);
        let pretty = j.pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn unicode_and_escapes_roundtrip() {
        let j = Json::Str("quote \" back \\ nl \n tab \t ünïcødé 𝄞".into());
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn surrogate_pair() {
        let j = Json::parse(r#""𝄞""#).unwrap();
        assert_eq!(j, Json::Str("𝄞".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integers_emit_without_decimal_point() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.5).dump(), "3.5");
    }
}
