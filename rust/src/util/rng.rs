//! Deterministic pseudo-random number generation (PCG64 / PCG-XSL-RR).
//!
//! Replaces the `rand` crate (unavailable offline). Every stochastic
//! component in the system (client selection, stochastic rounding,
//! sparsification masks, synthetic data) draws from a seeded [`Pcg64`], so
//! whole federated runs are bit-reproducible from a single seed.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Distinct streams from
    /// the same seed are independent sequences; we use the stream id to give
    /// every client / subsystem its own lane off a single experiment seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        // SplitMix64 the inputs so low-entropy seeds still fill the state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let s0 = next();
        let s1 = next();
        let state = ((s0 as u128) << 64) | s1 as u128;
        // The increment must be odd; derive it from the stream id.
        let inc = (((stream as u128) << 1) | 1).wrapping_mul(0x5851_f42d_4c95_7f2d) | 1;
        let mut rng = Self { state, inc };
        // Warm up: decorrelates seeds that differ in few bits.
        rng.state = rng.state.wrapping_add(inc);
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Single-argument convenience constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Next 32-bit output (high half of a 64-bit draw).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire's multiply-shift rejection).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let low = m as u64;
            if low >= bound || low >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple and exact
    /// enough for synthetic data and noise injection).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean / standard deviation, as `f32`.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx
    }

    /// Fork an independent child generator (e.g. one per client).
    pub fn fork(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg64::seeded(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_respects_bound_and_hits_all_values() {
        let mut rng = Pcg64::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(4);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seeded(5);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
        assert!(t.iter().all(|&i| i < 50));
    }

    #[test]
    fn permutation_covers_range() {
        let mut rng = Pcg64::seeded(6);
        let p = rng.permutation(31);
        let mut q = p.clone();
        q.sort_unstable();
        assert_eq!(q, (0..31).collect::<Vec<_>>());
    }
}
