//! Small statistics helpers: summaries, online (Welford) accumulation,
//! percentiles, histograms. Used by the metrics pipeline, the entropy
//! analysis (Fig. 5) and the bench harness.

/// Mean of a slice (0 for empty input).
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f32]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64;
    var.sqrt()
}

/// L2 norm of a slice, accumulated in f64 for stability.
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// `p`-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// The magnitude of the k-th largest |x| (k is 1-based). Used for top-p%
/// gradient clipping: `k = ceil(p/100 * n)`.
pub fn kth_largest_abs(xs: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= xs.len(), "kth_largest_abs k={k} n={}", xs.len());
    let mut mags: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
    // select_nth_unstable is O(n) average — this is on the encode hot path.
    let idx = mags.len() - k;
    let (_, kth, _) = mags.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
    *kth
}

/// Online mean/variance/min/max accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-bin histogram over a closed range.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            total: 0,
        }
    }
    pub fn push(&mut self, x: f64) {
        let b = ((x - self.lo) / (self.hi - self.lo) * self.counts.len() as f64) as isize;
        let b = b.clamp(0, self.counts.len() as isize - 1) as usize;
        self.counts[b] += 1;
        self.total += 1;
    }
    /// Shannon entropy of the bin distribution, in bits.
    pub fn entropy_bits(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let n = self.total as f64;
        self.counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - (1.25f64).sqrt()).abs() < 1e-6);
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0f32, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn kth_largest() {
        let xs = [-10.0f32, 1.0, -3.0, 7.0];
        assert_eq!(kth_largest_abs(&xs, 1), 10.0);
        assert_eq!(kth_largest_abs(&xs, 2), 7.0);
        assert_eq!(kth_largest_abs(&xs, 4), 1.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((o.mean() - m).abs() < 1e-12);
        assert!((o.variance() - v).abs() < 1e-12);
        assert_eq!(o.count(), 100);
    }

    #[test]
    fn histogram_entropy() {
        // Uniform over 4 bins -> 2 bits; single bin -> 0 bits.
        let mut h = Histogram::new(0.0, 4.0, 4);
        for i in 0..400 {
            h.push((i % 4) as f64 + 0.5);
        }
        assert!((h.entropy_bits() - 2.0).abs() < 1e-9);
        let mut h1 = Histogram::new(0.0, 1.0, 8);
        for _ in 0..10 {
            h1.push(0.5);
        }
        assert_eq!(h1.entropy_bits(), 0.0);
    }

    #[test]
    fn histogram_clamps_out_of_range() {
        let mut h = Histogram::new(0.0, 1.0, 2);
        h.push(-5.0);
        h.push(5.0);
        assert_eq!(h.counts, vec![1, 1]);
    }
}
