//! Wall-clock timing helpers.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    pub fn restart(&mut self) -> Duration {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Human-readable duration, e.g. "1.53s", "12.3ms", "850ns".
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Human-readable byte count, e.g. "1.25 MiB".
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_durations() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50ms");
        assert_eq!(fmt_duration(Duration::from_millis(2500)), "2.50s");
    }

    #[test]
    fn format_bytes() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.00 MiB");
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed() >= Duration::from_millis(1));
    }
}
