//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Grammar: `prog <subcommand> [positionals] [--key value]... [--flag]...`
//! Values may also be attached as `--key=value`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments in order (subcommand is `positional[0]`).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'"))
            })
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn basic_shapes() {
        let a = parse(&["figure", "fig6", "--rounds", "50", "--scale=full", "--quiet"]);
        assert_eq!(a.subcommand(), Some("figure"));
        assert_eq!(a.positional, vec!["figure", "fig6"]);
        assert_eq!(a.opt("rounds"), Some("50"));
        assert_eq!(a.opt("scale"), Some("full"));
        assert!(a.flag("quiet"));
        assert!(!a.flag("loud"));
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = parse(&["train", "--eta", "0.5"]);
        assert_eq!(a.opt_usize("rounds", 10), 10);
        assert!((a.opt_f64("eta", 0.1) - 0.5).abs() < 1e-12);
        assert_eq!(a.opt_or("codec", "cosine"), "cosine");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.opt("b"), Some("v"));
    }

    #[test]
    #[should_panic]
    fn bad_integer_panics() {
        let a = parse(&["x", "--rounds", "abc"]);
        a.opt_usize("rounds", 1);
    }
}
