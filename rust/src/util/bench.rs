//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! The `benches/*.rs` targets are built with `harness = false` and drive
//! this module directly: warm-up, timed iterations, and a one-line report
//! with mean / p50 / p95 and optional throughput.
//!
//! Results can be dumped as a machine-readable **perf trajectory**
//! (`BENCH_*.json`, schema [`TRAJECTORY_SCHEMA`]): one stable shape shared
//! by the compress and sim suites, so ns/elem numbers are comparable
//! across PRs (`repro bench --json`, `cargo bench --bench bench_kernel --
//! --json`, `cargo bench --bench bench_sim -- --json`). Each recording
//! **appends** a timestamped run to the file's `runs` array — the
//! committed `BENCH_*.json` baselines accumulate history instead of being
//! overwritten.

use std::hint::black_box;
use std::path::Path;
use std::time::{Duration, Instant};

use super::json::Json;
use super::timer::fmt_duration;

/// Schema tag for the perf-trajectory files.
pub const TRAJECTORY_SCHEMA: &str = "cossgd-bench/v1";

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    /// Optional bytes processed per iteration (for throughput reporting).
    pub bytes_per_iter: Option<u64>,
    /// Optional elements processed per iteration.
    pub elems_per_iter: Option<u64>,
}

impl BenchResult {
    /// Render the standard one-line report.
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>10}/iter  p50 {:>10}  p95 {:>10}  ({} iters)",
            self.name,
            fmt_duration(self.mean),
            fmt_duration(self.p50),
            fmt_duration(self.p95),
            self.iters
        );
        if let Some(b) = self.bytes_per_iter {
            let gibps = b as f64 / self.mean.as_secs_f64() / (1u64 << 30) as f64;
            s.push_str(&format!("  {gibps:>7.3} GiB/s"));
        }
        if let Some(e) = self.elems_per_iter {
            let meps = e as f64 / self.mean.as_secs_f64() / 1e6;
            s.push_str(&format!("  {meps:>9.1} Melem/s"));
        }
        s
    }

    /// Mean nanoseconds per element (the trajectory's primary metric),
    /// when the case was annotated with an element count.
    pub fn ns_per_elem(&self) -> Option<f64> {
        self.elems_per_iter
            .map(|e| self.mean.as_nanos() as f64 / e.max(1) as f64)
    }

    /// Machine-readable form for the trajectory file.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("name", self.name.as_str())
            .set("iters", self.iters)
            .set("mean_ns", self.mean.as_nanos() as f64)
            .set("p50_ns", self.p50.as_nanos() as f64)
            .set("p95_ns", self.p95.as_nanos() as f64);
        if let Some(e) = self.elems_per_iter {
            j = j.set("elems_per_iter", e).set(
                "ns_per_elem",
                self.ns_per_elem().unwrap_or(0.0),
            );
        }
        if let Some(bts) = self.bytes_per_iter {
            j = j.set("bytes_per_iter", bts).set(
                "gib_per_s",
                bts as f64 / self.mean.as_secs_f64() / (1u64 << 30) as f64,
            );
        }
        j
    }
}

/// One run's entry in the trajectory `runs` array.
fn run_json(results: &[BenchResult]) -> Json {
    let unix_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    Json::obj().set("unix_secs", unix_secs).set(
        "results",
        Json::Arr(results.iter().map(BenchResult::to_json).collect()),
    )
}

/// Assemble a fresh single-run trajectory document.
pub fn trajectory_json(suite: &str, results: &[BenchResult]) -> Json {
    Json::obj()
        .set("schema", TRAJECTORY_SCHEMA)
        .set("suite", suite)
        .set("runs", Json::Arr(vec![run_json(results)]))
}

/// **Append** one run to the `BENCH_<suite>`-style trajectory at `path`,
/// so the perf record *accumulates* across PRs instead of each run
/// overwriting the last. Creates the file if absent; a pre-existing file
/// with a matching suite keeps its history (legacy single-run files — a
/// top-level `results` array — are folded in as their first run); a
/// mismatched or unparseable file is started fresh.
pub fn write_trajectory(
    path: &Path,
    suite: &str,
    results: &[BenchResult],
) -> std::io::Result<()> {
    let mut runs: Vec<Json> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(doc) = Json::parse(&text) {
            if doc.get("suite").and_then(Json::as_str) == Some(suite) {
                if let Some(prior) = doc.get("runs").and_then(Json::as_arr) {
                    runs.extend(prior.iter().cloned());
                } else if let Some(legacy) = doc.get("results") {
                    runs.push(Json::obj().set("unix_secs", 0u64).set("results", legacy.clone()));
                }
            }
        }
    }
    runs.push(run_json(results));
    let doc = Json::obj()
        .set("schema", TRAJECTORY_SCHEMA)
        .set("suite", suite)
        .set("runs", Json::Arr(runs));
    std::fs::write(path, doc.pretty() + "\n")
}

/// `--quick` convention for `harness = false` bench binaries and
/// `repro bench`: cap sampling so CI smoke runs finish in seconds.
pub fn quick_requested() -> bool {
    std::env::args().any(|a| a == "--quick")
}

/// `--json` convention for the same binaries: record the trajectory file.
pub fn json_requested() -> bool {
    std::env::args().any(|a| a == "--json")
}

/// Benchmark runner with a time budget per case.
pub struct Bencher {
    /// Minimum sampling time per case after warm-up.
    pub min_time: Duration,
    /// Max iterations per case (guards very fast functions).
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // CI/bench default: enough samples for stable p50 without taking
        // minutes per target. Override with BENCH_MIN_TIME_MS.
        let ms = std::env::var("BENCH_MIN_TIME_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(300u64);
        Bencher {
            min_time: Duration::from_millis(ms),
            max_iters: 100_000,
            results: Vec::new(),
        }
    }

    /// Smoke-run configuration (`--quick`): a few samples per case, just
    /// enough to prove the path executes and emit a trajectory point.
    pub fn quick() -> Self {
        Bencher {
            min_time: Duration::from_millis(40),
            max_iters: 2_000,
            results: Vec::new(),
        }
    }

    /// Time `f` repeatedly; the closure's return value is black-boxed so the
    /// optimizer cannot elide the work.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_with_meta(name, None, None, &mut f)
    }

    /// Like [`bench`], annotating per-iteration bytes for GiB/s reporting.
    pub fn bench_bytes<T>(
        &mut self,
        name: &str,
        bytes: u64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_meta(name, Some(bytes), None, &mut f)
    }

    /// Like [`bench`], annotating per-iteration element count.
    pub fn bench_elems<T>(
        &mut self,
        name: &str,
        elems: u64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_meta(name, None, Some(elems), &mut f)
    }

    fn bench_with_meta<T>(
        &mut self,
        name: &str,
        bytes: Option<u64>,
        elems: Option<u64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        // Warm-up: at least one call, then until 10% of the budget (slow
        // cases — whole FL rounds — must not burn minutes warming up).
        let warm_budget = self.min_time / 10;
        let warm_start = Instant::now();
        let mut warm_iters = 0usize;
        while warm_iters < 1
            || (warm_iters < 3 && warm_start.elapsed() < warm_budget)
        {
            black_box(f());
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }

        // Sample (at least one).
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while samples.is_empty()
            || (start.elapsed() < self.min_time && samples.len() < self.max_iters)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let total: Duration = samples.iter().sum();
        let n = samples.len().max(1);
        let result = BenchResult {
            name: name.to_string(),
            iters: n,
            mean: total / n as u32,
            p50: samples[n / 2],
            p95: samples[(n as f64 * 0.95) as usize % n],
            bytes_per_iter: bytes,
            elems_per_iter: elems,
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher {
            min_time: Duration::from_millis(20),
            max_iters: 10_000,
            results: Vec::new(),
        };
        let r = b.bench("spin", || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean > Duration::from_nanos(1));
        assert!(r.iters >= 3);
    }

    #[test]
    fn trajectory_json_shape() {
        let mut b = Bencher {
            min_time: Duration::from_millis(5),
            max_iters: 50,
            results: Vec::new(),
        };
        b.bench_elems("case/a", 100, || 1 + 1);
        let j = trajectory_json("compress", b.results());
        assert_eq!(j.get("schema").unwrap().as_str(), Some(TRAJECTORY_SCHEMA));
        assert_eq!(j.get("suite").unwrap().as_str(), Some("compress"));
        let runs = j.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 1);
        let rs = runs[0].get("results").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].get("name").unwrap().as_str(), Some("case/a"));
        assert!(rs[0].get("ns_per_elem").unwrap().as_f64().unwrap() >= 0.0);
        // Round-trips through the in-tree JSON parser.
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
    }

    #[test]
    fn trajectory_file_accumulates_runs() {
        let dir = std::env::temp_dir().join("cossgd_bench_trajectory_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        std::fs::remove_file(&path).ok();
        let mut b = Bencher {
            min_time: Duration::from_millis(5),
            max_iters: 50,
            results: Vec::new(),
        };
        b.bench_elems("case/a", 10, || 1 + 1);
        // Three appends: the runs array grows; nothing is overwritten.
        for expect in 1..=3usize {
            write_trajectory(&path, "test", b.results()).unwrap();
            let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
            assert_eq!(doc.get("schema").unwrap().as_str(), Some(TRAJECTORY_SCHEMA));
            let runs = doc.get("runs").unwrap().as_arr().unwrap();
            assert_eq!(runs.len(), expect, "append #{expect}");
        }
        // A baseline skeleton with an empty runs array also accumulates.
        std::fs::write(
            &path,
            "{\"schema\": \"cossgd-bench/v1\", \"suite\": \"test\", \"runs\": []}\n",
        )
        .unwrap();
        write_trajectory(&path, "test", b.results()).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("runs").unwrap().as_arr().unwrap().len(), 1);
        // A different suite starts fresh rather than mixing histories.
        write_trajectory(&path, "other", b.results()).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("suite").unwrap().as_str(), Some("other"));
        assert_eq!(doc.get("runs").unwrap().as_arr().unwrap().len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn throughput_annotation() {
        let mut b = Bencher {
            min_time: Duration::from_millis(10),
            max_iters: 1000,
            results: Vec::new(),
        };
        let data = vec![1u8; 4096];
        let r = b.bench_bytes("sum4k", 4096, || data.iter().map(|&x| x as u64).sum::<u64>());
        assert_eq!(r.bytes_per_iter, Some(4096));
        assert!(r.report().contains("GiB/s"));
    }
}
