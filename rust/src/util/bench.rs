//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! The `benches/*.rs` targets are built with `harness = false` and drive
//! this module directly: warm-up, timed iterations, and a one-line report
//! with mean / p50 / p95 and optional throughput.

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::timer::fmt_duration;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    /// Optional bytes processed per iteration (for throughput reporting).
    pub bytes_per_iter: Option<u64>,
    /// Optional elements processed per iteration.
    pub elems_per_iter: Option<u64>,
}

impl BenchResult {
    /// Render the standard one-line report.
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>10}/iter  p50 {:>10}  p95 {:>10}  ({} iters)",
            self.name,
            fmt_duration(self.mean),
            fmt_duration(self.p50),
            fmt_duration(self.p95),
            self.iters
        );
        if let Some(b) = self.bytes_per_iter {
            let gibps = b as f64 / self.mean.as_secs_f64() / (1u64 << 30) as f64;
            s.push_str(&format!("  {gibps:>7.3} GiB/s"));
        }
        if let Some(e) = self.elems_per_iter {
            let meps = e as f64 / self.mean.as_secs_f64() / 1e6;
            s.push_str(&format!("  {meps:>9.1} Melem/s"));
        }
        s
    }
}

/// Benchmark runner with a time budget per case.
pub struct Bencher {
    /// Minimum sampling time per case after warm-up.
    pub min_time: Duration,
    /// Max iterations per case (guards very fast functions).
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // CI/bench default: enough samples for stable p50 without taking
        // minutes per target. Override with BENCH_MIN_TIME_MS.
        let ms = std::env::var("BENCH_MIN_TIME_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(300u64);
        Bencher {
            min_time: Duration::from_millis(ms),
            max_iters: 100_000,
            results: Vec::new(),
        }
    }

    /// Time `f` repeatedly; the closure's return value is black-boxed so the
    /// optimizer cannot elide the work.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_with_meta(name, None, None, &mut f)
    }

    /// Like [`bench`], annotating per-iteration bytes for GiB/s reporting.
    pub fn bench_bytes<T>(
        &mut self,
        name: &str,
        bytes: u64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_meta(name, Some(bytes), None, &mut f)
    }

    /// Like [`bench`], annotating per-iteration element count.
    pub fn bench_elems<T>(
        &mut self,
        name: &str,
        elems: u64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_meta(name, None, Some(elems), &mut f)
    }

    fn bench_with_meta<T>(
        &mut self,
        name: &str,
        bytes: Option<u64>,
        elems: Option<u64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        // Warm-up: at least one call, then until 10% of the budget (slow
        // cases — whole FL rounds — must not burn minutes warming up).
        let warm_budget = self.min_time / 10;
        let warm_start = Instant::now();
        let mut warm_iters = 0usize;
        while warm_iters < 1
            || (warm_iters < 3 && warm_start.elapsed() < warm_budget)
        {
            black_box(f());
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }

        // Sample (at least one).
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while samples.is_empty()
            || (start.elapsed() < self.min_time && samples.len() < self.max_iters)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let total: Duration = samples.iter().sum();
        let n = samples.len().max(1);
        let result = BenchResult {
            name: name.to_string(),
            iters: n,
            mean: total / n as u32,
            p50: samples[n / 2],
            p95: samples[(n as f64 * 0.95) as usize % n],
            bytes_per_iter: bytes,
            elems_per_iter: elems,
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher {
            min_time: Duration::from_millis(20),
            max_iters: 10_000,
            results: Vec::new(),
        };
        let r = b.bench("spin", || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean > Duration::from_nanos(1));
        assert!(r.iters >= 3);
    }

    #[test]
    fn throughput_annotation() {
        let mut b = Bencher {
            min_time: Duration::from_millis(10),
            max_iters: 1000,
            results: Vec::new(),
        };
        let data = vec![1u8; 4096];
        let r = b.bench_bytes("sum4k", 4096, || data.iter().map(|&x| x as u64).sum::<u64>());
        assert_eq!(r.bytes_per_iter, Some(4096));
        assert!(r.report().contains("GiB/s"));
    }
}
