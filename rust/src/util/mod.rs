//! Offline substrates: the environment vendors only the `xla` crate's
//! dependency tree, so the usual ecosystem crates (rand, serde, clap,
//! criterion, proptest) are replaced by small, tested, in-tree
//! implementations.

pub mod bench;
pub mod cli;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod timer;
