//! The PJRT execution engine.
//!
//! Wraps the `xla` crate: one CPU client, one lazily-compiled
//! [`xla::PjRtLoadedExecutable`] per artifact (cached for the life of the
//! process), manifest-driven input validation and output unmarshalling.
//!
//! HLO *text* is the interchange format (see `aot.py` / DESIGN.md): the
//! text parser reassigns instruction ids, avoiding xla_extension 0.5.1's
//! 64-bit-id proto rejection.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use super::manifest::{Dtype, Manifest};

/// A typed input value for an artifact call.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
    ScalarF32(f32),
}

impl Value {
    fn elements(&self) -> usize {
        match self {
            Value::F32(v) => v.len(),
            Value::I32(v) => v.len(),
            Value::ScalarF32(_) => 1,
        }
    }

    fn dtype(&self) -> Dtype {
        match self {
            Value::F32(_) | Value::ScalarF32(_) => Dtype::F32,
            Value::I32(_) => Dtype::I32,
        }
    }

    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::ScalarF32(x) => return Ok(xla::Literal::scalar(*x)),
            Value::F32(v) => xla::Literal::vec1(v),
            Value::I32(v) => xla::Literal::vec1(v),
        };
        if shape.is_empty() {
            // () scalar passed as a 1-element vec.
            lit.reshape(&[]).map_err(|e| anyhow!("reshape scalar: {e:?}"))
        } else {
            lit.reshape(&dims)
                .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
        }
    }
}

/// One decoded output tensor.
#[derive(Debug, Clone)]
pub enum OutValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl OutValue {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            OutValue::F32(v) => Ok(v),
            OutValue::I32(_) => bail!("output is i32, expected f32"),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            OutValue::I32(v) => Ok(v),
            OutValue::F32(_) => bail!("output is f32, expected i32"),
        }
    }
    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        anyhow::ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
        Ok(v[0])
    }
}

/// The engine: PJRT client + executable cache + manifest.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<BTreeMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: moving an `Engine` between threads is sound: the underlying
// PJRT C++ objects are not thread-affine (the `xla` crate types merely
// wrap raw pointers and lack auto traits only because raw pointers
// suppress them), `Manifest` is plain owned data, and the executable
// cache is an owned `Mutex`. Literals are created and consumed
// thread-locally per call.
unsafe impl Send for Engine {}
// SAFETY: sharing `&Engine` across the client worker threads of
// `fl::runner` is sound by the same argument as `Send` above, plus: the
// PJRT C++ API guarantees `PjRtClient::Compile` and
// `PjRtLoadedExecutable::Execute` are thread-safe (concurrent executions
// of one loaded executable are a core PJRT use case), and all Rust-side
// mutability (the executable cache) is behind the `Mutex`.
unsafe impl Sync for Engine {}

impl Engine {
    /// Create from an artifacts directory (must contain `manifest.json`).
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Engine {
            manifest,
            client,
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    /// Default artifacts location relative to the crate root.
    pub fn load_default() -> Result<Engine> {
        Self::load(Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    /// Compile (or fetch from cache) the executable for `name`.
    fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        // Compile outside the lock (it can take seconds); racing threads
        // may compile the same artifact once each, but the first insert
        // wins and both results are equivalent.
        let spec = self.manifest.artifact(name)?;
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .map_err(|e| anyhow!("parse {:?}: {e:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let exe = Arc::new(exe);
        let exe = self
            .cache
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(exe)
            .clone();
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (so first-round latency is paid
    /// up front at launch).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute artifact `name` with manifest-validated inputs; returns one
    /// [`OutValue`] per output in the lowered tuple.
    pub fn exec(&self, name: &str, inputs: &[Value]) -> Result<Vec<OutValue>> {
        let spec = self.manifest.artifact(name)?.clone();
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "{name}: {} inputs given, {} expected",
            inputs.len(),
            spec.inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (val, io) in inputs.iter().zip(&spec.inputs) {
            anyhow::ensure!(
                val.dtype() == io.dtype,
                "{name}.{}: dtype mismatch",
                io.name
            );
            anyhow::ensure!(
                val.elements() == io.elements(),
                "{name}.{}: {} elements given, shape {:?} needs {}",
                io.name,
                val.elements(),
                io.shape,
                io.elements()
            );
            literals.push(val.to_literal(&io.shape)?);
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always one tuple layer.
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let ty = lit.ty().map_err(|e| anyhow!("ty: {e:?}"))?;
                match ty {
                    xla::ElementType::F32 => Ok(OutValue::F32(
                        lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?,
                    )),
                    xla::ElementType::S32 => Ok(OutValue::I32(
                        lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))?,
                    )),
                    other => bail!("unsupported output type {other:?}"),
                }
            })
            .collect()
    }

    /// Number of artifacts compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

// ---------------------------------------------------------------------------
// High-level typed wrappers used by the FL layer.
// ---------------------------------------------------------------------------

impl Engine {
    /// Run a whole local round: returns `(delta, mean_loss)`.
    #[allow(clippy::too_many_arguments)]
    pub fn local_round(
        &self,
        artifact: &str,
        params: &[f32],
        x: Vec<f32>,
        y: Vec<i32>,
        perms: Vec<i32>,
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let out = self.exec(
            artifact,
            &[
                Value::F32(params.to_vec()),
                Value::F32(x),
                Value::I32(y),
                Value::I32(perms),
                Value::ScalarF32(lr),
            ],
        )?;
        anyhow::ensure!(out.len() == 2, "{artifact}: expected (delta, loss)");
        let delta = out[0].as_f32()?.to_vec();
        let loss = out[1].scalar_f32()?;
        Ok((delta, loss))
    }

    /// Classification eval: `(accuracy, mean_loss)` over `n` examples.
    pub fn classification_eval(
        &self,
        artifact: &str,
        params: &[f32],
        x: Vec<f32>,
        y: Vec<i32>,
        n: usize,
    ) -> Result<(f64, f32)> {
        let out = self.exec(
            artifact,
            &[Value::F32(params.to_vec()), Value::F32(x), Value::I32(y)],
        )?;
        let correct = out[0].scalar_f32()? as f64;
        let loss = out[1].scalar_f32()?;
        Ok((correct / n as f64, loss))
    }

    /// Segmentation eval: mean dice over classes 1.. (background excluded)
    /// plus the mean loss.
    pub fn segmentation_eval(
        &self,
        artifact: &str,
        params: &[f32],
        x: Vec<f32>,
        y: Vec<i32>,
    ) -> Result<(f64, f32)> {
        let out = self.exec(
            artifact,
            &[Value::F32(params.to_vec()), Value::F32(x), Value::I32(y)],
        )?;
        let inter = out[0].as_f32()?;
        let psum = out[1].as_f32()?;
        let tsum = out[2].as_f32()?;
        let loss = out[3].scalar_f32()?;
        let mut dice_sum = 0.0f64;
        let mut classes = 0usize;
        for c in 1..inter.len() {
            let denom = (psum[c] + tsum[c]) as f64;
            if denom > 0.0 {
                dice_sum += 2.0 * inter[c] as f64 / denom;
                classes += 1;
            }
        }
        Ok((dice_sum / classes.max(1) as f64, loss))
    }

    /// Per-step gradient (Fig. 4): `(grad, loss)`.
    pub fn grad_step(
        &self,
        params: &[f32],
        x: Vec<f32>,
        y: Vec<i32>,
    ) -> Result<(Vec<f32>, f32)> {
        let out = self.exec(
            "mnist_grad",
            &[Value::F32(params.to_vec()), Value::F32(x), Value::I32(y)],
        )?;
        Ok((out[0].as_f32()?.to_vec(), out[1].scalar_f32()?))
    }

    /// Quantize a gradient through the Pallas kernel artifact, chunk by
    /// chunk (pad with zeros; returns one code per input element).
    pub fn kernel_quantize(
        &self,
        bits: u8,
        g: &[f32],
        norm: f32,
        bound: f32,
        u: &[f32],
    ) -> Result<Vec<u16>> {
        let chunk = self.manifest.chunk;
        let name = format!("quant_cos_{bits}");
        let mut codes = Vec::with_capacity(g.len());
        for (gs, us) in g.chunks(chunk).zip(u.chunks(chunk)) {
            let mut gbuf = gs.to_vec();
            let mut ubuf = us.to_vec();
            gbuf.resize(chunk, 0.0);
            ubuf.resize(chunk, 0.5);
            let out = self.exec(
                &name,
                &[
                    Value::F32(gbuf),
                    Value::ScalarF32(norm),
                    Value::ScalarF32(bound),
                    Value::F32(ubuf),
                ],
            )?;
            let chunk_codes = out[0].as_i32()?;
            codes.extend(chunk_codes[..gs.len()].iter().map(|&c| c as u16));
        }
        Ok(codes)
    }

    /// Dequantize codes through the Pallas kernel artifact.
    pub fn kernel_dequantize(
        &self,
        bits: u8,
        codes: &[u16],
        norm: f32,
        bound: f32,
    ) -> Result<Vec<f32>> {
        let chunk = self.manifest.chunk;
        let name = format!("dequant_cos_{bits}");
        let mut out_vals = Vec::with_capacity(codes.len());
        for cs in codes.chunks(chunk) {
            let mut cbuf: Vec<i32> = cs.iter().map(|&c| c as i32).collect();
            cbuf.resize(chunk, 0);
            let out = self.exec(
                &name,
                &[
                    Value::I32(cbuf),
                    Value::ScalarF32(norm),
                    Value::ScalarF32(bound),
                ],
            )?;
            out_vals.extend_from_slice(&out[0].as_f32()?[..cs.len()]);
        }
        Ok(out_vals)
    }
}
