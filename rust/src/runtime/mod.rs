//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them on the request path.
//!
//! Python never runs here — the [`Engine`] compiles HLO **text** through
//! the `xla` crate's PJRT CPU client once per artifact (cached) and then
//! serves every federated round from Rust.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Value};
pub use manifest::{ArtifactSpec, IoSpec, LayerSpec, Manifest, ModelSpec};
