//! Manifest parsing: `artifacts/manifest.json` describes every artifact's
//! I/O signature plus per-model metadata (flat-parameter layout, init
//! scheme, round configuration). Written by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// dtype tags used on the artifact boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(tag: &str) -> Result<Dtype> {
        match tag {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(anyhow!("unknown dtype tag {other}")),
        }
    }
}

/// One artifact input or output.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact (an HLO module).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
}

/// One layer of a model's flat parameter vector.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub init: String,
    pub fan_in: usize,
}

/// Model metadata (parameter layout + round configuration).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub param_count: usize,
    pub classes: usize,
    pub optimizer: String,
    pub input_shape: Vec<usize>,
    pub layers: Vec<LayerSpec>,
}

/// Round configuration (n_data / batch / epochs / eval_n) per config key.
#[derive(Debug, Clone, Copy)]
pub struct RoundCfg {
    pub n_data: usize,
    pub batch: usize,
    pub epochs: usize,
    pub eval_n: usize,
}

impl RoundCfg {
    pub fn steps(&self) -> usize {
        self.epochs * (self.n_data / self.batch)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub chunk: usize,
    pub kernel_bits: Vec<u8>,
    pub grad_batch: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelSpec>,
    pub round_cfg: BTreeMap<String, RoundCfg>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        Self::from_json(&json, dir)
    }

    fn from_json(json: &Json, dir: PathBuf) -> Result<Manifest> {
        let io_spec = |j: &Json, idx: usize| -> Result<IoSpec> {
            Ok(IoSpec {
                name: j
                    .get("name")
                    .and_then(Json::as_str)
                    .unwrap_or(&format!("out{idx}"))
                    .to_string(),
                dtype: Dtype::parse(
                    j.get("dtype").and_then(Json::as_str).context("dtype")?,
                )?,
                shape: j
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("shape")?
                    .iter()
                    .map(|v| v.as_usize().context("dim"))
                    .collect::<Result<_>>()?,
            })
        };

        let mut artifacts = BTreeMap::new();
        for (name, art) in json
            .get("artifacts")
            .and_then(Json::as_obj)
            .context("artifacts")?
        {
            let inputs = art
                .get("inputs")
                .and_then(Json::as_arr)
                .context("inputs")?
                .iter()
                .enumerate()
                .map(|(i, j)| io_spec(j, i))
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(art.get("file").and_then(Json::as_str).context("file")?),
                    inputs,
                },
            );
        }

        let mut models = BTreeMap::new();
        for (name, m) in json.get("models").and_then(Json::as_obj).context("models")? {
            let layers = m
                .get("layers")
                .and_then(Json::as_arr)
                .context("layers")?
                .iter()
                .map(|l| -> Result<LayerSpec> {
                    Ok(LayerSpec {
                        name: l.get("name").and_then(Json::as_str).context("lname")?.into(),
                        shape: l
                            .get("shape")
                            .and_then(Json::as_arr)
                            .context("lshape")?
                            .iter()
                            .map(|v| v.as_usize().context("dim"))
                            .collect::<Result<_>>()?,
                        offset: l.get("offset").and_then(Json::as_usize).context("off")?,
                        size: l.get("size").and_then(Json::as_usize).context("size")?,
                        init: l.get("init").and_then(Json::as_str).context("init")?.into(),
                        fan_in: l.get("fan_in").and_then(Json::as_usize).context("fan")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelSpec {
                    name: name.clone(),
                    param_count: m
                        .get("param_count")
                        .and_then(Json::as_usize)
                        .context("param_count")?,
                    classes: m.get("classes").and_then(Json::as_usize).context("classes")?,
                    optimizer: m
                        .get("optimizer")
                        .and_then(Json::as_str)
                        .context("optimizer")?
                        .into(),
                    input_shape: m
                        .get("input_shape")
                        .and_then(Json::as_arr)
                        .context("input_shape")?
                        .iter()
                        .map(|v| v.as_usize().context("dim"))
                        .collect::<Result<_>>()?,
                    layers,
                },
            );
        }

        let mut round_cfg = BTreeMap::new();
        for (name, c) in json
            .get("round_cfg")
            .and_then(Json::as_obj)
            .context("round_cfg")?
        {
            round_cfg.insert(
                name.clone(),
                RoundCfg {
                    n_data: c.get("n_data").and_then(Json::as_usize).context("n_data")?,
                    batch: c.get("batch").and_then(Json::as_usize).context("batch")?,
                    epochs: c.get("epochs").and_then(Json::as_usize).context("epochs")?,
                    eval_n: c.get("eval_n").and_then(Json::as_usize).context("eval_n")?,
                },
            );
        }

        Ok(Manifest {
            dir,
            chunk: json.get("chunk").and_then(Json::as_usize).unwrap_or(65536),
            kernel_bits: json
                .get("kernel_bits")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|v| v.as_usize()).map(|b| b as u8).collect())
                .unwrap_or_else(|| vec![1, 2, 4, 8]),
            grad_batch: json.get("grad_batch").and_then(Json::as_usize).unwrap_or(64),
            artifacts,
            models,
            round_cfg,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }

    pub fn round(&self, key: &str) -> Result<RoundCfg> {
        self.round_cfg
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("round cfg '{key}' not in manifest"))
    }
}

/// Deterministic parameter initialization from the manifest layer specs
/// (mirrors `python/tests/test_models.py::init_flat` — He normal for "he",
/// Glorot uniform for "glorot", zeros for "zero").
pub fn init_params(model: &ModelSpec, seed: u64) -> Vec<f32> {
    use crate::util::rng::Pcg64;
    let mut flat = vec![0.0f32; model.param_count];
    let mut rng = Pcg64::new(seed, 0x1217);
    for layer in &model.layers {
        match layer.init.as_str() {
            "he" => {
                let std = (2.0 / layer.fan_in as f64).sqrt() as f32;
                for v in &mut flat[layer.offset..layer.offset + layer.size] {
                    *v = rng.normal_f32(0.0, std);
                }
            }
            "glorot" => {
                let fan_out = *layer.shape.last().unwrap_or(&layer.size);
                let limit = (6.0 / (layer.fan_in + fan_out) as f64).sqrt();
                for v in &mut flat[layer.offset..layer.offset + layer.size] {
                    *v = rng.range_f64(-limit, limit) as f32;
                }
            }
            _ => {} // zero
        }
    }
    flat
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_json() -> Json {
        Json::parse(
            r#"{
          "version": 1, "chunk": 65536, "kernel_bits": [2, 8], "grad_batch": 64,
          "artifacts": {
            "toy": {"file": "toy.hlo.txt",
              "inputs": [{"name": "params", "dtype": "f32", "shape": [10]},
                         {"name": "y", "dtype": "i32", "shape": [2, 3]}]}
          },
          "models": {
            "m": {"param_count": 10, "classes": 2, "optimizer": "sgd",
              "weight_decay": 0, "input_shape": [4],
              "layers": [
                {"name": "w", "shape": [4, 2], "offset": 0, "size": 8, "init": "he", "fan_in": 4},
                {"name": "b", "shape": [2], "offset": 8, "size": 2, "init": "zero", "fan_in": 2}]}
          },
          "round_cfg": {"m": {"n_data": 8, "batch": 4, "epochs": 2, "eval_n": 4}}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&sample_manifest_json(), PathBuf::from("/a")).unwrap();
        let art = m.artifact("toy").unwrap();
        assert_eq!(art.inputs.len(), 2);
        assert_eq!(art.inputs[1].dtype, Dtype::I32);
        assert_eq!(art.inputs[1].elements(), 6);
        assert_eq!(art.file, PathBuf::from("/a/toy.hlo.txt"));
        let model = m.model("m").unwrap();
        assert_eq!(model.param_count, 10);
        assert_eq!(m.round("m").unwrap().steps(), 4);
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn init_params_respects_layout() {
        let m = Manifest::from_json(&sample_manifest_json(), PathBuf::from("/a")).unwrap();
        let model = m.model("m").unwrap();
        let p = init_params(model, 1);
        assert_eq!(p.len(), 10);
        assert!(p[..8].iter().any(|&x| x != 0.0), "he layer initialized");
        assert!(p[8..].iter().all(|&x| x == 0.0), "bias zero");
        // Deterministic.
        assert_eq!(p, init_params(model, 1));
        assert_ne!(p, init_params(model, 2));
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Integration-ish: if `make artifacts` has run, the real manifest
        // must parse and contain the expected artifact set.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        for name in [
            "mnist_round", "cifar_round", "cifar_round_e1", "unet_round",
            "mnist_eval", "cifar_eval", "unet_eval", "mnist_grad",
            "quant_cos_2", "dequant_cos_8",
        ] {
            assert!(m.artifacts.contains_key(name), "{name}");
            assert!(m.artifact(name).unwrap().file.exists(), "{name} file");
        }
        assert_eq!(m.model("mnist").unwrap().param_count, 1_663_370);
        assert_eq!(m.model("cifar").unwrap().param_count, 122_570);
    }
}
