//! FedAvg server: an **incremental frame-ingest state machine** that holds
//! the global model and applies Eq. (1):
//!
//! `M^{t+1} = M^t − η_s · Σ_i ∇M_i · N_i / Σ_i N_i`
//!
//! where `∇M_i` is client i's *decoded* update (`g = M_in − M*`) and `N_i`
//! its local example count — and produces the per-round model broadcast.
//!
//! ## Frame ingest
//!
//! The server consumes opaque [`Frame`] envelopes one at a time:
//! [`Server::ingest`] checks the envelope (sender, round window,
//! duplicate) in O(1), validates the wire payload only for frames that
//! survive, and — when the frame is good — **fuses dequantize and
//! accumulate in a single pass
//! over the packed codes** ([`crate::compress::pipeline::accumulate_with`]):
//! no intermediate `Vec<f32>` per client. Each verdict
//! ([`Ingest::Accepted`], [`Ingest::Duplicate`], [`Ingest::StaleRound`],
//! [`Ingest::Malformed`]) is returned to the caller; only `Accepted`
//! touches the accumulator. Client aggregation weights (`N_i`) are
//! registered up front via [`Server::with_clients`] — FedAvg deployments
//! know shard sizes at selection time, so the weight never rides the wire.
//!
//! Ingest is split validate → route → accumulate:
//! [`Server::ingest_prepare`] runs every check and commits the verdict
//! bookkeeping, returning an accepted frame as a validated
//! [`PreparedFrame`]; the fold is either immediate ([`Server::ingest`])
//! or deferred onto the sharded parallel plane of [`crate::fl::ingest`]
//! — both run the same sub-range kernel, so shard count never changes
//! results.
//!
//! ## Round modes
//!
//! * [`RoundMode::Synchronous`] — classic FedAvg: the round's frames carry
//!   the current round tag; anything else is [`Ingest::StaleRound`]. The
//!   driver decides when to call [`Server::finish_round`].
//! * [`RoundMode::BufferedAsync`] — FedBuff-style buffered aggregation:
//!   frames may arrive tagged with any model version within
//!   `max_staleness` of the current one and are folded in with a
//!   staleness-discounted weight `N_i / (1 + staleness)`; the server
//!   signals [`Server::ready_to_apply`] once `buffer_k` updates have been
//!   buffered. Older frames are rejected as [`Ingest::StaleRound`].
//!
//! ## Downlink modes
//!
//! * [`Downlink::Float32Model`] (default) — the raw float32 model, metered
//!   at exactly `4·n` bytes per receiving client: byte-identical to the
//!   CSG1-era cost accounting.
//! * [`Downlink::Delta`] — the paper's *round-trip* scheme: the server
//!   encodes the model delta `Δ = M^{t+1} − M^t` through a downlink
//!   [`Pipeline`] and advances an internal replica by the **decoded**
//!   delta, so server and clients agree bit-exactly on the degraded model
//!   the fleet trains from. The replica starts at the initial model (the
//!   shared-initialization assumption of Algorithm 1), so round 0
//!   broadcasts a zero delta.
//!
//! Uplink decoding is self-describing (CSG2): the server needs no codec
//! configuration to receive updates.

use anyhow::{bail, Result};

use crate::compress::allocator::SegmentObs;
use crate::compress::pipeline::{
    accumulate_with, decode_with, Direction, EncodeScratch, EncodedTensor, Pipeline,
    PipelineState,
};
use crate::compress::wire;
use crate::util::rng::Pcg64;

use super::ingest::{self, PreparedFrame, PreparedSegment};
use super::transport::Frame;

/// When does the server fold its buffered updates into the model?
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundMode {
    /// Classic FedAvg: one aggregation per communication round; every
    /// frame must carry the current round tag.
    Synchronous,
    /// FedBuff-style buffered asynchronous aggregation: apply as soon as
    /// `buffer_k` updates are buffered; accept frames trained on any model
    /// version at most `max_staleness` behind the current one, with
    /// staleness-discounted weights `N_i / (1 + staleness)`.
    BufferedAsync {
        /// Updates buffered per aggregation.
        buffer_k: usize,
        /// Oldest accepted model-version lag.
        max_staleness: usize,
    },
}

impl RoundMode {
    /// Parse the CLI grammar: `sync`, `async:K`, or `async:K:S`
    /// (`S` defaults to 2 model versions).
    pub fn parse(s: &str) -> Result<RoundMode> {
        if s == "sync" || s == "synchronous" {
            return Ok(RoundMode::Synchronous);
        }
        if let Some(rest) = s.strip_prefix("async:") {
            let mut parts = rest.splitn(2, ':');
            let buffer_k: usize = parts
                .next()
                .unwrap_or_default()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad buffer size in --round-mode '{s}'"))?;
            anyhow::ensure!(buffer_k > 0, "--round-mode async needs a buffer of ≥ 1");
            let max_staleness: usize = match parts.next() {
                Some(p) => p
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad staleness bound in --round-mode '{s}'"))?,
                None => 2,
            };
            return Ok(RoundMode::BufferedAsync {
                buffer_k,
                max_staleness,
            });
        }
        bail!("unknown round mode '{s}' (sync, async:K, async:K:S)")
    }

    /// Compact label for logs / results files.
    pub fn name(&self) -> String {
        match self {
            RoundMode::Synchronous => "sync".into(),
            RoundMode::BufferedAsync {
                buffer_k,
                max_staleness,
            } => format!("async:{buffer_k} (≤{max_staleness} stale)"),
        }
    }
}

/// The verdict of one [`Server::ingest`] call. Only [`Ingest::Accepted`]
/// touches the accumulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ingest {
    /// Folded into the open aggregate (staleness 0 in synchronous mode).
    Accepted {
        /// Model versions behind the current one the update was trained on.
        staleness: usize,
    },
    /// This client already contributed to the open aggregate.
    Duplicate,
    /// The frame's round tag falls outside the acceptance window (older
    /// than `max_staleness`, or not the open round in synchronous mode).
    StaleRound,
    /// The envelope or payload failed validation: unregistered client,
    /// undecodable wire bytes, wrong direction, or wrong tensor length.
    Malformed,
}

/// Server → client compression policy.
#[derive(Debug, Clone)]
pub enum Downlink {
    /// Legacy raw float32 model broadcast (`4·n` bytes, no framing).
    Float32Model,
    /// Quantized model delta through a downlink pipeline (CSG2 frame).
    Delta(Pipeline),
}

impl Downlink {
    /// Human label for logs / results files.
    pub fn name(&self) -> String {
        match self {
            Downlink::Float32Model => "float32 model".into(),
            Downlink::Delta(p) => format!("Δ {}", p.name()),
        }
    }
}

/// One round's model broadcast. The broadcast *content* is not duplicated
/// here: in legacy mode it is exactly [`Server::params`]; in Delta mode
/// clients reconstruct it by decoding `wire`, and the server's own copy is
/// readable via [`Server::replica`].
pub struct Broadcast {
    /// The CSG2 frame (None for the raw float32 legacy broadcast).
    pub wire: Option<Vec<u8>>,
    /// Bytes on the wire per receiving client.
    pub bytes: usize,
    /// What the downlink DEFLATE stage did (None when the pipeline skips
    /// DEFLATE or in legacy float32 mode) — chunk / thread / byte counts
    /// for round telemetry.
    pub deflate: Option<crate::compress::deflate::DeflateStats>,
}

/// The global model + aggregation state.
pub struct Server {
    pub params: Vec<f32>,
    pub eta_s: f32,
    downlink: Downlink,
    /// The model as the client fleet currently holds it (Delta mode).
    replica: Vec<f32>,
    /// Downlink pipeline memory (EF residual, if enabled) + seed lane.
    state: PipelineState,
    /// Reusable encode/decode buffers (uplink ingest + downlink encode):
    /// steady-state rounds run the compression stages allocation-free.
    scratch: EncodeScratch,
    rng: Pcg64,
    /// Weighted-sum accumulator for the current round.
    acc: Vec<f64>,
    weight_sum: f64,
    updates_this_round: usize,
    /// Aggregation policy for [`Server::ingest`].
    mode: RoundMode,
    /// Open round index / model version (increments on
    /// [`Server::finish_round`]). Frames are tagged with the version they
    /// trained from.
    round: usize,
    /// Registered per-client aggregation weights (`N_i`, example counts).
    /// A frame from an unregistered client id is [`Ingest::Malformed`].
    client_weights: Vec<u32>,
    /// Round stamp of each client's last accepted contribution
    /// (`round + 1`; 0 = never) — O(1) duplicate detection with no
    /// per-round clearing sweep.
    contributed: Vec<u64>,
    /// Per-segment wire-header observations accumulated over the open
    /// round's accepted frames — the adaptive bit controller's free
    /// per-layer signal (`n`, `bits`, `norm`, `bound` all live in the
    /// CSG2 header; no payload access). Reset by [`Server::finish_round`].
    obs_round: Vec<ObsAcc>,
    /// Refused-frame tallies for the open round (duplicate / stale /
    /// malformed), behind [`Server::round_verdicts`]. Reset by
    /// [`Server::finish_round`] — refused frames used to vanish from every
    /// artifact, which hid the PR 6 fuzz findings.
    dup_this_round: usize,
    stale_this_round: usize,
    malformed_this_round: usize,
}

/// Accumulator behind [`Server::round_observations`]: RMS of the segment
/// norms across accepted frames, latest width/bound.
#[derive(Debug, Clone)]
struct ObsAcc {
    n: usize,
    bits: u8,
    norm_sq_sum: f64,
    bound: f32,
    /// Sum of as-traveled segment bytes (header + post-DEFLATE payload)
    /// across accepted frames — the allocator's measured-cost signal.
    wire_bytes_sum: u64,
    count: u64,
}

impl Server {
    pub fn new(params: Vec<f32>, eta_s: f32) -> Server {
        let n = params.len();
        Server {
            replica: params.clone(),
            params,
            eta_s,
            downlink: Downlink::Float32Model,
            state: PipelineState::new(),
            scratch: EncodeScratch::new(),
            rng: Pcg64::new(0, 0xD0_417),
            acc: vec![0.0; n],
            weight_sum: 0.0,
            updates_this_round: 0,
            mode: RoundMode::Synchronous,
            round: 0,
            client_weights: Vec::new(),
            contributed: Vec::new(),
            obs_round: Vec::new(),
            dup_this_round: 0,
            stale_this_round: 0,
            malformed_this_round: 0,
        }
    }

    /// Configure the downlink policy; `seed` drives the downlink
    /// pipeline's stochastic stages (mask/rotation seeds, rounding).
    pub fn with_downlink(mut self, downlink: Downlink, seed: u64) -> Server {
        self.downlink = downlink;
        self.rng = Pcg64::new(seed, 0xD0_417);
        self
    }

    /// Register the fleet's aggregation weights (`N_i` per client id) —
    /// required before [`Server::ingest`] will accept frames.
    pub fn with_clients(mut self, weights: Vec<u32>) -> Server {
        self.contributed = vec![0; weights.len()];
        self.client_weights = weights;
        self
    }

    /// Select the aggregation policy (default [`RoundMode::Synchronous`]).
    pub fn with_round_mode(mut self, mode: RoundMode) -> Server {
        self.mode = mode;
        self
    }

    /// The open round index / model version (frames train against this).
    pub fn round(&self) -> usize {
        self.round
    }

    /// Updates buffered in the open aggregate so far.
    pub fn buffered(&self) -> usize {
        self.updates_this_round
    }

    /// In buffered-async mode: has the buffer filled? (Synchronous mode
    /// always returns false — the driver owns the round boundary.)
    pub fn ready_to_apply(&self) -> bool {
        match self.mode {
            RoundMode::Synchronous => false,
            RoundMode::BufferedAsync { buffer_k, .. } => self.updates_this_round >= buffer_k,
        }
    }

    /// The duplicate-detection stamp of the open round.
    fn stamp(&self) -> u64 {
        self.round as u64 + 1
    }

    /// Consume one uplink frame: validate, window-check, dedupe, and fold
    /// the update into the open aggregate in a single fused pass over the
    /// packed codes. Non-`Accepted` verdicts leave the accumulator (and
    /// every other piece of server state) untouched.
    ///
    /// This is [`Server::ingest_prepare`] plus an immediate fold through
    /// the *same* sub-range kernel the sharded ingest plane runs
    /// ([`crate::fl::ingest`]) — so serial ingest and `--ingest-shards N`
    /// cannot drift apart: they are one code path at different cut
    /// counts.
    pub fn ingest(&mut self, frame: &Frame) -> Ingest {
        let (verdict, prepared) = self.ingest_prepare(frame);
        if let Some(p) = prepared {
            // Prepared frames are pre-validated, so the fold is
            // infallible in practice; stay fallible anyway — ingest must
            // never panic on any input.
            let folded = ingest::fold_frame(&p, &mut self.acc, &mut self.scratch);
            debug_assert!(folded.is_ok(), "prepared frame failed to fold: {folded:?}");
        }
        verdict
    }

    /// The validate → commit half of [`Server::ingest`], with the
    /// accumulator fold *deferred*: every envelope and payload check
    /// runs, the verdict tallies / duplicate stamp / weight sum /
    /// round observations update exactly as serial ingest would — but
    /// instead of touching the accumulator, an accepted frame comes back
    /// as a [`PreparedFrame`] (validated, inflated, weight fixed at
    /// accept time) for the caller to queue on an
    /// [`crate::fl::ingest::IngestPlane`]. Callers must flush the plane
    /// before reading round results.
    ///
    /// Verdict precedence: the O(1) *envelope* checks run first —
    /// unregistered sender, round window, duplicate — so a frame the
    /// server would discard anyway never pays payload deserialization
    /// (the ingest hot path on straggler fleets is mostly rejections).
    /// Payload validation (wire header, direction, tensor length,
    /// inflate) runs only for frames that would otherwise be accepted,
    /// and is all-or-nothing: a malformed tail segment has no side
    /// effects.
    pub fn ingest_prepare(&mut self, frame: &Frame) -> (Ingest, Option<PreparedFrame>) {
        let (verdict, prepared) = self.classify_and_prepare(frame);
        match verdict {
            Ingest::Accepted { .. } => {}
            Ingest::Duplicate => self.dup_this_round += 1,
            Ingest::StaleRound => self.stale_this_round += 1,
            Ingest::Malformed => self.malformed_this_round += 1,
        }
        (verdict, prepared)
    }

    fn classify_and_prepare(&mut self, frame: &Frame) -> (Ingest, Option<PreparedFrame>) {
        let Some(&n_i) = self.client_weights.get(frame.client_id) else {
            return (Ingest::Malformed, None);
        };
        let staleness = match self.mode {
            RoundMode::Synchronous => {
                if frame.round != self.round {
                    return (Ingest::StaleRound, None);
                }
                0
            }
            RoundMode::BufferedAsync { max_staleness, .. } => {
                if frame.round > self.round {
                    // A version the server never broadcast: outside the
                    // acceptance window just like an expired one.
                    return (Ingest::StaleRound, None);
                }
                let s = self.round - frame.round;
                if s > max_staleness {
                    return (Ingest::StaleRound, None);
                }
                s
            }
        };
        // client_weights and contributed are sized together in new(), so
        // the get() above already proved this id in range; stay fallible
        // anyway — ingest must never panic on any input.
        let stamp = self.stamp();
        match self.contributed.get(frame.client_id) {
            Some(&c) if c == stamp => return (Ingest::Duplicate, None),
            Some(_) => {}
            None => return (Ingest::Malformed, None),
        }
        let weight = n_i as f64 / (1 + staleness) as f64;
        let Ok((first, used)) = wire::deserialize_prefix(&frame.payload) else {
            return (Ingest::Malformed, None);
        };
        let segments: Vec<PreparedSegment> = if used == frame.payload.len() {
            // Single whole-tensor frame — the legacy hot path.
            if first.direction != Direction::Uplink || first.n as usize != self.params.len() {
                return (Ingest::Malformed, None);
            }
            match PreparedSegment::prepare(first, 0, &mut self.scratch) {
                Ok(seg) => vec![seg],
                Err(_) => return (Ingest::Malformed, None),
            }
        } else {
            // Multi-segment payload (one CSG2 frame per layer, mixed bit
            // widths — the adaptive schedule's wire shape).
            match self.prepare_segments(&frame.payload) {
                Ok(segs) => segs,
                Err(_) => return (Ingest::Malformed, None),
            }
        };
        // Commit: every check has passed; nothing below can fail.
        self.note_segments(&segments);
        if let Some(slot) = self.contributed.get_mut(frame.client_id) {
            *slot = stamp;
        }
        self.weight_sum += weight;
        self.updates_this_round += 1;
        (
            Ingest::Accepted { staleness },
            Some(PreparedFrame::new(weight, segments)),
        )
    }

    /// Validate and prepare a multi-segment payload. Decode is keyed
    /// entirely off each segment's header — never off configuration.
    /// All-or-nothing: every segment is fully validated (inflate, kind
    /// id, payload length — [`PreparedSegment::prepare`]) *before* any
    /// state changes, so a malformed tail segment has no side effects.
    /// Each dense segment then folds via the fused sub-range kernel —
    /// the same zero-`Vec<f32>` path single frames take, pinned
    /// bit-identical to decode-then-add in `tests/kernel_equivalence.rs`.
    fn prepare_segments(&mut self, payload: &[u8]) -> Result<Vec<PreparedSegment>> {
        let segs = wire::deserialize_stream(payload)?;
        let total: usize = segs.iter().map(|s| s.n as usize).sum();
        anyhow::ensure!(
            total == self.params.len(),
            "segments cover {total} of {} params",
            self.params.len()
        );
        anyhow::ensure!(
            segs.iter().all(|s| s.direction == Direction::Uplink),
            "non-uplink segment in an uplink stream"
        );
        let mut prepared = Vec::with_capacity(segs.len());
        let mut off = 0usize;
        for seg in segs {
            let n = seg.n as usize;
            prepared.push(PreparedSegment::prepare(seg, off, &mut self.scratch)?);
            off += n;
        }
        Ok(prepared)
    }

    /// Record one accepted frame's segment headers into the round's
    /// observation accumulator. A frame whose segment structure differs
    /// from what accumulated so far (an adaptive plan change inside a
    /// buffered-async window) restarts the accumulation — the controller
    /// always sees the freshest structure. Headers are read post-prepare,
    /// but normalization never touches `n`/`bits`/`norm`/`bound`, so the
    /// controller sees exactly the wire headers.
    fn note_segments(&mut self, segs: &[PreparedSegment]) {
        let matches = self.obs_round.len() == segs.len()
            && self
                .obs_round
                .iter()
                .zip(segs)
                .all(|(o, p)| o.n == p.header().n as usize);
        if !matches {
            self.obs_round = segs
                .iter()
                .map(|p| {
                    let s = p.header();
                    ObsAcc {
                        n: s.n as usize,
                        bits: s.bits,
                        norm_sq_sum: 0.0,
                        bound: s.bound,
                        wire_bytes_sum: 0,
                        count: 0,
                    }
                })
                .collect();
        }
        for (o, p) in self.obs_round.iter_mut().zip(segs) {
            let s = p.header();
            o.bits = s.bits;
            o.bound = s.bound;
            o.norm_sq_sum += (s.norm as f64) * (s.norm as f64);
            o.wire_bytes_sum += p.wire_bytes() as u64;
            o.count += 1;
        }
    }

    /// The open round's weighted-sum accumulator — the sharded ingest
    /// plane's flush target
    /// ([`crate::fl::ingest::IngestPlane::flush_into`]).
    pub(crate) fn accumulator_mut(&mut self) -> &mut [f64] {
        &mut self.acc
    }

    /// Refused-frame tallies of the open round, as
    /// `(duplicate, stale, malformed)` — the ingest verdict counters the
    /// history records and the trace metrics surface. Reset (with the rest
    /// of the round state) by [`Server::finish_round`].
    pub fn round_verdicts(&self) -> (usize, usize, usize) {
        (
            self.dup_this_round,
            self.stale_this_round,
            self.malformed_this_round,
        )
    }

    /// The open round's per-segment observations (RMS norm over accepted
    /// frames, latest width/bound, mean measured wire bytes) — what the
    /// runner feeds the adaptive bit controller. Empty until a frame is
    /// accepted. `wire_bytes` is the as-traveled (post-DEFLATE) segment
    /// size, so the controller's cost model tracks what the link actually
    /// carried, not the analytic packed size.
    pub fn round_observations(&self) -> Vec<SegmentObs> {
        self.obs_round
            .iter()
            .map(|o| SegmentObs {
                n: o.n,
                bits: o.bits,
                norm: (o.norm_sq_sum / o.count.max(1) as f64).sqrt() as f32,
                bound: o.bound,
                wire_bytes: (o.wire_bytes_sum / o.count.max(1)) as usize,
            })
            .collect()
    }

    /// Receive one client's wire bytes: deserialize and fold into the
    /// weighted sum (Algorithm 1 lines 6–7). This is the *trusted* direct
    /// path — no round/duplicate bookkeeping; experiment harnesses that
    /// drive aggregation by hand (tests, figures) use it. Frame-driven
    /// drivers go through [`Server::ingest`].
    pub fn receive_update(&mut self, wire_bytes: &[u8], num_examples: u32) -> Result<()> {
        let enc = wire::deserialize(wire_bytes)?;
        anyhow::ensure!(
            enc.direction == Direction::Uplink,
            "server received a non-uplink frame"
        );
        self.receive_decoded(&enc, num_examples)
    }

    /// Same, for an already-parsed [`EncodedTensor`]. Fuses dequantize and
    /// accumulate in one pass over the packed codes — no intermediate
    /// `Vec<f32>` (bit-identical to decode-then-add; see
    /// [`crate::compress::pipeline::accumulate_with`]).
    pub fn receive_decoded(&mut self, enc: &EncodedTensor, num_examples: u32) -> Result<()> {
        let w = num_examples as f64;
        accumulate_with(enc, w, &mut self.acc, &mut self.scratch)?;
        self.weight_sum += w;
        self.updates_this_round += 1;
        Ok(())
    }

    /// Finish the round: apply the aggregated update to the model
    /// (Eq. 1), reset the accumulator, and open the next round (the model
    /// version advances even when nothing arrived — time moves on).
    /// Returns the number of updates folded in.
    pub fn finish_round(&mut self) -> usize {
        let n_updates = self.updates_this_round;
        if self.weight_sum > 0.0 {
            let scale = self.eta_s as f64 / self.weight_sum;
            for (p, a) in self.params.iter_mut().zip(&mut self.acc) {
                *p -= (*a * scale) as f32;
                *a = 0.0;
            }
        }
        self.weight_sum = 0.0;
        self.updates_this_round = 0;
        self.obs_round.clear();
        self.dup_this_round = 0;
        self.stale_this_round = 0;
        self.malformed_this_round = 0;
        self.round += 1;
        n_updates
    }

    /// The model as the client fleet holds it (Delta mode): advances by
    /// the decoded delta on every [`Server::broadcast`]. In legacy mode it
    /// stays at the shared initialization and is unused.
    pub fn replica(&self) -> &[f32] {
        &self.replica
    }

    /// Produce this round's model broadcast (call once per round, before
    /// the selected clients train).
    pub fn broadcast(&mut self) -> Result<Broadcast> {
        match &self.downlink {
            Downlink::Float32Model => Ok(Broadcast {
                wire: None,
                bytes: self.params.len() * 4,
                deflate: None,
            }),
            Downlink::Delta(pipe) => {
                let delta: Vec<f32> = self
                    .params
                    .iter()
                    .zip(&self.replica)
                    .map(|(&p, &r)| p - r)
                    .collect();
                // Streaming encode: the DEFLATE stage writes straight into
                // the wire frame buffer, so serialization overlaps
                // compression instead of copying a finished payload.
                let mut frame = Vec::new();
                pipe.encode_wire_with(
                    &delta,
                    Direction::Downlink,
                    &mut self.state,
                    &mut self.rng,
                    &mut self.scratch,
                    &mut frame,
                );
                // Advance the reference replica by the *decoded* delta so
                // the server models exactly what clients reconstruct; the
                // next round's delta then carries this round's
                // quantization error (implicit downlink error feedback).
                // Decoding the frame bytes (rather than a pre-serialize
                // tensor) keeps server and fleet on the same input.
                let enc = wire::deserialize(&frame)?;
                let decoded = decode_with(&enc, &mut self.scratch)?;
                for (r, d) in self.replica.iter_mut().zip(&decoded) {
                    *r += d;
                }
                Ok(Broadcast {
                    bytes: frame.len(),
                    wire: Some(frame),
                    deflate: self.scratch.deflate_stats().cloned(),
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::client::ModelReplica;
    use crate::util::propcheck::gradient_like;
    use crate::util::stats::l2_norm;

    fn encode_update(pipe: &Pipeline, g: &[f32], seed: u64) -> EncodedTensor {
        pipe.encode(
            g,
            Direction::Uplink,
            &mut PipelineState::new(),
            &mut Pcg64::seeded(seed),
        )
    }

    #[test]
    fn aggregation_is_weighted_mean() {
        // Two float32 clients with weights 1 and 3: the update is the
        // weighted mean, scaled by eta_s.
        let pipe = Pipeline::float32();
        let mut server = Server::new(vec![1.0, 1.0], 2.0);
        let e1 = encode_update(&pipe, &[1.0, 0.0], 1);
        let e2 = encode_update(&pipe, &[0.0, 1.0], 2);
        server.receive_decoded(&e1, 1).unwrap();
        server.receive_decoded(&e2, 3).unwrap();
        assert_eq!(server.finish_round(), 2);
        // mean = (1*[1,0] + 3*[0,1]) / 4 = [0.25, 0.75]; M -= 2*mean.
        assert!((server.params[0] - 0.5).abs() < 1e-6);
        assert!((server.params[1] + 0.5).abs() < 1e-6);
    }

    #[test]
    fn wire_path_equals_decoded_path() {
        let pipe = Pipeline::cosine(8);
        let mut rng = Pcg64::seeded(2);
        let g = gradient_like(&mut rng, 500);
        let enc = pipe.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
        let bytes = wire::serialize(&enc);

        let mut s1 = Server::new(vec![0.0; 500], 1.0);
        s1.receive_update(&bytes, 10).unwrap();
        s1.finish_round();

        let mut s2 = Server::new(vec![0.0; 500], 1.0);
        s2.receive_decoded(&enc, 10).unwrap();
        s2.finish_round();

        assert_eq!(s1.params, s2.params);
    }

    #[test]
    fn rejects_downlink_frames_on_the_uplink() {
        let pipe = Pipeline::cosine(4);
        let mut rng = Pcg64::seeded(3);
        let g = gradient_like(&mut rng, 64);
        let enc = pipe.encode(&g, Direction::Downlink, &mut PipelineState::new(), &mut rng);
        let mut server = Server::new(vec![0.0; 64], 1.0);
        assert!(server.receive_update(&wire::serialize(&enc), 1).is_err());
    }

    #[test]
    fn empty_round_is_noop() {
        let mut server = Server::new(vec![3.0; 4], 1.0);
        assert_eq!(server.finish_round(), 0);
        assert_eq!(server.params, vec![3.0; 4]);
    }

    #[test]
    fn accumulator_resets_between_rounds() {
        let pipe = Pipeline::float32();
        let mut server = Server::new(vec![0.0; 2], 1.0);
        let e = encode_update(&pipe, &[1.0, 1.0], 3);
        server.receive_decoded(&e, 1).unwrap();
        server.finish_round();
        let after_first = server.params.clone();
        server.receive_decoded(&e, 1).unwrap();
        server.finish_round();
        // Second round applies exactly one more unit step.
        assert!((server.params[0] - (after_first[0] - 1.0)).abs() < 1e-6);
    }

    #[test]
    fn float32_broadcast_matches_csg1_accounting() {
        let mut server = Server::new(vec![0.5; 321], 1.0);
        let b = server.broadcast().unwrap();
        assert!(b.wire.is_none());
        assert!(b.deflate.is_none());
        assert_eq!(b.bytes, 321 * 4); // exactly the CSG1-era 4·n bytes
    }

    #[test]
    fn delta_broadcast_roundtrips_through_client_replica() {
        let mut rng = Pcg64::seeded(9);
        let init = gradient_like(&mut rng, 2000);
        let mut server = Server::new(init.clone(), 1.0)
            .with_downlink(Downlink::Delta(Pipeline::cosine(8)), 7);
        let mut fleet = ModelReplica::new(init);

        // Round 0: params == replica, so the delta is zero and tiny.
        let b0 = server.broadcast().unwrap();
        fleet.apply_wire(b0.wire.as_ref().unwrap()).unwrap();
        assert_eq!(fleet.params.as_slice(), server.replica());

        // Simulate two rounds of training drift + broadcast.
        for round in 0..2u64 {
            let drift = gradient_like(&mut Pcg64::seeded(100 + round), 2000);
            for (p, d) in server.params.iter_mut().zip(&drift) {
                *p -= 0.1 * d;
            }
            let b = server.broadcast().unwrap();
            // The quantized delta frame is strictly below the float32 cost.
            assert!(b.bytes < 2000 * 4, "delta frame {} bytes", b.bytes);
            // The downlink pipeline ran DEFLATE; the stats rode along.
            let stats = b.deflate.as_ref().expect("deflate stats");
            assert_eq!(stats.bytes_in as usize, 2000); // 8-bit codes, 1 B/elem
            fleet.apply_wire(b.wire.as_ref().unwrap()).unwrap();
            // Client replica and server reference replica agree bit-exactly.
            assert_eq!(fleet.params.as_slice(), server.replica());
        }

        // The replica tracks the true model within quantization error.
        let err: f64 = server
            .params
            .iter()
            .zip(&fleet.params)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let scale = l2_norm(&server.params).max(1e-9);
        assert!(err / scale < 0.1, "replica drift {}", err / scale);
    }

    fn uplink_frame(pipe: &Pipeline, g: &[f32], seed: u64, round: usize, client_id: usize) -> Frame {
        Frame {
            round,
            client_id,
            payload: wire::serialize(&encode_update(pipe, g, seed)),
        }
    }

    #[test]
    fn ingest_matches_the_direct_receive_path_bit_exactly() {
        // Frame ingest (fused dequantize+accumulate, registered weights)
        // must aggregate exactly like the trusted receive_update path.
        let pipe = Pipeline::cosine(6);
        let mut rng = Pcg64::seeded(21);
        let gs: Vec<Vec<f32>> = (0..3).map(|_| gradient_like(&mut rng, 700)).collect();
        let weights = vec![10u32, 25, 40];

        let mut by_frames =
            Server::new(vec![0.0; 700], 1.5).with_clients(weights.clone());
        let mut direct = Server::new(vec![0.0; 700], 1.5);
        for (c, g) in gs.iter().enumerate() {
            let frame = uplink_frame(&pipe, g, 50 + c as u64, 0, c);
            assert_eq!(by_frames.ingest(&frame), Ingest::Accepted { staleness: 0 });
            direct.receive_update(&frame.payload, weights[c]).unwrap();
        }
        assert_eq!(by_frames.finish_round(), 3);
        direct.finish_round();
        assert_eq!(by_frames.params, direct.params);
        assert_eq!(by_frames.round(), 1);
    }

    #[test]
    fn duplicate_frames_leave_the_accumulator_untouched() {
        let pipe = Pipeline::cosine(4);
        let mut rng = Pcg64::seeded(22);
        let g0 = gradient_like(&mut rng, 128);
        let g1 = gradient_like(&mut rng, 128);
        let run = |duplicate: bool| -> Vec<f32> {
            let mut s = Server::new(vec![0.0; 128], 1.0).with_clients(vec![7, 9]);
            assert_eq!(
                s.ingest(&uplink_frame(&pipe, &g0, 1, 0, 0)),
                Ingest::Accepted { staleness: 0 }
            );
            if duplicate {
                // Same client again (even with different contents): refused.
                assert_eq!(s.ingest(&uplink_frame(&pipe, &g1, 2, 0, 0)), Ingest::Duplicate);
            }
            assert_eq!(
                s.ingest(&uplink_frame(&pipe, &g1, 3, 0, 1)),
                Ingest::Accepted { staleness: 0 }
            );
            assert_eq!(s.finish_round(), 2);
            s.params
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn round_verdicts_tally_refusals_and_reset() {
        let pipe = Pipeline::cosine(4);
        let mut rng = Pcg64::seeded(26);
        let g = gradient_like(&mut rng, 64);
        let mut s = Server::new(vec![0.0; 64], 1.0).with_clients(vec![5, 5]);
        assert_eq!(s.round_verdicts(), (0, 0, 0));
        s.ingest(&uplink_frame(&pipe, &g, 1, 0, 0));
        s.ingest(&uplink_frame(&pipe, &g, 2, 0, 0)); // duplicate
        s.ingest(&uplink_frame(&pipe, &g, 3, 9, 1)); // stale (future tag)
        s.ingest(&uplink_frame(&pipe, &g, 4, 0, 99)); // malformed (unknown id)
        let mut bad = uplink_frame(&pipe, &g, 5, 0, 1);
        bad.payload[0] = b'X';
        s.ingest(&bad); // malformed (corrupt header)
        assert_eq!(s.round_verdicts(), (1, 1, 2));
        s.finish_round();
        assert_eq!(s.round_verdicts(), (0, 0, 0), "tallies reset per round");
    }

    #[test]
    fn stale_round_frames_are_refused_in_sync_mode() {
        let pipe = Pipeline::cosine(4);
        let mut rng = Pcg64::seeded(23);
        let g = gradient_like(&mut rng, 64);
        let mut s = Server::new(vec![0.25; 64], 1.0).with_clients(vec![5, 5]);
        assert_eq!(s.ingest(&uplink_frame(&pipe, &g, 1, 0, 0)), Ingest::Accepted { staleness: 0 });
        s.finish_round();
        let after_round = s.params.clone();
        // Round 0 tag at round 1: stale. A round from the future: refused too.
        assert_eq!(s.ingest(&uplink_frame(&pipe, &g, 2, 0, 1)), Ingest::StaleRound);
        assert_eq!(s.ingest(&uplink_frame(&pipe, &g, 3, 9, 1)), Ingest::StaleRound);
        assert_eq!(s.finish_round(), 0);
        assert_eq!(s.params, after_round, "stale frames must not move the model");
    }

    #[test]
    fn malformed_frames_are_refused_without_side_effects() {
        let pipe = Pipeline::cosine(4);
        let mut rng = Pcg64::seeded(24);
        let g = gradient_like(&mut rng, 64);
        let mut s = Server::new(vec![0.0; 64], 1.0).with_clients(vec![5, 5]);

        // Corrupted header bytes.
        let mut bad = uplink_frame(&pipe, &g, 1, 0, 0);
        bad.payload[0] = b'X';
        assert_eq!(s.ingest(&bad), Ingest::Malformed);
        // Truncated payload.
        let mut short = uplink_frame(&pipe, &g, 1, 0, 0);
        short.payload.truncate(10);
        assert_eq!(s.ingest(&short), Ingest::Malformed);
        // A downlink frame on the uplink.
        let enc = Pipeline::cosine(4).encode(
            &g,
            Direction::Downlink,
            &mut PipelineState::new(),
            &mut Pcg64::seeded(2),
        );
        let down = Frame { round: 0, client_id: 0, payload: wire::serialize(&enc) };
        assert_eq!(s.ingest(&down), Ingest::Malformed);
        // Wrong tensor length.
        let wrong_n = uplink_frame(&pipe, &g[..32], 3, 0, 0);
        assert_eq!(s.ingest(&wrong_n), Ingest::Malformed);
        // Unregistered client id.
        assert_eq!(s.ingest(&uplink_frame(&pipe, &g, 4, 0, 99)), Ingest::Malformed);

        // Nothing above touched the accumulator.
        assert_eq!(s.finish_round(), 0);
        assert_eq!(s.params, vec![0.0; 64]);
    }

    #[test]
    fn out_of_order_arrival_within_a_round_is_accepted() {
        let pipe = Pipeline::cosine(8);
        let mut rng = Pcg64::seeded(25);
        let gs: Vec<Vec<f32>> = (0..3).map(|_| gradient_like(&mut rng, 96)).collect();
        let mut s = Server::new(vec![0.0; 96], 1.0).with_clients(vec![1, 2, 3]);
        // Arrival order 2, 0, 1 — all frames of the open round land.
        for &c in &[2usize, 0, 1] {
            assert_eq!(
                s.ingest(&uplink_frame(&pipe, &gs[c], 10 + c as u64, 0, c)),
                Ingest::Accepted { staleness: 0 },
                "client {c} out of order"
            );
        }
        assert_eq!(s.finish_round(), 3);
        assert_ne!(s.params, vec![0.0; 96]);
    }

    #[test]
    fn buffered_async_discounts_staleness_and_signals_apply() {
        let pipe = Pipeline::float32();
        let mut s = Server::new(vec![0.0, 0.0], 1.0)
            .with_clients(vec![100, 100, 100])
            .with_round_mode(RoundMode::BufferedAsync {
                buffer_k: 2,
                max_staleness: 1,
            });
        s.finish_round(); // advance to round 1 so staleness exists
        assert_eq!(s.round(), 1);

        // Fresh update from client 0, stale-by-1 from client 1.
        assert_eq!(
            s.ingest(&uplink_frame(&pipe, &[1.0, 0.0], 1, 1, 0)),
            Ingest::Accepted { staleness: 0 }
        );
        assert!(!s.ready_to_apply());
        assert_eq!(
            s.ingest(&uplink_frame(&pipe, &[0.0, 1.0], 2, 0, 1)),
            Ingest::Accepted { staleness: 1 }
        );
        assert!(s.ready_to_apply(), "buffer of 2 filled");
        // Staleness 2 (round 0 at... round tag -1 impossible) — an expired
        // tag: client 2 trained on a version older than max_staleness.
        s.finish_round();
        assert_eq!(s.round(), 2);
        assert_eq!(s.ingest(&uplink_frame(&pipe, &[1.0, 1.0], 3, 0, 2)), Ingest::StaleRound);

        // The staleness discount halved client 1's weight:
        // mean = (100·[1,0] + 50·[0,1]) / 150 = [2/3, 1/3]; params = −mean.
        assert!((s.params[0] + 2.0 / 3.0).abs() < 1e-6, "{}", s.params[0]);
        assert!((s.params[1] + 1.0 / 3.0).abs() < 1e-6, "{}", s.params[1]);
    }

    #[test]
    fn round_mode_parse_grammar() {
        assert_eq!(RoundMode::parse("sync").unwrap(), RoundMode::Synchronous);
        assert_eq!(
            RoundMode::parse("async:8").unwrap(),
            RoundMode::BufferedAsync { buffer_k: 8, max_staleness: 2 }
        );
        assert_eq!(
            RoundMode::parse("async:4:7").unwrap(),
            RoundMode::BufferedAsync { buffer_k: 4, max_staleness: 7 }
        );
        assert!(RoundMode::parse("async").is_err());
        assert!(RoundMode::parse("async:0").is_err());
        assert!(RoundMode::parse("async:x").is_err());
        assert!(RoundMode::parse("gossip").is_err());
        assert_eq!(RoundMode::parse("async:4:1").unwrap().name(), "async:4 (≤1 stale)");
    }

    #[test]
    fn segmented_mixed_width_ingest_matches_decode_then_add() {
        // One payload = four CSG2 segments at four different widths.
        // Ingest must fold exactly like per-segment decode-then-add.
        let mut rng = Pcg64::seeded(31);
        let g = gradient_like(&mut rng, 800);
        let widths = [2u8, 8, 1, 5];
        let bounds = [0usize, 200, 400, 600, 800];
        let mut segs = Vec::new();
        for (l, &w) in widths.iter().enumerate() {
            let pipe = Pipeline::cosine(4).with_bits(w);
            segs.push(pipe.encode(
                &g[bounds[l]..bounds[l + 1]],
                Direction::Uplink,
                &mut PipelineState::new(),
                &mut Pcg64::seeded(90 + l as u64),
            ));
        }
        let frame = Frame {
            round: 0,
            client_id: 0,
            payload: wire::serialize_stream(&segs),
        };
        let mut s = Server::new(vec![0.0; 800], 1.0).with_clients(vec![13]);
        assert_eq!(s.ingest(&frame), Ingest::Accepted { staleness: 0 });
        // The controller sees one observation per segment, header-true.
        let obs = s.round_observations();
        assert_eq!(obs.len(), 4);
        for (o, (seg, &w)) in obs.iter().zip(segs.iter().zip(&widths)) {
            assert_eq!(o.bits, w);
            assert_eq!(o.n, seg.n as usize);
            assert!((o.norm - seg.norm).abs() < 1e-6);
            // Measured cost = exactly what this segment cost on the wire.
            assert_eq!(o.wire_bytes, wire::serialize(seg).len());
        }
        assert_eq!(s.finish_round(), 1);
        assert!(s.round_observations().is_empty(), "obs reset per round");

        // Manual decode-then-add reference.
        let mut expect = vec![0.0f64; 800];
        for (l, seg) in segs.iter().enumerate() {
            for (e, &d) in expect[bounds[l]..bounds[l + 1]]
                .iter_mut()
                .zip(&crate::compress::decode(seg).unwrap())
            {
                *e += d as f64 * 13.0;
            }
        }
        let scale = 1.0 / 13.0; // eta_s / weight_sum
        let manual: Vec<f32> = expect.iter().map(|&a| -((a * scale) as f32)).collect();
        assert_eq!(s.params, manual, "segmented ingest must be bit-identical");
    }

    #[test]
    fn segmented_ingest_is_all_or_nothing() {
        let mut rng = Pcg64::seeded(32);
        let g = gradient_like(&mut rng, 200);
        let pipe = Pipeline::cosine(4);
        let seg = |r: std::ops::Range<usize>, seed| {
            let mut rng = Pcg64::seeded(seed);
            pipe.encode(&g[r], Direction::Uplink, &mut PipelineState::new(), &mut rng)
        };
        let good = [seg(0..100, 1), seg(100..200, 2)];
        let mut s = Server::new(vec![0.0; 200], 1.0).with_clients(vec![5, 5]);

        // Truncated tail segment: refused, accumulator untouched.
        let mut cut = wire::serialize_stream(&good);
        cut.truncate(cut.len() - 3);
        assert_eq!(
            s.ingest(&Frame { round: 0, client_id: 0, payload: cut }),
            Ingest::Malformed
        );
        // Segments that do not cover the model: refused.
        let short = wire::serialize_stream(&good[..1]);
        assert_eq!(
            s.ingest(&Frame { round: 0, client_id: 0, payload: short }),
            Ingest::Malformed
        );
        // A downlink segment smuggled into the stream: refused.
        let mut mixed = good.clone();
        mixed[1] = pipe.encode(
            &g[100..200],
            Direction::Downlink,
            &mut PipelineState::new(),
            &mut Pcg64::seeded(3),
        );
        assert_eq!(
            s.ingest(&Frame { round: 0, client_id: 0, payload: wire::serialize_stream(&mixed) }),
            Ingest::Malformed
        );
        assert_eq!(s.finish_round(), 0);
        assert_eq!(s.params, vec![0.0; 200], "refused streams must not move the model");

        // The intact stream still lands.
        assert_eq!(
            s.ingest(&Frame { round: 1, client_id: 0, payload: wire::serialize_stream(&good) }),
            Ingest::Accepted { staleness: 0 }
        );
    }

    #[test]
    fn replica_error_feeds_back_into_next_delta() {
        // The delta is taken against the *decoded* replica, so a second
        // broadcast with unchanged params re-sends the residual error and
        // the replica converges toward the true model.
        let mut rng = Pcg64::seeded(11);
        let init = vec![0.0f32; 512];
        let target = gradient_like(&mut rng, 512);
        let mut server =
            Server::new(init.clone(), 1.0).with_downlink(Downlink::Delta(Pipeline::cosine(8)), 3);
        server.params = target.clone();
        let mut fleet = ModelReplica::new(init);
        let mut last_err = f64::INFINITY;
        for _ in 0..4 {
            let b = server.broadcast().unwrap();
            fleet.apply_wire(b.wire.as_ref().unwrap()).unwrap();
            let err: f64 = target
                .iter()
                .zip(&fleet.params)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            assert!(err < last_err * 1.001, "error did not shrink: {err} vs {last_err}");
            last_err = err;
        }
        assert!(last_err / l2_norm(&target) < 0.2, "final err {last_err}");
    }
}
