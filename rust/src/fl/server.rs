//! FedAvg server: holds the global model, applies Eq. (1):
//!
//! `M^{t+1} = M^t − η_s · Σ_i ∇M_i · N_i / Σ_i N_i`
//!
//! where `∇M_i` is client i's *decoded* update (`g = M_in − M*`) and `N_i`
//! its local example count — and produces the per-round model broadcast.
//!
//! ## Downlink modes
//!
//! * [`Downlink::Float32Model`] (default) — the raw float32 model, metered
//!   at exactly `4·n` bytes per receiving client: byte-identical to the
//!   CSG1-era cost accounting.
//! * [`Downlink::Delta`] — the paper's *round-trip* scheme: the server
//!   encodes the model delta `Δ = M^{t+1} − M^t` through a downlink
//!   [`Pipeline`] and advances an internal replica by the **decoded**
//!   delta, so server and clients agree bit-exactly on the degraded model
//!   the fleet trains from. The replica starts at the initial model (the
//!   shared-initialization assumption of Algorithm 1), so round 0
//!   broadcasts a zero delta.
//!
//! Uplink decoding is self-describing (CSG2): the server needs no codec
//! configuration to receive updates.

use anyhow::Result;

use crate::compress::pipeline::{
    decode_with, Direction, EncodeScratch, EncodedTensor, Pipeline, PipelineState,
};
use crate::compress::wire;
use crate::util::rng::Pcg64;

/// Server → client compression policy.
#[derive(Debug, Clone)]
pub enum Downlink {
    /// Legacy raw float32 model broadcast (`4·n` bytes, no framing).
    Float32Model,
    /// Quantized model delta through a downlink pipeline (CSG2 frame).
    Delta(Pipeline),
}

impl Downlink {
    /// Human label for logs / results files.
    pub fn name(&self) -> String {
        match self {
            Downlink::Float32Model => "float32 model".into(),
            Downlink::Delta(p) => format!("Δ {}", p.name()),
        }
    }
}

/// One round's model broadcast. The broadcast *content* is not duplicated
/// here: in legacy mode it is exactly [`Server::params`]; in Delta mode
/// clients reconstruct it by decoding `wire`, and the server's own copy is
/// readable via [`Server::replica`].
pub struct Broadcast {
    /// The CSG2 frame (None for the raw float32 legacy broadcast).
    pub wire: Option<Vec<u8>>,
    /// Bytes on the wire per receiving client.
    pub bytes: usize,
}

/// The global model + aggregation state.
pub struct Server {
    pub params: Vec<f32>,
    pub eta_s: f32,
    downlink: Downlink,
    /// The model as the client fleet currently holds it (Delta mode).
    replica: Vec<f32>,
    /// Downlink pipeline memory (EF residual, if enabled) + seed lane.
    state: PipelineState,
    /// Reusable encode/decode buffers (uplink ingest + downlink encode):
    /// steady-state rounds run the compression stages allocation-free.
    scratch: EncodeScratch,
    rng: Pcg64,
    /// Weighted-sum accumulator for the current round.
    acc: Vec<f64>,
    weight_sum: f64,
    updates_this_round: usize,
}

impl Server {
    pub fn new(params: Vec<f32>, eta_s: f32) -> Server {
        let n = params.len();
        Server {
            replica: params.clone(),
            params,
            eta_s,
            downlink: Downlink::Float32Model,
            state: PipelineState::new(),
            scratch: EncodeScratch::new(),
            rng: Pcg64::new(0, 0xD0_417),
            acc: vec![0.0; n],
            weight_sum: 0.0,
            updates_this_round: 0,
        }
    }

    /// Configure the downlink policy; `seed` drives the downlink
    /// pipeline's stochastic stages (mask/rotation seeds, rounding).
    pub fn with_downlink(mut self, downlink: Downlink, seed: u64) -> Server {
        self.downlink = downlink;
        self.rng = Pcg64::new(seed, 0xD0_417);
        self
    }

    /// Receive one client's wire bytes: deserialize, inflate, dequantize,
    /// scatter, and fold into the weighted sum (Algorithm 1 lines 6–7).
    pub fn receive_update(&mut self, wire_bytes: &[u8], num_examples: u32) -> Result<()> {
        let enc = wire::deserialize(wire_bytes)?;
        anyhow::ensure!(
            enc.direction == Direction::Uplink,
            "server received a non-uplink frame"
        );
        self.receive_decoded(&enc, num_examples)
    }

    /// Same, for an already-parsed [`EncodedTensor`].
    pub fn receive_decoded(&mut self, enc: &EncodedTensor, num_examples: u32) -> Result<()> {
        let delta = decode_with(enc, &mut self.scratch)?;
        anyhow::ensure!(
            delta.len() == self.params.len(),
            "update length {} != model {}",
            delta.len(),
            self.params.len()
        );
        let w = num_examples as f64;
        for (a, &d) in self.acc.iter_mut().zip(&delta) {
            *a += d as f64 * w;
        }
        self.weight_sum += w;
        self.updates_this_round += 1;
        Ok(())
    }

    /// Finish the round: apply the aggregated update to the model
    /// (Eq. 1) and reset the accumulator. Returns the number of updates
    /// folded in.
    pub fn finish_round(&mut self) -> usize {
        let n_updates = self.updates_this_round;
        if self.weight_sum > 0.0 {
            let scale = self.eta_s as f64 / self.weight_sum;
            for (p, a) in self.params.iter_mut().zip(&mut self.acc) {
                *p -= (*a * scale) as f32;
                *a = 0.0;
            }
        }
        self.weight_sum = 0.0;
        self.updates_this_round = 0;
        n_updates
    }

    /// The model as the client fleet holds it (Delta mode): advances by
    /// the decoded delta on every [`Server::broadcast`]. In legacy mode it
    /// stays at the shared initialization and is unused.
    pub fn replica(&self) -> &[f32] {
        &self.replica
    }

    /// Produce this round's model broadcast (call once per round, before
    /// the selected clients train).
    pub fn broadcast(&mut self) -> Result<Broadcast> {
        match &self.downlink {
            Downlink::Float32Model => Ok(Broadcast {
                wire: None,
                bytes: self.params.len() * 4,
            }),
            Downlink::Delta(pipe) => {
                let delta: Vec<f32> = self
                    .params
                    .iter()
                    .zip(&self.replica)
                    .map(|(&p, &r)| p - r)
                    .collect();
                let enc = pipe.encode_with(
                    &delta,
                    Direction::Downlink,
                    &mut self.state,
                    &mut self.rng,
                    &mut self.scratch,
                );
                let frame = wire::serialize(&enc);
                // Advance the reference replica by the *decoded* delta so
                // the server models exactly what clients reconstruct; the
                // next round's delta then carries this round's
                // quantization error (implicit downlink error feedback).
                let decoded = decode_with(&enc, &mut self.scratch)?;
                for (r, d) in self.replica.iter_mut().zip(&decoded) {
                    *r += d;
                }
                Ok(Broadcast {
                    bytes: frame.len(),
                    wire: Some(frame),
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::client::ModelReplica;
    use crate::util::propcheck::gradient_like;
    use crate::util::stats::l2_norm;

    fn encode_update(pipe: &Pipeline, g: &[f32], seed: u64) -> EncodedTensor {
        pipe.encode(
            g,
            Direction::Uplink,
            &mut PipelineState::new(),
            &mut Pcg64::seeded(seed),
        )
    }

    #[test]
    fn aggregation_is_weighted_mean() {
        // Two float32 clients with weights 1 and 3: the update is the
        // weighted mean, scaled by eta_s.
        let pipe = Pipeline::float32();
        let mut server = Server::new(vec![1.0, 1.0], 2.0);
        let e1 = encode_update(&pipe, &[1.0, 0.0], 1);
        let e2 = encode_update(&pipe, &[0.0, 1.0], 2);
        server.receive_decoded(&e1, 1).unwrap();
        server.receive_decoded(&e2, 3).unwrap();
        assert_eq!(server.finish_round(), 2);
        // mean = (1*[1,0] + 3*[0,1]) / 4 = [0.25, 0.75]; M -= 2*mean.
        assert!((server.params[0] - 0.5).abs() < 1e-6);
        assert!((server.params[1] + 0.5).abs() < 1e-6);
    }

    #[test]
    fn wire_path_equals_decoded_path() {
        let pipe = Pipeline::cosine(8);
        let mut rng = Pcg64::seeded(2);
        let g = gradient_like(&mut rng, 500);
        let enc = pipe.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
        let bytes = wire::serialize(&enc);

        let mut s1 = Server::new(vec![0.0; 500], 1.0);
        s1.receive_update(&bytes, 10).unwrap();
        s1.finish_round();

        let mut s2 = Server::new(vec![0.0; 500], 1.0);
        s2.receive_decoded(&enc, 10).unwrap();
        s2.finish_round();

        assert_eq!(s1.params, s2.params);
    }

    #[test]
    fn rejects_downlink_frames_on_the_uplink() {
        let pipe = Pipeline::cosine(4);
        let mut rng = Pcg64::seeded(3);
        let g = gradient_like(&mut rng, 64);
        let enc = pipe.encode(&g, Direction::Downlink, &mut PipelineState::new(), &mut rng);
        let mut server = Server::new(vec![0.0; 64], 1.0);
        assert!(server.receive_update(&wire::serialize(&enc), 1).is_err());
    }

    #[test]
    fn empty_round_is_noop() {
        let mut server = Server::new(vec![3.0; 4], 1.0);
        assert_eq!(server.finish_round(), 0);
        assert_eq!(server.params, vec![3.0; 4]);
    }

    #[test]
    fn accumulator_resets_between_rounds() {
        let pipe = Pipeline::float32();
        let mut server = Server::new(vec![0.0; 2], 1.0);
        let e = encode_update(&pipe, &[1.0, 1.0], 3);
        server.receive_decoded(&e, 1).unwrap();
        server.finish_round();
        let after_first = server.params.clone();
        server.receive_decoded(&e, 1).unwrap();
        server.finish_round();
        // Second round applies exactly one more unit step.
        assert!((server.params[0] - (after_first[0] - 1.0)).abs() < 1e-6);
    }

    #[test]
    fn float32_broadcast_matches_csg1_accounting() {
        let mut server = Server::new(vec![0.5; 321], 1.0);
        let b = server.broadcast().unwrap();
        assert!(b.wire.is_none());
        assert_eq!(b.bytes, 321 * 4); // exactly the CSG1-era 4·n bytes
    }

    #[test]
    fn delta_broadcast_roundtrips_through_client_replica() {
        let mut rng = Pcg64::seeded(9);
        let init = gradient_like(&mut rng, 2000);
        let mut server = Server::new(init.clone(), 1.0)
            .with_downlink(Downlink::Delta(Pipeline::cosine(8)), 7);
        let mut fleet = ModelReplica::new(init);

        // Round 0: params == replica, so the delta is zero and tiny.
        let b0 = server.broadcast().unwrap();
        fleet.apply_wire(b0.wire.as_ref().unwrap()).unwrap();
        assert_eq!(fleet.params.as_slice(), server.replica());

        // Simulate two rounds of training drift + broadcast.
        for round in 0..2u64 {
            let drift = gradient_like(&mut Pcg64::seeded(100 + round), 2000);
            for (p, d) in server.params.iter_mut().zip(&drift) {
                *p -= 0.1 * d;
            }
            let b = server.broadcast().unwrap();
            // The quantized delta frame is strictly below the float32 cost.
            assert!(b.bytes < 2000 * 4, "delta frame {} bytes", b.bytes);
            fleet.apply_wire(b.wire.as_ref().unwrap()).unwrap();
            // Client replica and server reference replica agree bit-exactly.
            assert_eq!(fleet.params.as_slice(), server.replica());
        }

        // The replica tracks the true model within quantization error.
        let err: f64 = server
            .params
            .iter()
            .zip(&fleet.params)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let scale = l2_norm(&server.params).max(1e-9);
        assert!(err / scale < 0.1, "replica drift {}", err / scale);
    }

    #[test]
    fn replica_error_feeds_back_into_next_delta() {
        // The delta is taken against the *decoded* replica, so a second
        // broadcast with unchanged params re-sends the residual error and
        // the replica converges toward the true model.
        let mut rng = Pcg64::seeded(11);
        let init = vec![0.0f32; 512];
        let target = gradient_like(&mut rng, 512);
        let mut server =
            Server::new(init.clone(), 1.0).with_downlink(Downlink::Delta(Pipeline::cosine(8)), 3);
        server.params = target.clone();
        let mut fleet = ModelReplica::new(init);
        let mut last_err = f64::INFINITY;
        for _ in 0..4 {
            let b = server.broadcast().unwrap();
            fleet.apply_wire(b.wire.as_ref().unwrap()).unwrap();
            let err: f64 = target
                .iter()
                .zip(&fleet.params)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            assert!(err < last_err * 1.001, "error did not shrink: {err} vs {last_err}");
            last_err = err;
        }
        assert!(last_err / l2_norm(&target) < 0.2, "final err {last_err}");
    }
}
