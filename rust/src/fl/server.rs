//! FedAvg server: holds the global model and applies Eq. (1):
//!
//! `M^{t+1} = M^t − η_s · Σ_i ∇M_i · N_i / Σ_i N_i`
//!
//! where `∇M_i` is client i's *decoded* update (`g = M_in − M*`) and `N_i`
//! its local example count.

use anyhow::Result;

use crate::compress::{codec::EncodedGradient, wire, Codec};

/// The global model + aggregation state.
pub struct Server {
    pub params: Vec<f32>,
    pub eta_s: f32,
    codec: Codec,
    /// Weighted-sum accumulator for the current round.
    acc: Vec<f64>,
    weight_sum: f64,
    updates_this_round: usize,
}

impl Server {
    pub fn new(params: Vec<f32>, eta_s: f32, codec: Codec) -> Server {
        let n = params.len();
        Server {
            params,
            eta_s,
            codec,
            acc: vec![0.0; n],
            weight_sum: 0.0,
            updates_this_round: 0,
        }
    }

    /// Receive one client's wire bytes: deserialize, Deflate-decompress,
    /// dequantize, scatter, and fold into the weighted sum
    /// (Algorithm 1 lines 6–7).
    pub fn receive_update(&mut self, wire_bytes: &[u8], num_examples: u32) -> Result<()> {
        let enc = wire::deserialize(wire_bytes)?;
        self.receive_decoded(&enc, num_examples)
    }

    /// Same, for an already-parsed [`EncodedGradient`].
    pub fn receive_decoded(&mut self, enc: &EncodedGradient, num_examples: u32) -> Result<()> {
        let delta = self.codec.decode(enc)?;
        anyhow::ensure!(
            delta.len() == self.params.len(),
            "update length {} != model {}",
            delta.len(),
            self.params.len()
        );
        let w = num_examples as f64;
        for (a, &d) in self.acc.iter_mut().zip(&delta) {
            *a += d as f64 * w;
        }
        self.weight_sum += w;
        self.updates_this_round += 1;
        Ok(())
    }

    /// Finish the round: apply the aggregated update to the model
    /// (Eq. 1) and reset the accumulator. Returns the number of updates
    /// folded in.
    pub fn finish_round(&mut self) -> usize {
        let n_updates = self.updates_this_round;
        if self.weight_sum > 0.0 {
            let scale = self.eta_s as f64 / self.weight_sum;
            for (p, a) in self.params.iter_mut().zip(&mut self.acc) {
                *p -= (*a * scale) as f32;
                *a = 0.0;
            }
        }
        self.weight_sum = 0.0;
        self.updates_this_round = 0;
        n_updates
    }

    /// Serialized model size for downlink accounting (float32 broadcast).
    pub fn broadcast_bytes(&self) -> usize {
        self.params.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::codec::ClientCodecState;
    use crate::util::rng::Pcg64;

    #[test]
    fn aggregation_is_weighted_mean() {
        // Two float32 clients with weights 1 and 3: the update is the
        // weighted mean, scaled by eta_s.
        let codec = Codec::float32();
        let mut server = Server::new(vec![1.0, 1.0], 2.0, codec);
        let mut rng = Pcg64::seeded(1);
        let mut st = ClientCodecState::new();
        let e1 = codec.encode(&[1.0, 0.0], &mut st, &mut rng);
        let e2 = codec.encode(&[0.0, 1.0], &mut st, &mut rng);
        server.receive_decoded(&e1, 1).unwrap();
        server.receive_decoded(&e2, 3).unwrap();
        assert_eq!(server.finish_round(), 2);
        // mean = (1*[1,0] + 3*[0,1]) / 4 = [0.25, 0.75]; M -= 2*mean.
        assert!((server.params[0] - 0.5).abs() < 1e-6);
        assert!((server.params[1] + 0.5).abs() < 1e-6);
    }

    #[test]
    fn wire_path_equals_decoded_path() {
        let codec = Codec::cosine(8);
        let mut rng = Pcg64::seeded(2);
        let g = crate::util::propcheck::gradient_like(&mut rng, 500);
        let enc = codec.encode(&g, &mut ClientCodecState::new(), &mut rng);
        let bytes = wire::serialize(&enc);

        let mut s1 = Server::new(vec![0.0; 500], 1.0, codec);
        s1.receive_update(&bytes, 10).unwrap();
        s1.finish_round();

        let mut s2 = Server::new(vec![0.0; 500], 1.0, codec);
        s2.receive_decoded(&enc, 10).unwrap();
        s2.finish_round();

        assert_eq!(s1.params, s2.params);
    }

    #[test]
    fn empty_round_is_noop() {
        let mut server = Server::new(vec![3.0; 4], 1.0, Codec::float32());
        assert_eq!(server.finish_round(), 0);
        assert_eq!(server.params, vec![3.0; 4]);
    }

    #[test]
    fn accumulator_resets_between_rounds() {
        let codec = Codec::float32();
        let mut server = Server::new(vec![0.0; 2], 1.0, codec);
        let mut rng = Pcg64::seeded(3);
        let mut st = ClientCodecState::new();
        let e = codec.encode(&[1.0, 1.0], &mut st, &mut rng);
        server.receive_decoded(&e, 1).unwrap();
        server.finish_round();
        let after_first = server.params.clone();
        server.receive_decoded(&e, 1).unwrap();
        server.finish_round();
        // Second round applies exactly one more unit step.
        assert!((server.params[0] - (after_first[0] - 1.0)).abs() < 1e-6);
    }
}
