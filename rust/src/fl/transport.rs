//! The transport layer: every client ↔ server exchange is an opaque,
//! serialized [`Frame`] carried by a [`Transport`] — so the bytes the
//! ledger meters are the *ground truth* of the protocol, not a side
//! channel replayed after the fact.
//!
//! Two implementations:
//!
//! * [`Loopback`] — in-memory, zero-latency: every frame is delivered in
//!   submission order. The pure byte-accounting harness.
//! * [`SimTransport`] — wraps a [`FleetSim`]: delivery is timed on the
//!   virtual clock per device profile, and the transport owns the
//!   availability/dropout lottery and the straggler-abort policy. Aborted
//!   uploads never reach the server **and are never metered** — the two
//!   facts cannot drift apart because they are one decision, made here.
//!
//! The runner is a thin event-loop driver on top: it trains clients,
//! hands their frames to the transport, and feeds whatever the transport
//! delivers into the server's ingest state machine
//! ([`crate::fl::Server::ingest`]).
//!
//! ## Ordering contracts
//!
//! * Synchronous [`Transport::exchange`] returns the surviving frames in
//!   **selection order** — exactly the aggregation order of the
//!   pre-transport runner, so synchronous runs are bit-identical to it.
//! * The buffered-async interface ([`Transport::dispatch`] /
//!   [`Transport::recv`]) delivers in **arrival order** (virtual-clock
//!   order for [`SimTransport`], FIFO for [`Loopback`]) — arrival order
//!   *is* the semantics of buffered aggregation.
//!
//! ## One broadcast, many receivers
//!
//! The downlink broadcast payload is never cloned per receiver: the
//! server produces one buffer, every replica decodes from that shared
//! slice, and [`Transport::broadcast`] meters `bytes × receivers` in
//! O(1). (A naive per-client downlink `Frame` would copy the model delta
//! once per device — at a million clients, that is the whole heap.)

use std::collections::VecDeque;

use crate::sim::{secs, Admission, ClientLoad, FleetSim, RoundPlan, SimConfig, Ticks, Timeline};

use super::network::NetworkLedger;

/// An opaque envelope on the wire: which client, which round (model
/// version) the payload was produced against, and the serialized CSG2
/// frame itself. The transport never looks inside the payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    /// Model version the sender trained from (the server's open round at
    /// dispatch time).
    pub round: usize,
    /// Fleet index of the sender.
    pub client_id: usize,
    /// Serialized wire bytes ([`crate::compress::wire`]).
    pub payload: Vec<u8>,
}

impl Frame {
    /// Bytes this frame costs on the wire.
    pub fn wire_bytes(&self) -> usize {
        self.payload.len()
    }
}

/// The carrier between clients and server. Owns byte metering and the
/// delivery/abort policy; see the module docs for the ordering contracts.
pub trait Transport {
    /// Policy-adjusted number of candidates to select so that `k`
    /// reporters are expected (over-selection lives in the carrier's
    /// round policy, not the runner).
    fn selection_count(&self, k: usize) -> usize;

    /// Open a synchronous round over `candidates`: the availability /
    /// dropout lottery decides who actually trains.
    fn plan_round(&mut self, candidates: &[usize]) -> RoundPlan;

    /// Meter one broadcast payload of `bytes` reaching `receivers`
    /// clients. The payload itself is shared — one buffer, decoded by
    /// every replica; nothing is cloned per receiver.
    fn broadcast(&mut self, bytes: usize, receivers: usize);

    /// Synchronous exchange: carry the active clients' uplink frames
    /// (in selection order, as planned by [`Transport::plan_round`]).
    /// The transport decides which uploads complete before the round
    /// closes — aborted stragglers are dropped *and not metered* — and
    /// returns the survivors in selection order.
    fn exchange(
        &mut self,
        round: usize,
        k_target: usize,
        broadcast_bytes: usize,
        frames: Vec<Frame>,
        examples_each: u64,
    ) -> Vec<Frame>;

    /// Buffered-async admission lottery for one candidate at the current
    /// virtual instant (offline/dropout clients are not worth training).
    fn admit(&mut self, client: usize) -> Admission;

    /// Buffered-async: put an admitted client's frame in flight from the
    /// current virtual instant (broadcast transfer → training → upload,
    /// timed per device on sim-clocked transports).
    fn dispatch(&mut self, frame: Frame, broadcast_bytes: usize, examples: u64);

    /// Buffered-async: the next frame to arrive at the server, advancing
    /// the virtual clock to its arrival. Every delivered frame is metered
    /// — it crossed the wire whether or not the server ends up using it.
    /// `None` when nothing is in flight.
    fn recv(&mut self) -> Option<Frame>;

    /// Buffered-async: close one aggregation window (timeline record on
    /// sim-clocked transports). `stale_dropped` counts delivered updates
    /// the server discarded as stale in this window.
    fn close_window(&mut self, round: usize, reporters: usize, stale_dropped: usize);

    /// The byte-exact traffic ledger.
    fn ledger(&self) -> &NetworkLedger;

    /// Current virtual time in seconds (`None` on untimed transports).
    fn clock_secs(&self) -> Option<f64>;

    /// Current virtual time in integer ticks (µs) — what the tracing
    /// plane stamps events with ([`crate::obs::TimeSource::manual`]).
    /// `None` on untimed transports (the default).
    fn clock_ticks(&self) -> Option<Ticks> {
        None
    }

    /// Consume the transport, yielding the ledger and the virtual-clock
    /// timeline (`None` on untimed transports).
    fn finish(self: Box<Self>) -> (NetworkLedger, Option<Timeline>);
}

/// In-memory loopback: every frame is delivered, in order, instantly.
#[derive(Debug, Default)]
pub struct Loopback {
    ledger: NetworkLedger,
    in_flight: VecDeque<Frame>,
}

impl Loopback {
    pub fn new() -> Loopback {
        Loopback::default()
    }
}

impl Transport for Loopback {
    fn selection_count(&self, k: usize) -> usize {
        k
    }

    fn plan_round(&mut self, candidates: &[usize]) -> RoundPlan {
        RoundPlan::full(candidates.to_vec())
    }

    fn broadcast(&mut self, bytes: usize, receivers: usize) {
        self.ledger.record_downlink_n(bytes, receivers);
    }

    fn exchange(
        &mut self,
        _round: usize,
        _k_target: usize,
        _broadcast_bytes: usize,
        frames: Vec<Frame>,
        _examples_each: u64,
    ) -> Vec<Frame> {
        for f in &frames {
            self.ledger.record_uplink(f.wire_bytes());
        }
        frames
    }

    fn admit(&mut self, _client: usize) -> Admission {
        Admission::Admitted
    }

    fn dispatch(&mut self, frame: Frame, _broadcast_bytes: usize, _examples: u64) {
        self.in_flight.push_back(frame);
    }

    fn recv(&mut self) -> Option<Frame> {
        let f = self.in_flight.pop_front()?;
        self.ledger.record_uplink(f.wire_bytes());
        Some(f)
    }

    fn close_window(&mut self, _round: usize, _reporters: usize, _stale_dropped: usize) {}

    fn ledger(&self) -> &NetworkLedger {
        &self.ledger
    }

    fn clock_secs(&self) -> Option<f64> {
        None
    }

    fn finish(self: Box<Self>) -> (NetworkLedger, Option<Timeline>) {
        (self.ledger, None)
    }
}

/// Sim-clocked transport: wraps a [`FleetSim`], which owns the device
/// fleet, the virtual clock, the availability/dropout lottery, and the
/// straggler policy. Frames in flight are parked here until the clock
/// reaches their arrival.
pub struct SimTransport {
    sim: FleetSim,
    ledger: NetworkLedger,
    /// The plan produced by the last [`Transport::plan_round`], consumed
    /// by the matching [`Transport::exchange`].
    pending_plan: Option<RoundPlan>,
    /// In-flight async frames, slotted by launch token (slots are
    /// recycled; lookups are by token, so iteration order never matters).
    flights: Vec<Option<Frame>>,
    free_slots: Vec<usize>,
    // Async window accounting (reset by `close_window`).
    window_selected: usize,
    window_offline: usize,
    window_dropouts: usize,
}

impl SimTransport {
    pub fn new(cfg: &SimConfig, n_devices: usize, seed: u64) -> SimTransport {
        SimTransport {
            sim: FleetSim::new(cfg, n_devices, seed),
            ledger: NetworkLedger::new(),
            pending_plan: None,
            flights: Vec::new(),
            free_slots: Vec::new(),
            window_selected: 0,
            window_offline: 0,
            window_dropouts: 0,
        }
    }

    /// The wrapped simulator (fleet introspection in tests).
    pub fn fleet(&self) -> &FleetSim {
        &self.sim
    }
}

impl Transport for SimTransport {
    fn selection_count(&self, k: usize) -> usize {
        self.sim.selection_count(k)
    }

    fn plan_round(&mut self, candidates: &[usize]) -> RoundPlan {
        let plan = self.sim.begin_round(candidates);
        self.pending_plan = Some(plan.clone());
        plan
    }

    fn broadcast(&mut self, bytes: usize, receivers: usize) {
        self.ledger.record_downlink_n(bytes, receivers);
    }

    fn exchange(
        &mut self,
        round: usize,
        k_target: usize,
        broadcast_bytes: usize,
        frames: Vec<Frame>,
        examples_each: u64,
    ) -> Vec<Frame> {
        let plan = self
            .pending_plan
            .take()
            .expect("plan_round must precede exchange");
        debug_assert_eq!(plan.active.len(), frames.len(), "one frame per active client");
        let loads: Vec<ClientLoad> = frames
            .iter()
            .map(|f| ClientLoad {
                device: f.client_id,
                upload_bytes: f.wire_bytes(),
                examples: examples_each,
            })
            .collect();
        let outcome = self
            .sim
            .complete_round(round, &plan, k_target, broadcast_bytes, &loads);
        let mut kept = outcome.kept;
        kept.sort_unstable();
        // Selection order filtered to the survivors — the pre-transport
        // aggregation order, so synchronous runs stay bit-identical.
        frames
            .into_iter()
            .filter(|f| kept.binary_search(&f.client_id).is_ok())
            .inspect(|f| self.ledger.record_uplink(f.wire_bytes()))
            .collect()
    }

    fn admit(&mut self, client: usize) -> Admission {
        let verdict = self.sim.admit(client);
        self.window_selected += 1;
        match verdict {
            Admission::Offline => self.window_offline += 1,
            Admission::Dropout => self.window_dropouts += 1,
            Admission::Admitted => {}
        }
        verdict
    }

    fn dispatch(&mut self, frame: Frame, broadcast_bytes: usize, examples: u64) {
        let slot = match self.free_slots.pop() {
            Some(s) => s,
            None => {
                self.flights.push(None);
                self.flights.len() - 1
            }
        };
        self.sim.launch(
            slot as u64,
            frame.client_id,
            broadcast_bytes,
            frame.wire_bytes(),
            examples,
        );
        self.flights[slot] = Some(frame);
    }

    fn recv(&mut self) -> Option<Frame> {
        let (_, token) = self.sim.arrive()?;
        let frame = self.flights[token as usize]
            .take()
            .expect("arrival for an empty flight slot");
        self.free_slots.push(token as usize);
        self.ledger.record_uplink(frame.wire_bytes());
        Some(frame)
    }

    fn close_window(&mut self, round: usize, reporters: usize, stale_dropped: usize) {
        self.sim.close_async_round(
            round,
            self.window_selected,
            self.window_offline,
            self.window_dropouts,
            reporters,
            stale_dropped,
        );
        self.window_selected = 0;
        self.window_offline = 0;
        self.window_dropouts = 0;
    }

    fn ledger(&self) -> &NetworkLedger {
        &self.ledger
    }

    fn clock_secs(&self) -> Option<f64> {
        Some(secs(self.sim.clock()))
    }

    fn clock_ticks(&self) -> Option<Ticks> {
        Some(self.sim.clock())
    }

    fn finish(self: Box<Self>) -> (NetworkLedger, Option<Timeline>) {
        (self.ledger, Some(self.sim.into_timeline()))
    }
}

/// Artifact-free protocol drivers: synthetic gradient updates pushed as
/// REAL encoded frames through the real transport and the real server
/// ingest state machine — everything but the training. Shared by the
/// `repro sim --quick` CI smoke and the system tests
/// (`tests/async_rounds.rs`), so the path CI exercises is the path the
/// tests validate.
pub mod dryrun {
    use anyhow::{bail, ensure, Result};

    use crate::compress::allocator::{BitController, BitPlan, BitSchedule, LayerMap};
    use crate::compress::{wire, Direction, Pipeline, PipelineState};
    use crate::obs::{emit_round_spans, Metrics, Tracer};
    use crate::sim::{Admission, SimConfig, Timeline};
    use crate::util::json::Json;
    use crate::util::propcheck::gradient_like;
    use crate::util::rng::Pcg64;

    use super::super::ingest::IngestPlane;
    use super::super::network::NetworkLedger;
    use super::super::server::{Ingest, RoundMode, Server};
    use super::{Frame, SimTransport, Transport};

    /// Histogram buckets for delivered frame sizes (bytes).
    const FRAME_BYTES_BOUNDS: &[f64] = &[1_000.0, 10_000.0, 100_000.0, 1_000_000.0];
    /// Histogram buckets for accepted-update staleness (model versions).
    const STALENESS_BOUNDS: &[f64] = &[0.0, 1.0, 2.0, 4.0, 8.0];

    fn verdict_label(v: &Ingest) -> &'static str {
        match v {
            Ingest::Accepted { .. } => "accepted",
            Ingest::Duplicate => "duplicate",
            Ingest::StaleRound => "stale",
            Ingest::Malformed => "malformed",
        }
    }

    fn verdict_counter(v: &Ingest) -> &'static str {
        match v {
            Ingest::Accepted { .. } => "ingest_accepted",
            Ingest::Duplicate => "ingest_duplicate",
            Ingest::StaleRound => "ingest_stale",
            Ingest::Malformed => "ingest_malformed",
        }
    }

    /// One `ingest` trace point + verdict counters per delivered frame.
    pub(crate) fn note_ingest(
        tracer: &mut Tracer,
        metrics: &mut Metrics,
        frame: &Frame,
        verdict: &Ingest,
    ) {
        metrics.inc(verdict_counter(verdict), 1);
        metrics.observe("frame_bytes", FRAME_BYTES_BOUNDS, frame.wire_bytes() as f64);
        let mut fields = vec![
            ("client", Json::from(frame.client_id)),
            ("round", Json::from(frame.round)),
            ("verdict", Json::from(verdict_label(verdict))),
        ];
        if let Ingest::Accepted { staleness } = verdict {
            metrics.observe("staleness", STALENESS_BOUNDS, *staleness as f64);
            fields.push(("staleness", Json::from(*staleness)));
        }
        tracer.point("ingest", fields);
    }

    /// One `bit_plan` trace point: the controller's decision plus the
    /// water-filling rationale (cost vs budget, pressure-raised floor).
    pub(crate) fn note_plan(
        tracer: &mut Tracer,
        controller: Option<&BitController>,
        plan: Option<&BitPlan>,
        round: usize,
    ) {
        let (Some(c), Some(p)) = (controller, plan) else {
            return;
        };
        let widths: Vec<String> = p.bits.iter().map(|b| b.to_string()).collect();
        tracer.point(
            "bit_plan",
            vec![
                ("round", Json::from(round)),
                ("bits", Json::from(widths.join(","))),
                ("segmented", Json::from(p.segmented)),
                ("cost", Json::from(c.plan_cost(p))),
                ("budget", Json::from(c.effective_budget())),
                ("floor", Json::from(1usize + c.pressure() as usize)),
            ],
        );
    }

    /// Drain the ingest plane into the server's accumulator and emit the
    /// flush telemetry (span point, fold counters, per-shard element
    /// gauges). No-op when nothing is pending. Flush granularity never
    /// changes bits — every accumulator element still receives its
    /// contributions in frame-arrival order — so callers flush whenever
    /// the bounded queue fills and always before closing a round. Shared
    /// by the production runner and the dry protocol drivers below.
    pub(crate) fn flush_plane(
        plane: &mut IngestPlane,
        server: &mut Server,
        tracer: &mut Tracer,
        metrics: &mut Metrics,
    ) -> Result<()> {
        if plane.is_empty() {
            return Ok(());
        }
        let shards = plane.shards();
        let stats = plane.flush_into(server)?;
        tracer.point(
            "ingest_flush",
            vec![
                ("frames", Json::from(stats.frames)),
                ("shards", Json::from(shards)),
                ("elems", Json::from(stats.elems)),
            ],
        );
        stats.record(metrics);
        metrics.set_gauge("ingest_queue_depth", 0.0);
        Ok(())
    }

    /// Post-run: replay the timeline's critical-path records as spans
    /// (the one-code-path contract with `repro sim`) and snapshot the
    /// byte-exact ledger into the metrics registry.
    pub(crate) fn note_finish(
        tracer: &mut Tracer,
        metrics: &mut Metrics,
        ledger: &NetworkLedger,
        timeline: Option<&Timeline>,
        aggregations: usize,
    ) {
        for r in timeline.map(|tl| tl.records.as_slice()).unwrap_or(&[]) {
            emit_round_spans(tracer, r);
        }
        metrics.inc("uplink_bytes", ledger.uplink_bytes);
        metrics.inc("downlink_bytes", ledger.downlink_bytes);
        metrics.inc("uplink_messages", ledger.uplink_messages);
        metrics.inc("downlink_messages", ledger.downlink_messages);
        metrics.inc("rounds", aggregations as u64);
    }

    /// What a dry protocol run produced.
    pub struct DryOutcome {
        pub ledger: NetworkLedger,
        pub timeline: Timeline,
        /// Model applications (= rounds, or async windows).
        pub aggregations: usize,
        /// Delivered updates the server discarded (stale or duplicate).
        pub dropped: usize,
        /// Mean measured relative quantization MSE (‖g − ĝ‖²/‖g‖²) of the
        /// accepted updates, per aggregation (bit-scheduled runs only).
        pub round_mse: Vec<f64>,
        /// Widths the bit controller chose, per aggregation
        /// (bit-scheduled runs only).
        pub round_bits: Vec<Vec<u8>>,
    }

    /// Bit-schedule harness for a dry run: the schedule, the layer
    /// partition, and the per-layer gradient scale decay (`decay^l` —
    /// the energy concentration that makes per-layer allocation matter;
    /// `1.0` = flat).
    #[derive(Debug, Clone)]
    pub struct DryBits {
        pub schedule: BitSchedule,
        pub map: LayerMap,
        pub decay: f32,
    }

    /// The per-flight RNG seed: injective in the flight index (an odd
    /// multiplier is a bijection on u64), so no two flights — not even
    /// re-dispatches of the SAME client inside one round — can collide
    /// onto one RNG stream. Pinned by `tests/async_rounds.rs`.
    pub fn flight_seed(run_seed: u64, flight: u64) -> u64 {
        run_seed ^ flight.wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// A synthetic gradient with geometric per-layer energy decay.
    pub fn layered_gradient(rng: &mut Pcg64, map: &LayerMap, decay: f32) -> Vec<f32> {
        let mut g = gradient_like(rng, map.param_count());
        for l in 0..map.len() {
            let s = decay.powi(l as i32);
            for v in &mut g[map.segment(l)] {
                *v *= s;
            }
        }
        g
    }

    /// Encode one dry update under the controller's plan. Returns the
    /// serialized frame payload and the measured relative reconstruction
    /// MSE (via real decode — the honest fidelity signal the
    /// time-to-accuracy proxies integrate).
    fn encode_planned(
        pipe: &Pipeline,
        g: &[f32],
        plan: Option<&BitPlan>,
        rng: &mut Pcg64,
    ) -> (Vec<u8>, f64) {
        let mut segs = Vec::new();
        match plan {
            Some(p) if p.segmented => {
                for (l, &b) in p.bits.iter().enumerate() {
                    let seg_pipe = pipe.with_bits(b);
                    segs.push(seg_pipe.encode(
                        &g[p.bounds[l]..p.bounds[l + 1]],
                        Direction::Uplink,
                        &mut PipelineState::new(),
                        rng,
                    ));
                }
            }
            Some(p) => {
                let uni = pipe.with_bits(p.bits[0]);
                segs.push(uni.encode(g, Direction::Uplink, &mut PipelineState::new(), rng));
            }
            None => {
                segs.push(pipe.encode(g, Direction::Uplink, &mut PipelineState::new(), rng));
            }
        }
        let mut err = 0.0f64;
        let mut energy = 0.0f64;
        let mut off = 0usize;
        for seg in &segs {
            let dec = crate::compress::decode(seg).expect("dry frame decodes");
            for (&gi, &di) in g[off..off + dec.len()].iter().zip(&dec) {
                err += ((gi - di) as f64).powi(2);
                energy += (gi as f64).powi(2);
            }
            off += dec.len();
        }
        (wire::serialize_stream(&segs), err / energy.max(1e-30))
    }

    /// One synthetic update as a real wire frame (unscheduled path).
    fn payload(pipe: &Pipeline, n: usize, client: usize, salt: u64) -> Vec<u8> {
        let mut rng = Pcg64::new(salt, client as u64);
        let g = gradient_like(&mut rng, n);
        let enc = pipe.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
        wire::serialize(&enc)
    }

    /// Synchronous FedAvg rounds over the sim-clocked transport.
    pub fn run_sync(
        pipe: &Pipeline,
        sim: &SimConfig,
        n: usize,
        n_clients: usize,
        k: usize,
        rounds: usize,
        seed: u64,
    ) -> Result<DryOutcome> {
        run_sync_bits(pipe, None, sim, n, n_clients, k, rounds, seed)
    }

    /// Synchronous rounds with an optional bit schedule in the loop: the
    /// controller picks widths per round (and per layer under
    /// `adaptive`), clients encode real mixed-width CSG2 segment
    /// streams, and the server's ingest observations feed back — the
    /// full control loop, minus training.
    #[allow(clippy::too_many_arguments)]
    pub fn run_sync_bits(
        pipe: &Pipeline,
        bits: Option<&DryBits>,
        sim: &SimConfig,
        n: usize,
        n_clients: usize,
        k: usize,
        rounds: usize,
        seed: u64,
    ) -> Result<DryOutcome> {
        run_sync_bits_traced(
            pipe,
            bits,
            sim,
            n,
            n_clients,
            k,
            rounds,
            seed,
            1,
            &mut Tracer::disabled(),
            &mut Metrics::new(),
        )
    }

    /// [`run_sync_bits`] with the observability plane in the loop: live
    /// `bit_plan`/`downlink`/`ingest`/`observe` points stamped on the sim
    /// clock, verdict/byte metrics, and a post-run span replay of the
    /// timeline. With a deterministic tracer clock the emitted trace is
    /// byte-identical per seed (pinned by `tests/obs_trace.rs`).
    ///
    /// `shards` sizes the ingest plane (`--ingest-shards`; 1 = inline
    /// fold) — bit-identical outcomes at any value.
    #[allow(clippy::too_many_arguments)]
    pub fn run_sync_bits_traced(
        pipe: &Pipeline,
        bits: Option<&DryBits>,
        sim: &SimConfig,
        n: usize,
        n_clients: usize,
        k: usize,
        rounds: usize,
        seed: u64,
        shards: usize,
        tracer: &mut Tracer,
        metrics: &mut Metrics,
    ) -> Result<DryOutcome> {
        if let Some(b) = bits {
            ensure!(b.map.param_count() == n, "layer map does not cover n");
        }
        let mut controller = bits.map(|b| BitController::new(b.schedule, b.map.clone()));
        let mut transport = SimTransport::new(sim, n_clients, seed);
        let mut server = Server::new(vec![0.0; n], 1.0).with_clients(vec![100; n_clients]);
        let whole_map = LayerMap::whole(n);
        let mut plane = IngestPlane::new(shards, bits.map(|b| &b.map).unwrap_or(&whole_map));
        let mut selector = Pcg64::new(seed, 0x5E1EC7);
        let mut flight = 0u64;
        let mut round_mse = Vec::new();
        let mut round_bits = Vec::new();
        for t in 0..rounds {
            let bit_plan = controller.as_mut().map(|c| c.plan(t, rounds));
            if let Some(at) = transport.clock_ticks() {
                tracer.set_now(at);
            }
            note_plan(tracer, controller.as_ref(), bit_plan.as_ref(), t);
            let k_sel = transport.selection_count(k);
            let selected = selector.sample_indices(n_clients, k_sel);
            let plan = transport.plan_round(&selected);
            transport.broadcast(n * 4, plan.active.len());
            tracer.point(
                "downlink",
                vec![("bytes", Json::from(n * 4)), ("receivers", Json::from(plan.active.len()))],
            );
            let mut mse_of = vec![0.0f64; n_clients];
            let frames: Vec<Frame> = plan
                .active
                .iter()
                .map(|&c| {
                    let mut rng = Pcg64::new(flight_seed(seed, flight), c as u64);
                    flight += 1;
                    let payload = match bits {
                        Some(b) => {
                            let g = layered_gradient(&mut rng, &b.map, b.decay);
                            let plan = bit_plan.as_ref();
                            let (p, mse) = encode_planned(pipe, &g, plan, &mut rng);
                            mse_of[c] = mse;
                            p
                        }
                        None => payload(pipe, n, c, flight_seed(seed, flight - 1)),
                    };
                    Frame {
                        round: server.round(),
                        client_id: c,
                        payload,
                    }
                })
                .collect();
            let delivered = transport.exchange(t + 1, k, n * 4, frames, 300);
            if let Some(at) = transport.clock_ticks() {
                tracer.set_now(at);
            }
            let mut mse_sum = 0.0f64;
            for f in &delivered {
                let (verdict, prepared) = server.ingest_prepare(f);
                note_ingest(tracer, metrics, f, &verdict);
                ensure!(
                    matches!(verdict, Ingest::Accepted { .. }),
                    "sync dry-run: ingest refused client {}",
                    f.client_id
                );
                if let Some(p) = prepared {
                    if plane.full() {
                        flush_plane(&mut plane, &mut server, tracer, metrics)?;
                    }
                    plane.submit(p);
                    metrics.set_gauge("ingest_queue_depth", plane.pending() as f64);
                }
                mse_sum += mse_of[f.client_id];
            }
            flush_plane(&mut plane, &mut server, tracer, metrics)?;
            if let Some(c) = controller.as_mut() {
                let obs = server.round_observations();
                tracer.point(
                    "observe",
                    vec![("round", Json::from(t)), ("segments", Json::from(obs.len()))],
                );
                c.observe(&obs, 0.0, None);
                round_mse.push(mse_sum / delivered.len().max(1) as f64);
                let widths = bit_plan.as_ref().map(|p| p.bits.clone());
                round_bits.push(widths.unwrap_or_default());
            }
            server.finish_round();
        }
        let (ledger, tl) = Box::new(transport).finish();
        let timeline = tl.expect("sim transport has a timeline");
        note_finish(tracer, metrics, &ledger, Some(&timeline), rounds);
        Ok(DryOutcome {
            ledger,
            timeline,
            aggregations: rounds,
            dropped: 0,
            round_mse,
            round_bits,
        })
    }

    /// Buffered-async windows over the same transport + state machine.
    #[allow(clippy::too_many_arguments)]
    pub fn run_async(
        pipe: &Pipeline,
        sim: &SimConfig,
        n: usize,
        n_clients: usize,
        buffer_k: usize,
        concurrency: usize,
        windows: usize,
        max_staleness: usize,
        seed: u64,
    ) -> Result<DryOutcome> {
        run_async_bits(
            pipe,
            None,
            sim,
            n,
            n_clients,
            buffer_k,
            concurrency,
            windows,
            max_staleness,
            seed,
        )
    }

    /// Buffered-async windows with an optional bit schedule: the plan is
    /// refreshed at every window close, so a width change lands *inside*
    /// the open round — in-flight frames keep the widths they were
    /// encoded with (the self-describing headers carry them).
    #[allow(clippy::too_many_arguments)]
    pub fn run_async_bits(
        pipe: &Pipeline,
        bits: Option<&DryBits>,
        sim: &SimConfig,
        n: usize,
        n_clients: usize,
        buffer_k: usize,
        concurrency: usize,
        windows: usize,
        max_staleness: usize,
        seed: u64,
    ) -> Result<DryOutcome> {
        run_async_bits_traced(
            pipe,
            bits,
            sim,
            n,
            n_clients,
            buffer_k,
            concurrency,
            windows,
            max_staleness,
            seed,
            1,
            &mut Tracer::disabled(),
            &mut Metrics::new(),
        )
    }

    /// [`run_async_bits`] with the observability plane in the loop:
    /// `dispatch`/`arrive`/`ingest` points on the virtual clock, a
    /// `queue_depth` gauge moved at both edges (every dispatch and every
    /// arrival, not just window close), and the same post-run span
    /// replay + ledger snapshot as the sync path.
    ///
    /// `shards` sizes the ingest plane (`--ingest-shards`; 1 = inline
    /// fold) — bit-identical outcomes at any value.
    #[allow(clippy::too_many_arguments)]
    pub fn run_async_bits_traced(
        pipe: &Pipeline,
        bits: Option<&DryBits>,
        sim: &SimConfig,
        n: usize,
        n_clients: usize,
        buffer_k: usize,
        concurrency: usize,
        windows: usize,
        max_staleness: usize,
        seed: u64,
        shards: usize,
        tracer: &mut Tracer,
        metrics: &mut Metrics,
    ) -> Result<DryOutcome> {
        ensure!(buffer_k <= n_clients, "buffer exceeds the fleet");
        if let Some(b) = bits {
            ensure!(b.map.param_count() == n, "layer map does not cover n");
        }
        let mut controller = bits.map(|b| BitController::new(b.schedule, b.map.clone()));
        let mut transport = SimTransport::new(sim, n_clients, seed);
        let whole_map = LayerMap::whole(n);
        let mut plane = IngestPlane::new(shards, bits.map(|b| &b.map).unwrap_or(&whole_map));
        let mut server = Server::new(vec![0.0; n], 1.0)
            .with_clients(vec![100; n_clients])
            .with_round_mode(RoundMode::BufferedAsync {
                buffer_k,
                max_staleness,
            });
        let mut selector = Pcg64::new(seed, 0x5E1EC7);
        let mut busy = vec![false; n_clients];
        let mut mse_of = vec![0.0f64; n_clients];
        let mut flight = 0u64;
        let mut bit_plan = controller.as_mut().map(|c| c.plan(0, windows));

        // Mirrors `fl::runner::dispatch_one` exactly (idle sampling,
        // admission lottery, rejection-streak cap) minus the training —
        // keep the two in lockstep so the CI-smoked protocol path and the
        // production event loop enforce the same semantics.
        let mut dispatch_one = |transport: &mut SimTransport,
                                busy: &mut [bool],
                                mse_of: &mut [f64],
                                selector: &mut Pcg64,
                                flight: &mut u64,
                                plan: Option<&BitPlan>,
                                round: usize,
                                tracer: &mut Tracer,
                                metrics: &mut Metrics|
         -> bool {
            let mut attempts = 0usize;
            loop {
                let idle: Vec<usize> = (0..n_clients).filter(|&c| !busy[c]).collect();
                if idle.is_empty() {
                    return false;
                }
                let candidate = idle[selector.below_usize(idle.len())];
                attempts += 1;
                match transport.admit(candidate) {
                    Admission::Admitted => {
                        let fs = flight_seed(seed, *flight);
                        *flight += 1;
                        let payload = match bits {
                            Some(b) => {
                                let mut rng = Pcg64::new(fs, candidate as u64);
                                let g = layered_gradient(&mut rng, &b.map, b.decay);
                                let (p, mse) = encode_planned(pipe, &g, plan, &mut rng);
                                mse_of[candidate] = mse;
                                p
                            }
                            None => payload(pipe, n, candidate, fs),
                        };
                        transport.broadcast(n * 4, 1);
                        if let Some(at) = transport.clock_ticks() {
                            tracer.set_now(at);
                        }
                        tracer.point(
                            "dispatch",
                            vec![("client", Json::from(candidate)), ("round", Json::from(round))],
                        );
                        metrics.inc("dispatches", 1);
                        transport.dispatch(
                            Frame {
                                round,
                                client_id: candidate,
                                payload,
                            },
                            n * 4,
                            300,
                        );
                        busy[candidate] = true;
                        metrics
                            .set_gauge("queue_depth", busy.iter().filter(|&&b| b).count() as f64);
                        return true;
                    }
                    Admission::Offline | Admission::Dropout => {
                        if attempts > n_clients * 4 {
                            return false; // pathological lottery streak
                        }
                    }
                }
            }
        };

        note_plan(tracer, controller.as_ref(), bit_plan.as_ref(), 0);
        for _ in 0..concurrency.min(n_clients) {
            dispatch_one(
                &mut transport,
                &mut busy,
                &mut mse_of,
                &mut selector,
                &mut flight,
                bit_plan.as_ref(),
                server.round(),
                tracer,
                metrics,
            );
        }
        let (mut applied, mut window_dropped, mut total_dropped) = (0usize, 0usize, 0usize);
        let (mut window_mse, mut window_accepted) = (0.0f64, 0usize);
        let mut round_mse = Vec::new();
        let mut round_bits = Vec::new();
        while applied < windows {
            let Some(frame) = transport.recv() else {
                ensure!(
                    dispatch_one(
                        &mut transport,
                        &mut busy,
                        &mut mse_of,
                        &mut selector,
                        &mut flight,
                        bit_plan.as_ref(),
                        server.round(),
                        tracer,
                        metrics,
                    ),
                    "async dry-run starved"
                );
                continue;
            };
            if let Some(at) = transport.clock_ticks() {
                tracer.set_now(at);
            }
            tracer.point("arrive", vec![("client", Json::from(frame.client_id))]);
            busy[frame.client_id] = false;
            // Drain edge of the in-flight gauge (enqueue edge is in
            // `dispatch_one`) — sampling only at window close
            // under-reported the depth between aggregations.
            metrics.set_gauge("queue_depth", busy.iter().filter(|&&b| b).count() as f64);
            let (verdict, prepared) = server.ingest_prepare(&frame);
            note_ingest(tracer, metrics, &frame, &verdict);
            match verdict {
                Ingest::Accepted { .. } => {
                    window_accepted += 1;
                    window_mse += mse_of[frame.client_id];
                    if let Some(p) = prepared {
                        if plane.full() {
                            flush_plane(&mut plane, &mut server, tracer, metrics)?;
                        }
                        plane.submit(p);
                        metrics.set_gauge("ingest_queue_depth", plane.pending() as f64);
                    }
                }
                Ingest::StaleRound | Ingest::Duplicate => {
                    window_dropped += 1;
                    total_dropped += 1;
                }
                Ingest::Malformed => bail!("async dry-run: malformed frame delivered"),
            }
            if server.ready_to_apply() {
                flush_plane(&mut plane, &mut server, tracer, metrics)?;
                if let Some(c) = controller.as_mut() {
                    let obs = server.round_observations();
                    tracer.point(
                        "observe",
                        vec![("round", Json::from(applied)), ("segments", Json::from(obs.len()))],
                    );
                    c.observe(&obs, 0.0, None);
                    round_mse.push(window_mse / window_accepted.max(1) as f64);
                    let widths = bit_plan.as_ref().map(|p| p.bits.clone());
                    round_bits.push(widths.unwrap_or_default());
                }
                let reporters = server.finish_round();
                applied += 1;
                transport.close_window(applied, reporters, window_dropped);
                metrics.set_gauge("queue_depth", busy.iter().filter(|&&b| b).count() as f64);
                window_dropped = 0;
                window_mse = 0.0;
                window_accepted = 0;
                bit_plan = controller.as_mut().map(|c| c.plan(applied, windows));
                note_plan(tracer, controller.as_ref(), bit_plan.as_ref(), applied);
            }
            if applied < windows {
                dispatch_one(
                    &mut transport,
                    &mut busy,
                    &mut mse_of,
                    &mut selector,
                    &mut flight,
                    bit_plan.as_ref(),
                    server.round(),
                    tracer,
                    metrics,
                );
            }
        }
        let (ledger, tl) = Box::new(transport).finish();
        let timeline = tl.expect("sim transport has a timeline");
        note_finish(tracer, metrics, &ledger, Some(&timeline), applied);
        Ok(DryOutcome {
            ledger,
            timeline,
            aggregations: applied,
            dropped: total_dropped,
            round_mse,
            round_bits,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::RoundPolicy;

    fn frame(client_id: usize, bytes: usize) -> Frame {
        Frame {
            round: 0,
            client_id,
            payload: vec![0xAB; bytes],
        }
    }

    #[test]
    fn loopback_delivers_everything_in_order_and_meters() {
        let mut t = Loopback::new();
        assert_eq!(t.selection_count(7), 7);
        let plan = t.plan_round(&[3, 1, 4]);
        assert_eq!(plan.active, vec![3, 1, 4]);
        t.broadcast(100, 5);
        let out = t.exchange(1, 3, 100, vec![frame(3, 10), frame(1, 20), frame(4, 30)], 50);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].client_id, 3);
        assert_eq!(t.ledger().uplink_bytes, 60);
        assert_eq!(t.ledger().downlink_bytes, 500);
        assert_eq!(t.clock_secs(), None);
        let (ledger, timeline) = Box::new(t).finish();
        assert_eq!(ledger.uplink_messages, 3);
        assert!(timeline.is_none());
    }

    #[test]
    fn loopback_async_is_fifo() {
        let mut t = Loopback::new();
        assert_eq!(t.admit(0), Admission::Admitted);
        t.dispatch(frame(0, 11), 100, 10);
        t.dispatch(frame(1, 13), 100, 10);
        assert_eq!(t.recv().unwrap().client_id, 0);
        assert_eq!(t.recv().unwrap().client_id, 1);
        assert!(t.recv().is_none());
        assert_eq!(t.ledger().uplink_bytes, 24);
    }

    #[test]
    fn sim_exchange_returns_survivors_in_selection_order() {
        // Over-selection keeps the first k arrivals but the exchange
        // returns them in SELECTION order — the bit-identity contract.
        let cfg = SimConfig::heterogeneous().with_policy(RoundPolicy::OverSelect {
            over_sample: 1.5,
        });
        let mut t = SimTransport::new(&cfg, 50, 11);
        let k = 4;
        let candidates: Vec<usize> = (0..t.selection_count(k)).collect();
        let plan = t.plan_round(&candidates);
        let frames: Vec<Frame> = plan.active.iter().map(|&c| frame(c, 40_000)).collect();
        let submitted: Vec<usize> = frames.iter().map(|f| f.client_id).collect();
        let kept = t.exchange(1, k, 200_000, frames, 300);
        assert!(kept.len() <= submitted.len());
        // Delivered ids appear in the same relative order as submitted.
        let mut it = submitted.iter();
        for f in &kept {
            assert!(
                it.any(|&s| s == f.client_id),
                "{} out of selection order",
                f.client_id
            );
        }
        // Metering covers exactly the survivors.
        assert_eq!(
            t.ledger().uplink_bytes,
            kept.iter().map(|f| f.wire_bytes() as u64).sum::<u64>()
        );
    }

    #[test]
    fn sim_async_arrivals_follow_the_virtual_clock() {
        // Two identical dispatches except for payload size: the smaller
        // upload arrives first regardless of dispatch order.
        let mut t = SimTransport::new(&SimConfig::uniform(), 4, 3);
        assert_eq!(t.admit(0), Admission::Admitted);
        assert_eq!(t.admit(1), Admission::Admitted);
        t.dispatch(frame(0, 1_000_000), 1_000, 100);
        t.dispatch(frame(1, 1_000), 1_000, 100);
        assert_eq!(t.recv().unwrap().client_id, 1);
        assert_eq!(t.recv().unwrap().client_id, 0);
        assert!(t.recv().is_none());
        // Every delivered frame was metered and the clock advanced.
        assert_eq!(t.ledger().uplink_bytes, 1_001_000);
        assert!(t.clock_secs().unwrap() > 0.0);
        // Window close produces a timeline record.
        t.close_window(1, 2, 0);
        let (_, timeline) = Box::new(t).finish();
        let tl = timeline.unwrap();
        assert_eq!(tl.records.len(), 1);
        assert_eq!(tl.records[0].reporters, 2);
        assert!(tl.records[0].end > 0);
    }
}
