//! Experiment configuration: one [`FlConfig`] fully describes a federated
//! run (task, federation shape, uplink/downlink pipelines, schedules,
//! seed). Constructors mirror the paper's §5.1 setups; everything is
//! overridable (CLI flags / JSON configs map onto these fields).

use anyhow::{bail, Result};

use crate::compress::allocator::BitSchedule;
use crate::compress::deflate::CompressionLevel;
use crate::compress::Pipeline;
use crate::sim::SimConfig;
use crate::util::json::Json;

use super::schedule::LrSchedule;
use super::server::{Downlink, RoundMode};

/// Which workload (and data distribution) to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// MNIST-like, IID split.
    MnistIid,
    /// MNIST-like, Non-IID shard split (≤2 classes/client).
    MnistNonIid,
    /// CIFAR-like, random equal split.
    Cifar,
    /// BraTS-like volumetric segmentation, 10 "hospitals".
    Unet,
}

impl Task {
    pub fn model_key(&self) -> &'static str {
        match self {
            Task::MnistIid | Task::MnistNonIid => "mnist",
            Task::Cifar => "cifar",
            Task::Unet => "unet",
        }
    }

    pub fn eval_artifact(&self) -> String {
        format!("{}_eval", self.model_key())
    }

    pub fn parse(s: &str) -> Result<Task> {
        Ok(match s {
            "mnist-iid" => Task::MnistIid,
            "mnist-noniid" | "mnist" => Task::MnistNonIid,
            "cifar" => Task::Cifar,
            "unet" | "brats" => Task::Unet,
            other => bail!("unknown task '{other}'"),
        })
    }
}

/// A complete federated-learning experiment description.
#[derive(Debug, Clone)]
pub struct FlConfig {
    pub task: Task,
    /// Communication rounds T.
    pub rounds: usize,
    /// Total clients m.
    pub n_clients: usize,
    /// Participation fraction C.
    pub participation: f64,
    /// Round artifact name (selects E/B via the manifest round_cfg).
    pub round_artifact: String,
    /// Manifest round-config key (n_data/batch/epochs).
    pub round_cfg_key: String,
    /// Uplink (gradient) compression pipeline.
    pub uplink: Pipeline,
    /// Bit-width schedule driving the uplink quantizer across the round
    /// loop (`--bits const:<b>|anneal:<hi>..<lo>|adaptive[:<budget>]`).
    /// `None` = legacy fixed width (exactly the `uplink` pipeline's).
    /// `const:<b>` through the controller is bit-identical to the legacy
    /// path; `adaptive` emits per-layer mixed-width CSG2 segment streams.
    pub bit_schedule: Option<BitSchedule>,
    /// Downlink (model broadcast) policy; [`Downlink::Float32Model`]
    /// reproduces the paper's uncompressed-broadcast cost accounting.
    pub downlink: Downlink,
    /// Server learning rate η_s (paper: 1 everywhere).
    pub eta_s: f32,
    /// Client learning-rate schedule η_c.
    pub client_lr: LrSchedule,
    pub seed: u64,
    /// Evaluate every k rounds (0 = only final).
    pub eval_every: usize,
    /// Route quantization through the Pallas kernel artifacts instead of
    /// the native Rust pipeline (demonstrates the L1 path; slower on CPU).
    pub use_kernel_quantizer: bool,
    /// Worker threads for the per-round client train+encode loop.
    /// `1` (default) runs serially; `0` means one per available core.
    /// Results are bit-identical at any value: every client owns its RNG
    /// lane, EF residual and scratch, and updates are aggregated in
    /// selection order regardless of completion order.
    pub client_threads: usize,
    /// DEFLATE effort for both pipelines (`--deflate-level
    /// fast|default|best`). Applied to `uplink` / `downlink` when the
    /// runner builds its pipelines, and recorded per round in the
    /// history. Level changes the bytes (better matches), never the
    /// validity of the stream.
    pub deflate_level: CompressionLevel,
    /// Worker threads for the DEFLATE stage of both pipelines
    /// (`--deflate-threads N`, 0 = auto, 1 = serial). Scheduling only:
    /// compressed bytes are identical at every value
    /// ([`crate::compress::deflate::deflate_into`]).
    pub deflate_threads: usize,
    /// Ingest-plane shards for the server's fused dequantize+accumulate
    /// fold (`--ingest-shards N`). `1` (default) folds inline on the
    /// coordinator; `0` means one per available core. Results are
    /// bit-identical at any value — workers own disjoint contiguous
    /// accumulator slices and fold in arrival order
    /// ([`crate::fl::ingest`]).
    pub ingest_shards: usize,
    /// Optional systems simulator ([`crate::sim`]): replay every round on
    /// a virtual clock over a heterogeneous device fleet. `None` keeps the
    /// pure byte-accounting harness.
    pub sim: Option<SimConfig>,
    /// Aggregation policy: classic synchronous FedAvg rounds, or
    /// FedBuff-style buffered-async windows
    /// ([`RoundMode::BufferedAsync`]) where slow uplinks no longer gate
    /// the fleet. In async mode `rounds` counts *aggregations* (model
    /// versions), so runs stay comparable at equal update counts.
    pub round_mode: RoundMode,
    /// Write the run's structured trace (span/point events + final
    /// metrics snapshot, JSONL) to this path (`--trace FILE`). With the
    /// simulator attached, the trace clock is virtual sim time and the
    /// file is byte-identical per seed; otherwise wall time.
    pub trace: Option<std::path::PathBuf>,
    pub verbose: bool,
}

impl FlConfig {
    /// MNIST §5.1: 100 clients, C=0.1, E=1, B=10, SGD; IID 50 rounds with
    /// constant η_c=0.1, Non-IID 500 rounds with cosine η_c.
    pub fn mnist(non_iid: bool) -> FlConfig {
        let rounds = if non_iid { 500 } else { 50 };
        FlConfig {
            task: if non_iid {
                Task::MnistNonIid
            } else {
                Task::MnistIid
            },
            rounds,
            n_clients: 100,
            participation: 0.1,
            round_artifact: "mnist_round".into(),
            round_cfg_key: "mnist".into(),
            uplink: Pipeline::float32(),
            bit_schedule: None,
            downlink: Downlink::Float32Model,
            eta_s: 1.0,
            client_lr: if non_iid {
                LrSchedule::Cosine {
                    base: 0.1,
                    total: rounds,
                }
            } else {
                LrSchedule::Const(0.1)
            },
            seed: 42,
            eval_every: 5,
            use_kernel_quantizer: false,
            client_threads: 1,
            deflate_level: CompressionLevel::Default,
            deflate_threads: 1,
            ingest_shards: 1,
            sim: None,
            round_mode: RoundMode::Synchronous,
            trace: None,
            verbose: false,
        }
    }

    /// CIFAR §5.1: 100 clients, C=0.1, E=5, B=50, momentum 0.9,
    /// cosine η_c from 0.1, 2000 rounds.
    pub fn cifar() -> FlConfig {
        FlConfig {
            task: Task::Cifar,
            rounds: 2000,
            n_clients: 100,
            participation: 0.1,
            round_artifact: "cifar_round".into(),
            round_cfg_key: "cifar".into(),
            uplink: Pipeline::float32(),
            bit_schedule: None,
            downlink: Downlink::Float32Model,
            eta_s: 1.0,
            client_lr: LrSchedule::Cosine {
                base: 0.1,
                total: 2000,
            },
            seed: 42,
            eval_every: 20,
            use_kernel_quantizer: false,
            client_threads: 1,
            deflate_level: CompressionLevel::Default,
            deflate_threads: 1,
            ingest_shards: 1,
            sim: None,
            round_mode: RoundMode::Synchronous,
            trace: None,
            verbose: false,
        }
    }

    /// Table 1's second system: (B=50, E=1, C=0.5) — same data touched.
    pub fn cifar_e1() -> FlConfig {
        let mut c = Self::cifar();
        c.round_artifact = "cifar_round_e1".into();
        c.round_cfg_key = "cifar_e1".into();
        c.participation = 0.5;
        c.rounds = 400; // 2000/5: same number of data passes
        c.client_lr = LrSchedule::Cosine {
            base: 0.1,
            total: 400,
        };
        c
    }

    /// BraTS §5.1: 10 hospitals, C=1, E=3, B=3, Adam, cosine warm restarts
    /// at rounds 20 and 60, 100 rounds.
    pub fn unet() -> FlConfig {
        FlConfig {
            task: Task::Unet,
            rounds: 100,
            n_clients: 10,
            participation: 1.0,
            round_artifact: "unet_round".into(),
            round_cfg_key: "unet".into(),
            uplink: Pipeline::float32(),
            bit_schedule: None,
            downlink: Downlink::Float32Model,
            eta_s: 1.0,
            client_lr: LrSchedule::cosine_warm_restarts(1e-3, 100, vec![20, 60]),
            seed: 42,
            eval_every: 5,
            use_kernel_quantizer: false,
            client_threads: 1,
            deflate_level: CompressionLevel::Default,
            deflate_threads: 1,
            ingest_shards: 1,
            sim: None,
            round_mode: RoundMode::Synchronous,
            trace: None,
            verbose: false,
        }
    }

    /// Set the uplink (gradient) compression pipeline.
    pub fn with_uplink(mut self, uplink: Pipeline) -> Self {
        self.uplink = uplink;
        self
    }

    /// Broadcast quantized model deltas through `pipeline` (the paper's
    /// round-trip scheme) instead of the raw float32 model.
    pub fn with_downlink(mut self, pipeline: Pipeline) -> Self {
        self.downlink = Downlink::Delta(pipeline);
        self
    }

    pub fn with_rounds(mut self, rounds: usize) -> Self {
        // Keep cosine horizons in sync with the shortened run.
        match &mut self.client_lr {
            LrSchedule::Cosine { total, .. } => *total = rounds,
            LrSchedule::CosineWarmRestarts { total, restarts, .. } => {
                let scale = rounds as f64 / (*total).max(1) as f64;
                for r in restarts.iter_mut() {
                    *r = ((*r as f64) * scale).round() as usize;
                }
                restarts.retain(|&r| r > 0 && r < rounds);
                // Aggressive downscaling can collide neighbors.
                restarts.dedup();
                *total = rounds;
            }
            LrSchedule::Const(_) => {}
        }
        self.rounds = rounds;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Attach the discrete-event systems simulator: rounds play out on a
    /// virtual clock over a device fleet sampled from `sim.tiers`, and the
    /// run yields a [`crate::sim::Timeline`] alongside the `History`.
    pub fn with_sim(mut self, sim: SimConfig) -> Self {
        self.sim = Some(sim);
        self
    }

    /// Run the per-round client train+encode loop on `threads` workers
    /// (`0` = one per available core, `1` = serial). Bit-identical
    /// results at any value.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.client_threads = threads;
        self
    }

    /// Select the DEFLATE effort for both pipelines
    /// (`--deflate-level fast|default|best`).
    pub fn with_deflate_level(mut self, level: CompressionLevel) -> Self {
        self.deflate_level = level;
        self
    }

    /// Run the DEFLATE stage of both pipelines on `threads` workers
    /// (`--deflate-threads`: `0` = one per available core, `1` = serial).
    /// Compressed bytes are identical at any value.
    pub fn with_deflate_threads(mut self, threads: usize) -> Self {
        self.deflate_threads = threads;
        self
    }

    /// Shard the server's ingest fold across `shards` workers
    /// (`--ingest-shards`: `0` = one per available core, `1` = inline
    /// serial fold). Bit-identical results at any value.
    pub fn with_ingest_shards(mut self, shards: usize) -> Self {
        self.ingest_shards = shards;
        self
    }

    /// Select the aggregation policy (`--round-mode sync|async:K[:S]`):
    /// synchronous FedAvg rounds, or FedBuff-style buffered-async windows.
    pub fn with_round_mode(mut self, mode: RoundMode) -> Self {
        self.round_mode = mode;
        self
    }

    /// Drive the uplink quantizer's width from a [`BitSchedule`]
    /// (`--bits const:<b>|anneal:<hi>..<lo>|adaptive[:<budget>]`) instead
    /// of the pipeline's fixed width.
    pub fn with_bit_schedule(mut self, schedule: BitSchedule) -> Self {
        self.bit_schedule = Some(schedule);
        self
    }

    /// Write the run's observability trace (JSONL events + metrics
    /// snapshot) to `path` (`--trace FILE`).
    pub fn with_trace(mut self, path: impl Into<std::path::PathBuf>) -> Self {
        self.trace = Some(path.into());
        self
    }

    /// Resolve [`Self::client_threads`] (`0` → available parallelism).
    pub fn effective_threads(&self) -> usize {
        match self.client_threads {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            t => t,
        }
    }

    /// Resolve [`Self::deflate_threads`] (`0` → available parallelism).
    /// The per-call [`crate::compress::deflate::deflate_into`] clamp to
    /// the chunk count still applies on top.
    pub fn effective_deflate_threads(&self) -> usize {
        match self.deflate_threads {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            t => t,
        }
    }

    /// Both experiment pipelines with this config's DEFLATE level and
    /// thread count applied — what the runner actually encodes with.
    /// Width reconfiguration ([`Pipeline::with_bits`]) clones, so the
    /// settings survive adaptive per-layer rebuilds.
    pub fn tuned_uplink(&self) -> Pipeline {
        self.uplink
            .clone()
            .with_deflate_level(self.deflate_level)
            .with_deflate_threads(self.deflate_threads)
    }

    /// [`Self::tuned_uplink`], for the downlink policy.
    pub fn tuned_downlink(&self) -> Downlink {
        match &self.downlink {
            Downlink::Float32Model => Downlink::Float32Model,
            Downlink::Delta(p) => Downlink::Delta(
                p.clone()
                    .with_deflate_level(self.deflate_level)
                    .with_deflate_threads(self.deflate_threads),
            ),
        }
    }

    /// Resolve [`Self::ingest_shards`] (`0` → available parallelism,
    /// capped at the per-shard metrics table —
    /// [`crate::fl::ingest::auto_shards`]).
    pub fn effective_ingest_shards(&self) -> usize {
        match self.ingest_shards {
            0 => super::ingest::auto_shards(),
            s => s,
        }
    }

    /// Clients selected per round.
    pub fn clients_per_round(&self) -> usize {
        ((self.n_clients as f64 * self.participation).round() as usize)
            .clamp(1, self.n_clients)
    }

    /// Summary for logs / results files.
    pub fn describe(&self) -> Json {
        Json::obj()
            .set("task", format!("{:?}", self.task))
            .set("rounds", self.rounds)
            .set("n_clients", self.n_clients)
            .set("participation", self.participation)
            .set("uplink", self.uplink.name())
            .set(
                "bits",
                self.bit_schedule.map_or("fixed".to_string(), |s| s.name()),
            )
            .set("downlink", self.downlink.name())
            .set("seed", self.seed)
            .set("threads", self.client_threads)
            .set("deflate_level", self.deflate_level.name())
            .set("deflate_threads", self.deflate_threads)
            .set("ingest_shards", self.ingest_shards)
            .set("round_mode", self.round_mode.name())
            .set("round_artifact", self.round_artifact.as_str())
            .set(
                "sim",
                self.sim.as_ref().map_or("off".to_string(), SimConfig::name),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults() {
        let m = FlConfig::mnist(true);
        assert_eq!(m.rounds, 500);
        assert_eq!(m.clients_per_round(), 10);
        assert!(matches!(m.downlink, Downlink::Float32Model));
        let mi = FlConfig::mnist(false);
        assert_eq!(mi.rounds, 50);
        let c = FlConfig::cifar();
        assert_eq!(c.clients_per_round(), 10);
        let c1 = FlConfig::cifar_e1();
        assert_eq!(c1.clients_per_round(), 50);
        let u = FlConfig::unet();
        assert_eq!(u.clients_per_round(), 10);
        assert_eq!(u.task.eval_artifact(), "unet_eval");
    }

    #[test]
    fn with_rounds_rescales_schedules() {
        let c = FlConfig::cifar().with_rounds(100);
        match c.client_lr {
            LrSchedule::Cosine { total, .. } => assert_eq!(total, 100),
            _ => panic!(),
        }
        let u = FlConfig::unet().with_rounds(50);
        match u.client_lr {
            LrSchedule::CosineWarmRestarts { total, restarts, .. } => {
                assert_eq!(total, 50);
                assert_eq!(restarts, vec![10, 30]);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn round_trip_config_builders() {
        let cfg = FlConfig::mnist(false)
            .with_uplink(Pipeline::cosine(4))
            .with_downlink(Pipeline::cosine(8));
        assert_eq!(cfg.uplink.name(), "cosine-4 +deflate");
        match &cfg.downlink {
            Downlink::Delta(p) => assert_eq!(p.name(), "cosine-8 +deflate"),
            other => panic!("unexpected downlink {other:?}"),
        }
    }

    #[test]
    fn sim_builder_and_describe() {
        let plain = FlConfig::mnist(false);
        assert!(plain.sim.is_none());
        assert_eq!(plain.describe().get("sim").unwrap().as_str(), Some("off"));
        let cfg = FlConfig::mnist(false).with_sim(SimConfig::heterogeneous());
        let sim = cfg.sim.as_ref().expect("sim attached");
        assert_eq!(sim.tiers.len(), 6);
        let described = cfg.describe().get("sim").unwrap().as_str().unwrap().to_string();
        assert!(described.contains("6 tiers"), "{described}");
    }

    #[test]
    fn bit_schedule_builder_and_describe() {
        let cfg = FlConfig::mnist(false);
        assert!(cfg.bit_schedule.is_none());
        assert_eq!(cfg.describe().get("bits").unwrap().as_str(), Some("fixed"));
        let cfg = cfg.with_bit_schedule(BitSchedule::Anneal { hi: 8, lo: 2 });
        assert_eq!(
            cfg.describe().get("bits").unwrap().as_str(),
            Some("anneal:8..2")
        );
    }

    #[test]
    fn round_mode_builder_and_describe() {
        let cfg = FlConfig::mnist(false);
        assert_eq!(cfg.round_mode, RoundMode::Synchronous);
        assert_eq!(
            cfg.describe().get("round_mode").unwrap().as_str(),
            Some("sync")
        );
        let cfg = cfg.with_round_mode(RoundMode::BufferedAsync {
            buffer_k: 5,
            max_staleness: 3,
        });
        assert_eq!(
            cfg.describe().get("round_mode").unwrap().as_str(),
            Some("async:5 (≤3 stale)")
        );
    }

    #[test]
    fn ingest_shards_builder_and_describe() {
        let cfg = FlConfig::mnist(false);
        assert_eq!(cfg.ingest_shards, 1, "serial fold by default");
        assert_eq!(cfg.effective_ingest_shards(), 1);
        let cfg = cfg.with_ingest_shards(4);
        assert_eq!(cfg.effective_ingest_shards(), 4);
        assert_eq!(
            cfg.describe().get("ingest_shards").unwrap().as_usize(),
            Some(4)
        );
        // 0 = auto: always at least one worker.
        let auto = FlConfig::mnist(false).with_ingest_shards(0);
        assert!(auto.effective_ingest_shards() >= 1);
    }

    #[test]
    fn deflate_knobs_builders_and_describe() {
        let cfg = FlConfig::mnist(false);
        assert_eq!(cfg.deflate_level, CompressionLevel::Default);
        assert_eq!(cfg.deflate_threads, 1, "serial DEFLATE by default");
        assert_eq!(cfg.effective_deflate_threads(), 1);
        let cfg = cfg
            .with_uplink(Pipeline::cosine(4))
            .with_downlink(Pipeline::cosine(8))
            .with_deflate_level(CompressionLevel::Fast)
            .with_deflate_threads(4);
        assert_eq!(cfg.effective_deflate_threads(), 4);
        let d = cfg.describe();
        assert_eq!(d.get("deflate_level").unwrap().as_str(), Some("fast"));
        assert_eq!(d.get("deflate_threads").unwrap().as_usize(), Some(4));
        // The tuned pipelines carry the knobs …
        let up = cfg.tuned_uplink();
        assert_eq!(up.level, CompressionLevel::Fast);
        assert_eq!(up.deflate_threads, 4);
        match cfg.tuned_downlink() {
            Downlink::Delta(p) => {
                assert_eq!(p.level, CompressionLevel::Fast);
                assert_eq!(p.deflate_threads, 4);
            }
            other => panic!("unexpected downlink {other:?}"),
        }
        // … and width rebuilds (the adaptive schedule's path) keep them.
        let rebuilt = up.with_bits(2);
        assert_eq!(rebuilt.level, CompressionLevel::Fast);
        assert_eq!(rebuilt.deflate_threads, 4);
        // 0 = auto resolves to at least one worker.
        let auto = FlConfig::mnist(false).with_deflate_threads(0);
        assert!(auto.effective_deflate_threads() >= 1);
    }

    #[test]
    fn task_parsing() {
        assert_eq!(Task::parse("mnist-iid").unwrap(), Task::MnistIid);
        assert_eq!(Task::parse("cifar").unwrap(), Task::Cifar);
        assert_eq!(Task::parse("brats").unwrap(), Task::Unet);
        assert!(Task::parse("imagenet").is_err());
    }
}
