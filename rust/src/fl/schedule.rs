//! Client learning-rate schedules (§5.1): constant, cosine decay, and
//! cosine with warm restarts (Loshchilov & Hutter [24], used for BraTS
//! with restarts at rounds 20 and 60).

use std::f64::consts::PI;

/// η_c as a function of the round index `t ∈ [0, total)`.
#[derive(Debug, Clone)]
pub enum LrSchedule {
    Const(f64),
    /// Cosine from `base` to 0 over `total` rounds.
    Cosine { base: f64, total: usize },
    /// Cosine with warm restarts at the given round indices.
    CosineWarmRestarts {
        base: f64,
        total: usize,
        restarts: Vec<usize>,
    },
}

impl LrSchedule {
    /// Build a warm-restart schedule with the restart list validated on
    /// construction: sorted, deduplicated, and stripped of no-op entries
    /// (`0` re-anchors the first segment at its own boundary; anything
    /// `≥ total` can never fire). [`LrSchedule::at`] is additionally
    /// robust to hand-built unnormalized lists — it scans for the
    /// enclosing segment instead of trusting the order — so construction
    /// and evaluation agree for every input.
    pub fn cosine_warm_restarts(base: f64, total: usize, mut restarts: Vec<usize>) -> LrSchedule {
        restarts.retain(|&r| r > 0 && r < total);
        restarts.sort_unstable();
        restarts.dedup();
        LrSchedule::CosineWarmRestarts {
            base,
            total,
            restarts,
        }
    }

    /// η_c at round `t`. Defined for ALL `t`: past the horizon
    /// (`t ≥ total`) every cosine variant has fully decayed and returns
    /// exactly `0.0` — the schedule's true endpoint, not a silent floor
    /// at the last pre-zero sample (the old clamp made figure harnesses
    /// that overrun by one keep training at a stale rate).
    pub fn at(&self, t: usize) -> f64 {
        match self {
            LrSchedule::Const(lr) => *lr,
            LrSchedule::Cosine { base, total } => {
                let total = (*total).max(1);
                if t >= total {
                    return 0.0;
                }
                base * 0.5 * (1.0 + (PI * t as f64 / total as f64).cos())
            }
            LrSchedule::CosineWarmRestarts {
                base,
                total,
                restarts,
            } => {
                let total = (*total).max(1);
                if t >= total {
                    return 0.0;
                }
                // Enclosing segment [seg_start, seg_end): the largest
                // valid restart ≤ t and the smallest valid restart > t.
                // A linear scan (no sort/order assumption) keeps the
                // result correct even for unsorted or duplicate-laden
                // hand-built lists; restarts ≥ total never fire and never
                // bound a segment.
                let mut seg_start = 0usize;
                let mut seg_end = total;
                for &r in restarts {
                    if r >= total {
                        continue;
                    }
                    if r <= t {
                        seg_start = seg_start.max(r);
                    } else {
                        seg_end = seg_end.min(r);
                    }
                }
                let len = (seg_end - seg_start).max(1);
                let local = t - seg_start; // < len by construction
                base * 0.5 * (1.0 + (PI * local as f64 / len as f64).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_is_const() {
        let s = LrSchedule::Const(0.1);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(999), 0.1);
    }

    #[test]
    fn cosine_decays_to_near_zero() {
        let s = LrSchedule::Cosine {
            base: 0.1,
            total: 100,
        };
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        assert!(s.at(50) < 0.06 && s.at(50) > 0.04);
        assert!(s.at(99) < 0.001);
        // Monotone decreasing.
        for t in 1..100 {
            assert!(s.at(t) <= s.at(t - 1) + 1e-12);
        }
    }

    #[test]
    fn cosine_is_exactly_zero_past_the_horizon() {
        // The old clamp silently floored the LR at the last pre-zero
        // sample for every t ≥ total; the defined behavior is the true
        // endpoint: the cosine has fully decayed.
        let s = LrSchedule::Cosine {
            base: 0.1,
            total: 100,
        };
        for t in [100, 101, 150, 10_000] {
            assert_eq!(s.at(t), 0.0, "at({t})");
        }
        // And the horizon value is strictly below the last in-range one.
        assert!(s.at(100) < s.at(99));
    }

    #[test]
    fn warm_restarts_empty_list_is_plain_cosine() {
        let plain = LrSchedule::Cosine {
            base: 0.1,
            total: 50,
        };
        let empty = LrSchedule::CosineWarmRestarts {
            base: 0.1,
            total: 50,
            restarts: vec![],
        };
        for t in 0..60 {
            assert!(
                (plain.at(t) - empty.at(t)).abs() < 1e-15,
                "t={t}: {} vs {}",
                plain.at(t),
                empty.at(t)
            );
        }
    }

    #[test]
    fn warm_restart_at_round_zero_is_plain_cosine() {
        // A restart at 0 only re-anchors the first segment at its own
        // boundary: the schedule is the plain cosine over [0, total).
        let s = LrSchedule::CosineWarmRestarts {
            base: 0.2,
            total: 40,
            restarts: vec![0],
        };
        let plain = LrSchedule::Cosine {
            base: 0.2,
            total: 40,
        };
        for t in 0..40 {
            assert!((s.at(t) - plain.at(t)).abs() < 1e-15, "t={t}");
        }
    }

    #[test]
    fn warm_restart_beyond_total_never_fires_and_never_missegments() {
        // A restart index ≥ total can never fire. The old code let it
        // BOUND the final segment anyway, silently stretching the decay
        // past the training horizon so the LR never reached its floor.
        // Defined behavior: such restarts are inert — the schedule is
        // the plain cosine over [0, total).
        let s = LrSchedule::CosineWarmRestarts {
            base: 0.1,
            total: 100,
            restarts: vec![150],
        };
        let plain = LrSchedule::Cosine {
            base: 0.1,
            total: 100,
        };
        for t in 0..110 {
            assert!((s.at(t) - plain.at(t)).abs() < 1e-15, "t={t}");
        }
        // The validated constructor strips them outright.
        let LrSchedule::CosineWarmRestarts { restarts, .. } =
            LrSchedule::cosine_warm_restarts(0.1, 100, vec![150, 0, 100])
        else {
            panic!()
        };
        assert!(restarts.is_empty(), "{restarts:?}");
    }

    #[test]
    fn warm_restarts_are_exactly_zero_past_the_horizon() {
        // Querying past `total` (figure harnesses overrun by one) returns
        // the fully-decayed endpoint, not a frozen stale rate.
        let s = LrSchedule::CosineWarmRestarts {
            base: 0.1,
            total: 100,
            restarts: vec![20, 60],
        };
        for t in [100, 101, 150, 10_000] {
            assert_eq!(s.at(t), 0.0, "at({t})");
        }
        assert!(s.at(99) > 0.0, "last in-range round still trains");
    }

    #[test]
    fn unsorted_or_duplicated_restarts_segment_correctly() {
        // The old segment scan trusted sort order: an unsorted list
        // truncated segments at the wrong boundary. The fix makes `at`
        // order-independent AND the constructor normalize.
        let sorted = LrSchedule::CosineWarmRestarts {
            base: 0.1,
            total: 100,
            restarts: vec![20, 60],
        };
        let shuffled = LrSchedule::CosineWarmRestarts {
            base: 0.1,
            total: 100,
            restarts: vec![60, 20, 60, 150, 0],
        };
        let constructed = LrSchedule::cosine_warm_restarts(0.1, 100, vec![60, 20, 60, 150, 0]);
        for t in 0..105 {
            assert!(
                (sorted.at(t) - shuffled.at(t)).abs() < 1e-15,
                "t={t}: {} vs {}",
                sorted.at(t),
                shuffled.at(t)
            );
            assert!((sorted.at(t) - constructed.at(t)).abs() < 1e-15, "t={t}");
        }
        // The constructor's normalized list is sorted and deduplicated.
        let LrSchedule::CosineWarmRestarts { restarts, .. } = constructed else {
            panic!()
        };
        assert_eq!(restarts, vec![20, 60]);
    }

    #[test]
    fn warm_restarts_jump_back_up() {
        let s = LrSchedule::CosineWarmRestarts {
            base: 0.1,
            total: 100,
            restarts: vec![20, 60],
        };
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        let before_restart = s.at(19);
        let at_restart = s.at(20);
        assert!(at_restart > before_restart, "{at_restart} vs {before_restart}");
        assert!((at_restart - 0.1).abs() < 1e-12);
        let before_second = s.at(59);
        assert!(s.at(60) > before_second);
        assert!(s.at(99) < 0.01);
    }
}
