//! Client learning-rate schedules (§5.1): constant, cosine decay, and
//! cosine with warm restarts (Loshchilov & Hutter [24], used for BraTS
//! with restarts at rounds 20 and 60).

use std::f64::consts::PI;

/// η_c as a function of the round index `t ∈ [0, total)`.
#[derive(Debug, Clone)]
pub enum LrSchedule {
    Const(f64),
    /// Cosine from `base` to 0 over `total` rounds.
    Cosine { base: f64, total: usize },
    /// Cosine with warm restarts at the given round indices.
    CosineWarmRestarts {
        base: f64,
        total: usize,
        restarts: Vec<usize>,
    },
}

impl LrSchedule {
    pub fn at(&self, t: usize) -> f64 {
        match self {
            LrSchedule::Const(lr) => *lr,
            LrSchedule::Cosine { base, total } => {
                let total = (*total).max(1);
                let t = t.min(total - 1);
                base * 0.5 * (1.0 + (PI * t as f64 / total as f64).cos())
            }
            LrSchedule::CosineWarmRestarts {
                base,
                total,
                restarts,
            } => {
                // Segment boundaries: [0, r1), [r1, r2), ..., [rk, total).
                let mut seg_start = 0usize;
                let mut seg_end = *total;
                for &r in restarts {
                    if t >= r {
                        seg_start = r;
                    } else {
                        seg_end = r;
                        break;
                    }
                }
                let len = (seg_end - seg_start).max(1);
                let local = (t - seg_start).min(len - 1);
                base * 0.5 * (1.0 + (PI * local as f64 / len as f64).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_is_const() {
        let s = LrSchedule::Const(0.1);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(999), 0.1);
    }

    #[test]
    fn cosine_decays_to_near_zero() {
        let s = LrSchedule::Cosine {
            base: 0.1,
            total: 100,
        };
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        assert!(s.at(50) < 0.06 && s.at(50) > 0.04);
        assert!(s.at(99) < 0.001);
        // Monotone decreasing.
        for t in 1..100 {
            assert!(s.at(t) <= s.at(t - 1) + 1e-12);
        }
    }

    #[test]
    fn warm_restarts_empty_list_is_plain_cosine() {
        let plain = LrSchedule::Cosine {
            base: 0.1,
            total: 50,
        };
        let empty = LrSchedule::CosineWarmRestarts {
            base: 0.1,
            total: 50,
            restarts: vec![],
        };
        for t in 0..60 {
            assert!(
                (plain.at(t) - empty.at(t)).abs() < 1e-15,
                "t={t}: {} vs {}",
                plain.at(t),
                empty.at(t)
            );
        }
    }

    #[test]
    fn warm_restart_at_round_zero_is_plain_cosine() {
        // A restart at 0 only re-anchors the first segment at its own
        // boundary: the schedule is the plain cosine over [0, total).
        let s = LrSchedule::CosineWarmRestarts {
            base: 0.2,
            total: 40,
            restarts: vec![0],
        };
        let plain = LrSchedule::Cosine {
            base: 0.2,
            total: 40,
        };
        for t in 0..40 {
            assert!((s.at(t) - plain.at(t)).abs() < 1e-15, "t={t}");
        }
    }

    #[test]
    fn warm_restart_beyond_total_stretches_the_segment() {
        // A restart index ≥ total never fires, but it still bounds the
        // segment: the cosine decays over [0, restart), so the LR stays
        // above the plain-cosine floor at the end of training and never
        // jumps back up.
        let s = LrSchedule::CosineWarmRestarts {
            base: 0.1,
            total: 100,
            restarts: vec![150],
        };
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        for t in 1..100 {
            assert!(s.at(t) <= s.at(t - 1) + 1e-12, "jumped up at t={t}");
        }
        let plain_end = LrSchedule::Cosine {
            base: 0.1,
            total: 100,
        }
        .at(99);
        assert!(s.at(99) > plain_end, "{} !> {plain_end}", s.at(99));
        assert!(s.at(99) > 0.01, "segment should not have fully decayed");
    }

    #[test]
    fn warm_restarts_past_the_horizon_stay_bounded() {
        // Querying past `total` (figure harnesses overrun by one) clamps
        // into the last segment instead of panicking or going negative.
        let s = LrSchedule::CosineWarmRestarts {
            base: 0.1,
            total: 100,
            restarts: vec![20, 60],
        };
        for t in [100, 101, 150, 10_000] {
            let v = s.at(t);
            assert!(v.is_finite() && (0.0..=0.1).contains(&v), "at({t}) = {v}");
            assert!((v - s.at(99)).abs() < 1e-12, "clamp should freeze the LR");
        }
    }

    #[test]
    fn warm_restarts_jump_back_up() {
        let s = LrSchedule::CosineWarmRestarts {
            base: 0.1,
            total: 100,
            restarts: vec![20, 60],
        };
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        let before_restart = s.at(19);
        let at_restart = s.at(20);
        assert!(at_restart > before_restart, "{at_restart} vs {before_restart}");
        assert!((at_restart - 0.1).abs() < 1e-12);
        let before_second = s.at(59);
        assert!(s.at(60) > before_second);
        assert!(s.at(99) < 0.01);
    }
}
