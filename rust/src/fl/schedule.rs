//! Client learning-rate schedules (§5.1): constant, cosine decay, and
//! cosine with warm restarts (Loshchilov & Hutter [24], used for BraTS
//! with restarts at rounds 20 and 60).

use std::f64::consts::PI;

/// η_c as a function of the round index `t ∈ [0, total)`.
#[derive(Debug, Clone)]
pub enum LrSchedule {
    Const(f64),
    /// Cosine from `base` to 0 over `total` rounds.
    Cosine { base: f64, total: usize },
    /// Cosine with warm restarts at the given round indices.
    CosineWarmRestarts {
        base: f64,
        total: usize,
        restarts: Vec<usize>,
    },
}

impl LrSchedule {
    pub fn at(&self, t: usize) -> f64 {
        match self {
            LrSchedule::Const(lr) => *lr,
            LrSchedule::Cosine { base, total } => {
                let total = (*total).max(1);
                let t = t.min(total - 1);
                base * 0.5 * (1.0 + (PI * t as f64 / total as f64).cos())
            }
            LrSchedule::CosineWarmRestarts {
                base,
                total,
                restarts,
            } => {
                // Segment boundaries: [0, r1), [r1, r2), ..., [rk, total).
                let mut seg_start = 0usize;
                let mut seg_end = *total;
                for &r in restarts {
                    if t >= r {
                        seg_start = r;
                    } else {
                        seg_end = r;
                        break;
                    }
                }
                let len = (seg_end - seg_start).max(1);
                let local = (t - seg_start).min(len - 1);
                base * 0.5 * (1.0 + (PI * local as f64 / len as f64).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_is_const() {
        let s = LrSchedule::Const(0.1);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(999), 0.1);
    }

    #[test]
    fn cosine_decays_to_near_zero() {
        let s = LrSchedule::Cosine {
            base: 0.1,
            total: 100,
        };
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        assert!(s.at(50) < 0.06 && s.at(50) > 0.04);
        assert!(s.at(99) < 0.001);
        // Monotone decreasing.
        for t in 1..100 {
            assert!(s.at(t) <= s.at(t - 1) + 1e-12);
        }
    }

    #[test]
    fn warm_restarts_jump_back_up() {
        let s = LrSchedule::CosineWarmRestarts {
            base: 0.1,
            total: 100,
            restarts: vec![20, 60],
        };
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        let before_restart = s.at(19);
        let at_restart = s.at(20);
        assert!(at_restart > before_restart, "{at_restart} vs {before_restart}");
        assert!((at_restart - 0.1).abs() < 1e-12);
        let before_second = s.at(59);
        assert!(s.at(60) > before_second);
        assert!(s.at(99) < 0.01);
    }
}
