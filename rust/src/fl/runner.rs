//! The experiment loop: a thin event-loop driver that wires clients, the
//! server's frame-ingest state machine, the compression pipelines and a
//! [`Transport`] into the full round structure of Algorithm 1 and produces
//! a [`History`]. Every client ↔ server exchange is a serialized
//! [`Frame`]; byte metering and the straggler/delivery policy live in the
//! transport, not here.
//!
//! Synchronous round structure (round-trip aware):
//! 1. the server produces the round's broadcast ([`Server::broadcast`]) —
//!    raw float32 model, or a quantized delta frame in Delta mode;
//! 2. the fleet's [`ModelReplica`] applies the frame through the real
//!    wire-decode path, decoding from **one shared buffer** (the
//!    broadcast payload is never cloned per client — metering counts
//!    receivers, the bytes exist once). A delta frame must reach EVERY
//!    client (a missed delta breaks the replica forever), so the whole
//!    fleet is metered; the raw model broadcast is stateless, so only the
//!    selected clients who train this round are metered — byte-identical
//!    to the CSG1-era accounting;
//! 3. selected clients train from the replica and upload their frames
//!    through [`Transport::exchange`], which applies the straggler policy
//!    and meters the survivors; the server ingests each delivered frame
//!    ([`Server::ingest`]) and closes the round.
//!
//! With a sim-clocked transport ([`crate::fl::transport::SimTransport`]),
//! the same exchange plays out on the virtual clock of a `FleetSim`:
//! over-selection, the availability/dropout lottery, per-device transfer
//! and compute times, and straggler aborts — aborted uploads are neither
//! ingested nor metered, one decision made in one place.
//!
//! ## Buffered-async rounds ([`RoundMode::BufferedAsync`])
//!
//! There are no synchronized rounds: up to `selection_count(K)` clients
//! train concurrently, each dispatched against the model version current
//! at its launch. The driver pops arrivals one at a time
//! ([`Transport::recv`]), feeds them to [`Server::ingest`] — which
//! discounts staleness and rejects expired updates — and applies the
//! model as soon as `buffer_k` updates are buffered
//! ([`Server::ready_to_apply`]), then refills the freed slot. `rounds`
//! counts aggregations, so sync and async runs compare at equal update
//! budgets. Slow uplinks stop gating the fleet — which is exactly where
//! low-bit quantization matters most (see `tests/async_rounds.rs`).
//!
//! The per-round client train+encode loop fans out over
//! [`std::thread::scope`] when [`FlConfig::client_threads`] ≠ 1
//! (synchronous mode; async dispatches train one at a time by
//! construction). This is *wall-clock* parallelism only: every client
//! owns its RNG lane, EF residual and encode scratch, the shared
//! `Engine`/model/task are read immutably, and updates are re-ordered
//! back into selection order before aggregation — so runs are
//! bit-identical to serial at any thread count (asserted by the
//! self-skipping e2e test in `tests/runtime_integration.rs`).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::compress::allocator::{BitController, BitPlan, LayerMap};
use crate::compress::deflate::DeflateStats;
use crate::compress::Pipeline;
use crate::data::partition::{self, eval_set};
use crate::data::synth::{SynthCifar, SynthMnist, SynthTask, SynthVolume};
use crate::obs::{self, Metrics, TimeSource, Tracer};
use crate::runtime::manifest::{init_params, RoundCfg};
use crate::runtime::Engine;
use crate::sim::{Admission, Timeline};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch; // analyze: allow(determinism): wall-secs reporting only, never steers the run

use super::client::{Client, ModelReplica};
use super::config::{FlConfig, Task};
use super::ingest::IngestPlane;
use super::metrics::{History, RoundRecord};
use super::network::NetworkLedger;
use super::server::{Ingest, RoundMode, Server};
use super::transport::dryrun::{flush_plane, note_finish, note_ingest, note_plan};
use super::transport::{Frame, Loopback, SimTransport, Transport};

/// The outcome of one federated run.
pub struct RunResult {
    pub history: History,
    pub network: NetworkLedger,
    pub final_params: Vec<f32>,
    pub wall_secs: f64,
    /// Per-round virtual-clock records ([`FlConfig::sim`] runs only).
    pub timeline: Option<Timeline>,
}

/// Static gauge names for per-worker DEFLATE output bytes — the same
/// allocation-free instrumentation pattern as the ingest shard tables
/// ([`crate::fl::ingest`]): `set_gauge` takes `&'static str`, so worker
/// indices map onto a fixed table and the overflow tail aggregates.
const DEFLATE_THREAD_BYTES: [&str; 16] = [
    "deflate_thread00_bytes",
    "deflate_thread01_bytes",
    "deflate_thread02_bytes",
    "deflate_thread03_bytes",
    "deflate_thread04_bytes",
    "deflate_thread05_bytes",
    "deflate_thread06_bytes",
    "deflate_thread07_bytes",
    "deflate_thread08_bytes",
    "deflate_thread09_bytes",
    "deflate_thread10_bytes",
    "deflate_thread11_bytes",
    "deflate_thread12_bytes",
    "deflate_thread13_bytes",
    "deflate_thread14_bytes",
    "deflate_thread15_bytes",
];
const DEFLATE_THREAD_REST: &str = "deflate_thread_rest_bytes";

/// Record one downlink DEFLATE run (chunk/byte/thread counts) into the
/// round telemetry. No-op when the broadcast skipped DEFLATE (legacy
/// float32 downlink, or a pipeline with the stage off).
fn note_deflate(tracer: &mut Tracer, metrics: &mut Metrics, stats: Option<&DeflateStats>) {
    let Some(s) = stats else { return };
    metrics.inc("deflate_chunks", s.chunks);
    metrics.inc("deflate_bytes_in", s.bytes_in);
    metrics.inc("deflate_bytes_out", s.bytes_out);
    metrics.set_gauge("deflate_threads", s.threads as f64);
    for (i, &b) in s.per_thread.iter().enumerate() {
        match DEFLATE_THREAD_BYTES.get(i) {
            Some(name) => metrics.set_gauge(name, b as f64),
            None => metrics.inc(DEFLATE_THREAD_REST, b),
        }
    }
    tracer.point(
        "deflate",
        vec![
            ("chunks", Json::from(s.chunks)),
            ("bytes_in", Json::from(s.bytes_in)),
            ("bytes_out", Json::from(s.bytes_out)),
            ("threads", Json::from(s.threads)),
        ],
    );
}

/// Evaluate `params` on the task's eval set.
fn eval_model(
    cfg: &FlConfig,
    engine: &Engine,
    eval_artifact: &str,
    eval_n: usize,
    eval_x: &[f32],
    eval_y: &[i32],
    params: &[f32],
) -> Result<(f64, f64)> {
    let (m, l) = match cfg.task {
        Task::Unet => {
            engine.segmentation_eval(eval_artifact, params, eval_x.to_vec(), eval_y.to_vec())?
        }
        _ => engine.classification_eval(
            eval_artifact,
            params,
            eval_x.to_vec(),
            eval_y.to_vec(),
            eval_n,
        )?,
    };
    Ok((m, l as f64))
}

/// Should round `done` (1-based) be evaluated?
fn eval_due(cfg: &FlConfig, done: usize) -> bool {
    cfg.rounds < 2
        || done == cfg.rounds
        || (cfg.eval_every > 0 && done % cfg.eval_every == 0)
}

/// Generic driver over a synthetic task.
fn run_task<T: SynthTask>(
    cfg: &FlConfig,
    engine: &Engine,
    task: &T,
    shards: Vec<partition::ClientShard>,
    label: &str,
) -> Result<RunResult> {
    let sw = Stopwatch::start(); // analyze: allow(determinism): wall-secs reporting only, never steers the run
    // Bake the DEFLATE level/thread knobs into both pipelines once; every
    // later width rebuild (`Pipeline::with_bits`) clones, so the settings
    // survive the adaptive schedule's per-layer reconfigurations.
    let cfg = &{
        let mut c = cfg.clone();
        c.uplink = c.tuned_uplink();
        c.downlink = c.tuned_downlink();
        c
    };
    let model = engine.manifest.model(cfg.task.model_key())?.clone();
    let round_cfg = engine.manifest.round(&cfg.round_cfg_key)?;
    let eval_artifact = cfg.task.eval_artifact();
    let eval_n = round_cfg.eval_n;
    let (eval_x, eval_y) = eval_set(task, eval_n);

    let mut clients: Vec<Client> = shards
        .into_iter()
        .map(|s| Client::new(s, cfg.seed))
        .collect();
    let init = init_params(&model, cfg.seed);
    // Aggregation weights (N_i) are registered up front — the frame
    // envelope carries only (round, client_id, payload).
    let weights: Vec<u32> = clients.iter().map(|c| c.shard.len() as u32).collect();
    let mut server = Server::new(init.clone(), cfg.eta_s)
        .with_downlink(cfg.downlink.clone(), cfg.seed)
        .with_round_mode(cfg.round_mode)
        .with_clients(weights);
    // All clients share the initialization (Algorithm 1's common M^0) and
    // receive every broadcast, so one replica stands in for the fleet —
    // every replica decodes the SAME shared frame buffer.
    let mut fleet_model = ModelReplica::new(init);
    let mut selector = Pcg64::new(cfg.seed, 0x5E1EC7);
    let mut history = History::new(label);
    let mut transport: Box<dyn Transport> = match cfg.sim.as_ref() {
        Some(s) => Box::new(SimTransport::new(s, cfg.n_clients, cfg.seed)),
        None => Box::new(Loopback::new()),
    };
    // Observability: the tracer only spends cycles when `--trace` is set.
    // Sim runs trace on the virtual clock — deterministic, so same-seed
    // runs produce byte-identical trace files (pinned by
    // `tests/obs_trace.rs`); wall runs fall back to the monotonic clock.
    let mut tracer = match (&cfg.trace, &cfg.sim) {
        (Some(_), Some(_)) => Tracer::new(TimeSource::manual(), obs::DEFAULT_RING_CAPACITY),
        (Some(_), None) => Tracer::new(TimeSource::wall(), obs::DEFAULT_RING_CAPACITY),
        (None, _) => Tracer::disabled(),
    };
    let mut metrics = Metrics::new();
    // Adaptive bit control: the layer map comes from the model manifest's
    // flat-parameter layout, so "per-layer" means real model layers.
    let mut controller = match cfg.bit_schedule {
        Some(schedule) => {
            // Schedules reconfigure the quantizer width per round; the
            // sign family and float32 passthrough have no width to move
            // (`Pipeline::with_bits` is a no-op for them), so a schedule
            // there would silently never run — refuse it instead.
            let q = cfg.uplink.quantizer().id();
            anyhow::ensure!(
                q == crate::compress::quantizer::ids::COSINE
                    || q == crate::compress::quantizer::ids::LINEAR,
                "--bits schedules need a variable-width quantizer (cosine or linear), \
                 not {}",
                cfg.uplink.name()
            );
            let extents: Vec<(usize, usize)> =
                model.layers.iter().map(|l| (l.offset, l.size)).collect();
            // Non-contiguous manifests degrade to one whole-tensor
            // segment: every schedule still works, `adaptive` just loses
            // its per-layer granularity.
            let map = LayerMap::from_extents(&extents)
                .ok()
                .filter(|m| m.param_count() == model.param_count)
                .unwrap_or_else(|| LayerMap::whole(model.param_count));
            Some(BitController::new(schedule, map))
        }
        None => None,
    };
    // Sharded ingest plane: accepted frames queue here and fold into the
    // server's accumulator across N workers sharded by layer extent —
    // bit-identical to serial ingest at any shard count (the worker
    // kernel IS the serial kernel, run over disjoint slices in arrival
    // order). Non-contiguous manifests degrade to one whole-tensor
    // extent; routing still splits it evenly by element.
    let ingest_extents: Vec<(usize, usize)> =
        model.layers.iter().map(|l| (l.offset, l.size)).collect();
    let ingest_map = LayerMap::from_extents(&ingest_extents)
        .ok()
        .filter(|m| m.param_count() == model.param_count)
        .unwrap_or_else(|| LayerMap::whole(model.param_count));
    let mut ingest_plane = IngestPlane::new(cfg.effective_ingest_shards(), &ingest_map);
    // Every client trains the same artifact schedule per round.
    let examples_per_round = (round_cfg.steps() * round_cfg.batch) as u64;
    let per_round = cfg.clients_per_round();

    match cfg.round_mode {
        RoundMode::Synchronous => run_sync_rounds(
            cfg,
            engine,
            task,
            &round_cfg,
            &eval_artifact,
            eval_n,
            &eval_x,
            &eval_y,
            &mut clients,
            &mut server,
            &mut fleet_model,
            &mut selector,
            transport.as_mut(),
            &mut history,
            &mut controller,
            &mut ingest_plane,
            examples_per_round,
            per_round,
            label,
            &mut tracer,
            &mut metrics,
        )?,
        RoundMode::BufferedAsync { .. } => run_async_windows(
            cfg,
            engine,
            task,
            &round_cfg,
            &eval_artifact,
            eval_n,
            &eval_x,
            &eval_y,
            &mut clients,
            &mut server,
            &mut fleet_model,
            &mut selector,
            transport.as_mut(),
            &mut history,
            &mut controller,
            &mut ingest_plane,
            examples_per_round,
            per_round,
            label,
            &mut tracer,
            &mut metrics,
        )?,
    }

    let (network, timeline) = transport.finish();
    if let Some(path) = cfg.trace.as_ref() {
        // Replay the timeline's critical-path records as round/phase
        // spans (one code path with `repro sim` / `repro trace`) and
        // snapshot the ledger, then flush the ring to JSONL.
        note_finish(&mut tracer, &mut metrics, &network, timeline.as_ref(), history.records.len());
        if !tracer.is_deterministic() {
            metrics.set_gauge("wall_secs", sw.elapsed_secs());
        }
        std::fs::write(path, obs::render_trace(&tracer, &metrics))
            .with_context(|| format!("writing trace {path:?}"))?;
    }
    Ok(RunResult {
        history,
        network,
        final_params: server.params,
        wall_secs: sw.elapsed_secs(),
        timeline,
    })
}

/// Classic FedAvg rounds over the transport + state machine. Bit-identical
/// to the pre-transport runner: same RNG streams, same selection, same
/// aggregation order (the transport's `exchange` contract), same ledger
/// totals.
#[allow(clippy::too_many_arguments)]
fn run_sync_rounds<T: SynthTask>(
    cfg: &FlConfig,
    engine: &Engine,
    task: &T,
    round_cfg: &RoundCfg,
    eval_artifact: &str,
    eval_n: usize,
    eval_x: &[f32],
    eval_y: &[i32],
    clients: &mut [Client],
    server: &mut Server,
    fleet_model: &mut ModelReplica,
    selector: &mut Pcg64,
    transport: &mut dyn Transport,
    history: &mut History,
    controller: &mut Option<BitController>,
    plane: &mut IngestPlane,
    examples_per_round: u64,
    per_round: usize,
    label: &str,
    tracer: &mut Tracer,
    metrics: &mut Metrics,
) -> Result<()> {
    for t in 0..cfg.rounds {
        let lr = cfg.client_lr.at(t) as f32;
        if let Some(at) = transport.clock_ticks() {
            tracer.set_now(at);
        }
        // The bit controller picks this round's widths; a uniform plan
        // collapses to the legacy single-frame path (bit-identical for
        // `const:<b>` — same pipeline config, same RNG draws).
        let bit_plan = controller.as_mut().map(|c| c.plan(t, cfg.rounds));
        note_plan(tracer, controller.as_ref(), bit_plan.as_ref(), t);
        let (eff_uplink, seg_plan) = effective_uplink(&cfg.uplink, bit_plan.as_ref());
        let broadcast = server.broadcast()?;
        let delta_mode = broadcast.wire.is_some();
        if let Some(frame) = &broadcast.wire {
            // Round-trip mode: every replica decodes the one shared frame.
            fleet_model.apply_wire(frame)?;
        }

        // Selection (the transport's policy may over-select), then the
        // availability/dropout lottery — offline devices and mid-round
        // failures never produce an update, so they are not worth
        // training.
        let k_select = transport.selection_count(per_round);
        let selected = selector.sample_indices(clients.len(), k_select);
        let plan = transport.plan_round(&selected);

        // Downlink metering: a delta frame must reach EVERY client to keep
        // the replicas in sync, so the whole fleet is metered; the raw
        // float32 model is stateless, so only the clients that train this
        // round are metered — byte-identical to the CSG1-era accounting.
        let receivers = if delta_mode {
            clients.len()
        } else {
            plan.active.len()
        };
        transport.broadcast(broadcast.bytes, receivers);
        tracer.point(
            "downlink",
            vec![("bytes", Json::from(broadcast.bytes)), ("receivers", Json::from(receivers))],
        );
        note_deflate(tracer, metrics, broadcast.deflate.as_ref());

        // Train + encode every active client; serially or fanned out over
        // scoped threads (bit-identical either way — see module docs).
        let round = server.round();
        let global_model: &[f32] = if delta_mode {
            &fleet_model.params
        } else {
            &server.params
        };
        let locals = fan_out(
            clients,
            &plan.active,
            cfg.effective_threads(),
            |client| {
                let update = client.run_round(
                    engine,
                    task,
                    &cfg.round_artifact,
                    round_cfg,
                    global_model,
                    lr,
                    &eff_uplink,
                    seg_plan,
                    cfg.use_kernel_quantizer,
                )?;
                Ok((update.payload(), update.train_loss, update.residual_norm))
            },
        )?;
        let mut residual_sum = 0.0f64;
        let trained = locals.len();
        let mut loss_of: BTreeMap<usize, f32> = BTreeMap::new();
        let frames: Vec<Frame> = plan
            .active
            .iter()
            .zip(locals)
            .map(|(&ci, (payload, train_loss, residual))| {
                loss_of.insert(ci, train_loss);
                residual_sum += residual;
                Frame {
                    round,
                    client_id: ci,
                    payload,
                }
            })
            .collect();

        // The transport decides which trained uploads land before the
        // round closes; aborted straggler uploads are neither delivered
        // nor metered. Survivors come back in selection order.
        let delivered =
            transport.exchange(t + 1, per_round, broadcast.bytes, frames, examples_per_round);

        let mut loss_sum = 0.0f64;
        let n_kept = delivered.len();
        if let Some(at) = transport.clock_ticks() {
            tracer.set_now(at);
        }
        for frame in &delivered {
            // Validate/commit on the coordinator; defer the fold to the
            // sharded plane (flushed below, before the round closes).
            let (verdict, prepared) = server.ingest_prepare(frame);
            note_ingest(tracer, metrics, frame, &verdict);
            match verdict {
                Ingest::Accepted { .. } => {
                    loss_sum += loss_of[&frame.client_id] as f64;
                    if let Some(p) = prepared {
                        if plane.full() {
                            flush_plane(plane, server, tracer, metrics)?;
                        }
                        plane.submit(p);
                        metrics.set_gauge("ingest_queue_depth", plane.pending() as f64);
                    }
                }
                verdict => bail!(
                    "round {}: server refused a delivered frame from client {} ({verdict:?})",
                    t + 1,
                    frame.client_id
                ),
            }
        }
        flush_plane(plane, server, tracer, metrics)?;
        let train_loss = loss_sum / n_kept.max(1) as f64;
        // Close the feedback loop BEFORE the round closes (observations
        // reset with it): the accepted segments' wire headers, the mean
        // client EF-residual norm, and the round's mean train loss.
        if let Some(c) = controller.as_mut() {
            let obs = server.round_observations();
            tracer.point(
                "observe",
                vec![("round", Json::from(t)), ("segments", Json::from(obs.len()))],
            );
            c.observe(
                &obs,
                residual_sum / trained.max(1) as f64,
                Some(train_loss),
            );
        }
        let (dup, stale, malformed) = server.round_verdicts();
        server.finish_round();

        let (metric, eval_loss) = if eval_due(cfg, t + 1) {
            let (m, l) = eval_model(
                cfg,
                engine,
                eval_artifact,
                eval_n,
                eval_x,
                eval_y,
                &server.params,
            )?;
            (Some(m), Some(l))
        } else {
            (None, None)
        };
        if let Some(m) = metric {
            tracer.point("eval", vec![("round", Json::from(t + 1)), ("metric", Json::from(m))]);
        }

        let ledger = transport.ledger();
        let rec = RoundRecord {
            round: t + 1,
            train_loss,
            eval_metric: metric,
            eval_loss,
            uplink_bytes: ledger.uplink_bytes,
            downlink_bytes: ledger.downlink_bytes,
            clients: n_kept,
            stale_updates: stale,
            dup_updates: dup,
            malformed_updates: malformed,
            bits: bit_plan.map(|p| p.bits).unwrap_or_default(),
            deflate_level: cfg.uplink.deflate.then(|| cfg.deflate_level.name()),
        };
        if cfg.verbose {
            let m = metric.map_or("-".to_string(), |m| format!("{m:.4}"));
            let sim_note = transport
                .clock_secs()
                .map_or(String::new(), |s| format!(" sim {s:.1}s"));
            println!(
                "[{label}] round {:>4}/{} loss {:.4} metric {m} uplink {} downlink {}{sim_note}",
                t + 1,
                cfg.rounds,
                rec.train_loss,
                crate::util::timer::fmt_bytes(rec.uplink_bytes),
                crate::util::timer::fmt_bytes(rec.downlink_bytes)
            );
        }
        history.push(rec);
    }
    Ok(())
}

/// Resolve one round's effective uplink from the bit controller's plan:
/// a uniform plan bakes its width into the pipeline (the legacy
/// single-frame path, byte-identical for `const:<b>`); a segmented plan
/// keeps the base pipeline and hands the per-layer widths to the client.
fn effective_uplink<'a>(
    base: &Pipeline,
    plan: Option<&'a BitPlan>,
) -> (Pipeline, Option<&'a BitPlan>) {
    match plan {
        None => (base.clone(), None),
        Some(p) if !p.segmented => (base.with_bits(p.bits[0]), None),
        Some(p) => (base.clone(), Some(p)),
    }
}

/// FedBuff-style buffered-async windows: dispatch / arrival event loop.
#[allow(clippy::too_many_arguments)]
fn run_async_windows<T: SynthTask>(
    cfg: &FlConfig,
    engine: &Engine,
    task: &T,
    round_cfg: &RoundCfg,
    eval_artifact: &str,
    eval_n: usize,
    eval_x: &[f32],
    eval_y: &[i32],
    clients: &mut [Client],
    server: &mut Server,
    fleet_model: &mut ModelReplica,
    selector: &mut Pcg64,
    transport: &mut dyn Transport,
    history: &mut History,
    controller: &mut Option<BitController>,
    plane: &mut IngestPlane,
    examples_per_round: u64,
    per_round: usize,
    label: &str,
    tracer: &mut Tracer,
    metrics: &mut Metrics,
) -> Result<()> {
    let RoundMode::BufferedAsync { buffer_k, .. } = cfg.round_mode else {
        unreachable!("run_async_windows requires BufferedAsync");
    };
    // Each client contributes at most once per window, so a buffer larger
    // than the fleet could never fill.
    anyhow::ensure!(
        buffer_k <= clients.len(),
        "async buffer {} exceeds the fleet ({} clients)",
        buffer_k,
        clients.len()
    );
    // Concurrent trainers: what the sync policy would select, but never
    // fewer than the buffer — the window must be fillable.
    let concurrency = transport
        .selection_count(per_round)
        .max(buffer_k)
        .min(clients.len());
    let mut busy = vec![false; clients.len()];
    let mut loss_of = vec![0.0f32; clients.len()];
    let mut residual_of = vec![0.0f64; clients.len()];
    // The widths of the open window; refreshed at every window close, so
    // a plan change lands mid-stream — in-flight frames keep the widths
    // they were encoded with (self-describing headers).
    let mut bit_plan = controller.as_mut().map(|c| c.plan(0, cfg.rounds));
    note_plan(tracer, controller.as_ref(), bit_plan.as_ref(), 0);

    // Initial broadcast (model version 0).
    let mut broadcast = server.broadcast()?;
    let mut delta_mode = broadcast.wire.is_some();
    if let Some(frame) = &broadcast.wire {
        fleet_model.apply_wire(frame)?;
        transport.broadcast(broadcast.bytes, clients.len());
        tracer.point(
            "downlink",
            vec![("bytes", Json::from(broadcast.bytes)), ("receivers", Json::from(clients.len()))],
        );
        note_deflate(tracer, metrics, broadcast.deflate.as_ref());
    }

    // Fill the pipeline.
    for _ in 0..concurrency {
        let global_model: &[f32] = if delta_mode {
            &fleet_model.params
        } else {
            &server.params
        };
        let (eff_uplink, seg) = effective_uplink(&cfg.uplink, bit_plan.as_ref());
        dispatch_one(
            cfg,
            engine,
            task,
            round_cfg,
            clients,
            &mut busy,
            &mut loss_of,
            &mut residual_of,
            selector,
            transport,
            server.round(),
            global_model,
            &eff_uplink,
            seg,
            broadcast.bytes,
            delta_mode,
            examples_per_round,
            tracer,
            metrics,
        )?;
    }

    let mut window_loss = 0.0f64;
    let mut window_residual = 0.0f64;
    let mut window_accepted = 0usize;
    let mut window_dropped = 0usize;
    let mut applied = 0usize;
    while applied < cfg.rounds {
        let Some(frame) = transport.recv() else {
            // Nothing in flight (a pathological all-offline streak drained
            // the pipeline): try once to refill, else the run is starved.
            let global_model: &[f32] = if delta_mode {
                &fleet_model.params
            } else {
                &server.params
            };
            let (eff_uplink, seg) = effective_uplink(&cfg.uplink, bit_plan.as_ref());
            if !dispatch_one(
                cfg,
                engine,
                task,
                round_cfg,
                clients,
                &mut busy,
                &mut loss_of,
                &mut residual_of,
                selector,
                transport,
                server.round(),
                global_model,
                &eff_uplink,
                seg,
                broadcast.bytes,
                delta_mode,
                examples_per_round,
                tracer,
                metrics,
            )? {
                bail!("buffered-async run starved: nothing in flight and no dispatchable client");
            }
            continue;
        };
        busy[frame.client_id] = false;
        // In-flight gauge moves at BOTH edges: here (drain) and at
        // dispatch (enqueue, inside `dispatch_one`) — sampling only at
        // window close under-reported the depth between aggregations.
        metrics.set_gauge("queue_depth", busy.iter().filter(|&&b| b).count() as f64);
        if let Some(at) = transport.clock_ticks() {
            tracer.set_now(at);
        }
        let (verdict, prepared) = server.ingest_prepare(&frame);
        note_ingest(tracer, metrics, &frame, &verdict);
        match verdict {
            Ingest::Accepted { .. } => {
                window_accepted += 1;
                window_loss += loss_of[frame.client_id] as f64;
                window_residual += residual_of[frame.client_id];
                if let Some(p) = prepared {
                    if plane.full() {
                        flush_plane(plane, server, tracer, metrics)?;
                    }
                    plane.submit(p);
                    metrics.set_gauge("ingest_queue_depth", plane.pending() as f64);
                }
            }
            // Delivered (and metered — it crossed the wire) but discarded:
            // expired staleness, or a surplus second contribution from a
            // fast client inside one window.
            Ingest::StaleRound | Ingest::Duplicate => window_dropped += 1,
            Ingest::Malformed => bail!(
                "async ingest refused a delivered frame from client {} as malformed",
                frame.client_id
            ),
        }

        if server.ready_to_apply() {
            // Fold everything still queued before the window closes —
            // `finish_round` consumes the accumulator.
            flush_plane(plane, server, tracer, metrics)?;
            let window_train_loss = window_loss / window_accepted.max(1) as f64;
            // Feed the controller before the round closes (observations
            // reset with it).
            if let Some(c) = controller.as_mut() {
                let obs = server.round_observations();
                tracer.point(
                    "observe",
                    vec![("round", Json::from(applied)), ("segments", Json::from(obs.len()))],
                );
                c.observe(
                    &obs,
                    window_residual / window_accepted.max(1) as f64,
                    Some(window_train_loss),
                );
            }
            let (dup, stale, malformed) = server.round_verdicts();
            let n_kept = server.finish_round();
            applied += 1;
            transport.close_window(applied, n_kept, window_dropped);
            metrics.set_gauge("queue_depth", busy.iter().filter(|&&b| b).count() as f64);

            // New model version: broadcast (delta replicas must see every
            // frame; the raw float32 model is metered per dispatch).
            broadcast = server.broadcast()?;
            delta_mode = broadcast.wire.is_some();
            if let Some(fw) = &broadcast.wire {
                fleet_model.apply_wire(fw)?;
                transport.broadcast(broadcast.bytes, clients.len());
                tracer.point(
                    "downlink",
                    vec![
                        ("bytes", Json::from(broadcast.bytes)),
                        ("receivers", Json::from(clients.len())),
                    ],
                );
                note_deflate(tracer, metrics, broadcast.deflate.as_ref());
            }

            let (metric, eval_loss) = if eval_due(cfg, applied) {
                let (m, l) = eval_model(
                    cfg,
                    engine,
                    eval_artifact,
                    eval_n,
                    eval_x,
                    eval_y,
                    &server.params,
                )?;
                (Some(m), Some(l))
            } else {
                (None, None)
            };
            if let Some(m) = metric {
                tracer.point(
                    "eval",
                    vec![("round", Json::from(applied)), ("metric", Json::from(m))],
                );
            }
            let ledger = transport.ledger();
            let rec = RoundRecord {
                round: applied,
                train_loss: window_train_loss,
                eval_metric: metric,
                eval_loss,
                uplink_bytes: ledger.uplink_bytes,
                downlink_bytes: ledger.downlink_bytes,
                clients: n_kept,
                stale_updates: stale,
                dup_updates: dup,
                malformed_updates: malformed,
                bits: bit_plan.as_ref().map(|p| p.bits.clone()).unwrap_or_default(),
                deflate_level: cfg.uplink.deflate.then(|| cfg.deflate_level.name()),
            };
            if cfg.verbose {
                let m = metric.map_or("-".to_string(), |m| format!("{m:.4}"));
                let sim_note = transport
                    .clock_secs()
                    .map_or(String::new(), |s| format!(" sim {s:.1}s"));
                println!(
                    "[{label}] window {:>4}/{} loss {:.4} metric {m} uplink {} stale {}{sim_note}",
                    applied,
                    cfg.rounds,
                    rec.train_loss,
                    crate::util::timer::fmt_bytes(rec.uplink_bytes),
                    window_dropped
                );
            }
            history.push(rec);
            window_loss = 0.0;
            window_residual = 0.0;
            window_accepted = 0;
            window_dropped = 0;
            // Next window's widths, from the freshly observed signals.
            bit_plan = controller.as_mut().map(|c| c.plan(applied, cfg.rounds));
            note_plan(tracer, controller.as_ref(), bit_plan.as_ref(), applied);
        }

        if applied < cfg.rounds {
            // Refill the freed slot against the current model version.
            let global_model: &[f32] = if delta_mode {
                &fleet_model.params
            } else {
                &server.params
            };
            let (eff_uplink, seg) = effective_uplink(&cfg.uplink, bit_plan.as_ref());
            dispatch_one(
                cfg,
                engine,
                task,
                round_cfg,
                clients,
                &mut busy,
                &mut loss_of,
                &mut residual_of,
                selector,
                transport,
                server.round(),
                global_model,
                &eff_uplink,
                seg,
                broadcast.bytes,
                delta_mode,
                examples_per_round,
                tracer,
                metrics,
            )?;
        }
    }
    Ok(())
}

/// Admit, train and launch ONE client at the current virtual instant
/// (buffered-async mode). Returns false when no idle client can be
/// dispatched (everyone busy, or a pathological offline/dropout streak).
///
/// The artifact-free protocol driver
/// ([`crate::fl::transport::dryrun::run_async`]) mirrors this logic minus
/// the training — change the two in lockstep.
#[allow(clippy::too_many_arguments)]
fn dispatch_one<T: SynthTask>(
    cfg: &FlConfig,
    engine: &Engine,
    task: &T,
    round_cfg: &RoundCfg,
    clients: &mut [Client],
    busy: &mut [bool],
    loss_of: &mut [f32],
    residual_of: &mut [f64],
    selector: &mut Pcg64,
    transport: &mut dyn Transport,
    server_round: usize,
    global_model: &[f32],
    uplink: &Pipeline,
    seg_plan: Option<&BitPlan>,
    broadcast_bytes: usize,
    delta_mode: bool,
    examples: u64,
    tracer: &mut Tracer,
    metrics: &mut Metrics,
) -> Result<bool> {
    let mut attempts = 0usize;
    loop {
        // A device cannot fly two uploads at once: sample among the idle.
        let idle: Vec<usize> = (0..clients.len()).filter(|&c| !busy[c]).collect();
        if idle.is_empty() {
            return Ok(false);
        }
        let candidate = idle[selector.below_usize(idle.len())];
        attempts += 1;
        match transport.admit(candidate) {
            Admission::Admitted => {
                let lr = cfg.client_lr.at(server_round) as f32;
                let update = clients[candidate].run_round(
                    engine,
                    task,
                    &cfg.round_artifact,
                    round_cfg,
                    global_model,
                    lr,
                    uplink,
                    seg_plan,
                    cfg.use_kernel_quantizer,
                )?;
                let payload = update.payload();
                loss_of[candidate] = update.train_loss;
                residual_of[candidate] = update.residual_norm;
                if !delta_mode {
                    // Raw float32 model: one model transfer per dispatch.
                    transport.broadcast(broadcast_bytes, 1);
                }
                if let Some(at) = transport.clock_ticks() {
                    tracer.set_now(at);
                }
                tracer.point(
                    "dispatch",
                    vec![("client", Json::from(candidate)), ("round", Json::from(server_round))],
                );
                metrics.inc("dispatches", 1);
                transport.dispatch(
                    Frame {
                        round: server_round,
                        client_id: candidate,
                        payload,
                    },
                    broadcast_bytes,
                    examples,
                );
                busy[candidate] = true;
                metrics.set_gauge("queue_depth", busy.iter().filter(|&&b| b).count() as f64);
                return Ok(true);
            }
            Admission::Offline | Admission::Dropout => {
                if attempts > clients.len() * 4 {
                    return Ok(false); // pathological lottery streak
                }
            }
        }
    }
}

/// Run `f` over the clients selected by `active`, returning results in
/// `active` order. `threads <= 1` runs serially in place; otherwise the
/// clients fan out round-robin over [`std::thread::scope`] workers.
///
/// Determinism: each worker touches only its own disjoint `&mut Client`s
/// (every client owns its RNG lane / EF residual / scratch), shared state
/// is read-only, and results carry their selection position, so the
/// returned vector — and any error, which is the first failure in
/// `active` order — is independent of scheduling and thread count.
fn fan_out<R: Send>(
    clients: &mut [Client],
    active: &[usize],
    threads: usize,
    f: impl Fn(&mut Client) -> Result<R> + Sync,
) -> Result<Vec<R>> {
    if threads <= 1 || active.len() <= 1 {
        let mut out = Vec::with_capacity(active.len());
        for &ci in active {
            out.push(f(&mut clients[ci])?);
        }
        return Ok(out);
    }

    // Disjoint &mut extraction: one sweep over the fleet, tagging each
    // selected client with its position in `active` (indices are distinct
    // by construction of `sample_indices`).
    let mut pos_of: Vec<usize> = vec![usize::MAX; clients.len()];
    for (p, &ci) in active.iter().enumerate() {
        debug_assert_eq!(pos_of[ci], usize::MAX, "duplicate selection {ci}");
        pos_of[ci] = p;
    }
    let refs: Vec<(usize, &mut Client)> = clients
        .iter_mut()
        .enumerate()
        .filter_map(|(ci, c)| {
            let p = pos_of[ci];
            (p != usize::MAX).then_some((p, c))
        })
        .collect();

    let threads = threads.min(refs.len());
    let mut buckets: Vec<Vec<(usize, &mut Client)>> = (0..threads).map(|_| Vec::new()).collect();
    for (i, r) in refs.into_iter().enumerate() {
        buckets[i % threads].push(r);
    }

    let f = &f;
    let per_thread: Vec<Vec<(usize, Result<R>)>> = std::thread::scope(|s| {
        let handles: Vec<_> = buckets
            .into_iter()
            .map(|bucket| {
                s.spawn(move || {
                    bucket
                        .into_iter()
                        .map(|(p, client)| (p, f(client)))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client worker panicked"))
            .collect()
    });

    let mut results: Vec<Option<Result<R>>> =
        std::iter::repeat_with(|| None).take(active.len()).collect();
    for (p, r) in per_thread.into_iter().flatten() {
        results[p] = Some(r);
    }
    let mut out = Vec::with_capacity(active.len());
    for r in results {
        out.push(r.expect("missing client result")?);
    }
    Ok(out)
}

/// Run a federated experiment to completion.
pub fn run(cfg: &FlConfig, engine: &Engine) -> Result<RunResult> {
    run_labeled(cfg, engine, &cfg.uplink.name())
}

/// Run with an explicit series label (figure harnesses).
pub fn run_labeled(cfg: &FlConfig, engine: &Engine, label: &str) -> Result<RunResult> {
    let round_cfg = engine.manifest.round(&cfg.round_cfg_key)?;
    match cfg.task {
        Task::MnistIid => {
            let task = SynthMnist::new(cfg.seed);
            let shards = partition::iid_partition(
                cfg.seed,
                cfg.n_clients,
                round_cfg.n_data,
                task.classes(),
            );
            run_task(cfg, engine, &task, shards, label)
        }
        Task::MnistNonIid => {
            let task = SynthMnist::new(cfg.seed);
            let shards = partition::non_iid_partition(
                cfg.seed,
                cfg.n_clients,
                round_cfg.n_data,
                task.classes(),
            );
            run_task(cfg, engine, &task, shards, label)
        }
        Task::Cifar => {
            let task = SynthCifar::new(cfg.seed);
            let shards = partition::iid_partition(
                cfg.seed,
                cfg.n_clients,
                round_cfg.n_data,
                task.classes(),
            );
            run_task(cfg, engine, &task, shards, label)
        }
        Task::Unet => {
            let task = SynthVolume::new(cfg.seed);
            let shards = partition::iid_partition(
                cfg.seed,
                cfg.n_clients,
                round_cfg.n_data,
                task.classes(),
            );
            run_task(cfg, engine, &task, shards, label)
        }
    }
}
