//! The experiment loop: wires clients, server, pipelines, network and
//! engine into the full FedAvg round structure of Algorithm 1 and produces
//! a [`History`].
//!
//! Round structure (round-trip aware):
//! 1. the server produces the round's broadcast ([`Server::broadcast`]) —
//!    raw float32 model, or a quantized delta frame in Delta mode;
//! 2. the fleet's [`ModelReplica`] applies the frame through the real
//!    wire-decode path. Downlink metering follows what each mode truly
//!    costs: a delta frame must reach EVERY client (a missed delta breaks
//!    the replica forever), so the whole fleet is metered; the raw model
//!    broadcast is stateless, so only the selected clients who train this
//!    round are metered — byte-identical to the CSG1-era accounting;
//! 3. selected clients train from the replica and upload compressed
//!    updates; the server decodes the self-describing frames and
//!    aggregates (Eq. 1).
//!
//! With [`FlConfig::sim`] set, the same round additionally plays out on
//! the virtual clock of a [`FleetSim`]: the policy may over-select,
//! the availability/dropout lottery thins the participants *before*
//! training, and the real serialized frame sizes (broadcast and per-client
//! upload) are divided by each device's bandwidth to time the round.
//! Updates from stragglers the round policy aborts are neither aggregated
//! nor metered — their uploads never completed.
//!
//! The per-round client train+encode loop fans out over
//! [`std::thread::scope`] when [`FlConfig::client_threads`] ≠ 1. This is
//! *wall-clock* parallelism only: every client owns its RNG lane, EF
//! residual and encode scratch, the shared `Engine`/model/task are read
//! immutably, and updates are re-ordered back into selection order before
//! aggregation — so runs are bit-identical to serial at any thread count
//! (asserted by the self-skipping e2e test in
//! `tests/runtime_integration.rs`).

use anyhow::Result;

use crate::compress::wire;
use crate::data::partition::{self, eval_set};
use crate::data::synth::{SynthCifar, SynthMnist, SynthTask, SynthVolume};
use crate::runtime::manifest::init_params;
use crate::runtime::Engine;
use crate::sim::{secs, ClientLoad, FleetSim, RoundPlan, Timeline};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

use super::client::{Client, ModelReplica};
use super::config::{FlConfig, Task};
use super::metrics::{History, RoundRecord};
use super::network::NetworkLedger;
use super::server::Server;

/// The outcome of one federated run.
pub struct RunResult {
    pub history: History,
    pub network: NetworkLedger,
    pub final_params: Vec<f32>,
    pub wall_secs: f64,
    /// Per-round virtual-clock records ([`FlConfig::sim`] runs only).
    pub timeline: Option<Timeline>,
}

/// Generic driver over a synthetic task.
fn run_task<T: SynthTask>(
    cfg: &FlConfig,
    engine: &Engine,
    task: &T,
    shards: Vec<partition::ClientShard>,
    label: &str,
) -> Result<RunResult> {
    let sw = Stopwatch::start();
    let model = engine.manifest.model(cfg.task.model_key())?.clone();
    let round_cfg = engine.manifest.round(&cfg.round_cfg_key)?;
    let eval_artifact = cfg.task.eval_artifact();
    let eval_n = round_cfg.eval_n;
    let (eval_x, eval_y) = eval_set(task, eval_n);

    let mut clients: Vec<Client> = shards
        .into_iter()
        .map(|s| Client::new(s, cfg.seed))
        .collect();
    let init = init_params(&model, cfg.seed);
    let mut server = Server::new(init.clone(), cfg.eta_s)
        .with_downlink(cfg.downlink.clone(), cfg.seed);
    // All clients share the initialization (Algorithm 1's common M^0) and
    // receive every broadcast, so one replica stands in for the fleet.
    let mut fleet_model = ModelReplica::new(init);
    let mut network = NetworkLedger::new();
    let mut selector = Pcg64::new(cfg.seed, 0x5E1EC7);
    let mut history = History::new(label);
    let mut sim: Option<FleetSim> = cfg
        .sim
        .as_ref()
        .map(|s| FleetSim::new(s, cfg.n_clients, cfg.seed));
    // Every client trains the same artifact schedule per round.
    let examples_per_round = (round_cfg.steps() * round_cfg.batch) as u64;

    let per_round = cfg.clients_per_round();
    for t in 0..cfg.rounds {
        let lr = cfg.client_lr.at(t) as f32;
        let broadcast = server.broadcast()?;
        let delta_mode = broadcast.wire.is_some();
        if let Some(frame) = &broadcast.wire {
            // Round-trip mode: clients decode the delta frame themselves.
            fleet_model.apply_wire(frame)?;
        }

        // Selection (policy may over-select), then the availability /
        // dropout lottery — offline devices and mid-round failures never
        // produce an update, so they are not worth training.
        let k_select = sim
            .as_ref()
            .map_or(per_round, |s| s.selection_count(per_round));
        let selected = selector.sample_indices(clients.len(), k_select);
        let plan = match sim.as_mut() {
            Some(s) => s.begin_round(&selected),
            None => RoundPlan::full(selected),
        };

        // Downlink metering: a delta frame must reach EVERY client to keep
        // the replicas in sync, so the whole fleet is metered; the raw
        // float32 model is stateless, so only the clients that train this
        // round are metered — byte-identical to the CSG1-era accounting.
        let receivers = if delta_mode {
            clients.len()
        } else {
            plan.active.len()
        };
        network.record_downlink_n(broadcast.bytes, receivers);

        // Train + encode every active client; serially or fanned out over
        // scoped threads (bit-identical either way — see module docs).
        let global_model: &[f32] = if delta_mode {
            &fleet_model.params
        } else {
            &server.params
        };
        let locals = fan_out(
            &mut clients,
            &plan.active,
            cfg.effective_threads(),
            |client| {
                let update = client.run_round(
                    engine,
                    task,
                    &cfg.round_artifact,
                    &round_cfg,
                    global_model,
                    lr,
                    &cfg.uplink,
                    cfg.use_kernel_quantizer,
                )?;
                let bytes = wire::serialize(&update.encoded);
                Ok((bytes, update.num_examples, update.train_loss))
            },
        )?;
        let updates: Vec<(usize, Vec<u8>, u32, f32)> = plan
            .active
            .iter()
            .zip(locals)
            .map(|(&ci, (bytes, num_examples, train_loss))| (ci, bytes, num_examples, train_loss))
            .collect();

        // With the simulator on, the round policy decides which trained
        // updates actually land before the round closes; aborted straggler
        // uploads are neither aggregated nor metered.
        let kept: Vec<usize> = match sim.as_mut() {
            Some(s) => {
                let loads: Vec<ClientLoad> = updates
                    .iter()
                    .map(|(ci, bytes, _, _)| ClientLoad {
                        device: *ci,
                        upload_bytes: bytes.len(),
                        examples: examples_per_round,
                    })
                    .collect();
                s.complete_round(t + 1, &plan, per_round, broadcast.bytes, &loads)
                    .kept
            }
            None => plan.active.clone(),
        };
        let mut kept_sorted = kept;
        kept_sorted.sort_unstable();

        let mut loss_sum = 0.0f64;
        let mut n_kept = 0usize;
        for (ci, bytes, num_examples, train_loss) in &updates {
            if kept_sorted.binary_search(ci).is_err() {
                continue;
            }
            network.record_uplink(bytes.len());
            server.receive_update(bytes, *num_examples)?;
            loss_sum += *train_loss as f64;
            n_kept += 1;
        }
        server.finish_round();

        let evaluate = cfg.rounds < 2
            || t + 1 == cfg.rounds
            || (cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0);
        let (metric, eval_loss) = if evaluate {
            let (m, l) = match cfg.task {
                Task::Unet => engine.segmentation_eval(
                    &eval_artifact,
                    &server.params,
                    eval_x.clone(),
                    eval_y.clone(),
                )?,
                _ => engine.classification_eval(
                    &eval_artifact,
                    &server.params,
                    eval_x.clone(),
                    eval_y.clone(),
                    eval_n,
                )?,
            };
            (Some(m), Some(l as f64))
        } else {
            (None, None)
        };

        let rec = RoundRecord {
            round: t + 1,
            train_loss: loss_sum / n_kept.max(1) as f64,
            eval_metric: metric,
            eval_loss,
            uplink_bytes: network.uplink_bytes,
            downlink_bytes: network.downlink_bytes,
            clients: n_kept,
        };
        if cfg.verbose {
            let m = metric.map_or("-".to_string(), |m| format!("{m:.4}"));
            let sim_note = sim
                .as_ref()
                .map_or(String::new(), |s| format!(" sim {:.1}s", secs(s.clock())));
            println!(
                "[{label}] round {:>4}/{} loss {:.4} metric {m} uplink {} downlink {}{sim_note}",
                t + 1,
                cfg.rounds,
                rec.train_loss,
                crate::util::timer::fmt_bytes(network.uplink_bytes),
                crate::util::timer::fmt_bytes(network.downlink_bytes)
            );
        }
        history.push(rec);
    }

    Ok(RunResult {
        history,
        network,
        final_params: server.params,
        wall_secs: sw.elapsed_secs(),
        timeline: sim.map(FleetSim::into_timeline),
    })
}

/// Run `f` over the clients selected by `active`, returning results in
/// `active` order. `threads <= 1` runs serially in place; otherwise the
/// clients fan out round-robin over [`std::thread::scope`] workers.
///
/// Determinism: each worker touches only its own disjoint `&mut Client`s
/// (every client owns its RNG lane / EF residual / scratch), shared state
/// is read-only, and results carry their selection position, so the
/// returned vector — and any error, which is the first failure in
/// `active` order — is independent of scheduling and thread count.
fn fan_out<R: Send>(
    clients: &mut [Client],
    active: &[usize],
    threads: usize,
    f: impl Fn(&mut Client) -> Result<R> + Sync,
) -> Result<Vec<R>> {
    if threads <= 1 || active.len() <= 1 {
        let mut out = Vec::with_capacity(active.len());
        for &ci in active {
            out.push(f(&mut clients[ci])?);
        }
        return Ok(out);
    }

    // Disjoint &mut extraction: one sweep over the fleet, tagging each
    // selected client with its position in `active` (indices are distinct
    // by construction of `sample_indices`).
    let mut pos_of: Vec<usize> = vec![usize::MAX; clients.len()];
    for (p, &ci) in active.iter().enumerate() {
        debug_assert_eq!(pos_of[ci], usize::MAX, "duplicate selection {ci}");
        pos_of[ci] = p;
    }
    let refs: Vec<(usize, &mut Client)> = clients
        .iter_mut()
        .enumerate()
        .filter_map(|(ci, c)| {
            let p = pos_of[ci];
            (p != usize::MAX).then_some((p, c))
        })
        .collect();

    let threads = threads.min(refs.len());
    let mut buckets: Vec<Vec<(usize, &mut Client)>> = (0..threads).map(|_| Vec::new()).collect();
    for (i, r) in refs.into_iter().enumerate() {
        buckets[i % threads].push(r);
    }

    let f = &f;
    let per_thread: Vec<Vec<(usize, Result<R>)>> = std::thread::scope(|s| {
        let handles: Vec<_> = buckets
            .into_iter()
            .map(|bucket| {
                s.spawn(move || {
                    bucket
                        .into_iter()
                        .map(|(p, client)| (p, f(client)))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client worker panicked"))
            .collect()
    });

    let mut results: Vec<Option<Result<R>>> =
        std::iter::repeat_with(|| None).take(active.len()).collect();
    for (p, r) in per_thread.into_iter().flatten() {
        results[p] = Some(r);
    }
    let mut out = Vec::with_capacity(active.len());
    for r in results {
        out.push(r.expect("missing client result")?);
    }
    Ok(out)
}

/// Run a federated experiment to completion.
pub fn run(cfg: &FlConfig, engine: &Engine) -> Result<RunResult> {
    run_labeled(cfg, engine, &cfg.uplink.name())
}

/// Run with an explicit series label (figure harnesses).
pub fn run_labeled(cfg: &FlConfig, engine: &Engine, label: &str) -> Result<RunResult> {
    let round_cfg = engine.manifest.round(&cfg.round_cfg_key)?;
    match cfg.task {
        Task::MnistIid => {
            let task = SynthMnist::new(cfg.seed);
            let shards = partition::iid_partition(
                cfg.seed,
                cfg.n_clients,
                round_cfg.n_data,
                task.classes(),
            );
            run_task(cfg, engine, &task, shards, label)
        }
        Task::MnistNonIid => {
            let task = SynthMnist::new(cfg.seed);
            let shards = partition::non_iid_partition(
                cfg.seed,
                cfg.n_clients,
                round_cfg.n_data,
                task.classes(),
            );
            run_task(cfg, engine, &task, shards, label)
        }
        Task::Cifar => {
            let task = SynthCifar::new(cfg.seed);
            let shards = partition::iid_partition(
                cfg.seed,
                cfg.n_clients,
                round_cfg.n_data,
                task.classes(),
            );
            run_task(cfg, engine, &task, shards, label)
        }
        Task::Unet => {
            let task = SynthVolume::new(cfg.seed);
            let shards = partition::iid_partition(
                cfg.seed,
                cfg.n_clients,
                round_cfg.n_data,
                task.classes(),
            );
            run_task(cfg, engine, &task, shards, label)
        }
    }
}
