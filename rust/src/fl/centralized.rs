//! Centralized-SGD toy harness for Figure 4: how important are the top vs
//! rear gradients?
//!
//! Per step, the per-batch gradient (from the `mnist_grad` artifact) is
//! perturbed — zero or Gaussian-noise the top-k% or rear-k% coordinates by
//! |g| — before the SGD update. The paper's observation: corrupting the
//! top gradients breaks training; corrupting the rear barely matters.

use anyhow::Result;

use crate::data::partition::eval_set;
use crate::data::synth::{SynthMnist, SynthTask};
use crate::runtime::manifest::init_params;
use crate::runtime::Engine;
use crate::util::rng::Pcg64;
use crate::util::stats::kth_largest_abs;

/// What to do to the selected coordinates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Perturbation {
    None,
    /// Set the selected coordinates to zero.
    Zero,
    /// Add Gaussian noise with the given std (paper: 0.1).
    Noise(f32),
}

/// Which coordinates to select.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Target {
    /// Top `frac` by |g|.
    Top(f64),
    /// Rear (smallest) `frac` by |g|.
    Rear(f64),
}

/// Apply a perturbation in place.
pub fn perturb(g: &mut [f32], target: Target, p: Perturbation, rng: &mut Pcg64) {
    if p == Perturbation::None {
        return;
    }
    let n = g.len();
    let frac = match target {
        Target::Top(f) | Target::Rear(f) => f,
    };
    let k = ((frac * n as f64).ceil() as usize).clamp(1, n);
    match target {
        Target::Top(_) => {
            // top k%: |g| at or above the k-th largest magnitude.
            let thresh = kth_largest_abs(g, k);
            for v in g.iter_mut() {
                if v.abs() >= thresh {
                    apply(v, p, rng);
                }
            }
        }
        Target::Rear(_) => {
            // rear k%: the k smallest |g| — threshold is the k-th smallest,
            // i.e. the (n+1-k)-th largest.
            let thresh = kth_largest_abs(g, n + 1 - k);
            for v in g.iter_mut() {
                if v.abs() <= thresh {
                    apply(v, p, rng);
                }
            }
        }
    }
}

fn apply(v: &mut f32, p: Perturbation, rng: &mut Pcg64) {
    match p {
        Perturbation::None => {}
        Perturbation::Zero => *v = 0.0,
        Perturbation::Noise(std) => *v += rng.normal_f32(0.0, std),
    }
}

/// One training curve of the toy study.
pub struct ToyCurve {
    pub label: String,
    /// (epoch, eval accuracy).
    pub points: Vec<(usize, f64)>,
}

/// Run centralized SGD on the MNIST-like task with gradient perturbation.
pub fn run_centralized(
    engine: &Engine,
    epochs: usize,
    n_train: usize,
    lr: f32,
    target: Target,
    perturbation: Perturbation,
    seed: u64,
    label: &str,
) -> Result<ToyCurve> {
    let task = SynthMnist::new(seed);
    let model = engine.manifest.model("mnist")?.clone();
    let batch = engine.manifest.grad_batch;
    let mut rng = Pcg64::new(seed, 0xF164);

    // Training pool: balanced classes.
    let mut train_x = Vec::with_capacity(n_train * 784);
    let mut train_y = Vec::with_capacity(n_train);
    for i in 0..n_train {
        let (x, y) = task.gen(i % 10, (i / 10) as u64);
        train_x.extend_from_slice(&x);
        train_y.push(y[0]);
    }
    let eval_n = engine.manifest.round("mnist")?.eval_n;
    let (eval_x, eval_y) = eval_set(&task, eval_n);

    let mut params = init_params(&model, seed);
    let mut points = Vec::new();
    let steps_per_epoch = n_train / batch;
    for epoch in 0..epochs {
        for _ in 0..steps_per_epoch {
            // Sample a batch.
            let mut bx = Vec::with_capacity(batch * 784);
            let mut by = Vec::with_capacity(batch);
            for _ in 0..batch {
                let i = rng.below_usize(n_train);
                bx.extend_from_slice(&train_x[i * 784..(i + 1) * 784]);
                by.push(train_y[i]);
            }
            let (mut grad, _loss) = engine.grad_step(&params, bx, by)?;
            perturb(&mut grad, target, perturbation, &mut rng);
            for (p, g) in params.iter_mut().zip(&grad) {
                *p -= lr * g;
            }
        }
        let (acc, _) = engine.classification_eval(
            "mnist_eval",
            &params,
            eval_x.clone(),
            eval_y.clone(),
            eval_n,
        )?;
        points.push((epoch + 1, acc));
    }
    Ok(ToyCurve {
        label: label.to_string(),
        points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perturb_zero_top_hits_largest() {
        let mut rng = Pcg64::seeded(1);
        let mut g = vec![0.1f32, -5.0, 0.2, 3.0, -0.05];
        perturb(&mut g, Target::Top(0.4), Perturbation::Zero, &mut rng);
        // top 40% of 5 = 2 coordinates: -5 and 3.
        assert_eq!(g[1], 0.0);
        assert_eq!(g[3], 0.0);
        assert_eq!(g[0], 0.1);
        assert_eq!(g[2], 0.2);
    }

    #[test]
    fn perturb_zero_rear_hits_smallest() {
        let mut rng = Pcg64::seeded(2);
        let mut g = vec![0.1f32, -5.0, 0.2, 3.0, -0.05];
        perturb(&mut g, Target::Rear(0.4), Perturbation::Zero, &mut rng);
        // rear 40% = 2 smallest: 0.1 and -0.05.
        assert_eq!(g[0], 0.0);
        assert_eq!(g[4], 0.0);
        assert_eq!(g[1], -5.0);
        assert_eq!(g[3], 3.0);
    }

    #[test]
    fn perturb_noise_changes_selected_only() {
        let mut rng = Pcg64::seeded(3);
        let orig = vec![0.01f32, -2.0, 0.02, 1.5, -0.03];
        let mut g = orig.clone();
        perturb(&mut g, Target::Top(0.4), Perturbation::Noise(0.1), &mut rng);
        assert_ne!(g[1], orig[1]);
        assert_ne!(g[3], orig[3]);
        assert_eq!(g[0], orig[0]);
        assert_eq!(g[2], orig[2]);
        assert_eq!(g[4], orig[4]);
    }

    #[test]
    fn perturb_none_is_identity() {
        let mut rng = Pcg64::seeded(4);
        let orig = vec![1.0f32, 2.0, 3.0];
        let mut g = orig.clone();
        perturb(&mut g, Target::Top(0.5), Perturbation::None, &mut rng);
        assert_eq!(g, orig);
    }
}
