//! Experiment metrics: per-round records plus JSON export under
//! `artifacts/results/` (one file per figure/run; the figure harnesses and
//! EXPERIMENTS.md consume these).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One evaluated round.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    /// Mean local training loss across selected clients this round.
    pub train_loss: f64,
    /// Accuracy (classification) or mean dice (segmentation), if evaluated.
    pub eval_metric: Option<f64>,
    pub eval_loss: Option<f64>,
    /// Cumulative uplink bytes after this round.
    pub uplink_bytes: u64,
    /// Cumulative downlink (broadcast) bytes after this round.
    pub downlink_bytes: u64,
    pub clients: usize,
    /// Delivered updates the server discarded as stale in this round
    /// (buffered-async aggregation windows; always 0 in synchronous mode).
    pub stale_updates: usize,
    /// Frames the server refused as duplicates of an already-counted
    /// client this round.
    pub dup_updates: usize,
    /// Frames the server refused as malformed (undecodable payload or
    /// wrong parameter count) this round.
    pub malformed_updates: usize,
    /// Quantizer widths the bit controller chose for this round — one
    /// entry per layer segment (a single entry for uniform schedules;
    /// empty on the legacy fixed-width path).
    pub bits: Vec<u8>,
    /// DEFLATE effort the pipelines ran at (`fast`/`default`/`best`;
    /// `None` when the uplink skips DEFLATE, e.g. the float32 baseline).
    pub deflate_level: Option<&'static str>,
}

/// A labelled series of round records.
#[derive(Debug, Clone, Default)]
pub struct History {
    pub label: String,
    pub records: Vec<RoundRecord>,
}

impl History {
    pub fn new(label: impl Into<String>) -> Self {
        History {
            label: label.into(),
            records: Vec::new(),
        }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    /// Best (max) eval metric seen.
    pub fn best_metric(&self) -> Option<f64> {
        self.records
            .iter()
            .filter_map(|r| r.eval_metric)
            .fold(None, |acc, m| Some(acc.map_or(m, |a: f64| a.max(m))))
    }

    /// Final eval metric.
    pub fn final_metric(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.eval_metric)
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("label", self.label.as_str())
            .set(
                "records",
                Json::Arr(
                    self.records
                        .iter()
                        .map(|r| {
                            let mut j = Json::obj()
                                .set("round", r.round)
                                .set("train_loss", r.train_loss)
                                .set("uplink_bytes", r.uplink_bytes)
                                .set("downlink_bytes", r.downlink_bytes)
                                .set("clients", r.clients)
                                .set("stale_updates", r.stale_updates)
                                .set("dup_updates", r.dup_updates)
                                .set("malformed_updates", r.malformed_updates);
                            if let Some(level) = r.deflate_level {
                                j = j.set("deflate_level", level);
                            }
                            if !r.bits.is_empty() {
                                let widths: Vec<usize> =
                                    r.bits.iter().map(|&b| b as usize).collect();
                                j = j.set("bits", Json::from_usize_slice(&widths));
                            }
                            if let Some(m) = r.eval_metric {
                                j = j.set("eval_metric", m);
                            }
                            if let Some(l) = r.eval_loss {
                                j = j.set("eval_loss", l);
                            }
                            j
                        })
                        .collect(),
                ),
            )
    }
}

/// Write a set of histories (one experiment) to a results JSON file.
pub fn save_results(path: impl AsRef<Path>, name: &str, series: &[History]) -> Result<()> {
    let json = Json::obj().set("experiment", name).set(
        "series",
        Json::Arr(series.iter().map(History::to_json).collect()),
    );
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, json.pretty()).with_context(|| format!("writing {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, metric: Option<f64>) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0 / (round + 1) as f64,
            eval_metric: metric,
            eval_loss: metric.map(|m| 1.0 - m),
            uplink_bytes: round as u64 * 100,
            downlink_bytes: round as u64 * 400,
            clients: 10,
            stale_updates: 0,
            dup_updates: 0,
            malformed_updates: 0,
            bits: vec![4],
            deflate_level: Some("default"),
        }
    }

    #[test]
    fn best_and_final() {
        let mut h = History::new("test");
        h.push(rec(0, Some(0.5)));
        h.push(rec(1, None));
        h.push(rec(2, Some(0.8)));
        h.push(rec(3, Some(0.7)));
        assert_eq!(h.best_metric(), Some(0.8));
        assert_eq!(h.final_metric(), Some(0.7));
        assert_eq!(History::new("e").best_metric(), None);
    }

    #[test]
    fn json_roundtrip_structure() {
        let mut h = History::new("cosine-2");
        h.push(rec(0, Some(0.25)));
        let j = h.to_json();
        assert_eq!(j.get("label").unwrap().as_str(), Some("cosine-2"));
        let recs = j.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].get("round").unwrap().as_usize(), Some(0));
        assert_eq!(recs[0].get("eval_metric").unwrap().as_f64(), Some(0.25));
        assert_eq!(recs[0].get("downlink_bytes").unwrap().as_u64(), Some(0));
        let bits = recs[0].get("bits").unwrap().as_arr().unwrap();
        assert_eq!(bits.len(), 1);
        assert_eq!(bits[0].as_usize(), Some(4));
        assert_eq!(
            recs[0].get("deflate_level").unwrap().as_str(),
            Some("default")
        );
    }

    #[test]
    fn save_results_writes_parseable_json() {
        let dir = std::env::temp_dir().join("cossgd_test_results");
        let path = dir.join("unit.json");
        let mut h = History::new("s");
        h.push(rec(1, Some(0.5)));
        save_results(&path, "unit", &[h]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let json = Json::parse(&text).unwrap();
        assert_eq!(json.get("experiment").unwrap().as_str(), Some("unit"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
