//! A federated worker (Algorithm 1 "Worker"): runs E local epochs through
//! the AOT round artifact, forms `g = M_in − M*`, and compresses it with
//! the experiment's uplink [`Pipeline`]. Per-client state (EF residual,
//! RNG lane, cached local data) lives here for the life of the run.
//!
//! [`ModelReplica`] is the client side of the round-trip scheme: the
//! decoded model copy a client maintains by applying each round's
//! dequantized downlink delta.

use anyhow::Result;

use crate::compress::allocator::BitPlan;
use crate::compress::pipeline::{
    Direction, EncodeScratch, EncodedTensor, Pipeline, PipelineState,
};
use crate::compress::quantizer::Quantizer;
use crate::compress::wire;
use crate::data::partition::ClientShard;
use crate::data::synth::SynthTask;
use crate::runtime::manifest::RoundCfg;
use crate::runtime::Engine;
use crate::util::rng::Pcg64;

/// The client-side decoded model replica (Delta downlink mode).
///
/// Starts from the shared initialization (Algorithm 1's common `M^0`) and
/// advances by the dequantized delta of every broadcast frame, decoding
/// from a borrowed `&[u8]` — the runner hands every replica the SAME
/// broadcast buffer, so the frame is never cloned per client (metering
/// counts receivers; the bytes exist once). In the simulator one replica
/// stands in for the whole fleet — every client receives every broadcast,
/// so all replicas are bit-identical.
#[derive(Debug, Clone)]
pub struct ModelReplica {
    pub params: Vec<f32>,
}

impl ModelReplica {
    pub fn new(init: Vec<f32>) -> ModelReplica {
        ModelReplica { params: init }
    }

    /// Apply one downlink frame: deserialize, decode, add the delta.
    pub fn apply_wire(&mut self, frame: &[u8]) -> Result<()> {
        let enc = wire::deserialize(frame)?;
        anyhow::ensure!(
            enc.direction == Direction::Downlink,
            "model replica received a non-downlink frame"
        );
        let delta = crate::compress::pipeline::decode(&enc)?;
        anyhow::ensure!(
            delta.len() == self.params.len(),
            "delta length {} != model {}",
            delta.len(),
            self.params.len()
        );
        for (p, d) in self.params.iter_mut().zip(&delta) {
            *p += d;
        }
        Ok(())
    }
}

/// One client.
pub struct Client {
    pub shard: ClientShard,
    pub state: PipelineState,
    rng: Pcg64,
    /// Materialized local data, generated lazily on first selection.
    cache: Option<(Vec<f32>, Vec<i32>)>,
    /// Reusable encode buffers — steady-state rounds allocate nothing in
    /// the compression stages. Client-private, so the runner's parallel
    /// fan-out needs no synchronization around it.
    scratch: EncodeScratch,
    /// Per-layer pipeline memory for segmented (adaptive bit-schedule)
    /// uplinks: each layer segment is its own encode call, so each keeps
    /// its own EF residual. Empty until the first segmented round.
    seg_states: Vec<PipelineState>,
}

/// The result of one local round: the update as one or more CSG2
/// segments (a single whole-tensor frame on the legacy and uniform
/// bit-schedule paths; one segment per layer — mixed widths allowed —
/// under an adaptive schedule), plus the signals the bit controller
/// reads.
pub struct LocalUpdate {
    /// The encoded segments, in layer order; `wire::serialize_stream`
    /// turns them into the frame payload.
    pub segments: Vec<EncodedTensor>,
    pub num_examples: u32,
    pub train_loss: f32,
    /// ‖EF residual‖₂ after this encode (0 when error feedback is off) —
    /// one of the adaptive controller's pressure signals.
    pub residual_norm: f64,
}

impl LocalUpdate {
    /// The serialized frame payload (all segments, concatenated).
    pub fn payload(&self) -> Vec<u8> {
        wire::serialize_stream(&self.segments)
    }
}

impl Client {
    pub fn new(shard: ClientShard, run_seed: u64) -> Client {
        let rng = Pcg64::new(run_seed, 0xC11E0000 | shard.client_id as u64);
        Client {
            shard,
            state: PipelineState::new(),
            rng,
            cache: None,
            scratch: EncodeScratch::new(),
            seg_states: Vec::new(),
        }
    }

    /// Epoch permutations: `steps × batch` indices into the local dataset,
    /// reshuffled per epoch (this is the only stochasticity inside a local
    /// round; it lives in Rust so artifacts stay deterministic).
    fn perms(&mut self, cfg: &RoundCfg) -> Vec<i32> {
        let nb = cfg.n_data / cfg.batch;
        let mut out = Vec::with_capacity(cfg.epochs * nb * cfg.batch);
        for _ in 0..cfg.epochs {
            let perm = self.rng.permutation(cfg.n_data);
            out.extend(perm[..nb * cfg.batch].iter().map(|&i| i as i32));
        }
        out
    }

    /// Run one local round and compress the update. `plan` is the bit
    /// controller's segmented layer plan for this round (`None` on the
    /// legacy path and for uniform-width schedules, whose width is
    /// already baked into `uplink` via [`Pipeline::with_bits`]).
    #[allow(clippy::too_many_arguments)]
    pub fn run_round<T: SynthTask>(
        &mut self,
        engine: &Engine,
        task: &T,
        artifact: &str,
        cfg: &RoundCfg,
        global_params: &[f32],
        lr: f32,
        uplink: &Pipeline,
        plan: Option<&BitPlan>,
        use_kernel_quantizer: bool,
    ) -> Result<LocalUpdate> {
        if self.cache.is_none() {
            self.cache = Some(self.shard.materialize(task));
        }
        let (x, y) = self.cache.as_ref().unwrap().clone();
        let perms = self.perms(cfg);
        let (delta, train_loss) =
            engine.local_round(artifact, global_params, x, y, perms, lr)?;

        let segments = match plan {
            Some(p) if p.segmented => {
                anyhow::ensure!(
                    !use_kernel_quantizer,
                    "the Pallas kernel path supports only uniform bit widths"
                );
                self.encode_segmented(&delta, uplink, p)?
            }
            _ => {
                let enc = if use_kernel_quantizer {
                    self.encode_via_kernel(engine, &delta, uplink)?
                } else {
                    uplink.encode_with(
                        &delta,
                        Direction::Uplink,
                        &mut self.state,
                        &mut self.rng,
                        &mut self.scratch,
                    )
                };
                vec![enc]
            }
        };
        Ok(LocalUpdate {
            segments,
            num_examples: self.shard.len() as u32,
            train_loss,
            residual_norm: self.residual_norm(uplink),
        })
    }

    /// Encode one update as per-layer CSG2 segments at the plan's widths.
    /// Every segment is an independent pipeline pass over its slice of
    /// the delta (its own EF residual lane, its own mask/rotation seeds
    /// from this client's RNG), so mixed widths compose with every stage.
    fn encode_segmented(
        &mut self,
        delta: &[f32],
        uplink: &Pipeline,
        plan: &BitPlan,
    ) -> Result<Vec<EncodedTensor>> {
        anyhow::ensure!(
            plan.bounds.last() == Some(&delta.len()) && plan.bounds.len() == plan.bits.len() + 1,
            "bit plan does not cover the update ({:?} segments over {} params)",
            plan.bits.len(),
            delta.len()
        );
        if self.seg_states.len() != plan.bits.len() {
            self.seg_states = vec![PipelineState::new(); plan.bits.len()];
        }
        let mut segs = Vec::with_capacity(plan.bits.len());
        for (l, &bits) in plan.bits.iter().enumerate() {
            let pipe = uplink.with_bits(bits);
            segs.push(pipe.encode_with(
                &delta[plan.bounds[l]..plan.bounds[l + 1]],
                Direction::Uplink,
                &mut self.seg_states[l],
                &mut self.rng,
                &mut self.scratch,
            ));
        }
        Ok(segs)
    }

    /// ‖EF residual‖₂ across all pipeline state lanes (0 when EF is off).
    fn residual_norm(&self, uplink: &Pipeline) -> f64 {
        if !uplink.error_feedback {
            return 0.0;
        }
        let sq: f64 = self
            .seg_states
            .iter()
            .chain(std::iter::once(&self.state))
            .flat_map(|s| s.residual.iter())
            .map(|&r| (r as f64) * (r as f64))
            .sum();
        sq.sqrt()
    }

    /// Quantize through the Pallas kernel artifacts (L1 on the hot path):
    /// norm/bound from the Rust reducers, angle transform + rounding in the
    /// lowered kernel, then bit-pack + DEFLATE exactly as the native path.
    fn encode_via_kernel(
        &mut self,
        engine: &Engine,
        delta: &[f32],
        uplink: &Pipeline,
    ) -> Result<EncodedTensor> {
        use crate::compress::cosine::{BoundMode, CosineQuantizer, Rounding};
        use crate::compress::{bitpack, deflate};
        let cq = match uplink
            .quantizer()
            .as_any()
            .downcast_ref::<CosineQuantizer>()
        {
            Some(q) => q,
            None => anyhow::bail!("kernel quantizer only supports the cosine scheme"),
        };
        anyhow::ensure!(
            uplink.keep_frac >= 1.0 && !uplink.rotate && !uplink.error_feedback,
            "kernel quantizer path supports only the dense unrotated pipeline"
        );
        let (bits, rounding, bound_mode) = (cq.bits, cq.rounding, cq.bound);
        let norm = crate::util::stats::l2_norm(delta) as f32;
        if norm <= 0.0 {
            return Ok(uplink.encode(delta, Direction::Uplink, &mut self.state, &mut self.rng));
        }
        // Bound from the same definitions as the native quantizer
        // (CosineQuantizer::compute_bound, §3).
        let bound = match bound_mode {
            BoundMode::FixedAngle(b) => b,
            BoundMode::Auto => {
                let mut tmin = std::f32::consts::PI;
                let mut tmax = 0.0f32;
                for &g in delta {
                    let t = (g / norm).clamp(-1.0, 1.0).acos();
                    tmin = tmin.min(t);
                    tmax = tmax.max(t);
                }
                tmin.min(std::f32::consts::PI - tmax)
                    .clamp(0.0, std::f32::consts::PI / 2.0)
            }
            BoundMode::ClipTopPercent(p) => {
                let k = ((p / 100.0) * delta.len() as f64).ceil().max(1.0) as usize;
                let clip = crate::util::stats::kth_largest_abs(delta, k.min(delta.len()));
                (clip.min(norm) / norm).clamp(-1.0, 1.0).acos()
            }
        };
        let u: Vec<f32> = match rounding {
            Rounding::Biased => vec![0.5; delta.len()],
            Rounding::Unbiased => (0..delta.len()).map(|_| self.rng.f32()).collect(),
        };
        let codes = engine.kernel_quantize(bits, delta, norm, bound, &u)?;
        let packed = bitpack::pack(&codes, bits);
        let (payload, deflated) = if uplink.deflate {
            let c = deflate::deflate(&packed, uplink.level);
            if c.len() < packed.len() {
                (c, true)
            } else {
                (packed, false)
            }
        } else {
            (packed, false)
        };
        Ok(EncodedTensor {
            direction: Direction::Uplink,
            kind_id: uplink.quantizer().id(),
            bits,
            n: delta.len() as u32,
            kept: delta.len() as u32,
            mask_seed: 0,
            rot_seed: 0,
            rotated: false,
            norm,
            bound,
            deflated,
            payload,
        })
    }

    /// Drop the materialized data (memory control for large federations).
    pub fn evict_cache(&mut self) {
        self.cache = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::iid_partition;

    #[test]
    fn perms_cover_dataset_each_epoch() {
        let shard = iid_partition(1, 1, 20, 10).remove(0);
        let mut c = Client::new(shard, 7);
        let cfg = RoundCfg {
            n_data: 20,
            batch: 5,
            epochs: 3,
            eval_n: 0,
        };
        let p = c.perms(&cfg);
        assert_eq!(p.len(), 3 * 20);
        for e in 0..3 {
            let mut epoch: Vec<i32> = p[e * 20..(e + 1) * 20].to_vec();
            epoch.sort_unstable();
            assert_eq!(epoch, (0..20).collect::<Vec<i32>>());
        }
        // Different epochs use different orders (overwhelmingly likely).
        assert_ne!(p[0..20], p[20..40]);
    }

    #[test]
    fn clients_have_independent_rng_lanes() {
        let shards = iid_partition(1, 2, 10, 10);
        let mut a = Client::new(shards[0].clone(), 7);
        let mut b = Client::new(shards[1].clone(), 7);
        let cfg = RoundCfg {
            n_data: 10,
            batch: 5,
            epochs: 1,
            eval_n: 0,
        };
        assert_ne!(a.perms(&cfg), b.perms(&cfg));
        // Same client id + seed → same stream.
        let mut a2 = Client::new(shards[0].clone(), 7);
        assert_eq!(Client::new(shards[0].clone(), 7).perms(&cfg), a2.perms(&cfg));
    }

    #[test]
    fn replica_rejects_uplink_frames() {
        let pipe = Pipeline::cosine(4);
        let mut rng = Pcg64::seeded(5);
        let g = crate::util::propcheck::gradient_like(&mut rng, 32);
        let enc = pipe.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
        let mut replica = ModelReplica::new(vec![0.0; 32]);
        assert!(replica.apply_wire(&wire::serialize(&enc)).is_err());
    }
}
