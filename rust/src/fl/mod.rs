//! The federated-learning coordinator (Layer 3) — frame-driven.
//!
//! Implements FedAvg (McMahan et al. [25]) exactly as the paper's
//! Algorithm 1, in both directions and in two aggregation modes. Every
//! client ↔ server exchange is a serialized CSG2 frame in an opaque
//! [`transport::Frame`] envelope, carried by a [`transport::Transport`]:
//! the bytes the ledger meters ARE the protocol, and the
//! delivery/abort/straggler policy lives in the carrier — one decision,
//! one place.
//!
//! ```text
//!                        ┌──────────────────────────────┐
//!   runner (event loop)  │ server (state machine)       │
//!   ───────────────────  │  ingest_prepare(Frame) →     │
//!   select → train →     │    Accepted / Duplicate /    │
//!   frames ──┐           │    StaleRound / Malformed    │
//!            ▼           │    + PreparedFrame           │
//!   ┌─────────────────┐  │  finish_round() → M^{t+1}    │
//!   │ Transport       │  └───────┬──────────▲───────────┘
//!   │  Loopback       │          │ accepted │ flush_into
//!   │  SimTransport ──┼──► ┌─────▼──────────┴───────────┐
//!   │  (FleetSim:     │    │ ingest (sharded plane)     │
//!   │   virtual clock,│    │  N workers, disjoint acc   │
//!   │   lottery,      │    │  slices, fused sub-range   │
//!   │   stragglers)   │    │  dequantize+accumulate —   │
//!   └─────────────────┘    │  bit-identical ∀ shards    │
//!   byte metering          └────────────────────────────┘
//!   (NetworkLedger) and the straggler policy live in the
//!   carrier — metered bytes are the ground truth
//! ```
//!
//! Per round the server broadcasts the model (raw float32, or a quantized
//! delta through a downlink [`crate::compress::Pipeline`] — the paper's
//! round-trip scheme; ONE shared frame buffer, decoded by every replica,
//! never cloned per client), selected clients run `E` local epochs
//! (through the AOT round artifacts — [`crate::runtime::Engine`]) and
//! upload compressed `g = M_in − M*` frames; the server ingests each
//! delivered frame — fusing dequantize+accumulate in a single pass over
//! the packed codes — and applies Eq. (1).
//!
//! Aggregation modes ([`server::RoundMode`]):
//! * **Synchronous** — classic FedAvg rounds; through the transport path
//!   this is bit-identical to the pre-transport runner.
//! * **BufferedAsync** — FedBuff-style: clients train continuously
//!   against whatever model version is current, the server applies as
//!   soon as `buffer_k` updates are buffered, and stale updates are
//!   staleness-discounted or dropped. Slow uplinks stop gating the fleet
//!   — the regime where low-bit quantization buys the most
//!   time-to-accuracy.
//!
//! Two properties of this layer are machine-enforced by the in-tree
//! analyzer ([`crate::analyze`], `repro analyze`, CI-gated):
//! *determinism* — [`server`], [`runner`] and [`transport`] may not use
//! `HashMap`/`HashSet` (iteration order), wall clocks, or ambient RNG, so
//! a seeded run replays byte-identically — and *panic-safety* —
//! [`server::Server::ingest`] sits on the untrusted-input boundary, so
//! `server.rs` bans `unwrap`/`expect`/`panic!` and bare indexing outside
//! `#[cfg(test)]`; malformed frames must come back [`server::Ingest`]
//! verdicts, never unwind (fuzzed in `tests/analyze.rs`).
//!
//! Bytes become *time* one layer up: with [`FlConfig::sim`] set, the
//! transport is sim-clocked ([`transport::SimTransport`] over
//! [`crate::sim::FleetSim`]) — per-device bandwidth/compute tiers,
//! availability, dropout, straggler aborts — and the run yields a
//! [`crate::sim::Timeline`] (simulated seconds per phase,
//! time-to-target-metric) alongside the [`History`].

pub mod centralized;
pub mod client;
pub mod config;
pub mod ingest;
pub mod metrics;
pub mod network;
pub mod runner;
pub mod schedule;
pub mod server;
pub mod transport;

pub use client::ModelReplica;
pub use config::{FlConfig, Task};
pub use ingest::{FlushStats, IngestPlane, PreparedFrame, PreparedSegment};
pub use metrics::{History, RoundRecord};
pub use network::NetworkLedger;
pub use runner::{run, run_labeled, RunResult};
pub use schedule::LrSchedule;
pub use server::{Broadcast, Downlink, Ingest, RoundMode, Server};
pub use transport::{Frame, Loopback, SimTransport, Transport};
