//! The federated-learning coordinator (Layer 3).
//!
//! Implements FedAvg (McMahan et al. [25]) exactly as the paper's
//! Algorithm 1, in both directions: per round the server broadcasts the
//! model (raw float32, or a quantized delta through a downlink
//! [`crate::compress::Pipeline`] — the paper's round-trip scheme), a
//! random `C` fraction of clients runs `E` local epochs (through the AOT
//! round artifacts — [`crate::runtime::Engine`]) and compresses
//! `g = M_in − M*` with the uplink pipeline, and the server decodes the
//! self-describing frames and aggregates with Eq. (1). Every byte that
//! moves is metered by [`network::NetworkLedger`].
//!
//! Bytes become *time* one layer up: with [`FlConfig::sim`] set, each
//! round also plays out on the virtual clock of [`crate::sim`] —
//! broadcast transfer → local training → upload transfer per device, with
//! heterogeneous bandwidth/compute tiers, availability, dropout and
//! straggler policies — and the run yields a [`crate::sim::Timeline`]
//! (simulated seconds per phase, time-to-target-metric) alongside the
//! [`History`]:
//!
//! ```text
//!   runner ──▶ NetworkLedger   bytes   (what moved)
//!          └─▶ sim::FleetSim   ticks   (how long it took, per device)
//! ```

pub mod centralized;
pub mod client;
pub mod config;
pub mod metrics;
pub mod network;
pub mod runner;
pub mod schedule;
pub mod server;

pub use client::ModelReplica;
pub use config::{FlConfig, Task};
pub use metrics::{History, RoundRecord};
pub use network::NetworkLedger;
pub use runner::{run, run_labeled, RunResult};
pub use schedule::LrSchedule;
pub use server::{Broadcast, Downlink, Server};
