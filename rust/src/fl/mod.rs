//! The federated-learning coordinator (Layer 3).
//!
//! Implements FedAvg (McMahan et al. [25]) exactly as the paper's
//! Algorithm 1: per round, a random `C` fraction of clients runs `E` local
//! epochs (through the AOT round artifacts — [`crate::runtime::Engine`]),
//! compresses `g = M_in − M*` with a [`crate::compress::Codec`], and the
//! server decompresses and aggregates with Eq. (1). Every byte that moves
//! is metered by [`network::NetworkLedger`].

pub mod centralized;
pub mod client;
pub mod config;
pub mod metrics;
pub mod network;
pub mod runner;
pub mod schedule;
pub mod server;

pub use config::{FlConfig, Task};
pub use metrics::{History, RoundRecord};
pub use network::NetworkLedger;
pub use runner::{run, RunResult};
pub use schedule::LrSchedule;
