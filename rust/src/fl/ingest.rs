//! Sharded parallel ingest plane: N-way fused dequantize+accumulate with
//! a deterministic tree-reduce merge.
//!
//! [`crate::fl::server::Server::ingest`] is a single-threaded state
//! machine, so at the fleet sizes the simulator models the server CPU —
//! not the network — becomes the bottleneck quantization cannot fix. This
//! module parallelizes the *fold* (the fused dequantize+accumulate over
//! packed codes) while keeping every piece of verdict bookkeeping on the
//! coordinator, so `Ingest` verdicts, `round_verdicts()` and
//! `round_observations()` are byte-for-byte what the serial server
//! produced.
//!
//! ```text
//!   coordinator (Server::ingest_prepare)          workers (flush)
//!   ───────────────────────────────────          ─────────────────────
//!   envelope checks ─ dup/stale/malformed   ┌──► shard 0  acc[b0..b1]
//!   payload parse + inflate + validation    │    shard 1  acc[b1..b2]
//!   verdict tallies, round observations     │      …
//!        │                                  │    shard S  acc[bS..n]
//!        ▼                                  │      │ fused sub-range
//!   PreparedFrame ──► bounded pending ──────┘      │ accumulate_range_with
//!   (weight + segs)   queue (SPMC: every           ▼
//!                     worker reads the run,   ShardStats ──┐ pairwise
//!                     folds only its slice)   ShardStats ──┤ tree-reduce
//!                                             ShardStats ──┘ → FlushStats
//! ```
//!
//! ## Routing
//!
//! Shard bounds come from the model's [`LayerMap`]: layer extents are
//! contiguous, so each worker owns one contiguous accumulator slice and
//! segmented mixed-width frames route each segment to (usually) a single
//! owner with zero locking. Single-layer / legacy whole-tensor frames
//! fall back to an even element split — ownership is purely positional,
//! so the cut points never affect results, only load balance.
//!
//! ## Determinism contract
//!
//! Bit-identical to the serial server at **any** shard count and **any**
//! flush granularity:
//!
//! * workers own *disjoint, contiguous* accumulator slices — no element
//!   is ever written by two shards, so the "merge" of the folded values
//!   is plain concatenation, deterministic by construction;
//! * every worker walks the *whole* pending run in arrival order, so each
//!   accumulator element receives its `+= v·w` contributions in exactly
//!   the order the serial loop applied them — f64 addition order is
//!   preserved, not just the operand set;
//! * the per-element values are position-pure:
//!   [`crate::compress::bitpack::unpack_range_into`] reproduces
//!   `unpack_into(..)[start..]` exactly, and
//!   [`accumulate_range_with`] pins the one length-dependent scheme
//!   (signSGD+Norm) to the header's full `n`;
//! * the only cross-shard reduction — [`ShardStats`] — is integer-only
//!   and merged by a fixed-shape pairwise tree.
//!
//! The contract is pinned by `tests/ingest_shards.rs` (shards {1, 4, 16}
//! over shuffled frame orders, dup/stale/malformed interleavings and
//! mixed widths) and `tests/kernel_equivalence.rs` (sub-range kernels).

use anyhow::{anyhow, ensure, Result};

use crate::compress::allocator::LayerMap;
use crate::compress::pipeline::{
    accumulate_range_with, decode_with, EncodeScratch, EncodedTensor,
};
use crate::compress::{bitpack, deflate, quantizer};
use crate::obs::Metrics;

use super::server::Server;

/// One validated, normalized wire segment, ready for lock-free sub-range
/// folding: inflated (never DEFLATE-compressed), and — when the fused
/// kernel cannot walk it positionally (rotated or sparsified frames) —
/// staged to a dense value vector on the coordinator.
#[derive(Debug, Clone)]
pub struct PreparedSegment {
    /// First accumulator index this segment covers.
    offset: usize,
    /// The inflated wire frame: headers drive the fold, payload feeds the
    /// fused sub-range kernel.
    enc: EncodedTensor,
    /// Bytes this segment occupied on the wire (header + payload *as it
    /// traveled*, i.e. post-DEFLATE) — captured before inflate, so the
    /// adaptive bit controller can water-fill against measured compressed
    /// cost instead of the analytic pre-compression size.
    wire_bytes: usize,
    /// Dense decoded values for rotated/sparsified segments (positional
    /// sub-range folding needs coordinate order; the Hadamard rotation
    /// and mask scatter do not preserve it).
    staged: Option<Vec<f32>>,
}

impl PreparedSegment {
    /// Validate and normalize one wire segment covering
    /// `offset..offset + enc.n` of the accumulator. Everything that could
    /// fail at fold time fails *here*, on the coordinator — inflate
    /// errors, bad kind ids, short payloads — so the all-or-nothing
    /// ingest contract holds and shard workers are infallible in
    /// practice.
    pub fn prepare(
        mut enc: EncodedTensor,
        offset: usize,
        scratch: &mut EncodeScratch,
    ) -> Result<PreparedSegment> {
        let n = enc.n as usize;
        let wire_bytes = crate::compress::wire::HEADER_BYTES + enc.payload.len();
        if enc.rotated || enc.kept as usize != n {
            // Stage-decode: full validation (inflate, mask regeneration,
            // payload shape) happens inside decode_with.
            let staged = decode_with(&enc, scratch)?;
            ensure!(
                staged.len() == n,
                "staged decode produced {} of {n} values",
                staged.len()
            );
            return Ok(PreparedSegment { offset, enc, wire_bytes, staged: Some(staged) });
        }
        if enc.deflated {
            enc.payload = deflate::inflate(&enc.payload)?;
            enc.deflated = false;
        }
        if enc.kind_id == quantizer::ids::FLOAT32 {
            ensure!(enc.bits == 32, "float32 frame with bits {}", enc.bits);
            ensure!(
                enc.payload.len() == n * 4,
                "float32 payload size {} != {}",
                enc.payload.len(),
                n * 4
            );
        } else {
            // Rejects unknown kind ids and out-of-range widths up front.
            quantizer::from_wire(enc.kind_id, enc.bits)?;
            ensure!(
                enc.payload.len() >= bitpack::packed_len(n, enc.bits),
                "payload too short: {} bytes for {n} codes of {} bits",
                enc.payload.len(),
                enc.bits
            );
        }
        Ok(PreparedSegment { offset, enc, wire_bytes, staged: None })
    }

    /// The wire header (post-inflate; `n`/`bits`/`norm`/`bound` are
    /// untouched by normalization) — what the round-observation
    /// accumulator reads.
    pub fn header(&self) -> &EncodedTensor {
        &self.enc
    }

    /// Bytes this segment occupied on the wire as it traveled
    /// (header + post-DEFLATE payload) — the measured-cost signal the
    /// adaptive bit controller folds into its per-layer cost scale.
    pub fn wire_bytes(&self) -> usize {
        self.wire_bytes
    }

    /// Accumulator extent covered by this segment.
    pub fn extent(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.enc.n as usize
    }
}

/// One accepted frame, validated and committed on the coordinator,
/// awaiting its (deferred) fold into the accumulator.
#[derive(Debug, Clone)]
pub struct PreparedFrame {
    /// Aggregation weight `N_i / (1 + staleness)` — fixed at accept time,
    /// so a deferred fold cannot drift from the verdict-time staleness.
    weight: f64,
    /// Segments in coverage order; offsets tile `0..n` exactly.
    segments: Vec<PreparedSegment>,
}

impl PreparedFrame {
    pub fn new(weight: f64, segments: Vec<PreparedSegment>) -> PreparedFrame {
        PreparedFrame { weight, segments }
    }

    pub fn weight(&self) -> f64 {
        self.weight
    }

    pub fn segments(&self) -> &[PreparedSegment] {
        &self.segments
    }
}

/// Integer per-shard fold tallies — the only cross-shard reduction, and
/// therefore the only thing the tree-reduce has to keep deterministic
/// (integer addition is associative, so the fixed pairwise shape is
/// belt-and-braces; the accumulator itself needs no merge at all).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Segment⋂shard intersections folded.
    pub segments: u64,
    /// Accumulator elements written.
    pub elems: u64,
}

impl ShardStats {
    fn merge(self, other: ShardStats) -> ShardStats {
        ShardStats {
            segments: self.segments + other.segments,
            elems: self.elems + other.elems,
        }
    }
}

/// Fixed-shape pairwise tree-reduce over per-shard stats: level k merges
/// neighbors 2i and 2i+1 of level k−1, identical for every run at a given
/// shard count.
fn tree_reduce(stats: &[ShardStats]) -> ShardStats {
    let mut layer: Vec<ShardStats> = Vec::with_capacity(stats.len());
    layer.extend_from_slice(stats);
    while layer.len() > 1 {
        let mut next = Vec::with_capacity(layer.len().div_ceil(2));
        for pair in layer.chunks(2) {
            next.push(pair.iter().copied().fold(ShardStats::default(), ShardStats::merge));
        }
        layer = next;
    }
    layer.first().copied().unwrap_or_default()
}

/// What one [`IngestPlane::flush`] did — tree-reduced totals plus the
/// per-shard element counts the busy gauges surface.
#[derive(Debug, Clone, Default)]
pub struct FlushStats {
    /// Frames drained from the pending queue.
    pub frames: u64,
    /// Segment⋂shard intersections folded (tree-reduced).
    pub segments: u64,
    /// Accumulator elements written (tree-reduced).
    pub elems: u64,
    /// Elements folded per shard, in shard order — the load-balance /
    /// busy signal.
    pub per_shard: Vec<u64>,
}

/// Per-shard busy gauges need `&'static str` names (the metrics registry
/// never allocates keys); shards beyond the table aggregate into
/// [`SHARD_ELEMS_REST`].
const SHARD_ELEMS: [&str; 16] = [
    "ingest_shard00_elems",
    "ingest_shard01_elems",
    "ingest_shard02_elems",
    "ingest_shard03_elems",
    "ingest_shard04_elems",
    "ingest_shard05_elems",
    "ingest_shard06_elems",
    "ingest_shard07_elems",
    "ingest_shard08_elems",
    "ingest_shard09_elems",
    "ingest_shard10_elems",
    "ingest_shard11_elems",
    "ingest_shard12_elems",
    "ingest_shard13_elems",
    "ingest_shard14_elems",
    "ingest_shard15_elems",
];
const SHARD_ELEMS_REST: &str = "ingest_shard_rest_elems";

impl FlushStats {
    /// Record this flush into the metrics registry: cumulative fold
    /// counters plus the per-shard busy gauge family.
    pub fn record(&self, metrics: &mut Metrics) {
        metrics.inc("ingest_flushes", 1);
        metrics.inc("ingest_frames_folded", self.frames);
        metrics.inc("ingest_segments_folded", self.segments);
        metrics.inc("ingest_elems_folded", self.elems);
        let mut rest = 0u64;
        for (i, &e) in self.per_shard.iter().enumerate() {
            match SHARD_ELEMS.get(i) {
                Some(name) => metrics.set_gauge(name, e as f64),
                None => rest += e,
            }
        }
        if self.per_shard.len() > SHARD_ELEMS.len() {
            metrics.set_gauge(SHARD_ELEMS_REST, rest as f64);
        }
    }
}

/// Compute the shard cut points over `0..map.param_count()`.
///
/// Multi-layer maps snap each even cut to the nearest layer boundary
/// (layer extents are contiguous, so most segments then route to exactly
/// one owner); single-layer maps split evenly by element. Cuts that
/// collapse onto a neighbor are dropped, so the effective shard count may
/// be lower than requested — never higher. Bounds are strictly
/// increasing, start at 0 and end at `param_count()`.
pub fn shard_bounds(map: &LayerMap, shards: usize) -> Vec<usize> {
    let n = map.param_count();
    let shards = shards.max(1).min(n.max(1));
    let mut bounds = Vec::with_capacity(shards + 1);
    bounds.push(0usize);
    if map.len() > 1 {
        let ends: Vec<usize> = (0..map.len()).map(|l| map.segment(l).end).collect();
        for i in 1..shards {
            let target = i * n / shards;
            let nearest = ends
                .iter()
                .copied()
                .filter(|&e| e > 0 && e < n)
                .min_by_key(|&e| e.abs_diff(target))
                .unwrap_or(target);
            if nearest > bounds.last().copied().unwrap_or(0) {
                bounds.push(nearest);
            }
        }
    } else {
        for i in 1..shards {
            let cut = i * n / shards;
            if cut > bounds.last().copied().unwrap_or(0) {
                bounds.push(cut);
            }
        }
    }
    if bounds.last().copied().unwrap_or(0) < n || bounds.len() == 1 {
        bounds.push(n);
    }
    bounds
}

/// Fold every pending frame's intersection with `lo..hi` into `out`
/// (`out.len() == hi - lo`), in arrival order — the per-worker kernel.
/// Infallible for frames that went through [`PreparedSegment::prepare`];
/// stays fallible anyway so a logic error surfaces as an `Err`, not a
/// poisoned accumulator.
fn fold_shard(
    pending: &[PreparedFrame],
    lo: usize,
    hi: usize,
    out: &mut [f64],
    scratch: &mut EncodeScratch,
) -> Result<ShardStats> {
    ensure!(out.len() == hi - lo, "shard slice {} != extent {}", out.len(), hi - lo);
    let mut stats = ShardStats::default();
    for frame in pending {
        for seg in &frame.segments {
            let s_lo = seg.offset;
            let s_hi = s_lo + seg.enc.n as usize;
            let a = s_lo.max(lo);
            let b = s_hi.min(hi);
            if a >= b {
                continue;
            }
            let dst = &mut out[a - lo..b - lo];
            match &seg.staged {
                Some(values) => {
                    for (o, &d) in dst.iter_mut().zip(&values[a - s_lo..b - s_lo]) {
                        *o += d as f64 * frame.weight;
                    }
                }
                None => {
                    accumulate_range_with(&seg.enc, a - s_lo, frame.weight, dst, scratch)?;
                }
            }
            stats.segments += 1;
            stats.elems += (b - a) as u64;
        }
    }
    Ok(stats)
}

/// Fold one prepared frame over the whole accumulator — the serial
/// (shards = 1) ingest path, routed through the *same* kernel the shard
/// workers run so serial and sharded ingest cannot drift apart.
pub(crate) fn fold_frame(
    frame: &PreparedFrame,
    acc: &mut [f64],
    scratch: &mut EncodeScratch,
) -> Result<()> {
    fold_shard(std::slice::from_ref(frame), 0, acc.len(), acc, scratch)?;
    Ok(())
}

/// The sharded ingest plane: a bounded pending queue of
/// [`PreparedFrame`]s plus per-shard scratch, flushed through scoped
/// worker threads into disjoint accumulator slices.
///
/// The queue is SPMC in the broadcast sense: the coordinator is the
/// single producer; at flush time every worker reads the *entire* queued
/// run (ownership decides what it folds), which is exactly what the
/// arrival-order determinism contract requires.
pub struct IngestPlane {
    /// Strictly increasing cut points; `bounds[i]..bounds[i+1]` is shard
    /// i's slice. See [`shard_bounds`].
    bounds: Vec<usize>,
    /// Accepted frames awaiting their fold, in arrival order.
    pending: Vec<PreparedFrame>,
    /// One scratch arena per worker — steady-state flushes run
    /// allocation-free.
    scratch: Vec<EncodeScratch>,
    /// Queue bound: [`IngestPlane::full`] past this many pending frames.
    capacity: usize,
}

impl IngestPlane {
    /// Default pending-queue bound: deep enough to amortize the scoped
    /// thread spawn per flush, shallow enough to keep staged frames from
    /// accumulating.
    pub const DEFAULT_CAPACITY: usize = 64;

    /// A plane with `shards` workers (clamped to ≥ 1; the effective count
    /// may be lower if cut points collapse — see [`shard_bounds`]) over
    /// the accumulator extent described by `map`.
    pub fn new(shards: usize, map: &LayerMap) -> IngestPlane {
        let bounds = shard_bounds(map, shards);
        let shards = bounds.len() - 1;
        IngestPlane {
            bounds,
            pending: Vec::new(),
            scratch: (0..shards).map(|_| EncodeScratch::new()).collect(),
            capacity: Self::DEFAULT_CAPACITY,
        }
    }

    /// Override the pending-queue bound (minimum 1).
    pub fn with_capacity(mut self, frames: usize) -> IngestPlane {
        self.capacity = frames.max(1);
        self
    }

    /// Effective worker count.
    pub fn shards(&self) -> usize {
        self.bounds.len().saturating_sub(1).max(1)
    }

    /// The shard cut points (for logs / tests).
    pub fn bounds(&self) -> &[usize] {
        &self.bounds
    }

    /// Frames queued and not yet flushed.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Has the bounded queue filled? Callers flush when this turns true
    /// (and always before reading round results).
    pub fn full(&self) -> bool {
        self.pending.len() >= self.capacity
    }

    /// Enqueue one accepted frame (single producer: the coordinator).
    pub fn submit(&mut self, frame: PreparedFrame) {
        self.pending.push(frame);
    }

    /// Drain the queue: fold every pending frame into `acc` across the
    /// shard workers and tree-reduce their stats. `acc.len()` must equal
    /// the plane extent. Serial (1-shard) planes fold inline — no thread
    /// is ever spawned, so `--ingest-shards 1` *is* the serial server.
    pub fn flush(&mut self, acc: &mut [f64]) -> Result<FlushStats> {
        let n = self.bounds.last().copied().unwrap_or(0);
        ensure!(
            acc.len() == n,
            "accumulator length {} != plane extent {n}",
            acc.len()
        );
        let frames = self.pending.len() as u64;
        let shards = self.shards();
        if frames == 0 {
            return Ok(FlushStats {
                frames: 0,
                segments: 0,
                elems: 0,
                per_shard: std::iter::repeat(0).take(shards).collect(),
            });
        }
        let stats: Vec<ShardStats> = if shards == 1 {
            let first = self
                .scratch
                .first_mut()
                .ok_or_else(|| anyhow!("ingest plane has no scratch arena"))?;
            let mut one = Vec::with_capacity(1);
            one.push(fold_shard(&self.pending, 0, n, acc, first)?);
            one
        } else {
            let bounds = &self.bounds;
            let pending = &self.pending;
            let mut parts: Vec<(usize, &mut [f64], &mut EncodeScratch)> =
                Vec::with_capacity(shards);
            let mut rest = acc;
            let mut scratches = &mut self.scratch[..];
            for i in 0..shards {
                let len = bounds[i + 1] - bounds[i];
                let (head, tail) = rest.split_at_mut(len);
                let (scr, scr_tail) = scratches
                    .split_first_mut()
                    .ok_or_else(|| anyhow!("scratch arenas out of step with shard count"))?;
                parts.push((i, head, scr));
                rest = tail;
                scratches = scr_tail;
            }
            let results: Vec<Result<ShardStats>> = std::thread::scope(|scope| {
                let handles: Vec<_> = parts
                    .into_iter()
                    .map(|(i, slice, scratch)| {
                        let lo = bounds[i];
                        let hi = bounds[i + 1];
                        scope.spawn(move || fold_shard(pending, lo, hi, slice, scratch))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| {
                        h.join()
                            .unwrap_or_else(|_| Err(anyhow!("ingest shard worker panicked")))
                    })
                    .collect()
            });
            let mut stats = Vec::with_capacity(results.len());
            for r in results {
                stats.push(r?);
            }
            stats
        };
        self.pending.clear();
        let per_shard: Vec<u64> = stats.iter().map(|s| s.elems).collect();
        let total = tree_reduce(&stats);
        Ok(FlushStats {
            frames,
            segments: total.segments,
            elems: total.elems,
            per_shard,
        })
    }

    /// [`IngestPlane::flush`] straight into a server's open-round
    /// accumulator.
    pub fn flush_into(&mut self, server: &mut Server) -> Result<FlushStats> {
        self.flush(server.accumulator_mut())
    }
}

/// Resolve `--ingest-shards 0` (auto): the machine's available
/// parallelism, capped at the per-shard gauge table. Affects load balance
/// and wall-clock only — never results (the determinism contract above).
pub fn auto_shards() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(SHARD_ELEMS.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::pipeline::{accumulate_with, Direction, Pipeline, PipelineState};
    use crate::compress::wire;
    use crate::util::propcheck::gradient_like;
    use crate::util::rng::Pcg64;

    fn enc_of(pipe: &Pipeline, g: &[f32], seed: u64) -> EncodedTensor {
        pipe.encode(
            g,
            Direction::Uplink,
            &mut PipelineState::new(),
            &mut Pcg64::seeded(seed),
        )
    }

    #[test]
    fn shard_bounds_even_split_on_single_layer() {
        let map = LayerMap::whole(100);
        assert_eq!(shard_bounds(&map, 1), vec![0, 100]);
        assert_eq!(shard_bounds(&map, 4), vec![0, 25, 50, 75, 100]);
        // More shards than elements: clamped.
        let tiny = LayerMap::whole(2);
        assert_eq!(shard_bounds(&tiny, 16), vec![0, 1, 2]);
        // Empty model.
        assert_eq!(shard_bounds(&LayerMap::whole(0), 4), vec![0, 0]);
    }

    #[test]
    fn shard_bounds_snap_to_layer_extents() {
        // Layers of 10/70/20: the 2-shard cut (target 50) snaps to the
        // nearest layer end (80).
        let map = LayerMap::from_extents(&[(0, 10), (1, 70), (2, 20)]).unwrap();
        assert_eq!(shard_bounds(&map, 2), vec![0, 80, 100]);
        // 4 shards, targets 25/50/75 → all snap to 10 or 80; duplicates
        // collapse, so the effective count drops to 3.
        assert_eq!(shard_bounds(&map, 4), vec![0, 10, 80, 100]);
    }

    #[test]
    fn bounds_are_strictly_increasing_and_cover() {
        let mut rng = Pcg64::seeded(7);
        for _ in 0..50 {
            let layers = 1 + rng.below_usize(6);
            let extents: Vec<(usize, usize)> = (0..layers)
                .map(|l| (l, 1 + rng.below_usize(300)))
                .collect();
            let map = LayerMap::from_extents(&extents).unwrap();
            for shards in [1usize, 2, 3, 4, 16, 64] {
                let b = shard_bounds(&map, shards);
                assert_eq!(b[0], 0);
                assert_eq!(*b.last().unwrap(), map.param_count());
                assert!(b.windows(2).all(|w| w[0] < w[1]), "{b:?}");
                assert!(b.len() - 1 <= shards.max(1));
            }
        }
    }

    #[test]
    fn tree_reduce_totals() {
        let stats: Vec<ShardStats> = (0..5)
            .map(|i| ShardStats { segments: i, elems: 10 * i })
            .collect();
        let t = tree_reduce(&stats);
        assert_eq!(t.segments, 10);
        assert_eq!(t.elems, 100);
        assert_eq!(tree_reduce(&[]), ShardStats::default());
    }

    #[test]
    fn prepare_normalizes_deflate_and_stages_rotation() {
        let mut rng = Pcg64::seeded(11);
        let g = gradient_like(&mut rng, 600);
        let mut scratch = EncodeScratch::new();

        let dense = enc_of(&Pipeline::cosine(3), &g, 1);
        let traveled = wire::serialize(&dense).len();
        let was_deflated = dense.deflated;
        let p = PreparedSegment::prepare(dense, 0, &mut scratch).unwrap();
        assert!(!p.header().deflated, "deflate is undone at prepare");
        assert!(p.staged.is_none(), "dense frames stay packed");
        // Measured wire cost is the as-traveled (compressed) size, not the
        // inflated one the fold works on.
        assert_eq!(p.wire_bytes(), traveled);
        if was_deflated {
            assert!(p.wire_bytes() < wire::HEADER_BYTES + p.header().payload.len());
        }

        let rotated = enc_of(&Pipeline::cosine(4).with_rotation(), &g, 2);
        let p = PreparedSegment::prepare(rotated, 0, &mut scratch).unwrap();
        assert_eq!(p.staged.as_ref().unwrap().len(), 600);

        let sparse = enc_of(&Pipeline::cosine(4).with_sparsify(0.25), &g, 3);
        let p = PreparedSegment::prepare(sparse, 0, &mut scratch).unwrap();
        assert_eq!(p.staged.as_ref().unwrap().len(), 600);
    }

    #[test]
    fn prepare_rejects_what_the_fold_would_choke_on() {
        let mut rng = Pcg64::seeded(12);
        let g = gradient_like(&mut rng, 64);
        let mut scratch = EncodeScratch::new();
        // Truncated payload.
        let mut enc = enc_of(&Pipeline::cosine(8).without_deflate(), &g, 1);
        enc.payload.truncate(4);
        assert!(PreparedSegment::prepare(enc, 0, &mut scratch).is_err());
        // Unknown kind id.
        let mut enc = enc_of(&Pipeline::cosine(8).without_deflate(), &g, 2);
        enc.kind_id = 99;
        assert!(PreparedSegment::prepare(enc, 0, &mut scratch).is_err());
        // Corrupt DEFLATE body.
        let mut enc = enc_of(&Pipeline::cosine(8), &g, 3);
        if enc.deflated {
            enc.payload.clear();
            assert!(PreparedSegment::prepare(enc, 0, &mut scratch).is_err());
        }
    }

    fn prepared(pipe: &Pipeline, g: &[f32], seed: u64, weight: f64) -> PreparedFrame {
        let enc = enc_of(pipe, g, seed);
        let mut scratch = EncodeScratch::new();
        let seg = PreparedSegment::prepare(enc, 0, &mut scratch).unwrap();
        PreparedFrame::new(weight, vec![seg])
    }

    #[test]
    fn sharded_flush_is_bit_identical_to_serial_fold() {
        let mut rng = Pcg64::seeded(13);
        let n = 777;
        let pipes = [
            Pipeline::cosine(4),
            Pipeline::cosine(1),
            Pipeline::float32(),
            Pipeline::sign_norm(),
            Pipeline::cosine(8).with_rotation(),
            Pipeline::cosine(4).with_sparsify(0.5),
        ];
        let frames: Vec<PreparedFrame> = pipes
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let g = gradient_like(&mut rng, n);
                prepared(p, &g, 40 + i as u64, 1.0 + i as f64)
            })
            .collect();

        // Serial reference: the fused whole-frame fold.
        let mut reference = vec![0.0f64; n];
        let mut scratch = EncodeScratch::new();
        for f in &frames {
            for s in &f.segments {
                match &s.staged {
                    Some(v) => {
                        for (a, &d) in reference.iter_mut().zip(v) {
                            *a += d as f64 * f.weight;
                        }
                    }
                    None => {
                        accumulate_with(&s.enc, f.weight, &mut reference, &mut scratch).unwrap();
                    }
                }
            }
        }

        for shards in [1usize, 2, 4, 16] {
            let mut plane = IngestPlane::new(shards, &LayerMap::whole(n));
            for f in &frames {
                plane.submit(f.clone());
            }
            let mut acc = vec![0.0f64; n];
            let stats = plane.flush(&mut acc).unwrap();
            assert_eq!(stats.frames, frames.len() as u64);
            assert_eq!(stats.elems, (n * frames.len()) as u64);
            let ref_bits: Vec<u64> = reference.iter().map(|v| v.to_bits()).collect();
            let acc_bits: Vec<u64> = acc.iter().map(|v| v.to_bits()).collect();
            assert_eq!(acc_bits, ref_bits, "shards={shards}");
            assert!(plane.is_empty(), "flush drains the queue");
        }
    }

    #[test]
    fn flush_granularity_does_not_change_bits() {
        // One flush per frame vs one flush for all frames: identical —
        // the fold order per element is arrival order either way.
        let mut rng = Pcg64::seeded(14);
        let n = 320;
        let frames: Vec<PreparedFrame> = (0..6)
            .map(|i| {
                let g = gradient_like(&mut rng, n);
                prepared(&Pipeline::cosine(5), &g, 70 + i, 2.0)
            })
            .collect();
        let map = LayerMap::even(n, 4);
        let mut batched = IngestPlane::new(4, &map);
        let mut stepped = IngestPlane::new(4, &map);
        let mut acc_a = vec![0.0f64; n];
        let mut acc_b = vec![0.0f64; n];
        for f in &frames {
            batched.submit(f.clone());
            stepped.submit(f.clone());
            stepped.flush(&mut acc_b).unwrap();
        }
        batched.flush(&mut acc_a).unwrap();
        let a: Vec<u64> = acc_a.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u64> = acc_b.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn segmented_frames_route_to_owning_shards() {
        // A 3-layer model, segment per layer, shard per layer: every
        // segment has exactly one owner, and stats see one intersection
        // per segment.
        let mut rng = Pcg64::seeded(15);
        let sizes = [100usize, 200, 60];
        let map = LayerMap::from_extents(&[(0, 100), (1, 200), (2, 60)]).unwrap();
        let n: usize = sizes.iter().sum();
        let g = gradient_like(&mut rng, n);
        let mut scratch = EncodeScratch::new();
        let mut segs = Vec::new();
        let mut off = 0usize;
        for (l, &sz) in sizes.iter().enumerate() {
            let pipe = Pipeline::cosine(4).with_bits(2 + l as u8);
            let enc = enc_of(&pipe, &g[off..off + sz], 80 + l as u64);
            segs.push(PreparedSegment::prepare(enc, off, &mut scratch).unwrap());
            off += sz;
        }
        let frame = PreparedFrame::new(3.0, segs);
        let mut plane = IngestPlane::new(3, &map);
        assert_eq!(plane.bounds(), &[0, 100, 300, 360]);
        plane.submit(frame.clone());
        let mut acc = vec![0.0f64; n];
        let stats = plane.flush(&mut acc).unwrap();
        assert_eq!(stats.segments, 3, "one owner per segment");
        assert_eq!(stats.per_shard, vec![100, 200, 60]);

        // And the fold equals the serial whole-frame fold.
        let mut reference = vec![0.0f64; n];
        fold_frame(&frame, &mut reference, &mut scratch).unwrap();
        let a: Vec<u64> = acc.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u64> = reference.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn flush_stats_record_metrics() {
        let stats = FlushStats {
            frames: 3,
            segments: 7,
            elems: 1000,
            per_shard: vec![600, 400],
        };
        let mut m = Metrics::new();
        stats.record(&mut m);
        stats.record(&mut m);
        assert_eq!(m.counter("ingest_flushes"), 2);
        assert_eq!(m.counter("ingest_frames_folded"), 6);
        assert_eq!(m.counter("ingest_elems_folded"), 2000);
        assert_eq!(m.gauge("ingest_shard00_elems"), Some(600.0));
        assert_eq!(m.gauge("ingest_shard01_elems"), Some(400.0));
        assert_eq!(m.gauge("ingest_shard_rest_elems"), None);
    }

    #[test]
    fn queue_bound_and_capacity() {
        let mut plane = IngestPlane::new(1, &LayerMap::whole(8)).with_capacity(2);
        assert!(!plane.full());
        let g = [1.0f32; 8];
        plane.submit(prepared(&Pipeline::float32(), &g, 1, 1.0));
        assert!(!plane.full());
        plane.submit(prepared(&Pipeline::float32(), &g, 2, 1.0));
        assert!(plane.full());
        assert_eq!(plane.pending(), 2);
        let mut acc = vec![0.0f64; 8];
        plane.flush(&mut acc).unwrap();
        assert!(!plane.full());
        assert_eq!(acc, vec![2.0f64; 8]);
    }

    #[test]
    fn wire_roundtrip_prepares_cleanly() {
        // A frame that went through serialize/deserialize prepares the
        // same as the in-memory EncodedTensor.
        let mut rng = Pcg64::seeded(16);
        let g = gradient_like(&mut rng, 256);
        let enc = enc_of(&Pipeline::cosine(6), &g, 5);
        let bytes = wire::serialize(&enc);
        let back = wire::deserialize(&bytes).unwrap();
        let mut scratch = EncodeScratch::new();
        let a = PreparedSegment::prepare(enc, 0, &mut scratch).unwrap();
        let b = PreparedSegment::prepare(back, 0, &mut scratch).unwrap();
        assert_eq!(a.enc, b.enc);
    }

    #[test]
    fn auto_shards_is_positive_and_bounded() {
        let s = auto_shards();
        assert!(s >= 1);
        assert!(s <= SHARD_ELEMS.len());
    }
}
