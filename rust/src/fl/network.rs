//! Simulated network: a byte-exact ledger of everything that moves between
//! clients and server. The paper's cost tables (Table 1, the x-axes of
//! Figs. 9–10) are uplink gradient bytes; the downlink (model broadcast)
//! is metered symmetrically so round-trip compression figures are
//! reproducible.
//!
//! In the frame-driven runner the ledger is owned by the
//! [`crate::fl::transport::Transport`] carrying the frames, so a byte is
//! metered exactly when (and only when) it is delivered — aborted
//! straggler uploads never reach the ledger, by construction rather than
//! by a separately-maintained replay.

use crate::util::timer::fmt_bytes;

/// Cumulative traffic ledger.
#[derive(Debug, Clone, Default)]
pub struct NetworkLedger {
    pub uplink_bytes: u64,
    pub downlink_bytes: u64,
    pub uplink_messages: u64,
    pub downlink_messages: u64,
}

impl NetworkLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// A client → server update of `bytes`.
    pub fn record_uplink(&mut self, bytes: usize) {
        self.uplink_bytes += bytes as u64;
        self.uplink_messages += 1;
    }

    /// A server → client model broadcast of `bytes`.
    pub fn record_downlink(&mut self, bytes: usize) {
        self.downlink_bytes += bytes as u64;
        self.downlink_messages += 1;
    }

    /// `receivers` identical broadcasts of `bytes` each, folded in O(1):
    /// the per-round fan-out must not cost O(fleet) ledger calls at
    /// million-client scale. At that scale the product can also overflow
    /// u64 (a multi-GB model × a million-device fleet × many rounds), so
    /// the fold is checked: overflow saturates (and trips a debug
    /// assertion) instead of silently wrapping the ledger back toward
    /// zero — a saturated ledger reads as "at least this much", a
    /// wrapped one reads as almost nothing.
    pub fn record_downlink_n(&mut self, bytes: usize, receivers: usize) {
        let total = (bytes as u64).checked_mul(receivers as u64).unwrap_or_else(|| {
            debug_assert!(false, "downlink fan-out overflow: {bytes} B × {receivers}");
            u64::MAX
        });
        self.downlink_bytes = self.downlink_bytes.saturating_add(total);
        self.downlink_messages = self.downlink_messages.saturating_add(receivers as u64);
    }

    /// Mean uplink bytes per message.
    pub fn mean_uplink(&self) -> f64 {
        if self.uplink_messages == 0 {
            0.0
        } else {
            self.uplink_bytes as f64 / self.uplink_messages as f64
        }
    }

    /// Compression ratio of total uplink vs a float32 baseline that would
    /// have sent `param_count` f32s per message. `None` until traffic has
    /// been recorded — there is no ratio of nothing.
    pub fn uplink_compression_vs_float32(&self, param_count: usize) -> Option<f64> {
        ratio_vs_float32(self.uplink_bytes, self.uplink_messages, param_count)
    }

    /// Symmetric downlink ratio: total broadcast bytes vs `4·param_count`
    /// per message. `None` until traffic has been recorded.
    pub fn downlink_compression_vs_float32(&self, param_count: usize) -> Option<f64> {
        ratio_vs_float32(self.downlink_bytes, self.downlink_messages, param_count)
    }

    pub fn summary(&self) -> String {
        format!(
            "uplink {} in {} msgs (mean {}), downlink {} in {} msgs",
            fmt_bytes(self.uplink_bytes),
            self.uplink_messages,
            fmt_bytes(self.mean_uplink() as u64),
            fmt_bytes(self.downlink_bytes),
            self.downlink_messages,
        )
    }
}

/// Display form of an optional compression ratio: `"12.3x"`, or `"-"`
/// when no traffic has been recorded yet.
pub fn fmt_ratio(r: Option<f64>) -> String {
    r.map(|x| format!("{x:.1}x")).unwrap_or_else(|| "-".into())
}

fn ratio_vs_float32(bytes: u64, messages: u64, param_count: usize) -> Option<f64> {
    if bytes == 0 || messages == 0 {
        return None;
    }
    let baseline = messages as f64 * param_count as f64 * 4.0;
    Some(baseline / bytes as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates() {
        let mut n = NetworkLedger::new();
        n.record_uplink(100);
        n.record_uplink(300);
        n.record_downlink(1000);
        assert_eq!(n.uplink_bytes, 400);
        assert_eq!(n.uplink_messages, 2);
        assert_eq!(n.mean_uplink(), 200.0);
        assert_eq!(n.downlink_bytes, 1000);
    }

    #[test]
    fn bulk_downlink_equals_the_loop() {
        let mut bulk = NetworkLedger::new();
        bulk.record_downlink_n(1234, 57);
        let mut looped = NetworkLedger::new();
        for _ in 0..57 {
            looped.record_downlink(1234);
        }
        assert_eq!(bulk.downlink_bytes, looped.downlink_bytes);
        assert_eq!(bulk.downlink_messages, looped.downlink_messages);
        // Zero receivers is a no-op, not a message.
        bulk.record_downlink_n(999, 0);
        assert_eq!(bulk.downlink_bytes, looped.downlink_bytes);
        assert_eq!(bulk.downlink_messages, looped.downlink_messages);
    }

    #[test]
    fn bulk_downlink_near_overflow_is_exact() {
        // Million-fleet × multi-GB model: the product brushes against
        // u64::MAX but still fits — the checked path must stay exact.
        // 2^40 bytes (1 TiB of frames) × 2^23 receivers = 2^63 exactly.
        let mut n = NetworkLedger::new();
        n.record_downlink_n(1usize << 40, 1usize << 23);
        assert_eq!(n.downlink_bytes, 1u64 << 63);
        assert_eq!(n.downlink_messages, 1u64 << 23);
        // A second near-max fold saturates the running total instead of
        // wrapping it back toward zero.
        n.record_downlink_n(1usize << 40, 1usize << 23);
        assert_eq!(n.downlink_bytes, u64::MAX);
    }

    // The product-overflow fallback trips a debug assertion by design, so
    // the saturation behavior itself is only testable in release builds.
    #[cfg(not(debug_assertions))]
    #[test]
    fn bulk_downlink_product_overflow_saturates() {
        let mut n = NetworkLedger::new();
        n.record_downlink_n(usize::MAX, 3);
        assert_eq!(n.downlink_bytes, u64::MAX);
        assert_eq!(n.downlink_messages, 3);
    }

    #[test]
    fn compression_ratio_vs_baseline() {
        let mut n = NetworkLedger::new();
        // Two messages of 1000 bytes for a 10_000-param model:
        // baseline = 2 * 40_000 bytes -> ratio 40.
        n.record_uplink(1000);
        n.record_uplink(1000);
        assert!((n.uplink_compression_vs_float32(10_000).unwrap() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn ratio_display_form() {
        assert_eq!(fmt_ratio(None), "-");
        assert_eq!(fmt_ratio(Some(12.34)), "12.3x");
    }

    #[test]
    fn no_traffic_means_no_ratio() {
        // The old API returned a misleading 1.0 here.
        let n = NetworkLedger::new();
        assert_eq!(n.uplink_compression_vs_float32(10), None);
        assert_eq!(n.downlink_compression_vs_float32(10), None);
    }

    #[test]
    fn downlink_ratio_is_symmetric() {
        let mut n = NetworkLedger::new();
        n.record_downlink(4000); // one float32 broadcast of 1000 params
        assert!((n.downlink_compression_vs_float32(1000).unwrap() - 1.0).abs() < 1e-9);
        n.record_downlink(400); // one 10x-compressed delta
        let r = n.downlink_compression_vs_float32(1000).unwrap();
        assert!((r - 8000.0 / 4400.0).abs() < 1e-9);
    }
}
