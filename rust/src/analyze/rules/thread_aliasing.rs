//! Rule `thread_aliasing`: inside `thread::scope` blocks of the scoped
//! files, spawn closures must `move`-capture, and any `&mut NAME` they
//! use must be provably disjoint per worker. Recognized disjointness
//! idioms, in the order checked:
//!
//! * `NAME` is a closure parameter (the iterator that produced it split
//!   the state — `split_at_mut` chains feed `.map(|(i, slice, ..)| …)`);
//! * `NAME` is `let`-bound inside the closure body (worker-owned state);
//! * `NAME` is bound, anywhere in the enclosing fn before the spawn, on a
//!   line using a splitting/channel idiom (`split_at_mut`, `chunks_mut`,
//!   `iter_mut`, `sync_channel`, `.recv()`, …);
//! * `NAME` is an owned local `move`-captured by the closure (each worker
//!   gets its own value — `let mut scratch = …` before a `move` spawn).
//!
//! Anything else — a non-`move` closure, or a `&mut` reborrow of shared
//! state smuggled into workers — is a violation.

use super::super::config::RuleScope;
use super::super::lexer::SourceFile;
use super::super::report::Diagnostic;
use super::super::symbols::{brace_span, paren_span};
use super::{suppressed, token_hit, Rule};

const RULE: &str = "thread_aliasing";

const IDIOMS: &[&str] = &[
    "split_at_mut",
    "split_first_mut",
    "split_last_mut",
    "chunks_mut",
    "iter_mut",
    "sync_channel",
    ".recv()",
    "split_off",
];

pub struct ThreadAliasing;

impl Rule for ThreadAliasing {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, files: &[SourceFile], scope: &RuleScope) -> Vec<Diagnostic> {
        let mut out = Vec::new();
        for file in files {
            if !scope.covers(&file.rel_path) {
                continue;
            }
            for ln in 0..file.lines.len() {
                let Some(col) = file.lines[ln].find("thread::scope(") else {
                    continue;
                };
                if file.in_test(ln) {
                    continue;
                }
                let Some((_, close)) = brace_span(&file.lines, ln, col) else {
                    continue;
                };
                for sln in ln..=close.min(file.lines.len().saturating_sub(1)) {
                    let line = file.lines[sln].clone();
                    let mut from = 0usize;
                    while let Some(p) = line[from..].find(".spawn(") {
                        let at = from + p;
                        from = at + ".spawn(".len();
                        check_spawn(file, scope, sln, at + ".spawn".len(), &mut out);
                    }
                }
            }
        }
        out
    }
}

/// Audit one `.spawn(` call whose `(` sits at (`ln`, `paren_col`).
fn check_spawn(
    file: &SourceFile,
    scope: &RuleScope,
    ln: usize,
    paren_col: usize,
    out: &mut Vec<Diagnostic>,
) {
    if suppressed(file, scope, RULE, ln) {
        return;
    }
    let Some((sl, el)) = paren_span(&file.lines, ln, paren_col) else {
        return;
    };
    // Flatten the spawn call region, starting at its `(`.
    let mut region = String::new();
    for l in sl..=el.min(file.lines.len().saturating_sub(1)) {
        let s = &file.lines[l];
        if l == sl {
            region.push_str(&s[paren_col.min(s.len())..]);
        } else {
            region.push_str(s);
        }
        region.push('\n');
    }
    let is_move = region
        .get(1..)
        .map(|r| r.trim_start().starts_with("move"))
        .unwrap_or(false);
    if !is_move {
        out.push(Diagnostic::new(
            &file.rel_path,
            ln,
            RULE,
            "scoped spawn closure must `move`-capture; implicit borrows alias shared state across workers"
                .to_string(),
        ));
    }
    // Closure params (between the first `|` pair) and body (after it).
    let (params, body) = match region.find('|') {
        Some(a) => match region[a + 1..].find('|') {
            Some(off) => (
                region[a + 1..a + 1 + off].to_string(),
                region[a + 2 + off..].to_string(),
            ),
            None => (String::new(), region[a + 1..].to_string()),
        },
        None => (String::new(), region.clone()),
    };

    let bb = body.as_bytes();
    let mut i = 0usize;
    while let Some(p) = body[i..].find("&mut ") {
        let at = i + p + "&mut ".len();
        i = at;
        let mut e = at;
        while e < bb.len() && bb[e] == b' ' {
            e += 1;
        }
        let s2 = e;
        while e < bb.len() && (bb[e].is_ascii_alphanumeric() || bb[e] == b'_') {
            e += 1;
        }
        if e == s2 {
            continue; // `&mut (...)` — not a named capture
        }
        let name = &body[s2..e];
        if name == "self" {
            continue;
        }
        if token_hit(&params, name) || body_binds(&body, name) {
            continue;
        }
        let fn_start = file.enclosing_fn(ln).map(|f| f.decl).unwrap_or(0);
        if pre_spawn_idiom(file, fn_start, ln, name) {
            continue;
        }
        if is_move && owned_local(file, fn_start, ln, name) {
            continue;
        }
        out.push(Diagnostic::new(
            &file.rel_path,
            ln,
            RULE,
            format!(
                "`&mut {name}` captured by a scoped spawn closure without a recognized disjointness idiom (split_at_mut/chunks_mut/iter_mut chain, per-worker channel endpoint, or move-captured owned local)"
            ),
        ));
    }
}

/// Is `name` `let`-bound inside the closure body (left of an `=`)?
fn body_binds(body: &str, name: &str) -> bool {
    body.lines().any(|l| {
        let lhs = l.split('=').next().unwrap_or(l);
        token_hit(lhs, "let") && token_hit(lhs, name)
    })
}

/// Does a line of the enclosing fn before the spawn bind/use `name`
/// through a recognized disjointness idiom?
fn pre_spawn_idiom(file: &SourceFile, fn_start: usize, spawn_ln: usize, name: &str) -> bool {
    file.lines[fn_start..=spawn_ln]
        .iter()
        .any(|l| token_hit(l, name) && IDIOMS.iter().any(|i| l.contains(i)))
}

/// Is `name` an owned local of the enclosing fn (a `let` binding whose
/// initializer is not itself a `&mut` reborrow)? Under a `move` closure
/// each worker then captures its own value.
fn owned_local(file: &SourceFile, fn_start: usize, spawn_ln: usize, name: &str) -> bool {
    file.lines[fn_start..=spawn_ln].iter().any(|l| {
        let mut split = l.splitn(2, '=');
        let lhs = split.next().unwrap_or(l);
        let rhs = split.next().unwrap_or("");
        token_hit(lhs, "let") && token_hit(lhs, name) && !rhs.contains("&mut")
    })
}
