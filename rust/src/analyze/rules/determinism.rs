//! Rule `determinism`: order-sensitive modules (server aggregation, the
//! round loop, transport, the event-driven simulator, the compression
//! pipeline) must stay bit-identical across runs. Unordered containers,
//! wall-clock reads, and OS-seeded RNG are banned there.

use super::super::config::RuleScope;
use super::super::lexer::SourceFile;
use super::super::report::Diagnostic;
use super::{scan_tokens, Rule};

const BANNED: &[(&str, &str)] = &[
    (
        "HashMap",
        "unordered iteration breaks bit-identical aggregation; use BTreeMap or sort keys before iterating",
    ),
    (
        "HashSet",
        "unordered iteration breaks bit-identical aggregation; use BTreeSet or a sorted Vec",
    ),
    (
        "Instant",
        "wall-clock reads are nondeterministic; thread sim::Clock time through the caller",
    ),
    (
        "SystemTime",
        "wall-clock reads are nondeterministic; thread sim::Clock time through the caller",
    ),
    (
        "Stopwatch",
        "wall-clock timing is nondeterministic; use obs::TimeSource or sim ticks, or waive for report-only timing",
    ),
    (
        "thread_rng",
        "OS-seeded RNG is nondeterministic; use the seeded util::rng::Pcg64",
    ),
];

pub struct Determinism;

impl Rule for Determinism {
    fn name(&self) -> &'static str {
        "determinism"
    }

    fn check(&self, files: &[SourceFile], scope: &RuleScope) -> Vec<Diagnostic> {
        scan_tokens(files, scope, self.name(), BANNED)
    }
}
