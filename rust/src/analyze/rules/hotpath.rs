//! Rule `hotpath`: the quantization kernels went transcendental-free and
//! allocation-free in PR 3 — per-element `cos`/`acos` etc. and per-call
//! clones must not creep back into `compress/kernel.rs` / `bitpack.rs`.
//! Reference paths and LUT builders carry `// analyze: allow(hotpath)`
//! waivers instead of allowlist entries so the justification sits next to
//! the code.

use super::super::config::RuleScope;
use super::super::lexer::SourceFile;
use super::super::report::Diagnostic;
use super::{scan_tokens, Rule};

const TRANSCENDENTAL_WHY: &str =
    "per-element transcendental call; use the LUT / polynomial fast path (PR 3)";
const ALLOC_WHY: &str = "per-call allocation in a hot kernel; reuse caller-provided scratch";

const BANNED: &[(&str, &str)] = &[
    (".cos(", TRANSCENDENTAL_WHY),
    (".acos(", TRANSCENDENTAL_WHY),
    (".sin(", TRANSCENDENTAL_WHY),
    (".asin(", TRANSCENDENTAL_WHY),
    (".tan(", TRANSCENDENTAL_WHY),
    (".atan(", TRANSCENDENTAL_WHY),
    (".exp(", TRANSCENDENTAL_WHY),
    (".exp2(", TRANSCENDENTAL_WHY),
    (".ln(", TRANSCENDENTAL_WHY),
    (".log2(", TRANSCENDENTAL_WHY),
    (".log10(", TRANSCENDENTAL_WHY),
    (".powf(", TRANSCENDENTAL_WHY),
    (".clone()", ALLOC_WHY),
    (".to_vec()", ALLOC_WHY),
    (".to_owned()", ALLOC_WHY),
    ("vec![", ALLOC_WHY),
];

pub struct HotPath;

impl Rule for HotPath {
    fn name(&self) -> &'static str {
        "hotpath"
    }

    fn check(&self, files: &[SourceFile], scope: &RuleScope) -> Vec<Diagnostic> {
        scan_tokens(files, scope, self.name(), BANNED)
    }
}
