//! Rule `unsafe_audit`: every `unsafe` block / fn / impl / trait must
//! carry an adjacent `// SAFETY:` comment stating the invariant that makes
//! it sound (same line, the line below for block bodies, or the comment
//! block directly above).

use super::super::config::RuleScope;
use super::super::lexer::SourceFile;
use super::super::report::Diagnostic;
use super::{suppressed, Rule};

pub struct UnsafeAudit;

impl Rule for UnsafeAudit {
    fn name(&self) -> &'static str {
        "unsafe_audit"
    }

    fn check(&self, files: &[SourceFile], scope: &RuleScope) -> Vec<Diagnostic> {
        let mut out = Vec::new();
        for file in files {
            if !scope.covers(&file.rel_path) {
                continue;
            }
            for site in &file.unsafes {
                if file.has_safety_comment(site.line) {
                    continue;
                }
                if suppressed(file, scope, self.name(), site.line) {
                    continue;
                }
                out.push(Diagnostic::new(
                    &file.rel_path,
                    site.line,
                    self.name(),
                    format!(
                        "{} without an adjacent `// SAFETY:` comment documenting the invariant",
                        site.kind.label()
                    ),
                ));
            }
        }
        out
    }
}
