//! Rule `hotloop_alloc`: loop bodies in the hot-path files (`paths`) may
//! not allocate — directly or **transitively through the call graph**.
//! A `Vec::new` hidden three calls deep behind a per-element accumulate
//! loop is exactly the regression this rule exists to catch; the per-file
//! `hotpath` rule cannot see it. Sinful constructs may live in any file
//! (only the loop must be in a scoped file); the diagnostic carries the
//! call chain from the looping fn to the allocating fn.
//!
//! The sin list is deliberately narrow — steady-state per-element
//! allocations, not one-time setup: `Vec::new(`, `vec![`, `Box::new(`,
//! `.clone()`, `.to_vec()`, `.to_owned()`. Amortized constructs
//! (`with_capacity` reuse, `collect` into preallocated reductions) stay
//! legal; a scoping decision that proves too loose is tightened in the
//! manifest, not here.

use std::collections::HashMap;
use std::collections::HashSet;

use super::super::callgraph::CallGraph;
use super::super::config::RuleScope;
use super::super::lexer::SourceFile;
use super::super::report::Diagnostic;
use super::super::symbols::SymbolTable;
use super::{suppressed, token_hit, Rule};

const RULE: &str = "hotloop_alloc";

const ALLOC: &[(&str, &str)] = &[
    ("Vec::new(", "allocates per iteration; hoist or reuse a scratch buffer"),
    ("vec![", "allocates per iteration; hoist or reuse a scratch buffer"),
    ("Box::new(", "heap-allocates per iteration; use a stack value or reuse"),
    (".clone()", "deep-copies per iteration; borrow or reuse"),
    (".to_vec()", "copies the slice per iteration; borrow or reuse"),
    (".to_owned()", "copies per iteration; borrow or reuse"),
];

pub struct HotLoopAlloc;

impl Rule for HotLoopAlloc {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, files: &[SourceFile], scope: &RuleScope) -> Vec<Diagnostic> {
        let syms = SymbolTable::build(files);
        let graph = CallGraph::build(&syms);
        // First sin (token, line) per fn, scanning only the fn's own lines.
        let direct: Vec<Option<(usize, usize)>> = syms
            .fns
            .iter()
            .map(|f| {
                let file = &files[f.file];
                if f.in_test {
                    return None;
                }
                for ln in f.decl..=f.end.min(file.lines.len().saturating_sub(1)) {
                    if file.enclosing_fn(ln).map(|e| e.decl) != Some(f.decl)
                        || file.in_test(ln)
                        || file.waived(RULE, ln)
                    {
                        continue;
                    }
                    for (ti, (token, _)) in ALLOC.iter().enumerate() {
                        if token_hit(&file.lines[ln], token) {
                            return Some((ti, ln));
                        }
                    }
                }
                None
            })
            .collect();

        let mut memo: HashMap<usize, Option<Vec<usize>>> = HashMap::new();
        let mut out = Vec::new();
        for lp in &syms.loops {
            let f = &syms.fns[lp.fn_id];
            let file = &files[f.file];
            if f.in_test || !scope.covers(&file.rel_path) {
                continue;
            }
            // Direct allocations inside the loop body.
            for ln in lp.start..=lp.end.min(file.lines.len().saturating_sub(1)) {
                if file.enclosing_fn(ln).map(|e| e.decl) != Some(f.decl)
                    || suppressed(file, scope, RULE, ln)
                {
                    continue;
                }
                for (token, why) in ALLOC {
                    if token_hit(&file.lines[ln], token) {
                        out.push(Diagnostic::new(
                            &file.rel_path,
                            ln,
                            RULE,
                            format!("`{token}` inside a hot loop: {why}"),
                        ));
                    }
                }
            }
            // Transitive allocations behind calls made inside the loop.
            for call in &syms.calls {
                if call.caller != lp.fn_id || call.line < lp.start || call.line > lp.end {
                    continue;
                }
                if suppressed(file, scope, RULE, call.line) {
                    continue;
                }
                for callee in syms.resolve(call) {
                    let Some(path) =
                        sin_path(callee, &graph, &direct, &mut memo, &mut HashSet::new())
                    else {
                        continue;
                    };
                    let sinner = *path.last().expect("non-empty sin path");
                    let (ti, sin_ln) = direct[sinner].expect("path ends at a direct sin");
                    let (token, why) = ALLOC[ti];
                    let mut chain = vec![syms.label(lp.fn_id)];
                    chain.extend(path.iter().map(|&x| syms.label(x)));
                    out.push(
                        Diagnostic::new(
                            &file.rel_path,
                            call.line,
                            RULE,
                            format!(
                                "hot loop calls `{}` which allocates (`{token}` at {}:{}): {why}",
                                syms.label(callee),
                                files[syms.fns[sinner].file].rel_path,
                                sin_ln + 1,
                            ),
                        )
                        .with_chain(chain),
                    );
                }
            }
        }
        out
    }
}

/// Shortest-by-DFS path from `id` to a fn with a direct sin, inclusive of
/// both ends (`[id, .., sinner]`), or None. Memoized; cycles break to None.
fn sin_path(
    id: usize,
    graph: &CallGraph,
    direct: &[Option<(usize, usize)>],
    memo: &mut HashMap<usize, Option<Vec<usize>>>,
    stack: &mut HashSet<usize>,
) -> Option<Vec<usize>> {
    if let Some(m) = memo.get(&id) {
        return m.clone();
    }
    if !stack.insert(id) {
        return None;
    }
    let res = if direct[id].is_some() {
        Some(vec![id])
    } else {
        let mut found = None;
        for &(callee, _) in graph.callees(id) {
            if let Some(mut p) = sin_path(callee, graph, direct, memo, stack) {
                p.insert(0, id);
                found = Some(p);
                break;
            }
        }
        found
    };
    stack.remove(&id);
    memo.insert(id, res.clone());
    res
}
