//! The rule families and shared matching helpers.
//!
//! Each rule implements [`Rule`] over the full set of lexed files. The
//! lexical families are per-line token scans and `wire` is a cross-file
//! consistency check; `panic_propagation`, `thread_aliasing`, and
//! `hotloop_alloc` are interprocedural — they build the whole-tree
//! [`symbols::SymbolTable`](super::symbols::SymbolTable) and walk the
//! [`callgraph::CallGraph`](super::callgraph::CallGraph). Shared
//! suppression logic: test spans, manifest allowlists (file or `file::fn`),
//! and inline `// analyze: allow(rule)` waivers.

mod determinism;
mod hotloop_alloc;
mod hotpath;
mod panic_propagation;
mod panic_safety;
mod thread_aliasing;
mod unsafe_audit;
mod wire;

use super::config::RuleScope;
use super::lexer::SourceFile;
use super::report::Diagnostic;

/// One rule family.
pub trait Rule {
    fn name(&self) -> &'static str;
    /// Scan `files` (already restricted to `.rs` sources under the root);
    /// `scope` carries the manifest paths/allowlist for this rule.
    fn check(&self, files: &[SourceFile], scope: &RuleScope) -> Vec<Diagnostic>;
}

/// All rule families, in a fixed order (the report re-sorts anyway).
pub fn all() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(determinism::Determinism),
        Box::new(panic_safety::PanicSafety),
        Box::new(panic_propagation::PanicPropagation),
        Box::new(hotpath::HotPath),
        Box::new(hotloop_alloc::HotLoopAlloc),
        Box::new(thread_aliasing::ThreadAliasing),
        Box::new(unsafe_audit::UnsafeAudit),
        Box::new(wire::WireInvariants),
    ]
}

/// Is the finding at `line` (0-indexed) suppressed for `rule`?
pub(crate) fn suppressed(file: &SourceFile, scope: &RuleScope, rule: &str, line: usize) -> bool {
    if file.in_test(line) {
        return true;
    }
    if scope.allows_file(&file.rel_path) {
        return true;
    }
    if let Some(f) = file.enclosing_fn(line) {
        if scope.allows_fn(&file.rel_path, &f.name) {
            return true;
        }
    }
    file.waived(rule, line)
}

fn is_ident_char(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Does `line` contain `token` at identifier boundaries? Boundaries are
/// only enforced on token ends that are themselves identifier characters,
/// so `.unwrap()` matches as a substring while `HashMap` will not match
/// inside `MyHashMapExt`.
pub(crate) fn token_hit(line: &str, token: &str) -> bool {
    let lb = line.as_bytes();
    let tb = token.as_bytes();
    if tb.is_empty() {
        return false;
    }
    let mut from = 0usize;
    while let Some(p) = line[from..].find(token) {
        let at = from + p;
        let before_ok =
            !is_ident_char(tb[0]) || at == 0 || !is_ident_char(lb[at - 1]);
        let end = at + tb.len();
        let after_ok =
            !is_ident_char(tb[tb.len() - 1]) || end >= lb.len() || !is_ident_char(lb[end]);
        if before_ok && after_ok {
            return true;
        }
        from = at + 1;
    }
    false
}

/// Per-line token scan shared by determinism / panic-safety / hot-path:
/// emit one diagnostic per (line, banned token).
pub(crate) fn scan_tokens(
    files: &[SourceFile],
    scope: &RuleScope,
    rule: &'static str,
    banned: &[(&str, &str)],
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for file in files {
        if !scope.covers(&file.rel_path) {
            continue;
        }
        for (ln, line) in file.lines.iter().enumerate() {
            for (token, why) in banned {
                if token_hit(line, token) && !suppressed(file, scope, rule, ln) {
                    out.push(Diagnostic::new(
                        &file.rel_path,
                        ln,
                        rule,
                        format!("`{token}`: {why}"),
                    ));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_boundaries() {
        assert!(token_hit("let m: HashMap<u32, u32> = x;", "HashMap"));
        assert!(!token_hit("let m: MyHashMapExt = x;", "HashMap"));
        assert!(token_hit("v.unwrap();", ".unwrap()"));
        assert!(!token_hit("v.unwrap_or(0);", ".unwrap()"));
        assert!(token_hit("x.expect(\"\");", ".expect("));
        assert!(!token_hit("x.expect_err(\"\");", ".expect("));
        assert!(token_hit("std::time::Instant::now()", "Instant"));
    }
}
