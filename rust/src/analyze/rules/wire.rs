//! Rule `wire`: cross-file consistency of the CSG2 framing constants.
//!
//! * `HEADER_BYTES` is defined exactly once, in `compress/wire.rs`; every
//!   consumer imports it — a second definition or a bare `44` literal in
//!   compress/fl code can silently diverge from the real header size.
//! * The header layout doc table in `compress/wire.rs` (`offset size
//!   field` rows) must be cumulative and end at `HEADER_BYTES`, with a
//!   4-byte `magic` row — the table *is* the format spec the simulator's
//!   byte accounting relies on.
//! * Magic byte strings (`CSG2`/`CSG1`) appear only in `compress/wire.rs`;
//!   consumers use `wire::MAGIC`.

use super::super::config::RuleScope;
use super::super::lexer::SourceFile;
use super::super::report::Diagnostic;
use super::{suppressed, token_hit, Rule};

const RULE: &str = "wire";
const CANON: &str = "compress/wire.rs";

pub struct WireInvariants;

impl Rule for WireInvariants {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, files: &[SourceFile], scope: &RuleScope) -> Vec<Diagnostic> {
        let mut out = Vec::new();

        // Collect every `const HEADER_BYTES` definition in scope.
        let mut defs: Vec<(&SourceFile, usize, Option<usize>)> = Vec::new();
        for file in files {
            if !scope.covers(&file.rel_path) {
                continue;
            }
            for (ln, line) in file.lines.iter().enumerate() {
                if file.in_test(ln) {
                    continue;
                }
                if token_hit(line, "HEADER_BYTES") && token_hit(line, "const") {
                    defs.push((file, ln, parse_const_value(line)));
                }
            }
        }

        let canonical = defs.iter().find(|(f, _, _)| f.rel_path == CANON).cloned();
        for (file, ln, _) in &defs {
            if file.rel_path != CANON && !suppressed(file, scope, RULE, *ln) {
                out.push(Diagnostic::new(
                    &file.rel_path,
                    *ln,
                    RULE,
                    format!(
                        "duplicate HEADER_BYTES definition; the single source of truth is {CANON}"
                    ),
                ));
            }
        }

        let wire_file = files.iter().find(|f| f.rel_path == CANON);
        if let Some(wf) = wire_file {
            match canonical {
                None => out.push(Diagnostic::new(
                    CANON,
                    0,
                    RULE,
                    "missing `const HEADER_BYTES` definition".to_string(),
                )),
                Some((_, def_line, value)) => {
                    let header = match value {
                        Some(v) => v,
                        None => {
                            out.push(Diagnostic::new(
                                CANON,
                                def_line,
                                RULE,
                                "HEADER_BYTES must be a literal integer".to_string(),
                            ));
                            return out;
                        }
                    };
                    check_doc_table(wf, header, &mut out);
                    check_bare_literals(files, scope, header, def_line, &mut out);
                }
            }
        }

        // Magic strings outside the canonical file.
        for file in files {
            if !scope.covers(&file.rel_path) || file.rel_path == CANON {
                continue;
            }
            for (ln, val) in &file.literals {
                if (val.contains("CSG2") || val.contains("CSG1"))
                    && !file.in_test(*ln)
                    && !suppressed(file, scope, RULE, *ln)
                {
                    out.push(Diagnostic::new(
                        &file.rel_path,
                        *ln,
                        RULE,
                        format!("magic bytes hardcoded outside {CANON}; use wire::MAGIC"),
                    ));
                }
            }
        }
        out
    }
}

/// Parse `... = <int>;` off a const definition line.
fn parse_const_value(line: &str) -> Option<usize> {
    let rhs = line.split('=').nth(1)?;
    rhs.trim().trim_end_matches(';').trim().parse().ok()
}

/// Validate the `offset size field` doc table in the canonical file:
/// consecutive comment rows whose first token is an integer, sizes
/// cumulative, terminated by a `<HEADER> .. payload` row.
fn check_doc_table(wf: &SourceFile, header: usize, out: &mut Vec<Diagnostic>) {
    let mut expected = 0usize;
    let mut rows = 0usize;
    let mut terminated = false;
    for (ln, c) in wf.comments.iter().enumerate() {
        let text = c.trim_start_matches(['!', '/']).trim();
        let mut toks = text.split_whitespace();
        let first = toks.next().unwrap_or("");
        let offset: usize = match first.parse() {
            Ok(v) => v,
            Err(_) => continue,
        };
        let size = toks.next().unwrap_or("");
        let field = toks.next().unwrap_or("");
        if size == ".." {
            rows += 1;
            terminated = true;
            if offset != header {
                out.push(Diagnostic::new(
                    &wf.rel_path,
                    ln,
                    RULE,
                    format!(
                        "header doc table ends at offset {offset} but HEADER_BYTES = {header}"
                    ),
                ));
            }
            break;
        }
        let size: usize = match size.parse() {
            Ok(v) => v,
            Err(_) => continue, // not a table row (e.g. prose starting with a number)
        };
        rows += 1;
        if rows == 1 {
            expected = offset;
        }
        if offset != expected {
            out.push(Diagnostic::new(
                &wf.rel_path,
                ln,
                RULE,
                format!(
                    "header doc table row `{field}` at offset {offset}, expected {expected} (rows must be cumulative)"
                ),
            ));
            expected = offset; // resync so one slip yields one diagnostic
        }
        if field == "magic" && size != 4 {
            out.push(Diagnostic::new(
                &wf.rel_path,
                ln,
                RULE,
                format!("magic field is {size} bytes in the doc table; the magic is 4 bytes"),
            ));
        }
        expected += size;
    }
    if rows < 3 || !terminated {
        out.push(Diagnostic::new(
            &wf.rel_path,
            0,
            RULE,
            "header layout doc table (`offset size field` rows ending in `<N> .. payload`) not found"
                .to_string(),
        ));
    }
}

/// Flag bare `<HEADER_BYTES>` integer literals in covered non-test code.
fn check_bare_literals(
    files: &[SourceFile],
    scope: &RuleScope,
    header: usize,
    def_line: usize,
    out: &mut Vec<Diagnostic>,
) {
    let needle = header.to_string();
    for file in files {
        if !scope.covers(&file.rel_path) {
            continue;
        }
        for (ln, line) in file.lines.iter().enumerate() {
            if file.rel_path == CANON && ln == def_line {
                continue;
            }
            if bare_number_hit(line, &needle) && !suppressed(file, scope, RULE, ln) {
                out.push(Diagnostic::new(
                    &file.rel_path,
                    ln,
                    RULE,
                    format!("bare `{needle}` header-size literal; use wire::HEADER_BYTES"),
                ));
            }
        }
    }
}

/// Like `token_hit` but for integers: neighbours may not be identifier
/// characters *or* `.` (so `44` does not match inside `44.0` or `0.44`).
fn bare_number_hit(line: &str, needle: &str) -> bool {
    let lb = line.as_bytes();
    let mut from = 0usize;
    while let Some(p) = line[from..].find(needle) {
        let at = from + p;
        let end = at + needle.len();
        let num_ish = |b: u8| b.is_ascii_alphanumeric() || b == b'_' || b == b'.';
        let before_ok = at == 0 || !num_ish(lb[at - 1]);
        let after_ok = end >= lb.len() || !num_ish(lb[end]);
        if before_ok && after_ok {
            return true;
        }
        from = at + 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_value_and_number_hits() {
        assert_eq!(parse_const_value("pub const HEADER_BYTES: usize = 44;"), Some(44));
        assert_eq!(parse_const_value("const X: usize = wire::HEADER_BYTES;"), None);
        assert!(bare_number_hit("let x = 44 + n;", "44"));
        assert!(!bare_number_hit("let x = 44.0;", "44"));
        assert!(!bare_number_hit("let x = 0x44;", "44"));
        assert!(!bare_number_hit("let x = 442;", "44"));
        assert!(!bare_number_hit("let x = a44;", "44"));
    }
}
