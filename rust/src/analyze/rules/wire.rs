//! Rule `wire`: cross-file consistency of the CSG2 framing constants.
//!
//! * `HEADER_BYTES` is defined exactly once, in `compress/wire.rs`; every
//!   consumer imports it — a second definition or a bare `44` literal in
//!   compress/fl code can silently diverge from the real header size.
//! * The header layout doc table in `compress/wire.rs` (`offset size
//!   field` rows) must be cumulative and end at `HEADER_BYTES`, with a
//!   4-byte `magic` row — the table *is* the format spec the simulator's
//!   byte accounting relies on.
//! * Magic byte strings (`CSG2`/`CSG1`) appear only in `compress/wire.rs`;
//!   consumers use `wire::MAGIC`.
//! * Every `const FLAG_*` bit in `compress/wire.rs` is OR-ed into
//!   `KNOWN_FLAGS` (else the unknown-flag guard rejects frames that
//!   legitimately set it) and consumed on the decode path
//!   (`flags & FLAG_X`) — a written-but-never-read bit is dead weight the
//!   format spec silently carries forever.

use super::super::config::RuleScope;
use super::super::lexer::SourceFile;
use super::super::report::Diagnostic;
use super::{suppressed, token_hit, Rule};

const RULE: &str = "wire";
const CANON: &str = "compress/wire.rs";

pub struct WireInvariants;

impl Rule for WireInvariants {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, files: &[SourceFile], scope: &RuleScope) -> Vec<Diagnostic> {
        let mut out = Vec::new();

        // Collect every `const HEADER_BYTES` definition in scope.
        let mut defs: Vec<(&SourceFile, usize, Option<usize>)> = Vec::new();
        for file in files {
            if !scope.covers(&file.rel_path) {
                continue;
            }
            for (ln, line) in file.lines.iter().enumerate() {
                if file.in_test(ln) {
                    continue;
                }
                if token_hit(line, "HEADER_BYTES") && token_hit(line, "const") {
                    defs.push((file, ln, parse_const_value(line)));
                }
            }
        }

        let canonical = defs.iter().find(|(f, _, _)| f.rel_path == CANON).cloned();
        for (file, ln, _) in &defs {
            if file.rel_path != CANON && !suppressed(file, scope, RULE, *ln) {
                out.push(Diagnostic::new(
                    &file.rel_path,
                    *ln,
                    RULE,
                    format!(
                        "duplicate HEADER_BYTES definition; the single source of truth is {CANON}"
                    ),
                ));
            }
        }

        let wire_file = files.iter().find(|f| f.rel_path == CANON);
        if let Some(wf) = wire_file {
            match canonical {
                None => out.push(Diagnostic::new(
                    CANON,
                    0,
                    RULE,
                    "missing `const HEADER_BYTES` definition".to_string(),
                )),
                Some((_, def_line, value)) => {
                    let header = match value {
                        Some(v) => v,
                        None => {
                            out.push(Diagnostic::new(
                                CANON,
                                def_line,
                                RULE,
                                "HEADER_BYTES must be a literal integer".to_string(),
                            ));
                            return out;
                        }
                    };
                    check_doc_table(wf, header, &mut out);
                    check_bare_literals(files, scope, header, def_line, &mut out);
                }
            }
            check_flag_exhaustiveness(wf, scope, &mut out);
        }

        // Magic strings outside the canonical file.
        for file in files {
            if !scope.covers(&file.rel_path) || file.rel_path == CANON {
                continue;
            }
            for (ln, val) in &file.literals {
                if (val.contains("CSG2") || val.contains("CSG1"))
                    && !file.in_test(*ln)
                    && !suppressed(file, scope, RULE, *ln)
                {
                    out.push(Diagnostic::new(
                        &file.rel_path,
                        *ln,
                        RULE,
                        format!("magic bytes hardcoded outside {CANON}; use wire::MAGIC"),
                    ));
                }
            }
        }
        out
    }
}

/// Parse `... = <int>;` off a const definition line.
fn parse_const_value(line: &str) -> Option<usize> {
    let rhs = line.split('=').nth(1)?;
    rhs.trim().trim_end_matches(';').trim().parse().ok()
}

/// Validate the `offset size field` doc table in the canonical file:
/// consecutive comment rows whose first token is an integer, sizes
/// cumulative, terminated by a `<HEADER> .. payload` row.
fn check_doc_table(wf: &SourceFile, header: usize, out: &mut Vec<Diagnostic>) {
    let mut expected = 0usize;
    let mut rows = 0usize;
    let mut terminated = false;
    for (ln, c) in wf.comments.iter().enumerate() {
        let text = c.trim_start_matches(['!', '/']).trim();
        let mut toks = text.split_whitespace();
        let first = toks.next().unwrap_or("");
        let offset: usize = match first.parse() {
            Ok(v) => v,
            Err(_) => continue,
        };
        let size = toks.next().unwrap_or("");
        let field = toks.next().unwrap_or("");
        if size == ".." {
            rows += 1;
            terminated = true;
            if offset != header {
                out.push(Diagnostic::new(
                    &wf.rel_path,
                    ln,
                    RULE,
                    format!(
                        "header doc table ends at offset {offset} but HEADER_BYTES = {header}"
                    ),
                ));
            }
            break;
        }
        let size: usize = match size.parse() {
            Ok(v) => v,
            Err(_) => continue, // not a table row (e.g. prose starting with a number)
        };
        rows += 1;
        if rows == 1 {
            expected = offset;
        }
        if offset != expected {
            out.push(Diagnostic::new(
                &wf.rel_path,
                ln,
                RULE,
                format!(
                    "header doc table row `{field}` at offset {offset}, expected {expected} (rows must be cumulative)"
                ),
            ));
            expected = offset; // resync so one slip yields one diagnostic
        }
        if field == "magic" && size != 4 {
            out.push(Diagnostic::new(
                &wf.rel_path,
                ln,
                RULE,
                format!("magic field is {size} bytes in the doc table; the magic is 4 bytes"),
            ));
        }
        expected += size;
    }
    if rows < 3 || !terminated {
        out.push(Diagnostic::new(
            &wf.rel_path,
            0,
            RULE,
            "header layout doc table (`offset size field` rows ending in `<N> .. payload`) not found"
                .to_string(),
        ));
    }
}

/// Flag bare `<HEADER_BYTES>` integer literals in covered non-test code.
fn check_bare_literals(
    files: &[SourceFile],
    scope: &RuleScope,
    header: usize,
    def_line: usize,
    out: &mut Vec<Diagnostic>,
) {
    let needle = header.to_string();
    for file in files {
        if !scope.covers(&file.rel_path) {
            continue;
        }
        for (ln, line) in file.lines.iter().enumerate() {
            if file.rel_path == CANON && ln == def_line {
                continue;
            }
            if bare_number_hit(line, &needle) && !suppressed(file, scope, RULE, ln) {
                out.push(Diagnostic::new(
                    &file.rel_path,
                    ln,
                    RULE,
                    format!("bare `{needle}` header-size literal; use wire::HEADER_BYTES"),
                ));
            }
        }
    }
}

/// `FLAG_*` exhaustiveness in the canonical file (see module docs).
fn check_flag_exhaustiveness(wf: &SourceFile, scope: &RuleScope, out: &mut Vec<Diagnostic>) {
    let mut flags: Vec<(String, usize)> = Vec::new();
    let mut known_line: Option<usize> = None;
    for (ln, line) in wf.lines.iter().enumerate() {
        if wf.in_test(ln) || !token_hit(line, "const") {
            continue;
        }
        if token_hit(line, "KNOWN_FLAGS") {
            known_line = Some(ln);
            continue;
        }
        let Some(p) = line.find("FLAG_") else {
            continue;
        };
        let b = line.as_bytes();
        if p > 0 && (b[p - 1].is_ascii_alphanumeric() || b[p - 1] == b'_') {
            continue; // e.g. `const OTHER_FLAG_BITS`
        }
        let mut e = p;
        while e < b.len() && (b[e].is_ascii_alphanumeric() || b[e] == b'_') {
            e += 1;
        }
        flags.push((line[p..e].to_string(), ln));
    }
    if flags.is_empty() {
        return;
    }
    let Some(kl) = known_line else {
        out.push(Diagnostic::new(
            &wf.rel_path,
            flags[0].1,
            RULE,
            "FLAG_* bits defined but no `const KNOWN_FLAGS` mask found".to_string(),
        ));
        return;
    };
    for (name, ln) in &flags {
        if suppressed(wf, scope, RULE, *ln) {
            continue;
        }
        if !token_hit(&wf.lines[kl], name) {
            out.push(Diagnostic::new(
                &wf.rel_path,
                *ln,
                RULE,
                format!(
                    "`{name}` is not OR-ed into KNOWN_FLAGS; the unknown-flag guard rejects frames that set it"
                ),
            ));
        }
        let consumed = wf
            .lines
            .iter()
            .enumerate()
            .any(|(l2, line)| !wf.in_test(l2) && amp_consumed(line, name));
        if !consumed {
            out.push(Diagnostic::new(
                &wf.rel_path,
                *ln,
                RULE,
                format!(
                    "`{name}` is never consumed on the decode path (`flags & {name}`); the bit is written but ignored"
                ),
            ));
        }
    }
}

/// Does `line` read `name` through a `&` mask (`flags & NAME`, `& !NAME`
/// does not count because that is the KNOWN_FLAGS guard, not a per-bit
/// read — but NAME there is KNOWN_FLAGS anyway)?
fn amp_consumed(line: &str, name: &str) -> bool {
    let lb = line.as_bytes();
    let mut from = 0usize;
    while let Some(p) = line[from..].find(name) {
        let at = from + p;
        let end = at + name.len();
        from = at + 1;
        if end < lb.len() && (lb[end].is_ascii_alphanumeric() || lb[end] == b'_') {
            continue; // FLAG_A inside FLAG_AB
        }
        let mut i = at;
        while i > 0 && lb[i - 1] == b' ' {
            i -= 1;
        }
        if i > 0 && lb[i - 1] == b'&' {
            return true;
        }
    }
    false
}

/// Like `token_hit` but for integers: neighbours may not be identifier
/// characters *or* `.` (so `44` does not match inside `44.0` or `0.44`).
fn bare_number_hit(line: &str, needle: &str) -> bool {
    let lb = line.as_bytes();
    let mut from = 0usize;
    while let Some(p) = line[from..].find(needle) {
        let at = from + p;
        let end = at + needle.len();
        let num_ish = |b: u8| b.is_ascii_alphanumeric() || b == b'_' || b == b'.';
        let before_ok = at == 0 || !num_ish(lb[at - 1]);
        let after_ok = end >= lb.len() || !num_ish(lb[end]);
        if before_ok && after_ok {
            return true;
        }
        from = at + 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_value_and_number_hits() {
        assert_eq!(parse_const_value("pub const HEADER_BYTES: usize = 44;"), Some(44));
        assert_eq!(parse_const_value("const X: usize = wire::HEADER_BYTES;"), None);
        assert!(bare_number_hit("let x = 44 + n;", "44"));
        assert!(!bare_number_hit("let x = 44.0;", "44"));
        assert!(!bare_number_hit("let x = 0x44;", "44"));
        assert!(!bare_number_hit("let x = 442;", "44"));
        assert!(!bare_number_hit("let x = a44;", "44"));
    }

    #[test]
    fn amp_consumption() {
        assert!(amp_consumed("rotated: flags & FLAG_ROTATED != 0,", "FLAG_ROTATED"));
        assert!(amp_consumed("if flags &FLAG_X != 0 {", "FLAG_X"));
        assert!(!amp_consumed("flags |= FLAG_ROTATED;", "FLAG_ROTATED"));
        assert!(!amp_consumed("const FLAG_ROTATED: u8 = 1 << 1;", "FLAG_ROTATED"));
        assert!(!amp_consumed("flags & FLAG_AB != 0", "FLAG_A"));
    }
}
