//! Rule `panic_propagation`: interprocedural panic-safety. From the
//! manifest's boundary entry points (`entries = ["fl/server.rs::Server::
//! ingest", "compress/wire.rs::deserialize*"]`) walk the whole-tree call
//! graph; **no reachable fn in any file** may use a panicking combinator,
//! and bare indexing is additionally banned in reachable fns of the files
//! listed under `paths` (files whose indexing is provably in-range by
//! construction stay out of `paths` — the scoping decision is written in
//! `analyze.toml`). Every diagnostic carries the offending call chain
//! from the entry, rendered in both the text and JSON reports.

use super::super::callgraph::CallGraph;
use super::super::config::RuleScope;
use super::super::lexer::SourceFile;
use super::super::report::Diagnostic;
use super::super::symbols::SymbolTable;
use super::{panic_safety, suppressed, token_hit, Rule};

const RULE: &str = "panic_propagation";

pub struct PanicPropagation;

impl Rule for PanicPropagation {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, files: &[SourceFile], scope: &RuleScope) -> Vec<Diagnostic> {
        if scope.entries.is_empty() {
            return Vec::new();
        }
        let syms = SymbolTable::build(files);
        let graph = CallGraph::build(&syms);
        let mut entry_ids: Vec<usize> = scope
            .entries
            .iter()
            .flat_map(|pat| syms.resolve_entry(pat))
            .collect();
        entry_ids.sort_unstable();
        entry_ids.dedup();
        let reach = graph.reach(&entry_ids);

        let mut out = Vec::new();
        for (id, f) in syms.fns.iter().enumerate() {
            if f.in_test || !reach.contains(id) {
                continue;
            }
            let file = &files[f.file];
            let chain: Vec<String> = reach.chain(id).iter().map(|&x| syms.label(x)).collect();
            let entry = chain.first().cloned().unwrap_or_default();
            let check_indexing = scope.covers(&file.rel_path);
            for ln in f.decl..=f.end.min(file.lines.len().saturating_sub(1)) {
                // Lines of nested fns belong to their own (also reachable
                // or not) symbol, not to this one.
                if file.enclosing_fn(ln).map(|e| e.decl) != Some(f.decl) {
                    continue;
                }
                let line = &file.lines[ln];
                for (token, why) in panic_safety::BANNED {
                    if token_hit(line, token) && !suppressed(file, scope, RULE, ln) {
                        out.push(
                            Diagnostic::new(
                                &file.rel_path,
                                ln,
                                RULE,
                                format!("`{token}` reachable from boundary entry `{entry}`: {why}"),
                            )
                            .with_chain(chain.clone()),
                        );
                    }
                }
                if check_indexing
                    && panic_safety::has_bare_indexing(line)
                    && !suppressed(file, scope, RULE, ln)
                {
                    out.push(
                        Diagnostic::new(
                            &file.rel_path,
                            ln,
                            RULE,
                            format!(
                                "bare indexing reachable from boundary entry `{entry}`; use `.get(..)`"
                            ),
                        )
                        .with_chain(chain.clone()),
                    );
                }
            }
        }
        out
    }
}
