//! Rule `panic_safety`: untrusted-input paths (CSG2 frame decode, server
//! ingest) must refuse hostile bytes with `Ingest::Malformed` / `Err`,
//! never a panic. Panicking combinators and bare slice indexing are banned.

use super::super::config::RuleScope;
use super::super::lexer::SourceFile;
use super::super::report::Diagnostic;
use super::{scan_tokens, suppressed, Rule};

/// Shared with `panic_propagation`, which bans the same combinators in
/// any fn reachable from a boundary entry point.
pub(crate) const BANNED: &[(&str, &str)] = &[
    (".unwrap()", "panics on None/Err; propagate with `?` or match"),
    (".expect(", "panics on None/Err; propagate with `?` or match"),
    ("panic!", "hostile input must map to Malformed/Err, not a panic"),
    ("unreachable!", "hostile input can reach it; return an error"),
    ("todo!", "unfinished path reachable from untrusted input"),
    ("unimplemented!", "unfinished path reachable from untrusted input"),
];

pub struct PanicSafety;

impl Rule for PanicSafety {
    fn name(&self) -> &'static str {
        "panic_safety"
    }

    fn check(&self, files: &[SourceFile], scope: &RuleScope) -> Vec<Diagnostic> {
        let mut out = scan_tokens(files, scope, self.name(), BANNED);
        // Bare indexing `x[i]` / `x[a..b]` panics out of bounds; require
        // `.get(..)`. `vec![..]` (macro), `#[..]` (attribute), and type
        // positions like `&[u8]` are excluded by the preceding character.
        for file in files {
            if !scope.covers(&file.rel_path) {
                continue;
            }
            for (ln, line) in file.lines.iter().enumerate() {
                if has_bare_indexing(line) && !suppressed(file, scope, self.name(), ln) {
                    out.push(Diagnostic::new(
                        &file.rel_path,
                        ln,
                        self.name(),
                        "bare slice/array indexing panics out of bounds; use `.get(..)`"
                            .to_string(),
                    ));
                }
            }
        }
        out
    }
}

/// `[` directly preceded by an identifier character, `)` or `]` is an
/// index expression (Rust style never puts a space there).
pub(crate) fn has_bare_indexing(line: &str) -> bool {
    let b = line.as_bytes();
    for (i, &c) in b.iter().enumerate() {
        if c != b'[' || i == 0 {
            continue;
        }
        let p = b[i - 1];
        if p.is_ascii_alphanumeric() || p == b'_' || p == b')' || p == b']' {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_heuristic() {
        assert!(has_bare_indexing("let x = bytes[0];"));
        assert!(has_bare_indexing("acc[off..off + v.len()].fill(0.0);"));
        assert!(has_bare_indexing("f(x)[1]"));
        assert!(!has_bare_indexing("let v = vec![0u8; 4];"));
        assert!(!has_bare_indexing("#[derive(Debug)]"));
        assert!(!has_bare_indexing("fn f(x: &[u8]) -> [u8; 4] {"));
        assert!(!has_bare_indexing("let [a, b] = pair;"));
    }
}
