//! A small Rust lexer for the static analyzer — not a parser.
//!
//! One pass over the raw text *scrubs* everything that is not code
//! (comments, string/byte-string/char literals — including raw strings
//! with arbitrary `#` fences and nested block comments) to spaces while
//! collecting the comment text and literal values per line. A second pass
//! over the scrubbed text recovers just enough structure for the rules:
//!
//! * `fn` spans (declaration line → closing brace), innermost-wins;
//! * `#[cfg(test)]` / `#[test]` item spans (rules skip test code);
//! * `unsafe` sites (blocks, `unsafe fn`, `unsafe impl`, `unsafe trait`);
//! * waiver comments — `// analyze: allow(<rule>[, <rule>…]): reason` —
//!   resolved to a line range: the same line for a trailing comment, the
//!   whole next `fn` when the comment sits directly above a declaration,
//!   otherwise just the next code line.
//!
//! The lexer is deliberately heuristic where full parsing would be needed
//! (lifetimes vs char literals, attribute extents); the heuristics are
//! pinned by fixtures in `tests/analyze_fixtures/lexer/`.

/// One `fn` item span (0-indexed lines, inclusive).
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    /// Line of the `fn` keyword.
    pub decl: usize,
    /// Line of the opening brace.
    pub open: usize,
    /// Line of the matching closing brace.
    pub end: usize,
}

/// What kind of `unsafe` appeared at a site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnsafeKind {
    Block,
    Fn,
    Impl,
    Trait,
}

impl UnsafeKind {
    pub fn label(self) -> &'static str {
        match self {
            UnsafeKind::Block => "unsafe block",
            UnsafeKind::Fn => "unsafe fn",
            UnsafeKind::Impl => "unsafe impl",
            UnsafeKind::Trait => "unsafe trait",
        }
    }
}

/// One `unsafe` occurrence (0-indexed line of the `unsafe` keyword).
#[derive(Debug, Clone)]
pub struct UnsafeSite {
    pub line: usize,
    pub kind: UnsafeKind,
}

/// A resolved `analyze: allow(...)` waiver: `rule` is waived on lines
/// `start..=end` (0-indexed).
#[derive(Debug, Clone)]
pub struct Waiver {
    pub rule: String,
    pub start: usize,
    pub end: usize,
}

/// A lexed source file: scrubbed code plus the structure the rules need.
#[derive(Debug, Clone)]
pub struct SourceFile {
    /// Path relative to the analysis root, always `/`-separated.
    pub rel_path: String,
    /// Code with comments and literal bodies blanked to spaces, one entry
    /// per source line.
    pub lines: Vec<String>,
    /// Comment text per line (empty if the line carries no comment; the
    /// leading `//`, `/*` etc. delimiters are stripped, inner `!`/`/` doc
    /// markers kept).
    pub comments: Vec<String>,
    /// String / byte-string literal contents: `(line, raw_inner_text)`.
    pub literals: Vec<(usize, String)>,
    pub fns: Vec<FnSpan>,
    /// Inclusive line spans of `#[cfg(test)]` / `#[test]` items.
    pub test_spans: Vec<(usize, usize)>,
    pub unsafes: Vec<UnsafeSite>,
    pub waivers: Vec<Waiver>,
}

impl SourceFile {
    /// Is `line` inside a `#[cfg(test)]` / `#[test]` item?
    pub fn in_test(&self, line: usize) -> bool {
        self.test_spans.iter().any(|&(s, e)| s <= line && line <= e)
    }

    /// The innermost `fn` span containing `line`, if any.
    pub fn enclosing_fn(&self, line: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| f.decl <= line && line <= f.end)
            .min_by_key(|f| f.end - f.decl)
    }

    /// Is `rule` waived at `line` by an `analyze: allow(...)` comment?
    pub fn waived(&self, rule: &str, line: usize) -> bool {
        self.waivers
            .iter()
            .any(|w| w.rule == rule && w.start <= line && line <= w.end)
    }

    /// Does the `unsafe` site at `line` carry an adjacent `// SAFETY:`
    /// comment? Adjacent = on the site line itself, on the line directly
    /// below (first line of a block body), or in the contiguous
    /// comment/attribute block immediately above.
    pub fn has_safety_comment(&self, line: usize) -> bool {
        let marked = |l: usize| {
            self.comments
                .get(l)
                .map(|c| c.contains("SAFETY"))
                .unwrap_or(false)
        };
        if marked(line) || marked(line + 1) {
            return true;
        }
        let mut l = line;
        while l > 0 {
            l -= 1;
            if marked(l) {
                return true;
            }
            let has_comment = self.comments.get(l).map(|c| !c.is_empty()).unwrap_or(false);
            let code = self.lines.get(l).map(String::as_str).unwrap_or("").trim();
            let attr_only = code.starts_with('#') || code.is_empty();
            if !(has_comment || attr_only) {
                break; // a real code line ends the adjacency window
            }
        }
        false
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lex a file's text. `rel_path` is carried through to diagnostics.
pub fn lex_str(rel_path: &str, text: &str) -> SourceFile {
    let (scrub, comments, literals) = scrub_pass(text);
    let lines: Vec<String> = split_keep_count(&scrub);
    let comment_lines = comments;
    let (fns, test_spans, unsafes) = structure_pass(&lines);
    let waivers = resolve_waivers(&lines, &comment_lines, &fns);
    SourceFile {
        rel_path: rel_path.to_string(),
        lines,
        comments: comment_lines,
        literals,
        fns,
        test_spans,
        unsafes,
        waivers,
    }
}

/// Split scrubbed text into lines, preserving the count (including a
/// trailing line without a newline).
fn split_keep_count(s: &str) -> Vec<String> {
    let mut out: Vec<String> = s.split('\n').map(|l| l.to_string()).collect();
    // `split` yields a final empty element for text ending in '\n'; that
    // phantom line has no source counterpart only when the file ends
    // exactly at the newline — keep it, it is harmless (all-blank).
    if out.is_empty() {
        out.push(String::new());
    }
    out
}

// ---------------------------------------------------------------------------
// Pass 1: scrub comments and literals.
// ---------------------------------------------------------------------------

type ScrubOut = (String, Vec<String>, Vec<(usize, String)>);

fn scrub_pass(text: &str) -> ScrubOut {
    let b = text.as_bytes();
    let n = b.len();
    let mut out: Vec<u8> = Vec::with_capacity(n);
    let mut comments: Vec<String> = vec![String::new()];
    let mut literals: Vec<(usize, String)> = Vec::new();
    let mut line = 0usize;
    let mut i = 0usize;

    // Helpers operate on the captured locals via macros to keep borrows
    // simple in this hand-rolled state machine.
    macro_rules! newline {
        () => {{
            out.push(b'\n');
            line += 1;
            comments.push(String::new());
            i += 1;
        }};
    }
    macro_rules! blank {
        () => {{
            out.push(b' ');
            i += 1;
        }};
    }
    macro_rules! comment_byte {
        ($byte:expr) => {{
            comments[line].push($byte as char);
        }};
    }

    while i < n {
        let c = b[i];
        if c == b'\n' {
            newline!();
            continue;
        }
        // Line comment.
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            blank!();
            blank!();
            while i < n && b[i] != b'\n' {
                comment_byte!(b[i]);
                blank!();
            }
            continue;
        }
        // Block comment (nested).
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            blank!();
            blank!();
            let mut depth = 1usize;
            while i < n && depth > 0 {
                if b[i] == b'\n' {
                    newline!();
                } else if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    blank!();
                    blank!();
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    blank!();
                    blank!();
                } else {
                    comment_byte!(b[i]);
                    blank!();
                }
            }
            continue;
        }
        let prev_ident = out.last().copied().map(is_ident).unwrap_or(false);
        // Raw strings: r"..." / r#"..."# / br#"..."# (b consumed below).
        if (c == b'r' || (c == b'b' && i + 1 < n && b[i + 1] == b'r')) && !prev_ident {
            let pfx = if c == b'b' { 2 } else { 1 };
            let mut h = 0usize;
            while i + pfx + h < n && b[i + pfx + h] == b'#' {
                h += 1;
            }
            if i + pfx + h < n && b[i + pfx + h] == b'"' {
                for _ in 0..pfx + h + 1 {
                    blank!();
                }
                let start_line = line;
                let mut val = String::new();
                loop {
                    if i >= n {
                        break; // unterminated — tolerate
                    }
                    if b[i] == b'"' && i + h < n - 0 && b[i + 1..].len() >= h
                        && b[i + 1..i + 1 + h].iter().all(|&x| x == b'#')
                    {
                        for _ in 0..h + 1 {
                            blank!();
                        }
                        break;
                    }
                    if b[i] == b'\n' {
                        val.push('\n');
                        newline!();
                    } else {
                        val.push(b[i] as char);
                        blank!();
                    }
                }
                literals.push((start_line, val));
                continue;
            }
            // Not a raw string: fall through, copy as code.
        }
        // Plain / byte strings.
        if c == b'"' || (c == b'b' && i + 1 < n && b[i + 1] == b'"' && !prev_ident) {
            if c == b'b' {
                blank!();
            }
            blank!(); // opening quote
            let start_line = line;
            let mut val = String::new();
            while i < n {
                match b[i] {
                    b'"' => {
                        blank!();
                        break;
                    }
                    b'\\' => {
                        val.push('\\');
                        blank!();
                        if i < n && b[i] != b'\n' {
                            val.push(b[i] as char);
                            blank!();
                        }
                    }
                    b'\n' => {
                        val.push('\n');
                        newline!();
                    }
                    x => {
                        val.push(x as char);
                        blank!();
                    }
                }
            }
            literals.push((start_line, val));
            continue;
        }
        // Byte char b'x'.
        if c == b'b' && i + 1 < n && b[i + 1] == b'\'' && !prev_ident {
            blank!();
            blank!();
            if i < n && b[i] == b'\\' {
                blank!();
                if i < n {
                    blank!();
                }
            } else if i < n {
                blank!();
            }
            if i < n && b[i] == b'\'' {
                blank!();
            }
            continue;
        }
        // Char literal vs lifetime/label.
        if c == b'\'' {
            if i + 1 < n && b[i + 1] == b'\\' {
                // Escaped char literal: blank the escaped character first
                // (it may itself be a quote, as in '\''), then scan to the
                // closing quote.
                blank!(); // '
                blank!(); // backslash
                if i < n && b[i] != b'\n' {
                    blank!();
                }
                while i < n && b[i] != b'\'' && b[i] != b'\n' {
                    blank!();
                }
                if i < n && b[i] == b'\'' {
                    blank!();
                }
                continue;
            }
            // One UTF-8 scalar, then a quote ⇒ char literal; else lifetime.
            let clen = if i + 1 < n {
                utf8_len(b[i + 1])
            } else {
                1
            };
            if i + 1 + clen < n && b[i + 1 + clen] == b'\'' {
                for _ in 0..clen + 2 {
                    blank!();
                }
            } else {
                out.push(b'\'');
                i += 1;
            }
            continue;
        }
        out.push(c);
        i += 1;
    }

    // UTF-8 multibyte code bytes were copied verbatim; the scrub buffer is
    // valid UTF-8 because literals/comments (the only places we blank
    // mid-char) are blanked whole.
    let scrub = String::from_utf8_lossy(&out).into_owned();
    (scrub, comments, literals)
}

fn utf8_len(lead: u8) -> usize {
    match lead {
        x if x < 0x80 => 1,
        x if x >= 0xF0 => 4,
        x if x >= 0xE0 => 3,
        x if x >= 0xC0 => 2,
        _ => 1,
    }
}

// ---------------------------------------------------------------------------
// Pass 2: structure (fn spans, test spans, unsafe sites).
// ---------------------------------------------------------------------------

struct Open {
    kind: OpenKind,
    line: usize,
    test_marker: bool,
}

enum OpenKind {
    Plain,
    Fn(usize),
}

type StructureOut = (Vec<FnSpan>, Vec<(usize, usize)>, Vec<UnsafeSite>);

fn structure_pass(lines: &[String]) -> StructureOut {
    let mut fns: Vec<FnSpan> = Vec::new();
    let mut test_spans: Vec<(usize, usize)> = Vec::new();
    let mut unsafes: Vec<UnsafeSite> = Vec::new();

    let mut stack: Vec<Open> = Vec::new();
    let mut pending_test = false;
    let mut pending_fn: Option<(String, usize)> = None;
    let mut pending_unsafe: Option<usize> = None;
    let mut expecting_fn_name = false;
    // `;` only clears pending markers outside ( ) / [ ] groups, so
    // signatures like `fn f(a: [u8; 4])` survive to their brace.
    let mut group_depth = 0i64;
    // Multi-line attribute accumulation.
    let mut attr_depth = 0i64;
    let mut attr_text = String::new();

    for (ln, l) in lines.iter().enumerate() {
        let bytes = l.as_bytes();
        let mut i = 0usize;
        while i < bytes.len() {
            let c = bytes[i];
            if attr_depth > 0 {
                match c {
                    b'[' => attr_depth += 1,
                    b']' => {
                        attr_depth -= 1;
                        if attr_depth == 0 {
                            if attr_is_test(&attr_text) {
                                pending_test = true;
                            }
                            attr_text.clear();
                        }
                    }
                    x => attr_text.push(x as char),
                }
                i += 1;
                continue;
            }
            match c {
                b'#' => {
                    // `#[` / `#![` attribute start; anything else is code.
                    let mut j = i + 1;
                    if j < bytes.len() && bytes[j] == b'!' {
                        j += 1;
                    }
                    if j < bytes.len() && bytes[j] == b'[' {
                        attr_depth = 1;
                        attr_text.clear();
                        i = j + 1;
                    } else {
                        i += 1;
                    }
                }
                b'(' | b'[' => {
                    group_depth += 1;
                    i += 1;
                }
                b')' | b']' => {
                    group_depth -= 1;
                    i += 1;
                }
                b';' => {
                    if group_depth <= 0 {
                        pending_fn = None;
                        pending_test = false;
                        pending_unsafe = None;
                        expecting_fn_name = false;
                    }
                    i += 1;
                }
                b'{' => {
                    if let Some(ul) = pending_unsafe.take() {
                        unsafes.push(UnsafeSite {
                            line: ul,
                            kind: UnsafeKind::Block,
                        });
                        stack.push(Open {
                            kind: OpenKind::Plain,
                            line: ln,
                            test_marker: false,
                        });
                    } else if let Some((name, decl)) = pending_fn.take() {
                        let idx = fns.len();
                        fns.push(FnSpan {
                            name,
                            decl,
                            open: ln,
                            end: ln,
                        });
                        stack.push(Open {
                            kind: OpenKind::Fn(idx),
                            line: ln.min(decl),
                            test_marker: std::mem::take(&mut pending_test),
                        });
                    } else {
                        stack.push(Open {
                            kind: OpenKind::Plain,
                            line: ln,
                            test_marker: std::mem::take(&mut pending_test),
                        });
                    }
                    i += 1;
                }
                b'}' => {
                    if let Some(open) = stack.pop() {
                        if let OpenKind::Fn(idx) = open.kind {
                            fns[idx].end = ln;
                        }
                        if open.test_marker {
                            test_spans.push((open.line, ln));
                        }
                    }
                    i += 1;
                }
                x if is_ident(x) => {
                    let start = i;
                    while i < bytes.len() && is_ident(bytes[i]) {
                        i += 1;
                    }
                    let word = &l[start..i];
                    // Raw identifier `r#word`: the scrub pass leaves the
                    // prefix in place (it is code, not a raw string), so
                    // a word directly preceded by `r#` must never be
                    // treated as a keyword — `r#fn` is a name, not `fn`.
                    let raw_ident = start >= 2
                        && bytes[start - 1] == b'#'
                        && bytes[start - 2] == b'r'
                        && (start == 2 || !is_ident(bytes[start - 3]));
                    if expecting_fn_name {
                        if word == "r" && i < bytes.len() && bytes[i] == b'#' {
                            continue; // `fn r#name` — the name follows the prefix
                        }
                        let name = if raw_ident {
                            format!("r#{word}")
                        } else {
                            word.to_string()
                        };
                        pending_fn = Some((name, ln));
                        expecting_fn_name = false;
                        continue;
                    }
                    if raw_ident {
                        continue;
                    }
                    match word {
                        "fn" => {
                            if let Some(ul) = pending_unsafe.take() {
                                unsafes.push(UnsafeSite {
                                    line: ul,
                                    kind: UnsafeKind::Fn,
                                });
                            }
                            expecting_fn_name = true;
                        }
                        "unsafe" => pending_unsafe = Some(ln),
                        "impl" => {
                            if let Some(ul) = pending_unsafe.take() {
                                unsafes.push(UnsafeSite {
                                    line: ul,
                                    kind: UnsafeKind::Impl,
                                });
                            }
                        }
                        "trait" => {
                            if let Some(ul) = pending_unsafe.take() {
                                unsafes.push(UnsafeSite {
                                    line: ul,
                                    kind: UnsafeKind::Trait,
                                });
                            }
                        }
                        _ => {}
                    }
                }
                _ => i += 1,
            }
        }
    }
    // A `fn(` type (no name) leaves expecting_fn_name dangling across the
    // `(`-group; the `(` path above does not clear it, but the next word
    // would be misread. Guard: clear at line ends via the loop epilogue —
    // handled implicitly since `(` is not a word; acceptable for this
    // codebase's style (function-pointer types are rare and never precede
    // an item brace).
    (fns, test_spans, unsafes)
}

/// Does the attribute text mark a test item? Token-boundary match of
/// `test` anywhere inside (covers `test`, `cfg(test)`, `cfg(all(test, …))`).
fn attr_is_test(attr: &str) -> bool {
    let b = attr.as_bytes();
    let mut from = 0usize;
    while let Some(p) = attr[from..].find("test") {
        let at = from + p;
        let before_ok = at == 0 || !is_ident(b[at - 1]);
        let after = at + 4;
        let after_ok = after >= b.len() || !is_ident(b[after]);
        if before_ok && after_ok {
            return true;
        }
        from = at + 4;
    }
    false
}

// ---------------------------------------------------------------------------
// Waivers.
// ---------------------------------------------------------------------------

fn resolve_waivers(lines: &[String], comments: &[String], fns: &[FnSpan]) -> Vec<Waiver> {
    let mut out = Vec::new();
    for (ln, c) in comments.iter().enumerate() {
        for rule in parse_allow(c) {
            let has_code = lines
                .get(ln)
                .map(|l| !l.trim().is_empty())
                .unwrap_or(false);
            let (start, end) = if has_code {
                (ln, ln) // trailing comment: this line only
            } else {
                // Find the next code line, skipping attribute-only lines.
                let mut l2 = ln + 1;
                while l2 < lines.len() {
                    let code = lines[l2].trim();
                    if code.is_empty() || code.starts_with('#') {
                        l2 += 1;
                    } else {
                        break;
                    }
                }
                match fns.iter().find(|f| f.decl == l2) {
                    Some(f) => (f.decl, f.end), // annotation above a fn
                    None => (l2, l2),           // next code line only
                }
            };
            out.push(Waiver { rule, start, end });
        }
    }
    out
}

/// Extract rule names from `analyze: allow(a, b)` / `analyze::allow(a)`.
fn parse_allow(comment: &str) -> Vec<String> {
    let mut rules = Vec::new();
    for marker in ["analyze: allow(", "analyze::allow("] {
        let mut from = 0usize;
        while let Some(p) = comment[from..].find(marker) {
            let open = from + p + marker.len();
            if let Some(close) = comment[open..].find(')') {
                for r in comment[open..open + close].split(',') {
                    let r = r.trim();
                    if !r.is_empty() {
                        rules.push(r.to_string());
                    }
                }
                from = open + close;
            } else {
                break;
            }
        }
    }
    rules
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrubs_comments_and_strings() {
        let f = lex_str(
            "x.rs",
            "let a = \"HashMap\"; // HashMap in comment\nlet b = 1; /* HashMap */ let c = 2;\n",
        );
        assert!(!f.lines[0].contains("HashMap"));
        assert!(!f.lines[1].contains("HashMap"));
        assert!(f.comments[0].contains("HashMap"));
        assert!(f.comments[1].contains("HashMap"));
        assert_eq!(f.literals.len(), 1);
        assert_eq!(f.literals[0].1, "HashMap");
        // Code around the literals survives.
        assert!(f.lines[0].contains("let a ="));
        assert!(f.lines[1].contains("let c = 2;"));
    }

    #[test]
    fn fn_spans_and_enclosing() {
        let src = "fn outer() {\n    let x = 1;\n    fn inner() {\n        let y = 2;\n    }\n}\n";
        let f = lex_str("x.rs", src);
        assert_eq!(f.fns.len(), 2);
        let inner = f.enclosing_fn(3).unwrap();
        assert_eq!(inner.name, "inner");
        let outer = f.enclosing_fn(1).unwrap();
        assert_eq!(outer.name, "outer");
    }

    #[test]
    fn signature_brackets_do_not_eat_the_fn() {
        // The `;` inside `[u8; 4]` must not clear the pending fn.
        let f = lex_str("x.rs", "fn takes(a: [u8; 4]) -> u8 {\n    a.len() as u8\n}\n");
        assert_eq!(f.fns.len(), 1);
        assert_eq!(f.fns[0].name, "takes");
        assert_eq!(f.fns[0].end, 2);
    }

    #[test]
    fn unsafe_sites_and_safety_adjacency() {
        let src = "\
// SAFETY: documented argument.
unsafe impl Send for X {}
unsafe impl Sync for X {}
fn f() {
    unsafe { danger() } // SAFETY: same-line note
    unsafe {
        undocumented();
    }
}
";
        let f = lex_str("x.rs", src);
        let kinds: Vec<UnsafeKind> = f.unsafes.iter().map(|u| u.kind).collect();
        assert_eq!(
            kinds,
            vec![
                UnsafeKind::Impl,
                UnsafeKind::Impl,
                UnsafeKind::Block,
                UnsafeKind::Block
            ]
        );
        assert!(f.has_safety_comment(f.unsafes[0].line));
        // Second impl: nearest line above is code (the first impl) — not
        // covered by the comment two lines up.
        assert!(!f.has_safety_comment(f.unsafes[1].line));
        assert!(f.has_safety_comment(f.unsafes[2].line));
        assert!(!f.has_safety_comment(f.unsafes[3].line));
    }

    #[test]
    fn raw_identifiers_are_not_keywords() {
        let src = "\
fn caller() {
    let r#fn = 1;
    let r#unsafe = r#fn + 1;
    r#unsafe
}
fn r#match(x: u32) -> u32 {
    x
}
";
        let f = lex_str("x.rs", src);
        let names: Vec<&str> = f.fns.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["caller", "r#match"]);
        assert!(f.unsafes.is_empty(), "r#unsafe is a name, not a keyword");
        assert_eq!(f.fns[0].end, 4);
    }

    #[test]
    fn waiver_scopes() {
        let src = "\
// analyze: allow(hotpath): reference path
fn reference() {
    x.acos();
}
fn other() {
    // analyze: allow(hotpath): LUT build
    y.cos();
    z.cos();
}
let q = 1; // analyze: allow(determinism)
";
        let f = lex_str("x.rs", src);
        assert!(f.waived("hotpath", 2), "fn-level waiver covers the body");
        assert!(f.waived("hotpath", 6), "line waiver covers the next line");
        assert!(!f.waived("hotpath", 7), "line waiver is one line only");
        assert!(f.waived("determinism", 9), "trailing waiver covers its line");
        assert!(!f.waived("panic_safety", 2), "other rules unaffected");
    }
}
