//! Hand-rolled parser for the `analyze.toml` manifest (a strict TOML
//! subset — same zero-dependency policy as `util/json`).
//!
//! ```toml
//! # one section per rule family
//! [determinism]
//! paths = ["fl/server.rs", "sim/*"]   # exact path or `dir/*` prefix, or "*"
//! allow = ["fl/runner.rs::wall_clock"] # `file` or `file::fn` escape hatch
//! ```
//!
//! Only string arrays are supported, `#` starts a comment outside strings,
//! arrays may span lines. Unknown keys or sections are hard errors so the
//! manifest cannot silently drift from the rule set. Interprocedural
//! rules additionally take `entries = ["file.rs::Type::fn", "f.rs::pre*"]`
//! — the call-graph boundary entry points they walk from.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Scope + allowlist for one rule family.
#[derive(Debug, Clone, Default)]
pub struct RuleScope {
    /// Path patterns this rule applies to: exact relative path, `dir/*`
    /// prefix, or `*` for everything.
    pub paths: Vec<String>,
    /// Allowlist entries: `relative/path.rs` (whole file) or
    /// `relative/path.rs::fn_name` (one function).
    pub allow: Vec<String>,
    /// Call-graph boundary entry points for interprocedural rules:
    /// `file.rs::fn`, `file.rs::Type::fn`, with an optional trailing `*`
    /// suffix glob on the fn name.
    pub entries: Vec<String>,
}

impl RuleScope {
    pub fn covers(&self, rel: &str) -> bool {
        self.paths.iter().any(|p| match_pattern(p, rel))
    }

    pub fn allows_file(&self, rel: &str) -> bool {
        self.allow.iter().any(|a| a == rel)
    }

    pub fn allows_fn(&self, rel: &str, fn_name: &str) -> bool {
        self.allow
            .iter()
            .any(|a| a.len() == rel.len() + 2 + fn_name.len()
                && a.starts_with(rel)
                && a.ends_with(fn_name)
                && a[rel.len()..].starts_with("::"))
    }
}

fn match_pattern(pat: &str, rel: &str) -> bool {
    if pat == "*" || pat == rel {
        return true;
    }
    if let Some(prefix) = pat.strip_suffix('*') {
        return rel.starts_with(prefix);
    }
    false
}

/// The parsed manifest: one [`RuleScope`] per rule family.
#[derive(Debug, Clone, Default)]
pub struct AnalyzeConfig {
    pub rules: BTreeMap<String, RuleScope>,
}

impl AnalyzeConfig {
    /// Parse manifest text. `known_rules` pins the accepted section names;
    /// every known rule must have a section and no section may be unknown.
    pub fn parse(text: &str, known_rules: &[&str]) -> Result<AnalyzeConfig> {
        let mut cfg = AnalyzeConfig::default();
        let mut section: Option<String> = None;

        let mut lines = text.lines().enumerate().peekable();
        while let Some((ln, raw)) = lines.next() {
            let line = strip_comment(raw);
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("manifest line {}: unterminated section", ln + 1))?
                    .trim()
                    .to_string();
                if name.is_empty() || !name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_')
                {
                    bail!("manifest line {}: bad section name '{}'", ln + 1, name);
                }
                if !known_rules.contains(&name.as_str()) {
                    bail!(
                        "manifest line {}: unknown rule section '{}' (known: {})",
                        ln + 1,
                        name,
                        known_rules.join(", ")
                    );
                }
                if cfg.rules.contains_key(&name) {
                    bail!("manifest line {}: duplicate section '{}'", ln + 1, name);
                }
                cfg.rules.insert(name.clone(), RuleScope::default());
                section = Some(name);
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("manifest line {}: expected `key = [...]`", ln + 1))?;
            let key = key.trim();
            let sec = section
                .clone()
                .ok_or_else(|| anyhow!("manifest line {}: key before any [section]", ln + 1))?;
            let mut value = value.trim().to_string();
            // Arrays may span lines: keep appending until brackets balance.
            while bracket_balance(&value) > 0 {
                let (ln2, next) = lines
                    .next()
                    .ok_or_else(|| anyhow!("manifest line {}: unterminated array", ln + 1))?;
                let _ = ln2;
                value.push(' ');
                value.push_str(strip_comment(next).trim());
            }
            let items = parse_string_array(&value)
                .map_err(|e| anyhow!("manifest line {}: {}", ln + 1, e))?;
            let scope = cfg.rules.get_mut(&sec).expect("section just inserted");
            match key {
                "paths" => scope.paths = items,
                "allow" => scope.allow = items,
                "entries" => scope.entries = items,
                other => bail!(
                    "manifest line {}: unknown key '{}' (expected paths/allow/entries)",
                    ln + 1,
                    other
                ),
            }
        }

        for rule in known_rules {
            let scope = cfg
                .rules
                .get(*rule)
                .ok_or_else(|| anyhow!("manifest is missing a [{}] section", rule))?;
            if scope.paths.is_empty() {
                bail!("manifest section [{}] has no `paths` entry", rule);
            }
        }
        Ok(cfg)
    }
}

/// Cut a trailing `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let b = line.as_bytes();
    let mut in_str = false;
    for (i, &c) in b.iter().enumerate() {
        match c {
            b'"' => in_str = !in_str,
            b'#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Net `[`/`]` balance outside strings.
fn bracket_balance(s: &str) -> i64 {
    let mut bal = 0i64;
    let mut in_str = false;
    for c in s.bytes() {
        match c {
            b'"' => in_str = !in_str,
            b'[' if !in_str => bal += 1,
            b']' if !in_str => bal -= 1,
            _ => {}
        }
    }
    bal
}

/// Parse `["a", "b"]` into its string items.
fn parse_string_array(s: &str) -> Result<Vec<String>, String> {
    let s = s.trim();
    let inner = s
        .strip_prefix('[')
        .and_then(|r| r.strip_suffix(']'))
        .ok_or_else(|| format!("expected a [\"...\"] array, got `{s}`"))?;
    let mut items = Vec::new();
    let b = inner.as_bytes();
    let mut i = 0usize;
    loop {
        while i < b.len() && (b[i] == b' ' || b[i] == b'\t' || b[i] == b',') {
            i += 1;
        }
        if i >= b.len() {
            break;
        }
        if b[i] != b'"' {
            return Err(format!("expected a quoted string in array, got `{inner}`"));
        }
        i += 1;
        let start = i;
        while i < b.len() && b[i] != b'"' {
            i += 1;
        }
        if i >= b.len() {
            return Err("unterminated string in array".to_string());
        }
        items.push(inner[start..i].to_string());
        i += 1;
    }
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    const KNOWN: &[&str] = &["determinism", "panic_safety"];

    #[test]
    fn parses_sections_and_arrays() {
        let cfg = AnalyzeConfig::parse(
            "# top comment\n[determinism]\npaths = [\"fl/server.rs\", \"sim/*\"] # inline\nallow = []\n\n[panic_safety]\npaths = [\n  \"compress/wire.rs\",\n]\nallow = [\"fl/server.rs::debug_dump\"]\n",
            KNOWN,
        )
        .unwrap();
        let det = &cfg.rules["determinism"];
        assert!(det.covers("fl/server.rs"));
        assert!(det.covers("sim/clock.rs"));
        assert!(!det.covers("fl/runner.rs"));
        let ps = &cfg.rules["panic_safety"];
        assert!(ps.covers("compress/wire.rs"));
        assert!(ps.allows_fn("fl/server.rs", "debug_dump"));
        assert!(!ps.allows_fn("fl/server.rs", "ingest"));
        assert!(!ps.allows_file("fl/server.rs"));
    }

    #[test]
    fn parses_entries() {
        let cfg = AnalyzeConfig::parse(
            "[determinism]\npaths=[\"*\"]\nentries = [\"fl/server.rs::Server::ingest\", \"compress/wire.rs::deserialize*\"]\n[panic_safety]\npaths=[\"*\"]\n",
            KNOWN,
        )
        .unwrap();
        assert_eq!(cfg.rules["determinism"].entries.len(), 2);
        assert!(cfg.rules["panic_safety"].entries.is_empty());
    }

    #[test]
    fn rejects_unknown_sections_keys_and_missing_rules() {
        assert!(AnalyzeConfig::parse("[mystery]\npaths=[\"*\"]\n", KNOWN).is_err());
        assert!(AnalyzeConfig::parse("[determinism]\nbad = [\"*\"]\n", KNOWN).is_err());
        // missing panic_safety section
        assert!(AnalyzeConfig::parse("[determinism]\npaths=[\"*\"]\n", KNOWN).is_err());
    }

    #[test]
    fn wildcard_scope() {
        let cfg = AnalyzeConfig::parse(
            "[determinism]\npaths=[\"*\"]\n[panic_safety]\npaths=[\"*\"]\n",
            KNOWN,
        )
        .unwrap();
        assert!(cfg.rules["determinism"].covers("anything/at/all.rs"));
    }
}
