//! Whole-tree symbol table: every `fn` definition with its `impl`/`trait`
//! owner, every call site (plain / method / path-qualified), and every
//! loop span — the cross-file layer the call-graph rules build on.
//!
//! Resolution is conservative in exactly one direction: an *ambiguous*
//! callee resolves to every plausible in-tree definition (a method call
//! fans out to every impl fn of that name — over-approximation keeps
//! reachability sound), but a qualified path whose receiver names no
//! in-tree type, module file, or module directory resolves to nothing:
//! `std::` / external calls must not drag unrelated same-named fns into
//! the graph. Known blind spots, accepted as heuristics: turbofish call
//! syntax (`f::<T>()`), `<T as Trait>::f()` casts, and braces inside
//! `for`-loop patterns; none occur on the audited paths and the fixtures
//! pin the shapes that do.

use std::collections::BTreeMap;

use super::lexer::{FnSpan, SourceFile};

/// A function definition with its file and `impl`/`trait` owner.
#[derive(Debug, Clone)]
pub struct FnSym {
    /// Index into the lexed file set.
    pub file: usize,
    pub name: String,
    /// `impl`/`trait` block type name, when defined inside one.
    pub owner: Option<String>,
    /// 0-indexed lines (declaration, opening brace, closing brace).
    pub decl: usize,
    pub open: usize,
    pub end: usize,
    pub in_test: bool,
}

/// How a call site names its callee.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CallKind {
    /// `foo(...)` — unqualified.
    Plain,
    /// `.foo(...)` — method syntax.
    Method,
    /// `Recv::foo(...)` — the path segment directly before the name.
    Qualified(String),
}

/// One call site inside a known fn body.
#[derive(Debug, Clone)]
pub struct CallSite {
    /// [`FnSym`] id of the calling fn.
    pub caller: usize,
    pub kind: CallKind,
    pub name: String,
    /// 0-indexed line of the call.
    pub line: usize,
}

/// An inclusive `for`/`while`/`loop` body span inside a fn.
#[derive(Debug, Clone)]
pub struct LoopSpan {
    /// [`FnSym`] id of the enclosing fn.
    pub fn_id: usize,
    pub start: usize,
    pub end: usize,
}

/// The whole-tree table plus the indices resolution needs.
pub struct SymbolTable {
    pub fns: Vec<FnSym>,
    pub calls: Vec<CallSite>,
    pub loops: Vec<LoopSpan>,
    /// `rel_path` per file index (mirrors the lexed file order).
    pub paths: Vec<String>,
    file_fns: Vec<Vec<usize>>,
    free_by_name: BTreeMap<String, Vec<usize>>,
    method_by_name: BTreeMap<String, Vec<usize>>,
    owned: BTreeMap<(String, String), Vec<usize>>,
    /// File stem (`bitpack` for `compress/bitpack.rs`, parent dir for
    /// `mod.rs`) → file indices.
    stem_files: BTreeMap<String, Vec<usize>>,
    /// Any path directory component → file indices underneath it.
    dir_files: BTreeMap<String, Vec<usize>>,
}

impl SymbolTable {
    pub fn build(files: &[SourceFile]) -> SymbolTable {
        let mut fns: Vec<FnSym> = Vec::new();
        let mut loops: Vec<LoopSpan> = Vec::new();
        let mut file_fns: Vec<Vec<usize>> = vec![Vec::new(); files.len()];
        let mut paths: Vec<String> = Vec::with_capacity(files.len());

        for (fi, file) in files.iter().enumerate() {
            paths.push(file.rel_path.clone());
            let (owners, loop_lines) = scan_file(file);
            for span in &file.fns {
                let owner = owners
                    .iter()
                    .filter(|o| o.start <= span.decl && span.end <= o.end)
                    .max_by_key(|o| o.start)
                    .map(|o| o.name.clone());
                let id = fns.len();
                file_fns[fi].push(id);
                fns.push(FnSym {
                    file: fi,
                    name: span.name.clone(),
                    owner,
                    decl: span.decl,
                    open: span.open,
                    end: span.end,
                    in_test: file.in_test(span.decl) || file.in_test(span.open),
                });
            }
            for (start, end) in loop_lines {
                if let Some(fid) = innermost_fn(&file.fns, &file_fns[fi], start) {
                    loops.push(LoopSpan { fn_id: fid, start, end });
                }
            }
        }

        let mut free_by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut method_by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut owned: BTreeMap<(String, String), Vec<usize>> = BTreeMap::new();
        for (id, f) in fns.iter().enumerate() {
            match &f.owner {
                None => free_by_name.entry(f.name.clone()).or_default().push(id),
                Some(o) => {
                    method_by_name.entry(f.name.clone()).or_default().push(id);
                    owned.entry((o.clone(), f.name.clone())).or_default().push(id);
                }
            }
        }

        let mut stem_files: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut dir_files: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (fi, rel) in paths.iter().enumerate() {
            let comps: Vec<&str> = rel.split('/').collect();
            let fname = comps.last().copied().unwrap_or("");
            let stem = fname.strip_suffix(".rs").unwrap_or(fname);
            if stem == "mod" {
                if comps.len() >= 2 {
                    stem_files
                        .entry(comps[comps.len() - 2].to_string())
                        .or_default()
                        .push(fi);
                }
            } else {
                stem_files.entry(stem.to_string()).or_default().push(fi);
            }
            for dir in &comps[..comps.len().saturating_sub(1)] {
                dir_files.entry(dir.to_string()).or_default().push(fi);
            }
        }

        let mut calls = Vec::new();
        for (fi, file) in files.iter().enumerate() {
            extract_calls(file, &file_fns[fi], &fns, &mut calls);
        }

        SymbolTable {
            fns,
            calls,
            loops,
            paths,
            file_fns,
            free_by_name,
            method_by_name,
            owned,
            stem_files,
            dir_files,
        }
    }

    /// Conservative candidate set for a call site (test fns excluded).
    pub fn resolve(&self, call: &CallSite) -> Vec<usize> {
        let caller_file = self.fns[call.caller].file;
        let same_file = |out: &mut Vec<usize>| {
            out.extend(
                self.file_fns[caller_file]
                    .iter()
                    .copied()
                    .filter(|&id| self.fns[id].name == call.name),
            );
        };
        let mut out: Vec<usize> = Vec::new();
        match &call.kind {
            CallKind::Plain => {
                if let Some(v) = self.free_by_name.get(&call.name) {
                    out.extend_from_slice(v);
                }
                same_file(&mut out);
            }
            CallKind::Method => {
                if let Some(v) = self.method_by_name.get(&call.name) {
                    out.extend_from_slice(v);
                }
            }
            CallKind::Qualified(recv) => {
                if recv == "Self" || recv == "self" {
                    same_file(&mut out);
                } else if let Some(v) = self.owned.get(&(recv.clone(), call.name.clone())) {
                    out.extend_from_slice(v);
                } else {
                    let mut from_files = |files: &[usize], out: &mut Vec<usize>| {
                        for &fi in files {
                            out.extend(self.file_fns[fi].iter().copied().filter(|&id| {
                                self.fns[id].name == call.name && self.fns[id].owner.is_none()
                            }));
                        }
                    };
                    if let Some(fs) = self.stem_files.get(recv) {
                        from_files(fs, &mut out);
                    }
                    if out.is_empty() {
                        if let Some(fs) = self.dir_files.get(recv) {
                            from_files(fs, &mut out);
                        }
                    }
                    // No in-tree match ⇒ external (std etc.): no edge.
                }
            }
        }
        out.retain(|&id| !self.fns[id].in_test);
        out.sort_unstable();
        out.dedup();
        out
    }

    /// `file.rs::Owner::name` / `file.rs::name` display label.
    pub fn label(&self, id: usize) -> String {
        let f = &self.fns[id];
        match &f.owner {
            Some(o) => format!("{}::{}::{}", self.paths[f.file], o, f.name),
            None => format!("{}::{}", self.paths[f.file], f.name),
        }
    }

    /// Resolve an `entries` pattern — `file.rs::fn`, `file.rs::Type::fn`,
    /// with an optional trailing `*` suffix glob on the fn name — to fn
    /// ids (non-test only).
    pub fn resolve_entry(&self, pattern: &str) -> Vec<usize> {
        let Some(rs) = pattern.find(".rs::") else {
            return Vec::new();
        };
        let path = &pattern[..rs + 3];
        let rest: Vec<&str> = pattern[rs + 5..].split("::").collect();
        let (owner, name_pat) = match rest.as_slice() {
            [name] => (None, *name),
            [owner, name] => (Some(*owner), *name),
            _ => return Vec::new(),
        };
        let name_match = |name: &str| match name_pat.strip_suffix('*') {
            Some(prefix) => name.starts_with(prefix),
            None => name == name_pat,
        };
        self.fns
            .iter()
            .enumerate()
            .filter(|(_, f)| {
                !f.in_test
                    && self.paths[f.file] == path
                    && name_match(&f.name)
                    && owner.is_none_or(|o| f.owner.as_deref() == Some(o))
            })
            .map(|(id, _)| id)
            .collect()
    }
}

/// Innermost fn (by global id) whose span contains `line`.
fn innermost_fn(spans: &[FnSpan], ids: &[usize], line: usize) -> Option<usize> {
    spans
        .iter()
        .zip(ids)
        .filter(|(s, _)| s.decl <= line && line <= s.end)
        .min_by_key(|(s, _)| s.end - s.decl)
        .map(|(_, &id)| id)
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

struct OwnerSpan {
    start: usize,
    end: usize,
    name: String,
}

enum Mark {
    Plain,
    Owner(String, usize),
    Loop(usize),
}

/// One brace-matched scan per file: `impl`/`trait` block spans (with the
/// declared type name) and loop body spans.
fn scan_file(file: &SourceFile) -> (Vec<OwnerSpan>, Vec<(usize, usize)>) {
    let mut owners: Vec<OwnerSpan> = Vec::new();
    let mut loop_spans: Vec<(usize, usize)> = Vec::new();
    let mut stack: Vec<Mark> = Vec::new();
    // `impl`/`trait` header text being captured (until its `{`).
    let mut header: Option<(usize, String)> = None;
    let mut pending_loop: Option<usize> = None;
    // A top-level `fn` is being declared: `-> impl Trait {` must not
    // open an owner block.
    let mut after_fn = false;

    for (ln, l) in file.lines.iter().enumerate() {
        let bytes = l.as_bytes();
        let mut i = 0usize;
        while i < bytes.len() {
            let c = bytes[i];
            if is_ident(c) {
                let start = i;
                while i < bytes.len() && is_ident(bytes[i]) {
                    i += 1;
                }
                let word = &l[start..i];
                // `r#loop` / `r#fn` are raw identifiers, not keywords.
                let raw_ident = start >= 2
                    && bytes[start - 1] == b'#'
                    && bytes[start - 2] == b'r'
                    && (start == 2 || !is_ident(bytes[start - 3]));
                match if raw_ident { "" } else { word } {
                    "impl" | "trait" if stack.is_empty() && !after_fn && header.is_none() => {
                        header = Some((ln, String::new()));
                        continue;
                    }
                    "fn" => {
                        after_fn = stack.is_empty();
                    }
                    "for" if header.is_none() => {
                        // `for<'a>` HRTB bounds are not loops.
                        let mut j = i;
                        while j < bytes.len() && bytes[j] == b' ' {
                            j += 1;
                        }
                        if j >= bytes.len() || bytes[j] != b'<' {
                            pending_loop = Some(ln);
                        }
                    }
                    "while" | "loop" if header.is_none() => pending_loop = Some(ln),
                    _ => {}
                }
                if let Some((_, text)) = header.as_mut() {
                    text.push(' ');
                    text.push_str(word);
                }
                continue;
            }
            match c {
                b'{' => {
                    if let Some((start, text)) = header.take() {
                        match owner_name(&text) {
                            Some(name) => stack.push(Mark::Owner(name, start)),
                            None => stack.push(Mark::Plain),
                        }
                    } else if let Some(start) = pending_loop.take() {
                        stack.push(Mark::Loop(start));
                    } else {
                        stack.push(Mark::Plain);
                    }
                    after_fn = false;
                }
                b'}' => match stack.pop() {
                    Some(Mark::Owner(name, start)) => {
                        owners.push(OwnerSpan { start, end: ln, name })
                    }
                    Some(Mark::Loop(start)) => loop_spans.push((start, ln)),
                    _ => {}
                },
                b';' => {
                    pending_loop = None;
                    if stack.is_empty() {
                        after_fn = false;
                        header = None;
                    }
                }
                _ => {
                    if let Some((_, text)) = header.as_mut() {
                        text.push(c as char);
                    }
                }
            }
            i += 1;
        }
    }
    (owners, loop_spans)
}

/// Extract the type name an `impl`/`trait` header declares: the last
/// segment of the first path after `for` (`impl Trait for Type`), else
/// the first non-lifetime identifier outside generics.
fn owner_name(header: &str) -> Option<String> {
    let b = header.as_bytes();
    let mut depth = 0i32;
    let mut i = 0usize;
    let mut name: Option<&str> = None;
    let mut have_path = false;
    while i < b.len() {
        let c = b[i];
        match c {
            b'<' => depth += 1,
            b'>' => depth -= 1,
            b'\'' => {
                i += 1;
                while i < b.len() && is_ident(b[i]) {
                    i += 1;
                }
                continue;
            }
            _ if is_ident(c) => {
                let start = i;
                while i < b.len() && is_ident(b[i]) {
                    i += 1;
                }
                let word = &header[start..i];
                if depth != 0 {
                    continue;
                }
                if word == "for" {
                    name = None;
                    have_path = false;
                    continue;
                }
                if word == "where" {
                    break;
                }
                if matches!(word, "unsafe" | "const" | "dyn" | "mut" | "pub")
                    || b[start].is_ascii_digit()
                {
                    continue;
                }
                let continues =
                    start >= 2 && b[start - 1] == b':' && b[start - 2] == b':' && have_path;
                if continues || !have_path {
                    name = Some(word);
                    have_path = true;
                }
                continue;
            }
            _ => {}
        }
        i += 1;
    }
    name.map(|s| s.to_string())
}

const CALL_KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "loop", "return", "in", "move", "as", "let", "else", "fn",
    "impl", "use", "pub", "mut", "ref", "break", "continue", "unsafe", "where", "dyn", "crate",
    "super", "self", "Self", "struct", "enum", "trait", "type", "const", "static", "async",
    "await", "box", "yield",
];

/// Scan one file's scrubbed lines for `ident(` call sites and classify
/// them; only calls inside a known fn body are recorded.
fn extract_calls(file: &SourceFile, ids: &[usize], fns: &[FnSym], out: &mut Vec<CallSite>) {
    for (ln, l) in file.lines.iter().enumerate() {
        let b = l.as_bytes();
        let mut i = 0usize;
        let mut last_word = "";
        while i < b.len() {
            if !is_ident(b[i]) {
                i += 1;
                continue;
            }
            let start = i;
            while i < b.len() && is_ident(b[i]) {
                i += 1;
            }
            let word = &l[start..i];
            let mut j = i;
            while j < b.len() && b[j] == b' ' {
                j += 1;
            }
            let is_call = j < b.len() && b[j] == b'(';
            let is_macro = i < b.len() && b[i] == b'!';
            if is_call
                && !is_macro
                && !b[start].is_ascii_digit()
                && !CALL_KEYWORDS.contains(&word)
                && last_word != "fn"
            {
                let kind = if start > 0 && b[start - 1] == b'.' {
                    Some(CallKind::Method)
                } else if start >= 2 && b[start - 1] == b':' && b[start - 2] == b':' {
                    let e = start - 2;
                    let mut s = e;
                    while s > 0 && is_ident(b[s - 1]) {
                        s -= 1;
                    }
                    if s < e {
                        Some(CallKind::Qualified(l[s..e].to_string()))
                    } else {
                        None // `<T as Trait>::f(` / leading `::` — external
                    }
                } else if start > 0 && b[start - 1] == b'#' {
                    None // raw identifier `r#word(` — a name, not a call we track
                } else {
                    Some(CallKind::Plain)
                };
                if let Some(kind) = kind {
                    if let Some(caller) = innermost_global(fns, ids, ln) {
                        out.push(CallSite {
                            caller,
                            kind,
                            name: word.to_string(),
                            line: ln,
                        });
                    }
                }
            }
            last_word = word;
        }
    }
}

/// Innermost fn id containing `line`, over the global fn set restricted
/// to this file's ids.
fn innermost_global(fns: &[FnSym], ids: &[usize], line: usize) -> Option<usize> {
    ids.iter()
        .copied()
        .filter(|&id| fns[id].decl <= line && line <= fns[id].end)
        .min_by_key(|&id| fns[id].end - fns[id].decl)
}

/// Brace-match from the first `{` at or after (`line`, `col`) in scrubbed
/// lines; returns (open line, close line) inclusive.
pub(crate) fn brace_span(
    lines: &[String],
    line: usize,
    col: usize,
) -> Option<(usize, usize)> {
    let mut depth = 0i64;
    let mut open_line: Option<usize> = None;
    for (ln, l) in lines.iter().enumerate().skip(line) {
        let from = if ln == line { col.min(l.len()) } else { 0 };
        for &c in &l.as_bytes()[from..] {
            match c {
                b'{' => {
                    if open_line.is_none() {
                        open_line = Some(ln);
                    }
                    depth += 1;
                }
                b'}' => {
                    if open_line.is_some() {
                        depth -= 1;
                        if depth == 0 {
                            return Some((open_line.unwrap_or(line), ln));
                        }
                    }
                }
                _ => {}
            }
        }
    }
    None
}

/// Paren-match from the first `(` at or after (`line`, `col`); returns
/// (open line, close line) inclusive.
pub(crate) fn paren_span(
    lines: &[String],
    line: usize,
    col: usize,
) -> Option<(usize, usize)> {
    let mut depth = 0i64;
    let mut open_line: Option<usize> = None;
    for (ln, l) in lines.iter().enumerate().skip(line) {
        let from = if ln == line { col.min(l.len()) } else { 0 };
        for &c in &l.as_bytes()[from..] {
            match c {
                b'(' => {
                    if open_line.is_none() {
                        open_line = Some(ln);
                    }
                    depth += 1;
                }
                b')' => {
                    if open_line.is_some() {
                        depth -= 1;
                        if depth == 0 {
                            return Some((open_line.unwrap_or(line), ln));
                        }
                    }
                }
                _ => {}
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::lexer::lex_str;

    fn table(sources: &[(&str, &str)]) -> SymbolTable {
        let files: Vec<SourceFile> =
            sources.iter().map(|(p, t)| lex_str(p, t)).collect();
        SymbolTable::build(&files)
    }

    #[test]
    fn owners_and_generics() {
        let t = table(&[(
            "a/reader.rs",
            "impl<'a> BitReader<'a> {\n    fn read(&mut self) -> u8 { 0 }\n}\nimpl std::fmt::Display for Thing {\n    fn fmt(&self) -> u8 { 1 }\n}\ntrait Codec {\n    fn id(&self) -> u8 {\n        9\n    }\n}\n",
        )]);
        let owners: Vec<(String, Option<String>)> = t
            .fns
            .iter()
            .map(|f| (f.name.clone(), f.owner.clone()))
            .collect();
        assert_eq!(
            owners,
            vec![
                ("read".into(), Some("BitReader".into())),
                ("fmt".into(), Some("Thing".into())),
                ("id".into(), Some("Codec".into())),
            ]
        );
    }

    #[test]
    fn call_kinds_and_resolution() {
        let t = table(&[
            (
                "fl/server.rs",
                "impl Server {\n    pub fn ingest(&mut self) {\n        let x = helper();\n        self.classify();\n        pack::store(x);\n        std::thread::yield_now();\n        Other::missing();\n    }\n    fn classify(&self) {}\n}\nfn helper() -> u8 { 0 }\n",
            ),
            ("fl/pack.rs", "pub fn store(_x: u8) {}\n"),
        ]);
        let ingest = t.fns.iter().position(|f| f.name == "ingest").unwrap();
        let by_name = |n: &str| -> Vec<usize> {
            t.calls
                .iter()
                .filter(|c| c.caller == ingest && c.name == n)
                .flat_map(|c| t.resolve(c))
                .collect()
        };
        let labels = |ids: Vec<usize>| -> Vec<String> {
            ids.into_iter().map(|id| t.label(id)).collect()
        };
        assert_eq!(labels(by_name("helper")), vec!["fl/server.rs::helper"]);
        assert_eq!(
            labels(by_name("classify")),
            vec!["fl/server.rs::Server::classify"]
        );
        assert_eq!(labels(by_name("store")), vec!["fl/pack.rs::store"]);
        // `std::thread::yield_now` / `Other::missing`: no in-tree match,
        // no edge — external calls must not pull in same-named fns.
        assert!(by_name("yield_now").is_empty());
        assert!(by_name("missing").is_empty());
    }

    #[test]
    fn loops_and_entries() {
        let t = table(&[(
            "fl/hot.rs",
            "pub fn fold(xs: &[u8]) -> u32 {\n    let mut acc = 0u32;\n    for &x in xs {\n        acc += x as u32;\n    }\n    while acc > 100 {\n        acc /= 2;\n    }\n    acc\n}\npub fn fold_tail() {}\n",
        )]);
        assert_eq!(t.loops.len(), 2);
        assert_eq!(t.loops[0].start, 2);
        assert_eq!(t.loops[0].end, 4);
        assert_eq!(t.resolve_entry("fl/hot.rs::fold").len(), 1);
        assert_eq!(t.resolve_entry("fl/hot.rs::fold*").len(), 2);
        assert_eq!(t.resolve_entry("fl/hot.rs::Server::fold").len(), 0);
        assert_eq!(t.resolve_entry("other.rs::fold").len(), 0);
    }
}
