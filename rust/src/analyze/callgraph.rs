//! Reachability over the symbol table: one edge per conservatively
//! resolved call, multi-source BFS with parent pointers, and shortest
//! offending-chain extraction for the reports.

use std::collections::VecDeque;

use super::symbols::SymbolTable;

/// The whole-tree call graph, indexed by [`super::symbols::FnSym`] id.
pub struct CallGraph {
    /// `edges[f]` = sorted `(callee, line-of-first-call)` pairs, one per
    /// distinct callee.
    edges: Vec<Vec<(usize, usize)>>,
}

impl CallGraph {
    pub fn build(syms: &SymbolTable) -> CallGraph {
        let mut edges: Vec<Vec<(usize, usize)>> = vec![Vec::new(); syms.fns.len()];
        for call in &syms.calls {
            for callee in syms.resolve(call) {
                edges[call.caller].push((callee, call.line));
            }
        }
        for e in &mut edges {
            e.sort_unstable();
            e.dedup_by_key(|p| p.0); // keep the lowest call line per callee
        }
        CallGraph { edges }
    }

    pub fn callees(&self, f: usize) -> &[(usize, usize)] {
        &self.edges[f]
    }

    /// Multi-source BFS from `entries`; shortest chains win, ties broken
    /// by fn id (deterministic for a deterministic symbol table).
    pub fn reach(&self, entries: &[usize]) -> Reach {
        let n = self.edges.len();
        let mut seen = vec![false; n];
        let mut parent: Vec<Option<usize>> = vec![None; n];
        let mut queue: VecDeque<usize> = VecDeque::new();
        for &e in entries {
            if e < n && !seen[e] {
                seen[e] = true;
                queue.push_back(e);
            }
        }
        while let Some(f) = queue.pop_front() {
            for &(c, _) in &self.edges[f] {
                if !seen[c] {
                    seen[c] = true;
                    parent[c] = Some(f);
                    queue.push_back(c);
                }
            }
        }
        Reach { seen, parent }
    }
}

/// BFS result: membership plus parent pointers for chain rendering.
pub struct Reach {
    seen: Vec<bool>,
    parent: Vec<Option<usize>>,
}

impl Reach {
    pub fn contains(&self, f: usize) -> bool {
        self.seen.get(f).copied().unwrap_or(false)
    }

    /// Entry → … → `f`, as fn ids (entry first). `f` itself when `f` is
    /// an entry.
    pub fn chain(&self, f: usize) -> Vec<usize> {
        let mut out = vec![f];
        let mut cur = f;
        while let Some(p) = self.parent[cur] {
            out.push(p);
            cur = p;
        }
        out.reverse();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::lexer::lex_str;
    use crate::analyze::symbols::SymbolTable;

    #[test]
    fn bfs_chains_are_shortest() {
        let src = "\
pub fn entry() {
    mid();
    deep_a();
}
fn mid() {
    leaf();
}
fn deep_a() {
    deep_b();
}
fn deep_b() {
    leaf();
}
fn leaf() {}
fn island() {}
";
        let files = vec![lex_str("a.rs", src)];
        let syms = SymbolTable::build(&files);
        let graph = CallGraph::build(&syms);
        let id = |n: &str| syms.fns.iter().position(|f| f.name == n).unwrap();
        let reach = graph.reach(&[id("entry")]);
        assert!(reach.contains(id("leaf")));
        assert!(!reach.contains(id("island")));
        let chain: Vec<String> = reach
            .chain(id("leaf"))
            .into_iter()
            .map(|f| syms.fns[f].name.clone())
            .collect();
        assert_eq!(chain, vec!["entry", "mid", "leaf"], "shortest path wins");
        assert_eq!(reach.chain(id("entry")).len(), 1);
    }
}
