//! `repro analyze` — a zero-dependency static analyzer for the project's
//! hand-enforced invariants.
//!
//! ```text
//!            analyze.toml (scopes + allowlists + boundary `entries`,
//!                 │        hand-rolled TOML subset)
//!   *.rs ──► lexer::lex_str ──► SourceFile (scrubbed lines, comments,
//!                 │              literals, fn/test/unsafe spans, waivers)
//!                 ├───────────────────────────────┐
//!                 ▼                               ▼
//!            rules::all()                symbols::SymbolTable
//!             │                           (fn defs + owners, call
//!             │  per-file lexical:        sites, loop spans)
//!             │   determinism                     │
//!             │   panic_safety                    ▼
//!             │   hotpath                 callgraph::CallGraph
//!             │   unsafe_audit            (BFS reachability with
//!             │   wire                    parent-pointer chains)
//!             │                                   │
//!             │  interprocedural ◄────────────────┘
//!             │   panic_propagation · thread_aliasing · hotloop_alloc
//!             ▼
//!            report::Report (path-sorted; text / --json with rendered
//!                            `via a -> b -> c` call chains; exit 1 if dirty)
//! ```
//!
//! The invariants are the ones the repo's correctness story rests on and a
//! reviewer cannot re-check on every diff: bit-identical deterministic
//! aggregation, panic-free decode of hostile CSG2 frames — now traced
//! interprocedurally from the boundary entry points through the whole-tree
//! call graph — transcendental- and allocation-free quantization kernels
//! (including allocations hidden behind calls made from hot loops),
//! disjointness-audited `&mut` captures in scoped-thread spawn closures,
//! documented `unsafe`, and a single source of truth for the 44-byte wire
//! header. Scopes and escape hatches live in `rust/analyze.toml`; point
//! waivers live next to the code as `// analyze: allow(<rule>): reason`
//! comments.

pub mod callgraph;
pub mod config;
pub mod lexer;
pub mod report;
pub mod rules;
pub mod symbols;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use config::AnalyzeConfig;
use lexer::SourceFile;
use report::Report;

/// Run every rule over the `.rs` files under `root` (paths in the report
/// are `/`-separated and relative to `root`). `filters`, when non-empty,
/// restricts scanning to files whose relative path starts with one of the
/// given prefixes — cross-file wire checks that need `compress/wire.rs`
/// degrade gracefully when it is filtered out.
pub fn run(root: &Path, manifest: &Path, filters: &[String]) -> Result<Report> {
    let rules = rules::all();
    let known: Vec<&str> = rules.iter().map(|r| r.name()).collect();
    let manifest_text = std::fs::read_to_string(manifest)
        .with_context(|| format!("reading manifest {}", manifest.display()))?;
    let cfg = AnalyzeConfig::parse(&manifest_text, &known)
        .with_context(|| format!("parsing manifest {}", manifest.display()))?;

    let mut rel_paths = Vec::new();
    collect_rs_files(root, root, &mut rel_paths)
        .with_context(|| format!("walking {}", root.display()))?;
    rel_paths.sort();
    if !filters.is_empty() {
        rel_paths.retain(|p| filters.iter().any(|f| p.starts_with(f.as_str())));
    }

    let mut files = Vec::with_capacity(rel_paths.len());
    for rel in &rel_paths {
        let text = std::fs::read_to_string(root.join(rel))
            .with_context(|| format!("reading {rel}"))?;
        files.push(lexer::lex_str(rel, &text));
    }
    Ok(run_lexed(&files, &cfg, &rules))
}

/// Rule dispatch over already-lexed files (fixture tests enter here too).
pub fn run_lexed(
    files: &[SourceFile],
    cfg: &AnalyzeConfig,
    rules: &[Box<dyn rules::Rule>],
) -> Report {
    let mut diags = Vec::new();
    let mut names = Vec::new();
    for rule in rules {
        let scope = cfg
            .rules
            .get(rule.name())
            .cloned()
            .unwrap_or_default(); // parse() guarantees presence; default = empty scope
        diags.extend(rule.check(files, &scope));
        names.push(rule.name().to_string());
    }
    Report::new(diags, files.len(), names)
}

/// Deterministic recursive walk: directory entries sorted by name at every
/// level, `.rs` files only.
fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading dir {}", dir.display()))?
        .map(|e| e.map(|e| e.path()))
        .collect::<Result<_, _>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(root, &path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}
