//! Diagnostic report: deterministic ordering, text and JSON emit.

use crate::util::json::Json;

/// One rule violation. `line` is 1-indexed for display. Interprocedural
/// rules attach `chain`: the offending call chain from the boundary
/// entry to the sinful fn, as `file.rs::[Type::]fn` labels.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diagnostic {
    pub path: String,
    pub line: usize,
    pub rule: String,
    pub message: String,
    /// Empty for per-file lexical rules.
    pub chain: Vec<String>,
}

impl Diagnostic {
    pub fn new(path: &str, line0: usize, rule: &str, message: String) -> Diagnostic {
        Diagnostic {
            path: path.to_string(),
            line: line0 + 1,
            rule: rule.to_string(),
            message,
            chain: Vec::new(),
        }
    }

    /// Attach the offending call chain (entry first).
    pub fn with_chain(mut self, chain: Vec<String>) -> Diagnostic {
        self.chain = chain;
        self
    }
}

/// The full analysis result.
#[derive(Debug, Clone)]
pub struct Report {
    /// Sorted by (path, line, rule, message) — byte-identical across runs.
    pub diagnostics: Vec<Diagnostic>,
    pub files_scanned: usize,
    /// Rule family names that ran, sorted.
    pub rules_run: Vec<String>,
}

impl Report {
    pub fn new(mut diagnostics: Vec<Diagnostic>, files_scanned: usize, mut rules: Vec<String>) -> Report {
        diagnostics.sort();
        diagnostics.dedup();
        rules.sort();
        Report {
            diagnostics,
            files_scanned,
            rules_run: rules,
        }
    }

    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Human-readable report, one `path:line: [rule] message` per finding
    /// (plus an indented `via` line when a call chain is attached).
    pub fn text(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&format!(
                "{}:{}: [{}] {}\n",
                d.path, d.line, d.rule, d.message
            ));
            if !d.chain.is_empty() {
                out.push_str(&format!("    via {}\n", d.chain.join(" -> ")));
            }
        }
        out.push_str(&format!(
            "analyze: {} violation(s), {} file(s) scanned, {} rule(s): {}\n",
            self.diagnostics.len(),
            self.files_scanned,
            self.rules_run.len(),
            self.rules_run.join(", ")
        ));
        out
    }

    /// Machine-readable report (pretty-printed, stable key order).
    pub fn json(&self) -> String {
        let violations: Vec<Json> = self
            .diagnostics
            .iter()
            .map(|d| {
                let mut obj = Json::obj()
                    .set("file", d.path.as_str())
                    .set("line", d.line)
                    .set("message", d.message.as_str())
                    .set("rule", d.rule.as_str());
                if !d.chain.is_empty() {
                    obj = obj.set(
                        "chain",
                        Json::Arr(d.chain.iter().map(|c| Json::from(c.as_str())).collect()),
                    );
                }
                obj
            })
            .collect();
        Json::obj()
            .set("clean", self.clean())
            .set("files_scanned", self.files_scanned)
            .set(
                "rules",
                Json::Arr(self.rules_run.iter().map(|r| Json::from(r.as_str())).collect()),
            )
            .set("violations", Json::Arr(violations))
            .pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorted_and_deduped() {
        let r = Report::new(
            vec![
                Diagnostic::new("b.rs", 4, "determinism", "x".into()),
                Diagnostic::new("a.rs", 9, "hotpath", "y".into()),
                Diagnostic::new("a.rs", 9, "hotpath", "y".into()),
            ],
            2,
            vec!["hotpath".into(), "determinism".into()],
        );
        assert_eq!(r.diagnostics.len(), 2);
        assert_eq!(r.diagnostics[0].path, "a.rs");
        assert_eq!(r.rules_run, vec!["determinism", "hotpath"]);
        assert!(r.text().starts_with("a.rs:10: [hotpath] y\n"));
    }

    #[test]
    fn chain_renders_in_text_and_json() {
        let d = Diagnostic::new("a.rs", 4, "panic_propagation", "`.unwrap()` reachable".into())
            .with_chain(vec![
                "fl/server.rs::Server::ingest".into(),
                "a.rs::helper".into(),
            ]);
        let r = Report::new(vec![d], 1, vec!["panic_propagation".into()]);
        assert!(r
            .text()
            .contains("    via fl/server.rs::Server::ingest -> a.rs::helper\n"));
        let j = crate::util::json::Json::parse(&r.json()).unwrap();
        let v = j.get("violations").unwrap().as_arr().unwrap();
        let chain = v[0].get("chain").unwrap().as_arr().unwrap();
        assert_eq!(chain.len(), 2);
        assert_eq!(chain[0].as_str(), Some("fl/server.rs::Server::ingest"));
    }

    #[test]
    fn json_shape() {
        let r = Report::new(
            vec![Diagnostic::new("a.rs", 0, "wire", "bad".into())],
            1,
            vec!["wire".into()],
        );
        let j = crate::util::json::Json::parse(&r.json()).unwrap();
        assert_eq!(j.get("clean").unwrap().as_bool(), Some(false));
        let v = j.get("violations").unwrap().as_arr().unwrap();
        assert_eq!(v[0].get("line").unwrap().as_usize(), Some(1));
        assert_eq!(v[0].get("rule").unwrap().as_str(), Some("wire"));
    }
}
