//! Figure 5 (§4): why quantization + DEFLATE co-design works — multi-scale
//! entropy of 8-bit quantized gradient codes vs raw float32 bytes, and the
//! accumulated compression-ratio curves (paper: 8-bit codes go from ~4× to
//! >12× after Deflate; float32 only 1.073×).
//!
//! Gradients come from real local rounds of the UNet (BraTS-substitute)
//! training, as in the paper.

use anyhow::Result;

use crate::compress::cosine::CosineQuantizer;
use crate::compress::{bitpack, entropy};
use crate::data::partition::iid_partition;
use crate::data::synth::SynthVolume;
use crate::fl::client::Client;
use crate::runtime::manifest::init_params;
use crate::runtime::Engine;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

use super::FigOpts;

pub fn run(engine: &Engine, opts: &FigOpts) -> Result<()> {
    println!("== Figure 5: entropy & Deflate statistics on UNet training gradients ==");
    let model = engine.manifest.model("unet")?.clone();
    let round_cfg = engine.manifest.round("unet")?;
    let task = SynthVolume::new(opts.seed);
    let shards = iid_partition(opts.seed, 10, round_cfg.n_data, 3);
    let params = init_params(&model, opts.seed);
    let mut rng = Pcg64::new(opts.seed, 0xF165);

    // Collect deltas from a few clients' local rounds.
    let n_clients = if opts.full { 10 } else { 3 };
    let mut all_delta: Vec<f32> = Vec::new();
    for shard in shards.into_iter().take(n_clients) {
        let mut client = Client::new(shard, opts.seed);
        let up = client.run_round(
            engine,
            &task,
            "unet_round",
            &round_cfg,
            &params,
            1e-3,
            &crate::compress::Pipeline::float32(),
            None,
            false,
        )?;
        // Decode the float32 payload back to the dense delta.
        let delta = crate::compress::decode(&up.segments[0])?;
        all_delta.extend(delta);
    }
    println!("collected {} gradient values", all_delta.len());

    // 8-bit cosine quantization (paper default), packed to bytes.
    let quant = CosineQuantizer::paper_default(8).quantize(&all_delta, &mut rng);
    let packed = bitpack::pack(&quant.codes, 8);
    let float_bytes = entropy::f32_bytes(&all_delta);

    println!("\n-- multi-scale entropy (bits/byte; uniform random = 8.0) --");
    println!("{:>8} {:>12} {:>12}", "scale", "8-bit codes", "float32");
    let me_q = entropy::multiscale_entropy(&packed);
    let me_f = entropy::multiscale_entropy(&float_bytes);
    for ((s, eq), (_, ef)) in me_q.iter().zip(&me_f) {
        println!("{s:>8} {eq:>12.4} {ef:>12.4}");
    }

    println!("\n-- accumulated compression ratio (prefix bytes -> ratio) --");
    let curve_q = entropy::accumulated_compression_curve(&packed, 10);
    let curve_f = entropy::accumulated_compression_curve(&float_bytes, 10);
    println!("{:>12} {:>12} | {:>12} {:>12}", "codes bytes", "ratio", "f32 bytes", "ratio");
    for (a, b) in curve_q.iter().zip(&curve_f) {
        println!("{:>12} {:>12.3} | {:>12} {:>12.3}", a.0, a.1, b.0, b.1);
    }
    let final_q = curve_q.last().map(|x| x.1).unwrap_or(1.0);
    let final_f = curve_f.last().map(|x| x.1).unwrap_or(1.0);
    // Total vs float32 = 4x (bits) * deflate gain.
    println!(
        "\n8-bit quantization alone: 4.00x; with Deflate: {:.2}x total \
         (paper: ~4x -> >12x). float32 deflate: {final_f:.3}x (paper: 1.073x)",
        4.0 * final_q
    );

    let out = Json::obj()
        .set("n_values", all_delta.len())
        .set(
            "entropy_codes",
            Json::Arr(me_q.iter().map(|&(s, e)| Json::from_f64_slice(&[s as f64, e])).collect()),
        )
        .set(
            "entropy_float32",
            Json::Arr(me_f.iter().map(|&(s, e)| Json::from_f64_slice(&[s as f64, e])).collect()),
        )
        .set("deflate_ratio_codes", final_q)
        .set("deflate_ratio_float32", final_f)
        .set("total_ratio_8bit_deflate", 4.0 * final_q);
    std::fs::create_dir_all(&opts.out_dir)?;
    let path = opts.out_dir.join("fig5.json");
    std::fs::write(&path, out.pretty())?;
    println!("wrote {path:?}");
    Ok(())
}
