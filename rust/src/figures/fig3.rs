//! Figure 3 + the Eq. (5) computation (§3.1): per-interval quantization
//! error bounds of the cosine quantizer vs the flat linear bound, and the
//! fraction of intervals where cosine wins (paper: top 50% / 42.9% / 44.1%
//! for 2/4/8 bits).
//!
//! Purely analytic — no artifacts needed.

use anyhow::Result;

use crate::compress::cosine::{
    cosine_error_bound, intervals_cosine_beats_linear, linear_error_bound,
};
use crate::util::json::Json;

use super::FigOpts;

pub fn run(opts: &FigOpts) -> Result<()> {
    println!("== Figure 3: per-interval error bounds (unit-norm gradient) ==");
    let bound = 0.0f64;
    let mut out = Json::obj();
    for bits in [2u8, 4, 8] {
        let total = 1u32 << bits;
        let q = (std::f64::consts::PI - 2.0 * bound) / total as f64;
        let lin = linear_error_bound(bits, bound);
        println!("\n-- {bits}-bit: interval width q={q:.5}, linear bound {lin:.5} --");
        println!("{:>4} {:>12} {:>12} {:>6}", "k", "cosine", "linear", "win");
        let show = if bits <= 4 { total } else { 16 }; // subsample 8-bit print
        let step = (total / show).max(1);
        let mut series = Vec::new();
        for k in (0..total).step_by(step as usize) {
            let cb = cosine_error_bound(k, q, bound);
            series.push(Json::from_f64_slice(&[k as f64, cb]));
            println!(
                "{k:>4} {cb:>12.6} {lin:>12.6} {:>6}",
                if cb < lin { "cos" } else { "lin" }
            );
        }
        let (win, tot) = intervals_cosine_beats_linear(bits, bound);
        let frac = 100.0 * win as f64 / tot as f64;
        println!("cosine wins {win}/{tot} intervals = {frac:.1}% (paper: 50/42.9/44.1%)");
        out = out.set(
            &format!("bits{bits}"),
            Json::obj()
                .set("q", q)
                .set("linear_bound", lin)
                .set("win", win as usize)
                .set("total", tot as usize)
                .set("win_pct", frac)
                .set("series", Json::Arr(series)),
        );
    }
    let path = opts.out_dir.join("fig3.json");
    std::fs::create_dir_all(&opts.out_dir)?;
    std::fs::write(&path, out.pretty())?;
    println!("\nwrote {path:?}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_writes_json() {
        let dir = std::env::temp_dir().join("cossgd_fig3_test");
        let opts = FigOpts {
            out_dir: dir.clone(),
            ..Default::default()
        };
        run(&opts).unwrap();
        let text = std::fs::read_to_string(dir.join("fig3.json")).unwrap();
        let json = crate::util::json::Json::parse(&text).unwrap();
        // 2-bit: exactly half the intervals win.
        assert_eq!(json.path(&["bits2", "win"]).unwrap().as_usize(), Some(2));
        assert_eq!(json.path(&["bits2", "total"]).unwrap().as_usize(), Some(4));
        std::fs::remove_dir_all(&dir).ok();
    }
}
