//! Figure 8 (§5.2): low-bit schemes on CIFAR-10.
//!
//! (a) 2-bit: cosine vs unbiased linear vs Hadamard-rotated unbiased
//!     linear ("linear (U,R)") vs float32.
//! (b) 1-bit family: signSGD, signSGD+Norm (≡ our 1-bit), EF-signSGD, and
//!     2-bit + 50% random mask (same average bits/parameter).

use anyhow::Result;

use crate::compress::cosine::{BoundMode, Rounding};
use crate::compress::Pipeline;
use crate::fl::FlConfig;
use crate::runtime::Engine;

use super::{run_codec_series, FigOpts};

pub fn run(engine: &Engine, opts: &FigOpts) -> Result<()> {
    let rounds = opts.rounds_or(1, 2000);
    // Reduced scale: E=1 artifact, 20 clients (see fig7).
    let mut base = if opts.full {
        FlConfig::cifar()
    } else {
        let mut c = FlConfig::cifar_e1();
        c.participation = 0.1;
        c.n_clients = 20;
        c
    }
    .with_rounds(rounds);
    base.eval_every = (rounds / 4).max(1);

    // (a) 2-bit comparison with rotation.
    let cos2 = Pipeline::cosine_with(2, Rounding::Biased, BoundMode::ClipTopPercent(1.0));
    let lin2u = Pipeline::linear(2, Rounding::Unbiased);
    let lin2ur = Pipeline::linear_rotated(2, Rounding::Unbiased);
    let series_a = vec![
        ("float32".to_string(), Pipeline::float32()),
        (cos2.name(), cos2.clone()),
        (lin2u.name(), lin2u),
        (lin2ur.name(), lin2ur.clone()),
    ];
    run_codec_series(
        engine,
        &base,
        &series_a,
        "Figure 8a — CIFAR 2-bit schemes",
        "fig8a",
        opts,
    )?;

    // (b) 1-bit family.
    let series_b = vec![
        ("signSGD".to_string(), Pipeline::sign()),
        ("signSGD+Norm".to_string(), Pipeline::sign_norm()),
        ("EF-signSGD".to_string(), Pipeline::ef_sign()),
        ("cosine-2 @50%".to_string(), cos2.with_sparsify(0.5)),
        ("linear-2 (U,R) @50%".to_string(), lin2ur.with_sparsify(0.5)),
    ];
    run_codec_series(
        engine,
        &base,
        &series_b,
        "Figure 8b — CIFAR 1-bit-average schemes",
        "fig8b",
        opts,
    )?;
    Ok(())
}
