//! Figure 10 (§5.3): quantization × random sparsification — 25% / 10% / 5%
//! kept gradients at 8/4/2 bits, cosine vs the improved linear baseline
//! (unbiased + Hadamard rotation), on CIFAR and the BraTS substitute.
//!
//! Expected shape: cosine stays near float32 at every (bits, keep%) cell
//! (400–1200× compression at 2 bits); linear (U,R) degrades and collapses
//! at 2-bit/5%.

use anyhow::Result;

use crate::compress::cosine::{BoundMode, Rounding};
use crate::compress::Pipeline;
use crate::fl::FlConfig;
use crate::runtime::Engine;

use super::{run_codec_series, FigOpts};

fn cell_series(keeps: &[f64], bits_list: &[u8]) -> Vec<(String, Pipeline)> {
    let mut out = vec![("float32".to_string(), Pipeline::float32())];
    for &keep in keeps {
        for &bits in bits_list {
            let cos = Pipeline::cosine_with(bits, Rounding::Biased, BoundMode::ClipTopPercent(1.0))
                .with_sparsify(keep);
            let lin = Pipeline::linear_rotated(bits, Rounding::Unbiased).with_sparsify(keep);
            out.push((cos.name(), cos));
            out.push((lin.name(), lin));
        }
    }
    out
}

pub fn run(engine: &Engine, opts: &FigOpts) -> Result<()> {
    // Reduced default: the 5% column at {8,2} bits; full: all 9 cells.
    let (keeps, bits_list): (Vec<f64>, Vec<u8>) = if opts.full {
        (vec![0.25, 0.10, 0.05], vec![8, 4, 2])
    } else {
        (vec![0.05], vec![2])
    };

    // CIFAR panel (reduced: E=1 artifact + 20 clients; see fig7).
    let rounds = opts.rounds_or(1, 2000);
    let mut base = if opts.full {
        FlConfig::cifar()
    } else {
        let mut c = FlConfig::cifar_e1();
        c.participation = 0.1;
        c.n_clients = 20;
        c
    }
    .with_rounds(rounds);
    base.eval_every = (rounds / 4).max(1);
    let series = cell_series(&keeps, &bits_list);
    run_codec_series(
        engine,
        &base,
        &series,
        "Figure 10 — CIFAR: quantization x sparsification",
        "fig10_cifar",
        opts,
    )?;

    // BraTS panel.
    let rounds = opts.rounds_or(1, 100);
    let mut base = FlConfig::unet().with_rounds(rounds);
    base.eval_every = (rounds / 4).max(1);
    let series = cell_series(&keeps, &bits_list);
    run_codec_series(
        engine,
        &base,
        &series,
        "Figure 10 — BraTS-substitute: quantization x sparsification",
        "fig10_brats",
        opts,
    )?;
    Ok(())
}
