//! Figure 4 (§3.2): the toy study motivating the whole design — corrupting
//! the TOP gradients (zero or noise) breaks centralized training, while
//! corrupting the REAR (small) gradients barely matters.

use anyhow::Result;

use crate::fl::centralized::{run_centralized, Perturbation, Target, ToyCurve};
use crate::runtime::Engine;
use crate::util::json::Json;

use super::FigOpts;

pub fn run(engine: &Engine, opts: &FigOpts) -> Result<()> {
    let epochs = opts.rounds_or(2, 15);
    let n_train = if opts.full { 6000 } else { 320 };
    let lr = 0.1;
    println!("== Figure 4: top vs rear gradient importance (centralized, {epochs} epochs) ==");

    let cases: Vec<(&str, Target, Perturbation)> = vec![
        ("vanilla", Target::Top(0.01), Perturbation::None),
        ("top1%→0", Target::Top(0.01), Perturbation::Zero),
        ("rear50%→0", Target::Rear(0.5), Perturbation::Zero),
        ("top1%+noise", Target::Top(0.01), Perturbation::Noise(0.1)),
        ("rear50%+noise", Target::Rear(0.5), Perturbation::Noise(0.1)),
    ];
    let mut curves: Vec<ToyCurve> = Vec::new();
    for (label, target, pert) in cases {
        if opts.verbose {
            println!("running {label}...");
        }
        curves.push(run_centralized(
            engine, epochs, n_train, lr, target, pert, opts.seed, label,
        )?);
    }

    println!("\n{:<16}", "curve \\ epoch");
    print!("{:<16}", "");
    for e in 1..=epochs {
        print!(" {e:>7}");
    }
    println!();
    for c in &curves {
        print!("{:<16}", c.label);
        for &(_, acc) in &c.points {
            print!(" {acc:>7.4}");
        }
        println!();
    }

    // The paper's claim, checked on our substrate:
    let final_acc = |label: &str| {
        curves
            .iter()
            .find(|c| c.label == label)
            .and_then(|c| c.points.last().map(|p| p.1))
            .unwrap_or(0.0)
    };
    let vanilla = final_acc("vanilla");
    let top_zero = final_acc("top1%→0");
    let rear_zero = final_acc("rear50%→0");
    println!(
        "\nshape check: vanilla {vanilla:.3} vs rear-zero {rear_zero:.3} (should be close), \
         top-zero {top_zero:.3} (should lag)"
    );

    let out = Json::obj().set(
        "curves",
        Json::Arr(
            curves
                .iter()
                .map(|c| {
                    Json::obj().set("label", c.label.as_str()).set(
                        "points",
                        Json::Arr(
                            c.points
                                .iter()
                                .map(|&(e, a)| Json::from_f64_slice(&[e as f64, a]))
                                .collect(),
                        ),
                    )
                })
                .collect(),
        ),
    );
    std::fs::create_dir_all(&opts.out_dir)?;
    let path = opts.out_dir.join("fig4.json");
    std::fs::write(&path, out.pretty())?;
    println!("wrote {path:?}");
    Ok(())
}
