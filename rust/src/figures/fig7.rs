//! Figure 7 (§5.2): the same cosine-vs-linear comparison on CIFAR-10
//! (B=50, E=5, C=0.1, momentum, cosine η_c schedule).

use anyhow::Result;

use crate::compress::cosine::Rounding;
use crate::fl::FlConfig;
use crate::runtime::Engine;

use super::{fig6::bit_series, run_codec_series, FigOpts};

pub fn run(engine: &Engine, opts: &FigOpts) -> Result<()> {
    let rounds = opts.rounds_or(1, 2000);
    // Reduced scale: the E=1 round artifact (5x cheaper per client on a
    // 1-core box) and a 20-client federation; `--scale full` restores the
    // paper's E=5, 100 clients, 2000 rounds, both rounding panels.
    let mut base = if opts.full {
        FlConfig::cifar()
    } else {
        let mut c = FlConfig::cifar_e1();
        c.participation = 0.1;
        c.n_clients = 20;
        c
    }
    .with_rounds(rounds);
    base.eval_every = (rounds / 4).max(1);
    let panels: &[(&str, Rounding)] = if opts.full {
        &[("a: biased", Rounding::Biased), ("b: unbiased", Rounding::Unbiased)]
    } else {
        &[("a: biased", Rounding::Biased)]
    };
    for &(sub, rounding) in panels {
        let series = bit_series(rounding, opts.full);
        run_codec_series(
            engine,
            &base,
            &series,
            &format!("Figure 7{sub} — CIFAR accuracy"),
            &format!(
                "fig7_{}",
                if rounding == Rounding::Biased { "biased" } else { "unbiased" }
            ),
            opts,
        )?;
    }
    Ok(())
}
