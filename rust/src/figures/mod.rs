//! One driver per paper figure/table (DESIGN.md §3 experiment index).
//!
//! Every driver prints the same rows/series the paper reports and writes a
//! JSON dump under `artifacts/results/<id>.json`. Absolute numbers differ
//! (synthetic data, CPU substrate — DESIGN.md §5); the *shape* — who wins,
//! by roughly what factor, where training collapses — is the claim.
//!
//! Scale: defaults are CPU-budget-reduced round counts; `--scale full`
//! restores the paper's counts (500/2000/100 rounds).

pub mod fig10;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod tab1;
pub mod tab2;

use anyhow::{bail, Result};

use crate::runtime::Engine;
use crate::util::cli::Args;

/// Common figure-driver options parsed from the CLI.
#[derive(Debug, Clone)]
pub struct FigOpts {
    /// Override round count (None = the scale default).
    pub rounds: Option<usize>,
    /// Paper-scale rounds instead of reduced defaults.
    pub full: bool,
    pub seed: u64,
    pub verbose: bool,
    /// Where results JSON goes.
    pub out_dir: std::path::PathBuf,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts {
            rounds: None,
            full: false,
            seed: 42,
            verbose: false,
            out_dir: std::path::PathBuf::from("artifacts/results"),
        }
    }
}

impl FigOpts {
    pub fn from_args(args: &Args) -> FigOpts {
        FigOpts {
            rounds: args.opt("rounds").map(|r| r.parse().expect("--rounds")),
            full: args.opt_or("scale", "small") == "full" || args.flag("full"),
            seed: args.opt_u64("seed", 42),
            verbose: !args.flag("quiet"),
            out_dir: std::path::PathBuf::from(
                args.opt_or("out-dir", "artifacts/results"),
            ),
        }
    }

    /// Choose a round count: explicit > full-scale > reduced default.
    pub fn rounds_or(&self, small: usize, full: usize) -> usize {
        self.rounds.unwrap_or(if self.full { full } else { small })
    }
}

/// All figure ids, in paper order.
pub const ALL: &[&str] = &[
    "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "tab1", "tab2",
];

/// Dispatch a figure by id. `engine` is lazy so analytic figures (fig3)
/// work without artifacts.
pub fn run_figure(id: &str, engine: &mut Option<Engine>, opts: &FigOpts) -> Result<()> {
    let need_engine = id != "fig3";
    if need_engine && engine.is_none() {
        *engine = Some(Engine::load_default()?);
    }
    let eng = engine.as_ref();
    match id {
        "fig3" => fig3::run(opts),
        "fig4" => fig4::run(eng.unwrap(), opts),
        "fig5" => fig5::run(eng.unwrap(), opts),
        "fig6" => fig6::run(eng.unwrap(), opts),
        "fig7" => fig7::run(eng.unwrap(), opts),
        "fig8" => fig8::run(eng.unwrap(), opts),
        "fig9" => fig9::run(eng.unwrap(), opts),
        "fig10" => fig10::run(eng.unwrap(), opts),
        "tab1" => tab1::run(eng.unwrap(), opts),
        "tab2" => tab2::run(eng.unwrap(), opts),
        other => bail!("unknown figure '{other}' (use one of {ALL:?})"),
    }
}

/// Run one FL experiment per (label, uplink pipeline) pair over a shared
/// base config, print the convergence table, dump JSON, return the
/// histories.
pub fn run_codec_series(
    engine: &Engine,
    base: &crate::fl::FlConfig,
    series: &[(String, crate::compress::Pipeline)],
    title: &str,
    file: &str,
    opts: &FigOpts,
) -> Result<Vec<crate::fl::History>> {
    let mut histories = Vec::new();
    for (label, pipeline) in series {
        if opts.verbose {
            println!("[{file}] running {label} ({} rounds)...", base.rounds);
        }
        let mut cfg = base.clone().with_uplink(pipeline.clone()).with_seed(opts.seed);
        cfg.verbose = false;
        let result = crate::fl::runner::run_labeled(&cfg, engine, label)?;
        if opts.verbose {
            println!(
                "[{file}] {label}: best {:.4}, {} uplink, ratio {:.1}x, {:.1}s",
                result.history.best_metric().unwrap_or(f64::NAN),
                crate::util::timer::fmt_bytes(result.network.uplink_bytes),
                result
                    .network
                    .uplink_compression_vs_float32(
                        engine.manifest.model(base.task.model_key())?.param_count
                    )
                    .unwrap_or(f64::NAN),
                result.wall_secs,
            );
        }
        histories.push(result.history);
    }
    print_series_table(title, &histories);
    std::fs::create_dir_all(&opts.out_dir)?;
    let path = opts.out_dir.join(format!("{file}.json"));
    crate::fl::metrics::save_results(&path, title, &histories)?;
    println!("wrote {path:?}");
    Ok(histories)
}

/// Shared pretty-printer for convergence series.
pub fn print_series_table(title: &str, series: &[crate::fl::History]) {
    println!("\n== {title} ==");
    let mut rounds: Vec<usize> = series
        .iter()
        .flat_map(|h| {
            h.records
                .iter()
                .filter(|r| r.eval_metric.is_some())
                .map(|r| r.round)
        })
        .collect();
    rounds.sort_unstable();
    rounds.dedup();
    print!("{:<28}", "series \\ round");
    for r in &rounds {
        print!(" {r:>7}");
    }
    println!("    best");
    for h in series {
        print!("{:<28}", h.label);
        for r in &rounds {
            let v = h
                .records
                .iter()
                .find(|rec| rec.round == *r)
                .and_then(|rec| rec.eval_metric);
            match v {
                Some(m) => print!(" {:>7.4}", m),
                None => print!(" {:>7}", "-"),
            }
        }
        println!("   {:.4}", h.best_metric().unwrap_or(f64::NAN));
    }
}
