//! Table 2 (§5.4): ablation of top-gradient clipping for the bound b_θ —
//! clip percentages 0..6% on CIFAR with random sparsification, at the
//! most precise (8-bit @10%) and coarsest (2-bit @5%) settings.
//!
//! Expected shape: clip=0 (auto bound) collapses for 2-bit (the paper's
//! "10" entry); moderate clipping (1–6%) recovers and slightly improves
//! accuracy.

use anyhow::Result;

use crate::compress::cosine::{BoundMode, Rounding};
use crate::compress::Pipeline;
use crate::fl::{runner, FlConfig};
use crate::runtime::Engine;
use crate::util::json::Json;

use super::FigOpts;

pub fn run(engine: &Engine, opts: &FigOpts) -> Result<()> {
    let rounds = opts.rounds_or(1, 2000);
    let clips: Vec<f64> = if opts.full {
        vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0]
    } else {
        vec![0.0, 1.0, 6.0]
    };
    let settings: Vec<(&str, u8, f64)> =
        vec![("8-bit @10%", 8, 0.10), ("2-bit @5%", 2, 0.05)];

    println!("== Table 2 — clipping ablation (best accuracy) ==");
    // Reduced scale: E=1 artifact + 20 clients (see fig7).
    let mut base = if opts.full {
        FlConfig::cifar()
    } else {
        let mut c = FlConfig::cifar_e1();
        c.participation = 0.1;
        c.n_clients = 20;
        c
    }
    .with_rounds(rounds);
    base.eval_every = (rounds / 2).max(1);

    // float32 reference column.
    if opts.verbose {
        println!("running f32 reference...");
    }
    let f32_result = runner::run_labeled(
        &base.clone().with_uplink(Pipeline::float32()).with_seed(opts.seed),
        engine,
        "f32",
    )?;
    let f32_acc = f32_result.history.best_metric().unwrap_or(f64::NAN);

    let mut json_rows = Vec::new();
    print!("{:<14} {:>8}", "setting", "f32");
    for c in &clips {
        print!(" {:>7}", format!("{c}%"));
    }
    println!();
    for (label, bits, keep) in &settings {
        print!("{label:<14} {f32_acc:>8.4}");
        let mut row = Json::obj().set("setting", *label).set("f32", f32_acc);
        for &clip in &clips {
            let bound = if clip == 0.0 {
                BoundMode::Auto
            } else {
                BoundMode::ClipTopPercent(clip)
            };
            let codec = Pipeline::cosine_with(*bits, Rounding::Biased, bound)
                .with_sparsify(*keep);
            let cfg = base.clone().with_uplink(codec).with_seed(opts.seed);
            let result = runner::run_labeled(&cfg, engine, &format!("{label} clip{clip}"))?;
            let acc = result.history.best_metric().unwrap_or(f64::NAN);
            print!(" {acc:>7.4}");
            row = row.set(&format!("clip{clip}"), acc);
        }
        println!();
        json_rows.push(row);
    }
    println!("\npaper shape: clip=0 collapses at 2-bit; 1-6% clipping recovers/improves.");

    std::fs::create_dir_all(&opts.out_dir)?;
    let path = opts.out_dir.join("tab2.json");
    std::fs::write(&path, Json::obj().set("rows", Json::Arr(json_rows)).pretty())?;
    println!("wrote {path:?}");
    Ok(())
}
