//! Table 1 (§5.4): more computing clients — (B=50, E=5, C=0.1) vs
//! (B=50, E=1, C=0.5) at 5% random sparsification. Both systems touch the
//! same amount of data; the C=0.5 setup updates more parameters per round
//! and recovers most of the float32 accuracy at ~1300× compression.
//!
//! Cost ratios are reported exactly as the paper does:
//! `cost(B=50,E=1,C=0.5, float32, 100%) / cost(setup)`.

use anyhow::Result;

use crate::compress::cosine::{BoundMode, Rounding};
use crate::compress::Pipeline;
use crate::fl::{runner, FlConfig};
use crate::runtime::Engine;
use crate::util::json::Json;

use super::FigOpts;

pub fn run(engine: &Engine, opts: &FigOpts) -> Result<()> {
    let param_count = engine.manifest.model("cifar")?.param_count;
    // Same data touched: E=5,C=0.1 for R rounds ~ E=1,C=0.5 for R rounds
    // (10 clients x 5 epochs vs 50 clients x 1 epoch per round).
    let rounds = opts.rounds_or(1, 2000);
    // Reduced scale: a 4-client federation (E=5 system selects 1 client,
    // E=1/C=0.5 selects 2) keeps the E=5 round affordable on one core.
    let small_clients = 4;

    let cos2_5 = Pipeline::cosine_with(2, Rounding::Biased, BoundMode::ClipTopPercent(1.0))
        .with_sparsify(0.05);
    let lin2_5 = Pipeline::linear_rotated(2, Rounding::Unbiased).with_sparsify(0.05);

    let mut sys_a = FlConfig::cifar().with_rounds(rounds);
    let mut sys_b = FlConfig::cifar_e1().with_rounds(rounds);
    sys_b.participation = 0.5;
    if !opts.full {
        sys_a.n_clients = small_clients;
        sys_b.n_clients = small_clients;
    }
    let systems: Vec<(&str, FlConfig)> = vec![
        ("(B=50, E=5, C=0.1)", sys_a),
        ("(B=50, E=1, C=0.5)", sys_b),
    ];
    let codecs: Vec<(&str, Pipeline)> = vec![
        ("float32", Pipeline::float32()),
        ("linear 2 (U,R) @5%", lin2_5),
        ("cosine 2 @5%", cos2_5),
    ];

    // The paper's reference cost: float32, full updates, the C=0.5 system.
    // Per round that is 50 clients × 4·P bytes (plus headers, negligible).
    let mut rows = Vec::new();
    let mut reference_cost: Option<f64> = None;
    println!("== Table 1 — cost compression ratio and accuracy ==");
    for (sys_label, base) in &systems {
        for (codec_label, codec) in &codecs {
            let mut cfg = base.clone().with_uplink(codec.clone()).with_seed(opts.seed);
            cfg.eval_every = (rounds / 2).max(1);
            if opts.verbose {
                println!("running {sys_label} {codec_label}...");
            }
            let result = runner::run_labeled(&cfg, engine, codec_label)?;
            let total = result.network.uplink_bytes as f64;
            let per_client = result.network.mean_uplink();
            if reference_cost.is_none() && *codec_label == "float32" && sys_label.contains("C=0.5")
            {
                reference_cost = Some(total);
            }
            rows.push((
                sys_label.to_string(),
                codec_label.to_string(),
                total,
                per_client,
                result.history.best_metric().unwrap_or(f64::NAN),
            ));
        }
    }
    // Reference single-client cost: float32 full update.
    let ref_single = (param_count * 4) as f64;
    let ref_total = reference_cost.unwrap_or(1.0);

    println!(
        "\n{:<22} {:<20} {:>12} {:>12} {:>8}",
        "system", "method", "total ratio", "single ratio", "acc"
    );
    let mut json_rows = Vec::new();
    for (sys, codec, total, single, acc) in &rows {
        let total_ratio = ref_total / total.max(1.0);
        let single_ratio = ref_single / single.max(1.0);
        println!(
            "{sys:<22} {codec:<20} {total_ratio:>12.1} {single_ratio:>12.1} {acc:>8.4}"
        );
        json_rows.push(
            Json::obj()
                .set("system", sys.as_str())
                .set("method", codec.as_str())
                .set("total_ratio", total_ratio)
                .set("single_ratio", single_ratio)
                .set("accuracy", *acc),
        );
    }
    println!("\npaper shape: cosine ~matches float32 accuracy in both systems at ~1300x;");
    println!("linear 2 (U,R) collapses at (E=5,C=0.1) and lags at (E=1,C=0.5).");

    std::fs::create_dir_all(&opts.out_dir)?;
    let path = opts.out_dir.join("tab1.json");
    std::fs::write(&path, Json::obj().set("rows", Json::Arr(json_rows)).pretty())?;
    println!("wrote {path:?}");
    Ok(())
}
