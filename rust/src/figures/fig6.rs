//! Figure 6 (§5.2): MNIST — cosine vs linear quantization at 8/4/2 bits,
//! biased (a) and probabilistic-unbiased (b), IID and Non-IID.
//!
//! Expected shape: 2-bit biased linear collapses; unbiased linear recovers
//! partially; cosine ≈ float32 at every bit width.

use anyhow::Result;

use crate::compress::cosine::{BoundMode, Rounding};
use crate::compress::Pipeline;
use crate::fl::FlConfig;
use crate::runtime::Engine;

use super::{run_codec_series, FigOpts};

pub fn bit_series(rounding: Rounding, full: bool) -> Vec<(String, Pipeline)> {
    let mut out = vec![("float32".to_string(), Pipeline::float32())];
    let bit_list: &[u8] = if full { &[8, 4, 2] } else { &[8, 2] };
    for &bits in bit_list {
        let cos = Pipeline::cosine_with(bits, rounding, BoundMode::ClipTopPercent(1.0));
        let lin = Pipeline::linear(bits, rounding);
        out.push((cos.name(), cos));
        out.push((lin.name(), lin));
    }
    out
}

pub fn run(engine: &Engine, opts: &FigOpts) -> Result<()> {
    // Reduced scale (1-core CPU budget): IID panels only, 2 rounds, a
    // 20-client federation (2 selected/round). `--scale full` restores the
    // paper's IID+Non-IID × 500/50 rounds × 100 clients.
    let dists: &[(&str, bool)] = if opts.full {
        &[("IID", false), ("Non-IID", true)]
    } else {
        &[("IID", false)]
    };
    for &(dist, non_iid) in dists {
        let rounds = if non_iid {
            opts.rounds_or(2, 500)
        } else {
            opts.rounds_or(2, 50)
        };
        let mut base = FlConfig::mnist(non_iid).with_rounds(rounds);
        if !opts.full {
            base.n_clients = 20;
        }
        base.eval_every = (rounds / 4).max(1);
        for (sub, rounding) in [("a: biased", Rounding::Biased), ("b: unbiased", Rounding::Unbiased)]
        {
            let series = bit_series(rounding, opts.full);
            run_codec_series(
                engine,
                &base,
                &series,
                &format!("Figure 6{sub} — MNIST {dist} accuracy"),
                &format!(
                    "fig6_{}_{}",
                    if non_iid { "noniid" } else { "iid" },
                    if rounding == Rounding::Biased { "biased" } else { "unbiased" }
                ),
                opts,
            )?;
        }
    }
    Ok(())
}
