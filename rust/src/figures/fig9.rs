//! Figure 9 (§5.2): BraTS-substitute segmentation — dice vs communication
//! rounds AND vs total transferred gradient volume (B=3, E=3, C=1, Adam,
//! warm restarts).

use anyhow::Result;

use crate::compress::cosine::{BoundMode, Rounding};
use crate::compress::Pipeline;
use crate::fl::FlConfig;
use crate::runtime::Engine;
use crate::util::timer::fmt_bytes;

use super::{run_codec_series, FigOpts};

pub fn run(engine: &Engine, opts: &FigOpts) -> Result<()> {
    let rounds = opts.rounds_or(2, 100);
    let mut base = FlConfig::unet().with_rounds(rounds);
    base.eval_every = (rounds / 8).max(1);

    let cos =
        |bits| Pipeline::cosine_with(bits, Rounding::Biased, BoundMode::ClipTopPercent(1.0));
    let lin8ur = Pipeline::linear_rotated(8, Rounding::Unbiased);
    let series = if opts.full {
        vec![
            ("float32".to_string(), Pipeline::float32()),
            ("cosine-8".to_string(), cos(8)),
            ("cosine-4".to_string(), cos(4)),
            ("cosine-2".to_string(), cos(2)),
            ("linear-8 (U,R)".to_string(), lin8ur),
        ]
    } else {
        vec![
            ("float32".to_string(), Pipeline::float32()),
            ("cosine-8".to_string(), cos(8)),
            ("cosine-2".to_string(), cos(2)),
        ]
    };
    let histories = run_codec_series(
        engine,
        &base,
        &series,
        "Figure 9 — BraTS-substitute dice vs rounds",
        "fig9",
        opts,
    )?;

    // Second panel: dice vs transferred bytes.
    println!("\n-- dice vs cumulative uplink (final round) --");
    println!("{:<22} {:>14} {:>8}", "series", "uplink", "dice");
    for h in &histories {
        if let (Some(last), Some(m)) = (h.records.last(), h.final_metric()) {
            println!(
                "{:<22} {:>14} {:>8.4}",
                h.label,
                fmt_bytes(last.uplink_bytes),
                m
            );
        }
    }
    Ok(())
}
