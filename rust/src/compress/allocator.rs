//! Adaptive fine-grained bit-width control: spend quantization bits where
//! the error actually is.
//!
//! The paper's Fig. 9/10 sweeps (and follow-ups like FedFQ's fine-grained
//! per-parameter quantization) show that one global bit width for a whole
//! run wastes budget: early rounds tolerate coarse codes, late rounds
//! need fine ones, and layers with most of the gradient energy deserve
//! most of the bits. This module turns that observation into a scheduler:
//!
//! * [`BitSchedule`] — the run-level policy (`const:<b>`,
//!   `anneal:<hi>..<lo>`, `adaptive:<budget>`), parsed straight from the
//!   `--bits` CLI grammar.
//! * [`LayerMap`] — a partition of the flat parameter vector into layers
//!   (from the model manifest's layer extents, or an even split for
//!   harnesses without one).
//! * [`BitAllocator`] — budgeted water-filling: given per-layer signals
//!   and a total uplink-bytes-per-round target, greedily assign the next
//!   bit to the layer with the largest marginal MSE reduction per byte.
//! * [`BitController`] — the round-loop brain: consumes the signals the
//!   stack already produces (per-layer quantization MSE estimated from
//!   the kernel step tables via [`super::kernel::expected_mse`], the
//!   clients' EF-residual norm, and the round-over-round loss delta) and
//!   emits a [`BitPlan`] for the next round.
//!
//! ## Bit-identity contract
//!
//! `const:<b>` emits a *uniform, unsegmented* plan every round: the
//! encode path is byte-for-byte the legacy fixed-width pipeline (same
//! single CSG2 frame, same RNG draws), pinned by the e2e determinism
//! test. `anneal` is uniform-per-round (one frame, width varying across
//! the stream); only `adaptive` produces segmented multi-width payloads.

use anyhow::{bail, ensure, Result};

use super::kernel::expected_mse;
use super::wire::HEADER_BYTES;

/// Widths the allocator may pick. 8 bits is the paper's top end; 1 bit is
/// the signSGD+Norm degenerate case.
pub const MIN_BITS: u8 = 1;
pub const MAX_BITS: u8 = 8;

/// The run-level bit-width policy (`--bits` grammar).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BitSchedule {
    /// One width for the whole run — through the controller this is
    /// bit-identical to the legacy fixed-width path.
    Const(u8),
    /// Linear anneal from `hi` (round 0) to `lo` (last round), uniform
    /// across layers: coarse early exploration, fine late refinement.
    Anneal { hi: u8, lo: u8 },
    /// Budgeted water-filling over layers. `budget` is the target uplink
    /// payload bytes per client per round (headers included);
    /// `0` = auto (the cost of a uniform 4-bit frame set).
    Adaptive { budget: usize },
}

impl BitSchedule {
    /// Parse the CLI grammar: `const:<b>`, `anneal:<hi>..<lo>`,
    /// `adaptive[:<budget-bytes>]`, or a bare integer (alias of `const`).
    pub fn parse(s: &str) -> Result<BitSchedule> {
        if let Some(b) = s.strip_prefix("const:") {
            let b: u8 = b.parse().map_err(|_| bad_bits(s))?;
            ensure!((1..=16).contains(&b), "const width {b} outside 1..=16");
            return Ok(BitSchedule::Const(b));
        }
        if let Ok(b) = s.parse::<u8>() {
            ensure!((1..=16).contains(&b), "width {b} outside 1..=16");
            return Ok(BitSchedule::Const(b));
        }
        if let Some(rest) = s.strip_prefix("anneal:") {
            let Some((hi, lo)) = rest.split_once("..") else {
                bail!("--bits anneal wants anneal:<hi>..<lo>, got '{s}'");
            };
            let hi: u8 = hi.parse().map_err(|_| bad_bits(s))?;
            let lo: u8 = lo.parse().map_err(|_| bad_bits(s))?;
            ensure!(
                (1..=16).contains(&lo) && (1..=16).contains(&hi),
                "anneal widths outside 1..=16 in '{s}'"
            );
            ensure!(hi >= lo, "anneal runs high to low: {hi} < {lo}");
            return Ok(BitSchedule::Anneal { hi, lo });
        }
        if s == "adaptive" {
            return Ok(BitSchedule::Adaptive { budget: 0 });
        }
        if let Some(b) = s.strip_prefix("adaptive:") {
            let budget: usize = b
                .parse()
                .map_err(|_| anyhow::anyhow!("bad adaptive budget in --bits '{s}'"))?;
            return Ok(BitSchedule::Adaptive { budget });
        }
        bail!("unknown bit schedule '{s}' (const:<b>, anneal:<hi>..<lo>, adaptive[:<bytes>])")
    }

    /// Compact label for logs / results files.
    pub fn name(&self) -> String {
        match self {
            BitSchedule::Const(b) => format!("const:{b}"),
            BitSchedule::Anneal { hi, lo } => format!("anneal:{hi}..{lo}"),
            BitSchedule::Adaptive { budget: 0 } => "adaptive:auto".into(),
            BitSchedule::Adaptive { budget } => format!("adaptive:{budget}"),
        }
    }
}

fn bad_bits(s: &str) -> anyhow::Error {
    anyhow::anyhow!("bad bit width in --bits '{s}'")
}

/// The uniform width `anneal:<hi>..<lo>` picks for round `t` of `total`:
/// `hi` at round 0, `lo` at the last round, linear (rounded) in between.
pub fn anneal_bits(hi: u8, lo: u8, t: usize, total: usize) -> u8 {
    debug_assert!(hi >= lo);
    if total <= 1 || hi == lo {
        return if t == 0 { hi } else { lo };
    }
    let frac = (t as f64 / (total - 1) as f64).min(1.0);
    let w = hi as f64 - frac * (hi - lo) as f64;
    (w.round() as u8).clamp(lo, hi)
}

/// A partition of the flat parameter vector into contiguous layers.
/// `offsets` has `layers + 1` entries: segment `l` is
/// `offsets[l]..offsets[l+1]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerMap {
    offsets: Vec<usize>,
}

impl LayerMap {
    /// One segment covering the whole vector.
    pub fn whole(n: usize) -> LayerMap {
        LayerMap { offsets: vec![0, n] }
    }

    /// `layers` near-even segments (harnesses without a model manifest).
    pub fn even(n: usize, layers: usize) -> LayerMap {
        let layers = layers.clamp(1, n.max(1));
        let mut offsets = Vec::with_capacity(layers + 1);
        for l in 0..=layers {
            offsets.push(l * n / layers);
        }
        LayerMap { offsets }
    }

    /// From `(offset, size)` extents (the manifest's `LayerSpec` layout).
    /// Extents must be contiguous from 0 and non-empty.
    pub fn from_extents(extents: &[(usize, usize)]) -> Result<LayerMap> {
        ensure!(!extents.is_empty(), "layer map needs at least one extent");
        let mut offsets = Vec::with_capacity(extents.len() + 1);
        let mut at = 0usize;
        offsets.push(0);
        for &(off, size) in extents {
            ensure!(off == at, "layer extents not contiguous: {off} != {at}");
            ensure!(size > 0, "empty layer extent at offset {off}");
            at += size;
            offsets.push(at);
        }
        Ok(LayerMap { offsets })
    }

    /// Number of layers.
    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total parameter count covered.
    pub fn param_count(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// The half-open range of segment `l`.
    pub fn segment(&self, l: usize) -> std::ops::Range<usize> {
        self.offsets[l]..self.offsets[l + 1]
    }

    /// Per-segment element counts.
    pub fn sizes(&self) -> Vec<usize> {
        (0..self.len()).map(|l| self.segment(l).len()).collect()
    }
}

/// Wire cost of one CSG2 segment of `n` codes at `bits` (no DEFLATE —
/// the allocator budgets the honest pre-compression size).
pub fn segment_cost(n: usize, bits: u8) -> usize {
    HEADER_BYTES + (n * bits as usize).div_ceil(8)
}

/// Cost of a uniform `bits` plan over `map` (the `adaptive` auto-budget
/// reference point: what `const:4` would spend).
pub fn uniform_cost(map: &LayerMap, bits: u8) -> usize {
    (0..map.len()).map(|l| segment_cost(map.segment(l).len(), bits)).sum()
}

/// Per-layer signal the allocator water-fills against.
#[derive(Debug, Clone)]
pub struct LayerSignal {
    /// Elements in the layer.
    pub n: usize,
    /// Observed ‖g_l‖₂ of the layer's gradient segment.
    pub norm: f64,
    /// Observed angle bound of the layer's last quantization.
    pub bound: f32,
}

/// One wire segment the server accepted this round — the free per-layer
/// signal: `(n, bits, norm, bound)` all travel in the CSG2 header, so the
/// controller reads them without touching payload bytes. `wire_bytes` is
/// the one *measured* field: the bytes the segment actually occupied on
/// the wire (header + post-DEFLATE payload), averaged over the round's
/// accepted frames — the post-compression feedback the controller's cost
/// model learns from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SegmentObs {
    pub n: usize,
    pub bits: u8,
    pub norm: f32,
    pub bound: f32,
    /// Mean measured wire bytes per accepted frame (0 = unknown, e.g.
    /// hand-built observations — the cost model then assumes analytic).
    pub wire_bytes: usize,
}

/// The widths chosen for one round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitPlan {
    /// Segment boundaries (`layers + 1` offsets; `[0, n]` when uniform).
    pub bounds: Vec<usize>,
    /// Width per segment (one entry per layer; a single entry when
    /// uniform).
    pub bits: Vec<u8>,
    /// `false` ⇒ encode ONE frame at `bits[0]` (the legacy byte-identical
    /// path); `true` ⇒ one CSG2 segment per layer, mixed widths allowed.
    pub segmented: bool,
}

impl BitPlan {
    /// Uniform plan: one whole-tensor frame at `b`.
    pub fn uniform(n: usize, b: u8) -> BitPlan {
        BitPlan {
            bounds: vec![0, n],
            bits: vec![b],
            segmented: false,
        }
    }

    /// `Some(w)` when every segment uses the same width `w`.
    pub fn uniform_width(&self) -> Option<u8> {
        let w = *self.bits.first()?;
        self.bits.iter().all(|&b| b == w).then_some(w)
    }
}

/// Budgeted water-filling over layers: start every layer at `floor` bits
/// and repeatedly grant one more bit to the layer with the largest
/// marginal MSE reduction per byte, until the budget is spent or every
/// layer is at `cap`.
#[derive(Debug, Clone, Copy)]
pub struct BitAllocator {
    /// No layer goes below this width (raised by controller pressure).
    pub floor: u8,
    /// No layer goes above this width.
    pub cap: u8,
}

impl Default for BitAllocator {
    fn default() -> Self {
        BitAllocator {
            floor: MIN_BITS,
            cap: MAX_BITS,
        }
    }
}

impl BitAllocator {
    /// Water-fill widths under `budget` payload bytes (headers included),
    /// with the analytic (pre-compression) cost model. Deterministic:
    /// ties break toward the lowest layer index.
    pub fn allocate(&self, signals: &[LayerSignal], budget: usize) -> Vec<u8> {
        self.allocate_scaled(signals, budget, &[])
    }

    /// [`BitAllocator::allocate`] with a per-layer *measured cost scale*:
    /// layer `l`'s wire cost is modeled as `scale[l] · segment_cost(n, w)`
    /// where `scale[l]` is the controller's EWMA of measured
    /// (post-DEFLATE) over analytic bytes. Missing entries — or an empty
    /// slice — default to 1.0, which reproduces [`BitAllocator::allocate`]
    /// decision-for-decision (analytic costs are integers, exact in f64).
    /// Scales are clamped to [`COST_SCALE_RANGE`] so one degenerate
    /// observation can never zero out wire costs and grant unlimited bits.
    pub fn allocate_scaled(
        &self,
        signals: &[LayerSignal],
        budget: usize,
        scale: &[f64],
    ) -> Vec<u8> {
        let floor = self.floor.clamp(MIN_BITS, self.cap);
        let l_count = signals.len();
        let (lo, hi) = COST_SCALE_RANGE;
        let s_of = |l: usize| scale.get(l).copied().unwrap_or(1.0).clamp(lo, hi);
        let cost = |l: usize, n: usize, w: u8| s_of(l) * segment_cost(n, w) as f64;
        let budget = budget as f64;
        let mut bits = vec![MIN_BITS; l_count];
        let mut spent: f64 = signals
            .iter()
            .enumerate()
            .map(|(l, s)| cost(l, s.n, MIN_BITS))
            .sum();
        if spent > budget {
            // Even 1 bit everywhere busts the budget: send the minimum —
            // the budget is a target, not a hard wire limit.
            return bits;
        }
        // Raise to the floor first (uniformly, level by level, so a tight
        // budget degrades gracefully instead of starving the tail layers).
        for level in (MIN_BITS + 1)..=floor {
            for (l, s) in signals.iter().enumerate() {
                if bits[l] == level - 1 {
                    let inc = cost(l, s.n, level) - cost(l, s.n, level - 1);
                    if spent + inc <= budget {
                        bits[l] = level;
                        spent += inc;
                    }
                }
            }
        }
        // Greedy marginal-gain fill. Layer counts are small (a model has
        // dozens of layers, not thousands), so a plain scan per grant is
        // cheaper than maintaining a heap.
        loop {
            let mut best: Option<(usize, f64, f64)> = None; // (layer, inc, gain/byte)
            for (l, s) in signals.iter().enumerate() {
                let w = bits[l];
                if w >= self.cap {
                    continue;
                }
                let inc = cost(l, s.n, w + 1) - cost(l, s.n, w);
                if spent + inc > budget {
                    continue;
                }
                let gain = expected_mse(w, s.bound, s.norm as f32, s.n)
                    - expected_mse(w + 1, s.bound, s.norm as f32, s.n);
                // `.max(1.0)` matches the unscaled path exactly when the
                // scale is 1 (zero-byte grants rank by raw gain).
                let per_byte = gain / inc.max(1.0);
                let better = match best {
                    None => true,
                    Some((_, _, g)) => per_byte > g,
                };
                if better {
                    best = Some((l, inc, per_byte));
                }
            }
            let Some((l, inc, _)) = best else { break };
            bits[l] += 1;
            spent += inc;
        }
        bits
    }
}

/// Clamp range for the measured-over-analytic cost scales: DEFLATE on
/// quantized codes realistically lands in ~[0.25, 1.01] (plus header
/// overhead), so anything outside this range is a degenerate observation
/// (empty layer, corrupted feedback), not a signal to chase.
pub const COST_SCALE_RANGE: (f64, f64) = (0.05, 4.0);

/// EWMA weight of the newest measured-cost observation (round t's
/// measurement counts ~30%, history ~70% — smooth enough to ride out one
/// odd round, fast enough to track a regime change within a few rounds).
const COST_EWMA_ALPHA: f64 = 0.3;

/// The round-loop controller: owns the schedule and the layer map, eats
/// the signals the stack already produces, and emits a [`BitPlan`] per
/// round.
///
/// Signals and how they steer `adaptive`:
/// * **per-layer quantization MSE** — estimated from the accepted wire
///   headers `(n, bits, norm, bound)` through the kernel step tables
///   ([`expected_mse`]); drives the water-filling priorities.
/// * **EF-residual norm** — when the clients' error-feedback residual
///   carries a large fraction of the gradient energy, the quantizer is
///   dropping signal faster than EF can recycle it: the controller raises
///   the allocation floor one bit (budget unchanged — the widest layers
///   pay for it).
/// * **round-over-round loss delta** — a non-improving loss also raises
///   the floor: starved 1-bit layers are the usual suspect the MSE proxy
///   cannot see.
#[derive(Debug, Clone)]
pub struct BitController {
    schedule: BitSchedule,
    map: LayerMap,
    /// Latest per-layer observations (None until the first segmented
    /// round reports back).
    signals: Option<Vec<LayerSignal>>,
    /// Per-layer EWMA of measured (post-DEFLATE) over analytic wire
    /// bytes — the post-compression feedback loop. None until the first
    /// segmented round reports measured sizes; round 0 plans analytically.
    cost_scale: Option<Vec<f64>>,
    prev_loss: Option<f64>,
    /// Extra floor bits from the EF-residual / loss-delta pressure.
    pressure: u8,
}

impl BitController {
    pub fn new(schedule: BitSchedule, map: LayerMap) -> BitController {
        BitController {
            schedule,
            map,
            signals: None,
            cost_scale: None,
            prev_loss: None,
            pressure: 0,
        }
    }

    pub fn schedule(&self) -> BitSchedule {
        self.schedule
    }

    pub fn map(&self) -> &LayerMap {
        &self.map
    }

    /// The uplink payload budget `adaptive` water-fills under.
    pub fn effective_budget(&self) -> usize {
        match self.schedule {
            BitSchedule::Adaptive { budget: 0 } => uniform_cost(&self.map, 4),
            BitSchedule::Adaptive { budget } => budget,
            _ => 0,
        }
    }

    /// Extra floor bits currently forced by the EF-residual / loss-delta
    /// pressure signals (0 = no pressure) — the water-filling rationale
    /// the trace's `bit_plan` events record.
    pub fn pressure(&self) -> u8 {
        self.pressure
    }

    /// The learned per-layer measured-over-analytic cost scales (None
    /// until the first segmented round reports measured wire sizes) — the
    /// post-compression feedback the trace's `bit_plan` events record.
    pub fn cost_scale(&self) -> Option<&[f64]> {
        self.cost_scale.as_deref()
    }

    /// Wire cost of `plan` in payload bytes (headers included) — what the
    /// budget in [`BitController::effective_budget`] is compared against.
    pub fn plan_cost(&self, plan: &BitPlan) -> usize {
        plan.bits
            .iter()
            .enumerate()
            .map(|(l, &b)| segment_cost(plan.bounds[l + 1] - plan.bounds[l], b))
            .sum()
    }

    /// The widths for round `t` of `total`.
    pub fn plan(&mut self, t: usize, total: usize) -> BitPlan {
        let n = self.map.param_count();
        match self.schedule {
            BitSchedule::Const(b) => BitPlan::uniform(n, b),
            BitSchedule::Anneal { hi, lo } => BitPlan::uniform(n, anneal_bits(hi, lo, t, total)),
            BitSchedule::Adaptive { .. } => {
                let alloc = BitAllocator {
                    floor: MIN_BITS + self.pressure,
                    cap: MAX_BITS,
                };
                let signals = match &self.signals {
                    Some(s) => s.clone(),
                    // Cold start: unit-variance gradient prior
                    // (‖g_l‖ ≈ √n_l), bound 0 — sizes carry the plan.
                    None => (0..self.map.len())
                        .map(|l| {
                            let nl = self.map.segment(l).len();
                            LayerSignal {
                                n: nl,
                                norm: (nl as f64).sqrt(),
                                bound: 0.0,
                            }
                        })
                        .collect(),
                };
                let scale = self.cost_scale.as_deref().unwrap_or(&[]);
                let bits = alloc.allocate_scaled(&signals, self.effective_budget(), scale);
                BitPlan {
                    bounds: self.map.offsets.clone(),
                    bits,
                    segmented: true,
                }
            }
        }
    }

    /// Feed one round's observations back: the accepted segments' wire
    /// headers, the mean client EF-residual norm (0 when EF is off), and
    /// the round's mean train loss (`None` when unknown — dry runs).
    pub fn observe(&mut self, obs: &[SegmentObs], residual_norm: f64, train_loss: Option<f64>) {
        // Per-layer signals only update when the segment structure
        // matches the map (uniform rounds report one whole-tensor
        // segment — keep the previous per-layer view alive).
        if obs.len() == self.map.len()
            && obs
                .iter()
                .enumerate()
                .all(|(l, o)| o.n == self.map.segment(l).len())
        {
            self.signals = Some(
                obs.iter()
                    .map(|o| LayerSignal {
                        n: o.n,
                        norm: o.norm as f64,
                        bound: o.bound,
                    })
                    .collect(),
            );
            // Fold measured wire sizes into the per-layer cost scales:
            // ρ_l = measured / analytic bytes at the width that traveled.
            // Segments without a measurement (wire_bytes == 0) keep their
            // previous scale — hand-built observations stay analytic.
            let (lo, hi) = COST_SCALE_RANGE;
            let mut scales = self
                .cost_scale
                .take()
                .unwrap_or_else(|| vec![1.0; self.map.len()]);
            for (s, o) in scales.iter_mut().zip(obs) {
                if o.wire_bytes == 0 {
                    continue;
                }
                let analytic = segment_cost(o.n, o.bits);
                if analytic == 0 {
                    continue;
                }
                let rho = (o.wire_bytes as f64 / analytic as f64).clamp(lo, hi);
                // EWMA with a ρ=1 (analytic) prior.
                *s = (1.0 - COST_EWMA_ALPHA) * *s + COST_EWMA_ALPHA * rho;
            }
            self.cost_scale = Some(scales);
        }
        let grad_energy: f64 = obs.iter().map(|o| (o.norm as f64).powi(2)).sum();
        let residual_pressure = residual_norm * residual_norm > 0.25 * grad_energy
            && grad_energy > 0.0;
        let loss_pressure = match (self.prev_loss, train_loss) {
            (Some(prev), Some(now)) => now >= prev,
            _ => false,
        };
        self.pressure = residual_pressure as u8 + loss_pressure as u8;
        if let Some(l) = train_loss {
            self.prev_loss = Some(l);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_parse_grammar() {
        assert_eq!(BitSchedule::parse("const:4").unwrap(), BitSchedule::Const(4));
        assert_eq!(BitSchedule::parse("6").unwrap(), BitSchedule::Const(6));
        assert_eq!(
            BitSchedule::parse("anneal:8..2").unwrap(),
            BitSchedule::Anneal { hi: 8, lo: 2 }
        );
        assert_eq!(
            BitSchedule::parse("adaptive").unwrap(),
            BitSchedule::Adaptive { budget: 0 }
        );
        assert_eq!(
            BitSchedule::parse("adaptive:25000").unwrap(),
            BitSchedule::Adaptive { budget: 25_000 }
        );
        for bad in ["const:0", "const:17", "anneal:2..8", "anneal:8", "x", "0", "adaptive:x"] {
            assert!(BitSchedule::parse(bad).is_err(), "{bad} should not parse");
        }
        assert_eq!(BitSchedule::parse("anneal:8..2").unwrap().name(), "anneal:8..2");
        assert_eq!(BitSchedule::parse("adaptive").unwrap().name(), "adaptive:auto");
        assert_eq!(BitSchedule::parse("const:3").unwrap().name(), "const:3");
    }

    #[test]
    fn anneal_is_monotone_and_hits_both_ends() {
        let total = 10;
        let widths: Vec<u8> = (0..total).map(|t| anneal_bits(8, 2, t, total)).collect();
        assert_eq!(widths[0], 8);
        assert_eq!(widths[total - 1], 2);
        for w in widths.windows(2) {
            assert!(w[0] >= w[1], "anneal went up: {widths:?}");
        }
        // Past the horizon it stays at lo.
        assert_eq!(anneal_bits(8, 2, 99, total), 2);
        // Degenerate horizons.
        assert_eq!(anneal_bits(8, 2, 0, 1), 8);
        assert_eq!(anneal_bits(8, 2, 1, 1), 2);
        assert_eq!(anneal_bits(4, 4, 3, 7), 4);
    }

    #[test]
    fn layer_map_shapes() {
        let m = LayerMap::even(100, 3);
        assert_eq!(m.len(), 3);
        assert_eq!(m.param_count(), 100);
        assert_eq!(m.segment(0), 0..33);
        assert_eq!(m.segment(2), 66..100);
        assert_eq!(m.sizes().iter().sum::<usize>(), 100);

        let w = LayerMap::whole(42);
        assert_eq!(w.len(), 1);
        assert_eq!(w.segment(0), 0..42);

        let e = LayerMap::from_extents(&[(0, 10), (10, 30), (40, 2)]).unwrap();
        assert_eq!(e.len(), 3);
        assert_eq!(e.param_count(), 42);
        assert!(LayerMap::from_extents(&[(5, 10)]).is_err(), "gap at 0");
        assert!(LayerMap::from_extents(&[(0, 10), (20, 5)]).is_err(), "hole");
        assert!(LayerMap::from_extents(&[]).is_err());
    }

    fn flat_signals(sizes: &[usize]) -> Vec<LayerSignal> {
        sizes
            .iter()
            .map(|&n| LayerSignal {
                n,
                norm: (n as f64).sqrt(),
                bound: 0.0,
            })
            .collect()
    }

    #[test]
    fn allocator_respects_budget_and_cap() {
        let signals = flat_signals(&[1000, 1000, 1000, 1000]);
        let alloc = BitAllocator::default();
        for target in [2u8, 4, 6] {
            let budget: usize = signals.iter().map(|s| segment_cost(s.n, target)).sum();
            let bits = alloc.allocate(&signals, budget);
            let spent: usize = signals
                .iter()
                .zip(&bits)
                .map(|(s, &b)| segment_cost(s.n, b))
                .sum();
            assert!(spent <= budget, "target {target}: spent {spent} > {budget}");
            // Equal layers → (nearly) uniform allocation at the target.
            for &b in &bits {
                assert!(b.abs_diff(target) <= 1, "target {target}: {bits:?}");
            }
        }
        // A huge budget caps out at MAX_BITS.
        let bits = alloc.allocate(&signals, usize::MAX);
        assert_eq!(bits, vec![MAX_BITS; 4]);
        // A budget below 1-bit cost still emits the 1-bit minimum.
        let bits = alloc.allocate(&signals, 10);
        assert_eq!(bits, vec![MIN_BITS; 4]);
    }

    #[test]
    fn allocator_spends_bits_where_the_energy_is() {
        // Layer 0 holds almost all the gradient energy: water-filling at
        // a mid budget must give it strictly more bits than the tail.
        let mut signals = flat_signals(&[1000, 1000, 1000, 1000]);
        signals[0].norm *= 30.0;
        let budget: usize = signals.iter().map(|s| segment_cost(s.n, 3)).sum();
        let bits = BitAllocator::default().allocate(&signals, budget);
        assert!(
            bits[0] > bits[1] && bits[0] > bits[3],
            "no concentration: {bits:?}"
        );
        // And the total MSE beats the uniform 3-bit split at equal budget.
        let mse = |widths: &[u8]| -> f64 {
            signals
                .iter()
                .zip(widths)
                .map(|(s, &b)| expected_mse(b, s.bound, s.norm as f32, s.n))
                .sum()
        };
        assert!(mse(&bits) < mse(&[3, 3, 3, 3]), "water-filling must beat uniform");
    }

    #[test]
    fn controller_const_and_anneal_are_uniform_unsegmented() {
        let map = LayerMap::even(1000, 4);
        let mut c = BitController::new(BitSchedule::Const(4), map.clone());
        let p = c.plan(0, 10);
        assert!(!p.segmented);
        assert_eq!(p.uniform_width(), Some(4));
        assert_eq!(p.bounds, vec![0, 1000]);

        let mut a = BitController::new(BitSchedule::Anneal { hi: 8, lo: 2 }, map);
        assert_eq!(a.plan(0, 10).uniform_width(), Some(8));
        assert_eq!(a.plan(9, 10).uniform_width(), Some(2));
        assert!(!a.plan(5, 10).segmented);
    }

    #[test]
    fn controller_adaptive_uses_observations() {
        let map = LayerMap::even(4000, 4);
        let mut c = BitController::new(BitSchedule::Adaptive { budget: 0 }, map.clone());
        assert_eq!(c.effective_budget(), uniform_cost(&map, 4));
        let cold = c.plan(0, 10);
        assert!(cold.segmented);
        assert_eq!(cold.bits.len(), 4);
        // Feed observations where layer 3 has all the energy.
        let obs: Vec<SegmentObs> = (0..4)
            .map(|l| SegmentObs {
                n: 1000,
                bits: cold.bits[l],
                norm: if l == 3 { 100.0 } else { 1.0 },
                bound: 0.1,
                wire_bytes: 0, // hand-built: stay analytic
            })
            .collect();
        c.observe(&obs, 0.0, Some(1.0));
        let warm = c.plan(1, 10);
        assert!(
            warm.bits[3] > warm.bits[0],
            "energy concentration ignored: {:?}",
            warm.bits
        );
        // Plans stay within budget.
        let spent: usize = (0..4).map(|l| segment_cost(1000, warm.bits[l])).sum();
        assert!(spent <= c.effective_budget());
    }

    #[test]
    fn controller_pressure_raises_the_floor() {
        let map = LayerMap::even(8000, 8);
        let budget = uniform_cost(&map, 2);
        let mut c = BitController::new(BitSchedule::Adaptive { budget }, map.clone());
        let obs: Vec<SegmentObs> = (0..8)
            .map(|l| SegmentObs {
                n: 1000,
                bits: 2,
                norm: if l == 0 { 50.0 } else { 1.0 },
                bound: 0.1,
                wire_bytes: 0, // hand-built: stay analytic
            })
            .collect();
        // Healthy round: tiny residual, improving loss.
        c.observe(&obs, 0.0, Some(1.0));
        assert_eq!(c.pressure(), 0);
        let healthy = c.plan(1, 10);
        assert!(c.plan_cost(&healthy) <= budget);
        assert_eq!(
            c.plan_cost(&healthy),
            healthy
                .bits
                .iter()
                .enumerate()
                .map(|(l, &b)| segment_cost(map.segment(l).len(), b))
                .sum::<usize>()
        );
        let starved = healthy.bits.iter().filter(|&&b| b == 1).count();
        assert!(starved > 0, "tight budget should starve tail layers: {:?}", healthy.bits);
        // Pressure round: residual holds most of the energy AND the loss
        // went up → the floor rises to 3 wherever the budget allows.
        c.observe(&obs, 1000.0, Some(2.0));
        assert_eq!(c.pressure(), 2);
        let pressured = c.plan(2, 10);
        assert!(
            pressured.bits.iter().filter(|&&b| b == 1).count() < starved,
            "pressure must lift starved layers: {:?} -> {:?}",
            healthy.bits,
            pressured.bits
        );
    }

    #[test]
    fn empty_scale_matches_the_analytic_allocator() {
        // allocate_scaled with no scales must be decision-for-decision the
        // analytic path (integer costs are exact in f64).
        let mut signals = flat_signals(&[1000, 400, 2500, 1000]);
        signals[2].norm *= 8.0;
        let alloc = BitAllocator::default();
        for budget in [500usize, 2000, 4000, 20_000] {
            assert_eq!(
                alloc.allocate(&signals, budget),
                alloc.allocate_scaled(&signals, budget, &[]),
                "budget {budget}"
            );
            assert_eq!(
                alloc.allocate(&signals, budget),
                alloc.allocate_scaled(&signals, budget, &[1.0; 4]),
                "budget {budget} (explicit unit scales)"
            );
        }
    }

    #[test]
    fn measured_cheaper_costs_buy_more_bits() {
        // DEFLATE makes every layer 2× cheaper than analytic: under the
        // same budget the scaled allocator must hand out strictly more
        // bits, while the *measured* spend stays within budget.
        let signals = flat_signals(&[1000, 1000, 1000, 1000]);
        let alloc = BitAllocator::default();
        let budget: usize = signals.iter().map(|s| segment_cost(s.n, 3)).sum();
        let analytic = alloc.allocate(&signals, budget);
        let scaled = alloc.allocate_scaled(&signals, budget, &[0.5; 4]);
        let total = |bits: &[u8]| bits.iter().map(|&b| b as usize).sum::<usize>();
        assert!(
            total(&scaled) > total(&analytic),
            "scaled {scaled:?} !> analytic {analytic:?}"
        );
        let measured_spend: f64 = signals
            .iter()
            .zip(&scaled)
            .map(|(s, &b)| 0.5 * segment_cost(s.n, b) as f64)
            .sum();
        assert!(measured_spend <= budget as f64);
        // Degenerate scales are clamped, never a free-for-all.
        let runaway = alloc.allocate_scaled(&signals, budget, &[0.0; 4]);
        let spend_at_min: f64 = signals
            .iter()
            .zip(&runaway)
            .map(|(s, &b)| COST_SCALE_RANGE.0 * segment_cost(s.n, b) as f64)
            .sum();
        assert!(spend_at_min <= budget as f64);
    }

    #[test]
    fn controller_learns_measured_costs() {
        let map = LayerMap::even(4000, 4);
        let mut c = BitController::new(BitSchedule::Adaptive { budget: 0 }, map.clone());
        let cold = c.plan(0, 10);
        assert!(c.cost_scale().is_none(), "no feedback yet");
        // Measured wire bytes at half the analytic size (deflate working).
        let obs: Vec<SegmentObs> = (0..4)
            .map(|l| SegmentObs {
                n: 1000,
                bits: cold.bits[l],
                norm: (1000f32).sqrt(),
                bound: 0.1,
                wire_bytes: segment_cost(1000, cold.bits[l]) / 2,
            })
            .collect();
        c.observe(&obs, 0.0, Some(1.0));
        let scales = c.cost_scale().expect("scales learned");
        assert_eq!(scales.len(), 4);
        for &s in scales {
            // One EWMA step from the ρ=1 prior toward 0.5.
            assert!((s - 0.85).abs() < 1e-9, "scale {s}");
        }
        // Repeated observation converges toward the measured ratio …
        for _ in 0..20 {
            c.observe(&obs, 0.0, Some(1.0));
        }
        let s0 = c.cost_scale().unwrap()[0];
        assert!((s0 - 0.5).abs() < 0.02, "converged scale {s0}");
        // … and the learned cheapness buys more bits at the same budget.
        let warm = c.plan(5, 10);
        let total = |bits: &[u8]| bits.iter().map(|&b| b as usize).sum::<usize>();
        assert!(
            total(&warm.bits) > total(&cold.bits),
            "measured feedback unused: cold {:?} warm {:?}",
            cold.bits,
            warm.bits
        );
        // wire_bytes == 0 keeps the previous scales (analytic fallback).
        let blank: Vec<SegmentObs> =
            obs.iter().map(|o| SegmentObs { wire_bytes: 0, ..*o }).collect();
        let before = c.cost_scale().unwrap().to_vec();
        c.observe(&blank, 0.0, Some(1.0));
        assert_eq!(c.cost_scale().unwrap(), before.as_slice());
    }

    #[test]
    fn segment_costs_count_headers() {
        assert_eq!(segment_cost(8, 1), HEADER_BYTES + 1);
        assert_eq!(segment_cost(1000, 4), HEADER_BYTES + 500);
        let map = LayerMap::even(1000, 2);
        assert_eq!(uniform_cost(&map, 4), 2 * HEADER_BYTES + 250 + 250);
    }
}
