//! Random-mask gradient sparsification (Konečný et al. [17], §4 of the
//! paper: "we utilize random masks to send parts of the gradients").
//!
//! A seeded pseudo-random mask selects `⌈keep_frac·n⌉` coordinates; only
//! their values are quantized and transmitted, together with the 8-byte
//! mask seed. The server regenerates the mask from the seed and scatters
//! the decoded values into a dense zero vector (unselected coordinates
//! contribute 0 to the FedAvg average, exactly as the paper describes —
//! "there are 50% gradients on the server [that] are 0").

use crate::util::rng::Pcg64;

/// The selected coordinates for one update, regenerable from `(seed, n)`.
#[derive(Debug, Clone)]
pub struct Mask {
    pub seed: u64,
    pub n: usize,
    pub kept: Vec<usize>,
}

/// Number of coordinates kept at fraction `f` of `n` (at least 1).
pub fn kept_count(n: usize, keep_frac: f64) -> usize {
    ((keep_frac * n as f64).ceil() as usize).clamp(1, n)
}

/// Generate the mask for `(seed, n, keep_frac)`. Client and server call the
/// same function — only the seed travels.
pub fn mask(seed: u64, n: usize, keep_frac: f64) -> Mask {
    let k = kept_count(n, keep_frac);
    let mut rng = Pcg64::new(seed, 0x5AA5);
    let mut kept = rng.sample_indices(n, k);
    kept.sort_unstable(); // sorted order makes gather/scatter cache-friendly
    Mask { seed, n, kept }
}

/// Gather the kept coordinates of `g`.
pub fn gather(g: &[f32], m: &Mask) -> Vec<f32> {
    let mut out = Vec::new();
    gather_into(g, m, &mut out);
    out
}

/// [`gather`] into a reusable buffer (cleared first).
pub fn gather_into(g: &[f32], m: &Mask, out: &mut Vec<f32>) {
    debug_assert_eq!(g.len(), m.n);
    out.clear();
    out.extend(m.kept.iter().map(|&i| g[i]));
}

/// Scatter `values` back to a dense vector (zeros elsewhere).
pub fn scatter(values: &[f32], m: &Mask) -> Vec<f32> {
    assert_eq!(values.len(), m.kept.len());
    let mut out = vec![0.0f32; m.n];
    for (&i, &v) in m.kept.iter().zip(values) {
        out[i] = v;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{forall, gradient_like};

    #[test]
    fn mask_is_deterministic_in_seed() {
        let a = mask(99, 1000, 0.1);
        let b = mask(99, 1000, 0.1);
        assert_eq!(a.kept, b.kept);
        let c = mask(100, 1000, 0.1);
        assert_ne!(a.kept, c.kept);
    }

    #[test]
    fn kept_counts() {
        assert_eq!(kept_count(1000, 0.05), 50);
        assert_eq!(kept_count(1000, 0.25), 250);
        assert_eq!(kept_count(3, 0.0), 1); // floor at 1
        assert_eq!(kept_count(10, 1.0), 10);
        assert_eq!(kept_count(7, 0.5), 4); // ceil
    }

    #[test]
    fn indices_are_sorted_distinct_in_range() {
        let m = mask(7, 500, 0.2);
        assert_eq!(m.kept.len(), 100);
        assert!(m.kept.windows(2).all(|w| w[0] < w[1]));
        assert!(m.kept.iter().all(|&i| i < 500));
    }

    #[test]
    fn gather_scatter_roundtrip() {
        forall(
            40,
            61,
            |rng, size| {
                let n = size.len(rng) * 4 + 2;
                let g = gradient_like(rng, n);
                let frac = [0.05, 0.1, 0.25, 0.5, 1.0][rng.below_usize(5)];
                (g, frac, rng.next_u64())
            },
            |(g, frac, seed)| {
                let m = mask(*seed, g.len(), *frac);
                let dense = scatter(&gather(g, &m), &m);
                // Kept coordinates survive exactly; others are zero.
                let mut kept_iter = m.kept.iter().peekable();
                g.iter().enumerate().all(|(i, &gi)| {
                    if kept_iter.peek() == Some(&&i) {
                        kept_iter.next();
                        dense[i] == gi
                    } else {
                        dense[i] == 0.0
                    }
                })
            },
        );
    }

    #[test]
    fn half_mask_keeps_half() {
        let m = mask(3, 100, 0.5);
        assert_eq!(m.kept.len(), 50);
        let g = vec![1.0f32; 100];
        let dense = scatter(&gather(&g, &m), &m);
        assert_eq!(dense.iter().filter(|&&x| x == 1.0).count(), 50);
        assert_eq!(dense.iter().filter(|&&x| x == 0.0).count(), 50);
    }

    #[test]
    fn masks_are_roughly_uniform_over_coordinates() {
        // Over many seeds, every coordinate is selected ~keep_frac of the time.
        let n = 64;
        let trials = 2000;
        let mut counts = vec![0u32; n];
        for seed in 0..trials {
            for &i in &mask(seed, n, 0.25).kept {
                counts[i] += 1;
            }
        }
        let expect = trials as f64 * 0.25;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect).abs() < expect * 0.25,
                "coordinate {i}: {c} vs {expect}"
            );
        }
    }
}
