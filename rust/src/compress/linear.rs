//! Linear (value-space) uniform quantization — the baseline family the
//! paper compares against: biased round-to-nearest and the probabilistic
//! unbiased regime of QSGD [2] / Konečný et al. [17].
//!
//! Values are quantized uniformly on `[-b_g, b_g]` with `b_g = max |g_i|`
//! (optionally top-p% clipped, for parity with the cosine ablations).
//! Combined with [`super::hadamard`] this is the paper's "linear (U, R)"
//! baseline.

use crate::util::rng::Pcg64;
use crate::util::stats::kth_largest_abs;

use super::cosine::Rounding;
use super::kernel::{self, KernelScratch};

/// How the value bound `b_g` is obtained.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ValueBound {
    /// `b_g = max |g_i|`.
    MaxAbs,
    /// `b_g` = the `⌈p%·n⌉`-th largest |g|; larger values saturate.
    ClipTopPercent(f64),
}

/// Configuration of the linear quantizer.
#[derive(Debug, Clone, Copy)]
pub struct LinearQuantizer {
    pub bits: u8,
    pub rounding: Rounding,
    pub bound: ValueBound,
}

impl LinearQuantizer {
    pub fn new(bits: u8, rounding: Rounding, bound: ValueBound) -> Self {
        assert!((1..=16).contains(&bits), "bits must be in 1..=16");
        Self {
            bits,
            rounding,
            bound,
        }
    }

    /// The paper's "linear" baseline (biased) at `s` bits.
    pub fn biased(bits: u8) -> Self {
        Self::new(bits, Rounding::Biased, ValueBound::MaxAbs)
    }

    /// The paper's "linear (U)" baseline (probabilistic unbiased, QSGD [2]).
    pub fn unbiased(bits: u8) -> Self {
        Self::new(bits, Rounding::Unbiased, ValueBound::MaxAbs)
    }

    pub fn levels(&self) -> u32 {
        1u32 << self.bits
    }

    /// Quantize. Returns codes plus the value bound needed to invert.
    pub fn quantize(&self, g: &[f32], rng: &mut Pcg64) -> LinearQuantized {
        let mut codes = Vec::new();
        let bound = self.quantize_into(g, rng, &mut codes);
        LinearQuantized {
            codes,
            bound,
            bits: self.bits,
        }
    }

    /// Quantize into a reusable buffer (the pipeline's steady-state entry
    /// point). Returns the value bound.
    pub fn quantize_into(&self, g: &[f32], rng: &mut Pcg64, codes: &mut Vec<u16>) -> f32 {
        let n = g.len();
        codes.clear();
        let bound = match self.bound {
            ValueBound::MaxAbs => g.iter().fold(0.0f32, |m, &x| m.max(x.abs())),
            ValueBound::ClipTopPercent(p) => {
                let k = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
                kth_largest_abs(g, k.min(n))
            }
        };
        if !(bound.is_finite() && bound > 0.0) {
            codes.resize(n, 0);
            return 0.0;
        }
        let max_code = (self.levels() - 1) as f32;
        let scale = max_code / (2.0 * bound);
        codes.reserve(n);
        match self.rounding {
            Rounding::Biased => {
                for &gi in g {
                    let v = (gi.clamp(-bound, bound) + bound) * scale;
                    codes.push(((v + 0.5) as u16).min(max_code as u16));
                }
            }
            Rounding::Unbiased => {
                for &gi in g {
                    let v = (gi.clamp(-bound, bound) + bound) * scale;
                    let f = v.floor();
                    let p = v - f;
                    let up = (rng.f32() < p) as u16;
                    codes.push(((f as u16) + up).min(max_code as u16));
                }
            }
        }
        bound
    }
}

/// Output of [`LinearQuantizer::quantize`].
#[derive(Debug, Clone)]
pub struct LinearQuantized {
    pub codes: Vec<u16>,
    pub bound: f32,
    pub bits: u8,
}

impl LinearQuantized {
    pub fn dequantize(&self) -> Vec<f32> {
        dequantize_codes(&self.codes, self.bound, self.bits)
    }

    /// Width of one value interval.
    pub fn interval_width(&self) -> f32 {
        2.0 * self.bound / ((1u32 << self.bits) - 1) as f32
    }
}

/// Server-side reconstruction from raw codes. LUT-backed like the cosine
/// decoder — only `2^s` levels exist per tensor (bit-identical: each LUT
/// entry is the per-element formula evaluated once).
pub fn dequantize_codes(codes: &[u16], bound: f32, bits: u8) -> Vec<f32> {
    let mut out = Vec::new();
    dequantize_codes_into(codes, bound, bits, &mut KernelScratch::new(), &mut out);
    out
}

/// [`dequantize_codes`] into reusable buffers (steady-state decode path).
pub fn dequantize_codes_into(
    codes: &[u16],
    bound: f32,
    bits: u8,
    scratch: &mut KernelScratch,
    out: &mut Vec<f32>,
) {
    kernel::dequantize_linear(codes, bound, bits, scratch, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{forall, gradient_like};

    #[test]
    fn roundtrip_error_within_half_step() {
        let mut rng = Pcg64::seeded(31);
        forall(
            30,
            32,
            |r, size| { let n = size.len(r) * 8 + 2; gradient_like(r, n) },
            |g| {
                let quant = LinearQuantizer::biased(8).quantize(g, &mut rng);
                let back = quant.dequantize();
                let tol = quant.interval_width() / 2.0 + 1e-6;
                g.iter().zip(&back).all(|(&a, &b)| (a - b).abs() <= tol)
            },
        );
    }

    #[test]
    fn error_bound_is_uniform_unlike_cosine() {
        // The defining contrast with the cosine quantizer: the linear error
        // bound does not depend on |g|.
        let q = LinearQuantizer::biased(4);
        let g = vec![0.001f32, 0.5, -0.9, 1.0, -0.002];
        let mut rng = Pcg64::seeded(33);
        let quant = q.quantize(&g, &mut rng);
        let back = quant.dequantize();
        let half = quant.interval_width() / 2.0 + 1e-6;
        for (&a, &b) in g.iter().zip(&back) {
            assert!((a - b).abs() <= half);
        }
    }

    #[test]
    fn unbiased_mean_converges_to_value() {
        let mut rng = Pcg64::seeded(34);
        let g = vec![0.031f32, -0.017, 0.004, 0.0, -0.029];
        let q = LinearQuantizer::unbiased(2);
        let reps = 6000;
        let mut acc = vec![0.0f64; g.len()];
        for _ in 0..reps {
            let quant = q.quantize(&g, &mut rng);
            for (a, v) in acc.iter_mut().zip(quant.dequantize()) {
                *a += v as f64;
            }
        }
        let step = 2.0 * 0.031 / 3.0;
        let tol = step as f64 * 4.0 / (reps as f64).sqrt() + 1e-4;
        for (i, &gi) in g.iter().enumerate() {
            let mean = acc[i] / reps as f64;
            assert!(
                (mean - gi as f64).abs() < tol,
                "i={i} mean={mean} gi={gi} tol={tol}"
            );
        }
    }

    #[test]
    fn zero_vector() {
        let mut rng = Pcg64::seeded(35);
        let q = LinearQuantizer::biased(2);
        let quant = q.quantize(&[0.0; 9], &mut rng);
        assert_eq!(quant.bound, 0.0);
        assert_eq!(quant.dequantize(), vec![0.0; 9]);
    }

    #[test]
    fn clipping_saturates_outliers() {
        let mut rng = Pcg64::seeded(36);
        let mut g = vec![0.01f32; 100];
        g[0] = 10.0;
        let q = LinearQuantizer::new(4, Rounding::Biased, ValueBound::ClipTopPercent(1.0));
        let quant = q.quantize(&g, &mut rng);
        assert!(quant.bound <= 10.0);
        let back = quant.dequantize();
        assert!(back[0] <= quant.bound + 1e-6);
        // The bulk is reconstructed within a half-step of the TIGHT bound.
        let half = quant.interval_width() / 2.0 + 1e-6;
        for (&a, &b) in g.iter().zip(&back).skip(1) {
            assert!((a - b).abs() <= half);
        }
    }

    #[test]
    fn codes_fit_in_declared_bits() {
        let mut rng = Pcg64::seeded(37);
        let g = gradient_like(&mut rng, 777);
        for bits in [1u8, 2, 4, 8] {
            let quant = LinearQuantizer::unbiased(bits).quantize(&g, &mut rng);
            assert!(quant.codes.iter().all(|&c| (c as u32) < (1u32 << bits)));
        }
    }
}
