//! Wire format for compressed gradient updates.
//!
//! Every byte the simulated network meters corresponds to this
//! serialization, so the cost tables (Table 1, Figs. 9–10 x-axes) are
//! byte-exact. Layout (little-endian):
//!
//! ```text
//! offset size field
//! 0      4    magic  "CSG1"
//! 4      1    kind_id
//! 5      1    bits
//! 6      1    flags (bit0 = deflated)
//! 7      1    reserved (0)
//! 8      4    n      (full gradient length)
//! 12     4    kept   (transmitted coordinate count)
//! 16     8    mask_seed
//! 24     8    rot_seed
//! 32     4    norm   (f32)
//! 36     4    bound  (f32)
//! 40     4    payload_len
//! 44     ..   payload
//! ```

use anyhow::{bail, ensure, Result};

use super::codec::EncodedGradient;

pub const MAGIC: [u8; 4] = *b"CSG1";
pub const HEADER_BYTES: usize = 44;

/// Serialize an encoded gradient to wire bytes.
pub fn serialize(enc: &EncodedGradient) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_BYTES + enc.payload.len());
    out.extend_from_slice(&MAGIC);
    out.push(enc.kind_id);
    out.push(enc.bits);
    out.push(enc.deflated as u8);
    out.push(0);
    out.extend_from_slice(&enc.n.to_le_bytes());
    out.extend_from_slice(&enc.kept.to_le_bytes());
    out.extend_from_slice(&enc.mask_seed.to_le_bytes());
    out.extend_from_slice(&enc.rot_seed.to_le_bytes());
    out.extend_from_slice(&enc.norm.to_le_bytes());
    out.extend_from_slice(&enc.bound.to_le_bytes());
    out.extend_from_slice(&(enc.payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&enc.payload);
    out
}

/// Parse wire bytes back into an [`EncodedGradient`].
pub fn deserialize(bytes: &[u8]) -> Result<EncodedGradient> {
    ensure!(bytes.len() >= HEADER_BYTES, "short update: {}", bytes.len());
    if bytes[0..4] != MAGIC {
        bail!("bad magic {:02x?}", &bytes[0..4]);
    }
    let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
    let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
    let f32_at = |o: usize| f32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());

    let kind_id = bytes[4];
    ensure!(kind_id <= 6, "unknown codec id {kind_id}");
    let bits = bytes[5];
    ensure!(bits == 32 || (1..=16).contains(&bits), "bad bits {bits}");
    let flags = bytes[6];
    let n = u32_at(8);
    let kept = u32_at(12);
    ensure!(kept <= n.max(1), "kept {kept} > n {n}");
    let payload_len = u32_at(40) as usize;
    ensure!(
        bytes.len() == HEADER_BYTES + payload_len,
        "length mismatch: {} vs {}",
        bytes.len(),
        HEADER_BYTES + payload_len
    );
    Ok(EncodedGradient {
        kind_id,
        bits,
        n,
        kept,
        mask_seed: u64_at(16),
        rot_seed: u64_at(24),
        norm: f32_at(32),
        bound: f32_at(36),
        deflated: flags & 1 == 1,
        payload: bytes[HEADER_BYTES..].to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::codec::{ClientCodecState, Codec};
    use crate::util::propcheck::{forall, gradient_like};
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_simple() {
        let enc = EncodedGradient {
            kind_id: 1,
            bits: 2,
            n: 100,
            kept: 50,
            mask_seed: 0xDEADBEEF,
            rot_seed: 42,
            norm: 1.5,
            bound: 0.25,
            deflated: true,
            payload: vec![1, 2, 3, 4, 5],
        };
        let bytes = serialize(&enc);
        assert_eq!(bytes.len(), HEADER_BYTES + 5);
        assert_eq!(deserialize(&bytes).unwrap(), enc);
    }

    #[test]
    fn wire_bytes_matches_serialized_len() {
        let mut rng = Pcg64::seeded(121);
        let g = gradient_like(&mut rng, 5000);
        let codec = Codec::cosine(4).with_sparsify(0.25);
        let enc = codec.encode(&g, &mut ClientCodecState::new(), &mut rng);
        assert_eq!(serialize(&enc).len(), enc.wire_bytes());
    }

    #[test]
    fn rejects_corruption() {
        let enc = EncodedGradient {
            kind_id: 1,
            bits: 2,
            n: 10,
            kept: 10,
            mask_seed: 0,
            rot_seed: 0,
            norm: 1.0,
            bound: 0.0,
            deflated: false,
            payload: vec![0; 3],
        };
        let mut bytes = serialize(&enc);
        bytes[0] = b'X'; // magic
        assert!(deserialize(&bytes).is_err());
        let mut bytes = serialize(&enc);
        bytes[4] = 99; // kind id
        assert!(deserialize(&bytes).is_err());
        let mut bytes = serialize(&enc);
        bytes.truncate(bytes.len() - 1); // length
        assert!(deserialize(&bytes).is_err());
        assert!(deserialize(&bytes[..10]).is_err());
    }

    #[test]
    fn property_roundtrip_via_codec() {
        forall(
            25,
            122,
            |rng, size| { let n = size.len(rng) * 16 + 4; gradient_like(rng, n) },
            |g| {
                let mut rng = Pcg64::seeded(g.len() as u64);
                let codec = Codec::cosine(2).with_sparsify(0.5);
                let enc = codec.encode(g, &mut ClientCodecState::new(), &mut rng);
                let back = deserialize(&serialize(&enc)).unwrap();
                back == enc
                    && codec.decode(&back).unwrap() == codec.decode(&enc).unwrap()
            },
        );
    }
}
