//! Wire format for compressed tensors — version 2 (`CSG2`).
//!
//! Every byte the simulated network meters corresponds to this
//! serialization, so the cost tables (Table 1, Figs. 9–10 x-axes) are
//! byte-exact. Layout (little-endian):
//!
//! ```text
//! offset size field
//! 0      4    magic  "CSG2"
//! 4      1    kind_id   (quantizer wire id, see compress::quantizer::ids)
//! 5      1    bits      (32 for float32 passthrough, else 1..=16)
//! 6      1    flags     (bit0 = deflated, bit1 = rotated; others reserved 0)
//! 7      1    direction (0 = uplink, 1 = downlink)
//! 8      4    n         (full tensor length)
//! 12     4    kept      (transmitted coordinate count)
//! 16     8    mask_seed
//! 24     8    rot_seed
//! 32     4    norm      (f32)
//! 36     4    bound     (f32)
//! 40     4    payload_len
//! 44     ..   payload
//! ```
//!
//! ## CSG1 → CSG2 delta
//!
//! The header is the same 44 bytes as CSG1, so all CSG1 cost accounting
//! carries over byte-for-byte. Changes: the magic is bumped; the CSG1
//! reserved byte at offset 7 now carries the [`Direction`] tag; flags
//! bit 1 marks a Hadamard-rotated payload (CSG1 fused rotation into the
//! retired kind id 3); and frames are self-describing — the receiver
//! reconstructs the dequantizer from `(kind_id, bits)` alone.

use anyhow::{bail, ensure, Result};

use super::pipeline::{Direction, EncodedTensor};
use super::quantizer;

pub const MAGIC: [u8; 4] = *b"CSG2";
/// The retired version-1 magic, rejected with a dedicated message.
pub const MAGIC_V1: [u8; 4] = *b"CSG1";
pub const HEADER_BYTES: usize = 44;

const FLAG_DEFLATED: u8 = 1 << 0;
const FLAG_ROTATED: u8 = 1 << 1;
const KNOWN_FLAGS: u8 = FLAG_DEFLATED | FLAG_ROTATED;

/// Serialize an encoded tensor to wire bytes.
pub fn serialize(enc: &EncodedTensor) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_BYTES + enc.payload.len());
    serialize_into(enc, &mut out);
    out
}

/// Append one frame's wire bytes to `out` (no intermediate allocation —
/// the segment-stream encode path appends straight into one buffer).
pub fn serialize_into(enc: &EncodedTensor, out: &mut Vec<u8>) {
    write_header(enc, enc.deflated, enc.payload.len() as u32, out);
    out.extend_from_slice(&enc.payload);
}

/// Append the 44-byte header for `enc`, with the deflated flag and
/// payload length supplied by the caller (the streaming path knows them
/// only after the payload lands).
fn write_header(enc: &EncodedTensor, deflated: bool, payload_len: u32, out: &mut Vec<u8>) {
    out.extend_from_slice(&MAGIC);
    out.push(enc.kind_id);
    out.push(enc.bits);
    let mut flags = 0u8;
    if deflated {
        flags |= FLAG_DEFLATED;
    }
    if enc.rotated {
        flags |= FLAG_ROTATED;
    }
    out.push(flags);
    out.push(enc.direction.id());
    out.extend_from_slice(&enc.n.to_le_bytes());
    out.extend_from_slice(&enc.kept.to_le_bytes());
    out.extend_from_slice(&enc.mask_seed.to_le_bytes());
    out.extend_from_slice(&enc.rot_seed.to_le_bytes());
    out.extend_from_slice(&enc.norm.to_le_bytes());
    out.extend_from_slice(&enc.bound.to_le_bytes());
    out.extend_from_slice(&payload_len.to_le_bytes());
}

/// Streaming serialization: append the header to `out`, let
/// `write_payload` append the payload bytes directly behind it (e.g. a
/// DEFLATE stage compressing straight into the wire buffer), then patch
/// `payload_len` and the deflated flag to match what actually landed.
/// The callback returns whether the bytes it wrote are DEFLATE-compressed;
/// that bool is recorded in the flags byte and returned. `enc.payload`
/// and `enc.deflated` are ignored — the callback is the payload source.
/// The appended bytes are identical to [`serialize_into`] on a tensor
/// carrying the same `(payload, deflated)` pair.
pub fn serialize_with<F>(enc: &EncodedTensor, out: &mut Vec<u8>, write_payload: F) -> bool
where
    F: FnOnce(&mut Vec<u8>) -> bool,
{
    let header_at = out.len();
    write_header(enc, false, 0, out);
    let payload_at = out.len();
    let deflated = write_payload(out);
    let payload_len = (out.len() - payload_at) as u32;
    // Patch bytes this function just appended (output-side, never
    // input-driven); `get_mut` keeps the module free of panicking
    // indexing, and both lookups always succeed.
    // `payload_len` is the last header field: bytes HEADER_BYTES-4..HEADER_BYTES.
    if let Some(slot) = out.get_mut(header_at + HEADER_BYTES - 4..header_at + HEADER_BYTES) {
        slot.copy_from_slice(&payload_len.to_le_bytes());
    }
    if deflated {
        if let Some(flags) = out.get_mut(header_at + 6) {
            *flags |= FLAG_DEFLATED;
        }
    }
    deflated
}

/// Serialize a *stream* of encoded tensors: the segments of one logical
/// update, concatenated. Each CSG2 frame is self-describing (its header
/// carries `payload_len`), so the stream needs no extra framing — the
/// receiver walks it with [`deserialize_stream`]. A single-segment stream
/// is byte-identical to [`serialize`] — the adaptive bit controller's
/// mixed-width payloads and the legacy single-frame payloads share one
/// wire grammar.
pub fn serialize_stream(segments: &[EncodedTensor]) -> Vec<u8> {
    let total: usize = segments.iter().map(|s| HEADER_BYTES + s.payload.len()).sum();
    let mut out = Vec::with_capacity(total);
    for seg in segments {
        serialize_into(seg, &mut out);
    }
    out
}

/// Parse one frame off the front of `bytes`, tolerating trailing data
/// (the next segments of a stream). Returns the tensor and the bytes
/// consumed.
pub fn deserialize_prefix(bytes: &[u8]) -> Result<(EncodedTensor, usize)> {
    let enc = parse_one(bytes, false)?;
    let consumed = HEADER_BYTES + enc.payload.len();
    Ok((enc, consumed))
}

/// Parse a whole stream of concatenated CSG2 frames (at least one; every
/// byte must belong to a frame).
pub fn deserialize_stream(bytes: &[u8]) -> Result<Vec<EncodedTensor>> {
    let mut out = Vec::new();
    let mut rest = bytes;
    while !rest.is_empty() {
        let (enc, used) = deserialize_prefix(rest)?;
        out.push(enc);
        // `used <= rest.len()` is guaranteed by the non-truncation check
        // inside parse_one; get() keeps the hostile-input path panic-free
        // regardless.
        rest = rest.get(used..).unwrap_or(&[]);
    }
    ensure!(!out.is_empty(), "empty frame stream");
    Ok(out)
}

/// Parse wire bytes back into an [`EncodedTensor`], rejecting malformed
/// headers (bad magic, unknown quantizer identity, unknown flags,
/// truncated or oversized payload).
pub fn deserialize(bytes: &[u8]) -> Result<EncodedTensor> {
    parse_one(bytes, true)
}

/// Fixed-width field read — the panic-free replacement for
/// `bytes[o..o + N].try_into().unwrap()`. Hostile inputs hit the length
/// `ensure!` in `parse_one` first, but every access stays fallible so no
/// future reordering can reintroduce a decode panic.
fn arr<const N: usize>(bytes: &[u8], at: usize) -> Result<[u8; N]> {
    let end = at
        .checked_add(N)
        .ok_or_else(|| anyhow::anyhow!("field offset overflow at {at}"))?;
    bytes
        .get(at..end)
        .and_then(|s| <[u8; N]>::try_from(s).ok())
        .ok_or_else(|| anyhow::anyhow!("truncated frame: no field at {at}..{end}"))
}

fn byte_at(bytes: &[u8], at: usize) -> Result<u8> {
    bytes
        .get(at)
        .copied()
        .ok_or_else(|| anyhow::anyhow!("truncated frame: no byte at {at}"))
}

fn parse_one(bytes: &[u8], exact: bool) -> Result<EncodedTensor> {
    ensure!(bytes.len() >= HEADER_BYTES, "short frame: {}", bytes.len());
    let magic: [u8; 4] = arr(bytes, 0)?;
    if magic == MAGIC_V1 {
        bail!("legacy CSG1 frame: this build speaks CSG2 (same header size; see compress::wire)");
    }
    if magic != MAGIC {
        bail!("bad magic {magic:02x?}");
    }
    let u32_at = |o: usize| -> Result<u32> { Ok(u32::from_le_bytes(arr(bytes, o)?)) };
    let u64_at = |o: usize| -> Result<u64> { Ok(u64::from_le_bytes(arr(bytes, o)?)) };
    let f32_at = |o: usize| -> Result<f32> { Ok(f32::from_le_bytes(arr(bytes, o)?)) };

    let kind_id = byte_at(bytes, 4)?;
    let bits = byte_at(bytes, 5)?;
    // Validates (kind_id, bits) jointly — unknown ids and bad widths bail.
    quantizer::validate_wire(kind_id, bits)?;
    let flags = byte_at(bytes, 6)?;
    ensure!(flags & !KNOWN_FLAGS == 0, "unknown flags {flags:#04x}");
    let direction = Direction::from_id(byte_at(bytes, 7)?)?;
    let n = u32_at(8)?;
    let kept = u32_at(12)?;
    ensure!(kept <= n.max(1), "kept {kept} > n {n}");
    let payload_len = u32_at(40)? as usize;
    let frame_len = HEADER_BYTES
        .checked_add(payload_len)
        .ok_or_else(|| anyhow::anyhow!("payload_len overflow: {payload_len}"))?;
    if exact {
        ensure!(
            bytes.len() == frame_len,
            "length mismatch: {} vs {}",
            bytes.len(),
            frame_len
        );
    } else {
        ensure!(
            bytes.len() >= frame_len,
            "truncated frame: {} < {}",
            bytes.len(),
            frame_len
        );
    }
    let payload = bytes
        .get(HEADER_BYTES..frame_len)
        .map(<[u8]>::to_vec)
        .ok_or_else(|| anyhow::anyhow!("truncated payload: {} < {frame_len}", bytes.len()))?;
    Ok(EncodedTensor {
        direction,
        kind_id,
        bits,
        n,
        kept,
        mask_seed: u64_at(16)?,
        rot_seed: u64_at(24)?,
        rotated: flags & FLAG_ROTATED != 0,
        norm: f32_at(32)?,
        bound: f32_at(36)?,
        deflated: flags & FLAG_DEFLATED != 0,
        payload,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::pipeline::{decode, Pipeline, PipelineState};
    use crate::util::propcheck::{forall, gradient_like};
    use crate::util::rng::Pcg64;

    fn sample() -> EncodedTensor {
        EncodedTensor {
            direction: Direction::Downlink,
            kind_id: 1,
            bits: 2,
            n: 100,
            kept: 50,
            mask_seed: 0xDEADBEEF,
            rot_seed: 42,
            rotated: false,
            norm: 1.5,
            bound: 0.25,
            deflated: true,
            payload: vec![1, 2, 3, 4, 5],
        }
    }

    #[test]
    fn roundtrip_simple() {
        let enc = sample();
        let bytes = serialize(&enc);
        assert_eq!(bytes.len(), HEADER_BYTES + 5);
        assert_eq!(deserialize(&bytes).unwrap(), enc);
    }

    #[test]
    fn serialize_with_matches_serialize() {
        let enc = sample();
        let direct = serialize(&enc);
        // The streaming path gets metadata only (empty payload, flag off).
        let mut meta = enc.clone();
        meta.payload = Vec::new();
        meta.deflated = false;
        let mut out = vec![0xEE]; // pre-existing bytes must survive
        let deflated = serialize_with(&meta, &mut out, |buf| {
            buf.extend_from_slice(&enc.payload);
            true
        });
        assert!(deflated);
        assert_eq!(out[0], 0xEE);
        assert_eq!(&out[1..], &direct[..]);
        // A callback reporting "not deflated" leaves the flag clear.
        let mut out2 = Vec::new();
        assert!(!serialize_with(&meta, &mut out2, |buf| {
            buf.extend_from_slice(&enc.payload);
            false
        }));
        let back = deserialize(&out2).unwrap();
        assert!(!back.deflated);
        assert_eq!(back.payload, enc.payload);
    }

    #[test]
    fn direction_and_rotation_flags_roundtrip() {
        let mut enc = sample();
        enc.direction = Direction::Uplink;
        enc.rotated = true;
        let back = deserialize(&serialize(&enc)).unwrap();
        assert_eq!(back.direction, Direction::Uplink);
        assert!(back.rotated);
    }

    #[test]
    fn wire_bytes_matches_serialized_len() {
        let mut rng = Pcg64::seeded(121);
        let g = gradient_like(&mut rng, 5000);
        let pipe = Pipeline::cosine(4).with_sparsify(0.25);
        let enc = pipe.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
        assert_eq!(serialize(&enc).len(), enc.wire_bytes());
    }

    #[test]
    fn rejects_corruption() {
        let enc = sample();
        let mut bytes = serialize(&enc);
        bytes[0] = b'X'; // magic
        assert!(deserialize(&bytes).is_err());
        let mut bytes = serialize(&enc);
        bytes[4] = 99; // kind id
        assert!(deserialize(&bytes).is_err());
        let mut bytes = serialize(&enc);
        bytes[4] = 3; // retired CSG1 linear-rotated id
        assert!(deserialize(&bytes).is_err());
        let mut bytes = serialize(&enc);
        bytes[6] |= 0x80; // unknown flag
        assert!(deserialize(&bytes).is_err());
        let mut bytes = serialize(&enc);
        bytes[7] = 9; // bad direction
        assert!(deserialize(&bytes).is_err());
        let mut bytes = serialize(&enc);
        bytes.truncate(bytes.len() - 1); // truncated payload
        assert!(deserialize(&bytes).is_err());
        assert!(deserialize(&bytes[..10]).is_err()); // truncated header
        let mut bytes = serialize(&enc);
        bytes[40..44].copy_from_slice(&u32::MAX.to_le_bytes()); // oversized payload_len
        assert!(deserialize(&bytes).is_err());
    }

    #[test]
    fn rejects_legacy_csg1_with_clear_error() {
        let mut bytes = serialize(&sample());
        bytes[0..4].copy_from_slice(&MAGIC_V1);
        let err = deserialize(&bytes).unwrap_err().to_string();
        assert!(err.contains("CSG1"), "unexpected error: {err}");
    }

    #[test]
    fn stream_roundtrip_and_prefix_parsing() {
        // A stream of three segments with THREE different widths: the
        // self-describing headers carry the split.
        let mut rng = Pcg64::seeded(321);
        let mut segs = Vec::new();
        for bits in [2u8, 5, 8] {
            let g = gradient_like(&mut rng, 300 + bits as usize);
            let pipe = Pipeline::cosine(bits);
            segs.push(pipe.encode(&g, Direction::Uplink, &mut PipelineState::new(), &mut rng));
        }
        let stream = serialize_stream(&segs);
        // Single-segment stream == plain serialize, byte for byte.
        assert_eq!(serialize_stream(&segs[..1]), serialize(&segs[0]));
        // Prefix parse peels exactly the first frame.
        let (first, used) = deserialize_prefix(&stream).unwrap();
        assert_eq!(first, segs[0]);
        assert_eq!(used, HEADER_BYTES + segs[0].payload.len());
        // Full stream parse recovers every segment in order.
        let back = deserialize_stream(&stream).unwrap();
        assert_eq!(back, segs);
        // Strict deserialize still rejects trailing bytes.
        assert!(deserialize(&stream).is_err());
        // A truncated tail poisons the stream parse.
        assert!(deserialize_stream(&stream[..stream.len() - 1]).is_err());
        assert!(deserialize_stream(&[]).is_err());
    }

    #[test]
    fn property_roundtrip_via_pipeline() {
        forall(
            25,
            122,
            |rng, size| {
                let n = size.len(rng) * 16 + 4;
                gradient_like(rng, n)
            },
            |g| {
                let mut rng = Pcg64::seeded(g.len() as u64);
                let pipe = Pipeline::cosine(2).with_sparsify(0.5);
                let enc =
                    pipe.encode(g, Direction::Uplink, &mut PipelineState::new(), &mut rng);
                let back = deserialize(&serialize(&enc)).unwrap();
                back == enc && decode(&back).unwrap() == decode(&enc).unwrap()
            },
        );
    }
}
