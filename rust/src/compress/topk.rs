//! Top-K gradient sparsification (Aji & Heafield [1], Lin et al. [22]) —
//! the *other* sparsification family the paper discusses (§2.2) and an
//! extension point beyond its random-mask experiments.
//!
//! Unlike the random mask, the selected indices depend on the data, so the
//! index set must travel on the wire: we transmit sorted indices
//! delta-encoded as LEB128 varints (small gaps ⇒ ~1 byte each after
//! DEFLATE), plus the values — which can then be quantized by any codec.

use crate::util::stats::kth_largest_abs;

/// Select the `k` largest-|g| coordinates. Returns sorted indices.
pub fn top_k_indices(g: &[f32], k: usize) -> Vec<usize> {
    let k = k.clamp(1, g.len().max(1));
    if g.is_empty() {
        return Vec::new();
    }
    let thresh = kth_largest_abs(g, k);
    // >= thresh may exceed k on ties: take ties in index order up to k.
    let mut idx: Vec<usize> = Vec::with_capacity(k);
    for (i, &v) in g.iter().enumerate() {
        if v.abs() > thresh {
            idx.push(i);
        }
    }
    for (i, &v) in g.iter().enumerate() {
        if idx.len() >= k {
            break;
        }
        if v.abs() == thresh {
            idx.push(i);
        }
    }
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

/// Gather values at `indices`.
pub fn gather(g: &[f32], indices: &[usize]) -> Vec<f32> {
    indices.iter().map(|&i| g[i]).collect()
}

/// Scatter values back into a dense zero vector of length `n`.
pub fn scatter(values: &[f32], indices: &[usize], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for (&i, &v) in indices.iter().zip(values) {
        out[i] = v;
    }
    out
}

/// Delta + LEB128 encode sorted indices.
pub fn encode_indices(indices: &[usize]) -> Vec<u8> {
    let mut out = Vec::with_capacity(indices.len());
    let mut prev = 0usize;
    for (pos, &i) in indices.iter().enumerate() {
        let gap = if pos == 0 { i } else { i - prev - 1 };
        let mut v = gap as u64;
        loop {
            let byte = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                out.push(byte);
                break;
            }
            out.push(byte | 0x80);
        }
        prev = i;
    }
    out
}

/// Decode `count` indices from the varint stream.
pub fn decode_indices(bytes: &[u8], count: usize) -> anyhow::Result<Vec<usize>> {
    let mut out = Vec::with_capacity(count);
    let mut pos = 0usize;
    let mut prev = 0usize;
    for i in 0..count {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = *bytes
                .get(pos)
                .ok_or_else(|| anyhow::anyhow!("truncated index stream"))?;
            pos += 1;
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                break;
            }
            shift += 7;
            anyhow::ensure!(shift < 64, "varint overflow");
        }
        let idx = if i == 0 {
            v as usize
        } else {
            prev + 1 + v as usize
        };
        out.push(idx);
        prev = idx;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{forall, gradient_like};
    use crate::util::rng::Pcg64;

    #[test]
    fn selects_largest_magnitudes() {
        let g = [0.1f32, -5.0, 0.2, 3.0, -0.05, 4.0];
        let idx = top_k_indices(&g, 3);
        assert_eq!(idx, vec![1, 3, 5]);
    }

    #[test]
    fn handles_ties_deterministically() {
        let g = [1.0f32, 1.0, 1.0, 1.0];
        let idx = top_k_indices(&g, 2);
        assert_eq!(idx.len(), 2);
        assert_eq!(idx, vec![0, 1]); // first ties in index order
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let g = [0.0f32, 2.0, 0.0, -3.0, 1.0];
        let idx = top_k_indices(&g, 2);
        let vals = gather(&g, &idx);
        let dense = scatter(&vals, &idx, g.len());
        assert_eq!(dense, vec![0.0, 2.0, 0.0, -3.0, 0.0]);
    }

    #[test]
    fn index_codec_roundtrip() {
        forall(
            60,
            71,
            |rng, size| {
                let n = size.len(rng) * 20 + 5;
                let k = 1 + rng.below_usize(n);
                let g = gradient_like(rng, n);
                (g, k)
            },
            |(g, k)| {
                let idx = top_k_indices(g, *k);
                let enc = encode_indices(&idx);
                decode_indices(&enc, idx.len()).unwrap() == idx
            },
        );
    }

    #[test]
    fn varints_compact_for_dense_selections() {
        // 10% of 10_000: average gap 9 -> 1 byte each.
        let mut rng = Pcg64::seeded(3);
        let g = gradient_like(&mut rng, 10_000);
        let idx = top_k_indices(&g, 1000);
        let enc = encode_indices(&idx);
        assert!(enc.len() <= 2 * idx.len(), "{} bytes for {}", enc.len(), idx.len());
    }

    #[test]
    fn decode_rejects_truncation() {
        let idx = vec![5usize, 300, 301];
        let enc = encode_indices(&idx);
        assert!(decode_indices(&enc[..enc.len() - 1], 3).is_err());
    }

    #[test]
    fn top_k_preserves_energy_better_than_random() {
        // The reason [22] uses it: top-k keeps most of the l2 energy.
        let mut rng = Pcg64::seeded(4);
        let g = gradient_like(&mut rng, 5000);
        let k = 250; // 5%
        let idx = top_k_indices(&g, k);
        let topk_energy: f64 = idx.iter().map(|&i| (g[i] as f64).powi(2)).sum();
        let rand_idx = rng.sample_indices(g.len(), k);
        let rand_energy: f64 = rand_idx.iter().map(|&i| (g[i] as f64).powi(2)).sum();
        let total: f64 = g.iter().map(|&x| (x as f64).powi(2)).sum();
        assert!(topk_energy / total > 0.5, "{}", topk_energy / total);
        assert!(topk_energy > 3.0 * rand_energy);
    }
}
