//! The compression perf-trajectory suite: one stable set of bench cases
//! (ns/elem for quantize / dequantize / pack / unpack per bit width, plus
//! end-to-end round time) shared by `repro bench`, the `bench_kernel`
//! bench target and the `#[ignore]`d bench-guard test, and recorded to
//! `BENCH_compress.json` so the numbers are comparable across PRs.

use crate::util::bench::{BenchResult, Bencher};
use crate::util::propcheck::gradient_like;
use crate::util::rng::Pcg64;

use super::bitpack;
use super::cosine::{self, BoundMode, CosineQuantizer, Rounding};
use super::kernel::KernelScratch;
use super::pipeline::{decode_with, Direction, EncodeScratch, Pipeline, PipelineState};
use super::wire;

/// Trajectory suite tag (the file is `BENCH_compress.json`).
pub const SUITE: &str = "compress";

/// The acceptance-criterion pair: 4-bit biased cosine quantize+pack,
/// kernel (threshold search, reused scratch) vs reference (`acos` loop).
/// Fixed angle bound so both sides measure the nonlinear map itself, not
/// the shared O(n) bound selection.
pub const HEADLINE_KERNEL: &str = "quantize+pack/cosine-biased-kernel/4b";
pub const HEADLINE_REFERENCE: &str = "quantize+pack/cosine-biased-reference/4b";

/// Bit widths each per-stage case sweeps.
pub const BIT_WIDTHS: [u8; 5] = [1, 2, 4, 8, 16];

/// Run the whole suite on an `n`-element gradient-like tensor.
pub fn run_suite(b: &mut Bencher, n: usize, seed: u64) {
    let mut rng = Pcg64::seeded(seed);
    let g = gradient_like(&mut rng, n);
    let mut scratch = KernelScratch::new();
    let mut codes_buf: Vec<u16> = Vec::new();
    let mut packed_buf: Vec<u8> = Vec::new();
    let mut values_buf: Vec<f32> = Vec::new();

    println!("== compress perf trajectory (n = {n}) ==");
    for bits in BIT_WIDTHS {
        let q = CosineQuantizer::paper_default(bits);
        b.bench_elems(
            &format!("quantize/cosine-biased-kernel/{bits}b"),
            n as u64,
            || q.quantize_into(&g, &mut Pcg64::seeded(2), &mut scratch, &mut codes_buf),
        );
        b.bench_elems(
            &format!("quantize/cosine-biased-reference/{bits}b"),
            n as u64,
            || q.quantize_reference(&g, &mut Pcg64::seeded(2)),
        );
        let quant = q.quantize(&g, &mut rng);
        b.bench_elems(&format!("dequantize/cosine/{bits}b"), n as u64, || {
            cosine::dequantize_codes_into(
                &quant.codes,
                quant.norm,
                quant.bound,
                bits,
                &mut scratch,
                &mut values_buf,
            )
        });
        b.bench_elems(&format!("pack/{bits}b"), n as u64, || {
            bitpack::pack_into(&quant.codes, bits, &mut packed_buf)
        });
        let packed = bitpack::pack(&quant.codes, bits);
        b.bench_elems(&format!("unpack/{bits}b"), n as u64, || {
            bitpack::unpack_into(&packed, bits, n, &mut codes_buf)
        });
    }

    // Headline pair (see const docs): fixed bound isolates the map.
    let qh = CosineQuantizer::new(4, Rounding::Biased, BoundMode::FixedAngle(0.1));
    b.bench_elems(HEADLINE_KERNEL, n as u64, || {
        qh.quantize_into(&g, &mut Pcg64::seeded(2), &mut scratch, &mut codes_buf);
        bitpack::pack_into(&codes_buf, 4, &mut packed_buf);
    });
    b.bench_elems(HEADLINE_REFERENCE, n as u64, || {
        let q = qh.quantize_reference(&g, &mut Pcg64::seeded(2));
        bitpack::pack(&q.codes, 4)
    });

    // End-to-end round time: encode → wire → decode, per direction of the
    // paper's default round trip plus the float32 baseline.
    for pipe in [Pipeline::cosine(4), Pipeline::cosine(8), Pipeline::float32()] {
        let mut st = PipelineState::new();
        let mut esc = EncodeScratch::new();
        let label = format!("round/{}", pipe.name());
        b.bench_elems(&label, n as u64, || {
            let enc = pipe.encode_with(
                &g,
                Direction::Uplink,
                &mut st,
                &mut Pcg64::seeded(3),
                &mut esc,
            );
            let bytes = wire::serialize(&enc);
            let back = wire::deserialize(&bytes).unwrap();
            decode_with(&back, &mut esc).unwrap()
        });
    }
}

/// Kernel-vs-reference speedup of the headline pair, when both ran.
pub fn headline_speedup(results: &[BenchResult]) -> Option<f64> {
    let find = |name: &str| results.iter().find(|r| r.name == name);
    let kernel = find(HEADLINE_KERNEL)?;
    let reference = find(HEADLINE_REFERENCE)?;
    Some(reference.mean.as_secs_f64() / kernel.mean.as_secs_f64().max(1e-12))
}
