//! Transcendental-free fast paths for the quantization hot loop.
//!
//! The paper sells CosSGD on "low computational complexity" (§3, §5), but
//! the naive encode pays one `acos` per element and the decode one `cos`
//! per element. Both collapse because the codes are *discrete*:
//!
//! * **Quantize** (biased rounding): the angle-domain bin edges
//!   `θ_k = b + (k + 0.5)·step` map through the monotone-decreasing `cos`
//!   into `2^s − 1` *value-domain* thresholds. A code is then just "how
//!   many thresholds lie above `g_i/‖g‖`" — a branchless binary search
//!   over a per-tensor table, zero transcendentals per element.
//! * **Dequantize**: only `2^s` distinct reconstruction values exist per
//!   tensor; build them once (`2^s` `cos` calls) and index.
//!
//! ## Bit-exactness contract
//!
//! The fast path must be **bit-identical** to the reference `acos` path
//! ([`CosineQuantizer::quantize_reference`]), which rounds in f32:
//!
//! ```text
//! code(x) = ⌊(clamp(acos(clamp(x,-1,1)), b, π−b) − b)·scale + 0.5⌋
//! ```
//!
//! `code` is monotone non-increasing in `x` (every stage — `acos`, the
//! clamps, the affine map, the floor — is monotone, including under f32
//! rounding), so for every boundary `k` there is an exact f32 threshold
//! `t_k = min{x : code(x) ≤ k}`. We *seed* each threshold with the
//! analytic `cos(θ_k)` and then pin it down exactly with a bit-level
//! binary search driven by the reference scalar map itself — so the table
//! is correct by construction even where libm rounding shifts a boundary
//! by an ULP. Construction costs `O(2^s · log)` reference evaluations per
//! tensor, amortized to nothing against element counts in the millions.
//!
//! The `Rounding::Unbiased` regime draws a uniform per element, so its
//! codes are not a pure function of `x`; it keeps the reference path.
//!
//! [`CosineQuantizer::quantize_reference`]: super::cosine::CosineQuantizer::quantize_reference

use std::f32::consts::PI;

/// Reusable buffers + memoization keys for the kernel fast paths. One per
/// long-lived endpoint (client, server); embedded in
/// [`super::pipeline::EncodeScratch`].
#[derive(Debug, Clone, Default)]
pub struct KernelScratch {
    /// Descending value-domain thresholds for the biased cosine quantizer.
    thresholds: Vec<f32>,
    /// `(bits, bound.to_bits())` the threshold table was built for.
    thresholds_key: Option<(u8, u32)>,
    /// Reconstruction LUT (`2^s` entries) for the cosine dequantizer.
    cos_levels: Vec<f32>,
    cos_levels_key: Option<(u8, u32, u32)>,
    /// Reconstruction LUT for the linear dequantizer.
    lin_levels: Vec<f32>,
    lin_levels_key: Option<(u8, u32)>,
}

impl KernelScratch {
    pub fn new() -> KernelScratch {
        Self::default()
    }
}

// ---------------------------------------------------------------------------
// Scalar reference map + exact threshold construction.
// ---------------------------------------------------------------------------

/// The quantizer scale factor, computed exactly as the reference encode
/// prologue (`cosine.rs`): `0.0` marks the degenerate all-code-0 regime.
#[inline]
pub fn scale_for(bits: u8, bound: f32) -> f32 {
    let max_code = ((1u32 << bits) - 1) as f32;
    let range = PI - 2.0 * bound;
    let inv_range = if range > 1e-6 { 1.0 / range } else { 0.0 };
    inv_range * max_code
}

/// The reference biased code for a pre-normalized ratio `x = g_i/‖g‖`
/// (public as the ground truth for the equivalence tests). Must stay
/// textually identical to the element step of
/// [`super::cosine::CosineQuantizer::quantize_reference`].
// analyze: allow(hotpath): the reference path is the acos ground truth the fast path is tested against
#[inline]
pub fn reference_code(x: f32, bound: f32, scale: f32) -> u16 {
    let theta = x.clamp(-1.0, 1.0).acos().clamp(bound, PI - bound);
    let v = (theta - bound) * scale;
    (v + 0.5) as u16 // round-to-nearest, v >= 0
}

/// Monotone bijection f32 → u32 (IEEE-754 total order on non-NaN values):
/// lets the threshold search bisect over *representable* values.
#[inline]
fn ordered(x: f32) -> u32 {
    let b = x.to_bits();
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b | 0x8000_0000
    }
}

#[inline]
fn from_ordered(k: u32) -> f32 {
    f32::from_bits(if k & 0x8000_0000 != 0 { k & 0x7fff_ffff } else { !k })
}

/// Exact boundary between codes `k` and `k+1`: the smallest f32 `x` in
/// `[-1, 1]` with `reference_code(x) <= k`, or `+∞` when no such `x`
/// exists (code `k+1` and up unreachable from above). Seeded by the
/// analytic candidate, pinned by bit-level bisection of the reference map.
fn exact_threshold(k: u16, candidate: f32, bound: f32, scale: f32, code_at_neg1: u16) -> f32 {
    let lo_key = ordered(-1.0);
    let hi_key = ordered(1.0);
    if code_at_neg1 <= k {
        return -1.0; // every clamped ratio already qualifies
    }
    // code(1.0) == 0 always (θ = 0 clamps up to b, v = 0), so a qualifying
    // x exists for every k and the bracket below is well-founded.
    let code = |key: u32| reference_code(from_ordered(key), bound, scale);
    let c = ordered(candidate.clamp(-1.0, 1.0)).clamp(lo_key, hi_key);
    // Bracket [lo, hi] with code(lo) > k and code(hi) <= k, grown outward
    // from the candidate by ULP doubling (the analytic seed is within a
    // few ULPs, so this stays O(1) in practice).
    let (mut lo, mut hi) = if code(c) <= k {
        let mut hi = c;
        let mut d = 1u32;
        let lo = loop {
            let probe = c.saturating_sub(d).max(lo_key);
            if code(probe) > k {
                break probe; // also hit when probe == lo_key (checked above)
            }
            hi = probe;
            d = d.saturating_mul(2);
        };
        (lo, hi)
    } else {
        let mut lo = c;
        let mut d = 1u32;
        let hi = loop {
            let probe = c.saturating_add(d).min(hi_key);
            if code(probe) <= k {
                break probe; // code(hi_key) == 0 <= k guarantees termination
            }
            lo = probe;
            d = d.saturating_mul(2);
        };
        (lo, hi)
    };
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if code(mid) <= k {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    from_ordered(hi)
}

/// Build the descending threshold table for `(bits, bound)` into `out`.
/// `out[k] > x  ⟺  reference_code(x) > k`, so the code of `x` is the
/// count of thresholds above it. Public as a test/diagnostic hook.
// analyze: allow(hotpath): per-(bits,bound) table build, amortized across the round — not per-element
pub fn build_thresholds(bits: u8, bound: f32, out: &mut Vec<f32>) {
    let scale = scale_for(bits, bound);
    let max_code = (1u32 << bits) - 1;
    out.clear();
    out.reserve(max_code as usize);
    debug_assert!(scale > 0.0, "degenerate scale handled by the caller");
    let code_at_neg1 = reference_code(-1.0, bound, scale);
    let inv_scale = 1.0 / scale as f64;
    for k in 0..max_code {
        // Analytic seed: the angle edge between codes k and k+1.
        let edge = bound as f64 + (k as f64 + 0.5) * inv_scale;
        let candidate = edge.cos() as f32;
        out.push(exact_threshold(
            k as u16,
            candidate,
            bound,
            scale,
            code_at_neg1,
        ));
    }
}

/// Code for a pre-clamped ratio `x ∈ [-1, 1]`: the number of thresholds
/// strictly above `x`. Written as a conditional-move binary search so the
/// hot loop carries no unpredictable branches.
#[inline]
pub fn search_code(x: f32, thresholds: &[f32]) -> u16 {
    if thresholds.len() <= 32 {
        // Short tables (s ≤ 5, including the headline 4-bit case): a
        // branch-free count auto-vectorizes and beats the search.
        // NaN x: every comparison is false → code 0, matching the
        // reference's NaN → 0 saturating cast.
        let mut c = 0u32;
        for &t in thresholds {
            c += (t > x) as u32;
        }
        return c as u16;
    }
    // Invariant: the answer lies in [lo, lo + len]. Both arms assign `lo`
    // and `len` shrinks identically, so the compiler lowers the body to
    // conditional moves — no data-dependent branch per probe.
    let mut lo = 0usize;
    let mut len = thresholds.len();
    while len > 1 {
        let half = len / 2;
        let mid = lo + half;
        lo = if thresholds[mid] > x { mid } else { lo };
        len -= half;
    }
    (lo + (thresholds[lo] > x) as usize) as u16
}

/// Quantize `g` with the transcendental-free biased cosine kernel —
/// bit-identical to the reference `acos` path. The caller guarantees
/// `norm` is finite and positive (the zero/non-finite regime is handled
/// upstream, exactly as in the reference).
pub fn quantize_cosine_biased(
    g: &[f32],
    norm: f32,
    bound: f32,
    bits: u8,
    scratch: &mut KernelScratch,
    codes: &mut Vec<u16>,
) {
    codes.clear();
    codes.reserve(g.len());
    let scale = scale_for(bits, bound);
    let inv_norm = 1.0 / norm;
    if scale == 0.0 {
        // Degenerate range (all angles identical): the reference emits
        // v = 0 → code 0 everywhere.
        codes.resize(g.len(), 0);
        return;
    }
    let key = (bits, bound.to_bits());
    let table_cached = scratch.thresholds_key == Some(key);
    // The bound is data-dependent, so a fresh tensor usually means a fresh
    // table: ~2^s bisections at roughly 8 reference probes each. Below
    // that break-even (wide codes on small tensors) the reference loop is
    // cheaper — and identical by definition, so the choice is invisible.
    if !table_cached && (1usize << bits).saturating_mul(8) > g.len() {
        codes.extend(g.iter().map(|&gi| reference_code(gi * inv_norm, bound, scale)));
        return;
    }
    if !table_cached {
        build_thresholds(bits, bound, &mut scratch.thresholds);
        scratch.thresholds_key = Some(key);
    }
    let t = &scratch.thresholds[..];
    for &gi in g {
        // Same normalization + clamp as the reference; only the
        // acos→affine→round tail is replaced by the threshold search.
        let x = (gi * inv_norm).clamp(-1.0, 1.0);
        codes.push(search_code(x, t));
    }
}

/// Analytic quantization-MSE estimate for a cosine-quantized tensor —
/// the per-layer error signal the adaptive bit controller water-fills
/// against ([`crate::compress::allocator`]).
///
/// Per element the angle error is at most `step/2` where
/// `step = (π − 2b)/(2^s − 1)`, which maps to a value error of roughly
/// `‖g‖·step/2·|sin θ|`; averaging `sin²` over the quantization interval
/// gives the `n/3` factor — the same envelope the round-trip accuracy
/// tests assert (`sqrt(n/3)·q/2` relative error). This is an *estimate*
/// computable from wire-header scalars alone (`bits`, `bound`, `norm`,
/// `n`) — no payload access, no decode.
pub fn expected_mse(bits: u8, bound: f32, norm: f32, n: usize) -> f64 {
    if bits >= 32 || n == 0 {
        return 0.0; // float32 passthrough is lossless
    }
    let max_code = ((1u64 << bits) - 1) as f64;
    let range = (PI - 2.0 * bound).max(0.0) as f64;
    let step = range / max_code;
    let per_elem = norm as f64 * step / 2.0;
    n as f64 / 3.0 * per_elem * per_elem
}

// ---------------------------------------------------------------------------
// Dequantize LUTs.
// ---------------------------------------------------------------------------

/// Cosine reconstruction through a `2^s`-entry LUT — bit-identical to the
/// per-element `cos` formula (each entry IS that formula, evaluated once).
/// Falls back to the direct loop when the tensor is smaller than the
/// table it would amortize.
pub fn dequantize_cosine(
    codes: &[u16],
    norm: f32,
    bound: f32,
    bits: u8,
    scratch: &mut KernelScratch,
    out: &mut Vec<f32>,
) {
    out.clear();
    if norm == 0.0 {
        out.resize(codes.len(), 0.0);
        return;
    }
    let max_code = ((1u32 << bits) - 1) as f32;
    let step = (PI - 2.0 * bound) / max_code;
    let levels = 1usize << bits;
    if codes.len() < levels {
        // Small tensor: the direct loop is cheaper than building the LUT.
        // analyze: allow(hotpath): sub-LUT-size fallback, bounded at 2^bits elements
        out.extend(codes.iter().map(|&c| (bound + c as f32 * step).cos() * norm));
        return;
    }
    let key = (bits, norm.to_bits(), bound.to_bits());
    if scratch.cos_levels_key != Some(key) {
        scratch.cos_levels.clear();
        scratch
            .cos_levels
            // analyze: allow(hotpath): LUT seed — 2^bits cos calls amortized over the tensor
            .extend((0..levels).map(|c| (bound + c as f32 * step).cos() * norm));
        scratch.cos_levels_key = Some(key);
    }
    let lut = &scratch.cos_levels[..];
    out.extend(codes.iter().map(|&c| {
        // Codes from the wire are masked to `bits`, so the index is in
        // range; out-of-range codes from arbitrary callers fall back to
        // the reference formula rather than panicking.
        lut.get(c as usize)
            .copied()
            // analyze: allow(hotpath): unreachable-for-wire-codes reference fallback
            .unwrap_or_else(|| (bound + c as f32 * step).cos() * norm)
    }));
}

/// Fused cosine dequantize+accumulate: `acc[i] += value(code_i) · w`
/// without materializing the decoded vector. The per-element value is
/// computed exactly as [`dequantize_cosine`] computes it (same LUT cache,
/// same small-tensor fallback, same degenerate-norm zeros), and the fold
/// is the same `f32 → f64` mul-add the server's aggregation loop performs
/// — so fused-accumulate is **bit-identical** to decode-then-add
/// (asserted across bit widths in `tests/kernel_equivalence.rs`).
pub fn accumulate_cosine(
    codes: &[u16],
    norm: f32,
    bound: f32,
    bits: u8,
    scratch: &mut KernelScratch,
    w: f64,
    acc: &mut [f64],
) {
    debug_assert_eq!(codes.len(), acc.len());
    if norm == 0.0 {
        // Decode-then-add would fold in exact zeros; do the same adds so
        // the accumulator bits match (0.0·w is +0.0 for every w > 0).
        for a in acc.iter_mut() {
            *a += 0.0f64 * w;
        }
        return;
    }
    let max_code = ((1u32 << bits) - 1) as f32;
    let step = (PI - 2.0 * bound) / max_code;
    let levels = 1usize << bits;
    if codes.len() < levels {
        for (a, &c) in acc.iter_mut().zip(codes) {
            // analyze: allow(hotpath): sub-LUT-size fallback, bounded at 2^bits elements
            *a += ((bound + c as f32 * step).cos() * norm) as f64 * w;
        }
        return;
    }
    let key = (bits, norm.to_bits(), bound.to_bits());
    if scratch.cos_levels_key != Some(key) {
        scratch.cos_levels.clear();
        scratch
            .cos_levels
            // analyze: allow(hotpath): LUT seed — 2^bits cos calls amortized over the tensor
            .extend((0..levels).map(|c| (bound + c as f32 * step).cos() * norm));
        scratch.cos_levels_key = Some(key);
    }
    let lut = &scratch.cos_levels[..];
    for (a, &c) in acc.iter_mut().zip(codes) {
        let v = lut
            .get(c as usize)
            .copied()
            // analyze: allow(hotpath): unreachable-for-wire-codes reference fallback
            .unwrap_or_else(|| (bound + c as f32 * step).cos() * norm);
        *a += v as f64 * w;
    }
}

/// Linear reconstruction through a level LUT (same contract as
/// [`dequantize_cosine`], mirroring `linear::dequantize_codes`).
pub fn dequantize_linear(
    codes: &[u16],
    bound: f32,
    bits: u8,
    scratch: &mut KernelScratch,
    out: &mut Vec<f32>,
) {
    out.clear();
    if bound == 0.0 {
        out.resize(codes.len(), 0.0);
        return;
    }
    let max_code = ((1u32 << bits) - 1) as f32;
    let step = 2.0 * bound / max_code;
    let levels = 1usize << bits;
    if codes.len() < levels {
        out.extend(codes.iter().map(|&c| c as f32 * step - bound));
        return;
    }
    let key = (bits, bound.to_bits());
    if scratch.lin_levels_key != Some(key) {
        scratch.lin_levels.clear();
        scratch
            .lin_levels
            .extend((0..levels).map(|c| c as f32 * step - bound));
        scratch.lin_levels_key = Some(key);
    }
    let lut = &scratch.lin_levels[..];
    out.extend(codes.iter().map(|&c| {
        lut.get(c as usize)
            .copied()
            .unwrap_or_else(|| c as f32 * step - bound)
    }));
}

/// Fused linear dequantize+accumulate — the [`accumulate_cosine`]
/// contract for the linear level map (bit-identical to
/// [`dequantize_linear`] followed by the f64 fold).
pub fn accumulate_linear(
    codes: &[u16],
    bound: f32,
    bits: u8,
    scratch: &mut KernelScratch,
    w: f64,
    acc: &mut [f64],
) {
    debug_assert_eq!(codes.len(), acc.len());
    if bound == 0.0 {
        for a in acc.iter_mut() {
            *a += 0.0f64 * w;
        }
        return;
    }
    let max_code = ((1u32 << bits) - 1) as f32;
    let step = 2.0 * bound / max_code;
    let levels = 1usize << bits;
    if codes.len() < levels {
        for (a, &c) in acc.iter_mut().zip(codes) {
            *a += (c as f32 * step - bound) as f64 * w;
        }
        return;
    }
    let key = (bits, bound.to_bits());
    if scratch.lin_levels_key != Some(key) {
        scratch.lin_levels.clear();
        scratch
            .lin_levels
            .extend((0..levels).map(|c| c as f32 * step - bound));
        scratch.lin_levels_key = Some(key);
    }
    let lut = &scratch.lin_levels[..];
    for (a, &c) in acc.iter_mut().zip(codes) {
        let v = lut
            .get(c as usize)
            .copied()
            .unwrap_or_else(|| c as f32 * step - bound);
        *a += v as f64 * w;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_is_a_monotone_bijection() {
        let samples = [
            -1.0f32,
            -0.5,
            -f32::MIN_POSITIVE,
            -0.0,
            0.0,
            f32::MIN_POSITIVE,
            1e-40, // subnormal
            0.5,
            1.0,
        ];
        for w in samples.windows(2) {
            assert!(ordered(w[0]) <= ordered(w[1]), "{} vs {}", w[0], w[1]);
        }
        for &x in &samples {
            assert_eq!(from_ordered(ordered(x)).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn search_counts_thresholds_above() {
        let t = [0.8f32, 0.4, 0.1, -0.3, -0.9]; // descending
        assert_eq!(search_code(0.9, &t), 0);
        assert_eq!(search_code(0.8, &t), 0); // not strictly above
        assert_eq!(search_code(0.5, &t), 1);
        assert_eq!(search_code(0.0, &t), 3);
        assert_eq!(search_code(-1.0, &t), 5);
        assert_eq!(search_code(f32::NAN, &t), 0);
        assert_eq!(search_code(0.5, &[]), 0);
        // Long table (binary-search path) agrees with the linear count.
        let long: Vec<f32> = (0..100).map(|i| 1.0 - i as f32 * 0.02).collect();
        for x in [-1.5f32, -1.0, -0.011, 0.0, 0.3, 0.999, 1.0, 2.0] {
            let linear = long.iter().filter(|&&t| t > x).count() as u16;
            assert_eq!(search_code(x, &long), linear, "x={x}");
        }
    }

    #[test]
    fn thresholds_are_descending_and_exact() {
        for bits in [1u8, 2, 4, 8] {
            for bound in [0.0f32, 0.3, 1.2] {
                let scale = scale_for(bits, bound);
                let mut t = Vec::new();
                build_thresholds(bits, bound, &mut t);
                assert_eq!(t.len(), (1usize << bits) - 1);
                for w in t.windows(2) {
                    assert!(w[0] >= w[1], "bits={bits} bound={bound}: {w:?}");
                }
                // Each finite threshold is the exact cutover of the
                // reference map.
                for (k, &tk) in t.iter().enumerate() {
                    if !tk.is_finite() {
                        continue;
                    }
                    assert!(
                        reference_code(tk, bound, scale) <= k as u16,
                        "bits={bits} bound={bound} k={k}: t_k does not qualify"
                    );
                    if tk > -1.0 {
                        let below = from_ordered(ordered(tk) - 1);
                        assert!(
                            reference_code(below, bound, scale) > k as u16,
                            "bits={bits} bound={bound} k={k}: t_k not minimal"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn expected_mse_tracks_width_and_energy() {
        // Monotone decreasing in bits, quadratic in norm, linear in n.
        let base = expected_mse(4, 0.1, 1.0, 1000);
        assert!(base > 0.0);
        assert!(expected_mse(5, 0.1, 1.0, 1000) < base);
        assert!(expected_mse(3, 0.1, 1.0, 1000) > base);
        assert!((expected_mse(4, 0.1, 2.0, 1000) / base - 4.0).abs() < 1e-9);
        assert!((expected_mse(4, 0.1, 1.0, 2000) / base - 2.0).abs() < 1e-9);
        // Lossless and degenerate cases.
        assert_eq!(expected_mse(32, 0.1, 1.0, 1000), 0.0);
        assert_eq!(expected_mse(4, 0.1, 1.0, 0), 0.0);
        // A wider bound shrinks the quantized range and the error.
        assert!(expected_mse(4, 0.5, 1.0, 1000) < base);
    }

    #[test]
    fn degenerate_scale_emits_zero_codes() {
        let g = [0.5f32, -0.5, 0.25];
        let mut scratch = KernelScratch::new();
        let mut codes = Vec::new();
        // bound ≈ π/2 ⇒ range below the reference's 1e-6 floor.
        let bound = PI / 2.0 - 1e-8;
        assert_eq!(scale_for(4, bound), 0.0);
        quantize_cosine_biased(&g, 1.0, bound, 4, &mut scratch, &mut codes);
        assert_eq!(codes, vec![0, 0, 0]);
    }
}
