//! The [`Quantizer`] trait — the single extension point for lossy
//! compression schemes.
//!
//! Every scheme (the paper's cosine quantizer, the linear baselines, the
//! sign family, and the float32 passthrough) is an `impl Quantizer`; the
//! [`super::pipeline::Pipeline`] composes one quantizer with the lossless /
//! structural stages (sparsify → rotate → quantize → bit-pack → DEFLATE).
//! Adding a new scheme is a drop-in impl plus one line in [`from_wire`] —
//! no enum surgery across encode/decode/name/cost sites.
//!
//! ## Wire identity
//!
//! A quantizer is identified on the wire by `(id, bits)`; the two scalar
//! side-infos (`norm`, `bound`) travel in the [`super::wire`] header. The
//! server reconstructs a dequantizer from the header alone via
//! [`from_wire`] — decode never consults the sender's configuration.

use std::any::Any;

use anyhow::{bail, Result};

use crate::util::rng::Pcg64;
use crate::util::stats::l2_norm;

use super::cosine::{self, BoundMode, CosineQuantizer, Rounding};
use super::kernel::KernelScratch;
use super::linear::{self, LinearQuantizer, ValueBound};
use super::signsgd;

/// Stable wire ids. Id 3 belonged to CSG1's fused "linear-rotated" kind;
/// rotation is a [`super::pipeline::Pipeline`] stage (wire flag) since
/// CSG2, so 3 is permanently retired.
pub mod ids {
    pub const FLOAT32: u8 = 0;
    pub const COSINE: u8 = 1;
    pub const LINEAR: u8 = 2;
    pub const SIGN: u8 = 4;
    pub const SIGN_NORM: u8 = 5;
    pub const EF_SIGN: u8 = 6;
}

/// The output of [`Quantizer::quantize`]: one code per input element plus
/// the (at most two) scalars the receiver needs to invert the mapping.
#[derive(Debug, Clone, PartialEq)]
pub struct Quantized {
    pub codes: Vec<u16>,
    /// First side-info scalar (‖g‖₂ for norm-based schemes, else 0).
    pub norm: f32,
    /// Second side-info scalar (angle/value bound or sign scale, else 0).
    pub bound: f32,
}

/// A lossy value↔code mapping, symmetric across directions: the same
/// trait quantizes uplink gradients and downlink model deltas.
pub trait Quantizer: std::fmt::Debug + Send + Sync {
    /// Stable wire id (see [`ids`]).
    fn id(&self) -> u8;

    /// Bits per transmitted code. `32` means "raw float32 payload": the
    /// pipeline serializes values directly and skips bit-packing.
    fn bits(&self) -> u8;

    /// Short human name (figure labels / CLI).
    fn name(&self) -> String;

    /// Map values to codes + side info. `rng` drives stochastic rounding;
    /// deterministic schemes ignore it.
    fn quantize(&self, values: &[f32], rng: &mut Pcg64) -> Quantized;

    /// Invert [`Self::quantize`] from codes + side info. Must not depend
    /// on encode-side configuration beyond `(id, bits)` — the receiver
    /// reconstructs the quantizer via [`from_wire`].
    fn dequantize(&self, codes: &[u16], norm: f32, bound: f32) -> Vec<f32>;

    /// Bit-identical to [`Self::quantize`], writing codes into a reusable
    /// buffer and drawing per-tensor tables from `scratch` — the
    /// steady-state pipeline entry point. Returns `(norm, bound)`. The
    /// default delegates to [`Self::quantize`] (one allocation); in-tree
    /// schemes override with true in-place fast paths.
    fn quantize_into(
        &self,
        values: &[f32],
        rng: &mut Pcg64,
        _scratch: &mut KernelScratch,
        codes: &mut Vec<u16>,
    ) -> (f32, f32) {
        let q = self.quantize(values, rng);
        codes.clear();
        codes.extend_from_slice(&q.codes);
        (q.norm, q.bound)
    }

    /// Bit-identical to [`Self::dequantize`], writing into a reusable
    /// buffer (LUT-backed for the table-friendly schemes).
    fn dequantize_into(
        &self,
        codes: &[u16],
        norm: f32,
        bound: f32,
        _scratch: &mut KernelScratch,
        out: &mut Vec<f32>,
    ) {
        let v = self.dequantize(codes, norm, bound);
        out.clear();
        out.extend_from_slice(&v);
    }

    /// Fused dequantize+accumulate: `acc[i] += value(code_i)·w` without
    /// materializing the decoded vector — the server's frame-ingest hot
    /// path (one pass over the packed codes per client, no intermediate
    /// `Vec<f32>`). Must be **bit-identical** to [`Self::dequantize_into`]
    /// followed by the `f32 → f64` mul-add fold. The default decodes then
    /// folds (one allocation); in-tree schemes override with true fused
    /// paths over the shared LUTs.
    ///
    /// Sub-slice caveat: callers may pass a *contiguous sub-range* of a
    /// tensor's codes (the sharded ingest plane does), which is exact for
    /// every scheme whose per-element value depends only on wire-header
    /// scalars. signSGD+Norm is the exception — its magnitude is
    /// `norm/√codes.len()`, so sub-range folds must compute the magnitude
    /// from the full tensor length and call
    /// [`super::signsgd::accumulate_signs`] directly (see
    /// [`super::pipeline::accumulate_range_with`]).
    fn accumulate_into(
        &self,
        codes: &[u16],
        norm: f32,
        bound: f32,
        _scratch: &mut KernelScratch,
        w: f64,
        acc: &mut [f64],
    ) {
        let v = self.dequantize(codes, norm, bound);
        for (a, &x) in acc.iter_mut().zip(&v) {
            *a += x as f64 * w;
        }
    }

    /// Downcast support (e.g. the Pallas kernel path needs the concrete
    /// [`CosineQuantizer`] configuration).
    fn as_any(&self) -> &dyn Any;
}

/// Check a wire identity without constructing anything (header
/// validation on the receive hot path).
pub fn validate_wire(id: u8, bits: u8) -> Result<()> {
    match id {
        ids::FLOAT32 => {
            if bits != 32 {
                bail!("float32 passthrough requires bits=32, got {bits}");
            }
        }
        ids::COSINE | ids::LINEAR => {
            if !(1..=16).contains(&bits) {
                bail!("bad code width {bits} for quantizer id {id}");
            }
        }
        ids::SIGN | ids::SIGN_NORM | ids::EF_SIGN => {
            if bits != 1 {
                bail!("sign-family quantizer id {id} requires bits=1, got {bits}");
            }
        }
        other => bail!("unknown quantizer id {other}"),
    }
    Ok(())
}

/// Reconstruct a dequantizer from its wire identity. Together with
/// [`validate_wire`] this is the one registry to extend when adding an
/// `impl Quantizer`.
pub fn from_wire(id: u8, bits: u8) -> Result<Box<dyn Quantizer>> {
    validate_wire(id, bits)?;
    Ok(match id {
        ids::FLOAT32 => Box::new(Float32Passthrough),
        ids::COSINE => Box::new(CosineQuantizer::new(bits, Rounding::Biased, BoundMode::Auto)),
        ids::LINEAR => Box::new(LinearQuantizer::new(bits, Rounding::Biased, ValueBound::MaxAbs)),
        ids::SIGN => Box::new(SignSgd),
        ids::SIGN_NORM => Box::new(SignSgdNorm),
        ids::EF_SIGN => Box::new(EfSign),
        other => bail!("unknown quantizer id {other}"),
    })
}

/// Fused dequantize+accumulate straight from a wire identity — the boxless
/// twin of `from_wire(id, bits)?.accumulate_into(..)`, dispatching to the
/// per-scheme fused kernels without constructing a `Box<dyn Quantizer>`
/// per call. The server's per-tensor ingest folds run once per
/// (client, tensor) inside the hot loop, where a heap allocation is
/// exactly what the `hotloop_alloc` analyzer rule rejects. Bit-identical
/// to the trait path (pinned in `accumulate_wire_matches_trait_path`).
/// Float32 frames have no packed codes, so they have no fused accumulate
/// and decode via the raw payload path instead.
#[allow(clippy::too_many_arguments)]
pub fn accumulate_wire(
    id: u8,
    bits: u8,
    codes: &[u16],
    norm: f32,
    bound: f32,
    scratch: &mut KernelScratch,
    w: f64,
    acc: &mut [f64],
) -> Result<()> {
    validate_wire(id, bits)?;
    match id {
        ids::COSINE => super::kernel::accumulate_cosine(codes, norm, bound, bits, scratch, w, acc),
        ids::LINEAR => super::kernel::accumulate_linear(codes, bound, bits, scratch, w, acc),
        ids::SIGN => signsgd::accumulate_signs(codes, 1.0, w, acc),
        ids::SIGN_NORM => {
            let mag = norm / (codes.len().max(1) as f32).sqrt();
            signsgd::accumulate_signs(codes, mag, w, acc);
        }
        ids::EF_SIGN => signsgd::accumulate_signs(codes, bound, w, acc),
        other => bail!("quantizer id {other} has no fused wire accumulate"),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Trait impls for the in-tree schemes.
// ---------------------------------------------------------------------------

impl Quantizer for CosineQuantizer {
    fn id(&self) -> u8 {
        ids::COSINE
    }

    fn bits(&self) -> u8 {
        self.bits
    }

    fn name(&self) -> String {
        format!(
            "cosine-{}{}",
            self.bits,
            if self.rounding == Rounding::Unbiased { " (U)" } else { "" }
        )
    }

    fn quantize(&self, values: &[f32], rng: &mut Pcg64) -> Quantized {
        let q = CosineQuantizer::quantize(self, values, rng);
        Quantized {
            codes: q.codes,
            norm: q.norm,
            bound: q.bound,
        }
    }

    fn dequantize(&self, codes: &[u16], norm: f32, bound: f32) -> Vec<f32> {
        cosine::dequantize_codes(codes, norm, bound, self.bits)
    }

    fn quantize_into(
        &self,
        values: &[f32],
        rng: &mut Pcg64,
        scratch: &mut KernelScratch,
        codes: &mut Vec<u16>,
    ) -> (f32, f32) {
        CosineQuantizer::quantize_into(self, values, rng, scratch, codes)
    }

    fn dequantize_into(
        &self,
        codes: &[u16],
        norm: f32,
        bound: f32,
        scratch: &mut KernelScratch,
        out: &mut Vec<f32>,
    ) {
        cosine::dequantize_codes_into(codes, norm, bound, self.bits, scratch, out);
    }

    fn accumulate_into(
        &self,
        codes: &[u16],
        norm: f32,
        bound: f32,
        scratch: &mut KernelScratch,
        w: f64,
        acc: &mut [f64],
    ) {
        super::kernel::accumulate_cosine(codes, norm, bound, self.bits, scratch, w, acc);
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

impl Quantizer for LinearQuantizer {
    fn id(&self) -> u8 {
        ids::LINEAR
    }

    fn bits(&self) -> u8 {
        self.bits
    }

    fn name(&self) -> String {
        format!(
            "linear-{}{}",
            self.bits,
            if self.rounding == Rounding::Unbiased { " (U)" } else { "" }
        )
    }

    fn quantize(&self, values: &[f32], rng: &mut Pcg64) -> Quantized {
        let q = LinearQuantizer::quantize(self, values, rng);
        Quantized {
            codes: q.codes,
            norm: 0.0,
            bound: q.bound,
        }
    }

    fn dequantize(&self, codes: &[u16], _norm: f32, bound: f32) -> Vec<f32> {
        linear::dequantize_codes(codes, bound, self.bits)
    }

    fn quantize_into(
        &self,
        values: &[f32],
        rng: &mut Pcg64,
        _scratch: &mut KernelScratch,
        codes: &mut Vec<u16>,
    ) -> (f32, f32) {
        let bound = LinearQuantizer::quantize_into(self, values, rng, codes);
        (0.0, bound)
    }

    fn dequantize_into(
        &self,
        codes: &[u16],
        _norm: f32,
        bound: f32,
        scratch: &mut KernelScratch,
        out: &mut Vec<f32>,
    ) {
        linear::dequantize_codes_into(codes, bound, self.bits, scratch, out);
    }

    fn accumulate_into(
        &self,
        codes: &[u16],
        _norm: f32,
        bound: f32,
        scratch: &mut KernelScratch,
        w: f64,
        acc: &mut [f64],
    ) {
        super::kernel::accumulate_linear(codes, bound, self.bits, scratch, w, acc);
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// No quantization: the pipeline serializes raw little-endian float32
/// values (the paper's baseline). `quantize`/`dequantize` are identity
/// stubs — the pipeline short-circuits on `bits() == 32`.
#[derive(Debug, Clone, Copy, Default)]
pub struct Float32Passthrough;

impl Quantizer for Float32Passthrough {
    fn id(&self) -> u8 {
        ids::FLOAT32
    }

    fn bits(&self) -> u8 {
        32
    }

    fn name(&self) -> String {
        "float32".into()
    }

    fn quantize(&self, _values: &[f32], _rng: &mut Pcg64) -> Quantized {
        Quantized {
            codes: Vec::new(),
            norm: 0.0,
            bound: 0.0,
        }
    }

    fn dequantize(&self, _codes: &[u16], _norm: f32, _bound: f32) -> Vec<f32> {
        Vec::new()
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// signSGD [4]: signs only, unit magnitude (the server folds the step size
/// into η_s).
#[derive(Debug, Clone, Copy, Default)]
pub struct SignSgd;

impl Quantizer for SignSgd {
    fn id(&self) -> u8 {
        ids::SIGN
    }

    fn bits(&self) -> u8 {
        1
    }

    fn name(&self) -> String {
        "signSGD".into()
    }

    fn quantize(&self, values: &[f32], _rng: &mut Pcg64) -> Quantized {
        Quantized {
            codes: signsgd::sign_codes(values),
            norm: 0.0,
            bound: 0.0,
        }
    }

    fn dequantize(&self, codes: &[u16], _norm: f32, _bound: f32) -> Vec<f32> {
        signsgd::decode_sign(codes)
    }

    fn quantize_into(
        &self,
        values: &[f32],
        _rng: &mut Pcg64,
        _scratch: &mut KernelScratch,
        codes: &mut Vec<u16>,
    ) -> (f32, f32) {
        signsgd::sign_codes_into(values, codes);
        (0.0, 0.0)
    }

    fn dequantize_into(
        &self,
        codes: &[u16],
        _norm: f32,
        _bound: f32,
        _scratch: &mut KernelScratch,
        out: &mut Vec<f32>,
    ) {
        signsgd::decode_signs_into(codes, 1.0, out);
    }

    fn accumulate_into(
        &self,
        codes: &[u16],
        _norm: f32,
        _bound: f32,
        _scratch: &mut KernelScratch,
        w: f64,
        acc: &mut [f64],
    ) {
        signsgd::accumulate_signs(codes, 1.0, w, acc);
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// signSGD+Norm [43]: signs plus ‖g‖₂, reconstructed as
/// `sign(g)·‖g‖₂/√n` — exactly CosSGD's 1-bit degenerate case.
#[derive(Debug, Clone, Copy, Default)]
pub struct SignSgdNorm;

impl Quantizer for SignSgdNorm {
    fn id(&self) -> u8 {
        ids::SIGN_NORM
    }

    fn bits(&self) -> u8 {
        1
    }

    fn name(&self) -> String {
        "signSGD+Norm".into()
    }

    fn quantize(&self, values: &[f32], _rng: &mut Pcg64) -> Quantized {
        Quantized {
            codes: signsgd::sign_codes(values),
            norm: l2_norm(values) as f32,
            bound: 0.0,
        }
    }

    fn dequantize(&self, codes: &[u16], norm: f32, _bound: f32) -> Vec<f32> {
        signsgd::decode_sign_norm(codes, norm)
    }

    fn quantize_into(
        &self,
        values: &[f32],
        _rng: &mut Pcg64,
        _scratch: &mut KernelScratch,
        codes: &mut Vec<u16>,
    ) -> (f32, f32) {
        signsgd::sign_codes_into(values, codes);
        (l2_norm(values) as f32, 0.0)
    }

    fn dequantize_into(
        &self,
        codes: &[u16],
        norm: f32,
        _bound: f32,
        _scratch: &mut KernelScratch,
        out: &mut Vec<f32>,
    ) {
        let mag = norm / (codes.len().max(1) as f32).sqrt();
        signsgd::decode_signs_into(codes, mag, out);
    }

    fn accumulate_into(
        &self,
        codes: &[u16],
        norm: f32,
        _bound: f32,
        _scratch: &mut KernelScratch,
        w: f64,
        acc: &mut [f64],
    ) {
        let mag = norm / (codes.len().max(1) as f32).sqrt();
        signsgd::accumulate_signs(codes, mag, w, acc);
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// The inner scheme of EF-signSGD [15]: `(‖v‖₁/n)·sign(v)`. Pair it with
/// [`super::pipeline::Pipeline::with_error_feedback`] to get the published
/// algorithm — the residual memory lives in the pipeline state, not here.
#[derive(Debug, Clone, Copy, Default)]
pub struct EfSign;

impl Quantizer for EfSign {
    fn id(&self) -> u8 {
        ids::EF_SIGN
    }

    fn bits(&self) -> u8 {
        1
    }

    fn name(&self) -> String {
        // Distinct from plain signSGD (id 4): the magnitude is the l1 mean.
        "signSGD(l1)".into()
    }

    fn quantize(&self, values: &[f32], _rng: &mut Pcg64) -> Quantized {
        let n = values.len().max(1);
        let scale = values.iter().map(|x| x.abs()).sum::<f32>() / n as f32;
        Quantized {
            codes: signsgd::sign_codes(values),
            norm: 0.0,
            bound: scale,
        }
    }

    fn dequantize(&self, codes: &[u16], _norm: f32, bound: f32) -> Vec<f32> {
        signsgd::decode_ef(codes, bound)
    }

    fn quantize_into(
        &self,
        values: &[f32],
        _rng: &mut Pcg64,
        _scratch: &mut KernelScratch,
        codes: &mut Vec<u16>,
    ) -> (f32, f32) {
        let n = values.len().max(1);
        let scale = values.iter().map(|x| x.abs()).sum::<f32>() / n as f32;
        signsgd::sign_codes_into(values, codes);
        (0.0, scale)
    }

    fn dequantize_into(
        &self,
        codes: &[u16],
        _norm: f32,
        bound: f32,
        _scratch: &mut KernelScratch,
        out: &mut Vec<f32>,
    ) {
        signsgd::decode_signs_into(codes, bound, out);
    }

    fn accumulate_into(
        &self,
        codes: &[u16],
        _norm: f32,
        bound: f32,
        _scratch: &mut KernelScratch,
        w: f64,
        acc: &mut [f64],
    ) {
        signsgd::accumulate_signs(codes, bound, w, acc);
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::gradient_like;

    #[test]
    fn wire_registry_covers_all_ids() {
        assert_eq!(from_wire(ids::FLOAT32, 32).unwrap().name(), "float32");
        assert_eq!(from_wire(ids::COSINE, 4).unwrap().bits(), 4);
        assert_eq!(from_wire(ids::LINEAR, 2).unwrap().id(), ids::LINEAR);
        assert_eq!(from_wire(ids::SIGN, 1).unwrap().bits(), 1);
        assert_eq!(from_wire(ids::SIGN_NORM, 1).unwrap().id(), ids::SIGN_NORM);
        assert_eq!(from_wire(ids::EF_SIGN, 1).unwrap().id(), ids::EF_SIGN);
    }

    #[test]
    fn wire_registry_rejects_bad_identities() {
        assert!(from_wire(3, 2).is_err()); // retired CSG1 linear-rotated
        assert!(from_wire(7, 2).is_err()); // unknown
        assert!(from_wire(ids::FLOAT32, 8).is_err()); // passthrough must be 32-bit
        assert!(from_wire(ids::COSINE, 0).is_err());
        assert!(from_wire(ids::COSINE, 17).is_err());
        assert!(from_wire(ids::SIGN, 2).is_err()); // sign family is 1-bit
        // The allocation-free validator agrees with the constructor.
        assert!(validate_wire(ids::COSINE, 4).is_ok());
        assert!(validate_wire(3, 2).is_err());
        assert!(validate_wire(ids::FLOAT32, 8).is_err());
    }

    #[test]
    fn trait_roundtrip_matches_inherent_api() {
        let mut rng = Pcg64::seeded(71);
        let g = gradient_like(&mut rng, 2048);
        let q = CosineQuantizer::paper_default(4);
        let via_trait = Quantizer::quantize(&q, &g, &mut Pcg64::seeded(5));
        let inherent = CosineQuantizer::quantize(&q, &g, &mut Pcg64::seeded(5));
        assert_eq!(via_trait.codes, inherent.codes);
        assert_eq!(via_trait.norm, inherent.norm);
        assert_eq!(via_trait.bound, inherent.bound);
        let back = q.dequantize(&via_trait.codes, via_trait.norm, via_trait.bound);
        assert_eq!(back, inherent.dequantize());
    }

    #[test]
    fn sign_family_side_info() {
        let mut rng = Pcg64::seeded(72);
        let g = vec![1.0f32, -2.0, 3.0, -4.0];
        let qn = Quantizer::quantize(&SignSgdNorm, &g, &mut rng);
        assert!((qn.norm - (30.0f32).sqrt()).abs() < 1e-5);
        let qe = Quantizer::quantize(&EfSign, &g, &mut rng);
        assert!((qe.bound - 2.5).abs() < 1e-6); // ℓ1 mean
        assert_eq!(qe.codes, vec![1, 0, 1, 0]);
        assert_eq!(EfSign.dequantize(&qe.codes, 0.0, qe.bound), vec![2.5, -2.5, 2.5, -2.5]);
    }

    #[test]
    fn into_variants_match_allocating_api() {
        // The scratch-buffer fast paths must be bit-identical to the
        // allocating trait methods for every scheme, including when the
        // scratch is reused across schemes (stale-table hazard).
        let mut rng = Pcg64::seeded(74);
        let g = gradient_like(&mut rng, 700);
        let schemes: Vec<Box<dyn Quantizer>> = vec![
            Box::new(CosineQuantizer::paper_default(4)),
            Box::new(CosineQuantizer::new(3, Rounding::Unbiased, BoundMode::Auto)),
            Box::new(LinearQuantizer::biased(8)),
            Box::new(SignSgd),
            Box::new(SignSgdNorm),
            Box::new(EfSign),
        ];
        let mut scratch = KernelScratch::new();
        let mut codes = Vec::new();
        let mut out = Vec::new();
        for q in schemes {
            let a = q.quantize(&g, &mut Pcg64::seeded(9));
            let (norm, bound) =
                q.quantize_into(&g, &mut Pcg64::seeded(9), &mut scratch, &mut codes);
            assert_eq!(codes, a.codes, "{}", q.name());
            assert_eq!(norm.to_bits(), a.norm.to_bits(), "{}", q.name());
            assert_eq!(bound.to_bits(), a.bound.to_bits(), "{}", q.name());
            let d = q.dequantize(&a.codes, a.norm, a.bound);
            q.dequantize_into(&codes, norm, bound, &mut scratch, &mut out);
            assert_eq!(out, d, "{}", q.name());
        }
    }

    #[test]
    fn accumulate_wire_matches_trait_path() {
        let mut rng = Pcg64::seeded(75);
        let g = gradient_like(&mut rng, 600);
        let cases: Vec<(u8, u8, Box<dyn Quantizer>)> = vec![
            (ids::COSINE, 4, Box::new(CosineQuantizer::paper_default(4))),
            (ids::LINEAR, 8, Box::new(LinearQuantizer::biased(8))),
            (ids::SIGN, 1, Box::new(SignSgd)),
            (ids::SIGN_NORM, 1, Box::new(SignSgdNorm)),
            (ids::EF_SIGN, 1, Box::new(EfSign)),
        ];
        let mut scratch = KernelScratch::new();
        for (id, bits, q) in cases {
            let a = q.quantize(&g, &mut Pcg64::seeded(11));
            let mut via_trait = vec![0.25f64; g.len()];
            let mut via_wire = via_trait.clone();
            q.accumulate_into(&a.codes, a.norm, a.bound, &mut scratch, 0.7, &mut via_trait);
            accumulate_wire(id, bits, &a.codes, a.norm, a.bound, &mut scratch, 0.7, &mut via_wire)
                .unwrap();
            let same = via_trait
                .iter()
                .zip(&via_wire)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "{}", q.name());
        }
        assert!(
            accumulate_wire(ids::FLOAT32, 32, &[], 0.0, 0.0, &mut scratch, 1.0, &mut []).is_err()
        );
    }

    #[test]
    fn dequantize_via_registry_matches_direct() {
        let mut rng = Pcg64::seeded(73);
        let g = gradient_like(&mut rng, 513);
        let q = LinearQuantizer::biased(8);
        let quant = Quantizer::quantize(&q, &g, &mut rng);
        let reg = from_wire(ids::LINEAR, 8).unwrap();
        assert_eq!(
            reg.dequantize(&quant.codes, quant.norm, quant.bound),
            q.dequantize(&quant.codes, quant.norm, quant.bound)
        );
    }
}
