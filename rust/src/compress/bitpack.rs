//! Dense bit-packing of quantization codes.
//!
//! Quantizers emit one code in `0..2^s` per kept gradient element
//! (`s` ∈ 1..=16). On the wire each code occupies exactly `s` bits,
//! LSB-first within a little-endian bit stream — the format DEFLATE then
//! compresses further.

/// Pack `codes` (each `< 2^bits`) into a byte vector, LSB-first.
pub fn pack(codes: &[u16], bits: u8) -> Vec<u8> {
    assert!((1..=16).contains(&bits), "bits must be in 1..=16");
    let bits = bits as u32;
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut acc: u32 = 0; // bit accumulator
    let mut nbits: u32 = 0; // valid bits in acc
    let mut pos = 0usize; // next output byte
    for &c in codes {
        debug_assert!(
            (c as u32) < (1u32 << bits),
            "code {c} does not fit in {bits} bits"
        );
        acc |= (c as u32) << nbits;
        nbits += bits;
        while nbits >= 8 {
            out[pos] = acc as u8;
            pos += 1;
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out[pos] = acc as u8;
    }
    out
}

/// Unpack `n` codes of `bits` bits each from `bytes`.
pub fn unpack(bytes: &[u8], bits: u8, n: usize) -> Vec<u16> {
    assert!((1..=16).contains(&bits), "bits must be in 1..=16");
    let bits = bits as u32;
    let needed = (n * bits as usize).div_ceil(8);
    assert!(
        bytes.len() >= needed,
        "unpack: need {needed} bytes for {n} codes of {bits} bits, got {}",
        bytes.len()
    );
    let mask: u32 = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
    let mut out = Vec::with_capacity(n);
    let mut acc: u32 = 0;
    let mut nbits: u32 = 0;
    let mut pos = 0usize;
    for _ in 0..n {
        while nbits < bits {
            acc |= (bytes[pos] as u32) << nbits;
            pos += 1;
            nbits += 8;
        }
        out.push((acc & mask) as u16);
        acc >>= bits;
        nbits -= bits;
    }
    out
}

/// Number of payload bytes for `n` codes at `bits` bits each.
pub fn packed_len(n: usize, bits: u8) -> usize {
    (n * bits as usize).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Pcg64::seeded(21);
        for bits in 1..=16u8 {
            let n = 1 + rng.below_usize(500);
            let max = 1u32 << bits;
            let codes: Vec<u16> = (0..n).map(|_| rng.below(max as u64) as u16).collect();
            let packed = pack(&codes, bits);
            assert_eq!(packed.len(), packed_len(n, bits));
            assert_eq!(unpack(&packed, bits, n), codes, "bits={bits} n={n}");
        }
    }

    #[test]
    fn two_bit_layout_is_lsb_first() {
        // codes [1,2,3,0] at 2 bits -> byte 0b00_11_10_01 = 0x39
        assert_eq!(pack(&[1, 2, 3, 0], 2), vec![0x39]);
        assert_eq!(unpack(&[0x39], 2, 4), vec![1, 2, 3, 0]);
    }

    #[test]
    fn one_bit_layout() {
        // codes [1,0,0,0, 0,0,0,1, 1] -> bytes [0b1000_0001, 0b0000_0001]
        assert_eq!(pack(&[1, 0, 0, 0, 0, 0, 0, 1, 1], 1), vec![0x81, 0x01]);
    }

    #[test]
    fn empty_input() {
        assert!(pack(&[], 4).is_empty());
        assert!(unpack(&[], 4, 0).is_empty());
    }

    #[test]
    fn property_roundtrip() {
        forall(
            100,
            22,
            |rng, size| {
                let bits = 1 + rng.below(16) as u8;
                let n = size.len(rng) * 4;
                let codes: Vec<u16> =
                    (0..n).map(|_| rng.below(1u64 << bits) as u16).collect();
                (bits, codes)
            },
            |(bits, codes)| unpack(&pack(codes, *bits), *bits, codes.len()) == *codes,
        );
    }

    #[test]
    #[should_panic(expected = "bits must be in 1..=16")]
    fn rejects_zero_bits() {
        pack(&[0], 0);
    }
}
